//! End-to-end validation driver: train a 2-layer GCN on a synthetic
//! power-law graph through the full stack — functional-RA model,
//! relational autodiff (graph mode: the generated backward query), and
//! the distributed BSP executor — driven entirely through the stateful
//! [`Session`] front door: the graph tables live in the session catalog
//! (partitioned once), the parameters are *named* slots re-homed per
//! step, and every evaluation shares the session's worker pool.
//!
//! Run: `cargo run --release --example train_gcn [-- steps=300 workers=4]`

use relad::data::graphs::power_law_graph;
use relad::dist::{ClusterConfig, MemPolicy};
use relad::ml::gcn::{self, GcnConfig};
use relad::ml::{Adam, SlotLayout};
use relad::session::{ModelSpec, Session};
use relad::util::Prng;

fn arg(name: &str, default: usize) -> usize {
    std::env::args()
        .find_map(|a| a.strip_prefix(&format!("{name}=")).map(|v| v.to_string()))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let steps = arg("steps", 300);
    let workers = arg("workers", 4);

    // ~arxiv-flavoured graph: 4k nodes, 22k edges, 64-d features, 40
    // classes; model = 64→64→40 (≈ 6.7k parameters — scaled to the
    // virtual cluster; the same driver runs the 1/24-scale datasets in
    // the table benches).
    let g = power_law_graph("e2e", 4000, 22_000, 64, 40, 0.3, 7);
    let cfg = GcnConfig {
        feat_dim: 64,
        hidden: 64,
        n_labels: 40,
        dropout: None, // deterministic loss curve
        seed: 9,
    };
    println!(
        "graph: |V|={} |E|={} labeled={}  model: {}→{}→{} ({} params)  workers={workers}",
        g.n_nodes,
        g.n_edges,
        g.labeled.len(),
        cfg.feat_dim,
        cfg.hidden,
        cfg.n_labels,
        cfg.feat_dim * cfg.hidden + cfg.hidden * cfg.n_labels,
    );

    // The session owns cluster, catalog, and pool. Data tables are
    // partitioned once at registration (edges on the destination vertex)
    // — the catalog is the cross-step partition cache.
    let mut sess = Session::new(
        ClusterConfig::new(workers).with_policy(MemPolicy::Spill),
    );
    sess.register_with_layout("Edge", &["dst", "src"], &g.edges, &SlotLayout::HashOn(vec![0]))?;
    sess.register("Node", &["id"], &g.feats)?;
    sess.register("Y", &["id"], &g.labels)?;

    let q = gcn::loss_query(&cfg, g.labels.len());
    let mut trainer = sess.trainer(ModelSpec::new(q).param("W1", 1).param("W2", 1))?;
    println!(
        "generated backward query: {} operators ({:?})",
        trainer.compiled().bwd.query.len(),
        trainer.compiled().bwd.query.op_counts()
    );

    let mut rng = Prng::new(3);
    let (mut w1, mut w2) = gcn::init_params(&cfg, &mut rng);
    let mut adam = Adam::new(0.02);

    let mut first = None;
    let mut last = 0.0;
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let res = trainer.step(&[("W1", &w1), ("W2", &w2)])?;
        for (name, grel) in &res.grads {
            match name.as_str() {
                "W1" => adam.step(&mut w1, grel),
                "W2" => adam.step(&mut w2, grel),
                _ => {}
            }
        }
        first.get_or_insert(res.loss);
        last = res.loss;
        if step % 25 == 0 || step == steps - 1 {
            println!("step {step:>4}  loss {:.5}", res.loss);
        }
    }
    let first = first.unwrap();
    let vtime = sess.stats().virtual_time_s;
    println!(
        "loss {first:.4} -> {last:.4} over {steps} steps  \
         (wall {:.1}s, virtual-cluster time {vtime:.1}s)",
        t0.elapsed().as_secs_f64()
    );
    assert!(
        last < first * 0.5,
        "loss did not halve: {first} -> {last}"
    );
    println!("train_gcn e2e OK");
    Ok(())
}
