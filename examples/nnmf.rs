//! NNMF (Appendix B): factorize a blocked non-negative matrix with
//! projected SGD, gradients via relational autodiff — driven through a
//! [`Session`] trainer whose two factor tables are named,
//! hash-partitioned parameter slots (V rides along as a constant).
//!
//! Run: `cargo run --release --example nnmf`

use relad::data::matrices::random_block_matrix;
use relad::dist::ClusterConfig;
use relad::ml::nnmf;
use relad::ml::{Sgd, SlotLayout};
use relad::session::{ModelSpec, Session};
use relad::util::Prng;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let chunk = 32;
    let (n, rank) = (256, 64); // 8x8 blocks, rank 2 blocks
    let mut rng = Prng::new(5);
    let v = random_block_matrix(n, n, chunk, &mut rng, true);
    let q = nnmf::loss_query(Arc::new(v), n * n);
    let (mut w, mut h) = nnmf::init_factors(n / chunk, rank / chunk, n / chunk, chunk, &mut rng);

    let sess = Session::new(ClusterConfig::default());
    let mut trainer = sess.trainer(
        ModelSpec::new(q)
            .param_with_layout("W", 2, SlotLayout::HashFull)
            .param_with_layout("H", 2, SlotLayout::HashFull),
    )?;

    let sgd = Sgd::nonneg(4.0);
    let mut first = None;
    let mut last = 0.0;
    for step in 0..150 {
        let res = trainer.step(&[("W", &w), ("H", &h)])?;
        first.get_or_insert(res.loss);
        last = res.loss;
        if step % 25 == 0 {
            println!("step {step:>3}  ‖V−WH‖²/n = {:.5}", res.loss);
        }
        sgd.step(&mut w, res.grad("W").expect("declared parameter"));
        sgd.step(&mut h, res.grad("H").expect("declared parameter"));
    }
    // factors remain non-negative (projected SGD)
    for (_, c) in w.iter().chain(h.iter()) {
        assert!(c.data().iter().all(|&x| x >= 0.0));
    }
    println!("reconstruction error {:.4} -> {last:.4}", first.unwrap());
    assert!(last < first.unwrap());
    println!("nnmf OK");
    Ok(())
}
