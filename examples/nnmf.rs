//! NNMF (Appendix B): factorize a blocked non-negative matrix with
//! projected SGD, gradients via relational autodiff.
//!
//! Run: `cargo run --release --example nnmf`

use relad::autodiff::grad;
use relad::data::matrices::random_block_matrix;
use relad::kernels::NativeBackend;
use relad::ml::nnmf;
use relad::ml::Sgd;
use relad::ra::Key;
use relad::util::Prng;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let chunk = 32;
    let (n, rank) = (256, 64); // 8x8 blocks, rank 2 blocks
    let mut rng = Prng::new(5);
    let v = random_block_matrix(n, n, chunk, &mut rng, true);
    let q = nnmf::loss_query(Arc::new(v), n * n);
    let (mut w, mut h) = nnmf::init_factors(n / chunk, rank / chunk, n / chunk, chunk, &mut rng);
    let sgd = Sgd::nonneg(4.0);
    let mut first = None;
    let mut last = 0.0;
    for step in 0..150 {
        let (tape, grads) = grad(&q, &[&w, &h], &NativeBackend)?;
        let loss = tape.output(&q).get(&Key::empty()).unwrap().as_scalar();
        first.get_or_insert(loss);
        last = loss;
        if step % 25 == 0 {
            println!("step {step:>3}  ‖V−WH‖²/n = {loss:.5}");
        }
        sgd.step(&mut w, grads.slot(nnmf::SLOT_W));
        sgd.step(&mut h, grads.slot(nnmf::SLOT_H));
    }
    // factors remain non-negative (projected SGD)
    for (_, c) in w.iter().chain(h.iter()) {
        assert!(c.data().iter().all(|&x| x >= 0.0));
    }
    println!("reconstruction error {:.4} -> {last:.4}", first.unwrap());
    assert!(last < first.unwrap());
    println!("nnmf OK");
    Ok(())
}
