//! Quickstart: the paper's §2.3 worked example end to end.
//!
//! Builds logistic regression as a functional-RA query (matmul join →
//! logistic selection → BCE-loss join → Σ), differentiates it with the
//! relational autodiff, and trains with SGD.
//!
//! Run: `cargo run --release --example quickstart [-- --backend xla]`

use relad::autodiff::grad;
use relad::kernels::registry::{make_backend, BackendKind};
use relad::ml::logreg;
use relad::ml::Sgd;
use relad::ra::Key;
use relad::sql::to_sql;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let backend_kind = if std::env::args().any(|a| a == "xla") {
        BackendKind::Xla
    } else {
        BackendKind::Native
    };
    let backend = make_backend(backend_kind, "artifacts")?;
    println!("kernel backend: {}", backend.name());

    // 1024 points, 64 features, blocked 64x64.
    let data = logreg::synthetic(1024, 64, 64, 42);
    let q = logreg::loss_query(
        Arc::new(data.x.clone()),
        Arc::new(data.y.clone()),
        data.n_rows,
    );
    println!("--- forward query (RA) ---\n{}", q.render());
    println!("--- forward query (SQL) ---\n{}\n", to_sql(&q));

    let mut theta = data.theta0.clone();
    let sgd = Sgd::new(2.0);
    for step in 0..50 {
        let (tape, grads) = grad(&q, &[&theta], backend.as_ref())?;
        let loss = tape.output(&q).get(&Key::empty()).unwrap().as_scalar();
        if step % 10 == 0 {
            println!("step {step:>3}  loss {loss:.5}");
        }
        sgd.step(&mut theta, grads.slot(0));
    }
    let (tape, _) = grad(&q, &[&theta], backend.as_ref())?;
    let final_loss = tape.output(&q).get(&Key::empty()).unwrap().as_scalar();
    println!("final loss {final_loss:.5}");
    assert!(final_loss < 0.3, "training failed to converge");
    println!("quickstart OK");
    Ok(())
}
