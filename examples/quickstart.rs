//! Quickstart: the paper's §2.3 worked example end to end, through the
//! engine's stateful front door.
//!
//! Opens a [`Session`], compiles logistic regression (matmul join →
//! logistic selection → BCE-loss join → Σ) as a functional-RA query with
//! one named parameter slot, and trains with SGD — forward tape, the
//! *generated backward query*, and every gather run on the session's
//! worker pool.
//!
//! Run: `cargo run --release --example quickstart [-- --backend xla]`

use relad::dist::ClusterConfig;
use relad::kernels::registry::{make_backend, BackendKind};
use relad::ml::logreg;
use relad::ml::Sgd;
use relad::session::{ModelSpec, Session};
use relad::sql::to_sql;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let backend_kind = if std::env::args().any(|a| a == "xla") {
        BackendKind::Xla
    } else {
        BackendKind::Native
    };
    let backend = make_backend(backend_kind, "artifacts")?;
    println!("kernel backend: {}", backend.name());

    // 1024 points, 64 features, blocked 64x64.
    let data = logreg::synthetic(1024, 64, 64, 42);
    let q = logreg::loss_query(
        Arc::new(data.x.clone()),
        Arc::new(data.y.clone()),
        data.n_rows,
    );
    println!("--- forward query (RA) ---\n{}", q.render());
    println!("--- forward query (SQL) ---\n{}\n", to_sql(&q));

    // One session = one engine: it owns the worker pool and accumulates
    // execution stats across every step below. The data (X, y) lives in
    // the query as constants; θ is the single named parameter.
    let sess = Session::with_backend(ClusterConfig::default(), backend);
    let mut trainer = sess.trainer(ModelSpec::new(q).param("theta", 1))?;

    let mut theta = data.theta0.clone();
    let sgd = Sgd::new(2.0);
    let mut final_loss = f32::NAN;
    for step in 0..=50 {
        let res = trainer.step(&[("theta", &theta)])?;
        if step % 10 == 0 {
            println!("step {step:>3}  loss {:.5}", res.loss);
        }
        final_loss = res.loss;
        if step < 50 {
            sgd.step(&mut theta, res.grad("theta").expect("θ is the one parameter"));
        }
    }
    println!("final loss {final_loss:.5}");
    println!(
        "session ran {} stage(s) over {} step(s)",
        sess.stats().stages,
        trainer.steps()
    );
    assert!(final_loss < 0.3, "training failed to converge");
    println!("quickstart OK");
    Ok(())
}
