//! Knowledge-graph embeddings (Appendix C): TransE-L2 and TransR on a
//! synthetic Freebase-like KG, margin ranking loss, SGD — the embedding
//! tables are relations and every gradient is a generated RA computation.
//!
//! Run: `cargo run --release --example kge`

use relad::autodiff::grad;
use relad::data::KgDataset;
use relad::kernels::NativeBackend;
use relad::ml::kge::{self, KgeConfig, KgeVariant};
use relad::ml::Sgd;
use relad::ra::{Key, Relation};
use relad::util::Prng;

fn train(variant: KgeVariant) -> anyhow::Result<(f32, f32)> {
    let kg = KgDataset::freebase_scaled(2000, 16_000, 12, 11);
    let cfg = KgeConfig {
        variant,
        dim: 32,
        margin: 1.0,
    };
    let mut rng = Prng::new(13);
    let mut tables = kge::init_tables(&cfg, kg.n_entities, kg.n_relations, &mut rng);
    let sgd = Sgd::new(0.5);
    let (mut first, mut last) = (None, 0.0);
    for step in 0..40 {
        let (pos, negs) = kg.sample_batch(64, 8, &mut rng);
        let (rp, rn) = kge::batch_relations(&pos, &negs);
        let q = kge::loss_query(&cfg, rp, rn, 64 * 8);
        let refs: Vec<&Relation> = tables.iter().collect();
        let (tape, grads) = grad(&q, &refs, &NativeBackend)?;
        let loss = tape.output(&q).get(&Key::empty()).unwrap().as_scalar();
        first.get_or_insert(loss);
        last = loss;
        for (i, t) in tables.iter_mut().enumerate() {
            sgd.step(t, grads.slot(i));
        }
        if step % 10 == 0 {
            println!("  step {step:>3}  margin loss {loss:.5}");
        }
    }
    Ok((first.unwrap(), last))
}

fn main() -> anyhow::Result<()> {
    for variant in [KgeVariant::TransE, KgeVariant::TransR] {
        println!("=== {variant:?} ===");
        let (first, last) = train(variant)?;
        println!("  loss {first:.4} -> {last:.4}");
        assert!(last < first, "{variant:?} did not improve");
    }
    println!("kge OK");
    Ok(())
}
