//! Knowledge-graph embeddings (Appendix C): TransE-L2 and TransR on a
//! synthetic Freebase-like KG, margin ranking loss, SGD — the embedding
//! tables are relations, every gradient is a generated RA computation,
//! and the whole loop runs through a [`Session`]: each mini-batch loss
//! query compiles to a trainer with *named* parameter tables (E/R/M).
//!
//! Run: `cargo run --release --example kge`

use relad::data::KgDataset;
use relad::dist::ClusterConfig;
use relad::ml::kge::{self, KgeConfig, KgeVariant};
use relad::ml::Sgd;
use relad::ra::Relation;
use relad::session::{ModelSpec, Session};
use relad::util::Prng;

/// Parameter-table names in `kge::init_tables` slot order.
const TABLES: [&str; 3] = ["E", "R", "M"];

fn train(variant: KgeVariant) -> anyhow::Result<(f32, f32)> {
    let kg = KgDataset::freebase_scaled(2000, 16_000, 12, 11);
    let cfg = KgeConfig {
        variant,
        dim: 32,
        margin: 1.0,
    };
    let mut rng = Prng::new(13);
    let mut tables = kge::init_tables(&cfg, kg.n_entities, kg.n_relations, &mut rng);
    let sgd = Sgd::new(0.5);
    // One session drives the whole run; every batch's query (the
    // sampled triples ride along as constants) compiles against it.
    let sess = Session::new(ClusterConfig::default());
    let (mut first, mut last) = (None, 0.0);
    for step in 0..40 {
        let (pos, negs) = kg.sample_batch(64, 8, &mut rng);
        let (rp, rn) = kge::batch_relations(&pos, &negs);
        let q = kge::loss_query(&cfg, rp, rn, 64 * 8);
        let mut spec = ModelSpec::new(q);
        for name in TABLES.iter().take(tables.len()) {
            spec = spec.param(name, 1);
        }
        let mut trainer = sess.trainer(spec)?;
        let named: Vec<(&str, &Relation)> = tables
            .iter()
            .enumerate()
            .map(|(i, t)| (TABLES[i], t))
            .collect();
        let res = trainer.step(&named)?;
        first.get_or_insert(res.loss);
        last = res.loss;
        for (i, t) in tables.iter_mut().enumerate() {
            sgd.step(t, res.grad(TABLES[i]).expect("declared parameter"));
        }
        if step % 10 == 0 {
            println!("  step {step:>3}  margin loss {:.5}", res.loss);
        }
    }
    Ok((first.unwrap(), last))
}

fn main() -> anyhow::Result<()> {
    for variant in [KgeVariant::TransE, KgeVariant::TransR] {
        println!("=== {variant:?} ===");
        let (first, last) = train(variant)?;
        println!("  loss {first:.4} -> {last:.4}");
        assert!(last < first, "{variant:?} did not improve");
    }
    println!("kge OK");
    Ok(())
}
