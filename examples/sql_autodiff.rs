//! The paper's headline workflow: write a computation in SQL, auto-diff
//! it, get a *new SQL query* computing the gradient (Figs. 4 & 5) — all
//! through the engine's stateful front door: register tables on a
//! [`Session`], `sess.sql(..)` them into a lazy frame, `explain()` the
//! physical plan the executor takes, `grad("W")` the generated backward
//! query on the same worker pool.
//!
//! Run: `cargo run --release --example sql_autodiff`

use relad::autodiff::{backward_graph, grad};
use relad::dist::ClusterConfig;
use relad::kernels::NativeBackend;
use relad::ra::{Chunk, Key, Relation};
use relad::session::Session;
use relad::sql::to_sql;
use relad::util::Prng;

fn main() -> anyhow::Result<()> {
    // Fig. 4's forward pass: Z = X·W, blocked.
    let sql = "SELECT X.row, W.col, SUM(matrix_multiply(X.val, W.val)) \
               FROM X, W WHERE X.col = W.row GROUP BY X.row, W.col";
    println!("--- input SQL ---\n{sql}\n");

    let mut rng = Prng::new(17);
    let mut x = Relation::new();
    let mut w = Relation::new();
    for i in 0..3i64 {
        for k in 0..2i64 {
            x.insert(Key::k2(i, k), Chunk::random(16, 16, &mut rng, 1.0));
            w.insert(Key::k2(k, i), Chunk::random(16, 16, &mut rng, 1.0));
        }
    }

    // A 2-worker session: the engine that parses, plans, partitions,
    // differentiates, and executes.
    let mut sess = Session::new(ClusterConfig::new(2));
    sess.register("X", &["row", "col"], &x)?;
    sess.register("W", &["row", "col"], &w)?;
    let frame = sess.sql(sql)?;
    println!("--- lowered RA ---\n{}", frame.query().render());
    println!("--- physical plan (executed) ---\n{}", frame.explain()?);

    // Differentiate w.r.t. W: the backward computation is itself RA/SQL.
    let plan = backward_graph(frame.query(), &[2, 2], &[1])?;
    println!("--- generated gradient query (RA) ---\n{}", plan.render());
    println!(
        "--- generated gradient query (SQL) ---\n{}\n",
        to_sql(&plan.query)
    );

    // Execute the gradient through the session and cross-check against
    // eager mode (Algorithm 2) with the same ones seed.
    let dw = frame.grad("W")?;
    let (_, eager) = grad(frame.query(), &[&x, &w], &NativeBackend)?;
    assert!(
        dw.approx_eq(eager.slot(1), 1e-4),
        "generated SQL gradient disagrees with Algorithm 2"
    );
    println!(
        "gradient of W: {} block tuples, matches eager Algorithm 2 to 1e-4",
        dw.len()
    );
    println!("sql_autodiff OK");
    Ok(())
}
