//! The paper's headline workflow: write a computation in SQL, auto-diff
//! it, get a *new SQL query* computing the gradient (Figs. 4 & 5).
//!
//! Run: `cargo run --release --example sql_autodiff`

use relad::autodiff::{backward_graph, eval_backward, grad};
use relad::kernels::NativeBackend;
use relad::ra::eval::eval_query_tape;
use relad::ra::{Chunk, Key, Relation};
use relad::sql::{parse_query, to_sql, Catalog};
use relad::util::Prng;

fn main() -> anyhow::Result<()> {
    // Fig. 4's forward pass: Z = X·W, blocked.
    let catalog = Catalog::default()
        .table("X", 0, &["row", "col"])
        .table("W", 1, &["row", "col"]);
    let sql = "SELECT X.row, W.col, SUM(matrix_multiply(X.val, W.val)) \
               FROM X, W WHERE X.col = W.row GROUP BY X.row, W.col";
    println!("--- input SQL ---\n{sql}\n");
    let q = parse_query(sql, &catalog)?;
    println!("--- lowered RA ---\n{}", q.render());

    // Differentiate w.r.t. W: the backward computation is itself RA/SQL.
    let plan = backward_graph(&q, &[2, 2], &[1])?;
    println!("--- generated gradient query (RA) ---\n{}", plan.render());
    println!("--- generated gradient query (SQL) ---\n{}\n", to_sql(&plan.query));

    // Execute both on blocked data and cross-check against eager mode.
    let mut rng = Prng::new(17);
    let mut x = Relation::new();
    let mut w = Relation::new();
    for i in 0..3i64 {
        for k in 0..2i64 {
            x.insert(Key::k2(i, k), Chunk::random(16, 16, &mut rng, 1.0));
            w.insert(Key::k2(k, i), Chunk::random(16, 16, &mut rng, 1.0));
        }
    }
    let tape = eval_query_tape(&q, &[&x, &w], &NativeBackend)?;
    let mut seed = Relation::new();
    for (k, v) in tape.rels[q.output].iter() {
        seed.insert(*k, Chunk::filled(v.rows(), v.cols(), 1.0));
    }
    let got = eval_backward(&plan, &tape, &seed, &NativeBackend)?;
    let (_, eager) = grad(&q, &[&x, &w], &NativeBackend)?;
    assert!(
        got[0].1.approx_eq(eager.slot(1), 1e-4),
        "generated SQL gradient disagrees with Algorithm 2"
    );
    println!(
        "gradient of W: {} block tuples, matches eager Algorithm 2 to 1e-4",
        got[0].1.len()
    );
    println!("sql_autodiff OK");
    Ok(())
}
