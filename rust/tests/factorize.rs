//! Factorized evaluation end-to-end: the Σ-below-⋈ pushdown and the
//! partition-aware shuffle elision must be invisible in results —
//! **bitwise** identical to the plan as written, across worker counts,
//! communication modes and spill budgets — and visible only in the
//! traffic counters.
//!
//! Inputs are integer-valued floats throughout, so every sum the
//! rewrite reassociates (partial Σ per side before the join instead of
//! one Σ above it) is exact in f32 and the bitwise bar is meaningful,
//! not vacuous.
//!
//! Also here (satellite coverage): the legality edge cases that must
//! *refuse* — group keys minted by the join projection rather than
//! passed through, an AddQ between Σ and ⋈, and non-decomposable
//! aggregation kernels (Max) — each asserted as "no rewrite found" plus
//! bitwise-identical execution with the knob on and off; and the GCN
//! training grid, where every Σ-over-⋈ refuses structurally and the
//! headline win is pure shuffle elision (the two message joins
//! reshuffle the same Edge scan the same way).

mod common;

use common::{bitwise_eq, sgd_apply};
use relad::autodiff::{backward_graph, graph::node_arities};
use relad::data::graphs::power_law_graph;
use relad::dist::{ClusterConfig, ExecStats, MemPolicy};
use relad::kernels::{AggKernel, BinaryKernel};
use relad::ml::gcn::{self, GcnConfig};
use relad::ml::SlotLayout;
use relad::plan::factorize_query;
use relad::ra::{Chunk, JoinPred, Key, KeyProj, KeyProj2, Query, QueryBuilder, Relation, Sel2};
use relad::session::{ModelSpec, Session};
use relad::util::Prng;

/// `n` tuples keyed ⟨i mod groups, i⟩ with integer-valued `c×c` chunks
/// (values exact in f32). Few distinct group keys means the per-side
/// partial Σ genuinely collapses every shard's slice, so factorized
/// traffic is deterministically below materialized.
fn grouped_int(n: i64, groups: i64, c: usize, seed: u64) -> Relation {
    let mut rng = Prng::new(seed);
    let mut r = Relation::new();
    for i in 0..n {
        let v = (rng.next_u64() % 9 + 1) as f32;
        r.insert(Key::k2(i % groups, i), Chunk::filled(c, c, v));
    }
    r
}

/// Σ_a Mul over R(a,b) ⋈ S(a,c) GROUP BY a — both sides collapse to
/// their join key, the canonical factorizable shape.
fn sumjoin_query() -> Query {
    let mut qb = QueryBuilder::new();
    let r = qb.scan(0, "R");
    let s = qb.scan(1, "S");
    let j = qb.join(
        JoinPred::on(vec![(0, 0)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
        BinaryKernel::Mul,
        r,
        s,
    );
    let a = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, j);
    qb.finish(a)
}

fn sumjoin_session(w: usize, comm: bool, budget: Option<u64>, factorize: bool) -> Session {
    let mut cfg = ClusterConfig::new(w)
        .with_parallel_comm(comm)
        .with_factorize(factorize);
    if let Some(b) = budget {
        cfg = cfg.with_policy(MemPolicy::Spill).with_budget(b);
    }
    let sess = Session::new(cfg);
    sess.register("R", &["a", "b"], &grouped_int(32, 2, 2, 0xFAC1))
        .unwrap();
    sess.register("S", &["a", "c"], &grouped_int(32, 2, 2, 0xFAC2))
        .unwrap();
    sess
}

#[test]
fn pushdown_is_bitwise_across_workers_comm_and_spill() {
    let q = sumjoin_query();
    // The rewrite must actually fire on this shape.
    assert!(
        factorize_query(&q, &[2, 2]).is_some(),
        "sumjoin shape must be a pushdown candidate"
    );
    for w in [1usize, 2, 8] {
        for comm in [true, false] {
            for budget in [None, Some(4096u64)] {
                let on = sumjoin_session(w, comm, budget, true);
                let off = sumjoin_session(w, comm, budget, false);
                let (po, so) = on.query(&q).unwrap().collect_partitioned().unwrap();
                let (pm, sm) = off.query(&q).unwrap().collect_partitioned().unwrap();
                assert!(
                    bitwise_eq(&po.gather(), &pm.gather()),
                    "w={w} comm={comm} budget={budget:?}: factorized result diverged"
                );
                if w > 1 {
                    assert!(
                        so.bytes_shuffled < sm.bytes_shuffled,
                        "w={w} comm={comm} budget={budget:?}: factorized moved {} B, \
                         materialized {} B — pushdown should shrink traffic",
                        so.bytes_shuffled,
                        sm.bytes_shuffled
                    );
                }
            }
        }
    }
}

#[test]
fn backward_factorization_keeps_gradients_bitwise() {
    // Message-passing shape: R(a,i) ⋈ S(a) weighted by Mul, Σ over a.
    // Its generated backward for ∂S is itself a Σ-over-⋈ whose taped-R
    // side collapses — the rewrite must fire on the *backward* plan
    // (`grad` runs the forward as written; the backward reads taped
    // intermediates whose values a forward rewrite would change).
    let mut qb = QueryBuilder::new();
    let r = qb.scan(0, "R");
    let s = qb.scan(1, "S");
    let j = qb.join(
        JoinPred::on(vec![(0, 0)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1)]),
        BinaryKernel::Mul,
        r,
        s,
    );
    let a = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, j);
    let q = qb.finish(a);

    // Structural check that the backward is a pushdown candidate.
    let arities = [2usize, 1];
    let plan = backward_graph(&q, &arities, &[1]).unwrap();
    let fwd_ar = node_arities(&q, &arities);
    let mut bwd_ar = vec![fwd_ar[q.output]];
    bwd_ar.extend(plan.tape_inputs.iter().map(|&n| fwd_ar[n]));
    assert!(
        factorize_query(&plan.query, &bwd_ar).is_some(),
        "∂S backward must be a pushdown candidate"
    );

    let rr = grouped_int(64, 2, 2, 0xAB);
    let mut ss = Relation::new();
    for g in 0..2i64 {
        ss.insert(Key::k1(g), Chunk::filled(2, 2, (g + 2) as f32));
    }
    for w in [1usize, 2, 8] {
        let mk = |factorize: bool| {
            let sess = Session::new(ClusterConfig::new(w).with_factorize(factorize));
            sess.register("R", &["a", "i"], &rr).unwrap();
            sess.register("S", &["a"], &ss).unwrap();
            sess
        };
        let on = mk(true);
        let off = mk(false);
        let go = on.query(&q).unwrap().grad("S").unwrap();
        let gm = off.query(&q).unwrap().grad("S").unwrap();
        assert!(
            bitwise_eq(&go, &gm),
            "w={w}: ∂S diverged under backward factorization"
        );
    }
}

#[test]
fn explain_renders_rewrite_and_elision_columns() {
    let q = sumjoin_query();
    let on = sumjoin_session(2, true, None, true);
    let text = on.query(&q).unwrap().explain().unwrap();
    assert!(
        text.contains("rewrite: ") && text.contains("combining Σ"),
        "explain must render the factorization:\n{text}"
    );
    assert!(text.contains("elided") && text.contains("totals:"), "{text}");
    let off = sumjoin_session(2, true, None, false);
    let text = off.query(&q).unwrap().explain().unwrap();
    assert!(
        !text.contains("rewrite: "),
        "knob off must execute the plan as written:\n{text}"
    );
}

/// Run a refusal shape with the knob on and off and assert bitwise
/// agreement (the plan must execute as written either way).
fn assert_refused_and_bitwise(q: &Query, label: &str) {
    assert!(
        factorize_query(q, &[2, 2]).is_none(),
        "{label}: rewrite must refuse"
    );
    for w in [1usize, 3] {
        let on = sumjoin_session(w, true, None, true);
        let off = sumjoin_session(w, true, None, false);
        let go = on.query(q).unwrap().collect().unwrap();
        let gm = off.query(q).unwrap().collect().unwrap();
        assert!(bitwise_eq(&go, &gm), "{label}: w={w} diverged");
    }
}

#[test]
fn refuses_group_key_minted_by_projection() {
    // A 1-1 join whose projection mints a literal key component the Σ
    // then groups by: the combining Σ could not reconstruct it from
    // per-side partials, so the rewrite must leave the plan alone.
    let mut qb = QueryBuilder::new();
    let r = qb.scan(0, "R");
    let s = qb.scan(1, "S");
    let j = qb.join(
        JoinPred::on(vec![(0, 0), (1, 1)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::Lit(7)]),
        BinaryKernel::Mul,
        r,
        s,
    );
    let a = qb.agg(KeyProj::take(&[0, 2]), AggKernel::Sum, j);
    assert_refused_and_bitwise(&qb.finish(a), "projection-minted group key");
}

#[test]
fn refuses_addq_between_agg_and_join() {
    let mut qb = QueryBuilder::new();
    let r = qb.scan(0, "R");
    let s = qb.scan(1, "S");
    let proj = KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]);
    let pred = JoinPred::on(vec![(0, 0)]);
    let j1 = qb.join(pred.clone(), proj.clone(), BinaryKernel::Mul, r, s);
    let j2 = qb.join(pred, proj, BinaryKernel::Mul, r, s);
    let sum = qb.add(j1, j2);
    let a = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, sum);
    assert_refused_and_bitwise(&qb.finish(a), "AddQ between Σ and ⋈");
}

#[test]
fn refuses_non_decomposable_agg_kernel() {
    let mut qb = QueryBuilder::new();
    let r = qb.scan(0, "R");
    let s = qb.scan(1, "S");
    let j = qb.join(
        JoinPred::on(vec![(0, 0)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
        BinaryKernel::Mul,
        r,
        s,
    );
    let a = qb.agg(KeyProj::take(&[0]), AggKernel::Max, j);
    assert_refused_and_bitwise(&qb.finish(a), "Max over ⋈");
}

/// Three GCN training steps (forward + backward + SGD) at one cluster
/// shape, returning per-step loss bits, final parameters, and the
/// accumulated step stats.
fn gcn_run(
    g: &relad::data::GraphDataset,
    q: &Query,
    w1_0: &Relation,
    w2_0: &Relation,
    w: usize,
    comm: bool,
    factorize: bool,
) -> (Vec<u32>, Relation, Relation, ExecStats) {
    let cfg = ClusterConfig::new(w)
        .with_parallel_comm(comm)
        .with_factorize(factorize);
    let sess = Session::new(cfg);
    sess.register_with_layout("Edge", &["dst", "src"], &g.edges, &SlotLayout::HashOn(vec![0]))
        .unwrap();
    sess.register("Node", &["id"], &g.feats).unwrap();
    sess.register("Y", &["id"], &g.labels).unwrap();
    let mut trainer = sess
        .trainer(ModelSpec::new(q.clone()).param("W1", 1).param("W2", 1))
        .unwrap();
    let (mut w1, mut w2) = (w1_0.clone(), w2_0.clone());
    let mut losses = Vec::new();
    let mut stats = ExecStats::default();
    for _ in 0..3 {
        let step = trainer.step(&[("W1", &w1), ("W2", &w2)]).unwrap();
        losses.push(step.loss.to_bits());
        for (name, grel) in &step.grads {
            let target = if name == "W1" { &mut w1 } else { &mut w2 };
            sgd_apply(target, grel, 0.1);
        }
        stats.merge(&step.stats);
    }
    (losses, w1, w2, stats)
}

#[test]
fn gcn_training_is_bitwise_and_elision_cuts_traffic() {
    // Sized so the planner *reshuffles* the shared Edge scan for both
    // message joins (wide features make broadcasting the node side too
    // expensive): the second reshuffle is a memo hit, which is the
    // entire factorized-vs-materialized delta — every GCN Σ-over-⋈
    // refuses pushdown structurally, so bitwise equality is exact, not
    // merely integer-exact.
    // feat_dim 16 (not 64): it never enters the broadcast-vs-reshuffle
    // inequality — the X⋈W join stays local — and quarters the debug-
    // mode matmul cost of the grid.
    let g = power_law_graph("fx", 1000, 3000, 16, 64, 0.4, 11);
    let cfg = GcnConfig {
        feat_dim: 16,
        hidden: 64,
        n_labels: 64,
        dropout: None,
        seed: 5,
    };
    let q = gcn::loss_query(&cfg, g.labels.len());
    let mut rng = Prng::new(77);
    let (w1_0, w2_0) = gcn::init_params(&cfg, &mut rng);
    for w in [1usize, 2, 8] {
        for comm in [true, false] {
            let on = gcn_run(&g, &q, &w1_0, &w2_0, w, comm, true);
            let off = gcn_run(&g, &q, &w1_0, &w2_0, w, comm, false);
            assert_eq!(on.0, off.0, "w={w} comm={comm}: per-step losses diverged");
            assert!(bitwise_eq(&on.1, &off.1), "w={w} comm={comm}: W1 diverged");
            assert!(bitwise_eq(&on.2, &off.2), "w={w} comm={comm}: W2 diverged");
            let (so, sm) = (on.3, off.3);
            if w > 1 {
                assert!(
                    so.shuffles_elided > 0,
                    "w={w} comm={comm}: elision memo never hit"
                );
                assert!(
                    so.bytes_shuffled < sm.bytes_shuffled,
                    "w={w} comm={comm}: factorized moved {} B, materialized {} B",
                    so.bytes_shuffled,
                    sm.bytes_shuffled
                );
                // The elided bytes account exactly for the delta.
                assert_eq!(
                    so.bytes_shuffled + so.bytes_shuffle_elided,
                    sm.bytes_shuffled,
                    "w={w} comm={comm}: elision accounting drifted"
                );
            } else {
                assert_eq!(so.bytes_shuffled, sm.bytes_shuffled, "w=1 moves nothing");
            }
        }
    }
}

/// Satellite for the incremental engine: delta replay composes with the
/// factorized rewrite. A factorized frame takes an insert-only delta
/// into R and replays it against the *rewritten* plan — the untouched
/// S-side pushed-down partial Σ is served from the previous factorized
/// tape — bitwise identical (shard for shard) to a fresh factorized run
/// over the merged tables and (gathered) to the plan as written, and
/// the replay's real-plus-elided traffic never exceeds what either
/// fresh run moved: reuse is never double-counted as shuffle work.
#[test]
fn delta_replay_composes_with_factorization_bitwise() {
    let q = sumjoin_query();
    let r0 = grouped_int(32, 2, 2, 0xFAC1);
    let s0 = grouped_int(32, 2, 2, 0xFAC2);
    let batch: Vec<(Key, Chunk)> = {
        let mut rng = Prng::new(0xFAC3);
        (0..8)
            .map(|i| {
                let v = (rng.next_u64() % 9 + 1) as f32;
                (Key::k2(i % 2, 1000 + i), Chunk::filled(2, 2, v))
            })
            .collect()
    };
    let mut r1_pairs = r0.pairs().to_vec();
    r1_pairs.extend(batch.iter().cloned());
    let r1 = Relation::from_pairs(r1_pairs);
    for w in [1usize, 2, 8] {
        let mk = |rel: &Relation, factorize: bool| {
            let sess = Session::new(ClusterConfig::new(w).with_factorize(factorize));
            sess.register("R", &["a", "b"], rel).unwrap();
            sess.register("S", &["a", "c"], &s0).unwrap();
            sess
        };
        let sess = mk(&r0, true);
        let frame = sess.query(&q).unwrap();
        frame.collect().unwrap();
        sess.insert("R", batch.clone()).unwrap();
        let (got, st) = frame.collect_partitioned().unwrap();
        // The delta gate admits the rewritten plan (all-Sum Σs, pure
        // equi ⋈ of the partials): no fallback, and the untouched
        // S-side partial Σ is served from the previous tape on every
        // worker.
        assert_eq!(
            sess.stats().delta_fallbacks,
            0,
            "w={w}: gate refused the rewritten plan"
        );
        assert!(
            st.shards_reused >= w as u64,
            "w={w}: untouched pushed-down branch must reuse, got {}",
            st.shards_reused
        );
        // Bitwise against a fresh factorized run over the merged tables
        // (same config → same rewrite decision → same layout)…
        let on = mk(&r1, true);
        let (want_on, st_on) = on.query(&q).unwrap().collect_partitioned().unwrap();
        assert_eq!(got.workers(), want_on.workers(), "w={w}");
        for (wi, (x, y)) in got.shards.iter().zip(want_on.shards.iter()).enumerate() {
            assert!(
                bitwise_eq(x.as_ref(), y.as_ref()),
                "w={w}: shard {wi} diverged from fresh factorized"
            );
        }
        // …and, gathered, against the plan as written.
        let off = mk(&r1, false);
        let (want_off, st_off) = off.query(&q).unwrap().collect_partitioned().unwrap();
        assert!(
            bitwise_eq(&got.gather(), &want_off.gather()),
            "w={w}: diverged from the materialized plan"
        );
        // No double-counting across reuse: replaying a delta can only
        // shrink the factorized run's traffic, and real + elided bytes
        // together stay below the materialized plan's movement.
        assert!(
            st.bytes_shuffled <= st_on.bytes_shuffled,
            "w={w}: replay moved {} B, fresh factorized moved {} B",
            st.bytes_shuffled,
            st_on.bytes_shuffled
        );
        if w > 1 {
            assert!(
                st.bytes_shuffled + st.bytes_shuffle_elided < st_off.bytes_shuffled,
                "w={w}: replay {} B real + {} B elided vs materialized {} B",
                st.bytes_shuffled,
                st.bytes_shuffle_elided,
                st_off.bytes_shuffled
            );
        }
    }
}
