//! Skew-aware partitioning acceptance suite: heavy-hitter detection at
//! ingest, the salted and replicated join strategies, and the headline
//! invariant — a skew-aware session is **bitwise identical** to its
//! oblivious twin (same float bits, same per-shard emission order, same
//! gathered relation) while strictly shrinking the hot worker's join
//! load. The shapes covered:
//!
//! * a Zipf-headed join + Σ at w ∈ {1, 2, 8} × parallel_comm ∈ {on,
//!   off} × {ample, grace-spill} budgets, with a plan assertion that
//!   `SkewSalt` actually fired at w ≥ 2 and a trace assertion that
//!   `max_shard_bytes` strictly shrank,
//! * the `SkewBroadcast` arm: the probe side mispartitioned *and* hot
//!   on the join key, so the oblivious reshuffle would pile both sides'
//!   hot rows onto one worker,
//! * factorization parity: the hot-key annotation must not change which
//!   plan factorizes (`Partitioning::hash_comps` covers `SkewHash`),
//! * GCN gradients and a 3-step training loop on a Chung-Lu power-law
//!   graph, skew-aware vs oblivious, loss/grad/parameter bits equal,
//! * ingest-sampler properties: deterministic for a fixed seed, finds
//!   the Zipf(1.1) head through the 1024-row sample, flags nothing on
//!   uniform keys (and charges nothing to `hot_keys_detected`).
//!
//! Inputs are integer-valued floats throughout so every Σ is exact in
//! f32 and the bitwise bar is meaningful, not vacuous.

mod common;

use std::collections::HashMap;

use common::{bitwise_eq, sgd_apply};
use relad::data::graphs::power_law_graph;
use relad::dist::{ClusterConfig, MemPolicy, NetModel, PartitionedRelation};
use relad::kernels::{AggKernel, BinaryKernel};
use relad::ml::gcn::{self, GcnConfig};
use relad::ml::SlotLayout;
use relad::ra::{Chunk, JoinPred, Key, KeyProj, KeyProj2, Query, QueryBuilder, Relation, Sel2};
use relad::session::{detect_hot_keys, Frame, ModelSpec, Session};
use relad::util::Prng;

/// Integer-valued `c×c` chunks (exact in f32) for the given keys, in
/// iteration order.
fn int_pairs(keys: impl IntoIterator<Item = Key>, c: usize, seed: u64) -> Vec<(Key, Chunk)> {
    let mut rng = Prng::new(seed);
    keys.into_iter()
        .map(|k| {
            let v = (rng.next_u64() % 9 + 1) as f32;
            (k, Chunk::filled(c, c, v))
        })
        .collect()
}

/// Order-exact per-shard bitwise equality: same shard row counts, same
/// key emission order, same value bits — the contract the skew merge
/// promises against the oblivious baseline.
fn assert_shards_bitwise(got: &PartitionedRelation, want: &PartitionedRelation, ctx: &str) {
    assert_eq!(got.workers(), want.workers(), "{ctx}: worker counts differ");
    for wi in 0..got.workers() {
        let (a, b) = (&got.shards[wi], &want.shards[wi]);
        assert_eq!(a.len(), b.len(), "{ctx}: shard {wi} row counts differ");
        for ((ka, va), (kb, vb)) in a.iter().zip(b.iter()) {
            assert_eq!(ka, kb, "{ctx}: shard {wi} emission order differs");
            assert_eq!(va.shape(), vb.shape(), "{ctx}: shard {wi} key {ka} shape differs");
            let ba: Vec<u32> = va.data().iter().map(|x| x.to_bits()).collect();
            let bb: Vec<u32> = vb.data().iter().map(|x| x.to_bits()).collect();
            assert_eq!(ba, bb, "{ctx}: shard {wi} key {ka} value bits differ");
        }
    }
}

/// Σ over R(a,b) ⋈ S(a,c) GROUP BY a — the ⋈ projection ⟨a, b, c⟩ is
/// injective on matches (b and c are unique per side).
fn sumjoin_query() -> Query {
    let mut qb = QueryBuilder::new();
    let r = qb.scan(0, "R");
    let s = qb.scan(1, "S");
    let j = qb.join(
        JoinPred::on(vec![(0, 0)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
        BinaryKernel::Mul,
        r,
        s,
    );
    let a = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, j);
    qb.finish(a)
}

/// Byte-dominated fabric: test relations are tiny, so zero the
/// per-message latency and shrink bandwidth until the straggler term
/// decides the skew costing (same device as the exec-layer unit tests).
fn skew_net() -> NetModel {
    NetModel {
        bandwidth_bps: 1e3,
        latency_s: 0.0,
    }
}

/// 192 rows piled on join key a = 0 plus a 64-row cold tail spread over
/// a ∈ 1..64 — the sampler sees a 75% heavy hitter at any threshold
/// below that.
fn zipf_head_r() -> Vec<(Key, Chunk)> {
    let mut keys: Vec<Key> = (0..192).map(|i| Key::k2(0, i)).collect();
    keys.extend((0..64).map(|i| Key::k2(1 + (i % 63), 1000 + i)));
    int_pairs(keys, 2, 0x5A11)
}

/// One S row per group — uniform, so only R carries the annotation.
fn uniform_s() -> Vec<(Key, Chunk)> {
    int_pairs((0..64).map(|g| Key::k2(g, 5000 + g)), 2, 0x5A12)
}

/// The traced ⋈ profile: (max per-worker join-input load, whether a
/// skew strategy fired on any join stage).
fn join_profile(frame: &Frame) -> (u64, bool) {
    let (trace, _) = frame.trace().unwrap();
    let max = trace
        .iter()
        .filter(|t| t.op == "⋈")
        .map(|t| t.max_shard_bytes)
        .max()
        .unwrap_or(0);
    let fired = trace
        .iter()
        .any(|t| matches!(&t.strategy, Some(s) if format!("{s:?}").contains("Skew")));
    (max, fired)
}

/// The tentpole grid. A skew-aware session (ingest sampler on) and its
/// oblivious twin run the same Zipf-headed ⋈ + Σ over bitwise-identical
/// catalogs at w ∈ {1, 2, 8} × parallel_comm ∈ {on, off} × {ample,
/// grace-spill} budgets. At w ≥ 2 the `SkewSalt` plan must fire, salt
/// rows, pay replicated hot bytes, and strictly shrink the hot worker's
/// join load — and in every cell the outputs match the oblivious run
/// per shard, in emission order, bit for bit.
#[test]
fn skewed_join_sigma_grid_bitwise() {
    let q = sumjoin_query();
    let r0 = zipf_head_r();
    let s0 = uniform_s();
    for w in [1usize, 2, 8] {
        for comm in [true, false] {
            for budget in [None, Some(2048u64)] {
                let ctx = format!("w={w} comm={comm} budget={budget:?}");
                let mk = |thresh: Option<f64>| {
                    let mut cfg = ClusterConfig::new(w)
                        .with_factorize(false)
                        .with_parallel_comm(comm)
                        .with_net(skew_net());
                    if let Some(b) = budget {
                        cfg = cfg.with_policy(MemPolicy::Spill).with_budget(b);
                    }
                    if let Some(t) = thresh {
                        cfg = cfg.with_skew_threshold(t);
                    }
                    let sess = Session::new(cfg);
                    sess.register_with_layout(
                        "R",
                        &["a", "b"],
                        &Relation::from_pairs(r0.clone()),
                        &SlotLayout::HashOn(vec![0]),
                    )
                    .unwrap();
                    sess.register_with_layout(
                        "S",
                        &["a", "c"],
                        &Relation::from_pairs(s0.clone()),
                        &SlotLayout::HashOn(vec![0]),
                    )
                    .unwrap();
                    sess
                };
                let obl = mk(None);
                assert_eq!(obl.stats().hot_keys_detected, 0, "{ctx}: sampler off");
                let skew = mk(Some(0.3));
                assert_eq!(
                    skew.stats().hot_keys_detected,
                    1,
                    "{ctx}: exactly the a=0 head is hot"
                );

                let oframe = obl.query(&q).unwrap();
                let sframe = skew.query(&q).unwrap();
                let (omax, ofired) = join_profile(&oframe);
                let (smax, sfired) = join_profile(&sframe);
                assert!(!ofired, "{ctx}: oblivious session must not plan skew");
                if w >= 2 {
                    assert!(sfired, "{ctx}: SkewSalt must fire on the annotated ⋈");
                    assert!(
                        smax < omax,
                        "{ctx}: hot shard must strictly shrink ({smax} !< {omax})"
                    );
                    let text = sframe.explain().unwrap();
                    assert!(
                        text.contains("skew: 1 hot key(s) bound"),
                        "{ctx}: explain must render the binding:\n{text}"
                    );
                } else {
                    assert!(!sfired, "{ctx}: one worker has no straggler to fix");
                }

                let (want, base) = oframe.collect_partitioned().unwrap();
                let (got, stats) = sframe.collect_partitioned().unwrap();
                assert_eq!(base.rows_salted, 0, "{ctx}: oblivious run must not salt");
                assert_eq!(base.bytes_hot_replicated, 0, "{ctx}");
                if w >= 2 {
                    assert!(stats.rows_salted > 0, "{ctx}: salted routing must engage");
                    assert!(
                        stats.bytes_hot_replicated > 0,
                        "{ctx}: hot rows must replicate"
                    );
                }
                assert_shards_bitwise(&got, &want, &ctx);
                assert!(
                    bitwise_eq(&got.gather(), &want.gather()),
                    "{ctx}: gathered result diverged"
                );
            }
        }
    }
}

/// The `SkewBroadcast` arm: S is partitioned off the join key *and* hot
/// on it, so the oblivious plan (reshuffle S alone) would route S's hot
/// rows onto R's already-hot home. The skew plan replicates R's hot
/// rows instead, pins S's hot rows at their source, hash-routes only
/// the cold tail — and reproduces the oblivious reshuffle bit for bit.
#[test]
fn skew_broadcast_pins_hot_probe_rows_bitwise() {
    let q = sumjoin_query();
    let mut r_keys: Vec<Key> = (0..48).map(|i| Key::k2(0, i)).collect();
    r_keys.extend((0..6).map(|i| Key::k2(1 + (i % 3), 100 + i)));
    let r0 = int_pairs(r_keys, 2, 0x5B01);
    let mut s_keys: Vec<Key> = (0..30).map(|k| Key::k2(0, k)).collect();
    s_keys.extend((1..4).map(|j| Key::k2(j, 50 + j)));
    let s0 = int_pairs(s_keys, 2, 0x5B02);
    for w in [2usize, 8] {
        let ctx = format!("w={w}");
        let mk = |thresh: Option<f64>| {
            let mut cfg = ClusterConfig::new(w).with_factorize(false).with_net(skew_net());
            if let Some(t) = thresh {
                cfg = cfg.with_skew_threshold(t);
            }
            let sess = Session::new(cfg);
            sess.register_with_layout(
                "R",
                &["a", "b"],
                &Relation::from_pairs(r0.clone()),
                &SlotLayout::HashOn(vec![0]),
            )
            .unwrap();
            // S is placed by its *second* column: mispartitioned for the
            // ⋈ on a, and uniform on that placement key, so S itself is
            // never annotated — only R's hot set drives the plan.
            sess.register_with_layout(
                "S",
                &["a", "c"],
                &Relation::from_pairs(s0.clone()),
                &SlotLayout::HashOn(vec![1]),
            )
            .unwrap();
            sess
        };
        let obl = mk(None);
        let skew = mk(Some(0.3));
        assert_eq!(skew.stats().hot_keys_detected, 1, "{ctx}: only R's head");

        let oframe = obl.query(&q).unwrap();
        let sframe = skew.query(&q).unwrap();
        let (omax, ofired) = join_profile(&oframe);
        let (smax, sfired) = join_profile(&sframe);
        assert!(!ofired, "{ctx}: oblivious session must not plan skew");
        assert!(sfired, "{ctx}: SkewBroadcast must fire");
        let (strace, _) = sframe.trace().unwrap();
        assert!(
            strace
                .iter()
                .any(|t| matches!(&t.strategy, Some(s) if format!("{s:?}").contains("SkewBroadcast"))),
            "{ctx}: expected the broadcast strategy, not salting"
        );
        assert!(
            smax < omax,
            "{ctx}: hot shard must strictly shrink ({smax} !< {omax})"
        );

        let (want, base) = oframe.collect_partitioned().unwrap();
        let (got, stats) = sframe.collect_partitioned().unwrap();
        assert_eq!(base.bytes_hot_replicated, 0, "{ctx}");
        assert!(stats.rows_salted > 0, "{ctx}: hot probe rows must pin at source");
        assert!(
            stats.bytes_hot_replicated > 0,
            "{ctx}: hot build rows must replicate"
        );
        assert_shards_bitwise(&got, &want, &ctx);
        assert!(
            bitwise_eq(&got.gather(), &want.gather()),
            "{ctx}: gathered result diverged"
        );
    }
}

/// Factorization parity: with the session-default rewriter *on*, the
/// hot-key annotation must not change which plan factorizes
/// (`hash_comps` treats `SkewHash` exactly like `Hash`) — same traced
/// stage sequence, bitwise-identical outputs.
#[test]
fn factorized_plan_is_unchanged_by_skew_annotation() {
    let q = sumjoin_query();
    let r0 = zipf_head_r();
    let s0 = uniform_s();
    let w = 2usize;
    let mk = |thresh: Option<f64>| {
        let mut cfg = ClusterConfig::new(w).with_net(skew_net());
        if let Some(t) = thresh {
            cfg = cfg.with_skew_threshold(t);
        }
        let sess = Session::new(cfg);
        sess.register_with_layout(
            "R",
            &["a", "b"],
            &Relation::from_pairs(r0.clone()),
            &SlotLayout::HashOn(vec![0]),
        )
        .unwrap();
        sess.register_with_layout(
            "S",
            &["a", "c"],
            &Relation::from_pairs(s0.clone()),
            &SlotLayout::HashOn(vec![0]),
        )
        .unwrap();
        sess
    };
    let obl = mk(None);
    let skew = mk(Some(0.3));
    let oframe = obl.query(&q).unwrap();
    let sframe = skew.query(&q).unwrap();
    let (otrace, _) = oframe.trace().unwrap();
    let (strace, _) = sframe.trace().unwrap();
    let oops: Vec<&str> = otrace.iter().map(|t| t.op).collect();
    let sops: Vec<&str> = strace.iter().map(|t| t.op).collect();
    assert_eq!(oops, sops, "annotation changed the factorized stage sequence");
    let (want, _) = oframe.collect_partitioned().unwrap();
    let (got, _) = sframe.collect_partitioned().unwrap();
    assert_shards_bitwise(&got, &want, "factorize parity");
    assert!(bitwise_eq(&got.gather(), &want.gather()), "gathered diverged");
}

/// The end-to-end ML claim: GCN gradients and a 3-step training loop on
/// a Chung-Lu power-law graph — whose hub node the ingest sampler
/// annotates on the Edge relation — produce bit-identical losses,
/// per-step gradients, and final parameters with the skew machinery on
/// and off, at every worker count.
#[test]
fn gcn_training_on_power_law_graph_is_bitwise_under_skew() {
    let g = power_law_graph("skew", 40, 120, 8, 4, 0.5, 31);
    let cfg = GcnConfig {
        feat_dim: 8,
        hidden: 8,
        n_labels: 4,
        dropout: None,
        seed: 5,
    };
    let q = gcn::loss_query(&cfg, g.labels.len());
    for w in [1usize, 2, 8] {
        let run = |thresh: Option<f64>| {
            let mut ccfg = ClusterConfig::new(w).with_net(skew_net());
            if let Some(t) = thresh {
                ccfg = ccfg.with_skew_threshold(t);
            }
            let sess = Session::new(ccfg);
            sess.register_with_layout(
                "Edge",
                &["dst", "src"],
                &g.edges,
                &SlotLayout::HashOn(vec![0]),
            )
            .unwrap();
            sess.register("Node", &["id"], &g.feats).unwrap();
            sess.register("Y", &["id"], &g.labels).unwrap();
            let hot = sess.stats().hot_keys_detected;
            let mut trainer = sess
                .trainer(ModelSpec::new(q.clone()).param("W1", 1).param("W2", 1))
                .unwrap();
            let mut rng = Prng::new(77);
            let (mut w1, mut w2) = gcn::init_params(&cfg, &mut rng);
            let mut losses = Vec::new();
            let mut grad_bits = Vec::new();
            for _ in 0..3 {
                let res = trainer.step(&[("W1", &w1), ("W2", &w2)]).unwrap();
                losses.push(res.loss.to_bits());
                for (name, grel) in &res.grads {
                    let bits: Vec<u32> = grel
                        .iter()
                        .flat_map(|(_, v)| v.data().iter().map(|x| x.to_bits()))
                        .collect();
                    grad_bits.push((name.clone(), bits));
                    let target = if name == "W1" { &mut w1 } else { &mut w2 };
                    sgd_apply(target, grel, 0.1);
                }
            }
            (hot, losses, grad_bits, w1, w2)
        };
        let ctx = format!("w={w}");
        let (hc, lc, gc, c1, c2) = run(None);
        assert_eq!(hc, 0, "{ctx}: sampler off detects nothing");
        let (hs, ls, gs, s1, s2) = run(Some(0.03));
        assert!(
            hs > 0,
            "{ctx}: the power-law hub must be annotated on Edge"
        );
        assert_eq!(lc, ls, "{ctx}: loss curves diverged under skew handling");
        assert_eq!(gc, gs, "{ctx}: per-step gradient bits diverged");
        assert!(bitwise_eq(&c1, &s1), "{ctx}: final W1 diverged");
        assert!(bitwise_eq(&c2, &s2), "{ctx}: final W2 diverged");
    }
}

/// Sampler properties on the >1024-row path: a fixed seed reproduces
/// the same hot set, and the Zipf(1.1) head — the population-wide most
/// frequent join subkey — survives the 1024-row sample at a 10%
/// threshold.
#[test]
fn ingest_sampler_is_deterministic_and_finds_the_zipf_head() {
    let mut rng = Prng::new(0x51E0);
    let mut r = Relation::new();
    for i in 0..4096i64 {
        r.insert(
            Key::k2(rng.zipf(64, 1.1) as i64, i),
            Chunk::filled(1, 1, 1.0),
        );
    }
    let hot = detect_hot_keys(&r, &[0], 0.1);
    assert_eq!(hot, detect_hot_keys(&r, &[0], 0.1), "sampler must be deterministic");
    assert!(!hot.is_empty(), "a Zipf(1.1) head must be detected");
    // Ground truth from the full population, not the sample.
    let mut counts: HashMap<i64, usize> = HashMap::new();
    for (k, _) in r.iter() {
        *counts.entry(k.get(0)).or_insert(0) += 1;
    }
    let top = counts
        .iter()
        .max_by_key(|(_, c)| **c)
        .map(|(k, _)| *k)
        .unwrap();
    assert!(
        hot.contains(&Key::k1(top)),
        "the population head {top} must be in the hot set {hot:?}"
    );
}

/// Uniform keys are never flagged: `detect_hot_keys` returns nothing,
/// a sampler-on session leaves the table plain hash-partitioned, and
/// the `hot_keys_detected` counter stays zero — skew handling costs
/// nothing when there is no skew.
#[test]
fn ingest_sampler_ignores_uniform_keys() {
    let pairs = int_pairs((0..2048).map(|i| Key::k2(i, i)), 1, 0x0511);
    let r = Relation::from_pairs(pairs);
    assert!(
        detect_hot_keys(&r, &[0], 0.01).is_empty(),
        "distinct keys must never be hot"
    );
    let sess = Session::new(ClusterConfig::new(2).with_skew_threshold(0.01));
    sess.register_with_layout("U", &["a", "b"], &r, &SlotLayout::HashOn(vec![0]))
        .unwrap();
    assert_eq!(sess.stats().hot_keys_detected, 0, "uniform ingest must charge nothing");
}
