//! Public-API coverage of the `Session` front door: typed error paths
//! (no panics on user input), the SQL round-trip fixpoint exercised
//! through `sess.sql`, and bitwise identity between session-driven
//! training and the legacy (deprecated) trainer path.

mod common;

use common::{bitwise_eq, blocked, sgd_apply};
use relad::dist::{ClusterConfig, DistError, MemPolicy};
use relad::kernels::AggKernel;
use relad::ml::gcn::{self, GcnConfig};
use relad::ml::SlotLayout;
use relad::ra::eval::eval_query;
use relad::ra::expr::matmul_query;
use relad::ra::{Chunk, Key, KeyProj, QueryBuilder, Relation};
use relad::session::{ModelSpec, Session, SessionError};
use relad::sql;
use relad::util::Prng;

const MATMUL_SQL: &str = "SELECT A.row, B.col, SUM(matmul(A.val, B.val)) \
                          FROM A, B WHERE A.col = B.row GROUP BY A.row, B.col";

// ---------------------------------------------------------- error paths

#[test]
fn oom_under_fail_policy_is_a_typed_session_error() {
    let mut rng = Prng::new(900);
    let a = blocked(4, 4, 8, &mut rng);
    let b = blocked(4, 4, 8, &mut rng);
    let cfg = ClusterConfig::new(3)
        .with_budget(2048)
        .with_policy(MemPolicy::Fail);
    let sess = Session::new(cfg);
    sess.register("A", &["row", "col"], &a).unwrap();
    sess.register("B", &["row", "col"], &b).unwrap();
    match sess.sql(MATMUL_SQL).unwrap().collect() {
        Err(SessionError::Exec(DistError::Oom { needed, budget, .. })) => {
            assert!(needed > budget);
        }
        other => panic!("expected typed OOM, got {:?}", other.map(|r| r.len())),
    }
    // The same session under Spill degrades instead (the paper's
    // headline asymmetry), visible through the session stats — and the
    // degradation is real: measured temp-file bytes, fully re-read.
    let spill = ClusterConfig::new(3)
        .with_budget(2048)
        .with_policy(MemPolicy::Spill);
    let sess = Session::new(spill);
    sess.register("A", &["row", "col"], &a).unwrap();
    sess.register("B", &["row", "col"], &b).unwrap();
    sess.sql(MATMUL_SQL).unwrap().collect().unwrap();
    let st = sess.stats();
    assert!(st.spill_passes > 0, "tight budget must spill");
    assert!(st.spill_bytes_written > 0, "spill must hit real temp files");
    assert_eq!(
        st.spill_bytes_read, st.spill_bytes_written,
        "a completed run re-reads exactly what it wrote"
    );
}

#[test]
fn spill_bytes_are_budget_driven_through_the_session() {
    let mut rng = Prng::new(907);
    let a = blocked(4, 4, 8, &mut rng);
    let b = blocked(4, 4, 8, &mut rng);
    let run = |budget: Option<u64>| {
        let mut cfg = ClusterConfig::new(2);
        if let Some(bb) = budget {
            cfg = cfg.with_budget(bb);
        }
        let sess = Session::new(cfg);
        sess.register("A", &["row", "col"], &a).unwrap();
        sess.register("B", &["row", "col"], &b).unwrap();
        let out = sess.sql(MATMUL_SQL).unwrap().collect().unwrap();
        (out, sess.stats())
    };
    // Ample budget: zero measured spill traffic, explain shows none.
    let (want, ample) = run(Some(1 << 30));
    assert_eq!(ample.spill_passes, 0);
    assert_eq!(ample.spill_bytes_written, 0);
    assert_eq!(ample.spill_bytes_read, 0);
    // Tight budget: nonzero traffic, identical bits.
    let (got, tight) = run(Some(2048));
    assert!(tight.spill_bytes_written > 0);
    assert_eq!(tight.spill_bytes_read, tight.spill_bytes_written);
    assert!(bitwise_eq(&got, &want), "spilled SQL result diverged");
    // And the rendered explain surfaces the measured counters.
    let mut cfg_sess = Session::new(ClusterConfig::new(2).with_budget(2048));
    cfg_sess.register("A", &["row", "col"], &a).unwrap();
    cfg_sess.register("B", &["row", "col"], &b).unwrap();
    let text = cfg_sess.sql(MATMUL_SQL).unwrap().explain().unwrap();
    assert!(text.contains("B spilled to disk"), "{text}");
}

#[test]
fn unknown_table_is_typed_in_sql_query_and_grad() {
    let mut rng = Prng::new(901);
    let a = blocked(2, 2, 2, &mut rng);
    let sess = Session::new(ClusterConfig::new(2));
    sess.register("A", &["row", "col"], &a).unwrap();
    // SQL FROM references a table the catalog does not hold.
    match sess.sql("SELECT Z.row, relu(Z.val) FROM Z") {
        Err(SessionError::UnknownTable(n)) => assert_eq!(n, "Z"),
        other => panic!("expected UnknownTable, got {:?}", other.map(|_| ())),
    }
    // RA query whose scan name is unregistered (matmul scans A and B).
    assert!(matches!(
        sess.query(&matmul_query()),
        Err(SessionError::UnknownTable(_))
    ));
    // grad target that is not an input of the frame.
    let mut rng = Prng::new(902);
    let b = blocked(2, 2, 2, &mut rng);
    sess.register("B", &["row", "col"], &b).unwrap();
    let frame = sess.query(&matmul_query()).unwrap();
    assert!(matches!(
        frame.grad("Nope"),
        Err(SessionError::UnknownTable(_))
    ));
}

#[test]
fn arity_mismatch_is_typed() {
    let mut rng = Prng::new(903);
    let a = blocked(3, 2, 2, &mut rng); // 2-component keys
    let sess = Session::new(ClusterConfig::new(2));
    match sess.register("A", &["row"], &a) {
        Err(SessionError::ArityMismatch {
            table,
            expected,
            got,
        }) => {
            assert_eq!(table, "A");
            assert_eq!((expected, got), (1, 2));
        }
        other => panic!("expected ArityMismatch, got {other:?}"),
    }
}

#[test]
fn grad_of_non_differentiable_query_is_typed() {
    // Σ with ⊕ = max has no graph-mode derivative: the engine must say
    // so, typed, instead of panicking.
    let mut rng = Prng::new(904);
    let x = blocked(4, 1, 2, &mut rng);
    let q = {
        let mut qb = QueryBuilder::new();
        let s = qb.scan(0, "X");
        let m = qb.agg(KeyProj::take(&[1]), AggKernel::Max, s);
        qb.finish(m)
    };
    let sess = Session::new(ClusterConfig::new(2));
    sess.register("X", &["row", "col"], &x).unwrap();
    let frame = sess.query(&q).unwrap();
    match frame.grad("X") {
        Err(SessionError::NotDifferentiable(why)) => {
            assert!(why.contains("max"), "{why}");
        }
        other => panic!("expected NotDifferentiable, got {:?}", other.map(|_| ())),
    }
}

// --------------------------------------------------- SQL round-trip

#[test]
fn sql_round_trip_fixpoint_through_the_session() {
    let mut rng = Prng::new(905);
    let a = blocked(3, 2, 4, &mut rng);
    let b = blocked(2, 3, 4, &mut rng);
    let sess = Session::new(ClusterConfig::new(2));
    sess.register("A", &["row", "col"], &a).unwrap();
    sess.register("B", &["row", "col"], &b).unwrap();
    sess.register("P", &["row"], &{
        let mut p = Relation::new();
        for i in 0..4 {
            p.insert(Key::k1(i), Chunk::random(2, 2, &mut rng, 1.0));
        }
        p
    })
    .unwrap();
    for stmt in [
        MATMUL_SQL,
        "SELECT P.row, logistic(P.val) FROM P",
        "SELECT A.row, SUM(mul(A.val, B.val)) FROM A, B \
         WHERE A.row = B.row AND A.col = B.col GROUP BY A.row",
    ] {
        // parse → unparse → parse is a fixpoint at the statement level…
        let once = sql::parse::parse(stmt).unwrap();
        let rendered = sql::stmt_to_sql(&once);
        assert_eq!(once, sql::parse::parse(&rendered).unwrap(), "{stmt}");
        // …and both renditions execute identically through the session
        // frontend.
        let got = sess.sql(stmt).unwrap().collect().unwrap();
        let rt = sess.sql(&rendered).unwrap().collect().unwrap();
        assert!(bitwise_eq(&got, &rt), "round-tripped SQL diverged: {stmt}");
    }
}

#[test]
fn sql_frame_matches_single_node_reference() {
    let mut rng = Prng::new(906);
    let a = blocked(3, 2, 4, &mut rng);
    let b = blocked(2, 3, 4, &mut rng);
    let q = matmul_query();
    let want = eval_query(&q, &[&a, &b], &relad::kernels::NativeBackend).unwrap();
    for w in [1usize, 2, 5] {
        let sess = Session::new(ClusterConfig::new(w));
        sess.register("A", &["row", "col"], &a).unwrap();
        sess.register("B", &["row", "col"], &b).unwrap();
        let got = sess.sql(MATMUL_SQL).unwrap().collect().unwrap();
        assert!(got.approx_eq(&want, 1e-4), "w={w}");
    }
}

// ----------------------------------------- session ≡ legacy, bitwise

/// Session-driven training must reproduce the legacy
/// `DistTrainer::pipeline` path **to the bit** — same losses, same final
/// parameters — at every worker count (threaded where the host allows,
/// serial beyond: both paths share the engage rule).
#[test]
fn session_training_bitwise_matches_legacy_path() {
    let g = relad::data::graphs::power_law_graph("sid", 40, 120, 8, 4, 0.5, 31);
    let cfg = GcnConfig {
        feat_dim: 8,
        hidden: 8,
        n_labels: 4,
        dropout: None,
        seed: 5,
    };
    let q = gcn::loss_query(&cfg, g.labels.len());
    for w in [1usize, 2, 8] {
        // Legacy: positional slots, explicit layouts, pipeline-owned pool.
        #[allow(deprecated)]
        let (legacy_losses, lw1, lw2) = {
            let trainer = relad::ml::DistTrainer::new(
                q.clone(),
                &[1, 1, 2, 1, 1],
                &[gcn::SLOT_W1, gcn::SLOT_W2],
            )
            .unwrap();
            let mut rng = Prng::new(77);
            let (mut w1, mut w2) = gcn::init_params(&cfg, &mut rng);
            let mut pipe = trainer.pipeline(vec![
                SlotLayout::Replicated,
                SlotLayout::Replicated,
                SlotLayout::HashOn(vec![0]),
                SlotLayout::HashFull,
                SlotLayout::HashFull,
            ]);
            let ccfg = ClusterConfig::new(w);
            let mut losses = Vec::new();
            for _ in 0..3 {
                let inputs = [&w1, &w2, &g.edges, &g.feats, &g.labels];
                let res = pipe
                    .step(&inputs, &ccfg, &relad::kernels::NativeBackend)
                    .unwrap();
                losses.push(res.loss.to_bits());
                for (slot, grel) in &res.grads {
                    let t = if *slot == gcn::SLOT_W1 { &mut w1 } else { &mut w2 };
                    sgd_apply(t, grel, 0.1);
                }
            }
            (losses, w1, w2)
        };

        // Session: named slots, catalog-cached data, session-owned pool.
        let (sess_losses, sw1, sw2) = {
            let sess = Session::new(ClusterConfig::new(w));
            sess.register_with_layout(
                "Edge",
                &["dst", "src"],
                &g.edges,
                &SlotLayout::HashOn(vec![0]),
            )
            .unwrap();
            sess.register("Node", &["id"], &g.feats).unwrap();
            sess.register("Y", &["id"], &g.labels).unwrap();
            let mut trainer = sess
                .trainer(ModelSpec::new(q.clone()).param("W1", 1).param("W2", 1))
                .unwrap();
            let mut rng = Prng::new(77);
            let (mut w1, mut w2) = gcn::init_params(&cfg, &mut rng);
            let mut losses = Vec::new();
            for _ in 0..3 {
                let res = trainer.step(&[("W1", &w1), ("W2", &w2)]).unwrap();
                losses.push(res.loss.to_bits());
                for (name, grel) in &res.grads {
                    let t = if name == "W1" { &mut w1 } else { &mut w2 };
                    sgd_apply(t, grel, 0.1);
                }
            }
            (losses, w1, w2)
        };

        assert_eq!(legacy_losses, sess_losses, "w={w}: loss curves diverged");
        assert!(bitwise_eq(&lw1, &sw1), "w={w}: W1 diverged");
        assert!(bitwise_eq(&lw2, &sw2), "w={w}: W2 diverged");
    }
}
