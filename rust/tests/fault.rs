//! Fault-tolerance acceptance suite: deterministic fault injection at
//! every instrumented point of the BSP executor, bounded stage retry
//! with lineage replay, and trainer checkpoint/restore. The headline
//! invariant throughout: a faulty-but-retried run is **bitwise
//! identical** to the fault-free run — same float bits, same shard
//! layouts, same exact counters (`bytes_shuffled`, `msgs`, spill bytes)
//! — across worker counts, both communication paths, and in-memory as
//! well as grace-spilling budgets. Failure paths are typed
//! (`DistError::StageFailed` with stage/worker/attempt coordinates),
//! never a driver panic, and never leak spill scratch.
//!
//! CI runs this suite in its fault-suite step with `RELAD_SPILL_DIR`
//! pointed at a job-scoped scratch directory (orphans checked after).

mod common;

use std::fs;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use common::{bitwise_eq, blocked, sgd_apply};
use relad::data::graphs::power_law_graph;
use relad::dist::spill::file_count;
use relad::dist::{
    ClusterConfig, DistError, ExecStats, FaultKind, FaultPlan, InjectionPoint, NetModel,
    PartitionedRelation, StageFailure,
};
use relad::kernels::{AggKernel, BinaryKernel, KernelBackend, UnaryKernel};
use relad::ml::gcn::{self, GcnConfig};
use relad::ml::SlotLayout;
use relad::ra::{Chunk, JoinPred, Key, KeyProj, KeyProj2, QueryBuilder, Relation, Sel2};
use relad::session::{ModelSpec, Session, SessionError};
use relad::util::Prng;

/// The shuffle-heavy plan `tests/spill.rs` established: a matmul whose
/// inputs are partitioned *off* the join key (the planner reshuffles
/// both sides at w > 1), followed by two cross-worker Σs. It exercises
/// every injection point: JoinBuild/JoinProbe on the ⋈ stage,
/// ShuffleSend on the reshuffles, SigmaMerge on the Σ exchanges, and
/// SpillWrite/SpillRead once a grace budget is set.
fn reshuffle_matmul_two_sigma_query() -> relad::ra::Query {
    let mut qb = QueryBuilder::new();
    let a = qb.scan(0, "A");
    let b = qb.scan(1, "B");
    let j = qb.join(
        JoinPred::on(vec![(1, 0)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
        BinaryKernel::MatMul,
        a,
        b,
    );
    let s1 = qb.agg(KeyProj::take(&[0, 2]), AggKernel::Sum, j);
    let s2 = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, s1);
    qb.finish(s2)
}

/// Bandwidth-only fabric (provably picks the both-sides reshuffle for
/// the plan above, as asserted in `tests/spill.rs`).
fn test_net() -> NetModel {
    NetModel {
        bandwidth_bps: 1.25e9,
        latency_s: 0.0,
    }
}

/// A fresh, test-unique directory to hand to `ClusterConfig::spill_dir`.
fn scratch_root(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("relad-fault-test-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&p);
    fs::create_dir_all(&p).unwrap();
    p
}

/// Exact-counter equality between a faulty-but-recovered run and its
/// fault-free baseline: retries must neither double-count traffic or
/// spill I/O nor change the stage count.
fn assert_counters_match(st: &ExecStats, base: &ExecStats, ctx: &str) {
    assert_eq!(st.bytes_shuffled, base.bytes_shuffled, "{ctx}: traffic diverged");
    assert_eq!(st.msgs, base.msgs, "{ctx}: message count diverged");
    assert_eq!(st.stages, base.stages, "{ctx}: stage count diverged");
    assert_eq!(
        st.spill_bytes_written, base.spill_bytes_written,
        "{ctx}: retries double-counted spill writes"
    );
    assert_eq!(
        st.spill_bytes_read, base.spill_bytes_read,
        "{ctx}: retries double-counted spill reads"
    );
}

/// The tentpole property. For every injection point × fault kind
/// (transient error and injected panic), a single scripted fault on
/// worker 0 is retried via lineage replay and the run converges to the
/// bit-exact fault-free result — shards, gathered relation, and exact
/// counters — at w ∈ {1, 2, 8} × parallel_comm ∈ {on, off} × {ample,
/// two-pass-spill} budgets. Where the site is guaranteed to be probed,
/// the fault fires exactly once and costs exactly one stage retry
/// (`shards_recomputed` = w per retry).
#[test]
fn transient_fault_at_every_point_retries_to_bitwise_identity() {
    let mut rng = Prng::new(0xFA01);
    let a = blocked(6, 4, 4, &mut rng);
    let b = blocked(4, 6, 4, &mut rng);
    let q = reshuffle_matmul_two_sigma_query();
    let net = test_net();
    for w in [1usize, 2, 8] {
        let pa = PartitionedRelation::hash_partition(&a, &[0], w);
        let pb = PartitionedRelation::hash_partition(&b, &[1], w);
        // Floor on the heaviest worker's join working set (its two
        // re-homed input shards) — budget = floor forces ≥ 2 grace
        // passes there, exactly as derived in tests/spill.rs.
        let (ra, _) = pa.reshuffle(&[1], w);
        let (rb, _) = pb.reshuffle(&[0], w);
        let two_pass = (0..w)
            .map(|i| ra.shards[i].nbytes() as u64 + rb.shards[i].nbytes() as u64)
            .max()
            .unwrap();
        for comm in [true, false] {
            for (budget, ample) in [(u64::MAX / 4, true), (two_pass, false)] {
                let run = |plan: Option<FaultPlan>| {
                    let mut cfg = ClusterConfig::new(w)
                        .with_net(net)
                        .with_parallel_comm(comm)
                        .with_budget(budget);
                    if let Some(p) = plan {
                        cfg = cfg.with_fault_plan(p);
                    }
                    let sess = Session::new(cfg);
                    sess.register_partitioned("A", &["r", "c"], pa.clone()).unwrap();
                    sess.register_partitioned("B", &["r", "c"], pb.clone()).unwrap();
                    sess.query(&q).unwrap().collect_partitioned().unwrap()
                };
                let (bp, bst) = run(None);
                assert_eq!(bst.faults_injected, 0);
                assert_eq!(bst.stage_retries, 0);
                let want = bp.gather();
                for point in InjectionPoint::ALL {
                    for kind in [FaultKind::TransientError, FaultKind::PanicJob] {
                        let ctx = format!(
                            "w={w} comm={comm} ample={ample} point={point} kind={kind:?}"
                        );
                        let (gp, st) = run(Some(FaultPlan::new().once(point, 0, 1, kind)));
                        assert!(
                            bitwise_eq(&gp.gather(), &want),
                            "{ctx}: faulty-but-retried run diverged from fault-free"
                        );
                        for (x, y) in gp.shards.iter().zip(bp.shards.iter()) {
                            assert!(
                                bitwise_eq(x.as_ref(), y.as_ref()),
                                "{ctx}: shard layout diverged"
                            );
                        }
                        assert_counters_match(&st, &bst, &ctx);
                        // Every fired fault costs exactly one replay of
                        // one stage, i.e. w recomputed shards.
                        assert_eq!(
                            st.stage_retries, st.faults_injected,
                            "{ctx}: fault/retry accounting out of sync"
                        );
                        assert_eq!(
                            st.shards_recomputed,
                            st.stage_retries * w as u64,
                            "{ctx}: lineage replay recomputes all w shards"
                        );
                        // Where the site is structurally guaranteed to
                        // be probed (or guaranteed not to be), pin the
                        // counters exactly.
                        let must_fire: Option<bool> = match point {
                            // Every join stage probes these, any budget.
                            InjectionPoint::JoinBuild | InjectionPoint::JoinProbe => Some(true),
                            // The Σ exchange provably runs at w > 1
                            // (two cross-worker Σs in this plan).
                            InjectionPoint::SigmaMerge => (w > 1).then_some(true),
                            // Reshuffles exist iff there is more than
                            // one worker to exchange with.
                            InjectionPoint::ShuffleSend => Some(w > 1),
                            // Grace spill runs under the tight budget;
                            // at w = 1 the only worker is the spiller.
                            InjectionPoint::SpillWrite | InjectionPoint::SpillRead => {
                                if ample {
                                    Some(false)
                                } else {
                                    (w == 1).then_some(true)
                                }
                            }
                            // Fresh runs never take the delta path; the
                            // site is only probed when a frame replays a
                            // catalog delta (covered below).
                            InjectionPoint::DeltaApply => Some(false),
                        };
                        match must_fire {
                            Some(true) => {
                                assert_eq!(st.faults_injected, 1, "{ctx}: fault must fire once");
                                assert_eq!(st.stage_retries, 1, "{ctx}: exactly one retry");
                            }
                            Some(false) => {
                                assert_eq!(st.faults_injected, 0, "{ctx}: site must not probe")
                            }
                            None => {}
                        }
                    }
                }
            }
        }
    }
}

/// A straggler (`FaultKind::Slow`) is counted in `faults_injected` but
/// is not a failure: no retry, bit-identical result.
#[test]
fn slow_worker_is_counted_but_never_retried() {
    let mut rng = Prng::new(0x510E);
    let a = blocked(6, 4, 4, &mut rng);
    let b = blocked(4, 6, 4, &mut rng);
    let q = reshuffle_matmul_two_sigma_query();
    let run = |plan: Option<FaultPlan>| {
        let mut cfg = ClusterConfig::new(2).with_net(test_net());
        if let Some(p) = plan {
            cfg = cfg.with_fault_plan(p);
        }
        let sess = Session::new(cfg);
        sess.register("A", &["r", "c"], &a).unwrap();
        sess.register("B", &["r", "c"], &b).unwrap();
        let (gp, st) = sess.query(&q).unwrap().collect_partitioned().unwrap();
        (gp.gather(), st)
    };
    let (want, _) = run(None);
    let slow = FaultPlan::new().always(
        InjectionPoint::JoinBuild,
        0,
        FaultKind::Slow { delay_ms: 2 },
    );
    let (got, st) = run(Some(slow));
    assert!(bitwise_eq(&got, &want), "a straggler changed the result");
    assert!(st.faults_injected >= 1, "straggler faults must be counted");
    assert_eq!(st.stage_retries, 0, "a straggler is not a failure");
    assert_eq!(st.shards_recomputed, 0);
}

/// A fault that survives every allowed lineage replay surfaces as a
/// typed `DistError::StageFailed` with exact coordinates — the failed
/// query node, the failing worker, and the attempt count
/// (`max_stage_retries` + 1) — never a driver panic. Checked at both
/// `max_stage_retries` = 0 (fail fast) and the default budget.
#[test]
fn permanent_transient_fault_surfaces_typed_stage_failure() {
    let mut rng = Prng::new(0xDEAD);
    let a = blocked(6, 4, 4, &mut rng);
    let b = blocked(4, 6, 4, &mut rng);
    // Factorization off so node ids are exactly as written:
    // scan A = 0, scan B = 1, join = 2, Σ = 3, Σ = 4.
    let q = reshuffle_matmul_two_sigma_query();
    for retries in [0u32, 2] {
        let plan =
            FaultPlan::new().always(InjectionPoint::JoinBuild, 1, FaultKind::TransientError);
        let cfg = ClusterConfig::new(2)
            .with_net(test_net())
            .with_factorize(false)
            .with_max_stage_retries(retries)
            .with_fault_plan(plan);
        let sess = Session::new(cfg);
        sess.register("A", &["r", "c"], &a).unwrap();
        sess.register("B", &["r", "c"], &b).unwrap();
        match sess.query(&q).unwrap().collect() {
            Err(SessionError::Exec(DistError::StageFailed {
                stage,
                worker,
                attempts,
                source: StageFailure::RetriesExhausted(_),
            })) => {
                assert_eq!(stage, 2, "retries={retries}: wrong stage coordinate");
                assert_eq!(worker, 1, "retries={retries}: wrong worker coordinate");
                assert_eq!(
                    attempts,
                    retries + 1,
                    "retries={retries}: wrong attempt count"
                );
            }
            other => panic!(
                "retries={retries}: expected StageFailed(RetriesExhausted), got {:?}",
                other.map(|r| r.len())
            ),
        }
    }
}

/// A permanent fault inside the grace-spill loop: the stage fails typed
/// (after exhausting retries) and leaves **zero** files in the
/// configured scratch directory — failed attempts drop their runs, and
/// the session drop removes the whole tree.
#[test]
fn exhausted_spill_fault_leaves_no_scratch_orphans() {
    let mut rng = Prng::new(0x0F0A);
    let a = blocked(6, 4, 4, &mut rng);
    let b = blocked(4, 6, 4, &mut rng);
    let q = reshuffle_matmul_two_sigma_query();
    let w = 2usize;
    let pa = PartitionedRelation::hash_partition(&a, &[0], w);
    let pb = PartitionedRelation::hash_partition(&b, &[1], w);
    let (ra, _) = pa.reshuffle(&[1], w);
    let (rb, _) = pb.reshuffle(&[0], w);
    let two_pass = (0..w)
        .map(|i| ra.shards[i].nbytes() as u64 + rb.shards[i].nbytes() as u64)
        .max()
        .unwrap();
    let root = scratch_root("orphan");
    // Whichever worker spills hits a permanent read fault.
    let plan = FaultPlan::new()
        .always(InjectionPoint::SpillRead, 0, FaultKind::TransientError)
        .always(InjectionPoint::SpillRead, 1, FaultKind::TransientError);
    let cfg = ClusterConfig::new(w)
        .with_net(test_net())
        .with_budget(two_pass)
        .with_spill_dir(&root)
        .with_fault_plan(plan);
    let sess = Session::new(cfg);
    sess.register_partitioned("A", &["r", "c"], pa.clone()).unwrap();
    sess.register_partitioned("B", &["r", "c"], pb.clone()).unwrap();
    match sess.query(&q).unwrap().collect() {
        Err(SessionError::Exec(DistError::StageFailed {
            attempts,
            source: StageFailure::RetriesExhausted(_),
            ..
        })) => assert_eq!(attempts, 3, "default budget is 2 retries = 3 attempts"),
        other => panic!(
            "expected StageFailed(RetriesExhausted), got {:?}",
            other.map(|r| r.len())
        ),
    }
    assert_eq!(file_count(&root), 0, "failed faulty stage leaked spill runs");
    drop(sess);
    assert!(
        fs::read_dir(&root).unwrap().next().is_none(),
        "session drop must remove its scratch tree"
    );
    let _ = fs::remove_dir_all(&root);
}

/// A kernel backend whose `binary` panics exactly once across all
/// worker instances (a scripted *genuine* bug — a plain `panic!`, not
/// an injected fault), then computes natively.
struct FaultyOnceBackend {
    tripped: Arc<AtomicBool>,
}

impl KernelBackend for FaultyOnceBackend {
    fn unary(&self, k: &UnaryKernel, key: &Key, x: &Chunk) -> Chunk {
        relad::kernels::native::apply_unary(k, key, x)
    }

    fn binary(&self, k: &BinaryKernel, key: &Key, l: &Chunk, r: &Chunk) -> Chunk {
        if !self.tripped.swap(true, Ordering::SeqCst) {
            panic!("simulated kernel bug");
        }
        relad::kernels::native::apply_binary(k, key, l, r)
    }

    fn name(&self) -> &'static str {
        "faulty-once"
    }

    fn for_worker(&self) -> Box<dyn KernelBackend + Send + Sync> {
        Box::new(FaultyOnceBackend {
            tripped: Arc::clone(&self.tripped),
        })
    }
}

/// A genuine worker panic (non-injected payload) is classified fatal:
/// typed `StageFailed(FatalJob)` on the **first** attempt — a real bug
/// is never masked by retries — the driver does not panic, and the
/// worker pool survives to run the next query correctly.
#[test]
fn genuine_worker_panic_is_fatal_typed_and_pool_survives() {
    let mut rng = Prng::new(0xFA7A);
    let a = blocked(6, 4, 4, &mut rng);
    let b = blocked(4, 6, 4, &mut rng);
    let q = reshuffle_matmul_two_sigma_query();
    let register = |sess: &Session| {
        sess.register("A", &["r", "c"], &a).unwrap();
        sess.register("B", &["r", "c"], &b).unwrap();
    };
    let clean = Session::new(ClusterConfig::new(2).with_net(test_net()));
    register(&clean);
    let want = clean.query(&q).unwrap().collect().unwrap();

    let tripped = Arc::new(AtomicBool::new(false));
    let sess = Session::with_backend(
        ClusterConfig::new(2).with_net(test_net()),
        Box::new(FaultyOnceBackend {
            tripped: Arc::clone(&tripped),
        }),
    );
    register(&sess);
    match sess.query(&q).unwrap().collect() {
        Err(SessionError::Exec(DistError::StageFailed {
            attempts,
            source: StageFailure::FatalJob(msg),
            ..
        })) => {
            assert_eq!(attempts, 1, "a fatal job must never be retried");
            assert!(msg.contains("simulated kernel bug"), "payload lost: {msg}");
        }
        other => panic!(
            "expected StageFailed(FatalJob), got {:?}",
            other.map(|r| r.len())
        ),
    }
    assert!(tripped.load(Ordering::SeqCst), "premise: the bug never ran");
    // The pool is not poisoned: the same session, same query, now that
    // the scripted bug is spent, produces the correct result.
    let got = sess.query(&q).unwrap().collect().unwrap();
    assert!(bitwise_eq(&got, &want), "post-panic session diverged");
}

fn gcn_session(cfg: ClusterConfig, g: &relad::data::GraphDataset) -> Session {
    let sess = Session::new(cfg);
    sess.register_with_layout("Edge", &["dst", "src"], &g.edges, &SlotLayout::HashOn(vec![0]))
        .unwrap();
    sess.register("Node", &["id"], &g.feats).unwrap();
    sess.register("Y", &["id"], &g.labels).unwrap();
    sess
}

/// The headline invariant on a full training loop: a 3-step GCN run
/// with scripted faults in every step (transient errors *and* injected
/// panics, landing in forward and backward executions) reproduces the
/// fault-free loop's losses and final parameters to the bit, at every
/// worker count, on both communication paths, in-memory and spilling.
#[test]
fn faulty_training_loop_matches_clean_loop_bitwise() {
    let g = power_law_graph("fault", 40, 120, 8, 4, 0.5, 31);
    let cfg = GcnConfig {
        feat_dim: 8,
        hidden: 8,
        n_labels: 4,
        dropout: None,
        seed: 5,
    };
    let q = gcn::loss_query(&cfg, g.labels.len());
    // Per-execution scripts (occurrence coordinates restart for every
    // forward/backward evaluation, so these fire throughout the loop).
    let plan = || {
        FaultPlan::new()
            .once(InjectionPoint::JoinBuild, 0, 1, FaultKind::TransientError)
            .once(InjectionPoint::SigmaMerge, 0, 2, FaultKind::PanicJob)
            .once(InjectionPoint::JoinProbe, 0, 3, FaultKind::TransientError)
    };
    for w in [1usize, 2, 8] {
        for comm in [true, false] {
            for budget in [None, Some(2048u64)] {
                let run = |faulty: bool| -> (Vec<u32>, Relation, Relation, ExecStats) {
                    let mut ccfg = ClusterConfig::new(w).with_parallel_comm(comm);
                    if let Some(bb) = budget {
                        ccfg = ccfg.with_budget(bb);
                    }
                    if faulty {
                        ccfg = ccfg.with_fault_plan(plan());
                    }
                    let sess = gcn_session(ccfg, &g);
                    let mut trainer = sess
                        .trainer(ModelSpec::new(q.clone()).param("W1", 1).param("W2", 1))
                        .unwrap();
                    let mut rng = Prng::new(77);
                    let (mut w1, mut w2) = gcn::init_params(&cfg, &mut rng);
                    let mut losses = Vec::new();
                    for _ in 0..3 {
                        let res = trainer.step(&[("W1", &w1), ("W2", &w2)]).unwrap();
                        losses.push(res.loss.to_bits());
                        for (name, grel) in &res.grads {
                            let target = if name == "W1" { &mut w1 } else { &mut w2 };
                            sgd_apply(target, grel, 0.1);
                        }
                    }
                    let stats = sess.stats();
                    (losses, w1, w2, stats)
                };
                let ctx = format!("w={w} comm={comm} budget={budget:?}");
                let (lc, c1, c2, sc) = run(false);
                assert_eq!(sc.faults_injected, 0, "{ctx}");
                assert_eq!(sc.stage_retries, 0, "{ctx}");
                let (lf, f1, f2, sf) = run(true);
                assert_eq!(lc, lf, "{ctx}: loss curves diverged under faults");
                assert!(bitwise_eq(&c1, &f1), "{ctx}: W1 diverged under faults");
                assert!(bitwise_eq(&c2, &f2), "{ctx}: W2 diverged under faults");
                assert!(sf.stage_retries > 0, "{ctx}: no fault ever fired");
                assert_eq!(
                    sf.stage_retries, sf.faults_injected,
                    "{ctx}: fault/retry accounting out of sync"
                );
                assert_eq!(
                    sf.shards_recomputed,
                    sf.stage_retries * w as u64,
                    "{ctx}: lineage replay recomputes all w shards"
                );
            }
        }
    }
}

/// Checkpoint → kill → restore: a 3-step GCN run interrupted after step
/// 1 (trainer checkpointed, session dropped — the "kill") and resumed in
/// a **fresh** session restores the step counter and parameter bits and
/// finishes with losses and final parameters bitwise identical to the
/// uninterrupted run. Exercised in-memory at w ∈ {1, 2, 8} and through
/// the grace-spill path at w = 2.
#[test]
fn checkpoint_kill_restore_resumes_bitwise() {
    let g = power_law_graph("ckpt", 40, 120, 8, 4, 0.5, 31);
    let cfg = GcnConfig {
        feat_dim: 8,
        hidden: 8,
        n_labels: 4,
        dropout: None,
        seed: 5,
    };
    let q = gcn::loss_query(&cfg, g.labels.len());
    let spec = || ModelSpec::new(q.clone()).param("W1", 1).param("W2", 1);
    for (w, budget) in [(1usize, None), (2, None), (8, None), (2, Some(2048u64))] {
        let mk_cfg = || {
            let mut ccfg = ClusterConfig::new(w);
            if let Some(bb) = budget {
                ccfg = ccfg.with_budget(bb);
            }
            ccfg
        };
        let ctx = format!("w={w} budget={budget:?}");

        // Uninterrupted reference: 3 steps, one session.
        let mut rng = Prng::new(77);
        let (mut r1, mut r2) = gcn::init_params(&cfg, &mut rng);
        let mut ref_losses = Vec::new();
        {
            let sess = gcn_session(mk_cfg(), &g);
            let mut trainer = sess.trainer(spec()).unwrap();
            for _ in 0..3 {
                let res = trainer.step(&[("W1", &r1), ("W2", &r2)]).unwrap();
                ref_losses.push(res.loss.to_bits());
                for (name, grel) in &res.grads {
                    let target = if name == "W1" { &mut r1 } else { &mut r2 };
                    sgd_apply(target, grel, 0.1);
                }
            }
        }

        // Interrupted run: 1 step, checkpoint, kill.
        let ckpt = std::env::temp_dir().join(format!(
            "relad-fault-ckpt-{}-{w}-{}",
            std::process::id(),
            budget.unwrap_or(0)
        ));
        let _ = fs::remove_dir_all(&ckpt);
        let mut rng = Prng::new(77);
        let (mut w1, mut w2) = gcn::init_params(&cfg, &mut rng);
        let first_loss;
        {
            let sess = gcn_session(mk_cfg(), &g);
            let mut trainer = sess.trainer(spec()).unwrap();
            let res = trainer.step(&[("W1", &w1), ("W2", &w2)]).unwrap();
            first_loss = res.loss.to_bits();
            for (name, grel) in &res.grads {
                let target = if name == "W1" { &mut w1 } else { &mut w2 };
                sgd_apply(target, grel, 0.1);
            }
            let total = trainer.checkpoint(&ckpt, &[("W1", &w1), ("W2", &w2)]).unwrap();
            assert!(total > 0, "{ctx}: empty checkpoint");
            assert!(
                sess.stats().checkpoint_bytes >= total,
                "{ctx}: checkpoint bytes not accounted"
            );
        } // <- the "kill": trainer and session drop here.
        assert_eq!(first_loss, ref_losses[0], "{ctx}: premise — step 1 diverged");

        // Fresh session, restore, finish the run.
        let sess = gcn_session(mk_cfg(), &g);
        let (mut trainer, restored) = sess.restore_trainer(&ckpt, spec()).unwrap();
        assert_eq!(trainer.steps(), 1, "{ctx}: step counter lost");
        let names: Vec<&str> = restored.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["W1", "W2"], "{ctx}: parameter order lost");
        assert!(bitwise_eq(&restored[0].1, &w1), "{ctx}: restored W1 drifted");
        assert!(bitwise_eq(&restored[1].1, &w2), "{ctx}: restored W2 drifted");
        let (mut w1, mut w2) = (restored[0].1.clone(), restored[1].1.clone());
        for step in 1..3 {
            let res = trainer.step(&[("W1", &w1), ("W2", &w2)]).unwrap();
            assert_eq!(
                res.loss.to_bits(),
                ref_losses[step],
                "{ctx}: resumed loss diverged at step {}",
                step + 1
            );
            for (name, grel) in &res.grads {
                let target = if name == "W1" { &mut w1 } else { &mut w2 };
                sgd_apply(target, grel, 0.1);
            }
        }
        assert_eq!(trainer.steps(), 3, "{ctx}: resumed run lost count");
        assert!(bitwise_eq(&w1, &r1), "{ctx}: resumed W1 diverged");
        assert!(bitwise_eq(&w2, &r2), "{ctx}: resumed W2 diverged");
        let _ = fs::remove_dir_all(&ckpt);
    }
}

/// Skew composition: scripted faults — a transient error and an
/// injected worker panic — landing at `JoinBuild` and `JoinProbe`
/// inside a **salted** join stage are retried via lineage replay like
/// any other stage fault (the salted routing is deterministic, so the
/// replay re-derives the identical bucket assignment), and the
/// recovered run is bitwise identical to the fault-free skew run with
/// exact counters: one fault, one retry, `w` recomputed shards, and no
/// double-charged salted rows or hot replicas across the retry.
#[test]
fn transient_fault_in_salted_join_retries_to_bitwise_identity() {
    let mut rng = Prng::new(0x5FA1);
    let mut chunk = || Chunk::filled(2, 2, (rng.next_u64() % 9 + 1) as f32);
    // Zipf-headed R (75% of rows on join key a = 0) against a uniform S,
    // co-partitioned on the join key; the ingest sampler annotates the
    // head and the byte-dominated fabric makes `SkewSalt` the cheapest
    // plan at w = 2 — the same shape `tests/skew.rs` proves fires.
    let mut r_keys: Vec<Key> = (0..192).map(|i| Key::k2(0, i)).collect();
    r_keys.extend((0..64).map(|i| Key::k2(1 + (i % 63), 1000 + i)));
    let r0: Vec<(Key, Chunk)> = r_keys.into_iter().map(|k| (k, chunk())).collect();
    let s0: Vec<(Key, Chunk)> = (0..64).map(|g| (Key::k2(g, 5000 + g), chunk())).collect();
    let mut qb = QueryBuilder::new();
    let r = qb.scan(0, "R");
    let s = qb.scan(1, "S");
    let j = qb.join(
        JoinPred::on(vec![(0, 0)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
        BinaryKernel::Mul,
        r,
        s,
    );
    let a = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, j);
    let q = qb.finish(a);
    let w = 2usize;
    let skew_net = NetModel {
        bandwidth_bps: 1e3,
        latency_s: 0.0,
    };
    let mk = |plan: Option<FaultPlan>| {
        let mut cfg = ClusterConfig::new(w)
            .with_net(skew_net)
            .with_factorize(false)
            .with_skew_threshold(0.3);
        if let Some(p) = plan {
            cfg = cfg.with_fault_plan(p);
        }
        let sess = Session::new(cfg);
        sess.register_with_layout(
            "R",
            &["a", "b"],
            &Relation::from_pairs(r0.clone()),
            &SlotLayout::HashOn(vec![0]),
        )
        .unwrap();
        sess.register_with_layout(
            "S",
            &["a", "c"],
            &Relation::from_pairs(s0.clone()),
            &SlotLayout::HashOn(vec![0]),
        )
        .unwrap();
        sess
    };
    // Premise: this shape actually takes the salted plan.
    let (trace, _) = mk(None).query(&q).unwrap().trace().unwrap();
    assert!(
        trace
            .iter()
            .any(|t| matches!(&t.strategy, Some(s) if format!("{s:?}").contains("SkewSalt"))),
        "premise: SkewSalt must fire on this shape"
    );
    let run = |plan: Option<FaultPlan>| {
        mk(plan).query(&q).unwrap().collect_partitioned().unwrap()
    };
    let (bp, bst) = run(None);
    assert_eq!(bst.faults_injected, 0);
    assert_eq!(bst.stage_retries, 0);
    assert!(bst.rows_salted > 0, "premise: salted routing must engage");
    assert!(bst.bytes_hot_replicated > 0, "premise: hot rows must replicate");
    for point in [InjectionPoint::JoinBuild, InjectionPoint::JoinProbe] {
        for kind in [FaultKind::TransientError, FaultKind::PanicJob] {
            let ctx = format!("salted-join point={point} kind={kind:?}");
            let (gp, st) = run(Some(FaultPlan::new().once(point, 0, 1, kind)));
            assert_eq!(st.faults_injected, 1, "{ctx}: the salted worker must probe");
            assert_eq!(st.stage_retries, 1, "{ctx}: exactly one retry");
            assert_eq!(st.shards_recomputed, w as u64, "{ctx}: one stage replayed");
            assert_eq!(
                st.rows_salted, bst.rows_salted,
                "{ctx}: salted rows double-charged across the retry"
            );
            assert_eq!(
                st.bytes_hot_replicated, bst.bytes_hot_replicated,
                "{ctx}: hot replicas double-charged across the retry"
            );
            assert_counters_match(&st, &bst, &ctx);
            assert!(
                bitwise_eq(&gp.gather(), &bp.gather()),
                "{ctx}: diverged from the fault-free skew run"
            );
            for (x, y) in gp.shards.iter().zip(bp.shards.iter()) {
                assert!(bitwise_eq(x.as_ref(), y.as_ref()), "{ctx}: shard layout diverged");
            }
        }
    }
}

/// `InjectionPoint::DeltaApply` — the probe at the head of every
/// delta-step replay. A fault (transient error or injected panic)
/// while a frame applies a catalog delta is retried like any stage
/// fault — delta planning is a pure function of the previous tape and
/// the computed children, so the replay is idempotent — and the
/// recovered run is bitwise identical to the fault-free delta run and
/// to a full recompute, with no reuse counter double-charged across
/// the retry.
#[test]
fn transient_fault_during_delta_apply_retries_to_bitwise_identity() {
    let mut rng = Prng::new(0xDE17);
    let mut chunk = || Chunk::filled(2, 2, (rng.next_u64() % 9 + 1) as f32);
    // Co-partitioned Σ(R ⋈ S) on the join key: the insert replays as a
    // join-append + Σ-fold, so the DeltaApply site is provably probed.
    let r0: Vec<(Key, Chunk)> = (0..64).map(|i| (Key::k2(i % 8, i), chunk())).collect();
    let s0: Vec<(Key, Chunk)> = (0..8).map(|g| (Key::k2(g, 100 + g), chunk())).collect();
    let batch: Vec<(Key, Chunk)> = (0..8).map(|g| (Key::k2(g, 1000 + g), chunk())).collect();
    let mut qb = QueryBuilder::new();
    let r = qb.scan(0, "R");
    let s = qb.scan(1, "S");
    let j = qb.join(
        JoinPred::on(vec![(0, 0)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
        BinaryKernel::Mul,
        r,
        s,
    );
    let a = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, j);
    let q = qb.finish(a);
    let w = 2usize;
    let run = |plan: Option<FaultPlan>| {
        let mut cfg = ClusterConfig::new(w).with_net(test_net()).with_factorize(false);
        if let Some(p) = plan {
            cfg = cfg.with_fault_plan(p);
        }
        let sess = Session::new(cfg);
        sess.register_with_layout(
            "R",
            &["a", "b"],
            &Relation::from_pairs(r0.clone()),
            &SlotLayout::HashOn(vec![0]),
        )
        .unwrap();
        sess.register_with_layout(
            "S",
            &["a", "c"],
            &Relation::from_pairs(s0.clone()),
            &SlotLayout::HashOn(vec![0]),
        )
        .unwrap();
        let frame = sess.query(&q).unwrap();
        frame.collect().unwrap();
        sess.insert("R", batch.clone()).unwrap();
        frame.collect_partitioned().unwrap()
    };
    let (bp, bst) = run(None);
    assert_eq!(bst.faults_injected, 0);
    assert!(
        bst.shards_reused >= 2 * w as u64,
        "premise: the delta path must engage, got {} reused shards",
        bst.shards_reused
    );
    for kind in [FaultKind::TransientError, FaultKind::PanicJob] {
        let ctx = format!("delta-apply kind={kind:?}");
        let (gp, st) = run(Some(FaultPlan::new().once(
            InjectionPoint::DeltaApply,
            0,
            1,
            kind,
        )));
        assert_eq!(st.faults_injected, 1, "{ctx}: the replay must probe DeltaApply");
        assert_eq!(st.stage_retries, 1, "{ctx}: exactly one retry");
        assert_eq!(st.shards_recomputed, w as u64, "{ctx}: one stage replayed");
        assert_eq!(
            st.shards_reused, bst.shards_reused,
            "{ctx}: reuse double-charged across the retry"
        );
        assert_counters_match(&st, &bst, &ctx);
        assert!(
            bitwise_eq(&gp.gather(), &bp.gather()),
            "{ctx}: diverged from the fault-free delta run"
        );
        for (x, y) in gp.shards.iter().zip(bp.shards.iter()) {
            assert!(bitwise_eq(x.as_ref(), y.as_ref()), "{ctx}: shard layout diverged");
        }
    }
    // And the recovered delta result is the full-recompute result.
    let fresh = Session::new(ClusterConfig::new(w).with_net(test_net()).with_factorize(false));
    let mut r1 = r0.clone();
    r1.extend(batch.iter().cloned());
    fresh
        .register_with_layout(
            "R",
            &["a", "b"],
            &Relation::from_pairs(r1),
            &SlotLayout::HashOn(vec![0]),
        )
        .unwrap();
    fresh
        .register_with_layout(
            "S",
            &["a", "c"],
            &Relation::from_pairs(s0.clone()),
            &SlotLayout::HashOn(vec![0]),
        )
        .unwrap();
    let want = fresh.query(&q).unwrap().collect().unwrap();
    assert!(
        bitwise_eq(&bp.gather(), &want),
        "delta run diverged from full recompute"
    );
}
