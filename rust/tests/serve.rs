//! Serving-layer acceptance: concurrent clients over one shared engine
//! are bitwise identical to serial fresh-session runs across the
//! worker-count × comm × memory grid, the admission probe never exceeds
//! the in-flight cap, the epoch-aware cache never serves stale results
//! across inserts/deletes/re-registrations, and the HTTP/JSON facade
//! round-trips `f32` data bitwise over a loopback socket.

mod common;

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};

use common::{bitwise_eq, blocked};
use relad::dist::{ClusterConfig, MemPolicy};
use relad::ra::{Chunk, Key, Relation};
use relad::serve::{CacheStatus, Engine, HttpServer, Json, ServeConfig, ServeError, ServeStats};
use relad::session::Session;
use relad::util::Prng;

// ------------------------------------------------- thread-safety audit

// Compile-time: the serving types must cross threads. A regression
// (e.g. an `Rc` slipping into the engine) fails this file at build.
fn assert_send<T: Send>() {}
fn assert_sync<T: Sync>() {}

#[test]
fn serving_types_are_send_and_sync() {
    assert_send::<Engine>();
    assert_sync::<Engine>();
    assert_send::<relad::serve::Client>();
    assert_sync::<relad::serve::Client>();
    assert_send::<relad::serve::QueryOutcome>();
    assert_sync::<relad::serve::QueryOutcome>();
    assert_send::<ServeStats>();
    assert_sync::<ServeStats>();
    assert_send::<ServeError>();
    assert_sync::<ServeError>();
    assert_send::<HttpServer>();
    assert_sync::<HttpServer>();
}

// ------------------------------------------------ concurrent bitwise grid

const MIX: [&str; 3] = [
    "SELECT R.a, SUM(mul(R.val, S.val)) FROM R, S WHERE R.a = S.a GROUP BY R.a",
    "SELECT R.a, R.b, relu(R.val) FROM R",
    "SELECT S.a, S.c, logistic(S.val) FROM S",
];

/// 4 concurrent clients replay an interleaved mix of [`MIX`]; every
/// result must be bitwise identical to a serial fresh `Session` under
/// the same cluster config, and the admission/pool probes must respect
/// the in-flight cap.
fn grid_case(workers: usize, comm: bool, spill: bool) {
    let mut rng = Prng::new(0x5EED + workers as u64);
    let r0 = blocked(4, 4, 8, &mut rng);
    let s0 = blocked(4, 3, 8, &mut rng);
    let cfg = || {
        let mut c = ClusterConfig::new(workers).with_parallel_comm(comm);
        if spill {
            c = c.with_budget(2048).with_policy(MemPolicy::Spill);
        }
        c
    };

    // Serial oracle: a fresh session, each statement collected once.
    let sess = Session::new(cfg());
    sess.register("R", &["a", "b"], &r0).unwrap();
    sess.register("S", &["a", "c"], &s0).unwrap();
    let want: Vec<Relation> = MIX
        .iter()
        .map(|q| sess.sql(q).unwrap().collect().unwrap())
        .collect();

    let cap = 2;
    let engine = Engine::with_config(
        cfg(),
        ServeConfig {
            max_inflight: cap,
            ..ServeConfig::default()
        },
    );
    let c0 = engine.client();
    c0.register("R", &["a", "b"], &r0).unwrap();
    c0.register("S", &["a", "c"], &s0).unwrap();

    std::thread::scope(|scope| {
        for t in 0..4usize {
            let client = engine.client();
            let want = &want;
            scope.spawn(move || {
                // Each client walks the mix from a different offset, so
                // the interleaving differs across clients and rounds.
                for rep in 0..3usize {
                    for qi in 0..MIX.len() {
                        let idx = (qi + t + rep) % MIX.len();
                        let out = client.query(MIX[idx]).unwrap();
                        assert!(
                            bitwise_eq(&out.result, &want[idx]),
                            "w={workers} comm={comm} spill={spill} client={t} \
                             stmt={idx}: served result diverged from serial oracle"
                        );
                    }
                }
            });
        }
    });

    let stats = engine.stats();
    assert!(
        stats.max_inflight_seen <= cap,
        "admission exceeded cap: {} > {cap}",
        stats.max_inflight_seen
    );
    assert!(
        stats.pool_rounds_high_water <= cap,
        "concurrent BSP rounds exceeded cap: {} > {cap}",
        stats.pool_rounds_high_water
    );
    // 36 queries over 3 statements: the cache must have served repeats.
    assert!(stats.cache_hits > 0, "no cache hits across repeated mix");
    assert_eq!(stats.cache_hits + stats.cache_misses, 36);
}

#[test]
fn concurrent_clients_bitwise_w1() {
    for comm in [true, false] {
        for spill in [false, true] {
            grid_case(1, comm, spill);
        }
    }
}

#[test]
fn concurrent_clients_bitwise_w2() {
    for comm in [true, false] {
        for spill in [false, true] {
            grid_case(2, comm, spill);
        }
    }
}

#[test]
fn concurrent_clients_bitwise_w8() {
    for comm in [true, false] {
        for spill in [false, true] {
            grid_case(8, comm, spill);
        }
    }
}

// ------------------------------------------------- cache invalidation

/// Fresh-session oracle over the current catalog contents.
fn oracle(workers: usize, rel: &Relation, key_cols: &[&str], q: &str) -> Relation {
    let sess = Session::new(ClusterConfig::new(workers));
    sess.register("R", key_cols, rel).unwrap();
    sess.sql(q).unwrap().collect().unwrap()
}

#[test]
fn cache_never_serves_stale_results() {
    let q = "SELECT R.a, SUM(relu(R.val)) FROM R GROUP BY R.a";
    for workers in [1usize, 2, 8] {
        let engine = Engine::new(ClusterConfig::new(workers));
        let client = engine.client();
        let mut rng = Prng::new(0xCACE + workers as u64);
        let r0 = blocked(6, 2, 4, &mut rng);
        client.register("R", &["a", "b"], &r0).unwrap();
        // `mirror` tracks what the catalog should hold after each step.
        let mut mirror = r0.clone();

        // Cold then hot: the repeat must be a hit with identical bits.
        let first = client.query(q).unwrap();
        assert_eq!(first.cache, CacheStatus::Miss);
        assert!(bitwise_eq(&first.result, &oracle(workers, &mirror, &["a", "b"], q)));
        let again = client.query(q).unwrap();
        assert_eq!(again.cache, CacheStatus::Hit);
        assert!(bitwise_eq(&again.result, &first.result));

        // Insert (epoch bump): the next query must re-execute and match
        // a fresh session over the merged catalog — a stale serve would
        // miss the new rows and fail the bitwise check.
        let batch: Vec<(Key, Chunk)> = (0..4)
            .map(|i| (Key::k2(i % 6, 100 + i), Chunk::filled(4, 4, 3.0)))
            .collect();
        client.insert("R", batch.clone()).unwrap();
        for (k, v) in batch {
            mirror.insert(k, v);
        }
        let after_insert = client.query(q).unwrap();
        assert_eq!(after_insert.cache, CacheStatus::Miss, "stale serve after insert");
        assert!(bitwise_eq(&after_insert.result, &oracle(workers, &mirror, &["a", "b"], q)));
        assert_eq!(client.query(q).unwrap().cache, CacheStatus::Hit);

        // Delete (epoch bump again).
        let dead = [Key::k2(0, 100), Key::k2(1, 101)];
        client.delete("R", &dead).unwrap();
        mirror = Relation::from_pairs(
            mirror
                .pairs()
                .iter()
                .filter(|(k, _)| !dead.contains(k))
                .cloned()
                .collect(),
        );
        let after_delete = client.query(q).unwrap();
        assert_eq!(after_delete.cache, CacheStatus::Miss, "stale serve after delete");
        assert!(bitwise_eq(&after_delete.result, &oracle(workers, &mirror, &["a", "b"], q)));

        // Drop + re-register with *swapped key columns* (new generation,
        // new schema): the cached plan must re-lower — replaying the old
        // plan would group by the wrong key component and diverge.
        client.drop_table("R").unwrap();
        let r1 = blocked(5, 3, 4, &mut rng);
        client.register("R", &["b", "a"], &r1).unwrap();
        let after_rereg = client.query(q).unwrap();
        assert_eq!(after_rereg.cache, CacheStatus::Miss, "stale serve after re-register");
        assert!(
            bitwise_eq(&after_rereg.result, &oracle(workers, &r1, &["b", "a"], q)),
            "w={workers}: stale plan replayed across re-registration"
        );
        assert_eq!(client.query(q).unwrap().cache, CacheStatus::Hit);
    }
}

// ------------------------------------------- multi-owner / drop resilience

#[test]
fn engine_survives_client_drop_and_typed_errors() {
    let mut rng = Prng::new(0xD07);
    let r0 = blocked(4, 2, 4, &mut rng);
    let engine = Engine::new(ClusterConfig::new(2));
    let keeper = engine.client();
    keeper.register("R", &["a", "b"], &r0).unwrap();
    let q = "SELECT R.a, R.b, relu(R.val) FROM R";
    let want = keeper.collect(q).unwrap();

    // A transient client queries from its own thread and drops there;
    // the pool and catalog must survive its exit mid-sequence.
    let transient = engine.client();
    std::thread::spawn(move || {
        for _ in 0..3 {
            let _ = transient.collect(q);
        }
        // `transient` drops here, on a foreign thread.
    })
    .join()
    .unwrap();

    // Typed errors on one handle never poison the engine: bad SQL and
    // an unknown table both fail typed, then real work proceeds.
    assert!(matches!(
        keeper.query("SELECT nonsense"),
        Err(ServeError::Session(_))
    ));
    assert!(matches!(
        keeper.query("SELECT Z.a, relu(Z.val) FROM Z"),
        Err(ServeError::Session(_))
    ));
    let got = keeper.collect(q).unwrap();
    assert!(bitwise_eq(&got, &want));
}

// ----------------------------------------------------- HTTP loopback

fn http_request(addr: &SocketAddr, method: &str, path: &str, body: &str) -> (u16, Json) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: loopback\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut resp = String::new();
    stream.read_to_string(&mut resp).unwrap();
    let status: u16 = resp
        .split_whitespace()
        .nth(1)
        .expect("status line")
        .parse()
        .expect("numeric status");
    let body_at = resp.find("\r\n\r\n").expect("header terminator") + 4;
    (status, Json::parse(&resp[body_at..]).expect("JSON body"))
}

/// `[{key, rows, cols, data}]` → `Relation` (mirrors the wire format).
fn relation_from_wire(data: &Json) -> Relation {
    let mut rel = Relation::new();
    for item in data.as_arr().unwrap() {
        let key: Vec<i64> = item
            .get("key")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|k| k.as_i64().unwrap())
            .collect();
        let rows = item.get("rows").unwrap().as_u64().unwrap() as usize;
        let cols = item.get("cols").unwrap().as_u64().unwrap() as usize;
        let chunk: Vec<f32> = item
            .get("data")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        rel.insert(Key::new(&key), Chunk::from_vec(rows, cols, chunk));
    }
    rel
}

#[test]
fn http_facade_round_trips_f32_bitwise() {
    let engine = Engine::new(ClusterConfig::new(2));
    let server = engine.serve_http("127.0.0.1:0").unwrap();
    let addr = server.addr();

    // Register two rows with awkward f32 payloads over the wire.
    let awkward = [0.1f32, -2.75, 3.5e-5, std::f32::consts::PI];
    let row = |a: i64, b: i64, scale: f32| {
        Json::Obj(vec![
            (
                "key".to_string(),
                Json::Arr(vec![Json::Num(a as f64), Json::Num(b as f64)]),
            ),
            ("rows".to_string(), Json::Num(2.0)),
            ("cols".to_string(), Json::Num(2.0)),
            (
                "data".to_string(),
                Json::Arr(awkward.iter().map(|&x| Json::Num((x * scale) as f64)).collect()),
            ),
        ])
    };
    let reg = Json::Obj(vec![
        ("name".to_string(), Json::Str("R".to_string())),
        (
            "key_cols".to_string(),
            Json::Arr(vec![Json::Str("a".to_string()), Json::Str("b".to_string())]),
        ),
        (
            "rows".to_string(),
            Json::Arr(vec![row(0, 0, 1.0), row(1, 0, -1.5)]),
        ),
    ]);
    let (status, resp) = http_request(&addr, "POST", "/register", &reg.render());
    assert_eq!(status, 200, "{resp:?}");
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));

    // /sql: first a miss, then a hit, visible in the summary.
    let q = "SELECT R.a, R.b, logistic(R.val) FROM R";
    let sql_body = Json::Obj(vec![("sql".to_string(), Json::Str(q.to_string()))]).render();
    let (status, resp) = http_request(&addr, "POST", "/sql", &sql_body);
    assert_eq!(status, 200, "{resp:?}");
    assert_eq!(resp.get("rows").unwrap().as_u64(), Some(2));
    assert_eq!(resp.get("cache").unwrap().as_str(), Some("miss"));
    let (_, resp) = http_request(&addr, "POST", "/sql", &sql_body);
    assert_eq!(resp.get("cache").unwrap().as_str(), Some("hit"));

    // /collect must hand back the same bits an in-process client sees.
    let want = engine.client().collect(q).unwrap();
    let (status, resp) = http_request(&addr, "POST", "/collect", &sql_body);
    assert_eq!(status, 200, "{resp:?}");
    let got = relation_from_wire(resp.get("data").unwrap());
    assert!(
        bitwise_eq(&got, &want),
        "HTTP collect diverged bitwise from the in-process client"
    );

    // /tables and /stats reflect the shared state.
    let (status, resp) = http_request(&addr, "GET", "/tables", "");
    assert_eq!(status, 200);
    let tables = resp.get("tables").unwrap().as_arr().unwrap();
    assert_eq!(tables.len(), 1);
    assert_eq!(tables[0].get("name").unwrap().as_str(), Some("R"));
    assert_eq!(tables[0].get("epoch").unwrap().as_u64(), Some(0));
    let (status, resp) = http_request(&addr, "GET", "/stats", "");
    assert_eq!(status, 200);
    assert!(resp.get("cache_hits").unwrap().as_u64().unwrap() >= 2);

    // Error mapping: bad SQL → 400 with an error body; no route → 404.
    let bad = Json::Obj(vec![(
        "sql".to_string(),
        Json::Str("SELECT utterly broken".to_string()),
    )])
    .render();
    let (status, resp) = http_request(&addr, "POST", "/sql", &bad);
    assert_eq!(status, 400);
    assert!(resp.get("error").is_some());
    let (status, _) = http_request(&addr, "GET", "/no-such-route", "");
    assert_eq!(status, 404);

    server.shutdown();
}
