//! Out-of-core correctness: executions that grace-spill through real
//! temp files must be **bitwise identical** to the same plans run fully
//! in memory — same float bits, same shard layouts, same `ShuffleStats`
//! — across worker counts, both communication paths, and budgets tight
//! enough to force one, two, and many grace passes. Also here: the
//! cleanup guarantees (no orphaned temp files after successful *or*
//! failed runs) and the measured spill counters' invariants.
//!
//! CI runs this suite as its dedicated low-memory smoke step with
//! `RELAD_SPILL_DIR` pointed at a job-scoped scratch directory.

mod common;

use std::fs;
use std::path::PathBuf;

use common::{bitwise_eq, blocked, sgd_apply};
use relad::data::graphs::power_law_graph;
use relad::dist::spill::file_count;
use relad::dist::{
    plan_join, ClusterConfig, ExecStats, JoinStrategy, MemPolicy, NetModel, PartitionedRelation,
};
use relad::kernels::{AggKernel, BinaryKernel};
use relad::ml::gcn::{self, GcnConfig};
use relad::ml::SlotLayout;
use relad::ra::{JoinPred, KeyProj, KeyProj2, QueryBuilder, Relation, Sel2};
use relad::session::{ModelSpec, Session, SessionError};
use relad::util::Prng;

/// Matmul whose inputs are partitioned *off* the join key so the planner
/// reshuffles both sides, followed by two cross-worker Σs — the
/// shuffle-heavy plan `tests/dist_parallel.rs` established; here the
/// reshuffled build sides are what goes to disk.
fn reshuffle_matmul_two_sigma_query() -> relad::ra::Query {
    let mut qb = QueryBuilder::new();
    let a = qb.scan(0, "A");
    let b = qb.scan(1, "B");
    let j = qb.join(
        JoinPred::on(vec![(1, 0)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
        BinaryKernel::MatMul,
        a,
        b,
    );
    let s1 = qb.agg(KeyProj::take(&[0, 2]), AggKernel::Sum, j);
    let s2 = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, s1);
    qb.finish(s2)
}

/// A fresh, test-unique directory to hand to `ClusterConfig::spill_dir`.
fn scratch_root(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("relad-spill-test-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&p);
    fs::create_dir_all(&p).unwrap();
    p
}

fn assert_spill_counters(st: &ExecStats, ctx: &str) {
    assert!(st.spill_passes >= 1, "{ctx}: budget failed to force spill");
    assert!(
        st.spill_bytes_written > 0,
        "{ctx}: spill must hit real temp files"
    );
    assert_eq!(
        st.spill_bytes_read, st.spill_bytes_written,
        "{ctx}: a completed run re-reads exactly what it wrote"
    );
}

/// The acceptance-criteria property: reshuffle-join + multi-Σ plans run
/// under budgets forcing 1 (ample: zero spill), ~2, and many grace
/// passes are bitwise identical to the unbudgeted run — per shard, with
/// identical `ShuffleStats` — at w∈{1,2,8} × parallel_comm∈{on,off}.
#[test]
fn spilled_reshuffle_join_multi_sigma_bitwise_identical() {
    let mut rng = Prng::new(0x0C0A);
    let a = blocked(6, 4, 4, &mut rng);
    let b = blocked(4, 6, 4, &mut rng);
    let q = reshuffle_matmul_two_sigma_query();
    // Bandwidth-only model: the planner provably picks the both-sides
    // reshuffle (premise asserted below), as in tests/dist_parallel.rs.
    let net = NetModel {
        bandwidth_bps: 1.25e9,
        latency_s: 0.0,
    };
    for w in [1usize, 2, 8] {
        let pa = PartitionedRelation::hash_partition(&a, &[0], w);
        let pb = PartitionedRelation::hash_partition(&b, &[1], w);
        if w > 1 {
            let plan = plan_join(&pa, &pb, &JoinPred::on(vec![(1, 0)]), &net, w);
            assert_eq!(
                plan.strategy,
                JoinStrategy::Reshuffle {
                    left: true,
                    right: true
                },
                "w={w}: premise broken — not a reshuffle join"
            );
        }
        // A floor on the spilling worker's join working set: its two
        // re-homed input shards (the working set adds the output on
        // top, so budget = this floor guarantees at least two passes on
        // the heaviest worker).
        let (ra, _) = pa.reshuffle(&[1], w);
        let (rb, _) = pb.reshuffle(&[0], w);
        let two_pass_budget = (0..w)
            .map(|i| ra.shards[i].nbytes() as u64 + rb.shards[i].nbytes() as u64)
            .max()
            .unwrap();
        assert!(two_pass_budget > 0);
        for comm in [true, false] {
            let mk = |budget: Option<u64>| {
                let mut cfg = ClusterConfig::new(w).with_net(net).with_parallel_comm(comm);
                if let Some(bb) = budget {
                    cfg = cfg.with_budget(bb);
                }
                let sess = Session::new(cfg);
                sess.register_partitioned("A", &["r", "c"], pa.clone()).unwrap();
                sess.register_partitioned("B", &["r", "c"], pb.clone()).unwrap();
                sess
            };
            // In-memory baseline: no budget at all.
            let base = mk(None);
            let (bp, bst) = base.query(&q).unwrap().collect_partitioned().unwrap();
            let want = bp.gather();
            assert_eq!(base.stats().spill_passes, 0);
            assert_eq!(base.stats().spill_bytes_written, 0);

            let mut prev_passes = 0u64;
            // Derive the tight budget from the two-pass one so the
            // monotone pass-count assertion below cannot be broken by a
            // shape/Key-size change flipping their order.
            let many_pass_budget = (two_pass_budget / 2).max(1);
            for (budget, label) in [
                (u64::MAX / 4, "ample"),
                (two_pass_budget, "two-pass"),
                (many_pass_budget, "many-pass"),
            ] {
                let sess = mk(Some(budget));
                let frame = sess.query(&q).unwrap();
                let (gp, st) = frame.collect_partitioned().unwrap();
                let ctx = format!("w={w} comm={comm} {label}");
                assert!(
                    bitwise_eq(&gp.gather(), &want),
                    "{ctx}: spilled result diverged from in-memory"
                );
                for (x, y) in gp.shards.iter().zip(bp.shards.iter()) {
                    assert!(
                        bitwise_eq(x.as_ref(), y.as_ref()),
                        "{ctx}: shard layout diverged"
                    );
                }
                // Same plan, same exchanges: spill never changes traffic.
                assert_eq!(st.bytes_shuffled, bst.bytes_shuffled, "{ctx}");
                assert_eq!(st.msgs, bst.msgs, "{ctx}");
                assert_eq!(st.stages, bst.stages, "{ctx}");
                if label == "ample" {
                    assert_eq!(st.spill_passes, 0, "{ctx}: spurious spill");
                    assert_eq!(st.spill_bytes_written, 0, "{ctx}");
                    assert_eq!(st.spill_bytes_read, 0, "{ctx}");
                } else {
                    assert_spill_counters(&st, &ctx);
                    assert!(
                        st.spill_passes >= prev_passes,
                        "{ctx}: tighter budget produced fewer passes"
                    );
                    prev_passes = st.spill_passes;
                }
            }
            assert!(prev_passes >= 2, "w={w} comm={comm}: never multi-passed");
        }
    }
}

fn gcn_session(cfg: ClusterConfig, g: &relad::data::GraphDataset) -> Session {
    let sess = Session::new(cfg);
    sess.register_with_layout("Edge", &["dst", "src"], &g.edges, &SlotLayout::HashOn(vec![0]))
        .unwrap();
    sess.register("Node", &["id"], &g.feats).unwrap();
    sess.register("Y", &["id"], &g.labels).unwrap();
    sess
}

/// A 3-step GCN training loop (taped forward + generated backward, SGD
/// applied between steps) under spill budgets reproduces the in-memory
/// loop's losses and final parameters to the bit, at every worker count
/// and on both communication paths.
#[test]
fn spilled_training_loop_bitwise_identical() {
    let g = power_law_graph("spill", 40, 120, 8, 4, 0.5, 31);
    let cfg = GcnConfig {
        feat_dim: 8,
        hidden: 8,
        n_labels: 4,
        dropout: None,
        seed: 5,
    };
    let q = gcn::loss_query(&cfg, g.labels.len());
    for w in [1usize, 2, 8] {
        for comm in [true, false] {
            let run = |budget: Option<u64>| -> (Vec<u32>, Relation, Relation, ExecStats) {
                let mut ccfg = ClusterConfig::new(w).with_parallel_comm(comm);
                if let Some(bb) = budget {
                    ccfg = ccfg.with_budget(bb);
                }
                let sess = gcn_session(ccfg, &g);
                let mut trainer = sess
                    .trainer(ModelSpec::new(q.clone()).param("W1", 1).param("W2", 1))
                    .unwrap();
                let mut rng = Prng::new(77);
                let (mut w1, mut w2) = gcn::init_params(&cfg, &mut rng);
                let mut losses = Vec::new();
                for _ in 0..3 {
                    let res = trainer.step(&[("W1", &w1), ("W2", &w2)]).unwrap();
                    losses.push(res.loss.to_bits());
                    for (name, grel) in &res.grads {
                        let target = if name == "W1" { &mut w1 } else { &mut w2 };
                        sgd_apply(target, grel, 0.1);
                    }
                }
                let stats = sess.stats();
                (losses, w1, w2, stats)
            };
            let (l_mem, m1, m2, s_mem) = run(None);
            assert_eq!(s_mem.spill_passes, 0);
            assert_eq!(s_mem.spill_bytes_written, 0);
            // A tight budget (forces spill in forward and backward joins)
            // and a tighter one (more passes): bit-identical loops.
            let (l_sp, a1, a2, s_sp) = run(Some(2048));
            assert_spill_counters(&s_sp, &format!("w={w} comm={comm} budget=2048"));
            assert_eq!(l_mem, l_sp, "w={w} comm={comm}: loss curves diverged");
            assert!(bitwise_eq(&m1, &a1), "w={w} comm={comm}: W1 diverged");
            assert!(bitwise_eq(&m2, &a2), "w={w} comm={comm}: W2 diverged");
            let (l_sp2, b1, b2, s_sp2) = run(Some(512));
            assert!(
                s_sp2.spill_passes >= s_sp.spill_passes,
                "w={w} comm={comm}: tighter budget produced fewer passes"
            );
            assert_eq!(l_mem, l_sp2, "w={w} comm={comm}: loss curves diverged (512)");
            assert!(bitwise_eq(&m1, &b1), "w={w} comm={comm}: W1 diverged (512)");
            assert!(bitwise_eq(&m2, &b2), "w={w} comm={comm}: W2 diverged (512)");
        }
    }
}

/// Scratch hygiene: a successful spilled run leaves zero files behind;
/// a *failed* stage (typed error out of a grace pass) leaves zero files
/// behind; dropping the session removes the whole scratch tree from the
/// configured `spill_dir`.
#[test]
fn spill_scratch_cleanup_on_success_failure_and_drop() {
    let mut rng = Prng::new(0xC1EA);
    let a = blocked(6, 4, 4, &mut rng);
    let b = blocked(4, 6, 4, &mut rng);
    let root = scratch_root("cleanup");

    // Pool-less (serial) session: scratch is per-evaluation and must be
    // fully gone — files *and* directories — right after the call.
    {
        let cfg = ClusterConfig::new(2)
            .with_parallel(false)
            .with_budget(1500)
            .with_spill_dir(&root);
        let sess = Session::new(cfg);
        sess.register("A", &["r", "c"], &a).unwrap();
        sess.register("B", &["r", "c"], &b).unwrap();
        let q = reshuffle_matmul_two_sigma_query();
        sess.query(&q).unwrap().collect().unwrap();
        assert!(sess.stats().spill_bytes_written > 0, "premise: must spill");
        assert_eq!(file_count(&root), 0, "successful run orphaned files");
        assert!(
            fs::read_dir(&root).unwrap().next().is_none(),
            "per-evaluation scratch directories must not outlive the run"
        );
    }

    // Failed stage: a non-injective ⋈ projection errors *during* the
    // grace passes (runs already written) — typed error, no orphans.
    {
        let cfg = ClusterConfig::new(2).with_budget(1500).with_spill_dir(&root);
        let sess = Session::new(cfg);
        sess.register("A", &["r", "c"], &a).unwrap();
        sess.register("B", &["r", "c"], &b).unwrap();
        let bad = {
            let mut qb = QueryBuilder::new();
            let sa = qb.scan(0, "A");
            let sb = qb.scan(1, "B");
            // Output key = B's row = the join key: collides for sure.
            let j = qb.join(
                JoinPred::on(vec![(1, 0)]),
                KeyProj2(vec![Sel2::R(0)]),
                BinaryKernel::MatMul,
                sa,
                sb,
            );
            qb.finish(j)
        };
        match sess.query(&bad).unwrap().collect() {
            Err(SessionError::Exec(_)) => {}
            other => panic!(
                "expected a typed execution error, got {:?}",
                other.map(|r| r.len())
            ),
        }
        assert_eq!(file_count(&root), 0, "failed stage orphaned spill files");
        drop(sess);
        assert!(
            fs::read_dir(&root).unwrap().next().is_none(),
            "session drop must remove its scratch tree"
        );
    }

    // Spill really is budget-driven: the same session shape with an
    // ample budget never touches the scratch device.
    {
        let cfg = ClusterConfig::new(2)
            .with_budget(u64::MAX / 4)
            .with_spill_dir(&root);
        let sess = Session::new(cfg);
        sess.register("A", &["r", "c"], &a).unwrap();
        sess.register("B", &["r", "c"], &b).unwrap();
        let q = reshuffle_matmul_two_sigma_query();
        sess.query(&q).unwrap().collect().unwrap();
        let st = sess.stats();
        assert_eq!(st.spill_passes, 0);
        assert_eq!(st.spill_bytes_written, 0);
        assert_eq!(file_count(&root), 0);
    }
    let _ = fs::remove_dir_all(&root);
}

/// The paper's headline asymmetry, end to end through the session: on
/// the same registered tables and the same budget, `MemPolicy::Fail`
/// returns a typed OOM while `MemPolicy::Spill` completes out-of-core
/// with the identical (bitwise) result the unbudgeted run produces.
#[test]
fn spill_succeeds_where_fail_ooms_same_tables() {
    let mut rng = Prng::new(0xA5F1);
    let a = blocked(5, 3, 8, &mut rng);
    let b = blocked(3, 5, 8, &mut rng);
    let q = reshuffle_matmul_two_sigma_query();
    let register = |cfg: ClusterConfig| -> Session {
        let sess = Session::new(cfg);
        sess.register("A", &["r", "c"], &a).unwrap();
        sess.register("B", &["r", "c"], &b).unwrap();
        sess
    };
    let want = register(ClusterConfig::new(3))
        .query(&q)
        .unwrap()
        .collect()
        .unwrap();
    let budget = 2048u64;
    let fail = register(
        ClusterConfig::new(3)
            .with_budget(budget)
            .with_policy(MemPolicy::Fail),
    );
    match fail.query(&q).unwrap().collect() {
        Err(SessionError::Exec(relad::dist::DistError::Oom { needed, budget: bb, .. })) => {
            assert!(needed > bb);
        }
        other => panic!("expected typed OOM, got {:?}", other.map(|r| r.len())),
    }
    let spill = register(ClusterConfig::new(3).with_budget(budget));
    let got = spill.query(&q).unwrap().collect().unwrap();
    assert!(bitwise_eq(&got, &want), "spilled ≠ in-memory");
    assert_spill_counters(&spill.stats(), "spill-vs-fail");
}
