//! Cross-module integration: SQL → RA → autodiff → distributed execution,
//! spill correctness, and training-loop parity.

use relad::autodiff::{grad, grad_wrt};
use relad::data::graphs::power_law_graph;
use relad::dist::{ClusterConfig, DistError, MemPolicy};
use relad::kernels::NativeBackend;
use relad::ml::gcn::{self, GcnConfig};
use relad::ml::{Adam, SlotLayout};
use relad::ra::eval::eval_query;
use relad::ra::{Chunk, Key, Relation};
use relad::session::{ModelSpec, Session, SessionError};
use relad::sql::{parse_query, Catalog};
use relad::util::Prng;

/// SQL-authored query executed distributed matches single-node, across
/// cluster sizes and under a spill-inducing budget.
#[test]
fn sql_query_distributed_and_spilled() {
    let catalog = Catalog::default()
        .table("A", 0, &["row", "col"])
        .table("B", 1, &["row", "col"]);
    let q = parse_query(
        "SELECT A.row, B.col, SUM(matmul(A.val, B.val)) \
         FROM A, B WHERE A.col = B.row GROUP BY A.row, B.col",
        &catalog,
    )
    .unwrap();
    let mut rng = Prng::new(201);
    let mut a = Relation::new();
    let mut b = Relation::new();
    for i in 0..4i64 {
        for k in 0..4i64 {
            a.insert(Key::k2(i, k), Chunk::random(8, 8, &mut rng, 1.0));
            b.insert(Key::k2(k, i), Chunk::random(8, 8, &mut rng, 1.0));
        }
    }
    let want = eval_query(&q, &[&a, &b], &NativeBackend).unwrap();
    for w in [1, 3, 8] {
        // Tight budget: force the spill path; results must be identical.
        let cfg = ClusterConfig::new(w)
            .with_budget(2048)
            .with_policy(MemPolicy::Spill);
        let sess = Session::new(cfg);
        sess.register("A", &["row", "col"], &a).unwrap();
        sess.register("B", &["row", "col"], &b).unwrap();
        let (part, stats) = sess.query(&q).unwrap().collect_partitioned().unwrap();
        assert!(part.gather().approx_eq(&want, 1e-4), "w={w}");
        assert!(stats.spill_passes > 0, "expected spilling at w={w}");
    }
}

/// The same tight budget under MemPolicy::Fail OOMs — the baseline-vs-RA
/// asymmetry the evaluation tables rely on.
#[test]
fn fail_policy_vs_spill_policy_asymmetry() {
    let catalog = Catalog::default()
        .table("A", 0, &["row", "col"])
        .table("B", 1, &["row", "col"]);
    let q = parse_query(
        "SELECT A.row, B.col, SUM(matmul(A.val, B.val)) \
         FROM A, B WHERE A.col = B.row GROUP BY A.row, B.col",
        &catalog,
    )
    .unwrap();
    let mut rng = Prng::new(202);
    let mut a = Relation::new();
    let mut b = Relation::new();
    for i in 0..3i64 {
        a.insert(Key::k2(i, 0), Chunk::random(16, 16, &mut rng, 1.0));
        b.insert(Key::k2(0, i), Chunk::random(16, 16, &mut rng, 1.0));
    }
    let fail = ClusterConfig::new(2)
        .with_budget(1024)
        .with_policy(MemPolicy::Fail);
    let sess = Session::new(fail);
    sess.register("A", &["row", "col"], &a).unwrap();
    sess.register("B", &["row", "col"], &b).unwrap();
    assert!(matches!(
        sess.query(&q).unwrap().collect(),
        Err(SessionError::Exec(DistError::Oom { .. }))
    ));
    let spill = ClusterConfig::new(2)
        .with_budget(1024)
        .with_policy(MemPolicy::Spill);
    let sess = Session::new(spill);
    sess.register("A", &["row", "col"], &a).unwrap();
    sess.register("B", &["row", "col"], &b).unwrap();
    assert!(sess.query(&q).unwrap().collect().is_ok());
}

/// Full training loop through the distributed trainer matches eager
/// single-node training loss step for step, and learns.
#[test]
fn distributed_gcn_training_matches_single_node_loss_trajectory() {
    let g = power_law_graph("it", 80, 240, 8, 4, 0.5, 203);
    let cfg = GcnConfig {
        feat_dim: 8,
        hidden: 8,
        n_labels: 4,
        dropout: None,
        seed: 3,
    };
    let q = gcn::loss_query(&cfg, g.labels.len());
    let mut rng = Prng::new(204);
    let (w1_0, w2_0) = gcn::init_params(&cfg, &mut rng);

    // single-node eager trajectory
    let mut w1 = w1_0.clone();
    let mut w2 = w2_0.clone();
    let mut adam = Adam::new(0.05);
    let mut sn_losses = Vec::new();
    for _ in 0..5 {
        let inputs = [&w1, &w2, &g.edges, &g.feats, &g.labels];
        let (tape, grads) =
            grad_wrt(&q, &inputs, &[gcn::SLOT_W1, gcn::SLOT_W2], &NativeBackend).unwrap();
        sn_losses.push(tape.output(&q).get(&Key::empty()).unwrap().as_scalar());
        adam.step(&mut w1, grads.slot(gcn::SLOT_W1));
        adam.step(&mut w2, grads.slot(gcn::SLOT_W2));
    }

    // distributed graph-mode trajectory, session-driven
    let sess = Session::new(ClusterConfig::new(4));
    sess.register_with_layout("Edge", &["dst", "src"], &g.edges, &SlotLayout::HashOn(vec![0]))
        .unwrap();
    sess.register("Node", &["id"], &g.feats).unwrap();
    sess.register("Y", &["id"], &g.labels).unwrap();
    let mut trainer = sess
        .trainer(ModelSpec::new(q.clone()).param("W1", 1).param("W2", 1))
        .unwrap();
    let mut w1 = w1_0;
    let mut w2 = w2_0;
    let mut adam = Adam::new(0.05);
    for (step, want) in sn_losses.iter().enumerate() {
        let res = trainer.step(&[("W1", &w1), ("W2", &w2)]).unwrap();
        assert!(
            (res.loss - want).abs() < 1e-3,
            "step {step}: dist {} vs single-node {want}",
            res.loss
        );
        for (name, grel) in &res.grads {
            match name.as_str() {
                "W1" => adam.step(&mut w1, grel),
                "W2" => adam.step(&mut w2, grel),
                _ => {}
            }
        }
    }
    assert!(sn_losses[4] < sn_losses[0], "no learning: {sn_losses:?}");
}

/// Logistic regression (the §2.3 pipeline) trains to convergence.
#[test]
fn logreg_trains_to_low_loss() {
    use relad::ml::logreg;
    use relad::ml::Sgd;
    use std::sync::Arc;
    let d = logreg::synthetic(128, 16, 16, 205);
    let q = logreg::loss_query(Arc::new(d.x.clone()), Arc::new(d.y.clone()), d.n_rows);
    let mut theta = d.theta0.clone();
    let sgd = Sgd::new(2.0);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..25 {
        let (tape, grads) = grad(&q, &[&theta], &NativeBackend).unwrap();
        let loss = tape.output(&q).get(&Key::empty()).unwrap().as_scalar();
        first.get_or_insert(loss);
        last = loss;
        sgd.step(&mut theta, grads.slot(0));
    }
    assert!(last < first.unwrap() * 0.6, "{first:?} -> {last}");
}
