//! Parallel-determinism properties of the pooled BSP executor, driven
//! through the `Session` front door.
//!
//! The pooled path (`ClusterConfig::parallel = true`, the default) must
//! be **bitwise** interchangeable with the serial reference path at
//! every worker count — and the pooled *communication* path
//! (`parallel_comm = true`) with the driver-serial one: threads change
//! *when* a shard runs or a bucket is built, never what it computes or
//! the order results are merged in. Across worker counts, queries
//! without a cross-worker Σ are bitwise partition-invariant too
//! (per-tuple kernels see identical operands); queries with a
//! cross-worker Σ are invariant up to float reassociation in the merge,
//! as the `dist` module documents.
//!
//! Also here: pool-lifecycle coverage — a session mints exactly one
//! backend per worker at construction (`for_worker`), and however many
//! queries and training steps it then runs, it never mints again.

mod common;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use common::{bitwise_eq, blocked, sgd_apply, CountingBackend};
use relad::data::graphs::power_law_graph;
use relad::dist::{plan_join, ClusterConfig, JoinStrategy, NetModel, PartitionedRelation};
use relad::kernels::{AggKernel, BinaryKernel, UnaryKernel};
use relad::ml::gcn::{self, GcnConfig};
use relad::ml::SlotLayout;
use relad::ra::{JoinPred, KeyPred, KeyProj, KeyProj2, QueryBuilder, Relation, Sel2};
use relad::session::{ModelSpec, Session};
use relad::util::Prng;

/// A session with tables `X`/`Y` (or any names) registered from
/// already-partitioned relations — the layout-controlled entry the
/// determinism tests need.
fn session_with(
    cfg: ClusterConfig,
    tables: &[(&str, PartitionedRelation)],
) -> Session {
    let sess = Session::new(cfg);
    for (name, part) in tables {
        sess.register_partitioned(name, &["a", "b"], part.clone())
            .unwrap();
    }
    sess
}

/// σ ∘ ⋈ query with an injective projection and no Σ: every output tuple
/// is computed by one worker from identical operands under any layout.
fn select_join_query() -> relad::ra::Query {
    let mut qb = QueryBuilder::new();
    let sx = qb.scan(0, "X");
    let sy = qb.scan(1, "Y");
    let t = qb.select(KeyPred::always(), KeyProj::take(&[0, 1]), UnaryKernel::Tanh, sx);
    let j = qb.join(
        JoinPred::on(vec![(0, 0), (1, 1)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1)]),
        BinaryKernel::Mul,
        t,
        sy,
    );
    qb.finish(j)
}

#[test]
fn threaded_equals_serial_bitwise_per_worker_count() {
    // Matmul (join + Σ) — the Σ merge order is fixed per worker count,
    // so threaded vs serial at the same w must agree to the bit.
    let mut rng = Prng::new(0xDE7);
    let a = blocked(4, 3, 8, &mut rng);
    let b = blocked(3, 4, 8, &mut rng);
    let q = relad::ra::expr::matmul_query();
    for w in [1usize, 2, 3, 8] {
        let pa = PartitionedRelation::hash_full(&a, w);
        let pb = PartitionedRelation::hash_full(&b, w);
        let tables = [("A", pa), ("B", pb)];
        let threaded = session_with(ClusterConfig::new(w), &tables);
        let serial = session_with(ClusterConfig::new(w).with_parallel(false), &tables);
        let (gt, st) = threaded.query(&q).unwrap().collect_partitioned().unwrap();
        let (gs, ss) = serial.query(&q).unwrap().collect_partitioned().unwrap();
        assert!(
            bitwise_eq(&gt.gather(), &gs.gather()),
            "w={w}: threaded and serial runs diverged"
        );
        // Same modeled counters either way — threads change wall clock
        // only.
        assert_eq!(st.bytes_shuffled, ss.bytes_shuffled, "w={w}");
        assert_eq!(st.msgs, ss.msgs, "w={w}");
        assert_eq!(st.stages, ss.stages, "w={w}");
        // And a second threaded run through the same session is bitwise
        // stable.
        let (gt2, _) = threaded.query(&q).unwrap().collect_partitioned().unwrap();
        assert!(bitwise_eq(&gt.gather(), &gt2.gather()), "w={w}: rerun diverged");
    }
}

#[test]
fn no_agg_query_bitwise_invariant_across_worker_counts() {
    let mut rng = Prng::new(0xACE);
    let x = blocked(6, 5, 4, &mut rng);
    let y = blocked(6, 5, 4, &mut rng);
    let q = select_join_query();
    let want = {
        let tables = [
            ("X", PartitionedRelation::hash_full(&x, 1)),
            ("Y", PartitionedRelation::hash_full(&y, 1)),
        ];
        session_with(ClusterConfig::new(1), &tables)
            .query(&q)
            .unwrap()
            .collect()
            .unwrap()
    };
    assert_eq!(want.len(), x.len());
    for w in [2usize, 3, 8] {
        let tables = [
            ("X", PartitionedRelation::hash_full(&x, w)),
            ("Y", PartitionedRelation::hash_full(&y, w)),
        ];
        let got = session_with(ClusterConfig::new(w), &tables)
            .query(&q)
            .unwrap()
            .collect()
            .unwrap();
        assert!(
            bitwise_eq(&got, &want),
            "w={w}: σ∘⋈ output must be bitwise equal to the single-worker result"
        );
    }
}

/// Matmul whose inputs are deliberately partitioned *off* the join key
/// (A by row, B by column): `plan_join` must pick
/// `Reshuffle{left, right}`, so the stage exercises the parallel
/// all-to-all on both sides, then the Σ exchange, then a second
/// cross-worker Σ (the first Σ's hash on ⟨0,1⟩ does not determine the
/// final grouping on ⟨0⟩ alone) — a shuffle-heavy multi-Σ plan.
fn reshuffle_matmul_two_sigma_query() -> relad::ra::Query {
    let mut qb = QueryBuilder::new();
    let a = qb.scan(0, "A");
    let b = qb.scan(1, "B");
    let j = qb.join(
        JoinPred::on(vec![(1, 0)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
        BinaryKernel::MatMul,
        a,
        b,
    );
    let s1 = qb.agg(KeyProj::take(&[0, 2]), AggKernel::Sum, j);
    let s2 = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, s1);
    qb.finish(s2)
}

#[test]
fn pooled_shuffle_bitwise_on_reshuffle_join_and_multi_sigma() {
    let mut rng = Prng::new(0xF00D);
    let a = blocked(6, 4, 4, &mut rng);
    let b = blocked(4, 6, 4, &mut rng);
    let q = reshuffle_matmul_two_sigma_query();
    // Zero per-message latency: on test-sized relations the default
    // model's latency term would tip the planner to broadcast; with
    // bandwidth only, re-homing both sides (2·(w-1)/w² per byte) is
    // never costlier than allgathering one (·(w-1)/w), so the plan is
    // the reshuffle join this test is about.
    let net = NetModel {
        bandwidth_bps: 1.25e9,
        latency_s: 0.0,
    };
    for w in [1usize, 2, 3, 8] {
        // Partition both sides off the join key A[1]=B[0] so the planner
        // must reshuffle both.
        let pa = PartitionedRelation::hash_partition(&a, &[0], w);
        let pb = PartitionedRelation::hash_partition(&b, &[1], w);
        if w > 1 {
            let plan = plan_join(&pa, &pb, &JoinPred::on(vec![(1, 0)]), &net, w);
            assert_eq!(
                plan.strategy,
                JoinStrategy::Reshuffle { left: true, right: true },
                "w={w}: test premise broken — planner did not pick a reshuffle join"
            );
        }
        let tables = [("A", pa), ("B", pb)];
        let pooled = session_with(ClusterConfig::new(w).with_net(net), &tables);
        let driver_comm = session_with(
            ClusterConfig::new(w).with_net(net).with_parallel_comm(false),
            &tables,
        );
        let serial = session_with(
            ClusterConfig::new(w).with_net(net).with_parallel(false),
            &tables,
        );
        let (gp, sp) = pooled.query(&q).unwrap().collect_partitioned().unwrap();
        let (gd, sd) = driver_comm.query(&q).unwrap().collect_partitioned().unwrap();
        let (gs, ss) = serial.query(&q).unwrap().collect_partitioned().unwrap();
        assert!(
            bitwise_eq(&gp.gather(), &gs.gather()),
            "w={w}: pooled shuffle/gather diverged from serial"
        );
        assert!(
            bitwise_eq(&gp.gather(), &gd.gather()),
            "w={w}: pooled comm diverged from driver-serial comm"
        );
        // Identical modeled traffic on all three paths.
        assert_eq!(sp.bytes_shuffled, ss.bytes_shuffled, "w={w}");
        assert_eq!(sp.bytes_shuffled, sd.bytes_shuffled, "w={w}");
        assert_eq!(sp.msgs, ss.msgs, "w={w}");
        assert_eq!(sp.stages, ss.stages, "w={w}");
        if w > 1 {
            assert!(sp.bytes_shuffled > 0, "w={w}: plan was not shuffle-heavy");
        }
        // Per-shard layouts agree too (not just the gathered union).
        for (x, y) in gp.shards.iter().zip(gs.shards.iter()) {
            assert!(bitwise_eq(x.as_ref(), y.as_ref()), "w={w}: shard layout diverged");
        }
        // The traced explain agrees with the premise: the ⋈ stage ran as
        // a both-sides reshuffle.
        if w > 1 {
            let (trace, _) = pooled.query(&q).unwrap().trace().unwrap();
            let join = trace.iter().find(|t| t.op == "⋈").unwrap();
            assert_eq!(
                join.strategy,
                Some(JoinStrategy::Reshuffle { left: true, right: true }),
                "w={w}"
            );
        }
    }
}

fn gcn_session(cfg: ClusterConfig, g: &relad::data::GraphDataset) -> Session {
    let sess = Session::new(cfg);
    sess.register_with_layout("Edge", &["dst", "src"], &g.edges, &SlotLayout::HashOn(vec![0]))
        .unwrap();
    sess.register("Node", &["id"], &g.feats).unwrap();
    sess.register("Y", &["id"], &g.labels).unwrap();
    sess
}

#[test]
fn trainer_loop_threaded_equals_serial() {
    // Seeded multi-step training (taped forward + generated backward):
    // the threaded session must reproduce the serial session's losses,
    // gradients and final parameters to the bit, at every worker count.
    let g = power_law_graph("det", 40, 120, 8, 4, 0.5, 31);
    let cfg = GcnConfig {
        feat_dim: 8,
        hidden: 8,
        n_labels: 4,
        dropout: None,
        seed: 5,
    };
    let q = gcn::loss_query(&cfg, g.labels.len());
    for w in [1usize, 2, 3, 8] {
        let mut run = |parallel: bool, parallel_comm: bool| -> (Vec<u32>, Relation, Relation) {
            let ccfg = ClusterConfig::new(w)
                .with_parallel(parallel)
                .with_parallel_comm(parallel_comm);
            let sess = gcn_session(ccfg, &g);
            let mut trainer = sess
                .trainer(ModelSpec::new(q.clone()).param("W1", 1).param("W2", 1))
                .unwrap();
            let mut rng = Prng::new(77);
            let (mut w1, mut w2) = gcn::init_params(&cfg, &mut rng);
            let mut losses = Vec::new();
            for _ in 0..3 {
                let res = trainer.step(&[("W1", &w1), ("W2", &w2)]).unwrap();
                losses.push(res.loss.to_bits());
                for (name, grel) in &res.grads {
                    let target = if name == "W1" { &mut w1 } else { &mut w2 };
                    sgd_apply(target, grel, 0.1);
                }
            }
            (losses, w1, w2)
        };
        let (lt, wt1, wt2) = run(true, true);
        let (ld, wd1, wd2) = run(true, false);
        let (ls, ws1, ws2) = run(false, true);
        assert_eq!(lt, ls, "w={w}: pooled and serial loss curves diverged");
        assert_eq!(lt, ld, "w={w}: pooled and driver-comm loss curves diverged");
        assert!(bitwise_eq(&wt1, &ws1), "w={w}: W1 diverged");
        assert!(bitwise_eq(&wt2, &ws2), "w={w}: W2 diverged");
        assert!(bitwise_eq(&wt1, &wd1), "w={w}: W1 diverged (driver comm)");
        assert!(bitwise_eq(&wt2, &wd2), "w={w}: W2 diverged (driver comm)");
    }
}

#[test]
fn session_mints_one_backend_per_worker_for_its_whole_lifetime() {
    let g = power_law_graph("pool", 30, 90, 8, 4, 0.5, 13);
    let cfg = GcnConfig {
        feat_dim: 8,
        hidden: 8,
        n_labels: 4,
        dropout: None,
        seed: 5,
    };
    let q = gcn::loss_query(&cfg, g.labels.len());
    let w = 2;
    let ccfg = ClusterConfig::new(w);
    // On a single-core host the pool never engages and mints nothing;
    // the expectation adapts so the assertion stays exact everywhere.
    let expect = if relad::dist::WorkerPool::engages(&ccfg) {
        w
    } else {
        0
    };
    let minted = Arc::new(AtomicUsize::new(0));
    let mut rng = Prng::new(21);
    let (w1, w2) = gcn::init_params(&cfg, &mut rng);

    // Construction mints once per worker…
    let sess = Session::with_backend(
        ccfg,
        Box::new(CountingBackend {
            minted: Arc::clone(&minted),
        }),
    );
    assert_eq!(
        minted.load(Ordering::SeqCst),
        expect,
        "session construction mints exactly one backend per worker"
    );
    sess.register_with_layout("Edge", &["dst", "src"], &g.edges, &SlotLayout::HashOn(vec![0]))
        .unwrap();
    sess.register("Node", &["id"], &g.feats).unwrap();
    sess.register("Y", &["id"], &g.labels).unwrap();

    // …and a 3-step training loop (forward + backward + gathers per
    // step) plus ad-hoc queries mint nothing further.
    let mut trainer = sess
        .trainer(ModelSpec::new(q.clone()).param("W1", 1).param("W2", 1))
        .unwrap();
    for _ in 0..3 {
        trainer.step(&[("W1", &w1), ("W2", &w2)]).unwrap();
    }
    assert_eq!(
        minted.load(Ordering::SeqCst),
        expect,
        "steps must reuse the session pool, never re-mint"
    );

    // A serial session mints nothing at all.
    let minted_serial = Arc::new(AtomicUsize::new(0));
    let serial = Session::with_backend(
        ClusterConfig::new(w).with_parallel(false),
        Box::new(CountingBackend {
            minted: Arc::clone(&minted_serial),
        }),
    );
    drop(serial);
    assert_eq!(minted_serial.load(Ordering::SeqCst), 0, "serial session must not mint");
}
