//! Parallel-determinism properties of the pooled BSP executor.
//!
//! The pooled path (`ClusterConfig::parallel = true`, the default) must
//! be **bitwise** interchangeable with the serial reference path at
//! every worker count — and the pooled *communication* path
//! (`parallel_comm = true`) with the driver-serial one: threads change
//! *when* a shard runs or a bucket is built, never what it computes or
//! the order results are merged in. Across worker counts, queries
//! without a cross-worker Σ are bitwise partition-invariant too
//! (per-tuple kernels see identical operands); queries with a
//! cross-worker Σ are invariant up to float reassociation in the merge,
//! as the `dist` module documents.
//!
//! Also here: pool-reuse coverage — `for_worker` must run exactly once
//! per worker per trainer run (not per stage or per evaluation), and a
//! multi-step `TrainPipeline` loop must reuse one pool throughout.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use relad::data::graphs::power_law_graph;
use relad::dist::{
    dist_eval, plan_join, ClusterConfig, JoinStrategy, NetModel, PartitionedRelation, WorkerPool,
};
use relad::kernels::{AggKernel, BinaryKernel, KernelBackend, NativeBackend, UnaryKernel};
use relad::ml::gcn::{self, GcnConfig};
use relad::ml::{DistTrainer, SlotLayout};
use relad::ra::{
    Chunk, JoinPred, Key, KeyPred, KeyProj, KeyProj2, QueryBuilder, Relation, Sel2,
};
use relad::util::Prng;

/// Bitwise equality: same key set, every chunk elementwise bit-identical.
fn bitwise_eq(a: &Relation, b: &Relation) -> bool {
    if a.len() != b.len() {
        return false;
    }
    a.iter().all(|(k, v)| match b.get(k) {
        Some(w) => {
            v.shape() == w.shape()
                && v.data()
                    .iter()
                    .zip(w.data().iter())
                    .all(|(x, y)| x.to_bits() == y.to_bits())
        }
        None => false,
    })
}

fn blocked(n: i64, m: i64, c: usize, rng: &mut Prng) -> Relation {
    let mut r = Relation::new();
    for i in 0..n {
        for j in 0..m {
            r.insert(Key::k2(i, j), Chunk::random(c, c, rng, 1.0));
        }
    }
    r
}

/// σ ∘ ⋈ query with an injective projection and no Σ: every output tuple
/// is computed by one worker from identical operands under any layout.
fn select_join_query() -> relad::ra::Query {
    let mut qb = QueryBuilder::new();
    let sx = qb.scan(0, "X");
    let sy = qb.scan(1, "Y");
    let t = qb.select(KeyPred::always(), KeyProj::take(&[0, 1]), UnaryKernel::Tanh, sx);
    let j = qb.join(
        JoinPred::on(vec![(0, 0), (1, 1)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1)]),
        BinaryKernel::Mul,
        t,
        sy,
    );
    qb.finish(j)
}

#[test]
fn threaded_equals_serial_bitwise_per_worker_count() {
    // Matmul (join + Σ) — the Σ merge order is fixed per worker count,
    // so threaded vs serial at the same w must agree to the bit.
    let mut rng = Prng::new(0xDE7);
    let a = blocked(4, 3, 8, &mut rng);
    let b = blocked(3, 4, 8, &mut rng);
    let q = relad::ra::expr::matmul_query();
    for w in [1usize, 2, 3, 8] {
        let pa = PartitionedRelation::hash_full(&a, w);
        let pb = PartitionedRelation::hash_full(&b, w);
        let threaded = ClusterConfig::new(w);
        let serial = ClusterConfig::new(w).with_parallel(false);
        let (gt, st) =
            dist_eval(&q, &[pa.clone(), pb.clone()], &threaded, &NativeBackend).unwrap();
        let (gs, ss) = dist_eval(&q, &[pa.clone(), pb.clone()], &serial, &NativeBackend).unwrap();
        assert!(
            bitwise_eq(&gt.gather(), &gs.gather()),
            "w={w}: threaded and serial runs diverged"
        );
        // Same modeled counters either way — threads change wall clock
        // only.
        assert_eq!(st.bytes_shuffled, ss.bytes_shuffled, "w={w}");
        assert_eq!(st.msgs, ss.msgs, "w={w}");
        assert_eq!(st.stages, ss.stages, "w={w}");
        // And a second threaded run is bitwise stable.
        let (gt2, _) = dist_eval(&q, &[pa, pb], &threaded, &NativeBackend).unwrap();
        assert!(bitwise_eq(&gt.gather(), &gt2.gather()), "w={w}: rerun diverged");
    }
}

#[test]
fn no_agg_query_bitwise_invariant_across_worker_counts() {
    let mut rng = Prng::new(0xACE);
    let x = blocked(6, 5, 4, &mut rng);
    let y = blocked(6, 5, 4, &mut rng);
    let q = select_join_query();
    let want = {
        let px = PartitionedRelation::hash_full(&x, 1);
        let py = PartitionedRelation::hash_full(&y, 1);
        dist_eval(&q, &[px, py], &ClusterConfig::new(1), &NativeBackend)
            .unwrap()
            .0
            .gather()
    };
    assert_eq!(want.len(), x.len());
    for w in [2usize, 3, 8] {
        let px = PartitionedRelation::hash_full(&x, w);
        let py = PartitionedRelation::hash_full(&y, w);
        let (got, _) = dist_eval(&q, &[px, py], &ClusterConfig::new(w), &NativeBackend).unwrap();
        assert!(
            bitwise_eq(&got.gather(), &want),
            "w={w}: σ∘⋈ output must be bitwise equal to the single-worker result"
        );
    }
}

/// Matmul whose inputs are deliberately partitioned *off* the join key
/// (A by row, B by column): `plan_join` must pick
/// `Reshuffle{left, right}`, so the stage exercises the parallel
/// all-to-all on both sides, then the Σ exchange, then a second
/// cross-worker Σ (the first Σ's hash on ⟨0,1⟩ does not determine the
/// final grouping on ⟨0⟩ alone) — a shuffle-heavy multi-Σ plan.
fn reshuffle_matmul_two_sigma_query() -> relad::ra::Query {
    let mut qb = QueryBuilder::new();
    let a = qb.scan(0, "A");
    let b = qb.scan(1, "B");
    let j = qb.join(
        JoinPred::on(vec![(1, 0)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
        BinaryKernel::MatMul,
        a,
        b,
    );
    let s1 = qb.agg(KeyProj::take(&[0, 2]), AggKernel::Sum, j);
    let s2 = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, s1);
    qb.finish(s2)
}

#[test]
fn pooled_shuffle_bitwise_on_reshuffle_join_and_multi_sigma() {
    let mut rng = Prng::new(0xF00D);
    let a = blocked(6, 4, 4, &mut rng);
    let b = blocked(4, 6, 4, &mut rng);
    let q = reshuffle_matmul_two_sigma_query();
    // Zero per-message latency: on test-sized relations the default
    // model's latency term would tip the planner to broadcast; with
    // bandwidth only, re-homing both sides (2·(w-1)/w² per byte) is
    // never costlier than allgathering one (·(w-1)/w), so the plan is
    // the reshuffle join this test is about.
    let net = NetModel {
        bandwidth_bps: 1.25e9,
        latency_s: 0.0,
    };
    for w in [1usize, 2, 3, 8] {
        // Partition both sides off the join key A[1]=B[0] so the planner
        // must reshuffle both.
        let pa = PartitionedRelation::hash_partition(&a, &[0], w);
        let pb = PartitionedRelation::hash_partition(&b, &[1], w);
        if w > 1 {
            let plan = plan_join(&pa, &pb, &JoinPred::on(vec![(1, 0)]), &net, w);
            assert_eq!(
                plan.strategy,
                JoinStrategy::Reshuffle { left: true, right: true },
                "w={w}: test premise broken — planner did not pick a reshuffle join"
            );
        }
        let ins = [pa, pb];
        let pooled = ClusterConfig::new(w).with_net(net);
        let driver_comm = ClusterConfig::new(w).with_net(net).with_parallel_comm(false);
        let serial = ClusterConfig::new(w).with_net(net).with_parallel(false);
        let (gp, sp) = dist_eval(&q, &ins, &pooled, &NativeBackend).unwrap();
        let (gd, sd) = dist_eval(&q, &ins, &driver_comm, &NativeBackend).unwrap();
        let (gs, ss) = dist_eval(&q, &ins, &serial, &NativeBackend).unwrap();
        assert!(
            bitwise_eq(&gp.gather(), &gs.gather()),
            "w={w}: pooled shuffle/gather diverged from serial"
        );
        assert!(
            bitwise_eq(&gp.gather(), &gd.gather()),
            "w={w}: pooled comm diverged from driver-serial comm"
        );
        // Identical modeled traffic on all three paths.
        assert_eq!(sp.bytes_shuffled, ss.bytes_shuffled, "w={w}");
        assert_eq!(sp.bytes_shuffled, sd.bytes_shuffled, "w={w}");
        assert_eq!(sp.msgs, ss.msgs, "w={w}");
        assert_eq!(sp.stages, ss.stages, "w={w}");
        if w > 1 {
            assert!(sp.bytes_shuffled > 0, "w={w}: plan was not shuffle-heavy");
        }
        // Per-shard layouts agree too (not just the gathered union).
        for (x, y) in gp.shards.iter().zip(gs.shards.iter()) {
            assert!(bitwise_eq(x.as_ref(), y.as_ref()), "w={w}: shard layout diverged");
        }
    }
}

/// In-place SGD shared by both loops so their arithmetic is identical.
fn sgd_apply(target: &mut Relation, grel: &Relation, lr: f32) {
    for kv in target.iter_mut() {
        let (k, v) = (&kv.0, &mut kv.1);
        if let Some(g) = grel.get(k) {
            let mut d = g.clone();
            d.scale_assign(-lr);
            v.add_assign(&d);
        }
    }
}

#[test]
fn trainer_loop_threaded_equals_serial() {
    // Seeded multi-step training (taped forward + generated backward):
    // the threaded run must reproduce the serial run's losses, gradients
    // and final parameters to the bit, at every worker count.
    let g = power_law_graph("det", 40, 120, 8, 4, 0.5, 31);
    let cfg = GcnConfig {
        feat_dim: 8,
        hidden: 8,
        n_labels: 4,
        dropout: None,
        seed: 5,
    };
    let q = gcn::loss_query(&cfg, g.labels.len());
    let trainer =
        DistTrainer::new(q, &[1, 1, 2, 1, 1], &[gcn::SLOT_W1, gcn::SLOT_W2]).unwrap();
    let layouts = || {
        vec![
            SlotLayout::Replicated,
            SlotLayout::Replicated,
            SlotLayout::HashOn(vec![0]),
            SlotLayout::HashFull,
            SlotLayout::HashFull,
        ]
    };
    for w in [1usize, 2, 3, 8] {
        let mut run = |parallel: bool, parallel_comm: bool| -> (Vec<u32>, Relation, Relation) {
            let mut rng = Prng::new(77);
            let (mut w1, mut w2) = gcn::init_params(&cfg, &mut rng);
            let ccfg = ClusterConfig::new(w)
                .with_parallel(parallel)
                .with_parallel_comm(parallel_comm);
            let mut pipe = trainer.pipeline(layouts());
            let mut losses = Vec::new();
            for _ in 0..3 {
                let inputs = [&w1, &w2, &g.edges, &g.feats, &g.labels];
                let res = pipe.step(&inputs, &ccfg, &NativeBackend).unwrap();
                losses.push(res.loss.to_bits());
                for (slot, grel) in &res.grads {
                    let target = if *slot == gcn::SLOT_W1 { &mut w1 } else { &mut w2 };
                    sgd_apply(target, grel, 0.1);
                }
            }
            (losses, w1, w2)
        };
        let (lt, wt1, wt2) = run(true, true);
        let (ld, wd1, wd2) = run(true, false);
        let (ls, ws1, ws2) = run(false, true);
        assert_eq!(lt, ls, "w={w}: pooled and serial loss curves diverged");
        assert_eq!(lt, ld, "w={w}: pooled and driver-comm loss curves diverged");
        assert!(bitwise_eq(&wt1, &ws1), "w={w}: W1 diverged");
        assert!(bitwise_eq(&wt2, &ws2), "w={w}: W2 diverged");
        assert!(bitwise_eq(&wt1, &wd1), "w={w}: W1 diverged (driver comm)");
        assert!(bitwise_eq(&wt2, &wd2), "w={w}: W2 diverged (driver comm)");
    }
}

/// A backend that counts `for_worker` mints (kernels dispatch natively,
/// so worker instances dispatch identically to the root instance).
struct CountingBackend {
    minted: Arc<AtomicUsize>,
}

impl KernelBackend for CountingBackend {
    fn unary(&self, k: &UnaryKernel, key: &Key, x: &Chunk) -> Chunk {
        relad::kernels::native::apply_unary(k, key, x)
    }

    fn binary(&self, k: &BinaryKernel, key: &Key, l: &Chunk, r: &Chunk) -> Chunk {
        relad::kernels::native::apply_binary(k, key, l, r)
    }

    fn name(&self) -> &'static str {
        "counting"
    }

    fn for_worker(&self) -> Box<dyn KernelBackend + Send> {
        self.minted.fetch_add(1, Ordering::SeqCst);
        Box::new(NativeBackend)
    }
}

#[test]
fn for_worker_minted_once_per_run_and_pool_reused_across_pipeline_steps() {
    let g = power_law_graph("pool", 30, 90, 8, 4, 0.5, 13);
    let cfg = GcnConfig {
        feat_dim: 8,
        hidden: 8,
        n_labels: 4,
        dropout: None,
        seed: 5,
    };
    let q = gcn::loss_query(&cfg, g.labels.len());
    let trainer =
        DistTrainer::new(q, &[1, 1, 2, 1, 1], &[gcn::SLOT_W1, gcn::SLOT_W2]).unwrap();
    let w = 2;
    let ccfg = ClusterConfig::new(w);
    // On a single-core host the pool never engages and mints nothing;
    // the expectation adapts so the assertion stays exact everywhere.
    let expect = if WorkerPool::engages(&ccfg) { w } else { 0 };
    let minted = Arc::new(AtomicUsize::new(0));
    let backend = CountingBackend {
        minted: Arc::clone(&minted),
    };
    let mut rng = Prng::new(21);
    let (w1, w2) = gcn::init_params(&cfg, &mut rng);

    // One trainer run = one pool: the forward evaluation, the backward
    // evaluation, and every stage in both share the same w backends.
    let pins = vec![
        PartitionedRelation::replicate(&w1, w),
        PartitionedRelation::replicate(&w2, w),
        PartitionedRelation::hash_partition(&g.edges, &[0], w),
        PartitionedRelation::hash_full(&g.feats, w),
        PartitionedRelation::hash_full(&g.labels, w),
    ];
    trainer.step(&pins, &ccfg, &backend).unwrap();
    assert_eq!(
        minted.load(Ordering::SeqCst),
        expect,
        "for_worker must run once per worker per trainer run, not per stage/evaluation"
    );

    // A 3-step pipeline loop reuses one pool: still `w` mints total.
    minted.store(0, Ordering::SeqCst);
    let mut pipe = trainer.pipeline(vec![
        SlotLayout::Replicated,
        SlotLayout::Replicated,
        SlotLayout::HashOn(vec![0]),
        SlotLayout::HashFull,
        SlotLayout::HashFull,
    ]);
    for _ in 0..3 {
        let inputs = [&w1, &w2, &g.edges, &g.feats, &g.labels];
        pipe.step(&inputs, &ccfg, &backend).unwrap();
    }
    assert_eq!(
        minted.load(Ordering::SeqCst),
        expect,
        "a pipeline loop must reuse one pool across steps"
    );

    // A serial step through the same pipeline drops the pool; the next
    // threaded step re-mints exactly once more.
    minted.store(0, Ordering::SeqCst);
    let serial = ClusterConfig::new(w).with_parallel(false);
    let inputs = [&w1, &w2, &g.edges, &g.feats, &g.labels];
    pipe.step(&inputs, &serial, &backend).unwrap();
    assert_eq!(minted.load(Ordering::SeqCst), 0, "serial step must not mint");
    pipe.step(&inputs, &ccfg, &backend).unwrap();
    assert_eq!(minted.load(Ordering::SeqCst), expect, "pool rebuilt once after serial step");
}
