//! Parallel-determinism properties of the threaded BSP executor.
//!
//! The threaded path (`ClusterConfig::parallel = true`, the default)
//! must be **bitwise** interchangeable with the serial reference path at
//! every worker count: threads change *when* a shard runs, never what it
//! computes or the order results are merged in. Across worker counts,
//! queries without a cross-worker Σ are bitwise partition-invariant too
//! (per-tuple kernels see identical operands); queries with a
//! cross-worker Σ are invariant up to float reassociation in the merge,
//! as the `dist` module documents.

use relad::data::graphs::power_law_graph;
use relad::dist::{dist_eval, ClusterConfig, PartitionedRelation};
use relad::kernels::{BinaryKernel, NativeBackend, UnaryKernel};
use relad::ml::gcn::{self, GcnConfig};
use relad::ml::{DistTrainer, SlotLayout};
use relad::ra::{
    Chunk, JoinPred, Key, KeyPred, KeyProj, KeyProj2, QueryBuilder, Relation, Sel2,
};
use relad::util::Prng;

/// Bitwise equality: same key set, every chunk elementwise bit-identical.
fn bitwise_eq(a: &Relation, b: &Relation) -> bool {
    if a.len() != b.len() {
        return false;
    }
    a.iter().all(|(k, v)| match b.get(k) {
        Some(w) => {
            v.shape() == w.shape()
                && v.data()
                    .iter()
                    .zip(w.data().iter())
                    .all(|(x, y)| x.to_bits() == y.to_bits())
        }
        None => false,
    })
}

fn blocked(n: i64, m: i64, c: usize, rng: &mut Prng) -> Relation {
    let mut r = Relation::new();
    for i in 0..n {
        for j in 0..m {
            r.insert(Key::k2(i, j), Chunk::random(c, c, rng, 1.0));
        }
    }
    r
}

/// σ ∘ ⋈ query with an injective projection and no Σ: every output tuple
/// is computed by one worker from identical operands under any layout.
fn select_join_query() -> relad::ra::Query {
    let mut qb = QueryBuilder::new();
    let sx = qb.scan(0, "X");
    let sy = qb.scan(1, "Y");
    let t = qb.select(KeyPred::always(), KeyProj::take(&[0, 1]), UnaryKernel::Tanh, sx);
    let j = qb.join(
        JoinPred::on(vec![(0, 0), (1, 1)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1)]),
        BinaryKernel::Mul,
        t,
        sy,
    );
    qb.finish(j)
}

#[test]
fn threaded_equals_serial_bitwise_per_worker_count() {
    // Matmul (join + Σ) — the Σ merge order is fixed per worker count,
    // so threaded vs serial at the same w must agree to the bit.
    let mut rng = Prng::new(0xDE7);
    let a = blocked(4, 3, 8, &mut rng);
    let b = blocked(3, 4, 8, &mut rng);
    let q = relad::ra::expr::matmul_query();
    for w in [1usize, 2, 3, 8] {
        let pa = PartitionedRelation::hash_full(&a, w);
        let pb = PartitionedRelation::hash_full(&b, w);
        let threaded = ClusterConfig::new(w);
        let serial = ClusterConfig::new(w).with_parallel(false);
        let (gt, st) =
            dist_eval(&q, &[pa.clone(), pb.clone()], &threaded, &NativeBackend).unwrap();
        let (gs, ss) = dist_eval(&q, &[pa.clone(), pb.clone()], &serial, &NativeBackend).unwrap();
        assert!(
            bitwise_eq(&gt.gather(), &gs.gather()),
            "w={w}: threaded and serial runs diverged"
        );
        // Same modeled counters either way — threads change wall clock
        // only.
        assert_eq!(st.bytes_shuffled, ss.bytes_shuffled, "w={w}");
        assert_eq!(st.msgs, ss.msgs, "w={w}");
        assert_eq!(st.stages, ss.stages, "w={w}");
        // And a second threaded run is bitwise stable.
        let (gt2, _) = dist_eval(&q, &[pa, pb], &threaded, &NativeBackend).unwrap();
        assert!(bitwise_eq(&gt.gather(), &gt2.gather()), "w={w}: rerun diverged");
    }
}

#[test]
fn no_agg_query_bitwise_invariant_across_worker_counts() {
    let mut rng = Prng::new(0xACE);
    let x = blocked(6, 5, 4, &mut rng);
    let y = blocked(6, 5, 4, &mut rng);
    let q = select_join_query();
    let want = {
        let px = PartitionedRelation::hash_full(&x, 1);
        let py = PartitionedRelation::hash_full(&y, 1);
        dist_eval(&q, &[px, py], &ClusterConfig::new(1), &NativeBackend)
            .unwrap()
            .0
            .gather()
    };
    assert_eq!(want.len(), x.len());
    for w in [2usize, 3, 8] {
        let px = PartitionedRelation::hash_full(&x, w);
        let py = PartitionedRelation::hash_full(&y, w);
        let (got, _) = dist_eval(&q, &[px, py], &ClusterConfig::new(w), &NativeBackend).unwrap();
        assert!(
            bitwise_eq(&got.gather(), &want),
            "w={w}: σ∘⋈ output must be bitwise equal to the single-worker result"
        );
    }
}

/// In-place SGD shared by both loops so their arithmetic is identical.
fn sgd_apply(target: &mut Relation, grel: &Relation, lr: f32) {
    for kv in target.iter_mut() {
        let (k, v) = (&kv.0, &mut kv.1);
        if let Some(g) = grel.get(k) {
            let mut d = g.clone();
            d.scale_assign(-lr);
            v.add_assign(&d);
        }
    }
}

#[test]
fn trainer_loop_threaded_equals_serial() {
    // Seeded multi-step training (taped forward + generated backward):
    // the threaded run must reproduce the serial run's losses, gradients
    // and final parameters to the bit, at every worker count.
    let g = power_law_graph("det", 40, 120, 8, 4, 0.5, 31);
    let cfg = GcnConfig {
        feat_dim: 8,
        hidden: 8,
        n_labels: 4,
        dropout: None,
        seed: 5,
    };
    let q = gcn::loss_query(&cfg, g.labels.len());
    let trainer =
        DistTrainer::new(q, &[1, 1, 2, 1, 1], &[gcn::SLOT_W1, gcn::SLOT_W2]).unwrap();
    let layouts = || {
        vec![
            SlotLayout::Replicated,
            SlotLayout::Replicated,
            SlotLayout::HashOn(vec![0]),
            SlotLayout::HashFull,
            SlotLayout::HashFull,
        ]
    };
    for w in [1usize, 2, 3, 8] {
        let mut run = |parallel: bool| -> (Vec<u32>, Relation, Relation) {
            let mut rng = Prng::new(77);
            let (mut w1, mut w2) = gcn::init_params(&cfg, &mut rng);
            let ccfg = ClusterConfig::new(w).with_parallel(parallel);
            let mut pipe = trainer.pipeline(layouts());
            let mut losses = Vec::new();
            for _ in 0..3 {
                let inputs = [&w1, &w2, &g.edges, &g.feats, &g.labels];
                let res = pipe.step(&inputs, &ccfg, &NativeBackend).unwrap();
                losses.push(res.loss.to_bits());
                for (slot, grel) in &res.grads {
                    let target = if *slot == gcn::SLOT_W1 { &mut w1 } else { &mut w2 };
                    sgd_apply(target, grel, 0.1);
                }
            }
            (losses, w1, w2)
        };
        let (lt, wt1, wt2) = run(true);
        let (ls, ws1, ws2) = run(false);
        assert_eq!(lt, ls, "w={w}: threaded and serial loss curves diverged");
        assert!(bitwise_eq(&wt1, &ws1), "w={w}: W1 diverged");
        assert!(bitwise_eq(&wt2, &ws2), "w={w}: W2 diverged");
    }
}
