//! Zero-cost-when-off: with `fault_plan: None` (the default) the
//! executor constructs no injector and the instrumented sites are
//! skipped entirely — the process-global probe counter
//! (`relad::dist::fault::probes`, incremented *only* inside
//! `FaultInjector::probe`) stays at zero across query evaluation,
//! grace-spilled evaluation, and a full training loop.
//!
//! This lives in its own test binary on purpose: `tests/fault.rs` runs
//! fault plans and legitimately racks the counter up, and cargo test
//! binaries share a process per file, so the zero assertion is only
//! meaningful when every test in the binary is fault-free.

mod common;

use common::{blocked, sgd_apply};
use relad::data::graphs::power_law_graph;
use relad::dist::ClusterConfig;
use relad::kernels::{AggKernel, BinaryKernel};
use relad::ml::gcn::{self, GcnConfig};
use relad::ml::SlotLayout;
use relad::ra::{JoinPred, KeyProj, KeyProj2, QueryBuilder, Sel2};
use relad::session::{ModelSpec, Session};
use relad::util::Prng;

#[test]
fn fault_free_configurations_never_reach_a_probe_site() {
    // 1. A shuffle-heavy query, pooled, in memory.
    let mut rng = Prng::new(0x0FF0);
    let a = blocked(6, 4, 4, &mut rng);
    let b = blocked(4, 6, 4, &mut rng);
    let q = {
        let mut qb = QueryBuilder::new();
        let sa = qb.scan(0, "A");
        let sb = qb.scan(1, "B");
        let j = qb.join(
            JoinPred::on(vec![(1, 0)]),
            KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
            BinaryKernel::MatMul,
            sa,
            sb,
        );
        let s1 = qb.agg(KeyProj::take(&[0, 2]), AggKernel::Sum, j);
        let s2 = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, s1);
        qb.finish(s2)
    };
    let run_query = |budget: Option<u64>| {
        let mut cfg = ClusterConfig::new(2);
        if let Some(bb) = budget {
            cfg = cfg.with_budget(bb);
        }
        let sess = Session::new(cfg);
        sess.register("A", &["r", "c"], &a).unwrap();
        sess.register("B", &["r", "c"], &b).unwrap();
        let got = sess.query(&q).unwrap().collect().unwrap();
        assert!(!got.is_empty());
        let st = sess.stats();
        assert_eq!(st.faults_injected, 0);
        assert_eq!(st.stage_retries, 0);
        assert_eq!(st.shards_recomputed, 0);
        st
    };
    run_query(None);
    // 2. The same query through the grace-spill path (probe sites exist
    // inside the spill loop too; they must still not be reached).
    let st = run_query(Some(1500));
    assert!(st.spill_bytes_written > 0, "premise: budget must force spill");

    // 3. A 3-step GCN training loop (forward + generated backward).
    let g = power_law_graph("hotpath", 40, 120, 8, 4, 0.5, 31);
    let gcfg = GcnConfig {
        feat_dim: 8,
        hidden: 8,
        n_labels: 4,
        dropout: None,
        seed: 5,
    };
    let lq = gcn::loss_query(&gcfg, g.labels.len());
    let sess = Session::new(ClusterConfig::new(2));
    sess.register_with_layout("Edge", &["dst", "src"], &g.edges, &SlotLayout::HashOn(vec![0]))
        .unwrap();
    sess.register("Node", &["id"], &g.feats).unwrap();
    sess.register("Y", &["id"], &g.labels).unwrap();
    let mut trainer = sess
        .trainer(ModelSpec::new(lq).param("W1", 1).param("W2", 1))
        .unwrap();
    let mut prng = Prng::new(77);
    let (mut w1, mut w2) = gcn::init_params(&gcfg, &mut prng);
    for _ in 0..3 {
        let res = trainer.step(&[("W1", &w1), ("W2", &w2)]).unwrap();
        assert!(res.loss.is_finite());
        for (name, grel) in &res.grads {
            let target = if name == "W1" { &mut w1 } else { &mut w2 };
            sgd_apply(target, grel, 0.1);
        }
    }
    drop(trainer);

    // The acceptance criterion: zero probe branches taken anywhere.
    assert_eq!(
        relad::dist::fault::probes(),
        0,
        "fault-free configurations must never reach an injection probe"
    );
}
