//! Integration: the AOT artifact path (JAX/Pallas → HLO text → PJRT)
//! must agree numerically with the native Rust kernels, and a full
//! autodiff pass must produce identical gradients on either backend.
//!
//! Requires `make artifacts` (skipped with a notice otherwise) and a
//! build with the non-default `xla` cargo feature — without it this
//! whole file compiles to nothing (the hermetic tier-1 build has no
//! PJRT runtime to exercise).
#![cfg(feature = "xla")]

use relad::autodiff::grad;
use relad::kernels::{
    AggKernel, BinaryKernel, KernelBackend, NativeBackend, UnaryKernel,
};
use relad::ra::expr::QueryBuilder;
use relad::ra::funcs::{JoinPred, KeyProj, KeyProj2, Sel2};
use relad::ra::{Chunk, Key, Relation};
use relad::runtime::XlaBackend;
use relad::util::Prng;

fn artifacts() -> Option<XlaBackend> {
    if !std::path::Path::new("artifacts/manifest.tsv").exists() {
        eprintln!("SKIP: artifacts/manifest.tsv missing — run `make artifacts`");
        return None;
    }
    Some(XlaBackend::load("artifacts").expect("loading artifacts"))
}

#[test]
fn xla_binary_kernels_match_native() {
    let Some(xla) = artifacts() else { return };
    let mut rng = Prng::new(71);
    let key = Key::k2(0, 0);
    let a64 = Chunk::random(64, 64, &mut rng, 1.0);
    let b64 = Chunk::random(64, 64, &mut rng, 1.0);
    let cases: Vec<(BinaryKernel, Chunk, Chunk, f32)> = vec![
        (BinaryKernel::MatMul, a64.clone(), b64.clone(), 1e-3),
        (BinaryKernel::MatMulTN, a64.clone(), b64.clone(), 1e-3),
        (BinaryKernel::MatMulNT, a64.clone(), b64.clone(), 1e-3),
        (BinaryKernel::Add, a64.clone(), b64.clone(), 1e-5),
        (BinaryKernel::Mul, a64.clone(), b64.clone(), 1e-5),
        (BinaryKernel::Sub, a64.clone(), b64.clone(), 1e-5),
        (
            BinaryKernel::SquaredDiff,
            a64.clone(),
            b64.clone(),
            1e-4,
        ),
        (
            BinaryKernel::DRelu,
            a64.clone(),
            b64.clone(),
            1e-5,
        ),
        (
            BinaryKernel::DLogistic,
            a64.clone(),
            b64.clone(),
            1e-4,
        ),
    ];
    let mut hits_before = xla.stats().0;
    for (k, l, r, tol) in cases {
        let want = NativeBackend.binary(&k, &key, &l, &r);
        let got = xla.binary(&k, &key, &l, &r);
        assert!(
            got.approx_eq(&want, tol),
            "kernel {:?}: xla vs native max diff {}",
            k,
            got.max_abs_diff(&want)
        );
        let hits_now = xla.stats().0;
        assert!(hits_now > hits_before, "kernel {k:?} did not hit an artifact");
        hits_before = hits_now;
    }
}

#[test]
fn xla_unary_kernels_match_native() {
    let Some(xla) = artifacts() else { return };
    let mut rng = Prng::new(72);
    let key = Key::k1(0);
    let x = Chunk::random(64, 64, &mut rng, 0.8);
    for (k, tol) in [
        (UnaryKernel::Logistic, 1e-5),
        (UnaryKernel::Relu, 1e-6),
        (UnaryKernel::Tanh, 1e-5),
        (UnaryKernel::Square, 1e-4),
        (UnaryKernel::SumAll, 1e-2),
        (UnaryKernel::RowSum, 1e-3),
        (UnaryKernel::Transpose, 0.0),
    ] {
        let want = NativeBackend.unary(&k, &key, &x);
        let got = xla.unary(&k, &key, &x);
        assert!(
            got.approx_eq(&want, tol),
            "kernel {:?}: xla vs native max diff {}",
            k,
            got.max_abs_diff(&want)
        );
    }
}

#[test]
fn xla_softmax_xent_on_label_shape() {
    let Some(xla) = artifacts() else { return };
    let mut rng = Prng::new(73);
    let key = Key::k1(0);
    let logits = Chunk::random(64, 40, &mut rng, 1.0);
    // one-hot labels
    let mut oh = Chunk::zeros(64, 40);
    for i in 0..64 {
        let j = (i * 7) % 40;
        oh.set(i, j, 1.0);
    }
    let k = BinaryKernel::SoftmaxXentRows;
    let want = NativeBackend.binary(&k, &key, &logits, &oh);
    let got = xla.binary(&k, &key, &logits, &oh);
    assert!(got.approx_eq(&want, 1e-4));
    let dk = BinaryKernel::DSoftmaxXentDl;
    let want_d = NativeBackend.binary(&dk, &key, &logits, &oh);
    let got_d = xla.binary(&dk, &key, &logits, &oh);
    assert!(got_d.approx_eq(&want_d, 1e-4));
}

#[test]
fn xla_fallback_on_unknown_shape() {
    let Some(xla) = artifacts() else { return };
    let mut rng = Prng::new(74);
    let key = Key::k1(0);
    // 17x17 is not in the artifact set → native fallback, same numbers.
    let l = Chunk::random(17, 17, &mut rng, 1.0);
    let r = Chunk::random(17, 17, &mut rng, 1.0);
    let misses_before = xla.stats().1;
    let got = xla.binary(&BinaryKernel::MatMul, &key, &l, &r);
    assert!(xla.stats().1 > misses_before);
    let want = NativeBackend.binary(&BinaryKernel::MatMul, &key, &l, &r);
    assert!(got.approx_eq(&want, 1e-4));
}

/// End-to-end: autodiff over a blocked-matmul loss executed entirely on
/// the XLA backend matches the native backend — i.e. the three-layer path
/// (Pallas kernel → HLO artifact → PJRT in rust) reproduces the engine's
/// semantics, gradients included.
#[test]
fn autodiff_identical_across_backends() {
    let Some(xla) = artifacts() else { return };
    let mut rng = Prng::new(75);
    let mut a = Relation::new();
    let mut b = Relation::new();
    for i in 0..2i64 {
        for k in 0..2i64 {
            a.insert(Key::k2(i, k), Chunk::random(64, 64, &mut rng, 0.3));
            b.insert(Key::k2(k, i), Chunk::random(64, 64, &mut rng, 0.3));
        }
    }
    let mut qb = QueryBuilder::new();
    let sa = qb.scan(0, "A");
    let sb = qb.scan(1, "B");
    let j = qb.join(
        JoinPred::on(vec![(1, 0)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
        BinaryKernel::MatMul,
        sa,
        sb,
    );
    let s = qb.agg(KeyProj::take(&[0, 2]), AggKernel::Sum, j);
    let act = qb.map(UnaryKernel::Tanh, 2, s);
    let sums = qb.map(UnaryKernel::SumAll, 2, act);
    let loss = qb.agg(KeyProj::to_empty(), AggKernel::Sum, sums);
    let q = qb.finish(loss);

    let (tape_n, g_n) = grad(&q, &[&a, &b], &NativeBackend).unwrap();
    let (tape_x, g_x) = grad(&q, &[&a, &b], &xla).unwrap();
    let ln = tape_n.output(&q).get(&Key::empty()).unwrap().as_scalar();
    let lx = tape_x.output(&q).get(&Key::empty()).unwrap().as_scalar();
    assert!((ln - lx).abs() < 1e-3, "loss mismatch: {ln} vs {lx}");
    for slot in 0..2 {
        let d = g_n.slot(slot).max_abs_diff(g_x.slot(slot)).unwrap();
        assert!(d < 1e-3, "slot {slot} gradient diff {d}");
    }
    let (hits, _) = xla.stats();
    assert!(hits > 0, "xla backend never hit an artifact");
}
