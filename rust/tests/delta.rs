//! Incremental-engine acceptance suite: delta tables maintained through
//! σ/⋈/Σ and the generated backward, proven **bitwise** against full
//! recompute from the merged tables — gathered relations, per-shard
//! layouts *and emission order*, and the delta counters — across worker
//! counts, both communication paths, and spill budgets.
//!
//! Inputs are integer-valued floats throughout, so every Σ the delta
//! path re-folds is exact in f32 and the bitwise bar is meaningful, not
//! vacuous. The shapes covered:
//!
//! * co-partitioned ⋈ + Σ where the append path genuinely fires
//!   (`shards_reused` > 0: suffix probe + fold, no recompute of the
//!   untouched side),
//! * an `AddQ` of two Σ-over-⋈ branches where the untouched branch is
//!   served verbatim from the previous tape,
//! * the reshuffle-⋈ + two-Σ plan under an insert/delete/mixed update
//!   grid (the delta gate admits it, the executor recomputes the dirty
//!   stages — bitwise either way),
//! * the refusal matrix (`Max` Σ, literal-pinned ⋈ predicate) falling
//!   back whole, charged in `delta_fallbacks` and rendered by `explain`,
//! * GCN gradients maintained through label inserts/deletes, and a
//!   3-step GCN training loop consuming interleaved updates without
//!   re-ingesting a table.

mod common;

use common::{bitwise_eq, sgd_apply};
use relad::data::graphs::power_law_graph;
use relad::dist::{ClusterConfig, MemPolicy, PartitionedRelation};
use relad::kernels::{AggKernel, BinaryKernel};
use relad::ml::gcn::{self, GcnConfig};
use relad::ml::SlotLayout;
use relad::ra::{Chunk, JoinPred, Key, KeyProj, KeyProj2, Query, QueryBuilder, Relation, Sel2};
use relad::session::{ModelSpec, Session};
use relad::util::Prng;

/// Integer-valued `c×c` chunks (exact in f32) for the given keys, in
/// iteration order — kept as a pair list so tests can mirror catalog
/// updates onto a full-recompute oracle with identical tuple order.
fn int_pairs(keys: impl IntoIterator<Item = Key>, c: usize, seed: u64) -> Vec<(Key, Chunk)> {
    let mut rng = Prng::new(seed);
    keys.into_iter()
        .map(|k| {
            let v = (rng.next_u64() % 9 + 1) as f32;
            (k, Chunk::filled(c, c, v))
        })
        .collect()
}

/// Order-exact per-shard bitwise equality: same shard row counts, same
/// key emission order, same value bits. Stricter than `bitwise_eq` on
/// the gathered relation — the delta path promises to reproduce the full
/// recompute's *layout*, not just its key→value map.
fn assert_shards_bitwise(got: &PartitionedRelation, want: &PartitionedRelation, ctx: &str) {
    assert_eq!(got.workers(), want.workers(), "{ctx}: worker counts differ");
    for wi in 0..got.workers() {
        let (a, b) = (&got.shards[wi], &want.shards[wi]);
        assert_eq!(a.len(), b.len(), "{ctx}: shard {wi} row counts differ");
        for ((ka, va), (kb, vb)) in a.iter().zip(b.iter()) {
            assert_eq!(ka, kb, "{ctx}: shard {wi} emission order differs");
            assert_eq!(va.shape(), vb.shape(), "{ctx}: shard {wi} key {ka} shape differs");
            let ba: Vec<u32> = va.data().iter().map(|x| x.to_bits()).collect();
            let bb: Vec<u32> = vb.data().iter().map(|x| x.to_bits()).collect();
            assert_eq!(ba, bb, "{ctx}: shard {wi} key {ka} value bits differ");
        }
    }
}

/// Σ over R(a,b) ⋈ S(a,c) GROUP BY a — co-partitioned on `a`, the shape
/// where the suffix-append path through ⋈ and Σ actually engages.
fn local_sumjoin(agg: AggKernel, pred: JoinPred) -> Query {
    let mut qb = QueryBuilder::new();
    let r = qb.scan(0, "R");
    let s = qb.scan(1, "S");
    let j = qb.join(
        pred,
        KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
        BinaryKernel::Mul,
        r,
        s,
    );
    let a = qb.agg(KeyProj::take(&[0]), agg, j);
    qb.finish(a)
}

/// R and S registered co-partitioned on the join key (`HashOn([0])`),
/// factorization off so the plain forward path is what runs.
fn co_session(w: usize, r: &[(Key, Chunk)], s: &[(Key, Chunk)]) -> Session {
    let sess = Session::new(ClusterConfig::new(w).with_factorize(false));
    sess.register_with_layout(
        "R",
        &["a", "b"],
        &Relation::from_pairs(r.to_vec()),
        &SlotLayout::HashOn(vec![0]),
    )
    .unwrap();
    sess.register_with_layout(
        "S",
        &["a", "c"],
        &Relation::from_pairs(s.to_vec()),
        &SlotLayout::HashOn(vec![0]),
    )
    .unwrap();
    sess
}

/// The append fast path end to end: an insert-only batch into R replays
/// as a per-shard suffix through the co-partitioned ⋈ (probe only the
/// new tuples against a build over clean S) and folds into the cached Σ
/// — `shards_reused` counts both stages — and the result matches a full
/// recompute over the merged tables shard for shard, bit for bit.
#[test]
fn append_through_join_and_sigma_reuses_shards_bitwise() {
    let q = local_sumjoin(AggKernel::Sum, JoinPred::on(vec![(0, 0)]));
    let r0 = int_pairs((0..64).map(|i| Key::k2(i % 8, i)), 2, 0xD1);
    let s0 = int_pairs((0..8).map(|g| Key::k2(g, 100 + g)), 2, 0xD2);
    let batch = int_pairs((0..8).map(|g| Key::k2(g, 1000 + g)), 2, 0xD3);
    for w in [1usize, 2, 8] {
        let sess = co_session(w, &r0, &s0);
        let frame = sess.query(&q).unwrap();
        frame.collect().unwrap();
        sess.insert("R", batch.clone()).unwrap();
        let (got, stats) = frame.collect_partitioned().unwrap();
        // ⋈ append + Σ fold: each serves/extends the previous tape on
        // every worker instead of recomputing.
        assert!(
            stats.shards_reused >= 2 * w as u64,
            "w={w}: expected ≥ {} reused shards, got {}",
            2 * w,
            stats.shards_reused
        );
        // Replay rows charge at the session layer, not per stage.
        assert_eq!(stats.delta_rows_applied, 0, "w={w}");
        assert_eq!(
            sess.stats().delta_rows_applied,
            16,
            "w={w}: 8 rows at ingest + 8 at frame replay"
        );
        assert_eq!(sess.stats().delta_fallbacks, 0, "w={w}: nothing refused");
        let mut r1 = r0.clone();
        r1.extend(batch.iter().cloned());
        let oracle = co_session(w, &r1, &s0);
        let (want, _) = oracle.query(&q).unwrap().collect_partitioned().unwrap();
        assert_shards_bitwise(&got, &want, &format!("w={w}"));
        assert!(
            bitwise_eq(&got.gather(), &want.gather()),
            "w={w}: gathered result diverged"
        );
    }
}

/// Σ(R⋈S) + Σ(T⋈U) with updates landing only in R: the whole T⋈U branch
/// — join and Σ — must be served verbatim from the previous tape (clean
/// reuse), the touched branch appends, and only the AddQ recomputes.
#[test]
fn untouched_sibling_branch_serves_previous_tape() {
    let mut qb = QueryBuilder::new();
    let r = qb.scan(0, "R");
    let s = qb.scan(1, "S");
    let t = qb.scan(2, "T");
    let u = qb.scan(3, "U");
    let proj = KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]);
    let j1 = qb.join(JoinPred::on(vec![(0, 0)]), proj.clone(), BinaryKernel::Mul, r, s);
    let a1 = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, j1);
    let j2 = qb.join(JoinPred::on(vec![(0, 0)]), proj, BinaryKernel::Mul, t, u);
    let a2 = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, j2);
    let out = qb.add(a1, a2);
    let q = qb.finish(out);

    let r0 = int_pairs((0..64).map(|i| Key::k2(i % 8, i)), 2, 0xE1);
    let s0 = int_pairs((0..8).map(|g| Key::k2(g, 100 + g)), 2, 0xE2);
    let t0 = int_pairs((0..48).map(|i| Key::k2(i % 8, i)), 2, 0xE3);
    let u0 = int_pairs((0..8).map(|g| Key::k2(g, 200 + g)), 2, 0xE4);
    let batch = int_pairs((0..4).map(|g| Key::k2(g, 1000 + g)), 2, 0xE5);
    let w = 2usize;
    let mk = |rp: &[(Key, Chunk)]| {
        let sess = Session::new(ClusterConfig::new(w).with_factorize(false));
        let tables: [(&str, &[(Key, Chunk)]); 4] =
            [("R", rp), ("S", &s0), ("T", &t0), ("U", &u0)];
        for (name, pairs) in tables {
            sess.register_with_layout(
                name,
                &["a", "b"],
                &Relation::from_pairs(pairs.to_vec()),
                &SlotLayout::HashOn(vec![0]),
            )
            .unwrap();
        }
        sess
    };
    let sess = mk(&r0);
    let frame = sess.query(&q).unwrap();
    frame.collect().unwrap();
    sess.insert("R", batch.clone()).unwrap();
    let (got, stats) = frame.collect_partitioned().unwrap();
    // Touched branch: ⋈ append + Σ fold. Untouched branch: ⋈ and Σ both
    // reused. Four stages × w workers served from the previous tape.
    assert!(
        stats.shards_reused >= 4 * w as u64,
        "expected ≥ {} reused shards, got {}",
        4 * w,
        stats.shards_reused
    );
    let mut r1 = r0.clone();
    r1.extend(batch.iter().cloned());
    let oracle = mk(&r1);
    let (want, _) = oracle.query(&q).unwrap().collect_partitioned().unwrap();
    assert_shards_bitwise(&got, &want, "AddQ two-branch");
    assert!(bitwise_eq(&got.gather(), &want.gather()), "gathered diverged");
}

/// The reshuffle-heavy plan from the spill/fault suites: ⋈ off the
/// partitioning key followed by two Σs — the delta gate admits updates
/// (pure equi ⋈, Sum Σs) but the executor recomputes the reshuffled
/// stages from the merged heads.
fn reshuffle_two_sigma_query() -> Query {
    let mut qb = QueryBuilder::new();
    let r = qb.scan(0, "R");
    let s = qb.scan(1, "S");
    let j = qb.join(
        JoinPred::on(vec![(1, 0)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
        BinaryKernel::Mul,
        r,
        s,
    );
    let s1 = qb.agg(KeyProj::take(&[0, 2]), AggKernel::Sum, j);
    let s2 = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, s1);
    qb.finish(s2)
}

/// The tentpole grid: one memoized frame taking an insert, a delete, a
/// second-table insert, and a mixed two-table update — re-collected
/// after each and compared bitwise (gathered + per-shard emission order)
/// against a fresh session over the merged tables, at w ∈ {1, 2, 8} ×
/// parallel_comm ∈ {on, off} × {in-memory, grace-spill} budgets, with
/// the session-default factorization knob left on so the delta path
/// composes with the Σ-pushdown machinery.
#[test]
fn update_grid_matches_full_recompute_bitwise() {
    let q = reshuffle_two_sigma_query();
    let r0 = int_pairs(
        (0..4).flat_map(|i| (0..3).map(move |j| Key::k2(i, j))),
        2,
        0xA1,
    );
    let s0 = int_pairs(
        (0..3).flat_map(|j| (0..4).map(move |k| Key::k2(j, k))),
        2,
        0xA2,
    );
    for w in [1usize, 2, 8] {
        for comm in [true, false] {
            for budget in [None, Some(4096u64)] {
                let ctx = format!("w={w} comm={comm} budget={budget:?}");
                let mk = |rp: &[(Key, Chunk)], sp: &[(Key, Chunk)]| {
                    let mut cfg = ClusterConfig::new(w).with_parallel_comm(comm);
                    if let Some(b) = budget {
                        cfg = cfg.with_policy(MemPolicy::Spill).with_budget(b);
                    }
                    let sess = Session::new(cfg);
                    sess.register("R", &["i", "j"], &Relation::from_pairs(rp.to_vec()))
                        .unwrap();
                    sess.register("S", &["j", "k"], &Relation::from_pairs(sp.to_vec()))
                        .unwrap();
                    sess
                };
                let (mut rp, mut sp) = (r0.clone(), s0.clone());
                let sess = mk(&rp, &sp);
                let frame = sess.query(&q).unwrap();
                frame.collect().unwrap();
                let verify = |tag: &str, rp: &[(Key, Chunk)], sp: &[(Key, Chunk)]| {
                    let (got, _) = frame.collect_partitioned().unwrap();
                    let oracle = mk(rp, sp);
                    let (want, _) = oracle.query(&q).unwrap().collect_partitioned().unwrap();
                    assert_shards_bitwise(&got, &want, &format!("{ctx} [{tag}]"));
                    assert!(
                        bitwise_eq(&got.gather(), &want.gather()),
                        "{ctx} [{tag}]: gathered diverged"
                    );
                };

                // Insert-only batch into R (a new block row).
                let batch_r = int_pairs((0..3).map(|j| Key::k2(9, j)), 2, 0xA3);
                sess.insert("R", batch_r.clone()).unwrap();
                rp.extend(batch_r.iter().cloned());
                verify("insert R", &rp, &sp);

                // Delete a base row and a freshly inserted one.
                let gone_r = [Key::k2(0, 0), Key::k2(9, 1)];
                sess.delete("R", &gone_r).unwrap();
                rp.retain(|(k, _)| !gone_r.contains(k));
                verify("delete R", &rp, &sp);

                // Insert into the other side of the ⋈.
                let batch_s = int_pairs((0..3).map(|j| Key::k2(j, 9)), 2, 0xA4);
                sess.insert("S", batch_s.clone()).unwrap();
                sp.extend(batch_s.iter().cloned());
                verify("insert S", &rp, &sp);

                // Two tables advance before one re-collect: an R batch
                // and an S delete land in the same refresh.
                let batch_r2 = int_pairs((0..3).map(|j| Key::k2(10, j)), 2, 0xA5);
                sess.insert("R", batch_r2.clone()).unwrap();
                rp.extend(batch_r2.iter().cloned());
                let gone_s = [Key::k2(0, 0)];
                sess.delete("S", &gone_s).unwrap();
                sp.retain(|(k, _)| !gone_s.contains(k));
                verify("mixed R+S", &rp, &sp);
            }
        }
    }
}

/// The refusal matrix: a `Max` Σ on the touched path (signed partials
/// cannot merge) and a literal-pinned ⋈ predicate (no pure equi-key to
/// route deltas by) each refuse the delta path — rendered as
/// `delta: refused(…)` by `explain`, charged in `delta_fallbacks`, and
/// satisfied by a full recompute that is still bitwise identical to the
/// fresh-session oracle.
#[test]
fn refused_shapes_fall_back_to_bitwise_recompute() {
    let r0 = int_pairs((0..64).map(|i| Key::k2(i % 8, i)), 2, 0xF1);
    let s0 = int_pairs((0..8).map(|g| Key::k2(g, 100 + g)), 2, 0xF2);
    let batch = int_pairs((0..8).map(|g| Key::k2(g, 1000 + g)), 2, 0xF3);
    let mut r1 = r0.clone();
    r1.extend(batch.iter().cloned());
    let w = 2usize;

    // (a) Σ with ⊕ = max over the touched ⋈.
    let q = local_sumjoin(AggKernel::Max, JoinPred::on(vec![(0, 0)]));
    let sess = co_session(w, &r0, &s0);
    let frame = sess.query(&q).unwrap();
    frame.collect().unwrap();
    sess.insert("R", batch.clone()).unwrap();
    let text = frame.explain().unwrap();
    assert!(
        text.contains("delta: refused(") && text.contains("Max"),
        "explain must render the Max refusal:\n{text}"
    );
    assert_eq!(sess.stats().delta_fallbacks, 1, "one refused replay");
    let (got, _) = frame.collect_partitioned().unwrap();
    let oracle = co_session(w, &r1, &s0);
    let (want, _) = oracle.query(&q).unwrap().collect_partitioned().unwrap();
    assert_shards_bitwise(&got, &want, "Max fallback");

    // (b) Literal-pinned (non-equi) ⋈ predicate on the delta path.
    let mut pred = JoinPred::on(vec![(0, 0)]);
    pred.r_lits.push((1, 101)); // S.c = 101 pins the g = 1 row
    let q = local_sumjoin(AggKernel::Sum, pred);
    let sess = co_session(w, &r0, &s0);
    let frame = sess.query(&q).unwrap();
    frame.collect().unwrap();
    sess.insert("R", batch.clone()).unwrap();
    let text = frame.explain().unwrap();
    assert!(
        text.contains("delta: refused(") && text.contains("non-equi"),
        "explain must render the literal-predicate refusal:\n{text}"
    );
    assert_eq!(sess.stats().delta_fallbacks, 1, "one refused replay");
    let (got, _) = frame.collect_partitioned().unwrap();
    let oracle = co_session(w, &r1, &s0);
    let (want, _) = oracle.query(&q).unwrap().collect_partitioned().unwrap();
    assert_shards_bitwise(&got, &want, "literal-predicate fallback");
}

/// A delta batch into a **skew-annotated** table refuses the delta path
/// outright: the batch shifts key frequencies, so the hot-key
/// annotation the planner would consult is stale. The refusal is
/// rendered by `explain`, charged in `delta_fallbacks`, and satisfied
/// by a full recompute that is bitwise identical — per shard, in
/// emission order — to a fresh session over the merged tables.
#[test]
fn delta_on_skew_annotated_table_refuses_to_bitwise_recompute() {
    let q = local_sumjoin(AggKernel::Sum, JoinPred::on(vec![(0, 0)]));
    // 48 rows piled on a = 0 plus a cold tail: the ingest sampler
    // annotates R at threshold 0.3; S stays uniform.
    let mut r_keys: Vec<Key> = (0..48).map(|i| Key::k2(0, i)).collect();
    r_keys.extend((0..6).map(|i| Key::k2(1 + (i % 3), 100 + i)));
    let r0 = int_pairs(r_keys, 2, 0xC1);
    let s0 = int_pairs((0..8).map(|g| Key::k2(g, 500 + g)), 2, 0xC2);
    let batch = int_pairs((0..8).map(|g| Key::k2(g, 9000 + g)), 2, 0xC3);
    let w = 2usize;
    let mk = |rp: &[(Key, Chunk)]| {
        let sess =
            Session::new(ClusterConfig::new(w).with_factorize(false).with_skew_threshold(0.3));
        sess.register_with_layout(
            "R",
            &["a", "b"],
            &Relation::from_pairs(rp.to_vec()),
            &SlotLayout::HashOn(vec![0]),
        )
        .unwrap();
        sess.register_with_layout(
            "S",
            &["a", "c"],
            &Relation::from_pairs(s0.to_vec()),
            &SlotLayout::HashOn(vec![0]),
        )
        .unwrap();
        sess
    };
    let sess = mk(&r0);
    assert_eq!(sess.stats().hot_keys_detected, 1, "premise: R must be annotated");
    let frame = sess.query(&q).unwrap();
    frame.collect().unwrap();
    sess.insert("R", batch.clone()).unwrap();
    let text = frame.explain().unwrap();
    assert!(
        text.contains("delta: refused(") && text.contains("skew-partitioned"),
        "explain must render the skew refusal:\n{text}"
    );
    assert_eq!(sess.stats().delta_fallbacks, 1, "one refused replay");
    let (got, stats) = frame.collect_partitioned().unwrap();
    assert_eq!(stats.shards_reused, 0, "a refused replay reuses nothing");
    let mut r1 = r0.clone();
    r1.extend(batch.iter().cloned());
    let oracle = mk(&r1);
    let (want, _) = oracle.query(&q).unwrap().collect_partitioned().unwrap();
    assert_shards_bitwise(&got, &want, "skew fallback");
    assert!(bitwise_eq(&got.gather(), &want.gather()), "gathered diverged");
}

/// GCN gradients are *maintained*: one frame, `grad_multi` after a label
/// insert and again after a label delete, each bitwise identical to a
/// fresh session differentiating the merged tables (the generated
/// backward replays in lockstep with the forward where admitted, and
/// recomputes where not — indistinguishable by results).
#[test]
fn gcn_grad_is_maintained_through_label_updates() {
    let g = power_law_graph("delta-grad", 40, 120, 8, 4, 0.5, 21);
    let cfg = GcnConfig {
        feat_dim: 8,
        hidden: 8,
        n_labels: 4,
        dropout: None,
        seed: 9,
    };
    let q = gcn::loss_query(&cfg, g.labels.len());
    let mut rng = Prng::new(55);
    let (w1, w2) = gcn::init_params(&cfg, &mut rng);
    let unlabeled = (0..40)
        .map(Key::k1)
        .find(|k| !g.labels.contains(k))
        .expect("an unlabeled node");
    let mut fresh_label = Chunk::zeros(1, 4);
    fresh_label.set(0, 2, 1.0);
    let gone = g.labels.pairs()[0].0;
    for w in [1usize, 2] {
        let mk = |labels: &Relation| {
            let sess = Session::new(ClusterConfig::new(w));
            sess.register_with_layout(
                "Edge",
                &["dst", "src"],
                &g.edges,
                &SlotLayout::HashOn(vec![0]),
            )
            .unwrap();
            sess.register("Node", &["id"], &g.feats).unwrap();
            sess.register("Y", &["id"], labels).unwrap();
            sess.register("W1", &["i"], &w1).unwrap();
            sess.register("W2", &["i"], &w2).unwrap();
            sess
        };
        let check = |got: &[(String, Relation)], want: &[(String, Relation)], tag: &str| {
            assert_eq!(got.len(), want.len(), "w={w} [{tag}]");
            for ((gn, gr), (wn, wr)) in got.iter().zip(want.iter()) {
                assert_eq!(gn, wn, "w={w} [{tag}]: gradient order");
                assert!(bitwise_eq(gr, wr), "w={w} [{tag}]: ∂{gn} diverged");
            }
        };
        let sess = mk(&g.labels);
        let frame = sess.query(&q).unwrap();
        frame.grad_multi(&["W1", "W2"]).unwrap();
        let mut y_pairs: Vec<(Key, Chunk)> = g.labels.pairs().to_vec();

        sess.insert("Y", vec![(unlabeled, fresh_label.clone())]).unwrap();
        y_pairs.push((unlabeled, fresh_label.clone()));
        let got = frame.grad_multi(&["W1", "W2"]).unwrap();
        let oracle = mk(&Relation::from_pairs(y_pairs.clone()));
        let want = oracle.query(&q).unwrap().grad_multi(&["W1", "W2"]).unwrap();
        check(&got, &want, "insert");

        sess.delete("Y", &[gone]).unwrap();
        y_pairs.retain(|(k, _)| *k != gone);
        let got = frame.grad_multi(&["W1", "W2"]).unwrap();
        let oracle = mk(&Relation::from_pairs(y_pairs.clone()));
        let want = oracle.query(&q).unwrap().grad_multi(&["W1", "W2"]).unwrap();
        check(&got, &want, "delete");
        assert!(sess.stats().delta_rows_applied >= 2, "w={w}");
    }
}

/// A 3-step GCN training loop with a label insert before step 2 and a
/// label delete before step 3: every step's loss bits and gradient bits
/// match a fresh trainer compiled over the merged tables — the live
/// trainer consumes the catalog deltas without re-ingesting anything.
#[test]
fn gcn_training_loop_with_interleaved_updates_is_bitwise() {
    let g = power_law_graph("delta-loop", 40, 120, 8, 4, 0.5, 31);
    let cfg = GcnConfig {
        feat_dim: 8,
        hidden: 8,
        n_labels: 4,
        dropout: None,
        seed: 5,
    };
    let q = gcn::loss_query(&cfg, g.labels.len());
    let spec = || ModelSpec::new(q.clone()).param("W1", 1).param("W2", 1);
    let unlabeled = (0..40)
        .map(Key::k1)
        .find(|k| !g.labels.contains(k))
        .expect("an unlabeled node");
    let mut fresh_label = Chunk::zeros(1, 4);
    fresh_label.set(0, 1, 1.0);
    let gone = g.labels.pairs()[0].0;
    for w in [1usize, 2] {
        let mk = |labels: &Relation| {
            let sess = Session::new(ClusterConfig::new(w));
            sess.register_with_layout(
                "Edge",
                &["dst", "src"],
                &g.edges,
                &SlotLayout::HashOn(vec![0]),
            )
            .unwrap();
            sess.register("Node", &["id"], &g.feats).unwrap();
            sess.register("Y", &["id"], labels).unwrap();
            sess
        };
        let mut y_pairs: Vec<(Key, Chunk)> = g.labels.pairs().to_vec();
        let sess = mk(&g.labels);
        let mut trainer = sess.trainer(spec()).unwrap();
        let mut rng = Prng::new(77);
        let (mut w1, mut w2) = gcn::init_params(&cfg, &mut rng);
        for step in 0..3 {
            if step == 1 {
                sess.insert("Y", vec![(unlabeled, fresh_label.clone())]).unwrap();
                y_pairs.push((unlabeled, fresh_label.clone()));
            }
            if step == 2 {
                sess.delete("Y", &[gone]).unwrap();
                y_pairs.retain(|(k, _)| *k != gone);
            }
            let live = trainer.step(&[("W1", &w1), ("W2", &w2)]).unwrap();
            // Oracle: a fresh session + trainer over the merged tables,
            // stepped once from the same parameters.
            let osess = mk(&Relation::from_pairs(y_pairs.clone()));
            let mut ot = osess.trainer(spec()).unwrap();
            let want = ot.step(&[("W1", &w1), ("W2", &w2)]).unwrap();
            let ctx = format!("w={w} step={step}");
            assert_eq!(
                live.loss.to_bits(),
                want.loss.to_bits(),
                "{ctx}: loss diverged"
            );
            assert_eq!(live.grads.len(), want.grads.len(), "{ctx}");
            for ((ln, lg), (wn, wg)) in live.grads.iter().zip(want.grads.iter()) {
                assert_eq!(ln, wn, "{ctx}: gradient order");
                assert!(bitwise_eq(lg, wg), "{ctx}: ∂{ln} diverged");
            }
            for (name, grel) in &live.grads {
                let target = if name == "W1" { &mut w1 } else { &mut w2 };
                sgd_apply(target, grel, 0.1);
            }
        }
        // Both updates were consumed as deltas (charged at ingest and at
        // the trainer's slot refresh), never as a table re-registration.
        assert!(sess.stats().delta_rows_applied >= 2, "w={w}");
    }
}
