//! Property-style invariant tests (seeded PRNG sweeps — the offline
//! stand-in for proptest).

use relad::autodiff::graph::{backward_graph, eval_backward, input_arities};
use relad::autodiff::{check, grad};
use relad::dist::{ClusterConfig, PartitionedRelation};
use relad::session::Session;
use relad::kernels::{AggKernel, BinaryKernel, NativeBackend, UnaryKernel};
use relad::ra::eval::eval_query;
use relad::ra::expr::{matmul_query, Query, QueryBuilder};
use relad::ra::funcs::{JoinPred, KeyProj, KeyProj2, Sel2};
use relad::ra::{Chunk, Key, Relation};
use relad::util::Prng;

fn random_relation(rng: &mut Prng, n: usize, arity: usize, shape: (usize, usize)) -> Relation {
    let mut r = Relation::new();
    let mut tries = 0;
    while r.len() < n && tries < n * 10 {
        tries += 1;
        let mut comps = Vec::new();
        for _ in 0..arity {
            comps.push(rng.below(12) as i64);
        }
        let k = Key::new(&comps);
        if !r.contains(&k) {
            r.insert(k, Chunk::random(shape.0, shape.1, rng, 1.0));
        }
    }
    r
}

/// Partition/gather round-trips for random worker counts and key comps.
#[test]
fn prop_partition_gather_roundtrip() {
    let mut rng = Prng::new(101);
    for case in 0..30 {
        let arity = 1 + (case % 3);
        let r = random_relation(&mut rng, 40, arity, (2, 2));
        let w = 1 + rng.below(9) as usize;
        let comp = rng.below(arity as u64) as usize;
        let p = PartitionedRelation::hash_partition(&r, &[comp], w);
        assert_eq!(p.len(), r.len(), "case {case}");
        assert!(p.gather().approx_eq(&r, 0.0), "case {case}");
        // reshuffle to another comp also preserves content
        let (p2, _) = p.reshuffle(&[arity - 1 - comp.min(arity - 1)], w);
        assert!(p2.gather().approx_eq(&r, 0.0), "case {case} reshuffle");
    }
}

/// Distributed evaluation == single-node evaluation for random blocked
/// matmuls and worker counts.
#[test]
fn prop_dist_eval_equals_single_node() {
    let mut rng = Prng::new(102);
    let q = matmul_query();
    for case in 0..10 {
        let (m, k, n) = (
            1 + rng.below(4) as i64,
            1 + rng.below(4) as i64,
            1 + rng.below(4) as i64,
        );
        let mut a = Relation::new();
        let mut b = Relation::new();
        for i in 0..m {
            for p in 0..k {
                a.insert(Key::k2(i, p), Chunk::random(3, 3, &mut rng, 1.0));
            }
        }
        for p in 0..k {
            for j in 0..n {
                b.insert(Key::k2(p, j), Chunk::random(3, 3, &mut rng, 1.0));
            }
        }
        let want = eval_query(&q, &[&a, &b], &NativeBackend).unwrap();
        let w = 1 + rng.below(6) as usize;
        let sess = Session::new(ClusterConfig::new(w));
        sess.register("A", &["row", "col"], &a).unwrap();
        sess.register("B", &["row", "col"], &b).unwrap();
        let got = sess.query(&q).unwrap().collect().unwrap();
        assert!(got.approx_eq(&want, 1e-4), "case {case} w={w}");
    }
}

/// Random unary-kernel chains: eager gradient == graph-mode gradient ==
/// finite differences.
fn random_chain_query(rng: &mut Prng, depth: usize) -> Query {
    let kernels = [
        UnaryKernel::Logistic,
        UnaryKernel::Tanh,
        UnaryKernel::Square,
        UnaryKernel::Scale(0.7),
        UnaryKernel::Neg,
    ];
    let mut qb = QueryBuilder::new();
    let mut node = qb.scan(0, "x");
    for _ in 0..depth {
        let k = kernels[rng.below(kernels.len() as u64) as usize];
        node = qb.map(k, 1, node);
    }
    let s = qb.map(UnaryKernel::SumAll, 1, node);
    let out = qb.agg(KeyProj::to_empty(), AggKernel::Sum, s);
    qb.finish(out)
}

#[test]
fn prop_random_chains_three_way_gradient_agreement() {
    let mut rng = Prng::new(103);
    for case in 0..12 {
        let q = random_chain_query(&mut rng, 1 + (case % 4));
        let x = random_relation(&mut rng, 4, 1, (2, 3));
        let (tape, eager) = grad(&q, &[&x], &NativeBackend).unwrap();
        // graph mode
        let plan = backward_graph(&q, &input_arities(&[&x]), &[0]).unwrap();
        let seed = Relation::from_pairs(vec![(Key::empty(), Chunk::scalar(1.0))]);
        let graph = eval_backward(&plan, &tape, &seed, &NativeBackend).unwrap();
        assert!(
            graph[0].1.approx_eq(eager.slot(0), 1e-4),
            "case {case}: graph vs eager"
        );
        // finite differences
        let fd = check::finite_diff_grad(&q, &[&x], 0, 1e-2, &NativeBackend).unwrap();
        check::assert_grad_close(eager.slot(0), &fd, 8e-2);
    }
}

/// Random 2-relation join losses: gradients agree with finite diff for
/// several join patterns and kernels.
#[test]
fn prop_random_join_losses_match_finite_diff() {
    let mut rng = Prng::new(104);
    let cases: Vec<(BinaryKernel, JoinPred)> = vec![
        (BinaryKernel::Mul, JoinPred::on(vec![(0, 0)])),
        (BinaryKernel::Add, JoinPred::on(vec![(0, 0)])),
        (BinaryKernel::Sub, JoinPred::on(vec![(0, 0)])),
        (BinaryKernel::Mul, JoinPred::on(vec![(0, 1)])),
    ];
    for (ci, (kernel, pred)) in cases.into_iter().enumerate() {
        let x = random_relation(&mut rng, 5, 1, (2, 2));
        let y = random_relation(&mut rng, 5, 2, (2, 2));
        let mut qb = QueryBuilder::new();
        let sx = qb.scan(0, "x");
        let sy = qb.scan(1, "y");
        let j = qb.join(
            pred,
            KeyProj2(vec![Sel2::R(0), Sel2::R(1)]),
            kernel,
            sx,
            sy,
        );
        let s = qb.map(UnaryKernel::SumAll, 2, j);
        let out = qb.agg(KeyProj::to_empty(), AggKernel::Sum, s);
        let q = qb.finish(out);
        match eval_query(&q, &[&x, &y], &NativeBackend) {
            Ok(out) if out.len() == 1 => {}
            _ => continue, // degenerate random case (empty join)
        }
        let (_, grads) = grad(&q, &[&x, &y], &NativeBackend).unwrap();
        for slot in 0..2 {
            let fd = check::finite_diff_grad(&q, &[&x, &y], slot, 1e-2, &NativeBackend).unwrap();
            check::assert_grad_close(grads.slot(slot), &fd, 8e-2);
        }
        let _ = ci;
    }
}

/// The relational partial-derivative *definition* (§3.1): perturbing a
/// single input tuple by h changes the loss by ≈ h·grad[that tuple].
#[test]
fn prop_partial_derivative_definition() {
    let mut rng = Prng::new(105);
    let q = {
        let mut qb = QueryBuilder::new();
        let s = qb.scan(0, "x");
        let sq = qb.map(UnaryKernel::Square, 1, s);
        let sa = qb.map(UnaryKernel::SumAll, 1, sq);
        let out = qb.agg(KeyProj::to_empty(), AggKernel::Sum, sa);
        qb.finish(out)
    };
    for _ in 0..8 {
        let x = random_relation(&mut rng, 6, 1, (1, 1));
        let (tape, grads) = grad(&q, &[&x], &NativeBackend).unwrap();
        let l0 = tape.output(&q).get(&Key::empty()).unwrap().as_scalar();
        // pick a tuple, perturb it
        let (k, v) = x.pairs()[rng.below(x.len() as u64) as usize].clone();
        let h = 1e-2f32;
        let mut xp = x.clone();
        for (kk, vv) in xp.iter_mut() {
            if *kk == k {
                *vv = Chunk::scalar(v.as_scalar() + h);
            }
        }
        let lp = eval_query(&q, &[&xp], &NativeBackend)
            .unwrap()
            .get(&Key::empty())
            .unwrap()
            .as_scalar();
        let g = grads.slot(0).get(&k).unwrap().as_scalar();
        assert!(
            ((lp - l0) / h - g).abs() < 0.1,
            "∂Q/∂{k}: fd {} vs grad {g}",
            (lp - l0) / h
        );
    }
}
