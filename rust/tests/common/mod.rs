//! Shared support for the integration tests. Each `tests/*.rs` file is
//! its own crate and includes this via `mod common;`; cargo does not
//! build the directory as a test target. Not every test file uses every
//! helper, hence the file-wide `dead_code` allowance.
#![allow(dead_code)]

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use relad::kernels::{BinaryKernel, KernelBackend, NativeBackend, UnaryKernel};
use relad::ra::{Chunk, Key, Relation};
use relad::util::Prng;

/// Bitwise equality: same key set, every chunk elementwise bit-identical.
pub fn bitwise_eq(a: &Relation, b: &Relation) -> bool {
    a.len() == b.len()
        && a.iter().all(|(k, v)| match b.get(k) {
            Some(w) => {
                v.shape() == w.shape()
                    && v.data()
                        .iter()
                        .zip(w.data().iter())
                        .all(|(x, y)| x.to_bits() == y.to_bits())
            }
            None => false,
        })
}

/// An n×m grid of c×c random chunks keyed ⟨i, j⟩.
pub fn blocked(n: i64, m: i64, c: usize, rng: &mut Prng) -> Relation {
    let mut r = Relation::new();
    for i in 0..n {
        for j in 0..m {
            r.insert(Key::k2(i, j), Chunk::random(c, c, rng, 1.0));
        }
    }
    r
}

/// In-place SGD: `target[k] -= lr * grad[k]` — shared so loops compared
/// bitwise use identical update arithmetic.
pub fn sgd_apply(target: &mut Relation, grel: &Relation, lr: f32) {
    for kv in target.iter_mut() {
        let (k, v) = (&kv.0, &mut kv.1);
        if let Some(g) = grel.get(k) {
            let mut d = g.clone();
            d.scale_assign(-lr);
            v.add_assign(&d);
        }
    }
}

/// A backend that counts `for_worker` mints (worker instances dispatch
/// natively, identically to the root instance) — for asserting pool
/// lifecycle guarantees.
pub struct CountingBackend {
    pub minted: Arc<AtomicUsize>,
}

impl KernelBackend for CountingBackend {
    fn unary(&self, k: &UnaryKernel, key: &Key, x: &Chunk) -> Chunk {
        relad::kernels::native::apply_unary(k, key, x)
    }

    fn binary(&self, k: &BinaryKernel, key: &Key, l: &Chunk, r: &Chunk) -> Chunk {
        relad::kernels::native::apply_binary(k, key, l, r)
    }

    fn name(&self) -> &'static str {
        "counting"
    }

    fn for_worker(&self) -> Box<dyn KernelBackend + Send + Sync> {
        self.minted.fetch_add(1, Ordering::SeqCst);
        Box::new(NativeBackend)
    }
}
