//! Wall-clock vs modeled-time trajectory of the pooled BSP executor:
//! the table2 GCN and fig2 NNMF workloads across worker counts, with
//! per-step clocks from a warm `Session` trainer (catalog partitions
//! and worker pool hot, so the measurement isolates stage execution,
//! not input scatter or backend minting).
//!
//! Every worker count is measured three times:
//!
//! * the full pooled path (`wall_s` — stage compute *and*
//!   shuffle/gather/Σ-merge sharded across the persistent worker pool),
//! * the driver-serial communication baseline (`wall_s_driver_comm`,
//!   `ClusterConfig::parallel_comm = false` — the pre-pool executor
//!   whose exchanges bound speedup at high worker counts), and
//! * the **out-of-core column** (`wall_s_spill`): the pooled path under
//!   a deliberately low per-worker budget, so over-budget join build
//!   sides grace-spill to real temp files (`spill_bytes_written`
//!   records the measured traffic per step). The gap to `wall_s` is the
//!   measured price of exceeding RAM on this host.
//!
//! Writes `BENCH_dist.json` at the repository root — the machine-readable
//! perf record. `wall_s` is real elapsed time on this host (speedup
//! saturates at the core count), `virtual_time_s` is the modeled cluster
//! time (keeps improving with workers past the core count).
//!
//! Run: `cargo bench --bench bench_dist [-- smoke]`
//! `smoke` = small shapes + {1, 2} workers, used by CI to exercise the
//! pooled and spilled paths on every push.

use relad::bench_util::{bench_json, gcn_step_clocks, nnmf_step_clocks, DistBenchPoint, StepClocks};
use relad::data::graphs::power_law_graph;
use relad::dist::DistError;
use relad::kernels::NativeBackend;
use std::path::Path;

fn run_workload(
    name: &str,
    worker_counts: &[usize],
    spill_budget: impl Fn(usize) -> u64,
    mut step: impl FnMut(usize, bool, Option<u64>) -> Result<StepClocks, DistError>,
) -> (String, Vec<DistBenchPoint>) {
    let mut points = Vec::new();
    let mut base_wall = None;
    println!("\n== {name} ==");
    println!(
        "{:>8} {:>12} {:>16} {:>12} {:>14} {:>16} {:>9} {:>9}",
        "workers",
        "wall_s",
        "wall_driver_comm",
        "wall_spill",
        "spill_B/step",
        "virtual_time_s",
        "speedup",
        "comm_win"
    );
    for &w in worker_counts {
        // Lazily: if the pooled run fails (OOM at a high worker count),
        // skip the equally expensive other measurements for this row.
        let all = step(w, true, None).and_then(|p| {
            let d = step(w, false, None)?;
            let s = step(w, true, Some(spill_budget(w)))?;
            Ok((p, d, s))
        });
        match all {
            Ok((pooled, driver, spilled)) => {
                let base = *base_wall.get_or_insert(pooled.wall_s);
                let speedup = if pooled.wall_s > 0.0 {
                    base / pooled.wall_s
                } else {
                    1.0
                };
                let comm_win = if pooled.wall_s > 0.0 {
                    driver.wall_s / pooled.wall_s
                } else {
                    1.0
                };
                println!(
                    "{w:>8} {:>12.4} {:>16.4} {:>12.4} {:>14} {:>16.4} {speedup:>8.2}x {comm_win:>8.2}x",
                    pooled.wall_s,
                    driver.wall_s,
                    spilled.wall_s,
                    spilled.spill_bytes_written,
                    pooled.virtual_time_s,
                );
                if spilled.spill_bytes_written == 0 {
                    println!(
                        "{w:>8} note: spill budget {} B did not force spill",
                        spill_budget(w)
                    );
                }
                points.push(DistBenchPoint {
                    workers: w,
                    wall_s: pooled.wall_s,
                    wall_s_driver_comm: driver.wall_s,
                    wall_s_spill: spilled.wall_s,
                    spill_bytes_written: spilled.spill_bytes_written,
                    virtual_time_s: pooled.virtual_time_s,
                    speedup,
                });
            }
            Err(e) => println!("{w:>8} ERR({e})"),
        }
    }
    (name.to_string(), points)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "smoke");
    let host_cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    // Smoke: tiny shapes, 2 workers max — a CI-speed exercise of the
    // pooled path. Full: e2e-scale shapes, up to 8 workers.
    let (worker_counts, steps): (Vec<usize>, usize) = if smoke {
        (vec![1, 2], 3)
    } else {
        (vec![1, 2, 4, 8], 3)
    };
    println!(
        "bench_dist: mode={} host_cores={host_cores} workers={worker_counts:?}",
        if smoke { "smoke" } else { "full" }
    );

    let g = if smoke {
        power_law_graph("bench", 400, 1600, 32, 8, 0.4, 11)
    } else {
        power_law_graph("bench", 4000, 22_000, 64, 40, 0.3, 11)
    };
    let hidden = if smoke { 32 } else { 64 };
    // Low-memory column: budget each worker at a fraction of its share
    // of the graph payload so the heavier joins must grace-spill, while
    // pass counts stay low enough to bench (the budget still bounds the
    // resident build side, not correctness — results are bitwise
    // identical either way, per tests/spill.rs).
    let graph_bytes = (g.edges.nbytes() + g.feats.nbytes() + g.labels.nbytes()) as u64;
    let gcn_budget = move |w: usize| (graph_bytes / (4 * w as u64)).max(1024);
    let gcn = run_workload("table2_gcn", &worker_counts, gcn_budget, |w, comm, budget| {
        gcn_step_clocks(&g, hidden, w, steps, comm, budget, &NativeBackend)
    });

    let (n, d, chunk) = if smoke { (128, 64, 32) } else { (512, 128, 32) };
    let v_bytes = (n * n * std::mem::size_of::<f32>()) as u64;
    let nnmf_budget = move |w: usize| (v_bytes / (4 * w as u64)).max(1024);
    let nnmf = run_workload("fig2_nnmf", &worker_counts, nnmf_budget, |w, comm, budget| {
        nnmf_step_clocks(n, d, chunk, w, steps, comm, budget, &NativeBackend)
    });

    let json = bench_json(
        if smoke { "smoke" } else { "full" },
        host_cores,
        &[gcn, nnmf],
    );
    // CARGO_MANIFEST_DIR = rust/; the trajectory file lives at the repo
    // root next to ROADMAP.md.
    let out = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_dist.json"))
        .unwrap_or_else(|| Path::new("BENCH_dist.json").to_path_buf());
    match std::fs::write(&out, &json) {
        Ok(()) => println!("\nwrote {}", out.display()),
        Err(e) => {
            println!("\ncould not write {}: {e}; dumping to stdout\n{json}", out.display());
        }
    }
}
