//! Wall-clock vs modeled-time trajectory of the pooled BSP executor:
//! the table2 GCN and fig2 NNMF workloads across worker counts, with
//! per-step clocks from a warm `Session` trainer (catalog partitions
//! and worker pool hot, so the measurement isolates stage execution,
//! not input scatter or backend minting).
//!
//! Every worker count is measured four times:
//!
//! * the full pooled path with factorized evaluation *off* (`wall_s` —
//!   the materialized baseline; stage compute *and*
//!   shuffle/gather/Σ-merge sharded across the persistent worker pool),
//! * the same step with factorized evaluation *on*
//!   (`wall_s_factorized`, the session default): Σ-below-⋈ pushdown
//!   where legal plus partition-aware shuffle elision —
//!   `bytes_shuffled_factorized` vs `bytes_shuffled` records the
//!   traffic the rewrite removed, `shuffles_elided` counts memo hits,
//! * the driver-serial communication baseline (`wall_s_driver_comm`,
//!   `ClusterConfig::parallel_comm = false` — the pre-pool executor
//!   whose exchanges bound speedup at high worker counts), and
//! * the **out-of-core column** (`wall_s_spill`): the pooled path under
//!   a deliberately low per-worker budget, so over-budget join build
//!   sides grace-spill to real temp files (`spill_bytes_written`
//!   records the measured traffic per step). The gap to `wall_s` is the
//!   measured price of exceeding RAM on this host, and
//! * the **faulty column** (`wall_s_faulty`): the pooled path under the
//!   standard scripted fault plan (`bench_util::bench_fault_plan` — one
//!   transient error and one injected worker panic per execution), every
//!   fault recovered by stage retry with lineage replay. The smoke run
//!   asserts the faulted loop's losses are bit-identical to the clean
//!   loop's and that retries actually fired; the gap to `wall_s` is the
//!   measured recovery cost.
//!
//! A separate **streaming-update workload** (`delta_update`) measures
//! the incremental engine: a memoized frame replaying 1%-sized insert
//! batches (`wall_s_delta`) against a fresh frame recomputing the same
//! merged catalog every round (`wall_s_recompute`), bitwise-compared
//! each round. The smoke run asserts the delta path is strictly faster,
//! bitwise identical, and actually reused shards at w = 2.
//!
//! A **skew workload** (`zipf_skew`) measures the skew-aware planner: a
//! Zipf(1.1)-keyed join + Σ executed twice per worker count — once with
//! hot-key detection off (`wall_s_oblivious`, hash partitioning sends
//! every hot row to one straggler shard) and once with the ingest
//! sampler on (`wall_s_skew`, the planner picks a salted or replicated
//! strategy for the annotated keys). Both runs are bitwise-compared
//! per shard and gathered; `max_shard_bytes_*` records the straggler
//! load the skew plan removed. The smoke run asserts the skew plan
//! fired at w = 2, stayed bitwise, and strictly shrank the hot shard.
//!
//! A **serving workload** (`serve_throughput`) measures the PR 9
//! serving layer: 4 concurrent `serve::Client` threads replaying a
//! three-statement mix against one shared engine — cold per-query wall
//! (cache empty, real BSP execution) vs warm per-query wall (every
//! repeat a result-cache hit). The smoke run asserts warm is strictly
//! faster than cold, the cache actually served hits, and admission
//! never exceeded the configured in-flight cap.
//!
//! Writes `BENCH_dist.json` at the repository root — the machine-readable
//! perf record. `wall_s` is real elapsed time on this host (speedup
//! saturates at the core count), `virtual_time_s` is the modeled cluster
//! time (keeps improving with workers past the core count).
//!
//! Run: `cargo bench --bench bench_dist [-- smoke]`
//! `smoke` = small shapes + {1, 2} workers, used by CI to exercise the
//! pooled and spilled paths on every push.

use relad::bench_util::{
    bench_fault_plan, bench_json, delta_update_clocks, gcn_step_clocks, gcn_step_clocks_faulted,
    nnmf_step_clocks, serve_throughput_clocks, zipf_skew_clocks, DistBenchPoint, StepClocks,
};
use relad::data::graphs::power_law_graph;
use relad::dist::DistError;
use relad::kernels::NativeBackend;
use std::path::Path;

fn run_workload(
    name: &str,
    worker_counts: &[usize],
    spill_budget: impl Fn(usize) -> u64,
    mut step: impl FnMut(usize, bool, Option<u64>, bool, bool) -> Result<StepClocks, DistError>,
) -> (String, Vec<DistBenchPoint>) {
    let mut points = Vec::new();
    let mut base_wall = None;
    println!("\n== {name} ==");
    println!(
        "{:>8} {:>12} {:>12} {:>16} {:>12} {:>14} {:>12} {:>12} {:>12} {:>8} {:>16} {:>9} {:>9}",
        "workers",
        "wall_s",
        "wall_fact",
        "wall_driver_comm",
        "wall_spill",
        "spill_B/step",
        "wall_faulty",
        "shuffle_B",
        "shuffle_B_f",
        "elided",
        "virtual_time_s",
        "speedup",
        "comm_win"
    );
    for &w in worker_counts {
        // Lazily: if the materialized pooled run fails (OOM at a high
        // worker count), skip the equally expensive other measurements
        // for this row. `step(w, comm, budget, factorize, faulty)`.
        let all = step(w, true, None, false, false).and_then(|p| {
            let f = step(w, true, None, true, false)?;
            let d = step(w, false, None, false, false)?;
            let s = step(w, true, Some(spill_budget(w)), false, false)?;
            let y = step(w, true, None, false, true)?;
            Ok((p, f, d, s, y))
        });
        match all {
            Ok((pooled, fact, driver, spilled, faulty)) => {
                let base = *base_wall.get_or_insert(pooled.wall_s);
                let speedup = if pooled.wall_s > 0.0 {
                    base / pooled.wall_s
                } else {
                    1.0
                };
                let comm_win = if pooled.wall_s > 0.0 {
                    driver.wall_s / pooled.wall_s
                } else {
                    1.0
                };
                println!(
                    "{w:>8} {:>12.4} {:>12.4} {:>16.4} {:>12.4} {:>14} {:>12.4} {:>12} {:>12} {:>8} {:>16.4} {speedup:>8.2}x {comm_win:>8.2}x",
                    pooled.wall_s,
                    fact.wall_s,
                    driver.wall_s,
                    spilled.wall_s,
                    spilled.spill_bytes_written,
                    faulty.wall_s,
                    pooled.bytes_shuffled,
                    fact.bytes_shuffled,
                    fact.shuffles_elided,
                    pooled.virtual_time_s,
                );
                if spilled.spill_bytes_written == 0 {
                    println!(
                        "{w:>8} note: spill budget {} B did not force spill",
                        spill_budget(w)
                    );
                }
                points.push(DistBenchPoint {
                    workers: w,
                    wall_s: pooled.wall_s,
                    wall_s_driver_comm: driver.wall_s,
                    wall_s_spill: spilled.wall_s,
                    spill_bytes_written: spilled.spill_bytes_written,
                    wall_s_factorized: fact.wall_s,
                    wall_s_faulty: faulty.wall_s,
                    bytes_shuffled: pooled.bytes_shuffled,
                    bytes_shuffled_factorized: fact.bytes_shuffled,
                    shuffles_elided: fact.shuffles_elided,
                    virtual_time_s: pooled.virtual_time_s,
                    speedup,
                });
            }
            Err(e) => println!("{w:>8} ERR({e})"),
        }
    }
    (name.to_string(), points)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "smoke");
    let host_cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    // Smoke: tiny shapes, 2 workers max — a CI-speed exercise of the
    // pooled path. Full: e2e-scale shapes, up to 8 workers.
    let (worker_counts, steps): (Vec<usize>, usize) = if smoke {
        (vec![1, 2], 3)
    } else {
        (vec![1, 2, 4, 8], 3)
    };
    println!(
        "bench_dist: mode={} host_cores={host_cores} workers={worker_counts:?}",
        if smoke { "smoke" } else { "full" }
    );

    // Smoke shape is sized so shuffle elision *fires*: the planner only
    // reshuffles the shared Edge scan (instead of broadcasting the
    // node-feature side) when the feature payload is wide enough, and
    // the elision memo only pays off when two joins reshuffle the same
    // scan the same way — 1000 nodes × 64-wide features over 3000 edges
    // crosses that threshold at 2 workers; the CI assertion below
    // depends on it.
    let g = if smoke {
        power_law_graph("bench", 1000, 3000, 64, 64, 0.4, 11)
    } else {
        power_law_graph("bench", 4000, 22_000, 64, 40, 0.3, 11)
    };
    let hidden = 64;
    // Low-memory column: budget each worker at a fraction of its share
    // of the graph payload so the heavier joins must grace-spill, while
    // pass counts stay low enough to bench (the budget still bounds the
    // resident build side, not correctness — results are bitwise
    // identical either way, per tests/spill.rs).
    let graph_bytes = (g.edges.nbytes() + g.feats.nbytes() + g.labels.nbytes()) as u64;
    let gcn_budget = move |w: usize| (graph_bytes / (4 * w as u64)).max(1024);
    let gcn = run_workload(
        "table2_gcn",
        &worker_counts,
        gcn_budget,
        |w, comm, budget, fact, faulty| {
            if faulty {
                gcn_step_clocks_faulted(
                    &g,
                    hidden,
                    w,
                    steps,
                    comm,
                    budget,
                    fact,
                    Some(bench_fault_plan()),
                    &NativeBackend,
                )
                .map(|f| f.clocks)
            } else {
                gcn_step_clocks(&g, hidden, w, steps, comm, budget, fact, &NativeBackend)
            }
        },
    );

    // CI smoke assertion: factorized evaluation must actually fire on
    // the GCN workload at w ≥ 2 — at least one shuffle served from the
    // elision memo, and strictly less traffic than materialized. A
    // silent regression here (planner flips to broadcast, memo key
    // drifts) would leave the headline delta quietly at zero.
    if smoke {
        let multi: Vec<_> = gcn.1.iter().filter(|p| p.workers >= 2).collect();
        let fired = !multi.is_empty()
            && multi.iter().all(|p| {
                p.shuffles_elided > 0 && p.bytes_shuffled_factorized < p.bytes_shuffled
            });
        if !fired {
            for p in &gcn.1 {
                eprintln!(
                    "w={}: bytes_shuffled={} factorized={} elided={}",
                    p.workers, p.bytes_shuffled, p.bytes_shuffled_factorized, p.shuffles_elided
                );
            }
            eprintln!("FAIL: factorized evaluation did not fire on the GCN smoke workload");
            std::process::exit(1);
        }
        println!("smoke: factorized plan fired on GCN (elided shuffles, lower traffic)");
    }

    // CI smoke assertion: the faulty-but-retried GCN loop must exit
    // zero with nonzero stage retries and a loss trajectory bit-equal
    // to the clean loop — the fault-tolerance headline, checked on
    // every push with real pooled execution.
    if smoke {
        let w = *worker_counts.last().unwrap();
        let clean = gcn_step_clocks_faulted(
            &g, hidden, w, steps, true, None, false, None, &NativeBackend,
        );
        let faulted = gcn_step_clocks_faulted(
            &g,
            hidden,
            w,
            steps,
            true,
            None,
            false,
            Some(bench_fault_plan()),
            &NativeBackend,
        );
        match (clean, faulted) {
            (Ok(c), Ok(f)) => {
                if f.stage_retries == 0 {
                    eprintln!("FAIL: fault plan injected nothing (stage_retries = 0)");
                    std::process::exit(1);
                }
                if c.loss_bits != f.loss_bits {
                    eprintln!(
                        "FAIL: faulted GCN losses diverged from clean: {:?} vs {:?}",
                        f.loss_bits, c.loss_bits
                    );
                    std::process::exit(1);
                }
                println!(
                    "smoke: faulted GCN recovered bitwise ({} fault(s), {} retr{})",
                    f.faults_injected,
                    f.stage_retries,
                    if f.stage_retries == 1 { "y" } else { "ies" }
                );
            }
            (c, f) => {
                eprintln!("FAIL: fault smoke errored: clean={c:?} faulted={f:?}");
                std::process::exit(1);
            }
        }
    }

    let (n, d, chunk) = if smoke { (128, 64, 32) } else { (512, 128, 32) };
    let v_bytes = (n * n * std::mem::size_of::<f32>()) as u64;
    let nnmf_budget = move |w: usize| (v_bytes / (4 * w as u64)).max(1024);
    let nnmf = run_workload(
        "fig2_nnmf",
        &worker_counts,
        nnmf_budget,
        |w, comm, budget, fact, faulty| {
            if faulty {
                relad::bench_util::nnmf_step_clocks_faulted(
                    n,
                    d,
                    chunk,
                    w,
                    steps,
                    comm,
                    budget,
                    fact,
                    Some(bench_fault_plan()),
                    &NativeBackend,
                )
                .map(|f| f.clocks)
            } else {
                nnmf_step_clocks(n, d, chunk, w, steps, comm, budget, fact, &NativeBackend)
            }
        },
    );

    // Streaming-update column: Σ over a co-partitioned ⋈ taking 1%
    // insert batches — one memoized frame replaying each batch through
    // the incremental engine (`wall_s_delta`) vs a fresh frame over the
    // same merged catalog every round (`wall_s_recompute`). Both paths
    // are bitwise compared every round.
    let (delta_n, delta_rounds) = if smoke { (20_000i64, 3) } else { (200_000i64, 3) };
    let mut delta_points = Vec::new();
    println!("\n== delta_update (1% insert batches) ==");
    println!(
        "{:>8} {:>14} {:>18} {:>12} {:>13} {:>8}",
        "workers", "wall_s_delta", "wall_s_recompute", "rows/round", "shards_reused", "bitwise"
    );
    for &w in &worker_counts {
        match delta_update_clocks(delta_n, 64, 2, 0.01, delta_rounds, w) {
            Ok(p) => {
                println!(
                    "{:>8} {:>14.6} {:>18.6} {:>12} {:>13} {:>8}",
                    p.workers,
                    p.wall_s_delta,
                    p.wall_s_recompute,
                    p.delta_rows_per_round,
                    p.shards_reused,
                    p.bitwise
                );
                delta_points.push(p);
            }
            Err(e) => println!("{w:>8} ERR({e})"),
        }
    }

    // CI smoke assertion: at w = 2 the delta path must be strictly
    // faster than full recompute, bitwise identical to it, and must
    // have actually served shards from the previous tape — a silent
    // regression (gate refusing the shape, replay recomputing) would
    // flatten the headline win to zero without failing any result
    // comparison.
    if smoke {
        let ok = delta_points
            .iter()
            .find(|p| p.workers == 2)
            .map(|p| p.bitwise && p.shards_reused > 0 && p.wall_s_delta < p.wall_s_recompute);
        match ok {
            Some(true) => println!(
                "smoke: delta path beat recompute bitwise at w=2 (reused shards, lower wall)"
            ),
            _ => {
                for p in &delta_points {
                    eprintln!(
                        "w={}: wall_s_delta={:.6} wall_s_recompute={:.6} shards_reused={} bitwise={}",
                        p.workers, p.wall_s_delta, p.wall_s_recompute, p.shards_reused, p.bitwise
                    );
                }
                eprintln!("FAIL: delta path not strictly faster + bitwise at w=2");
                std::process::exit(1);
            }
        }
    }

    // Serving column: concurrent clients over one shared engine, cold
    // (execute + fill cache) vs warm (all result-cache hits).
    let (serve_n, serve_clients, serve_repeats) =
        if smoke { (8_000i64, 4, 16) } else { (80_000i64, 4, 64) };
    let mut serve_points = Vec::new();
    println!("\n== serve_throughput ({serve_clients} concurrent clients) ==");
    println!(
        "{:>8} {:>8} {:>14} {:>14} {:>11} {:>13} {:>12}",
        "workers",
        "clients",
        "wall_s_cold/q",
        "wall_s_warm/q",
        "cache_hits",
        "max_inflight",
        "queries/s"
    );
    for &w in &worker_counts {
        match serve_throughput_clocks(serve_n, 64, 2, w, serve_clients, serve_repeats) {
            Ok(p) => {
                println!(
                    "{:>8} {:>8} {:>14.6} {:>14.6} {:>11} {:>13} {:>12.1}",
                    p.workers,
                    p.clients,
                    p.wall_s_cold,
                    p.wall_s_warm,
                    p.cache_hits,
                    p.max_inflight_seen,
                    p.queries_per_s
                );
                serve_points.push(p);
            }
            Err(e) => println!("{w:>8} ERR({e})"),
        }
    }

    // CI smoke assertion: at w = 2 the warm (cached) pass must be
    // strictly faster per query than the cold pass, the result cache
    // must have actually served the repeats, and the admission probe
    // must respect the in-flight cap — a silent regression in any of
    // the three would leave the serving headline hollow.
    if smoke {
        let ok = serve_points.iter().find(|p| p.workers == 2).map(|p| {
            p.cache_hits > 0
                && p.wall_s_warm < p.wall_s_cold
                && p.max_inflight_seen <= relad::serve::ServeConfig::default().max_inflight
        });
        match ok {
            Some(true) => println!(
                "smoke: cached repeats beat cold execution at w=2 (hits served, cap held)"
            ),
            _ => {
                for p in &serve_points {
                    eprintln!(
                        "w={}: wall_s_cold={:.6} wall_s_warm={:.6} cache_hits={} max_inflight_seen={}",
                        p.workers, p.wall_s_cold, p.wall_s_warm, p.cache_hits, p.max_inflight_seen
                    );
                }
                eprintln!("FAIL: serving cache not strictly faster (or cap exceeded) at w=2");
                std::process::exit(1);
            }
        }
    }

    // Skew column: the same Zipf-keyed Σ-over-⋈ executed oblivious
    // (hash placement piles the head keys onto one straggler) and
    // skew-aware (ingest sampler annotates the head; the planner salts
    // or replicates it). Both runs bitwise-compared per shard and
    // gathered inside `zipf_skew_clocks`.
    let (skew_n, skew_rounds) = if smoke { (6_000i64, 3) } else { (60_000i64, 3) };
    let mut skew_points = Vec::new();
    println!("\n== zipf_skew (Zipf(1.1) join keys, threshold 0.05) ==");
    println!(
        "{:>8} {:>16} {:>12} {:>9} {:>11} {:>12} {:>14} {:>13} {:>7} {:>8}",
        "workers",
        "wall_s_oblivious",
        "wall_s_skew",
        "hot_keys",
        "rows_salted",
        "hot_repl_B",
        "max_shard_obl",
        "max_shard_skw",
        "fired",
        "bitwise"
    );
    for &w in &worker_counts {
        match zipf_skew_clocks(skew_n, 64, 2, 1.1, 0.05, w, skew_rounds) {
            Ok(p) => {
                println!(
                    "{:>8} {:>16.6} {:>12.6} {:>9} {:>11} {:>12} {:>14} {:>13} {:>7} {:>8}",
                    p.workers,
                    p.wall_s_oblivious,
                    p.wall_s_skew,
                    p.hot_keys_detected,
                    p.rows_salted,
                    p.bytes_hot_replicated,
                    p.max_shard_bytes_oblivious,
                    p.max_shard_bytes_skew,
                    p.skew_fired,
                    p.bitwise
                );
                skew_points.push(p);
            }
            Err(e) => println!("{w:>8} ERR({e})"),
        }
    }

    // CI smoke assertion: at w = 2 the skew plan must actually fire on
    // the Zipf workload, stay bitwise identical to the oblivious run,
    // pay a nonzero replica cost, and strictly shrink the straggler
    // shard — a silent regression (sampler misses the head, planner
    // never picks a skew strategy, merge reorders rows) would hollow
    // out the skew headline without failing any other suite.
    if smoke {
        let ok = skew_points.iter().find(|p| p.workers == 2).map(|p| {
            p.bitwise
                && p.skew_fired
                && p.hot_keys_detected > 0
                && p.bytes_hot_replicated > 0
                && p.max_shard_bytes_skew < p.max_shard_bytes_oblivious
        });
        match ok {
            Some(true) => println!(
                "smoke: skew plan fired bitwise at w=2 (hot shard strictly smaller)"
            ),
            _ => {
                for p in &skew_points {
                    eprintln!(
                        "w={}: fired={} bitwise={} hot_keys={} hot_repl_B={} max_shard obl={} skew={}",
                        p.workers,
                        p.skew_fired,
                        p.bitwise,
                        p.hot_keys_detected,
                        p.bytes_hot_replicated,
                        p.max_shard_bytes_oblivious,
                        p.max_shard_bytes_skew
                    );
                }
                eprintln!("FAIL: skew plan not bitwise + strictly load-shrinking at w=2");
                std::process::exit(1);
            }
        }
    }

    let json = bench_json(
        if smoke { "smoke" } else { "full" },
        host_cores,
        &[gcn, nnmf],
        &delta_points,
        &serve_points,
        &skew_points,
    );
    // CARGO_MANIFEST_DIR = rust/; the trajectory file lives at the repo
    // root next to ROADMAP.md.
    let out = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_dist.json"))
        .unwrap_or_else(|| Path::new("BENCH_dist.json").to_path_buf());
    match std::fs::write(&out, &json) {
        Ok(()) => println!("\nwrote {}", out.display()),
        Err(e) => {
            println!("\ncould not write {}: {e}; dumping to stdout\n{json}", out.display());
        }
    }
}
