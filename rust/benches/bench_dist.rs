//! Wall-clock vs modeled-time trajectory of the pooled BSP executor:
//! the table2 GCN and fig2 NNMF workloads across worker counts, with
//! per-step clocks from a warm `Session` trainer (catalog partitions
//! and worker pool hot, so the measurement isolates stage execution,
//! not input scatter or backend minting).
//!
//! Every worker count is measured twice: the full pooled path
//! (`wall_s` — stage compute *and* shuffle/gather/Σ-merge sharded
//! across the persistent worker pool) and the driver-serial
//! communication baseline (`wall_s_driver_comm`,
//! `ClusterConfig::parallel_comm = false` — the pre-pool executor whose
//! exchanges bound speedup at high worker counts). The gap between the
//! two columns is the parallel-communication win this bench tracks
//! PR over PR.
//!
//! Writes `BENCH_dist.json` at the repository root — the machine-readable
//! perf record. `wall_s` is real elapsed time on this host (speedup
//! saturates at the core count), `virtual_time_s` is the modeled cluster
//! time (keeps improving with workers past the core count).
//!
//! Run: `cargo bench --bench bench_dist [-- smoke]`
//! `smoke` = small shapes + {1, 2} workers, used by CI to exercise the
//! pooled path on every push.

use relad::bench_util::{bench_json, gcn_step_clocks, nnmf_step_clocks, DistBenchPoint};
use relad::data::graphs::power_law_graph;
use relad::dist::DistError;
use relad::kernels::NativeBackend;
use std::path::Path;

fn run_workload(
    name: &str,
    worker_counts: &[usize],
    mut step: impl FnMut(usize, bool) -> Result<(f64, f64), DistError>,
) -> (String, Vec<DistBenchPoint>) {
    let mut points = Vec::new();
    let mut base_wall = None;
    println!("\n== {name} ==");
    println!(
        "{:>8} {:>12} {:>16} {:>16} {:>9} {:>9}",
        "workers", "wall_s", "wall_driver_comm", "virtual_time_s", "speedup", "comm_win"
    );
    for &w in worker_counts {
        // Lazily: if the pooled run fails (OOM at a high worker count),
        // skip the equally expensive driver-comm measurement for this row.
        let pooled = step(w, true);
        let both = pooled.and_then(|p| step(w, false).map(|d| (p, d)));
        match both {
            Ok(((wall_s, virtual_time_s), (wall_s_driver_comm, _))) => {
                let base = *base_wall.get_or_insert(wall_s);
                let speedup = if wall_s > 0.0 { base / wall_s } else { 1.0 };
                let comm_win = if wall_s > 0.0 {
                    wall_s_driver_comm / wall_s
                } else {
                    1.0
                };
                println!(
                    "{w:>8} {wall_s:>12.4} {wall_s_driver_comm:>16.4} {virtual_time_s:>16.4} {speedup:>8.2}x {comm_win:>8.2}x"
                );
                points.push(DistBenchPoint {
                    workers: w,
                    wall_s,
                    wall_s_driver_comm,
                    virtual_time_s,
                    speedup,
                });
            }
            Err(e) => println!("{w:>8} ERR({e})"),
        }
    }
    (name.to_string(), points)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "smoke");
    let host_cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    // Smoke: tiny shapes, 2 workers max — a CI-speed exercise of the
    // pooled path. Full: e2e-scale shapes, up to 8 workers.
    let (worker_counts, steps): (Vec<usize>, usize) = if smoke {
        (vec![1, 2], 3)
    } else {
        (vec![1, 2, 4, 8], 3)
    };
    println!(
        "bench_dist: mode={} host_cores={host_cores} workers={worker_counts:?}",
        if smoke { "smoke" } else { "full" }
    );

    let g = if smoke {
        power_law_graph("bench", 400, 1600, 32, 8, 0.4, 11)
    } else {
        power_law_graph("bench", 4000, 22_000, 64, 40, 0.3, 11)
    };
    let hidden = if smoke { 32 } else { 64 };
    let gcn = run_workload("table2_gcn", &worker_counts, |w, comm| {
        gcn_step_clocks(&g, hidden, w, steps, comm, &NativeBackend)
    });

    let (n, d, chunk) = if smoke { (128, 64, 32) } else { (512, 128, 32) };
    let nnmf = run_workload("fig2_nnmf", &worker_counts, |w, comm| {
        nnmf_step_clocks(n, d, chunk, w, steps, comm, &NativeBackend)
    });

    let json = bench_json(
        if smoke { "smoke" } else { "full" },
        host_cores,
        &[gcn, nnmf],
    );
    // CARGO_MANIFEST_DIR = rust/; the trajectory file lives at the repo
    // root next to ROADMAP.md.
    let out = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_dist.json"))
        .unwrap_or_else(|| Path::new("BENCH_dist.json").to_path_buf());
    match std::fs::write(&out, &json) {
        Ok(()) => println!("\nwrote {}", out.display()),
        Err(e) => {
            println!("\ncould not write {}: {e}; dumping to stdout\n{json}", out.display());
        }
    }
}
