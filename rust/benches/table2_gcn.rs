//! Table 2 reproduction: distributed GCN per-epoch time on the scaled
//! ogbn-arxiv and ogbn-products datasets, cluster sizes 1–16, systems
//! {DistDGL, AliGraph, RA-GCN (mini-batch), RA-GCN (full graph)}.
//!
//! Expected shape (paper): on these *small* datasets the custom systems
//! beat RA-GCN (DistDGL fastest), AliGraph is the slowest runnable
//! system, RA-GCN full ≈ 2× RA-GCN mini-batch, and everything scales
//! down with cluster size. Absolute numbers differ from the paper (this
//! substrate is a virtual cluster at 1/24–1/96 data scale).

use relad::baselines::distdgl::GnnBaselineCfg;
use relad::baselines::{aligraph, distdgl};
use relad::bench_util::{bcell, cell, print_header, print_row, ra_gcn_epoch};
use relad::data::{scaled_dataset, GraphScale};
use relad::dist::NetModel;
use relad::kernels::NativeBackend;

fn main() {
    let workers = [1usize, 2, 4, 8, 16];
    for scale in [GraphScale::Arxiv, GraphScale::Products] {
        let g = scaled_dataset(scale, 7);
        let budget = scale.scaled_budget();
        print_header(
            &format!(
                "Table 2: {} |V|={} |E|={} budget/worker={}MB",
                g.name,
                g.n_nodes,
                g.n_edges,
                budget >> 20
            ),
            &workers,
        );
        let batch = 1024 / 24; // the paper's B=1024 at dataset scale

        let mut row = Vec::new();
        for &w in &workers {
            let cfg = GnnBaselineCfg {
                workers: w,
                budget,
                batch,
                hidden: 64,
                fanout: (10, 25),
                net: NetModel::default(),
            };
            row.push(bcell(&distdgl::epoch_time(&g, &cfg)));
        }
        print_row("DistDGL", &row);

        let mut row = Vec::new();
        for &w in &workers {
            let cfg = GnnBaselineCfg {
                workers: w,
                budget,
                batch,
                hidden: 64,
                fanout: (10, 25),
                net: NetModel::default(),
            };
            row.push(bcell(&aligraph::epoch_time(&g, &cfg)));
        }
        print_row("AliGraph", &row);

        let mut row = Vec::new();
        for &w in &workers {
            row.push(cell(&ra_gcn_epoch(
                &g,
                w,
                Some(budget),
                Some(batch),
                &NativeBackend,
            )));
        }
        print_row("RA-GCN", &row);

        let mut row = Vec::new();
        for &w in &workers {
            row.push(cell(&ra_gcn_epoch(&g, w, Some(budget), None, &NativeBackend)));
        }
        print_row("RA-GCN(full)", &row);
    }
}
