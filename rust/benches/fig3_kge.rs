//! Figure 3 reproduction: knowledge-graph-embedding training time for
//! 100 iterations on the scaled Freebase, TransE-L2 and TransR,
//! D ∈ {50,100,200}, cluster sizes {4,8,16}, systems {RA-KGE, DGL-KE}.
//!
//! Expected shape (paper): DGL-KE is faster at small D but OOMs as D
//! grows (replicated embedding store); RA-KGE runs every configuration
//! and scales with cluster size; TransR costs a multiple of TransE.
//! Freebase is scaled 1/512 with batch 1K→128, negatives 200→32
//! (documented).

use relad::baselines::dglke::{self, DglkeCfg};
use relad::bench_util::{bcell, print_header, print_row};
use relad::data::KgDataset;
use relad::dist::{ClusterConfig, MemPolicy, NetModel, PartitionedRelation};
use relad::kernels::NativeBackend;
use relad::ml::kge::{self, KgeConfig, KgeVariant};
use relad::ml::DistTrainer;
use relad::util::Prng;

const N_ENTITIES: usize = 168_000 / 16; // 86M/512 further /16 for bench time
const N_TRIPLES: usize = 60_000;
const N_RELS: usize = 29;
const BATCH: usize = 128;
const N_NEG: usize = 32;

fn ra_kge_100iters(
    kg: &KgDataset,
    variant: KgeVariant,
    dim: usize,
    workers: usize,
    budget: u64,
) -> String {
    let cfg = KgeConfig {
        variant,
        dim,
        margin: 1.0,
    };
    let mut rng = Prng::new(31);
    let tables = kge::init_tables(&cfg, kg.n_entities, kg.n_relations, &mut rng);
    let (pos, negs) = kg.sample_batch(BATCH, N_NEG, &mut rng);
    let (rp, rn) = kge::batch_relations(&pos, &negs);
    let q = kge::loss_query(&cfg, rp, rn, BATCH * N_NEG);
    let slots: Vec<usize> = (0..tables.len()).collect();
    let arities = vec![1; tables.len()];
    let trainer = match DistTrainer::new(q, &arities, &slots) {
        Ok(t) => t,
        Err(e) => return format!("ERR({e})"),
    };
    let ccfg = ClusterConfig::new(workers)
        .with_budget(budget)
        .with_policy(MemPolicy::Spill);
    let inputs: Vec<PartitionedRelation> = tables
        .iter()
        .map(|t| PartitionedRelation::hash_full(t, workers))
        .collect();
    // Legacy positional one-shot step (sweeps worker counts past the
    // host's cores with per-call layouts); see the `session` module
    // migration note for the supported path.
    #[allow(deprecated)]
    let res = trainer.step(&inputs, &ccfg, &NativeBackend);
    match res {
        Ok(r) => format!("{:.3}s", r.stats.virtual_time_s * 100.0),
        Err(e) => format!("ERR({e})"),
    }
}

fn main() {
    let workers = [4usize, 8, 16];
    let kg = KgDataset::freebase_scaled(N_ENTITIES, N_TRIPLES, N_RELS, 13);
    // 64 GB scaled by the entity-count factor (86M / N_ENTITIES).
    let budget = (64u64 << 30) / (86_000_000 / N_ENTITIES as u64);
    println!(
        "Freebase scaled: {} entities, {} train triples, {} relations, budget/worker={}MB",
        kg.n_entities,
        kg.train.len(),
        kg.n_relations,
        budget >> 20
    );
    for variant in [KgeVariant::TransE, KgeVariant::TransR] {
        for dim in [50usize, 100, 200] {
            print_header(
                &format!("Figure 3: {variant:?} D={dim}, 100 iterations"),
                &workers,
            );
            let mut row = Vec::new();
            for &w in &workers {
                row.push(ra_kge_100iters(&kg, variant, dim, w, budget));
            }
            print_row("RA-KGE", &row);

            let mut row = Vec::new();
            for &w in &workers {
                let cfg = DglkeCfg {
                    workers: w,
                    budget,
                    dim,
                    variant,
                    batch: BATCH,
                    n_neg: N_NEG,
                    net: NetModel::default(),
                };
                row.push(bcell(&dglke::time_100_iters(&kg, &cfg)));
            }
            print_row("DGL-KE", &row);
        }
    }
}
