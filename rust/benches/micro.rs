//! Micro-benchmarks + the §Perf measurement harness:
//!   * chunk-kernel throughput, native vs XLA-artifact backends,
//!   * hash-join / aggregation tuple throughput,
//!   * autodiff overhead: eager backward vs forward, graph-build cost,
//!   * spill-path overhead vs in-memory.

use relad::autodiff::{backward_graph, eval_backward, grad_wrt};
use relad::kernels::{BinaryKernel, KernelBackend, NativeBackend};
use relad::ra::eval::eval_query_tape;
use relad::ra::expr::matmul_query;
use relad::ra::{Chunk, Key, Relation};
use relad::runtime::XlaBackend;
use relad::util::stats::{fmt_secs, time_it};
use relad::util::Prng;

fn main() {
    kernel_throughput();
    join_agg_throughput();
    autodiff_overhead();
    println!("\nmicro bench done");
}

fn kernel_throughput() {
    println!("=== kernel throughput (64x64 f32 chunks) ===");
    let mut rng = Prng::new(1);
    let a = Chunk::random(64, 64, &mut rng, 1.0);
    let b = Chunk::random(64, 64, &mut rng, 1.0);
    let key = Key::k1(0);
    let flops = BinaryKernel::MatMul.flops((64, 64), (64, 64)) as f64;

    let t = time_it(20, 200, || {
        std::hint::black_box(NativeBackend.binary(&BinaryKernel::MatMul, &key, &a, &b));
    });
    println!(
        "matmul  native: {}/op  {:.2} GFLOP/s",
        fmt_secs(t.mean),
        flops / t.mean / 1e9
    );

    if std::path::Path::new("artifacts/manifest.tsv").exists() {
        let xla = XlaBackend::load("artifacts").expect("artifacts");
        let t = time_it(20, 200, || {
            std::hint::black_box(xla.binary(&BinaryKernel::MatMul, &key, &a, &b));
        });
        println!(
            "matmul  xla:    {}/op  {:.2} GFLOP/s (incl. PJRT dispatch)",
            fmt_secs(t.mean),
            flops / t.mean / 1e9
        );
        let t = time_it(20, 200, || {
            std::hint::black_box(xla.binary(&BinaryKernel::Add, &key, &a, &b));
        });
        println!("add     xla:    {}/op", fmt_secs(t.mean));
    } else {
        println!("(artifacts missing — run `make artifacts` for the XLA rows)");
    }
    let t = time_it(20, 500, || {
        std::hint::black_box(NativeBackend.binary(&BinaryKernel::Add, &key, &a, &b));
    });
    println!("add     native: {}/op", fmt_secs(t.mean));
}

fn blocked(n: i64, m: i64, c: usize, rng: &mut Prng) -> Relation {
    let mut r = Relation::new();
    for i in 0..n {
        for j in 0..m {
            r.insert(Key::k2(i, j), Chunk::random(c, c, rng, 1.0));
        }
    }
    r
}

fn join_agg_throughput() {
    println!("\n=== join/agg throughput (blocked matmul query) ===");
    let mut rng = Prng::new(2);
    for (nb, c) in [(16i64, 16usize), (8, 64)] {
        let a = blocked(nb, nb, c, &mut rng);
        let b = blocked(nb, nb, c, &mut rng);
        let q = matmul_query();
        let t = time_it(2, 10, || {
            std::hint::black_box(eval_query_tape(&q, &[&a, &b], &NativeBackend).unwrap());
        });
        let tuples = (nb * nb * nb) as f64; // join emissions
        println!(
            "{nb}x{nb} blocks of {c}x{c}: {}/query, {:.0} join-tuples/s",
            fmt_secs(t.mean),
            tuples / t.mean
        );
    }
}

fn autodiff_overhead() {
    println!("\n=== autodiff overhead (blocked matmul loss) ===");
    let mut rng = Prng::new(3);
    let a = blocked(8, 8, 32, &mut rng);
    let b = blocked(8, 8, 32, &mut rng);
    let q = matmul_query();

    let fwd = time_it(2, 10, || {
        std::hint::black_box(eval_query_tape(&q, &[&a, &b], &NativeBackend).unwrap());
    });
    let both = time_it(2, 10, || {
        std::hint::black_box(grad_wrt(&q, &[&a, &b], &[0, 1], &NativeBackend).unwrap());
    });
    println!(
        "forward {}   forward+backward {}   bwd/fwd ratio {:.2}x",
        fmt_secs(fwd.mean),
        fmt_secs(both.mean),
        (both.mean - fwd.mean) / fwd.mean
    );

    let build = time_it(2, 50, || {
        std::hint::black_box(backward_graph(&q, &[2, 2], &[0, 1]).unwrap());
    });
    println!("backward-query generation (source transform): {}", fmt_secs(build.mean));

    // graph-mode execution vs eager
    let tape = eval_query_tape(&q, &[&a, &b], &NativeBackend).unwrap();
    let plan = backward_graph(&q, &[2, 2], &[0, 1]).unwrap();
    let mut seed = Relation::new();
    for (k, v) in tape.rels[q.output].iter() {
        seed.insert(*k, Chunk::filled(v.rows(), v.cols(), 1.0));
    }
    let ge = time_it(2, 10, || {
        std::hint::black_box(eval_backward(&plan, &tape, &seed, &NativeBackend).unwrap());
    });
    println!("graph-mode backward execution: {}", fmt_secs(ge.mean));
}
