//! Figure 2 reproduction: NNMF per-epoch time for four (N, D) cases on
//! cluster sizes {2,4,8,16}, systems {RA-NNMF, Dask, MPI}.
//!
//! Expected shape (paper): MPI fastest, RA-NNMF close behind, Dask
//! slowest and OOM on the N=60k,D=10k case (materialized intermediates);
//! all runnable systems scale with cluster size. Data is scaled 1/64
//! (documented), budget scaled accordingly.

use relad::baselines::dask_nnmf::{self, NnmfCase};
use relad::baselines::mpi_nnmf;
use relad::bench_util::{bcell, print_header, print_row};
use relad::dist::{ClusterConfig, MemPolicy, NetModel, PartitionedRelation};
use relad::kernels::NativeBackend;
use relad::ml::nnmf;
use relad::ml::DistTrainer;
use relad::util::Prng;
use std::sync::Arc;

const SCALE: usize = 64;

fn ra_nnmf_epoch(case: &NnmfCase, workers: usize, budget: u64) -> String {
    let (nb, db) = case.blocks();
    let mut rng = Prng::new(5);
    let v = relad::data::matrices::random_block_matrix(case.n, case.n, case.chunk, &mut rng, true);
    let (w, h) = nnmf::init_factors(nb, db, nb, case.chunk, &mut rng);
    let q = nnmf::loss_query(Arc::new(v), case.n * case.n);
    let trainer = DistTrainer::new(q, &[2, 2], &[nnmf::SLOT_W, nnmf::SLOT_H]).unwrap();
    let cfg = ClusterConfig::new(workers)
        .with_budget(budget)
        .with_policy(MemPolicy::Spill);
    let inputs = vec![
        PartitionedRelation::hash_full(&w, workers),
        PartitionedRelation::hash_full(&h, workers),
    ];
    // Legacy positional one-shot step (sweeps worker counts past the
    // host's cores with per-call layouts); see the `session` module
    // migration note for the supported path.
    #[allow(deprecated)]
    let res = trainer.step(&inputs, &cfg, &NativeBackend);
    match res {
        Ok(r) => format!("{:.3}s", r.stats.virtual_time_s),
        Err(e) => format!("ERR({e})"),
    }
}

fn main() {
    let workers = [2usize, 4, 8, 16];
    // Paper cases (N, D), scaled 1/64.
    let cases = [
        ("N=40k,D=40k", 40_000 / SCALE, 40_000 / SCALE),
        ("N=50k,D=40k", 50_000 / SCALE, 40_000 / SCALE),
        ("N=60k,D=10k", 60_000 / SCALE, 10_000 / SCALE),
        ("N=10k,D=60k", 10_000 / SCALE, 60_000 / SCALE),
    ];
    // 64 GB per node scaled by data-volume factor (SCALE² for an N×N
    // dense matrix) — the ratio that decides Dask's OOM.
    let budget = (64u64 << 30) / (SCALE as u64 * SCALE as u64);
    for (name, n, d) in cases {
        let case = NnmfCase { n, d, chunk: 32 };
        print_header(
            &format!("Figure 2: NNMF {name} (scaled /{SCALE}: n={n}, d={d}, budget/worker={}KB)", budget >> 10),
            &workers,
        );
        let work = dask_nnmf::measure_epoch(&case, 11);
        let net = NetModel::default();

        let mut row = Vec::new();
        for &w in &workers {
            row.push(ra_nnmf_epoch(&case, w, budget));
        }
        print_row("RA-NNMF", &row);

        let mut row = Vec::new();
        for &w in &workers {
            row.push(bcell(&dask_nnmf::epoch_time(&work, w, budget, &net)));
        }
        print_row("Dask", &row);

        let mut row = Vec::new();
        for &w in &workers {
            row.push(bcell(&mpi_nnmf::epoch_time(&case, &work, w, budget, &net)));
        }
        print_row("MPI", &row);
    }
}
