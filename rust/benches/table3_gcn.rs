//! Table 3 reproduction: GCN per-epoch time on the scaled
//! ogbn-papers100M and friendster datasets — the memory-pressure regime.
//!
//! Expected shape (paper): DistDGL OOMs below 4 nodes (papers100M) /
//! below 8 nodes (friendster); AliGraph OOMs everywhere (whole-graph
//! load); RA-GCN never OOMs — including single-node full-graph training —
//! by spilling, and overtakes DistDGL at large cluster sizes.

use relad::baselines::distdgl::GnnBaselineCfg;
use relad::baselines::{aligraph, distdgl};
use relad::bench_util::{bcell, cell, print_header, print_row, ra_gcn_epoch};
use relad::data::{scaled_dataset, GraphScale};
use relad::dist::NetModel;
use relad::kernels::NativeBackend;

fn main() {
    let workers = [1usize, 2, 4, 8, 16];
    for scale in [GraphScale::Papers100M, GraphScale::Friendster] {
        let g = scaled_dataset(scale, 9);
        let budget = scale.scaled_budget();
        print_header(
            &format!(
                "Table 3: {} |V|={} |E|={} budget/worker={}MB",
                g.name,
                g.n_nodes,
                g.n_edges,
                budget >> 20
            ),
            &workers,
        );
        let batch = 32;

        for (name, ali) in [("DistDGL", false), ("AliGraph", true)] {
            let mut row = Vec::new();
            for &w in &workers {
                let cfg = GnnBaselineCfg {
                    workers: w,
                    budget,
                    batch,
                    hidden: 64,
                    fanout: (10, 25),
                    net: NetModel::default(),
                };
                let r = if ali {
                    aligraph::epoch_time(&g, &cfg)
                } else {
                    distdgl::epoch_time(&g, &cfg)
                };
                row.push(bcell(&r));
            }
            print_row(name, &row);
        }

        let mut row = Vec::new();
        for &w in &workers {
            row.push(cell(&ra_gcn_epoch(
                &g,
                w,
                Some(budget),
                Some(batch),
                &NativeBackend,
            )));
        }
        print_row("RA-GCN", &row);

        let mut row = Vec::new();
        for &w in &workers {
            row.push(cell(&ra_gcn_epoch(&g, w, Some(budget), None, &NativeBackend)));
        }
        print_row("RA-GCN(full)", &row);
    }
}
