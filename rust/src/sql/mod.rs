//! SQL frontend: the paper's interface ("We implemented RA auto-diff …
//! accepting SQL input"). A deliberately small subset — exactly the
//! shape of the paper's examples:
//!
//! ```sql
//! SELECT A.row, B.col, SUM(matmul(A.val, B.val))
//! FROM A, B WHERE A.col = B.row
//! GROUP BY A.row, B.col
//! ```
//!
//! `parse_query` lowers such a statement onto the functional RA
//! (`ra::expr::Query`) against a `Catalog` mapping table names to input
//! slots and key-column names; `unparse::to_sql` renders any RA query —
//! including generated backward queries — back as SQL (Fig. 4/5).

pub mod lower;
pub mod parse;
pub mod unparse;

pub use lower::{Catalog, TableDef};
pub use parse::parse_query;
pub use unparse::{stmt_to_sql, to_sql};
