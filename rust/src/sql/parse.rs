//! Tokenizer + recursive-descent parser for the SQL subset.

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    Ident(String),
    Num(f32),
    Comma,
    Dot,
    LParen,
    RParen,
    Eq,
    Star,
}

pub fn lex(s: &str) -> Result<Vec<Tok>> {
    let mut out = Vec::new();
    let b: Vec<char> = s.chars().collect();
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        match c {
            ' ' | '\t' | '\n' | '\r' => i += 1,
            ',' => {
                out.push(Tok::Comma);
                i += 1;
            }
            '.' => {
                out.push(Tok::Dot);
                i += 1;
            }
            '(' => {
                out.push(Tok::LParen);
                i += 1;
            }
            ')' => {
                out.push(Tok::RParen);
                i += 1;
            }
            '=' => {
                out.push(Tok::Eq);
                i += 1;
            }
            '*' => {
                out.push(Tok::Star);
                i += 1;
            }
            c if c.is_ascii_alphabetic() || c == '_' => {
                let start = i;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
                out.push(Tok::Ident(b[start..i].iter().collect()));
            }
            c if c.is_ascii_digit() => {
                let start = i;
                while i < b.len() && (b[i].is_ascii_digit() || b[i] == '.') {
                    i += 1;
                }
                let t: String = b[start..i].iter().collect();
                out.push(Tok::Num(t.parse()?));
            }
            other => bail!("unexpected character {other:?}"),
        }
    }
    Ok(out)
}

/// `table.column`
#[derive(Clone, Debug, PartialEq)]
pub struct ColRef {
    pub table: String,
    pub column: String,
}

/// A parsed (not yet lowered) query. `PartialEq` backs the
/// parse → unparse → parse fixpoint regression
/// (`sql::unparse::stmt_to_sql`).
#[derive(Clone, Debug, PartialEq)]
pub struct SelectStmt {
    /// key output columns, in order
    pub key_cols: Vec<ColRef>,
    /// value expression: kernel name + value-column args; `agg` true if
    /// wrapped in SUM(…)
    pub kernel: String,
    pub args: Vec<ColRef>,
    pub agg: bool,
    pub tables: Vec<String>,
    /// equality predicates `a = b`
    pub preds: Vec<(ColRef, ColRef)>,
    pub group_by: Vec<ColRef>,
}

struct P {
    toks: Vec<Tok>,
    i: usize,
}

impl P {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.i)
    }

    fn next(&mut self) -> Result<Tok> {
        let t = self
            .toks
            .get(self.i)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of query"))?;
        self.i += 1;
        Ok(t)
    }

    fn expect_kw(&mut self, kw: &str) -> Result<()> {
        match self.next()? {
            Tok::Ident(s) if s.eq_ignore_ascii_case(kw) => Ok(()),
            other => bail!("expected {kw}, got {other:?}"),
        }
    }

    fn peek_kw(&self, kw: &str) -> bool {
        matches!(self.peek(), Some(Tok::Ident(s)) if s.eq_ignore_ascii_case(kw))
    }

    fn ident(&mut self) -> Result<String> {
        match self.next()? {
            Tok::Ident(s) => Ok(s),
            other => bail!("expected identifier, got {other:?}"),
        }
    }

    fn colref(&mut self) -> Result<ColRef> {
        let table = self.ident()?;
        match self.next()? {
            Tok::Dot => {}
            other => bail!("expected '.', got {other:?}"),
        }
        let column = self.ident()?;
        Ok(ColRef { table, column })
    }

    fn eat(&mut self, t: &Tok) -> bool {
        if self.peek() == Some(t) {
            self.i += 1;
            true
        } else {
            false
        }
    }
}

pub fn parse(sql: &str) -> Result<SelectStmt> {
    let mut p = P {
        toks: lex(sql)?,
        i: 0,
    };
    p.expect_kw("SELECT")?;
    // key columns until we hit SUM( or a kernel call
    let mut key_cols = Vec::new();
    let (kernel, args, agg);
    loop {
        if p.peek_kw("SUM") {
            p.next()?; // SUM
            if !p.eat(&Tok::LParen) {
                bail!("expected ( after SUM");
            }
            let (k, a) = parse_kernel_call(&mut p)?;
            if !p.eat(&Tok::RParen) {
                bail!("expected ) closing SUM");
            }
            kernel = k;
            args = a;
            agg = true;
            break;
        }
        // lookahead: IDENT ( → kernel call (no aggregation)
        if let (Some(Tok::Ident(_)), Some(Tok::LParen)) =
            (p.toks.get(p.i), p.toks.get(p.i + 1))
        {
            let (k, a) = parse_kernel_call(&mut p)?;
            kernel = k;
            args = a;
            agg = false;
            break;
        }
        key_cols.push(p.colref()?);
        if !p.eat(&Tok::Comma) {
            bail!("expected ',' in select list");
        }
    }
    p.expect_kw("FROM")?;
    let mut tables = vec![p.ident()?];
    while p.eat(&Tok::Comma) {
        tables.push(p.ident()?);
    }
    let mut preds = Vec::new();
    if p.peek_kw("WHERE") {
        p.next()?;
        loop {
            let a = p.colref()?;
            if !p.eat(&Tok::Eq) {
                bail!("expected '=' in WHERE");
            }
            let b = p.colref()?;
            preds.push((a, b));
            if p.peek_kw("AND") {
                p.next()?;
            } else {
                break;
            }
        }
    }
    let mut group_by = Vec::new();
    if p.peek_kw("GROUP") {
        p.next()?;
        p.expect_kw("BY")?;
        group_by.push(p.colref()?);
        while p.eat(&Tok::Comma) {
            group_by.push(p.colref()?);
        }
    }
    if p.peek().is_some() {
        bail!("trailing tokens after query");
    }
    Ok(SelectStmt {
        key_cols,
        kernel,
        args,
        agg,
        tables,
        preds,
        group_by,
    })
}

fn parse_kernel_call(p: &mut P) -> Result<(String, Vec<ColRef>)> {
    let name = p.ident()?;
    if !p.eat(&Tok::LParen) {
        bail!("expected ( after kernel {name}");
    }
    let mut args = vec![p.colref()?];
    while p.eat(&Tok::Comma) {
        args.push(p.colref()?);
    }
    if !p.eat(&Tok::RParen) {
        bail!("expected ) after kernel args");
    }
    Ok((name, args))
}

/// Re-export used by `sql::parse_query`.
pub use super::lower::parse_query;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexes_symbols_and_idents() {
        let t = lex("SELECT A.row, SUM(matmul(A.val, B.val))").unwrap();
        assert!(t.contains(&Tok::Ident("SELECT".into())));
        assert!(t.contains(&Tok::LParen));
        assert_eq!(t.iter().filter(|x| **x == Tok::Comma).count(), 2);
    }

    #[test]
    fn parses_paper_matmul_query() {
        let s = parse(
            "SELECT A.row, B.col, SUM(matmul(A.val, B.val)) \
             FROM A, B WHERE A.col = B.row GROUP BY A.row, B.col",
        )
        .unwrap();
        assert_eq!(s.tables, vec!["A", "B"]);
        assert_eq!(s.kernel, "matmul");
        assert!(s.agg);
        assert_eq!(s.preds.len(), 1);
        assert_eq!(s.group_by.len(), 2);
        assert_eq!(s.key_cols.len(), 2);
    }

    #[test]
    fn parses_unary_selection() {
        let s = parse("SELECT P.row, logistic(P.val) FROM P").unwrap();
        assert_eq!(s.kernel, "logistic");
        assert!(!s.agg);
        assert_eq!(s.args.len(), 1);
        assert!(s.preds.is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("SELECT FROM").is_err());
        assert!(parse("SELECT A.x, foo(A.val) FROM A extra").is_err());
        assert!(lex("SELECT 'quoted'").is_err());
    }
}
