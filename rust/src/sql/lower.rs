//! Lowering a parsed SELECT onto the functional RA.

use super::parse::{parse, ColRef, SelectStmt};
use crate::kernels::{AggKernel, BinaryKernel, UnaryKernel};
use crate::ra::expr::{Query, QueryBuilder};
use crate::ra::funcs::{JoinPred, KeyPred, KeyProj, KeyProj2, Sel, Sel2};
use anyhow::{bail, Context, Result};

/// A registered table: input slot + ordered key column names. The value
/// column is always addressed as `<table>.val`.
#[derive(Clone, Debug)]
pub struct TableDef {
    pub name: String,
    pub slot: usize,
    pub key_cols: Vec<String>,
}

#[derive(Clone, Debug, Default)]
pub struct Catalog {
    pub tables: Vec<TableDef>,
}

impl Catalog {
    pub fn table(mut self, name: &str, slot: usize, key_cols: &[&str]) -> Self {
        self.tables.push(TableDef {
            name: name.to_string(),
            slot,
            key_cols: key_cols.iter().map(|s| s.to_string()).collect(),
        });
        self
    }

    fn lookup(&self, name: &str) -> Result<&TableDef> {
        self.tables
            .iter()
            .find(|t| t.name == name)
            .with_context(|| format!("unknown table {name}"))
    }
}

fn unary_kernel(name: &str) -> Option<UnaryKernel> {
    Some(match name {
        "logistic" => UnaryKernel::Logistic,
        "relu" => UnaryKernel::Relu,
        "tanh" => UnaryKernel::Tanh,
        "exp" => UnaryKernel::Exp,
        "log" => UnaryKernel::Log,
        "square" => UnaryKernel::Square,
        "neg" => UnaryKernel::Neg,
        "sum_all" => UnaryKernel::SumAll,
        "row_sum" => UnaryKernel::RowSum,
        "softmax" => UnaryKernel::SoftmaxRows,
        "transpose" => UnaryKernel::Transpose,
        "id" => UnaryKernel::Id,
        _ => return None,
    })
}

fn binary_kernel(name: &str) -> Option<BinaryKernel> {
    Some(match name {
        "matmul" | "matrix_multiply" => BinaryKernel::MatMul,
        "matmul_tn" => BinaryKernel::MatMulTN,
        "matmul_nt" => BinaryKernel::MatMulNT,
        "add" => BinaryKernel::Add,
        "sub" => BinaryKernel::Sub,
        "mul" => BinaryKernel::Mul,
        "div" => BinaryKernel::Div,
        "bce_loss" => BinaryKernel::BceLoss,
        "squared_diff" => BinaryKernel::SquaredDiff,
        "softmax_xent" => BinaryKernel::SoftmaxXentRows,
        "scalar_mul" => BinaryKernel::ScalarMul,
        _ => return None,
    })
}

/// Parse + lower a SQL statement into a `Query` against the catalog.
pub fn parse_query(sql: &str, catalog: &Catalog) -> Result<Query> {
    let stmt = parse(sql)?;
    lower(&stmt, catalog)
}

fn key_index(t: &TableDef, col: &ColRef) -> Result<usize> {
    t.key_cols
        .iter()
        .position(|c| *c == col.column)
        .with_context(|| format!("unknown key column {}.{}", col.table, col.column))
}

pub fn lower(stmt: &SelectStmt, catalog: &Catalog) -> Result<Query> {
    let mut qb = QueryBuilder::new();
    match stmt.tables.len() {
        1 => {
            let t = catalog.lookup(&stmt.tables[0])?;
            let scan = qb.scan(t.slot, &t.name);
            if stmt.args.len() != 1 {
                bail!("single-table query takes a unary kernel");
            }
            let kernel = unary_kernel(&stmt.kernel)
                .with_context(|| format!("unknown unary kernel {}", stmt.kernel))?;
            // selection proj from the SELECT key columns
            let sels: Vec<Sel> = stmt
                .key_cols
                .iter()
                .map(|c| key_index(t, c).map(Sel::C))
                .collect::<Result<_>>()?;
            let sel = qb.select(KeyPred::always(), KeyProj(sels), kernel, scan);
            let out = if stmt.agg {
                let grp: Vec<usize> = (0..stmt.group_by.len()).collect();
                // group-by columns must be a prefix reordering of the
                // select keys; map by name
                let mut comps = Vec::new();
                for g in &stmt.group_by {
                    let pos = stmt
                        .key_cols
                        .iter()
                        .position(|c| c == g)
                        .context("GROUP BY column not in SELECT list")?;
                    comps.push(pos);
                }
                let _ = grp;
                qb.agg(KeyProj::take(&comps), AggKernel::Sum, sel)
            } else {
                sel
            };
            Ok(qb.finish(out))
        }
        2 => {
            let lt = catalog.lookup(&stmt.tables[0])?;
            let rt = catalog.lookup(&stmt.tables[1])?;
            let ls = qb.scan(lt.slot, &lt.name);
            let rs = qb.scan(rt.slot, &rt.name);
            let kernel = binary_kernel(&stmt.kernel)
                .with_context(|| format!("unknown binary kernel {}", stmt.kernel))?;
            if stmt.args.len() != 2 {
                bail!("binary kernel needs two args");
            }
            if stmt.args[0].table != lt.name || stmt.args[1].table != rt.name {
                bail!("kernel args must be <left>.val, <right>.val in FROM order");
            }
            // join predicate
            let mut eqs = Vec::new();
            for (a, b) in &stmt.preds {
                let (l, r) = if a.table == lt.name && b.table == rt.name {
                    (key_index(lt, a)?, key_index(rt, b)?)
                } else if a.table == rt.name && b.table == lt.name {
                    (key_index(lt, b)?, key_index(rt, a)?)
                } else {
                    bail!("predicate must relate the two FROM tables");
                };
                eqs.push((l, r));
            }
            // join output keys = SELECT key columns; when aggregating,
            // append the join-key columns as disambiguators (SQL joins
            // produce multiplicities; our relations are maps, so the
            // pre-aggregation key must be unique — the Σ then projects
            // them away, which is exactly the paper's matmul plan).
            let mut sels = Vec::new();
            for c in &stmt.key_cols {
                if c.table == lt.name {
                    sels.push(Sel2::L(key_index(lt, c)?));
                } else if c.table == rt.name {
                    sels.push(Sel2::R(key_index(rt, c)?));
                } else {
                    bail!("unknown table in SELECT: {}", c.table);
                }
            }
            if stmt.agg {
                for &(l, _) in &eqs {
                    let sel = Sel2::L(l);
                    if !sels.contains(&sel) {
                        sels.push(sel);
                    }
                }
            }
            let j = qb.join(JoinPred::on(eqs), KeyProj2(sels), kernel, ls, rs);
            let out = if stmt.agg {
                let mut comps = Vec::new();
                for g in &stmt.group_by {
                    let pos = stmt
                        .key_cols
                        .iter()
                        .position(|c| c == g)
                        .context("GROUP BY column not in SELECT list")?;
                    comps.push(pos);
                }
                qb.agg(KeyProj::take(&comps), AggKernel::Sum, j)
            } else {
                j
            };
            Ok(qb.finish(out))
        }
        n => bail!("only 1- or 2-table queries supported (got {n})"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::NativeBackend;
    use crate::ra::eval::eval_query;
    use crate::ra::expr::matmul_query;
    use crate::ra::{Chunk, Key, Relation};
    use crate::util::Prng;

    fn catalog() -> Catalog {
        Catalog::default()
            .table("A", 0, &["row", "col"])
            .table("B", 1, &["row", "col"])
    }

    #[test]
    fn paper_sql_equals_handbuilt_matmul_query() {
        let q = parse_query(
            "SELECT A.row, B.col, SUM(matrix_multiply(A.val, B.val)) \
             FROM A, B WHERE A.col = B.row GROUP BY A.row, B.col",
            &catalog(),
        )
        .unwrap();
        // evaluate both against the same blocked matrices
        let mut rng = Prng::new(71);
        let mut a = Relation::new();
        let mut b = Relation::new();
        for i in 0..2i64 {
            for k in 0..2i64 {
                a.insert(Key::k2(i, k), Chunk::random(4, 4, &mut rng, 1.0));
                b.insert(Key::k2(k, i), Chunk::random(4, 4, &mut rng, 1.0));
            }
        }
        let got = eval_query(&q, &[&a, &b], &NativeBackend).unwrap();
        let want = eval_query(&matmul_query(), &[&a, &b], &NativeBackend).unwrap();
        assert!(got.approx_eq(&want, 1e-5));
    }

    #[test]
    fn unary_select_lowering() {
        let cat = Catalog::default().table("P", 0, &["row"]);
        let q = parse_query("SELECT P.row, logistic(P.val) FROM P", &cat).unwrap();
        let p = Relation::from_pairs(vec![(Key::k1(0), Chunk::scalar(0.0))]);
        let out = eval_query(&q, &[&p], &NativeBackend).unwrap();
        assert!((out.get(&Key::k1(0)).unwrap().as_scalar() - 0.5).abs() < 1e-6);
    }

    #[test]
    fn sql_query_is_differentiable() {
        // The SQL-built query feeds straight into the RA autodiff.
        let cat = Catalog::default()
            .table("X", 0, &["row"])
            .table("Y", 1, &["row"]);
        let q = parse_query(
            "SELECT SUM(mul(X.val, Y.val)) FROM X, Y WHERE X.row = Y.row GROUP BY",
            &cat,
        );
        // GROUP BY with no columns isn't valid SQL; use the supported form:
        assert!(q.is_err() || q.is_ok()); // tolerated either way
        let q2 = parse_query(
            "SELECT X.row, SUM(mul(X.val, Y.val)) FROM X, Y WHERE X.row = Y.row GROUP BY X.row",
            &cat,
        )
        .unwrap();
        let x = Relation::from_pairs(vec![(Key::k1(0), Chunk::scalar(3.0))]);
        let y = Relation::from_pairs(vec![(Key::k1(0), Chunk::scalar(4.0))]);
        let (_, grads) = crate::autodiff::grad(&q2, &[&x, &y], &NativeBackend).unwrap();
        assert_eq!(grads.slot(0).get(&Key::k1(0)).unwrap().as_scalar(), 4.0);
        assert_eq!(grads.slot(1).get(&Key::k1(0)).unwrap().as_scalar(), 3.0);
    }

    #[test]
    fn errors_on_unknown_names() {
        assert!(parse_query("SELECT Z.row, relu(Z.val) FROM Z", &catalog()).is_err());
        assert!(parse_query(
            "SELECT A.bogus, B.col, SUM(matmul(A.val, B.val)) FROM A, B WHERE A.col = B.row GROUP BY A.bogus, B.col",
            &catalog()
        )
        .is_err());
    }
}
