//! Render any RA query — in particular the *generated backward queries* —
//! as SQL, the Fig. 4/5 demonstration: each DAG node becomes a CTE.

use crate::ra::expr::{Op, Query};
use crate::ra::funcs::{Sel, Sel2};

fn key_cols_unary(p: &crate::ra::funcs::KeyProj, src: &str) -> String {
    p.0.iter()
        .enumerate()
        .map(|(i, s)| match s {
            Sel::C(c) => format!("{src}.k{c} AS k{i}"),
            Sel::Lit(v) => format!("{v} AS k{i}"),
        })
        .collect::<Vec<_>>()
        .join(", ")
}

fn key_cols_binary(p: &crate::ra::funcs::KeyProj2, l: &str, r: &str) -> String {
    p.0.iter()
        .enumerate()
        .map(|(i, s)| match s {
            Sel2::L(c) => format!("{l}.k{c} AS k{i}"),
            Sel2::R(c) => format!("{r}.k{c} AS k{i}"),
            Sel2::Lit(v) => format!("{v} AS k{i}"),
        })
        .collect::<Vec<_>>()
        .join(", ")
}

/// Render a parsed [`SelectStmt`] back into the SQL subset the parser
/// accepts — so `parse(stmt_to_sql(&parse(s)?)?) == parse(s)` (the
/// round-trip fixpoint tier-1 regresses on the example queries). This is
/// the statement-level inverse of `parse`; [`to_sql`] below renders
/// whole RA DAGs (including generated backward queries) as WITH-chains,
/// which lie outside the input subset.
///
/// [`SelectStmt`]: crate::sql::parse::SelectStmt
pub fn stmt_to_sql(stmt: &crate::sql::parse::SelectStmt) -> String {
    let mut s = String::from("SELECT ");
    let col = |c: &crate::sql::parse::ColRef| format!("{}.{}", c.table, c.column);
    for k in &stmt.key_cols {
        s.push_str(&col(k));
        s.push_str(", ");
    }
    let call = format!(
        "{}({})",
        stmt.kernel,
        stmt.args.iter().map(col).collect::<Vec<_>>().join(", ")
    );
    if stmt.agg {
        s.push_str(&format!("SUM({call})"));
    } else {
        s.push_str(&call);
    }
    s.push_str(" FROM ");
    s.push_str(&stmt.tables.join(", "));
    if !stmt.preds.is_empty() {
        s.push_str(" WHERE ");
        s.push_str(
            &stmt
                .preds
                .iter()
                .map(|(a, b)| format!("{} = {}", col(a), col(b)))
                .collect::<Vec<_>>()
                .join(" AND "),
        );
    }
    if !stmt.group_by.is_empty() {
        s.push_str(" GROUP BY ");
        s.push_str(
            &stmt
                .group_by
                .iter()
                .map(col)
                .collect::<Vec<_>>()
                .join(", "),
        );
    }
    s
}

/// Render a query as a WITH-chain of SELECTs.
pub fn to_sql(q: &Query) -> String {
    let mut ctes: Vec<String> = Vec::new();
    let mut names: Vec<String> = Vec::with_capacity(q.nodes.len());
    for (i, node) in q.nodes.iter().enumerate() {
        let name = format!("v{i}");
        let body = match &node.op {
            Op::Scan { name: n, .. } => format!("SELECT * FROM {n}"),
            Op::Const { name: n, .. } => format!("SELECT * FROM {n} /* constant */"),
            Op::Select { pred, proj, kernel } => {
                let src = &names[node.children[0]];
                let keys = key_cols_unary(proj, src);
                let wh = if pred.is_always() {
                    String::new()
                } else {
                    let conds: Vec<String> = pred
                        .0
                        .iter()
                        .map(|(c, v)| format!("{src}.k{c} = {v}"))
                        .collect();
                    format!(" WHERE {}", conds.join(" AND "))
                };
                let sep = if keys.is_empty() { "" } else { ", " };
                format!(
                    "SELECT {keys}{sep}{}({src}.val) AS val FROM {src}{wh}",
                    kernel.name()
                )
            }
            Op::Join { pred, proj, kernel } => {
                let l = &names[node.children[0]];
                let r = &names[node.children[1]];
                let keys = key_cols_binary(proj, l, r);
                let mut conds: Vec<String> = pred
                    .eqs
                    .iter()
                    .map(|(a, b)| format!("{l}.k{a} = {r}.k{b}"))
                    .collect();
                conds.extend(pred.l_lits.iter().map(|(c, v)| format!("{l}.k{c} = {v}")));
                conds.extend(pred.r_lits.iter().map(|(c, v)| format!("{r}.k{c} = {v}")));
                let wh = if conds.is_empty() {
                    String::new()
                } else {
                    format!(" WHERE {}", conds.join(" AND "))
                };
                let sep = if keys.is_empty() { "" } else { ", " };
                format!(
                    "SELECT {keys}{sep}{}({l}.val, {r}.val) AS val FROM {l}, {r}{wh}",
                    kernel.name()
                )
            }
            Op::Agg { grp, agg } => {
                let src = &names[node.children[0]];
                let keys = key_cols_unary(grp, src);
                let gb: Vec<String> = grp
                    .0
                    .iter()
                    .filter_map(|s| match s {
                        Sel::C(c) => Some(format!("{src}.k{c}")),
                        Sel::Lit(_) => None,
                    })
                    .collect();
                let group = if gb.is_empty() {
                    String::new()
                } else {
                    format!(" GROUP BY {}", gb.join(", "))
                };
                let sep = if keys.is_empty() { "" } else { ", " };
                format!(
                    "SELECT {keys}{sep}{}({src}.val) AS val FROM {src}{group}",
                    agg.name().to_uppercase()
                )
            }
            Op::AddQ => {
                let l = &names[node.children[0]];
                let r = &names[node.children[1]];
                format!(
                    "SELECT COALESCE({l}.k0, {r}.k0) /* … */, add({l}.val, {r}.val) AS val \
                     FROM {l} FULL OUTER JOIN {r} USING (key)"
                )
            }
        };
        ctes.push(format!("  {name} AS (\n    {body}\n  )"));
        names.push(name);
    }
    format!(
        "WITH\n{}\nSELECT * FROM v{};",
        ctes.join(",\n"),
        q.output
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ra::expr::matmul_query;
    use crate::sql::parse::parse;

    #[test]
    fn stmt_round_trip_is_a_fixpoint() {
        for sql in [
            "SELECT A.row, B.col, SUM(matmul(A.val, B.val)) \
             FROM A, B WHERE A.col = B.row GROUP BY A.row, B.col",
            "SELECT P.row, logistic(P.val) FROM P",
            "SELECT X.row, SUM(mul(X.val, Y.val)) FROM X, Y \
             WHERE X.row = Y.row GROUP BY X.row",
        ] {
            let once = parse(sql).unwrap();
            let rendered = stmt_to_sql(&once);
            let twice = parse(&rendered).unwrap();
            assert_eq!(once, twice, "round trip diverged for {sql:?}:\n{rendered}");
            // And the rendering itself is a fixpoint.
            assert_eq!(rendered, stmt_to_sql(&twice));
        }
    }

    #[test]
    fn forward_matmul_sql_mentions_everything() {
        let sql = to_sql(&matmul_query());
        assert!(sql.contains("matmul("));
        assert!(sql.contains("GROUP BY"));
        assert!(sql.contains("WITH"));
        assert!(sql.contains("v0.k1 = v1.k0"));
    }

    #[test]
    fn backward_query_unparses_as_sql() {
        // Fig. 4: the generated gradient of a blocked matmul renders as
        // joins + SUM/GROUP BY over the taped inputs.
        let q = matmul_query();
        let plan = crate::autodiff::backward_graph(&q, &[2, 2], &[0, 1]).unwrap();
        let sql = to_sql(&plan.query);
        assert!(sql.contains("matmul_nt("), "dA = g·Bᵀ missing:\n{sql}");
        assert!(sql.contains("matmul_tn("), "dB = Aᵀ·g missing:\n{sql}");
        assert!(sql.contains("SUM("));
    }
}
