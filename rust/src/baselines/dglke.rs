//! DGL-KE-like baseline (Zheng et al. 2020b): data-parallel KGE training
//! with a shared-memory / replicated embedding store per worker.
//!
//! Memory model per worker: full entity + relation tables (DGL-KE's
//! shared-memory KVStore keeps the full embedding matrix mapped on every
//! machine for fast lookup) plus optimizer state (×2 for SGD-with-
//! momentum-style state the paper's config carries) and the framework's
//! ×2 object overhead — this is what drives the OOM cells at D=200 in
//! Figure 3. Compute is real: TransE/TransR batch scoring and gradient
//! arithmetic actually execute.

use super::{overhead, BaselineResult};
use crate::data::KgDataset;
use crate::dist::NetModel;
use crate::ml::kge::KgeVariant;
use crate::util::Prng;
use std::time::Instant;

pub struct DglkeCfg {
    pub workers: usize,
    pub budget: u64,
    pub dim: usize,
    pub variant: KgeVariant,
    pub batch: usize,
    pub n_neg: usize,
    pub net: NetModel,
}

/// Modeled time for 100 training iterations (Figure 3's metric).
pub fn time_100_iters(kg: &KgDataset, cfg: &DglkeCfg) -> BaselineResult {
    let d = cfg.dim;
    let rel_d = match cfg.variant {
        KgeVariant::TransE => d,
        KgeVariant::TransR => 2 * d,
    };
    // ---- memory: METIS-partitioned entity table (1/W per worker) with
    // a hot-entity cache (~25% of the table, Zipf head), replicated
    // relation tables, optimizer state ×2, framework object overhead ×2.
    let ent_bytes = kg.n_entities as u64 * d as u64 * 4;
    let rel_bytes = kg.n_relations as u64 * rel_d as u64 * 4;
    let proj_bytes = match cfg.variant {
        KgeVariant::TransE => 0,
        KgeVariant::TransR => kg.n_relations as u64 * (d * 2 * d) as u64 * 4,
    };
    let ent_local = ent_bytes / cfg.workers as u64 + ent_bytes / 4;
    let needed = (ent_local + rel_bytes + proj_bytes) * 2 * 2;
    if needed > cfg.budget {
        return BaselineResult::Oom {
            needed,
            budget: cfg.budget,
        };
    }

    // ---- real compute: score + grad for this worker's share ----
    let mut rng = Prng::new(0x4B47);
    let ent: Vec<f32> = (0..kg.n_entities.min(20_000) * d)
        .map(|_| rng.normal() * 0.1)
        .collect();
    let iters_per_worker = (100usize).div_ceil(cfg.workers);
    let t0 = Instant::now();
    let mut sink = 0.0f32;
    for _ in 0..iters_per_worker {
        let (pos, negs) = kg.sample_batch(cfg.batch, cfg.n_neg, &mut rng);
        for (i, &(h, _r, t)) in pos.iter().enumerate() {
            let hbase = (h as usize % 20_000) * d;
            let tbase = (t as usize % 20_000) * d;
            // positive score ‖h + r − t‖²  (r folded as constant shift)
            let mut s = 0.0f32;
            for j in 0..d {
                let diff = ent[hbase + j] - ent[tbase + j] + 0.05;
                s += diff * diff;
            }
            // negatives + margin-gradient arithmetic (3 ops/dim/neg)
            for &n in &negs[i] {
                let nbase = (n as usize % 20_000) * d;
                let mut sn = 0.0f32;
                for j in 0..d {
                    let diff = ent[hbase + j] - ent[nbase + j] + 0.05;
                    sn += diff * diff;
                }
                sink += (1.0 + s - sn).max(0.0);
            }
        }
    }
    let mut compute_s = t0.elapsed().as_secs_f64() * cfg.workers as f64; // total
    std::hint::black_box(sink);
    if cfg.variant == KgeVariant::TransR {
        // projection matmuls dominate TransR: 2D·D mults per entity
        // occurrence vs 3D adds — charge the measured ratio.
        compute_s *= (2.0 * d as f64) / 3.0;
    }

    // ---- comms: push-pull of touched embeddings per iteration ----
    let touched = cfg.batch * (2 + cfg.n_neg);
    let bytes = (touched * d * 4) as u64;
    let comm_s = 100.0 * cfg.net.shuffle_time(bytes, cfg.workers);

    BaselineResult::Time(compute_s * overhead::DGLKE / cfg.workers as f64 + comm_s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kg() -> KgDataset {
        KgDataset::freebase_scaled(5_000, 30_000, 16, 61)
    }

    fn cfg(workers: usize, dim: usize, budget: u64, variant: KgeVariant) -> DglkeCfg {
        DglkeCfg {
            workers,
            budget,
            dim,
            variant,
            batch: 512,
            n_neg: 64,
            net: NetModel::default(),
        }
    }

    #[test]
    fn scales_with_workers() {
        let kg = kg();
        let t4 = time_100_iters(&kg, &cfg(4, 100, u64::MAX, KgeVariant::TransE))
            .time()
            .unwrap();
        let t16 = time_100_iters(&kg, &cfg(16, 100, u64::MAX, KgeVariant::TransE))
            .time()
            .unwrap();
        assert!(t16 < t4);
    }

    #[test]
    fn larger_dim_ooms_first() {
        let kg = kg();
        // pick a budget between the D=50 and D=200 footprints
        let d50 = 5_000u64 * 50 * 4 * 4 + 16 * 50 * 4 * 4;
        let budget = d50 * 2;
        assert!(time_100_iters(&kg, &cfg(4, 50, budget, KgeVariant::TransE))
            .time()
            .is_some());
        assert!(matches!(
            time_100_iters(&kg, &cfg(4, 200, budget, KgeVariant::TransE)),
            BaselineResult::Oom { .. }
        ));
    }

    #[test]
    fn transr_costs_more_than_transe() {
        let kg = kg();
        let te = time_100_iters(&kg, &cfg(4, 32, u64::MAX, KgeVariant::TransE))
            .time()
            .unwrap();
        let tr = time_100_iters(&kg, &cfg(4, 32, u64::MAX, KgeVariant::TransR))
            .time()
            .unwrap();
        assert!(tr > te);
    }
}
