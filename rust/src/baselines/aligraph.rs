//! AliGraph-like baseline (Zhu et al. 2019): same data-parallel sampled
//! training loop as DistDGL, but (a) the user-side *loading/partitioning
//! stage requires the whole graph in one node's memory* (the paper: "the
//! user must load the whole graph into memory and manually partition
//! it"), and (b) the per-batch path goes through the PyTorch-distributed
//! graph-store client, charged as a documented ×6 overhead on measured
//! kernel compute (calibrated to Table 2's single-node AliGraph/DistDGL
//! ratio).

use super::distdgl::GnnBaselineCfg;
use super::{overhead, BaselineResult};
use crate::data::GraphDataset;

pub fn epoch_time(g: &GraphDataset, cfg: &GnnBaselineCfg) -> BaselineResult {
    // Whole-graph load on one node: COO + feature matrix + labels, plus
    // the store's ×2 object overhead.
    let whole_graph = (g.n_edges as u64 * 24
        + g.n_nodes as u64 * g.feat_dim as u64 * 4
        + g.labeled.len() as u64 * g.n_labels as u64 * 4)
        * 2;
    if whole_graph > cfg.budget {
        return BaselineResult::Oom {
            needed: whole_graph,
            budget: cfg.budget,
        };
    }
    // After loading, training follows the DistDGL-shaped loop with the
    // AliGraph overhead factor.
    match super::distdgl::epoch_time(g, cfg) {
        BaselineResult::Time(t) => {
            BaselineResult::Time(t / overhead::DISTDGL * overhead::ALIGRAPH)
        }
        oom => oom,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::graphs::power_law_graph;
    use crate::dist::NetModel;

    fn cfg(workers: usize, budget: u64) -> GnnBaselineCfg {
        GnnBaselineCfg {
            workers,
            budget,
            batch: 64,
            hidden: 16,
            fanout: (10, 5),
            net: NetModel::default(),
        }
    }

    #[test]
    fn slower_than_distdgl_but_runs_small() {
        let g = power_law_graph("t", 800, 4000, 16, 8, 0.3, 51);
        let ta = epoch_time(&g, &cfg(4, u64::MAX)).time().unwrap();
        let td = super::super::distdgl::epoch_time(&g, &cfg(4, u64::MAX))
            .time()
            .unwrap();
        assert!(ta > td, "AliGraph should be slower: {ta} vs {td}");
    }

    #[test]
    fn ooms_when_whole_graph_exceeds_one_node() {
        let g = power_law_graph("t", 2000, 20_000, 32, 8, 0.3, 52);
        let whole = (g.n_edges as u64 * 24 + g.n_nodes as u64 * 32 * 4) * 2;
        // budget below the whole-graph load OOMs REGARDLESS of cluster
        // size — the paper's "AliGraph OOM everywhere" pattern.
        for w in [1, 4, 16] {
            assert!(matches!(
                epoch_time(&g, &cfg(w, whole / 2)),
                BaselineResult::Oom { .. }
            ));
        }
    }
}
