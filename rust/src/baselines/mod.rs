//! Comparator systems, reimplemented algorithmically.
//!
//! Each baseline runs the *real* algorithm (partitioning, neighbor
//! sampling, dense layer compute on the native kernels, allreduce /
//! parameter push-pull cost via `dist::NetModel`) on the same virtual
//! cluster as the RA engine: compute is measured, communication is
//! modeled, and memory is checked against the same scaled per-worker
//! budget. Where a real system's gap is engineering rather than
//! algorithmic (Python/PyTorch per-op dispatch, graph-store indirection),
//! a documented constant overhead factor is charged — see
//! `overhead` and DESIGN.md §Substitutions.
//!
//! OOM is reported as a *result* (`BaselineResult::Oom`), reproducing the
//! OOM cells of Tables 2–3 and Figures 2–3.

pub mod aligraph;
pub mod dask_nnmf;
pub mod dglke;
pub mod distdgl;
pub mod gnn_common;
pub mod mpi_nnmf;

/// Documented engineering-overhead multipliers on measured kernel
/// compute, calibrated to the paper's single-node ratios (Table 2,
/// cluster size 1): DistDGL's C++ core ≈ our native kernels (1.0);
/// AliGraph's PyTorch-dist + graph-store path runs ≈ 6× slower per batch
/// in the paper; Dask's dynamic scheduler ≈ 1.6×; hand-tuned MPI ≈ 0.9×
/// (no engine bookkeeping at all); DGL-KE ≈ 1.0×.
pub mod overhead {
    pub const DISTDGL: f64 = 1.0;
    pub const ALIGRAPH: f64 = 6.0;
    pub const DASK: f64 = 1.6;
    pub const MPI: f64 = 0.9;
    pub const DGLKE: f64 = 1.0;
}

/// Outcome of a baseline epoch/iteration measurement.
#[derive(Clone, Debug)]
pub enum BaselineResult {
    /// Modeled per-epoch (or per-100-iteration) seconds.
    Time(f64),
    /// Out of memory: needed vs budget bytes on the worst worker.
    Oom { needed: u64, budget: u64 },
}

impl BaselineResult {
    pub fn time(&self) -> Option<f64> {
        match self {
            BaselineResult::Time(t) => Some(*t),
            BaselineResult::Oom { .. } => None,
        }
    }

    pub fn display(&self) -> String {
        match self {
            BaselineResult::Time(t) => format!("{:.3}s", t),
            BaselineResult::Oom { .. } => "OOM".to_string(),
        }
    }
}
