//! Shared machinery for the data-parallel GNN baselines: edge-cut
//! partitioning, neighbor sampling, dense mini-batch GCN compute.

use crate::data::GraphDataset;
use crate::kernels::native::{matmul, matmul_tn};
use crate::ra::Chunk;
use crate::util::{FxHashMap, FxHashSet, Prng};

/// Greedy hash edge-cut partitioner (DistDGL uses METIS; a random/greedy
/// cut preserves the *memory* and *traffic* structure we model — the
/// paper's point is the tooling burden, not cut quality).
pub struct Partitioned {
    /// worker of each node
    pub owner: Vec<u32>,
    /// per-worker local edge count
    pub local_edges: Vec<usize>,
    /// edges crossing workers
    pub cut_edges: usize,
}

pub fn partition_graph(g: &GraphDataset, w: usize) -> Partitioned {
    let owner: Vec<u32> = (0..g.n_nodes)
        .map(|u| (crate::util::fxhash::hash_u64(u as u64) % w as u64) as u32)
        .collect();
    let mut local_edges = vec![0usize; w];
    let mut cut = 0usize;
    for &(u, v) in &g.edge_list {
        if owner[u as usize] == owner[v as usize] {
            local_edges[owner[u as usize] as usize] += 1;
        } else {
            cut += 1;
            local_edges[owner[u as usize] as usize] += 1;
            local_edges[owner[v as usize] as usize] += 1;
        }
    }
    Partitioned {
        owner,
        local_edges,
        cut_edges: cut,
    }
}

/// CSR adjacency for sampling.
pub struct Csr {
    pub offsets: Vec<u32>,
    pub targets: Vec<u32>,
}

pub fn build_csr(g: &GraphDataset) -> Csr {
    let mut deg = vec![0u32; g.n_nodes];
    for &(u, v) in &g.edge_list {
        deg[u as usize] += 1;
        deg[v as usize] += 1;
    }
    let mut offsets = vec![0u32; g.n_nodes + 1];
    for i in 0..g.n_nodes {
        offsets[i + 1] = offsets[i] + deg[i];
    }
    let mut targets = vec![0u32; offsets[g.n_nodes] as usize];
    let mut cursor = offsets.clone();
    for &(u, v) in &g.edge_list {
        targets[cursor[u as usize] as usize] = v;
        cursor[u as usize] += 1;
        targets[cursor[v as usize] as usize] = u;
        cursor[v as usize] += 1;
    }
    Csr { offsets, targets }
}

/// 2-hop neighbor sampling with fanouts (DGL defaults 25/10): returns the
/// sampled node set and sampled-edge count (for memory accounting).
pub fn sample_2hop(
    csr: &Csr,
    seeds: &[u32],
    fanout1: usize,
    fanout2: usize,
    rng: &mut Prng,
) -> (Vec<u32>, usize) {
    let (nodes, edges) = sample_2hop_edges(csr, seeds, fanout1, fanout2, rng);
    (nodes, edges.len())
}

/// Like `sample_2hop` but also returns the sampled (dst, src) edge pairs
/// — the exact message set a sampled GCN batch propagates over.
pub fn sample_2hop_edges(
    csr: &Csr,
    seeds: &[u32],
    fanout1: usize,
    fanout2: usize,
    rng: &mut Prng,
) -> (Vec<u32>, Vec<(u32, u32)>) {
    let mut nodes: FxHashSet<u32> = seeds.iter().copied().collect();
    let mut edges = Vec::new();
    let mut frontier: Vec<u32> = seeds.to_vec();
    for fanout in [fanout1, fanout2] {
        let mut next = Vec::new();
        for &u in &frontier {
            let (s, e) = (csr.offsets[u as usize] as usize, csr.offsets[u as usize + 1] as usize);
            let deg = e - s;
            let take = deg.min(fanout);
            for _ in 0..take {
                let v = csr.targets[s + rng.below(deg.max(1) as u64) as usize];
                edges.push((u, v));
                if nodes.insert(v) {
                    next.push(v);
                }
            }
        }
        frontier = next;
    }
    (nodes.into_iter().collect(), edges)
}

/// Dense 2-layer GCN forward+backward over a sampled subgraph: real
/// matmuls on the native kernels; returns (flops-equivalent chunks done,
/// activation bytes peak).
pub struct BatchCompute {
    pub act_bytes: u64,
    pub grad_w1: Chunk,
    pub grad_w2: Chunk,
}

pub fn dense_batch_step(
    feats: &FxHashMap<u32, Vec<f32>>,
    nodes: &[u32],
    feat_dim: usize,
    hidden: usize,
    n_labels: usize,
    w1: &Chunk,
    w2: &Chunk,
) -> BatchCompute {
    let n = nodes.len();
    // gather features into a dense (n, F) matrix (the real DGL gather)
    let mut x = vec![0f32; n * feat_dim];
    for (i, &u) in nodes.iter().enumerate() {
        if let Some(f) = feats.get(&u) {
            x[i * feat_dim..(i + 1) * feat_dim].copy_from_slice(f);
        }
    }
    let xm = Chunk::from_vec(n, feat_dim, x);
    let h1 = matmul(&xm, w1).map(|v| v.max(0.0)); // (n, hidden)
    let z = matmul(&h1, w2); // (n, labels)
    // softmax-xent backward with fake one-hot (class = node id % labels)
    let mut gz = z.clone();
    {
        let d = gz.data_mut();
        for i in 0..n {
            let row = &mut d[i * n_labels..(i + 1) * n_labels];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut s = 0.0;
            for v in row.iter_mut() {
                *v = (*v - m).exp();
                s += *v;
            }
            for v in row.iter_mut() {
                *v /= s;
            }
            row[(nodes[i] as usize) % n_labels] -= 1.0;
        }
    }
    let grad_w2 = matmul_tn(&h1, &gz);
    let gh1 = crate::kernels::native::matmul_nt(&gz, w2);
    let grad_w1 = matmul_tn(&xm, &gh1);
    let act_bytes = (n * (feat_dim + hidden + n_labels) * 4) as u64;
    BatchCompute {
        act_bytes,
        grad_w1,
        grad_w2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::graphs::power_law_graph;

    #[test]
    fn partition_covers_all_nodes() {
        let g = power_law_graph("t", 200, 800, 8, 4, 0.3, 31);
        let p = partition_graph(&g, 4);
        assert_eq!(p.owner.len(), 200);
        assert!(p.cut_edges > 0, "hash cut should cross workers");
        assert!(p.local_edges.iter().sum::<usize>() >= g.n_edges);
    }

    #[test]
    fn csr_roundtrip_degrees() {
        let g = power_law_graph("t", 100, 300, 4, 3, 0.3, 32);
        let csr = build_csr(&g);
        assert_eq!(csr.targets.len(), g.n_edges * 2);
        let deg0 = (csr.offsets[1] - csr.offsets[0]) as usize;
        assert!(deg0 <= g.n_edges * 2);
    }

    #[test]
    fn sampling_bounded_by_fanout() {
        let g = power_law_graph("t", 300, 2000, 4, 3, 0.3, 33);
        let csr = build_csr(&g);
        let mut rng = Prng::new(1);
        let seeds: Vec<u32> = (0..10).collect();
        let (nodes, edges) = sample_2hop(&csr, &seeds, 5, 3, &mut rng);
        assert!(nodes.len() >= 10);
        // 10 seeds × ≤5 + ≤50×3 second hop
        assert!(edges <= 10 * 5 + 50 * 3);
    }

    #[test]
    fn dense_batch_produces_gradients() {
        let g = power_law_graph("t", 50, 150, 8, 4, 0.5, 34);
        let feats: FxHashMap<u32, Vec<f32>> = (0..50)
            .map(|u| {
                (
                    u as u32,
                    g.feats
                        .get(&crate::ra::Key::k1(u))
                        .unwrap()
                        .data()
                        .to_vec(),
                )
            })
            .collect();
        let w1 = Chunk::filled(8, 6, 0.1);
        let w2 = Chunk::filled(6, 4, 0.1);
        let nodes: Vec<u32> = (0..20).collect();
        let out = dense_batch_step(&feats, &nodes, 8, 6, 4, &w1, &w2);
        assert_eq!(out.grad_w1.shape(), (8, 6));
        assert_eq!(out.grad_w2.shape(), (6, 4));
        assert!(out.act_bytes > 0);
        assert!(out.grad_w2.sq_norm() > 0.0);
    }
}
