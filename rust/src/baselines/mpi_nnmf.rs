//! Hand-written MPI-style NNMF baseline: the careful BSP implementation
//! the paper compares against. Row-partitioned W and V, replicated H;
//! each epoch is local block matmuls + one allreduce of dH — streaming
//! reductions, no materialized intermediates, essentially no framework
//! overhead (×0.9: no engine bookkeeping at all).

use super::dask_nnmf::{NnmfCase, NnmfWork};
use super::{overhead, BaselineResult};
use crate::dist::NetModel;

pub fn epoch_time(
    case: &NnmfCase,
    work: &NnmfWork,
    workers: usize,
    budget: u64,
    net: &NetModel,
) -> BaselineResult {
    let (nb, db) = case.blocks();
    let c2 = (case.chunk * case.chunk * 4) as u64;
    // per-worker memory: V rows + W rows + full H replica + running acc.
    let per_worker = (nb as u64 * nb as u64 * c2) / workers as u64 // V rows
        + (nb as u64 * db as u64 * c2) / workers as u64            // W rows
        + db as u64 * nb as u64 * c2                               // H replica
        + db as u64 * nb as u64 * c2; // dH accumulator
    if per_worker > budget {
        return BaselineResult::Oom {
            needed: per_worker,
            budget,
        };
    }
    let compute = work.compute_s * overhead::MPI / workers as f64;
    let comm = net.allreduce_time(db as u64 * nb as u64 * c2, workers);
    BaselineResult::Time(compute + comm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::dask_nnmf::measure_epoch;

    #[test]
    fn mpi_beats_dask_given_same_work() {
        let case = NnmfCase {
            n: 128,
            d: 64,
            chunk: 32,
        };
        let work = measure_epoch(&case, 5);
        let net = NetModel::default();
        let tm = epoch_time(&case, &work, 4, u64::MAX, &net).time().unwrap();
        let td = crate::baselines::dask_nnmf::epoch_time(&work, 4, u64::MAX, &net)
            .time()
            .unwrap();
        assert!(tm < td, "MPI {tm} should beat Dask {td}");
    }

    #[test]
    fn replica_memory_ooms() {
        let case = NnmfCase {
            n: 128,
            d: 96,
            chunk: 32,
        };
        let work = measure_epoch(&case, 6);
        assert!(matches!(
            epoch_time(&case, &work, 16, 10_000, &NetModel::default()),
            BaselineResult::Oom { .. }
        ));
    }
}
