//! DistDGL-like baseline (Zheng et al. 2020): data-parallel mini-batch
//! GNN training — partition the graph, sample 2-hop neighborhoods per
//! batch, dense per-batch compute, ring-allreduce the weight gradients.
//!
//! Memory model per worker (checked against the scaled budget, policy =
//! Fail): graph partition in COO+CSR (≈24 B/edge), local features with
//! halo replication proportional to the edge-cut fraction, sampled
//! subgraph + activations, ×2 framework overhead (graph store + Python
//! object headers, per DGL's own memory reporting).

use super::gnn_common::{build_csr, dense_batch_step, partition_graph, sample_2hop};
use super::{overhead, BaselineResult};
use crate::data::GraphDataset;
use crate::dist::NetModel;
use crate::ra::Chunk;
use crate::util::{FxHashMap, Prng};
use std::time::Instant;

pub struct GnnBaselineCfg {
    pub workers: usize,
    pub budget: u64,
    pub batch: usize,
    pub hidden: usize,
    pub fanout: (usize, usize),
    pub net: NetModel,
}

pub fn epoch_time(g: &GraphDataset, cfg: &GnnBaselineCfg) -> BaselineResult {
    let w = cfg.workers;
    let part = partition_graph(g, w);
    let cut_frac = part.cut_edges as f64 / g.n_edges.max(1) as f64;

    // ---- memory check (worst worker) ----
    let max_local_edges = *part.local_edges.iter().max().unwrap_or(&0) as u64;
    let graph_bytes = max_local_edges * 24; // COO + CSR + edge ids
    let feat_bytes = (g.n_nodes as u64 / w as u64) * (g.feat_dim as u64) * 4;
    let halo_bytes = (feat_bytes as f64 * cut_frac) as u64;
    let batch_nodes_est = cfg.batch * (1 + cfg.fanout.0 + cfg.fanout.0 * cfg.fanout.1);
    let act_bytes =
        (batch_nodes_est * (g.feat_dim + cfg.hidden + g.n_labels) * 4) as u64;
    let needed = (graph_bytes + feat_bytes + halo_bytes + act_bytes) * 2; // framework 2×
    if needed > cfg.budget {
        return BaselineResult::Oom {
            needed,
            budget: cfg.budget,
        };
    }

    // ---- real compute: run this worker's share of batches ----
    let csr = build_csr(g);
    let feats: FxHashMap<u32, Vec<f32>> = g
        .feats
        .iter()
        .map(|(k, v)| (k.get(0) as u32, v.data().to_vec()))
        .collect();
    let mut rng = Prng::new(0xD61);
    let w1 = Chunk::random(g.feat_dim, cfg.hidden, &mut rng, 0.1);
    let w2 = Chunk::random(cfg.hidden, g.n_labels, &mut rng, 0.1);

    let n_batches = g.labeled.len().div_ceil(cfg.batch).max(1);
    let batches_per_worker = n_batches.div_ceil(w);
    let mut compute_s = 0.0f64;
    let mut sample_s = 0.0f64;
    for _ in 0..batches_per_worker {
        let seeds: Vec<u32> = (0..cfg.batch.min(g.labeled.len()))
            .map(|_| g.labeled[rng.below(g.labeled.len() as u64) as usize])
            .collect();
        let t0 = Instant::now();
        let (nodes, _edges) = sample_2hop(&csr, &seeds, cfg.fanout.0, cfg.fanout.1, &mut rng);
        sample_s += t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let _ = dense_batch_step(
            &feats,
            &nodes,
            g.feat_dim,
            cfg.hidden,
            g.n_labels,
            &w1,
            &w2,
        );
        compute_s += t1.elapsed().as_secs_f64();
    }

    // ---- comms: allreduce W1+W2 grads each batch; remote-halo feature
    // fetches proportional to the cut fraction ----
    let grad_bytes = ((g.feat_dim * cfg.hidden + cfg.hidden * g.n_labels) * 4) as u64;
    let halo_fetch =
        (batch_nodes_est as f64 * cut_frac * g.feat_dim as f64 * 4.0) as u64;
    let comm_s = batches_per_worker as f64
        * (cfg.net.allreduce_time(grad_bytes, w)
            + cfg.net.shuffle_time(halo_fetch, w));

    BaselineResult::Time(
        (compute_s + sample_s) * overhead::DISTDGL + comm_s,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::graphs::power_law_graph;

    fn cfg(workers: usize, budget: u64) -> GnnBaselineCfg {
        GnnBaselineCfg {
            workers,
            budget,
            batch: 64,
            hidden: 16,
            fanout: (10, 5),
            net: NetModel::default(),
        }
    }

    #[test]
    fn runs_and_scales_with_workers() {
        let g = power_law_graph("t", 1000, 5000, 16, 8, 0.3, 41);
        let t1 = epoch_time(&g, &cfg(1, u64::MAX)).time().unwrap();
        let t8 = epoch_time(&g, &cfg(8, u64::MAX)).time().unwrap();
        assert!(t8 < t1, "no scaling: t1={t1} t8={t8}");
    }

    #[test]
    fn ooms_under_tiny_budget() {
        let g = power_law_graph("t", 1000, 5000, 16, 8, 0.3, 42);
        match epoch_time(&g, &cfg(2, 10_000)) {
            BaselineResult::Oom { needed, budget } => {
                assert!(needed > budget);
            }
            BaselineResult::Time(_) => panic!("expected OOM"),
        }
    }

    #[test]
    fn more_workers_relieve_memory_pressure() {
        let g = power_law_graph("t", 2000, 20_000, 32, 8, 0.3, 43);
        // find a budget that OOMs at w=1 but fits at w=16 (the Table 3
        // pattern for papers100M)
        let needed1 = match epoch_time(&g, &cfg(1, 1)) {
            BaselineResult::Oom { needed, .. } => needed,
            _ => panic!(),
        };
        let budget = needed1 * 2 / 3;
        assert!(matches!(
            epoch_time(&g, &cfg(1, budget)),
            BaselineResult::Oom { .. }
        ));
        assert!(epoch_time(&g, &cfg(16, budget)).time().is_some());
    }
}
