//! Dask-like NNMF baseline (Rocklin 2015): blocked task-graph execution.
//!
//! Dask expresses `‖V − WH‖²` as a task graph over blocks; its scheduler
//! (a) charges a per-task dispatch overhead (~200 µs/task, Dask's own
//! documented scheduler throughput) and (b) *materializes the full
//! intermediate product set* of `W ⊗ H` before the tree-reduction — the
//! paper's observed failure mode ("Dask heavily relies on the large
//! memory capacity … and runs OOM during backward propagation").
//! Compute is real: every block matmul actually executes.

use super::{overhead, BaselineResult};
use crate::dist::NetModel;
use crate::kernels::native::{matmul, matmul_nt, matmul_tn};
use crate::ra::Chunk;
use crate::util::Prng;
use std::time::Instant;

#[derive(Clone, Copy)]
pub struct NnmfCase {
    /// matrix side (V is n × n)
    pub n: usize,
    /// factorization rank
    pub d: usize,
    pub chunk: usize,
}

impl NnmfCase {
    pub fn blocks(&self) -> (usize, usize) {
        (self.n.div_ceil(self.chunk), self.d.div_ceil(self.chunk))
    }
}

/// Measured per-epoch work of the blocked NNMF sweep (forward product +
/// both factor gradients), executed for real once; reused across cluster
/// sizes by the caller.
pub struct NnmfWork {
    pub compute_s: f64,
    pub n_tasks: u64,
    /// bytes of all W⊗H intermediate product blocks
    pub intermediate_bytes: u64,
    /// bytes of one factor's gradient (allreduce payload)
    pub grad_bytes: u64,
}

pub fn measure_epoch(case: &NnmfCase, seed: u64) -> NnmfWork {
    let (nb, db) = case.blocks();
    let c = case.chunk;
    let mut rng = Prng::new(seed);
    let w: Vec<Chunk> = (0..nb * db).map(|_| Chunk::random(c, c, &mut rng, 0.3)).collect();
    let h: Vec<Chunk> = (0..db * nb).map(|_| Chunk::random(c, c, &mut rng, 0.3)).collect();
    let v: Vec<Chunk> = (0..nb * nb).map(|_| Chunk::random(c, c, &mut rng, 0.3)).collect();

    let t0 = Instant::now();
    let mut n_tasks = 0u64;
    // forward: R(i,j) = Σ_k W(i,k)·H(k,j) − V(i,j)
    let mut resid: Vec<Chunk> = Vec::with_capacity(nb * nb);
    for i in 0..nb {
        for j in 0..nb {
            let mut acc = Chunk::zeros(c, c);
            for k in 0..db {
                acc.add_assign(&matmul(&w[i * db + k], &h[k * nb + j]));
                n_tasks += 1;
            }
            acc.add_assign(&v[i * nb + j].map(|x| -x));
            resid.push(acc);
            n_tasks += 1;
        }
    }
    // backward: dW(i,k) = Σ_j R(i,j)·H(k,j)ᵀ ; dH(k,j) = Σ_i W(i,k)ᵀ·R(i,j)
    for i in 0..nb {
        for k in 0..db {
            let mut acc = Chunk::zeros(c, c);
            for j in 0..nb {
                acc.add_assign(&matmul_nt(&resid[i * nb + j], &h[k * nb + j]));
                n_tasks += 1;
            }
        }
    }
    for k in 0..db {
        for j in 0..nb {
            let mut acc = Chunk::zeros(c, c);
            for i in 0..nb {
                acc.add_assign(&matmul_tn(&w[i * db + k], &resid[i * nb + j]));
                n_tasks += 1;
            }
        }
    }
    let compute_s = t0.elapsed().as_secs_f64();
    NnmfWork {
        compute_s,
        n_tasks,
        // every (i,k,j) product block materialized pre-reduction
        intermediate_bytes: (nb * db * nb) as u64 * (c * c * 4) as u64,
        grad_bytes: (nb * db) as u64 * (c * c * 4) as u64,
    }
}

/// Dask's per-task scheduler dispatch cost (documented constant).
pub const TASK_OVERHEAD_S: f64 = 200e-6;

pub fn epoch_time(work: &NnmfWork, workers: usize, budget: u64, net: &NetModel) -> BaselineResult {
    // Materialized intermediates spread across the cluster must fit.
    let per_worker = work.intermediate_bytes / workers as u64;
    if per_worker > budget {
        return BaselineResult::Oom {
            needed: per_worker,
            budget,
        };
    }
    let compute = work.compute_s * overhead::DASK / workers as f64;
    let sched = work.n_tasks as f64 * TASK_OVERHEAD_S / workers as f64;
    // shuffle of intermediate blocks to their reduction sites
    let comm = net.shuffle_time(work.intermediate_bytes, workers);
    BaselineResult::Time(compute + sched + comm)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales_and_ooms() {
        let case = NnmfCase {
            n: 128,
            d: 64,
            chunk: 32,
        };
        let work = measure_epoch(&case, 3);
        assert!(work.compute_s > 0.0);
        assert!(work.n_tasks > 0);
        let net = NetModel::default();
        let t2 = epoch_time(&work, 2, u64::MAX, &net).time().unwrap();
        let t8 = epoch_time(&work, 8, u64::MAX, &net).time().unwrap();
        assert!(t8 < t2);
        assert!(matches!(
            epoch_time(&work, 2, 1024, &net),
            BaselineResult::Oom { .. }
        ));
    }

    #[test]
    fn intermediates_grow_with_rank() {
        let small = NnmfCase { n: 128, d: 32, chunk: 32 };
        let big = NnmfCase { n: 128, d: 96, chunk: 32 };
        let (nb, db_s) = small.blocks();
        let (_, db_b) = big.blocks();
        assert!(db_b > db_s);
        let ws = measure_epoch(&small, 1);
        let wb = measure_epoch(&big, 1);
        assert!(wb.intermediate_bytes > ws.intermediate_bytes);
        let _ = nb;
    }
}
