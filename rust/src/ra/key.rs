//! Composite tuple keys.
//!
//! The paper makes no assumption about the form of a key beyond it being a
//! (possibly composite) value; every key arising in our workloads and in
//! the Section 4 RJP constructions is a short tuple of integers (the RJP
//! for join concatenates an input key with an output key, so widths up to
//! `MAX_KEY` = 8 cover two rank-2 block indices plus slack).

use std::fmt;

/// Maximum number of key components (inline, no allocation).
pub const MAX_KEY: usize = 8;

/// A composite key: an inline tuple of up to `MAX_KEY` i64 components.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Key {
    len: u8,
    comps: [i64; MAX_KEY],
}

impl Key {
    /// The empty key `⟨⟩` (used by constant grouping functions, e.g. the
    /// single loss tuple).
    #[inline]
    pub fn empty() -> Key {
        Key {
            len: 0,
            comps: [0; MAX_KEY],
        }
    }

    #[inline]
    pub fn new(comps: &[i64]) -> Key {
        assert!(comps.len() <= MAX_KEY, "key too wide: {}", comps.len());
        let mut c = [0i64; MAX_KEY];
        c[..comps.len()].copy_from_slice(comps);
        Key {
            len: comps.len() as u8,
            comps: c,
        }
    }

    /// Single-component key.
    #[inline]
    pub fn k1(a: i64) -> Key {
        Key::new(&[a])
    }

    /// Two-component key.
    #[inline]
    pub fn k2(a: i64, b: i64) -> Key {
        Key::new(&[a, b])
    }

    /// Three-component key.
    #[inline]
    pub fn k3(a: i64, b: i64, c: i64) -> Key {
        Key::new(&[a, b, c])
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len as usize
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn get(&self, i: usize) -> i64 {
        debug_assert!(i < self.len());
        self.comps[i]
    }

    #[inline]
    pub fn as_slice(&self) -> &[i64] {
        &self.comps[..self.len()]
    }

    /// `⟨self…, other…⟩` — used by the join RJP (`proj₂(keyL, keyR) ↦
    /// ⟨keyL, proj(keyL, keyR)⟩`).
    #[inline]
    pub fn concat(&self, other: &Key) -> Key {
        let n = self.len() + other.len();
        assert!(n <= MAX_KEY, "concatenated key too wide: {n}");
        let mut c = [0i64; MAX_KEY];
        c[..self.len()].copy_from_slice(self.as_slice());
        c[self.len()..n].copy_from_slice(other.as_slice());
        Key {
            len: n as u8,
            comps: c,
        }
    }

    #[inline]
    pub fn push(&self, v: i64) -> Key {
        let n = self.len();
        assert!(n < MAX_KEY);
        let mut c = self.comps;
        c[n] = v;
        Key {
            len: self.len + 1,
            comps: c,
        }
    }

    /// Stable 64-bit hash of the key (used for hash-partitioning across
    /// workers — must be identical on every worker, unlike `Hash`).
    #[inline]
    pub fn stable_hash(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for i in 0..self.len() {
            h = crate::util::fxhash::hash_u64(h ^ self.comps[i] as u64);
        }
        h
    }

    /// Hash of a subset of components (partition on the join key only).
    #[inline]
    pub fn stable_hash_of(&self, comps: &[usize]) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &i in comps {
            h = crate::util::fxhash::hash_u64(h ^ self.get(i) as u64);
        }
        h
    }
}

impl fmt::Debug for Key {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "⟨")?;
        for (i, c) in self.as_slice().iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{c}")?;
        }
        write!(f, "⟩")
    }
}

impl fmt::Display for Key {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_access() {
        let k = Key::k3(1, 2, 3);
        assert_eq!(k.len(), 3);
        assert_eq!(k.get(0), 1);
        assert_eq!(k.get(2), 3);
        assert_eq!(k.as_slice(), &[1, 2, 3]);
    }

    #[test]
    fn empty_key() {
        let k = Key::empty();
        assert!(k.is_empty());
        assert_eq!(format!("{k}"), "⟨⟩");
    }

    #[test]
    fn concat_and_push() {
        let a = Key::k2(1, 2);
        let b = Key::k1(9);
        assert_eq!(a.concat(&b), Key::k3(1, 2, 9));
        assert_eq!(a.push(7), Key::k3(1, 2, 7));
    }

    #[test]
    fn equality_ignores_unused_slots() {
        let a = Key::new(&[5]);
        let b = Key::k2(5, 0);
        assert_ne!(a, b); // different length
        assert_eq!(a, Key::k1(5));
    }

    #[test]
    fn stable_hash_consistency() {
        let a = Key::k2(3, 4);
        assert_eq!(a.stable_hash(), Key::k2(3, 4).stable_hash());
        assert_ne!(a.stable_hash(), Key::k2(4, 3).stable_hash());
        // Hash of join-key subset matches regardless of other comps.
        let x = Key::k3(1, 7, 2);
        let y = Key::k3(9, 7, 5);
        assert_eq!(x.stable_hash_of(&[1]), y.stable_hash_of(&[1]));
    }

    #[test]
    #[should_panic]
    fn too_wide_panics() {
        Key::new(&[0; MAX_KEY + 1]);
    }

    #[test]
    fn ordering_is_lexicographic_within_len() {
        assert!(Key::k2(1, 2) < Key::k2(1, 3));
        assert!(Key::k2(1, 9) < Key::k2(2, 0));
    }
}
