//! Relations: finite maps from keys to chunks, with insertion order kept
//! for deterministic iteration (tests, partition-stable shuffles).

use super::chunk::Chunk;
use super::key::Key;
use crate::util::FxHashMap;
use std::sync::Arc;

#[derive(Clone, Default)]
pub struct Relation {
    pairs: Vec<(Key, Chunk)>,
    index: FxHashMap<Key, u32>,
}

impl Relation {
    pub fn new() -> Relation {
        Relation::default()
    }

    pub fn with_capacity(n: usize) -> Relation {
        Relation {
            pairs: Vec::with_capacity(n),
            index: FxHashMap::with_capacity_and_hasher(n, Default::default()),
        }
    }

    pub fn from_pairs(pairs: Vec<(Key, Chunk)>) -> Relation {
        let mut r = Relation::with_capacity(pairs.len());
        for (k, v) in pairs {
            r.insert(k, v);
        }
        r
    }

    /// Assemble a relation from a pre-built index. `index` must map each
    /// key of `pairs` to its position, exactly as [`from_pairs`] would
    /// have built it — the caller vouches for agreement (checked in
    /// debug builds). The pooled gather uses this to merge per-shard
    /// index maps built in parallel instead of re-hashing every key on
    /// the driver.
    ///
    /// [`from_pairs`]: Self::from_pairs
    pub(crate) fn from_pairs_indexed(
        pairs: Vec<(Key, Chunk)>,
        index: FxHashMap<Key, u32>,
    ) -> Relation {
        debug_assert_eq!(pairs.len(), index.len());
        debug_assert!(pairs
            .iter()
            .enumerate()
            .all(|(i, (k, _))| index.get(k) == Some(&(i as u32))));
        Relation { pairs, index }
    }

    /// Insert a tuple; duplicate keys are a semantic error in the
    /// functional RA (a relation is a function from keys to values).
    pub fn insert(&mut self, key: Key, value: Chunk) {
        let id = self.pairs.len() as u32;
        let prev = self.index.insert(key, id);
        assert!(prev.is_none(), "duplicate key {key} inserted into relation");
        self.pairs.push((key, value));
    }

    /// Insert-or-combine (the aggregation hot path).
    pub fn merge(&mut self, key: Key, value: Chunk, combine: impl Fn(&mut Chunk, &Chunk)) {
        match self.index.get(&key) {
            Some(&id) => combine(&mut self.pairs[id as usize].1, &value),
            None => self.insert(key, value),
        }
    }

    /// Insert-or-add (Σ with ⊕ = +, and the total-derivative `add`).
    pub fn merge_add(&mut self, key: Key, value: Chunk) {
        match self.index.get(&key) {
            Some(&id) => self.pairs[id as usize].1.add_assign(&value),
            None => self.insert(key, value),
        }
    }

    #[inline]
    pub fn get(&self, key: &Key) -> Option<&Chunk> {
        self.index.get(key).map(|&id| &self.pairs[id as usize].1)
    }

    #[inline]
    pub fn contains(&self, key: &Key) -> bool {
        self.index.contains_key(key)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &(Key, Chunk)> {
        self.pairs.iter()
    }

    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut (Key, Chunk)> {
        self.pairs.iter_mut()
    }

    pub fn pairs(&self) -> &[(Key, Chunk)] {
        &self.pairs
    }

    pub fn into_pairs(self) -> Vec<(Key, Chunk)> {
        self.pairs
    }

    /// Total payload bytes (keys + chunk data), for memory accounting.
    pub fn nbytes(&self) -> usize {
        self.pairs
            .iter()
            .map(|(_, c)| c.nbytes() + std::mem::size_of::<Key>())
            .sum()
    }

    /// Key width of the first tuple (relations are homogeneous).
    pub fn key_arity(&self) -> Option<usize> {
        self.pairs.first().map(|(k, _)| k.len())
    }

    /// Deterministically ordered copy of the pairs (tests/printing).
    pub fn sorted_pairs(&self) -> Vec<(Key, Chunk)> {
        let mut v = self.pairs.clone();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Exact structural equality up to tuple order and `tol` on values.
    pub fn approx_eq(&self, other: &Relation, tol: f32) -> bool {
        if self.len() != other.len() {
            return false;
        }
        self.pairs.iter().all(|(k, v)| match other.get(k) {
            Some(w) => v.approx_eq(w, tol),
            None => false,
        })
    }

    /// Largest absolute difference across matching keys; `None` if key
    /// sets differ.
    pub fn max_abs_diff(&self, other: &Relation) -> Option<f32> {
        if self.len() != other.len() {
            return None;
        }
        let mut m = 0.0f32;
        for (k, v) in &self.pairs {
            let w = other.get(k)?;
            if v.shape() != w.shape() {
                return None;
            }
            m = m.max(v.max_abs_diff(w));
        }
        Some(m)
    }
}

impl std::fmt::Debug for Relation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "Relation({} tuples, {} B)", self.len(), self.nbytes())?;
        for (k, v) in self.sorted_pairs().iter().take(12) {
            writeln!(f, "  {k} -> {v:?}")?;
        }
        if self.len() > 12 {
            writeln!(f, "  …")?;
        }
        Ok(())
    }
}

/// Shared, immutable relation handle (tapes and constants).
pub type RelRef = Arc<Relation>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get() {
        let mut r = Relation::new();
        r.insert(Key::k2(0, 1), Chunk::scalar(3.0));
        assert_eq!(r.get(&Key::k2(0, 1)).unwrap().as_scalar(), 3.0);
        assert!(r.get(&Key::k2(1, 0)).is_none());
        assert_eq!(r.len(), 1);
        assert_eq!(r.key_arity(), Some(2));
    }

    #[test]
    #[should_panic]
    fn duplicate_key_panics() {
        let mut r = Relation::new();
        r.insert(Key::k1(0), Chunk::scalar(1.0));
        r.insert(Key::k1(0), Chunk::scalar(2.0));
    }

    #[test]
    fn merge_add_combines() {
        let mut r = Relation::new();
        r.merge_add(Key::k1(0), Chunk::scalar(1.0));
        r.merge_add(Key::k1(0), Chunk::scalar(2.0));
        r.merge_add(Key::k1(1), Chunk::scalar(5.0));
        assert_eq!(r.len(), 2);
        assert_eq!(r.get(&Key::k1(0)).unwrap().as_scalar(), 3.0);
    }

    #[test]
    fn approx_eq_unordered() {
        let a = Relation::from_pairs(vec![
            (Key::k1(0), Chunk::scalar(1.0)),
            (Key::k1(1), Chunk::scalar(2.0)),
        ]);
        let b = Relation::from_pairs(vec![
            (Key::k1(1), Chunk::scalar(2.0)),
            (Key::k1(0), Chunk::scalar(1.0)),
        ]);
        assert!(a.approx_eq(&b, 1e-6));
        assert_eq!(a.max_abs_diff(&b), Some(0.0));
    }

    #[test]
    fn nbytes_accounts_chunks() {
        let mut r = Relation::new();
        r.insert(Key::k1(0), Chunk::zeros(4, 4));
        assert_eq!(r.nbytes(), 64 + std::mem::size_of::<Key>());
    }
}
