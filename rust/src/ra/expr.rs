//! The functional-RA query DAG (Section 2.2).
//!
//! A `Query` is a higher-order function `𝔽(K₁,…,Kₙ) → 𝔽(K_o)`: it takes n
//! input relations (one per `Scan` slot) and produces one output relation.
//! Nodes are stored in topological order (children always precede
//! parents — enforced by the builder), which is exactly the order
//! Algorithm 2 needs for its forward execution and reverse sweep.
//!
//! `⋈const` (join with a constant relation) is represented as a `Join`
//! whose child is a `Const` node; gradients do not flow into `Const`.

use super::funcs::{JoinPred, KeyPred, KeyProj, KeyProj2};
use super::relation::Relation;
use crate::kernels::{AggKernel, BinaryKernel, UnaryKernel};
use std::fmt;
use std::sync::Arc;

pub type NodeId = usize;

#[derive(Clone)]
pub enum Op {
    /// TableScan `τ(K)`: returns the `slot`-th input relation.
    Scan { slot: usize, name: String },
    /// A constant relation (the constant side of `⋈const`).
    Const { rel: Arc<Relation>, name: String },
    /// Selection `σ(pred, proj, ⊙, ·)`.
    Select {
        pred: KeyPred,
        proj: KeyProj,
        kernel: UnaryKernel,
    },
    /// Join `⋈(pred, proj, ⊗, ·, ·)` — children `[left, right]`.
    Join {
        pred: JoinPred,
        proj: KeyProj2,
        kernel: BinaryKernel,
    },
    /// Aggregation `Σ(grp, ⊕, ·)`.
    Agg { grp: KeyProj, agg: AggKernel },
    /// `add(·, ·)`: pointwise sum of two queries over the same key set
    /// (needed for the total derivative, Section 5).
    AddQ,
}

impl Op {
    pub fn kind(&self) -> &'static str {
        match self {
            Op::Scan { .. } => "τ",
            Op::Const { .. } => "const",
            Op::Select { .. } => "σ",
            Op::Join { .. } => "⋈",
            Op::Agg { .. } => "Σ",
            Op::AddQ => "add",
        }
    }
}

#[derive(Clone)]
pub struct Node {
    pub op: Op,
    pub children: Vec<NodeId>,
}

#[derive(Clone)]
pub struct Query {
    pub nodes: Vec<Node>,
    pub output: NodeId,
    /// Number of scan slots (input relations).
    pub n_slots: usize,
}

impl Query {
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// For every node, the list of (parent, which-child-index) consumers.
    pub fn consumers(&self) -> Vec<Vec<(NodeId, usize)>> {
        let mut out = vec![Vec::new(); self.nodes.len()];
        for (p, node) in self.nodes.iter().enumerate() {
            for (ci, &c) in node.children.iter().enumerate() {
                out[c].push((p, ci));
            }
        }
        out
    }

    /// Scan node id for a given input slot (panics if the slot is unused).
    pub fn scan_node(&self, slot: usize) -> NodeId {
        self.nodes
            .iter()
            .position(|n| matches!(&n.op, Op::Scan { slot: s, .. } if *s == slot))
            .unwrap_or_else(|| panic!("no scan node for slot {slot}"))
    }

    /// Which nodes lie on a path from a requested input slot to the
    /// output — i.e. the nodes whose gradient the reverse sweep must
    /// compute. Skipping the rest avoids differentiating w.r.t. labels /
    /// data relations (whose kernels may have no vjp on that side).
    pub fn needed_for_slots(&self, slots: &[usize]) -> Vec<bool> {
        let mut needed = vec![false; self.nodes.len()];
        for (i, node) in self.nodes.iter().enumerate() {
            needed[i] = match &node.op {
                Op::Scan { slot, .. } => slots.contains(slot),
                Op::Const { .. } => false,
                _ => node.children.iter().any(|&c| needed[c]),
            };
        }
        needed
    }

    /// Pretty multi-line rendering of the DAG (used by examples/tests and
    /// the Fig. 5-style backward-query dumps).
    pub fn render(&self) -> String {
        let mut s = String::new();
        for (i, n) in self.nodes.iter().enumerate() {
            let desc = match &n.op {
                Op::Scan { slot, name } => format!("τ(slot={slot} \"{name}\")"),
                Op::Const { rel, name } => format!("const(\"{name}\", {} tuples)", rel.len()),
                Op::Select { pred, proj, kernel } => {
                    format!("σ(pred={pred:?}, proj={proj}, ⊙={})", kernel.name())
                }
                Op::Join { pred, proj, kernel } => {
                    format!("⋈(pred={pred}, proj={proj}, ⊗={})", kernel.name())
                }
                Op::Agg { grp, agg } => format!("Σ(grp={grp}, ⊕={})", agg.name()),
                Op::AddQ => "add".to_string(),
            };
            let kids = if n.children.is_empty() {
                String::new()
            } else {
                format!("  <- {:?}", n.children)
            };
            let mark = if i == self.output { " (output)" } else { "" };
            s.push_str(&format!("v{i}: {desc}{kids}{mark}\n"));
        }
        s
    }

    /// Operator counts by kind — used by tests asserting the structure of
    /// generated backward queries (e.g. "the optimized plan has no Σ").
    pub fn op_counts(&self) -> std::collections::BTreeMap<&'static str, usize> {
        let mut m = std::collections::BTreeMap::new();
        for n in &self.nodes {
            *m.entry(n.op.kind()).or_insert(0) += 1;
        }
        m
    }
}

impl fmt::Debug for Query {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.render())
    }
}

/// Builder: children must exist before parents, so node ids are already a
/// topological order.
#[derive(Default)]
pub struct QueryBuilder {
    nodes: Vec<Node>,
    n_slots: usize,
}

impl QueryBuilder {
    pub fn new() -> QueryBuilder {
        QueryBuilder::default()
    }

    fn push(&mut self, op: Op, children: Vec<NodeId>) -> NodeId {
        for &c in &children {
            assert!(c < self.nodes.len(), "child {c} does not exist yet");
        }
        self.nodes.push(Node { op, children });
        self.nodes.len() - 1
    }

    /// `τ`: scan input slot `slot`.
    pub fn scan(&mut self, slot: usize, name: &str) -> NodeId {
        self.n_slots = self.n_slots.max(slot + 1);
        self.push(
            Op::Scan {
                slot,
                name: name.to_string(),
            },
            vec![],
        )
    }

    pub fn constant(&mut self, rel: Arc<Relation>, name: &str) -> NodeId {
        self.push(
            Op::Const {
                rel,
                name: name.to_string(),
            },
            vec![],
        )
    }

    pub fn select(
        &mut self,
        pred: KeyPred,
        proj: KeyProj,
        kernel: UnaryKernel,
        input: NodeId,
    ) -> NodeId {
        self.push(Op::Select { pred, proj, kernel }, vec![input])
    }

    /// Convenience: apply a unary kernel keeping keys unchanged.
    pub fn map(&mut self, kernel: UnaryKernel, key_arity: usize, input: NodeId) -> NodeId {
        self.select(
            KeyPred::always(),
            KeyProj::identity(key_arity),
            kernel,
            input,
        )
    }

    pub fn join(
        &mut self,
        pred: JoinPred,
        proj: KeyProj2,
        kernel: BinaryKernel,
        left: NodeId,
        right: NodeId,
    ) -> NodeId {
        self.push(Op::Join { pred, proj, kernel }, vec![left, right])
    }

    /// `⋈const` with the constant on the right.
    pub fn join_const(
        &mut self,
        pred: JoinPred,
        proj: KeyProj2,
        kernel: BinaryKernel,
        left: NodeId,
        rel: Arc<Relation>,
        name: &str,
    ) -> NodeId {
        let c = self.constant(rel, name);
        self.join(pred, proj, kernel, left, c)
    }

    pub fn agg(&mut self, grp: KeyProj, agg: AggKernel, input: NodeId) -> NodeId {
        self.push(Op::Agg { grp, agg }, vec![input])
    }

    pub fn add(&mut self, left: NodeId, right: NodeId) -> NodeId {
        self.push(Op::AddQ, vec![left, right])
    }

    pub fn finish(self, output: NodeId) -> Query {
        assert!(output < self.nodes.len());
        Query {
            nodes: self.nodes,
            output,
            n_slots: self.n_slots,
        }
    }
}

/// The paper's running example: blocked matrix multiply
/// `Σ(grp, ⊕, ⋈(pred, proj, ⊗, τ(K), τ(K)))` with
/// pred `keyL[1]=keyR[0]`, proj `⟨L[0],L[1],R[1]⟩`, grp `⟨k[0],k[2]⟩`.
pub fn matmul_query() -> Query {
    use super::funcs::{Sel2};
    let mut qb = QueryBuilder::new();
    let a = qb.scan(0, "A");
    let b = qb.scan(1, "B");
    let j = qb.join(
        JoinPred::on(vec![(1, 0)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
        BinaryKernel::MatMul,
        a,
        b,
    );
    let s = qb.agg(KeyProj::take(&[0, 2]), AggKernel::Sum, j);
    qb.finish(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_topological() {
        let q = matmul_query();
        assert_eq!(q.len(), 4);
        assert_eq!(q.n_slots, 2);
        for (i, n) in q.nodes.iter().enumerate() {
            for &c in &n.children {
                assert!(c < i, "node {i} has non-topological child {c}");
            }
        }
    }

    #[test]
    fn consumers_computed() {
        let q = matmul_query();
        let cons = q.consumers();
        // scan A is consumed by the join as child 0
        assert_eq!(cons[0], vec![(2, 0)]);
        assert_eq!(cons[1], vec![(2, 1)]);
        assert_eq!(cons[2], vec![(3, 0)]);
        assert!(cons[3].is_empty());
    }

    #[test]
    fn scan_node_lookup() {
        let q = matmul_query();
        assert_eq!(q.scan_node(0), 0);
        assert_eq!(q.scan_node(1), 1);
    }

    #[test]
    fn render_mentions_ops() {
        let q = matmul_query();
        let r = q.render();
        assert!(r.contains("⋈"));
        assert!(r.contains("Σ"));
        assert!(r.contains("matmul"));
        let counts = q.op_counts();
        assert_eq!(counts["τ"], 2);
        assert_eq!(counts["⋈"], 1);
        assert_eq!(counts["Σ"], 1);
    }

    #[test]
    #[should_panic]
    fn bad_output_panics() {
        let qb = QueryBuilder::new();
        qb.finish(0);
    }
}
