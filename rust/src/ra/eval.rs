//! Single-node evaluator for functional-RA queries, with optional tape
//! capture (the forward pass of Algorithm 2 records every intermediate
//! relation `R_i`).

use super::expr::{Node, NodeId, Op, Query};
use super::key::Key;
use super::relation::Relation;
use crate::kernels::{AggKernel, KernelBackend};
use crate::util::FxHashMap;
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// Intermediate relations per node, as captured by a forward execution.
#[derive(Clone)]
pub struct Tape {
    pub rels: Vec<Arc<Relation>>,
}

impl Tape {
    pub fn rel(&self, id: NodeId) -> &Arc<Relation> {
        &self.rels[id]
    }

    pub fn output(&self, q: &Query) -> &Arc<Relation> {
        &self.rels[q.output]
    }

    pub fn nbytes(&self) -> usize {
        self.rels.iter().map(|r| r.nbytes()).sum()
    }
}

/// Evaluate a query against input relations; return only the output.
pub fn eval_query(
    q: &Query,
    inputs: &[&Relation],
    backend: &dyn KernelBackend,
) -> Result<Relation> {
    let tape = eval_query_tape(q, inputs, backend)?;
    Ok(Arc::try_unwrap(tape.rels.into_iter().nth(q.output).unwrap())
        .unwrap_or_else(|a| (*a).clone()))
}

/// Evaluate a query and return the relations of several nodes (used by the
/// backward plan, whose per-input gradients share one DAG).
pub fn eval_query_multi(
    q: &Query,
    inputs: &[&Relation],
    outputs: &[NodeId],
    backend: &dyn KernelBackend,
) -> Result<Vec<Relation>> {
    let tape = eval_query_tape(q, inputs, backend)?;
    Ok(outputs
        .iter()
        .map(|&id| (*tape.rels[id]).clone())
        .collect())
}

/// Evaluate a query capturing every intermediate relation.
pub fn eval_query_tape(
    q: &Query,
    inputs: &[&Relation],
    backend: &dyn KernelBackend,
) -> Result<Tape> {
    if inputs.len() < q.n_slots {
        bail!("query needs {} input(s), got {}", q.n_slots, inputs.len());
    }
    let mut rels: Vec<Arc<Relation>> = Vec::with_capacity(q.nodes.len());
    for (id, node) in q.nodes.iter().enumerate() {
        let r = eval_node(node, &rels, inputs, backend)
            .with_context(|| format!("evaluating node v{id} ({})", node.op.kind()))?;
        rels.push(r);
    }
    Ok(Tape { rels })
}

fn eval_node(
    node: &Node,
    rels: &[Arc<Relation>],
    inputs: &[&Relation],
    backend: &dyn KernelBackend,
) -> Result<Arc<Relation>> {
    Ok(match &node.op {
        Op::Scan { slot, .. } => Arc::new(inputs[*slot].clone()),
        Op::Const { rel, .. } => rel.clone(),
        Op::Select { pred, proj, kernel } => {
            let input = &rels[node.children[0]];
            Arc::new(apply_select(input, pred, proj, kernel, backend)?)
        }
        Op::Join { pred, proj, kernel } => {
            let left = &rels[node.children[0]];
            let right = &rels[node.children[1]];
            Arc::new(hash_join(left, right, pred, proj, kernel, backend)?)
        }
        Op::Agg { grp, agg } => {
            let input = &rels[node.children[0]];
            Arc::new(aggregate(input, grp, agg))
        }
        Op::AddQ => {
            let left = &rels[node.children[0]];
            let right = &rels[node.children[1]];
            Arc::new(add_relations(left, right))
        }
    })
}

/// σ: filter, project, apply the unary kernel, with the injectivity
/// check — shared by this evaluator and the distributed executor
/// (`dist::exec`), so the two error identically.
pub(crate) fn apply_select(
    input: &Relation,
    pred: &super::funcs::KeyPred,
    proj: &super::funcs::KeyProj,
    kernel: &crate::kernels::UnaryKernel,
    backend: &dyn KernelBackend,
) -> Result<Relation> {
    let mut out = Relation::with_capacity(input.len());
    for (k, v) in input.iter() {
        if !pred.matches(k) {
            continue;
        }
        let nk = proj.apply(k);
        let nv = backend.unary(kernel, k, v);
        if out.contains(&nk) {
            bail!("σ projection {proj} is not injective: key {nk} collides");
        }
        out.insert(nk, nv);
    }
    Ok(out)
}

/// Pointwise `add(·,·)` of two relations (the AddQ arm) — shared with
/// `dist::exec`.
pub(crate) fn add_relations(l: &Relation, r: &Relation) -> Relation {
    let mut out = l.clone();
    for (k, v) in r.iter() {
        out.merge_add(*k, v.clone());
    }
    out
}

/// Hash join: build on the smaller side, probe the other. Literal
/// constraints are applied as pre-filters; an empty equality list
/// degenerates to a (filtered) cross product.
pub fn hash_join(
    left: &Relation,
    right: &Relation,
    pred: &super::funcs::JoinPred,
    proj: &super::funcs::KeyProj2,
    kernel: &crate::kernels::BinaryKernel,
    backend: &dyn KernelBackend,
) -> Result<Relation> {
    let mut out = Relation::with_capacity(left.len().max(right.len()));
    if pred.eqs.is_empty() {
        // Cross product (rare: constant-key relations in loss plumbing).
        for (lk, lv) in left.iter() {
            if !pred.l_lits.iter().all(|&(i, v)| lk.get(i) == v) {
                continue;
            }
            for (rk, rv) in right.iter() {
                if !pred.r_lits.iter().all(|&(j, v)| rk.get(j) == v) {
                    continue;
                }
                emit(&mut out, proj, kernel, backend, lk, lv, rk, rv)?;
            }
        }
        return Ok(out);
    }

    let lcomps = pred.left_comps();
    let rcomps = pred.right_comps();
    // Build on the smaller side.
    if right.len() <= left.len() {
        let mut table: FxHashMap<Key, Vec<u32>> = FxHashMap::default();
        for (idx, (rk, _)) in right.iter().enumerate() {
            if !pred.r_lits.iter().all(|&(j, v)| rk.get(j) == v) {
                continue;
            }
            let jk = subkey(rk, &rcomps);
            table.entry(jk).or_default().push(idx as u32);
        }
        for (lk, lv) in left.iter() {
            if !pred.l_lits.iter().all(|&(i, v)| lk.get(i) == v) {
                continue;
            }
            let jk = subkey(lk, &lcomps);
            if let Some(matches) = table.get(&jk) {
                for &ri in matches {
                    let (rk, rv) = &right.pairs()[ri as usize];
                    emit(&mut out, proj, kernel, backend, lk, lv, rk, rv)?;
                }
            }
        }
    } else {
        let mut table: FxHashMap<Key, Vec<u32>> = FxHashMap::default();
        for (idx, (lk, _)) in left.iter().enumerate() {
            if !pred.l_lits.iter().all(|&(i, v)| lk.get(i) == v) {
                continue;
            }
            let jk = subkey(lk, &lcomps);
            table.entry(jk).or_default().push(idx as u32);
        }
        for (rk, rv) in right.iter() {
            if !pred.r_lits.iter().all(|&(j, v)| rk.get(j) == v) {
                continue;
            }
            let jk = subkey(rk, &rcomps);
            if let Some(matches) = table.get(&jk) {
                for &li in matches {
                    let (lk, lv) = &left.pairs()[li as usize];
                    emit(&mut out, proj, kernel, backend, lk, lv, rk, rv)?;
                }
            }
        }
    }
    Ok(out)
}

#[inline]
fn emit(
    out: &mut Relation,
    proj: &super::funcs::KeyProj2,
    kernel: &crate::kernels::BinaryKernel,
    backend: &dyn KernelBackend,
    lk: &Key,
    lv: &super::chunk::Chunk,
    rk: &Key,
    rv: &super::chunk::Chunk,
) -> Result<()> {
    let nk = proj.apply(lk, rk);
    let nv = backend.binary(kernel, &nk, lv, rv);
    if out.contains(&nk) {
        bail!("⋈ projection {proj} is not injective on matches: key {nk} collides (add a Σ to aggregate)");
    }
    out.insert(nk, nv);
    Ok(())
}

/// `⟨k[c] for c in comps⟩` — the join/partitioning key of a tuple
/// (shared with the distributed executor's cardinality estimation).
#[inline]
pub(crate) fn subkey(k: &Key, comps: &[usize]) -> Key {
    let mut out = Key::empty();
    for &c in comps {
        out = out.push(k.get(c));
    }
    out
}

pub fn aggregate(input: &Relation, grp: &super::funcs::KeyProj, agg: &AggKernel) -> Relation {
    let mut out = Relation::new();
    for (k, v) in input.iter() {
        let nk = grp.apply(k);
        out.merge(nk, v.clone(), |acc, x| agg.combine(acc, x));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{BinaryKernel, NativeBackend, UnaryKernel};
    use crate::ra::expr::{matmul_query, QueryBuilder};
    use crate::ra::funcs::{JoinPred, KeyPred, KeyProj, KeyProj2, Sel2};
    use crate::ra::Chunk;
    use crate::util::Prng;

    /// Decompose a dense matrix into a blocked relation with chunk size c.
    fn blockify(m: &[Vec<f32>], c: usize) -> Relation {
        let rows = m.len();
        let cols = m[0].len();
        let mut rel = Relation::new();
        for bi in 0..rows.div_ceil(c) {
            for bj in 0..cols.div_ceil(c) {
                let mut chunk = Chunk::zeros(c, c);
                for i in 0..c {
                    for j in 0..c {
                        let (gi, gj) = (bi * c + i, bj * c + j);
                        if gi < rows && gj < cols {
                            chunk.set(i, j, m[gi][gj]);
                        }
                    }
                }
                rel.insert(Key::k2(bi as i64, bj as i64), chunk);
            }
        }
        rel
    }

    fn dense(rows: usize, cols: usize, rng: &mut Prng) -> Vec<Vec<f32>> {
        (0..rows)
            .map(|_| (0..cols).map(|_| rng.uniform(-1.0, 1.0)).collect())
            .collect()
    }

    fn ref_matmul(a: &[Vec<f32>], b: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let (m, k, n) = (a.len(), b.len(), b[0].len());
        let mut c = vec![vec![0.0f32; n]; m];
        for i in 0..m {
            for p in 0..k {
                for j in 0..n {
                    c[i][j] += a[i][p] * b[p][j];
                }
            }
        }
        c
    }

    #[test]
    fn blocked_matmul_query_matches_dense() {
        let mut rng = Prng::new(11);
        let a = dense(8, 12, &mut rng);
        let b = dense(12, 6, &mut rng);
        let want = ref_matmul(&a, &b);
        let ra = blockify(&a, 4);
        let rb = blockify(&b, 4);
        let q = matmul_query();
        let out = eval_query(&q, &[&ra, &rb], &NativeBackend).unwrap();
        // 2 x 2 grid of 4x4 output blocks
        assert_eq!(out.len(), 2 * 2);
        for (k, chunk) in out.iter() {
            let (bi, bj) = (k.get(0) as usize, k.get(1) as usize);
            for i in 0..4 {
                for j in 0..4 {
                    let (gi, gj) = (bi * 4 + i, bj * 4 + j);
                    let want_v = if gi < 8 && gj < 6 { want[gi][gj] } else { 0.0 };
                    assert!(
                        (chunk.at(i, j) - want_v).abs() < 1e-4,
                        "block {k} elem ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn aggregation_to_single_tuple() {
        // Paper §2.2 example: aggregate a 2x2 grid of 2x2 chunks to one chunk.
        let pairs = vec![
            (Key::k2(0, 0), Chunk::from_vec(2, 2, vec![1., 4., 1., 2.])),
            (Key::k2(0, 1), Chunk::from_vec(2, 2, vec![1., 2., 4., 3.])),
            (Key::k2(1, 0), Chunk::from_vec(2, 2, vec![3., 1., 2., 1.])),
            (Key::k2(1, 1), Chunk::from_vec(2, 2, vec![2., 2., 2., 2.])),
        ];
        let r = Relation::from_pairs(pairs);
        let mut qb = QueryBuilder::new();
        let s = qb.scan(0, "X");
        let a = qb.agg(KeyProj::to_empty(), AggKernel::Sum, s);
        let q = qb.finish(a);
        let out = eval_query(&q, &[&r], &NativeBackend).unwrap();
        assert_eq!(out.len(), 1);
        let v = out.get(&Key::empty()).unwrap();
        assert_eq!(v.data(), &[7., 9., 9., 8.]);
    }

    #[test]
    fn select_filters_and_projects() {
        let r = Relation::from_pairs(vec![
            (Key::k2(0, 0), Chunk::scalar(1.0)),
            (Key::k2(0, 1), Chunk::scalar(2.0)),
            (Key::k2(1, 1), Chunk::scalar(3.0)),
        ]);
        let mut qb = QueryBuilder::new();
        let s = qb.scan(0, "R");
        // keep tuples with k[0]=0, key -> ⟨k[1]⟩, value -> 2x
        let sel = qb.select(
            KeyPred::eq_lit(0, 0),
            KeyProj::take(&[1]),
            UnaryKernel::Scale(2.0),
            s,
        );
        let q = qb.finish(sel);
        let out = eval_query(&q, &[&r], &NativeBackend).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out.get(&Key::k1(1)).unwrap().as_scalar(), 4.0);
        assert!(out.get(&Key::k1(2)).is_none());
    }

    #[test]
    fn noninjective_select_errors() {
        let r = Relation::from_pairs(vec![
            (Key::k2(0, 0), Chunk::scalar(1.0)),
            (Key::k2(0, 1), Chunk::scalar(2.0)),
        ]);
        let mut qb = QueryBuilder::new();
        let s = qb.scan(0, "R");
        let sel = qb.select(KeyPred::always(), KeyProj::take(&[0]), UnaryKernel::Id, s);
        let q = qb.finish(sel);
        assert!(eval_query(&q, &[&r], &NativeBackend).is_err());
    }

    #[test]
    fn add_query_merges() {
        let a = Relation::from_pairs(vec![
            (Key::k1(0), Chunk::scalar(1.0)),
            (Key::k1(1), Chunk::scalar(2.0)),
        ]);
        let b = Relation::from_pairs(vec![
            (Key::k1(1), Chunk::scalar(10.0)),
            (Key::k1(2), Chunk::scalar(20.0)),
        ]);
        let mut qb = QueryBuilder::new();
        let sa = qb.scan(0, "A");
        let sb = qb.scan(1, "B");
        let s = qb.add(sa, sb);
        let q = qb.finish(s);
        let out = eval_query(&q, &[&a, &b], &NativeBackend).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out.get(&Key::k1(1)).unwrap().as_scalar(), 12.0);
    }

    #[test]
    fn join_const_and_tape() {
        // y = x * w (w constant), tape captures every node.
        let x = Relation::from_pairs(vec![(Key::k1(0), Chunk::scalar(3.0))]);
        let w = Arc::new(Relation::from_pairs(vec![(Key::k1(0), Chunk::scalar(4.0))]));
        let mut qb = QueryBuilder::new();
        let sx = qb.scan(0, "x");
        let j = qb.join_const(
            JoinPred::on(vec![(0, 0)]),
            KeyProj2(vec![Sel2::L(0)]),
            BinaryKernel::Mul,
            sx,
            w,
            "w",
        );
        let q = qb.finish(j);
        let tape = eval_query_tape(&q, &[&x], &NativeBackend).unwrap();
        assert_eq!(tape.rels.len(), 3);
        assert_eq!(tape.output(&q).get(&Key::k1(0)).unwrap().as_scalar(), 12.0);
    }

    #[test]
    fn cross_join_via_empty_pred() {
        let a = Relation::from_pairs(vec![(Key::empty(), Chunk::scalar(2.0))]);
        let b = Relation::from_pairs(vec![(Key::k1(7), Chunk::scalar(5.0))]);
        let mut qb = QueryBuilder::new();
        let sa = qb.scan(0, "A");
        let sb = qb.scan(1, "B");
        let j = qb.join(
            JoinPred::cross(),
            KeyProj2(vec![Sel2::R(0)]),
            BinaryKernel::Mul,
            sa,
            sb,
        );
        let q = qb.finish(j);
        let out = eval_query(&q, &[&a, &b], &NativeBackend).unwrap();
        assert_eq!(out.get(&Key::k1(7)).unwrap().as_scalar(), 10.0);
    }

    #[test]
    fn missing_input_errors() {
        let q = matmul_query();
        let r = Relation::new();
        assert!(eval_query(&q, &[&r], &NativeBackend).is_err());
    }
}
