//! Key functions (`pred`, `proj`, `grp`) as *data*.
//!
//! Section 4's RJP constructions build new predicates and projections out
//! of the forward query's ones (e.g. `pred'(keyL, keyR) ↦ keyL =
//! proj(keyR)` for the selection RJP, or `proj₂(keyL, keyR) ↦ ⟨keyL,
//! proj(keyL, keyR)⟩` for the join RJP). Representing key functions as
//! component-selection structures makes those constructions mechanical
//! and keeps every generated plan printable as SQL.

use super::key::Key;
use std::fmt;

/// One output component of a unary key projection: either a component of
/// the input key or a literal.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sel {
    /// `key[i]`
    C(usize),
    /// constant
    Lit(i64),
}

/// Unary key projection / grouping function: `key ↦ ⟨…⟩`.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct KeyProj(pub Vec<Sel>);

impl KeyProj {
    /// Identity projection on `arity` components.
    pub fn identity(arity: usize) -> KeyProj {
        KeyProj((0..arity).map(Sel::C).collect())
    }

    /// Constant grouping function `key ↦ ⟨⟩` (aggregate-to-one-tuple).
    pub fn to_empty() -> KeyProj {
        KeyProj(vec![])
    }

    /// Keep a subset of components: `key ↦ ⟨key[i] for i in comps⟩`.
    pub fn take(comps: &[usize]) -> KeyProj {
        KeyProj(comps.iter().map(|&i| Sel::C(i)).collect())
    }

    #[inline]
    pub fn apply(&self, key: &Key) -> Key {
        let mut out = Key::empty();
        for s in &self.0 {
            out = out.push(match *s {
                Sel::C(i) => key.get(i),
                Sel::Lit(v) => v,
            });
        }
        out
    }

    pub fn out_arity(&self) -> usize {
        self.0.len()
    }

    /// Max input component referenced + 1 (0 if none).
    pub fn min_in_arity(&self) -> usize {
        self.0
            .iter()
            .filter_map(|s| match s {
                Sel::C(i) => Some(i + 1),
                Sel::Lit(_) => None,
            })
            .max()
            .unwrap_or(0)
    }

    pub fn is_identity(&self, arity: usize) -> bool {
        self.0.len() == arity && self.0.iter().enumerate().all(|(i, s)| *s == Sel::C(i))
    }

    /// Compose: `self ∘ inner` (apply `inner` first).
    pub fn compose(&self, inner: &KeyProj) -> KeyProj {
        KeyProj(
            self.0
                .iter()
                .map(|s| match *s {
                    Sel::C(i) => inner.0[i],
                    Sel::Lit(v) => Sel::Lit(v),
                })
                .collect(),
        )
    }

    /// Whether this projection is injective given the input arity: every
    /// input component appears in the output. Injective projections are
    /// exactly those for which a selection is information-preserving
    /// (needed by the cardinality analysis in `autodiff::optimize`).
    pub fn is_injective(&self, in_arity: usize) -> bool {
        (0..in_arity).all(|i| self.0.contains(&Sel::C(i)))
    }
}

/// One output component of a binary (join) key projection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sel2 {
    /// `keyL[i]`
    L(usize),
    /// `keyR[i]`
    R(usize),
    /// constant
    Lit(i64),
}

/// Binary key projection: `(keyL, keyR) ↦ ⟨…⟩`.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct KeyProj2(pub Vec<Sel2>);

impl KeyProj2 {
    pub fn new(sels: Vec<Sel2>) -> KeyProj2 {
        KeyProj2(sels)
    }

    #[inline]
    pub fn apply(&self, l: &Key, r: &Key) -> Key {
        let mut out = Key::empty();
        for s in &self.0 {
            out = out.push(match *s {
                Sel2::L(i) => l.get(i),
                Sel2::R(i) => r.get(i),
                Sel2::Lit(v) => v,
            });
        }
        out
    }

    pub fn out_arity(&self) -> usize {
        self.0.len()
    }

    /// `⟨keyL…, self(keyL,keyR)…⟩` — the join-RJP inner projection.
    pub fn prepend_left(&self, l_arity: usize) -> KeyProj2 {
        let mut sels: Vec<Sel2> = (0..l_arity).map(Sel2::L).collect();
        sels.extend(self.0.iter().copied());
        KeyProj2(sels)
    }
}

/// Unary selection predicate: conjunction of `key[i] = lit` constraints
/// (empty = `true`, the common case in ML queries).
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct KeyPred(pub Vec<(usize, i64)>);

impl KeyPred {
    pub fn always() -> KeyPred {
        KeyPred(vec![])
    }

    pub fn eq_lit(comp: usize, lit: i64) -> KeyPred {
        KeyPred(vec![(comp, lit)])
    }

    #[inline]
    pub fn matches(&self, key: &Key) -> bool {
        self.0.iter().all(|&(i, v)| key.get(i) == v)
    }

    pub fn is_always(&self) -> bool {
        self.0.is_empty()
    }
}

/// Equi-join predicate: conjunction of `keyL[i] = keyR[j]` equalities plus
/// optional literal constraints on either side. This is the class of join
/// predicates the paper's workloads use, and it is closed under the RJP
/// constructions (`keyL = proj(keyR)` with a component-selection `proj`
/// expands to exactly such a conjunction).
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct JoinPred {
    /// `keyL[i] = keyR[j]` pairs.
    pub eqs: Vec<(usize, usize)>,
    /// `keyL[i] = lit` constraints.
    pub l_lits: Vec<(usize, i64)>,
    /// `keyR[j] = lit` constraints.
    pub r_lits: Vec<(usize, i64)>,
}

impl JoinPred {
    pub fn on(eqs: Vec<(usize, usize)>) -> JoinPred {
        JoinPred {
            eqs,
            l_lits: vec![],
            r_lits: vec![],
        }
    }

    /// Cross product (no constraint).
    pub fn cross() -> JoinPred {
        JoinPred::default()
    }

    #[inline]
    pub fn matches(&self, l: &Key, r: &Key) -> bool {
        self.eqs.iter().all(|&(i, j)| l.get(i) == r.get(j))
            && self.l_lits.iter().all(|&(i, v)| l.get(i) == v)
            && self.r_lits.iter().all(|&(j, v)| r.get(j) == v)
    }

    /// Components of the left key participating in equalities, in `eqs`
    /// order — the hash-join / partitioning key.
    pub fn left_comps(&self) -> Vec<usize> {
        self.eqs.iter().map(|&(i, _)| i).collect()
    }

    pub fn right_comps(&self) -> Vec<usize> {
        self.eqs.iter().map(|&(_, j)| j).collect()
    }

    /// Build the predicate `keyL = p(keyR)` where `keyL` has
    /// `p.out_arity()` components: the form every unary RJP produces.
    /// Literal components of `p` become right-side constraints only when
    /// they constrain nothing on the left; here they become `keyL[i]=lit`.
    pub fn left_eq_proj_of_right(p: &KeyProj) -> JoinPred {
        let mut jp = JoinPred::default();
        for (i, s) in p.0.iter().enumerate() {
            match *s {
                Sel::C(j) => jp.eqs.push((i, j)),
                Sel::Lit(v) => jp.l_lits.push((i, v)),
            }
        }
        jp
    }
}

impl fmt::Display for KeyProj {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "⟨")?;
        for (n, s) in self.0.iter().enumerate() {
            if n > 0 {
                write!(f, ",")?;
            }
            match s {
                Sel::C(i) => write!(f, "k[{i}]")?,
                Sel::Lit(v) => write!(f, "{v}")?,
            }
        }
        write!(f, "⟩")
    }
}

impl fmt::Display for KeyProj2 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "⟨")?;
        for (n, s) in self.0.iter().enumerate() {
            if n > 0 {
                write!(f, ",")?;
            }
            match s {
                Sel2::L(i) => write!(f, "L[{i}]")?,
                Sel2::R(i) => write!(f, "R[{i}]")?,
                Sel2::Lit(v) => write!(f, "{v}")?,
            }
        }
        write!(f, "⟩")
    }
}

impl fmt::Display for JoinPred {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        for &(i, j) in &self.eqs {
            if !first {
                write!(f, " ∧ ")?;
            }
            write!(f, "L[{i}]=R[{j}]")?;
            first = false;
        }
        for &(i, v) in &self.l_lits {
            if !first {
                write!(f, " ∧ ")?;
            }
            write!(f, "L[{i}]={v}")?;
            first = false;
        }
        for &(j, v) in &self.r_lits {
            if !first {
                write!(f, " ∧ ")?;
            }
            write!(f, "R[{j}]={v}")?;
            first = false;
        }
        if first {
            write!(f, "true")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proj_apply() {
        // proj(keyL) ↦ ⟨key[1], 7, key[0]⟩
        let p = KeyProj(vec![Sel::C(1), Sel::Lit(7), Sel::C(0)]);
        assert_eq!(p.apply(&Key::k2(3, 4)), Key::k3(4, 7, 3));
        assert_eq!(p.out_arity(), 3);
        assert_eq!(p.min_in_arity(), 2);
    }

    #[test]
    fn proj_identity_and_compose() {
        let id = KeyProj::identity(2);
        assert!(id.is_identity(2));
        assert_eq!(id.apply(&Key::k2(5, 6)), Key::k2(5, 6));
        let swap = KeyProj(vec![Sel::C(1), Sel::C(0)]);
        let both = swap.compose(&swap);
        assert!(both.is_identity(2));
    }

    #[test]
    fn proj_injectivity() {
        assert!(KeyProj(vec![Sel::C(1), Sel::C(0)]).is_injective(2));
        assert!(!KeyProj(vec![Sel::C(0)]).is_injective(2)); // drops k[1]
        assert!(KeyProj(vec![Sel::C(0), Sel::Lit(3)]).is_injective(1));
    }

    #[test]
    fn proj2_apply_and_prepend() {
        // matmul proj: (keyL, keyR) ↦ ⟨L[0], L[1], R[1]⟩
        let p = KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]);
        assert_eq!(p.apply(&Key::k2(1, 2), &Key::k2(2, 3)), Key::k3(1, 2, 3));
        let pre = p.prepend_left(2);
        assert_eq!(
            pre.apply(&Key::k2(1, 2), &Key::k2(2, 3)),
            Key::new(&[1, 2, 1, 2, 3])
        );
    }

    #[test]
    fn join_pred_matmul() {
        // pred(keyL, keyR) ↦ keyL[1] = keyR[0]
        let p = JoinPred::on(vec![(1, 0)]);
        assert!(p.matches(&Key::k2(0, 5), &Key::k2(5, 2)));
        assert!(!p.matches(&Key::k2(0, 5), &Key::k2(4, 2)));
        assert_eq!(p.left_comps(), vec![1]);
        assert_eq!(p.right_comps(), vec![0]);
    }

    #[test]
    fn pred_from_proj() {
        // keyL = grp(keyR) with grp = ⟨k[0]⟩
        let grp = KeyProj::take(&[0]);
        let jp = JoinPred::left_eq_proj_of_right(&grp);
        assert!(jp.matches(&Key::k1(3), &Key::k2(3, 9)));
        assert!(!jp.matches(&Key::k1(4), &Key::k2(3, 9)));
        // with a literal component
        let p = KeyProj(vec![Sel::C(1), Sel::Lit(7)]);
        let jp2 = JoinPred::left_eq_proj_of_right(&p);
        assert!(jp2.matches(&Key::k2(9, 7), &Key::k2(0, 9)));
        assert!(!jp2.matches(&Key::k2(9, 8), &Key::k2(0, 9)));
    }

    #[test]
    fn key_pred() {
        let p = KeyPred::eq_lit(1, 4);
        assert!(p.matches(&Key::k2(0, 4)));
        assert!(!p.matches(&Key::k2(4, 0)));
        assert!(KeyPred::always().matches(&Key::empty()));
    }

    #[test]
    fn display_forms() {
        let p = KeyProj2(vec![Sel2::L(0), Sel2::R(1)]);
        assert_eq!(format!("{p}"), "⟨L[0],R[1]⟩");
        let jp = JoinPred::on(vec![(1, 0)]);
        assert_eq!(format!("{jp}"), "L[1]=R[0]");
    }
}
