//! Tensor chunks: the values stored in tensor-relations (Appendix A).
//!
//! All values are dense, row-major, rank-≤2 f32 blocks; scalars are 1×1.
//! Chunk data is reference-counted so that broadcast joins and relation
//! clones share storage (the simulated network still charges the bytes).

use std::fmt;
use std::sync::Arc;

#[derive(Clone, PartialEq)]
pub struct Chunk {
    rows: usize,
    cols: usize,
    data: Arc<Vec<f32>>,
}

impl Chunk {
    pub fn zeros(rows: usize, cols: usize) -> Chunk {
        Chunk {
            rows,
            cols,
            data: Arc::new(vec![0.0; rows * cols]),
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Chunk {
        assert_eq!(data.len(), rows * cols, "chunk shape/data mismatch");
        Chunk {
            rows,
            cols,
            data: Arc::new(data),
        }
    }

    /// 1×1 scalar chunk.
    pub fn scalar(v: f32) -> Chunk {
        Chunk::from_vec(1, 1, vec![v])
    }

    pub fn filled(rows: usize, cols: usize, v: f32) -> Chunk {
        Chunk::from_vec(rows, cols, vec![v; rows * cols])
    }

    /// Identity block (used in tests and the table-scan Jacobian).
    pub fn eye(n: usize) -> Chunk {
        let mut d = vec![0.0; n * n];
        for i in 0..n {
            d[i * n + i] = 1.0;
        }
        Chunk::from_vec(n, n, d)
    }

    pub fn random(rows: usize, cols: usize, rng: &mut crate::util::Prng, scale: f32) -> Chunk {
        Chunk::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| rng.normal() * scale).collect(),
        )
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.rows * self.cols
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Heap bytes held by this chunk (for memory accounting; shared chunks
    /// are charged per reference by the simulator, which models real
    /// per-node copies in a distributed setting).
    #[inline]
    pub fn nbytes(&self) -> usize {
        self.len() * std::mem::size_of::<f32>()
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable access (copy-on-write if shared).
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        Arc::make_mut(&mut self.data).as_mut_slice()
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        let cols = self.cols;
        self.data_mut()[r * cols + c] = v;
    }

    /// Value of a 1×1 chunk.
    pub fn as_scalar(&self) -> f32 {
        assert_eq!(self.shape(), (1, 1), "not a scalar chunk");
        self.data[0]
    }

    /// Elementwise map into a new chunk.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Chunk {
        Chunk::from_vec(self.rows, self.cols, self.data.iter().map(|&x| f(x)).collect())
    }

    /// Elementwise combine; shapes must match.
    pub fn zip_map(&self, other: &Chunk, f: impl Fn(f32, f32) -> f32) -> Chunk {
        assert_eq!(self.shape(), other.shape(), "zip_map shape mismatch");
        Chunk::from_vec(
            self.rows,
            self.cols,
            self.data
                .iter()
                .zip(other.data.iter())
                .map(|(&a, &b)| f(a, b))
                .collect(),
        )
    }

    /// In-place accumulate (the hot path of `Σ` with `⊕ = +`).
    pub fn add_assign(&mut self, other: &Chunk) {
        assert_eq!(self.shape(), other.shape(), "add_assign shape mismatch");
        let dst = self.data_mut();
        for (d, s) in dst.iter_mut().zip(other.data.iter()) {
            *d += s;
        }
    }

    pub fn scale_assign(&mut self, s: f32) {
        for d in self.data_mut() {
            *d *= s;
        }
    }

    pub fn transpose(&self) -> Chunk {
        let mut out = vec![0.0f32; self.len()];
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        Chunk::from_vec(self.cols, self.rows, out)
    }

    /// Sum of all elements.
    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    /// Frobenius norm squared.
    pub fn sq_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum()
    }

    pub fn approx_eq(&self, other: &Chunk, tol: f32) -> bool {
        self.shape() == other.shape()
            && self
                .data
                .iter()
                .zip(other.data.iter())
                .all(|(a, b)| (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())))
    }

    pub fn max_abs_diff(&self, other: &Chunk) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl fmt::Debug for Chunk {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.shape() == (1, 1) {
            return write!(f, "{:.4}", self.data[0]);
        }
        write!(f, "Chunk[{}x{}]", self.rows, self.cols)?;
        if self.len() <= 16 {
            write!(f, "{:?}", &self.data[..])?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_construction() {
        let c = Chunk::zeros(2, 3);
        assert_eq!(c.shape(), (2, 3));
        assert_eq!(c.nbytes(), 24);
        assert_eq!(Chunk::scalar(4.0).as_scalar(), 4.0);
    }

    #[test]
    fn eye_and_transpose() {
        let e = Chunk::eye(3);
        assert_eq!(e.at(1, 1), 1.0);
        assert_eq!(e.at(0, 1), 0.0);
        let c = Chunk::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t = c.transpose();
        assert_eq!(t.shape(), (3, 2));
        assert_eq!(t.at(2, 1), 6.0);
        assert_eq!(t.transpose(), c);
    }

    #[test]
    fn copy_on_write() {
        let a = Chunk::filled(2, 2, 1.0);
        let mut b = a.clone();
        b.set(0, 0, 9.0);
        assert_eq!(a.at(0, 0), 1.0);
        assert_eq!(b.at(0, 0), 9.0);
    }

    #[test]
    fn add_assign_accumulates() {
        let mut a = Chunk::filled(2, 2, 1.0);
        a.add_assign(&Chunk::filled(2, 2, 2.5));
        assert_eq!(a.at(1, 1), 3.5);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let mut a = Chunk::zeros(2, 2);
        a.add_assign(&Chunk::zeros(2, 3));
    }

    #[test]
    fn map_zip_sum() {
        let a = Chunk::from_vec(1, 3, vec![1., 2., 3.]);
        let b = a.map(|x| x * 2.0);
        assert_eq!(b.data(), &[2., 4., 6.]);
        let c = a.zip_map(&b, |x, y| x + y);
        assert_eq!(c.sum(), 18.0);
        assert_eq!(a.sq_norm(), 14.0);
    }

    #[test]
    fn approx_eq_tolerance() {
        let a = Chunk::scalar(1.0);
        let b = Chunk::scalar(1.0 + 1e-6);
        assert!(a.approx_eq(&b, 1e-5));
        assert!(!a.approx_eq(&Chunk::scalar(1.1), 1e-5));
        assert!(!a.approx_eq(&Chunk::zeros(1, 2), 1e-5));
    }
}
