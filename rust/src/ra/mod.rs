//! The functional relational algebra (Section 2 of the paper).
//!
//! Relations are finite maps from composite integer *keys* to tensor
//! *chunks* (Appendix A's "tensor-relational" extension: values are dense
//! blocks, not scalars). Queries are higher-order functions built from the
//! operators `TableScan`, `Selection`, `Join`, `Join-with-constant`,
//! `Aggregation` and `add`, represented as a DAG (`Query`) whose key
//! functions (`pred`, `proj`, `grp`) are *data* — component-selection
//! structures closed under the RJP constructions of Section 4.

pub mod chunk;
pub mod eval;
pub mod expr;
pub mod funcs;
pub mod key;
pub mod relation;

pub use chunk::Chunk;
pub use eval::{eval_query, eval_query_tape, Tape};
pub use expr::{NodeId, Op, Query, QueryBuilder};
pub use funcs::{JoinPred, KeyPred, KeyProj, KeyProj2, Sel, Sel2};
pub use key::Key;
pub use relation::Relation;
