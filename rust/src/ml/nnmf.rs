//! Non-negative matrix factorization (Appendix B / Figure 2):
//! `V ≈ W·H`, squared-error loss, projected SGD.

use crate::kernels::{AggKernel, BinaryKernel, UnaryKernel};
use crate::ra::expr::{Query, QueryBuilder};
use crate::ra::funcs::{JoinPred, KeyProj, KeyProj2, Sel2};
use crate::ra::{Chunk, Key, Relation};
use crate::util::Prng;
use std::sync::Arc;

pub const SLOT_W: usize = 0;
pub const SLOT_H: usize = 1;

/// `loss = Σ_{ij} (V_ij − [WH]_ij)²` over (chunk × chunk) blocks.
/// Slots: 0 = W (`⟨i,k⟩`), 1 = H (`⟨k,j⟩`); V is constant.
pub fn loss_query(v: Arc<Relation>, n_elems: usize) -> Query {
    let mut qb = QueryBuilder::new();
    let w = qb.scan(SLOT_W, "W");
    let h = qb.scan(SLOT_H, "H");
    let j = qb.join(
        JoinPred::on(vec![(1, 0)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
        BinaryKernel::MatMul,
        w,
        h,
    );
    let wh = qb.agg(KeyProj::take(&[0, 2]), AggKernel::Sum, j);
    let vs = qb.constant(v, "V");
    let diff = qb.join(
        JoinPred::on(vec![(0, 0), (1, 1)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1)]),
        BinaryKernel::SquaredDiff,
        wh,
        vs,
    );
    let per_block = qb.map(UnaryKernel::SumAll, 2, diff);
    let total = qb.agg(KeyProj::to_empty(), AggKernel::Sum, per_block);
    let mean = qb.map(UnaryKernel::Scale(1.0 / n_elems as f32), 0, total);
    qb.finish(mean)
}

/// Random non-negative factors: W (nb_n × nb_d blocks), H (nb_d × nb_n).
pub fn init_factors(
    nb_rows: usize,
    nb_rank: usize,
    nb_cols: usize,
    chunk: usize,
    rng: &mut Prng,
) -> (Relation, Relation) {
    let mut w = Relation::new();
    for i in 0..nb_rows {
        for k in 0..nb_rank {
            w.insert(
                Key::k2(i as i64, k as i64),
                Chunk::random(chunk, chunk, rng, 0.2).map(f32::abs),
            );
        }
    }
    let mut h = Relation::new();
    for k in 0..nb_rank {
        for j in 0..nb_cols {
            h.insert(
                Key::k2(k as i64, j as i64),
                Chunk::random(chunk, chunk, rng, 0.2).map(f32::abs),
            );
        }
    }
    (w, h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::grad;
    use crate::kernels::NativeBackend;
    use crate::ml::Sgd;

    #[test]
    fn factorization_reduces_reconstruction_error() {
        let mut rng = Prng::new(13);
        // V = Wt·Ht with non-negative ground-truth factors (2x1 and 1x2
        // grids of 8x8 blocks).
        let (wt, ht) = init_factors(2, 1, 2, 8, &mut rng);
        let q0 = {
            // materialize V via the forward query on the truth
            let mut qb = QueryBuilder::new();
            let w = qb.scan(0, "W");
            let h = qb.scan(1, "H");
            let j = qb.join(
                JoinPred::on(vec![(1, 0)]),
                KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
                BinaryKernel::MatMul,
                w,
                h,
            );
            let s = qb.agg(KeyProj::take(&[0, 2]), AggKernel::Sum, j);
            qb.finish(s)
        };
        let v = crate::ra::eval::eval_query(&q0, &[&wt, &ht], &NativeBackend).unwrap();

        let q = loss_query(Arc::new(v), 16 * 16);
        let (mut w, mut h) = init_factors(2, 1, 2, 8, &mut rng);
        let sgd = Sgd::nonneg(2.0);
        let mut losses = Vec::new();
        for _ in 0..120 {
            let (tape, grads) = grad(&q, &[&w, &h], &NativeBackend).unwrap();
            losses.push(tape.output(&q).get(&Key::empty()).unwrap().as_scalar());
            sgd.step(&mut w, grads.slot(SLOT_W));
            sgd.step(&mut h, grads.slot(SLOT_H));
        }
        let last = *losses.last().unwrap();
        assert!(
            last < losses[0] * 0.2,
            "NNMF did not converge: first {} last {last}",
            losses[0],
        );
        // non-negativity preserved
        for (_, c) in w.iter() {
            assert!(c.data().iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn nnmf_gradient_matches_finite_differences() {
        let mut rng = Prng::new(14);
        let (wt, ht) = init_factors(1, 1, 1, 4, &mut rng);
        let q0 = loss_query(
            Arc::new(Relation::from_pairs(vec![(
                Key::k2(0, 0),
                Chunk::random(4, 4, &mut rng, 1.0).map(f32::abs),
            )])),
            16,
        );
        let (_, grads) = grad(&q0, &[&wt, &ht], &NativeBackend).unwrap();
        let fd = crate::autodiff::check::finite_diff_grad(&q0, &[&wt, &ht], 0, 1e-2, &NativeBackend)
            .unwrap();
        crate::autodiff::check::assert_grad_close(grads.slot(0), &fd, 5e-2);
    }
}
