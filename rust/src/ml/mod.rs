//! ML workloads expressed as functional-RA queries and differentiated by
//! the relational autodiff — the paper's evaluation suite:
//!
//! * `logreg` — §2.3's logistic regression (quickstart / worked example),
//! * `gcn` — two-layer graph convolutional network (Tables 2–3),
//! * `nnmf` — non-negative matrix factorization (Figure 2),
//! * `kge` — TransE-L2 / TransR knowledge-graph embeddings (Figure 3),
//! * `optim` — SGD / Adam over gradient relations,
//! * `train` — the distributed training-step driver (forward tape →
//!   generated backward query → optimizer update, all through
//!   `dist::exec`).

pub mod gcn;
pub mod kge;
pub mod logreg;
pub mod nnmf;
pub mod optim;
pub mod train;

pub use optim::{Adam, Sgd};
pub use train::{DistTrainer, SlotLayout, StepResult};
// Deprecated in favour of `session::Session::trainer`; re-exported so
// existing callers keep compiling (with a nudge) until removal.
#[allow(deprecated)]
pub use train::TrainPipeline;
