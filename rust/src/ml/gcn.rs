//! Two-layer GCN as a relational computation (the paper's §6 workload).
//!
//! Storage follows the paper exactly: `Edge(⟨src,dst⟩ → weight)` and
//! `Node(⟨id⟩ → (1, F) embedding)`. Message passing is the three-way
//! join + aggregation the paper describes; the model matrices `W1`, `W2`
//! join with *no* key constraint (every node needs them), so the
//! distributed optimizer broadcasts them — the "data parallel" plan the
//! paper attributes to the database optimizer. The per-node gradient of
//! a mini-batch stays sparse automatically: only tuples reachable from
//! the labeled batch receive gradient tuples.

use crate::kernels::{AggKernel, BinaryKernel, UnaryKernel};
use crate::ra::expr::{Query, QueryBuilder};
use crate::ra::funcs::{JoinPred, KeyProj, KeyProj2, Sel2};
use crate::ra::{Chunk, Key, Relation};
use crate::util::Prng;

/// Slot layout of the GCN loss query.
pub const SLOT_W1: usize = 0;
pub const SLOT_W2: usize = 1;
pub const SLOT_EDGES: usize = 2;
pub const SLOT_FEATS: usize = 3;
pub const SLOT_LABELS: usize = 4;

#[derive(Clone, Copy, Debug)]
pub struct GcnConfig {
    pub feat_dim: usize,
    pub hidden: usize,
    pub n_labels: usize,
    pub dropout: Option<f32>,
    pub seed: u64,
}

impl GcnConfig {
    pub fn paper(feat_dim: usize, n_labels: usize) -> GcnConfig {
        GcnConfig {
            feat_dim,
            // The paper uses D=256 on the full datasets; scaled runs use
            // 64 to match the artifact chunk size.
            hidden: 64,
            n_labels,
            dropout: Some(0.5),
            seed: 0xD120,
        }
    }
}

/// Build the 2-layer GCN loss query:
///
/// ```text
/// S  = Σ_dst ( Edge(s,d) ⋈ [XW1](d) )          # propagate layer 1
/// H  = relu(S) [∘ dropout]
/// Z  = Σ_dst ( Edge(s,d) ⋈ [HW2](d) )          # propagate layer 2
/// L  = mean softmax-xent(Z ⋈ Y)
/// ```
pub fn loss_query(cfg: &GcnConfig, n_labeled: usize) -> Query {
    let mut qb = QueryBuilder::new();
    let w1 = qb.scan(SLOT_W1, "W1");
    let w2 = qb.scan(SLOT_W2, "W2");
    let edges = qb.scan(SLOT_EDGES, "Edge");
    let feats = qb.scan(SLOT_FEATS, "Node");
    let labels = qb.scan(SLOT_LABELS, "Y");

    // XW1: Node(n) × W1 (single chunk keyed ⟨0⟩). The predicate pins
    // W1's key to the literal 0 — semantically a broadcast join (every
    // node matches the one weight tuple), and it keeps the weight's key
    // recoverable in the generated backward query.
    let w_pred = JoinPred {
        eqs: vec![],
        l_lits: vec![],
        r_lits: vec![(0, 0)],
    };
    let xw = qb.join(
        w_pred.clone(),
        KeyProj2(vec![Sel2::L(0)]),
        BinaryKernel::MatMul,
        feats,
        w1,
    );
    // Propagate: Edge(s,d) ⋈ XW(d), weight × message, Σ over d.
    let msg1 = qb.join(
        JoinPred::on(vec![(1, 0)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1)]),
        BinaryKernel::ScalarMul,
        edges,
        xw,
    );
    let s1 = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, msg1);
    let mut h = qb.map(UnaryKernel::Relu, 1, s1);
    if let Some(rate) = cfg.dropout {
        h = qb.map(
            UnaryKernel::Dropout {
                seed: cfg.seed,
                rate,
            },
            1,
            h,
        );
    }
    // HW2 then propagate again.
    let hw = qb.join(
        w_pred,
        KeyProj2(vec![Sel2::L(0)]),
        BinaryKernel::MatMul,
        h,
        w2,
    );
    let msg2 = qb.join(
        JoinPred::on(vec![(1, 0)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1)]),
        BinaryKernel::ScalarMul,
        edges,
        hw,
    );
    let z = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, msg2);
    // Loss: only labeled nodes join (Y is sparse), softmax-xent per node.
    let l = qb.join(
        JoinPred::on(vec![(0, 0)]),
        KeyProj2(vec![Sel2::L(0)]),
        BinaryKernel::SoftmaxXentRows,
        z,
        labels,
    );
    let per_node = qb.map(UnaryKernel::SumAll, 1, l);
    let total = qb.agg(KeyProj::to_empty(), AggKernel::Sum, per_node);
    let mean = qb.map(UnaryKernel::Scale(1.0 / n_labeled.max(1) as f32), 0, total);
    qb.finish(mean)
}

/// Glorot-ish initial weights: W1 `⟨0⟩ → (F, H)`, W2 `⟨0⟩ → (H, L)`.
pub fn init_params(cfg: &GcnConfig, rng: &mut Prng) -> (Relation, Relation) {
    let s1 = (2.0 / (cfg.feat_dim + cfg.hidden) as f32).sqrt();
    let s2 = (2.0 / (cfg.hidden + cfg.n_labels) as f32).sqrt();
    let w1 = Relation::from_pairs(vec![(
        Key::k1(0),
        Chunk::random(cfg.feat_dim, cfg.hidden, rng, s1),
    )]);
    let w2 = Relation::from_pairs(vec![(
        Key::k1(0),
        Chunk::random(cfg.hidden, cfg.n_labels, rng, s2),
    )]);
    (w1, w2)
}

/// Mini-batch label relation: a random subset of the labeled nodes (the
/// unlabeled/rest simply don't join — gradients stay restricted to the
/// batch's 2-hop cone automatically).
pub fn batch_labels(labels: &Relation, labeled: &[u32], batch: usize, rng: &mut Prng) -> Relation {
    if batch >= labeled.len() {
        return labels.clone();
    }
    let idx = rng.sample_indices(labeled.len(), batch);
    let mut out = Relation::with_capacity(batch);
    for i in idx {
        let k = Key::k1(labeled[i] as i64);
        out.insert(k, labels.get(&k).unwrap().clone());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::grad_wrt;
    use crate::data::graphs::power_law_graph;
    use crate::kernels::NativeBackend;
    use crate::ml::Adam;

    fn tiny() -> (crate::data::GraphDataset, GcnConfig) {
        let g = power_law_graph("tiny", 60, 180, 8, 4, 0.5, 11);
        let cfg = GcnConfig {
            feat_dim: 8,
            hidden: 8,
            n_labels: 4,
            dropout: None,
            seed: 1,
        };
        (g, cfg)
    }

    #[test]
    fn loss_decreases_under_adam() {
        let (g, cfg) = tiny();
        let q = loss_query(&cfg, g.labels.len());
        let mut rng = Prng::new(3);
        let (mut w1, mut w2) = init_params(&cfg, &mut rng);
        let mut adam = Adam::new(0.08);
        let mut losses = Vec::new();
        for _ in 0..60 {
            let inputs = [&w1, &w2, &g.edges, &g.feats, &g.labels];
            let (tape, grads) =
                grad_wrt(&q, &inputs, &[SLOT_W1, SLOT_W2], &NativeBackend).unwrap();
            losses.push(tape.output(&q).get(&Key::empty()).unwrap().as_scalar());
            adam.step(&mut w1, grads.slot(SLOT_W1));
            adam.step(&mut w2, grads.slot(SLOT_W2));
        }
        let last = *losses.last().unwrap();
        assert!(
            last < losses[0] * 0.7,
            "GCN loss did not decrease: first {} last {last}",
            losses[0],
        );
    }

    #[test]
    fn minibatch_gradient_is_sparse() {
        // Gradient tuples w.r.t. features must be restricted to the
        // batch's 2-hop neighborhood (strictly fewer than all nodes).
        let (g, cfg) = tiny();
        let mut rng = Prng::new(4);
        let yb = batch_labels(&g.labels, &g.labeled, 3, &mut rng);
        assert_eq!(yb.len(), 3);
        let q = loss_query(&cfg, 3);
        let (w1, w2) = init_params(&cfg, &mut rng);
        let inputs = [&w1, &w2, &g.edges, &g.feats, &yb];
        let (_, grads) = grad_wrt(
            &q,
            &inputs,
            &[SLOT_W1, SLOT_W2, SLOT_EDGES, SLOT_FEATS],
            &NativeBackend,
        )
        .unwrap();
        let gf = grads.slot(SLOT_FEATS);
        assert!(!gf.is_empty());
        assert!(
            gf.len() < g.n_nodes,
            "feature gradient not sparse: {} of {}",
            gf.len(),
            g.n_nodes
        );
        // Edge gradients exist too (weights are differentiable in
        // principle even though training never updates them).
        assert!(!grads.slot(SLOT_EDGES).is_empty());
    }

    #[test]
    fn gcn_gradient_matches_finite_differences_on_w2() {
        let (g, cfg) = tiny();
        let q = loss_query(&cfg, g.labels.len());
        let mut rng = Prng::new(5);
        let (w1, w2) = init_params(&cfg, &mut rng);
        let inputs = [&w1, &w2, &g.edges, &g.feats, &g.labels];
        let (_, grads) = grad_wrt(&q, &inputs, &[SLOT_W2], &NativeBackend).unwrap();
        let fd =
            crate::autodiff::check::finite_diff_grad(&q, &inputs, SLOT_W2, 1e-2, &NativeBackend)
                .unwrap();
        crate::autodiff::check::assert_grad_close(grads.slot(SLOT_W2), &fd, 5e-2);
    }

    #[test]
    fn dropout_changes_loss_but_is_deterministic() {
        let (g, mut cfg) = tiny();
        cfg.dropout = Some(0.5);
        let q = loss_query(&cfg, g.labels.len());
        let mut rng = Prng::new(6);
        let (w1, w2) = init_params(&cfg, &mut rng);
        let inputs = [&w1, &w2, &g.edges, &g.feats, &g.labels];
        let (t1, _) = grad_wrt(&q, &inputs, &[SLOT_W1], &NativeBackend).unwrap();
        let (t2, _) = grad_wrt(&q, &inputs, &[SLOT_W1], &NativeBackend).unwrap();
        let l1 = t1.output(&q).get(&Key::empty()).unwrap().as_scalar();
        let l2 = t2.output(&q).get(&Key::empty()).unwrap().as_scalar();
        assert_eq!(l1, l2, "dropout must be deterministic per key/seed");
    }
}
