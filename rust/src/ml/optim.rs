//! Optimizers over relations: parameters and gradients are both
//! tensor-relations; updates are key-aligned chunk operations.

use crate::ra::{Chunk, Relation};
use crate::util::FxHashMap;
use crate::ra::Key;

/// Plain SGD: `θ ← θ - η·∇θ`; with optional projection to ≥ 0
/// (projected SGD for NNMF's non-negativity constraint).
pub struct Sgd {
    pub lr: f32,
    pub nonneg: bool,
}

impl Sgd {
    pub fn new(lr: f32) -> Sgd {
        Sgd { lr, nonneg: false }
    }

    pub fn nonneg(lr: f32) -> Sgd {
        Sgd { lr, nonneg: true }
    }

    pub fn step(&self, params: &mut Relation, grads: &Relation) {
        for (k, p) in params.iter_mut() {
            if let Some(g) = grads.get(k) {
                let lr = self.lr;
                let gd = g.data();
                let pd = p.data_mut();
                if self.nonneg {
                    for (pv, gv) in pd.iter_mut().zip(gd.iter()) {
                        *pv = (*pv - lr * gv).max(0.0);
                    }
                } else {
                    for (pv, gv) in pd.iter_mut().zip(gd.iter()) {
                        *pv -= lr * gv;
                    }
                }
            }
        }
    }
}

/// Adam (the paper's GCN optimizer, η = 0.1).
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    t: i32,
    m: FxHashMap<Key, Chunk>,
    v: FxHashMap<Key, Chunk>,
}

impl Adam {
    pub fn new(lr: f32) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: 0,
            m: FxHashMap::default(),
            v: FxHashMap::default(),
        }
    }

    pub fn step(&mut self, params: &mut Relation, grads: &Relation) {
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t);
        let bc2 = 1.0 - self.beta2.powi(self.t);
        for (k, p) in params.iter_mut() {
            let Some(g) = grads.get(k) else { continue };
            let m = self
                .m
                .entry(*k)
                .or_insert_with(|| Chunk::zeros(p.rows(), p.cols()));
            let v = self
                .v
                .entry(*k)
                .or_insert_with(|| Chunk::zeros(p.rows(), p.cols()));
            let (b1, b2, lr, eps) = (self.beta1, self.beta2, self.lr, self.eps);
            let gd = g.data();
            let md = m.data_mut();
            for (mv, gv) in md.iter_mut().zip(gd.iter()) {
                *mv = b1 * *mv + (1.0 - b1) * gv;
            }
            let vd = v.data_mut();
            for (vv, gv) in vd.iter_mut().zip(gd.iter()) {
                *vv = b2 * *vv + (1.0 - b2) * gv * gv;
            }
            let pd = p.data_mut();
            let (md, vd) = (m.data(), v.data());
            for i in 0..pd.len() {
                let mhat = md[i] / bc1;
                let vhat = vd[i] / bc2;
                pd[i] -= lr * mhat / (vhat.sqrt() + eps);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rel(v: f32) -> Relation {
        Relation::from_pairs(vec![(Key::k1(0), Chunk::scalar(v))])
    }

    #[test]
    fn sgd_descends_quadratic() {
        // minimize (θ-3)²: grad = 2(θ-3)
        let mut theta = rel(0.0);
        let sgd = Sgd::new(0.1);
        for _ in 0..100 {
            let t = theta.get(&Key::k1(0)).unwrap().as_scalar();
            let g = rel(2.0 * (t - 3.0));
            sgd.step(&mut theta, &g);
        }
        let t = theta.get(&Key::k1(0)).unwrap().as_scalar();
        assert!((t - 3.0).abs() < 1e-3, "sgd did not converge: {t}");
    }

    #[test]
    fn projected_sgd_stays_nonneg() {
        let mut theta = rel(0.1);
        let sgd = Sgd::nonneg(1.0);
        sgd.step(&mut theta, &rel(10.0)); // huge positive gradient
        assert_eq!(theta.get(&Key::k1(0)).unwrap().as_scalar(), 0.0);
    }

    #[test]
    fn adam_descends_quadratic() {
        let mut theta = rel(0.0);
        let mut adam = Adam::new(0.1);
        for _ in 0..300 {
            let t = theta.get(&Key::k1(0)).unwrap().as_scalar();
            let g = rel(2.0 * (t - 3.0));
            adam.step(&mut theta, &g);
        }
        let t = theta.get(&Key::k1(0)).unwrap().as_scalar();
        assert!((t - 3.0).abs() < 0.05, "adam did not converge: {t}");
    }

    #[test]
    fn missing_gradient_keys_leave_params_unchanged() {
        let mut theta = Relation::from_pairs(vec![
            (Key::k1(0), Chunk::scalar(1.0)),
            (Key::k1(1), Chunk::scalar(2.0)),
        ]);
        let g = rel(1.0); // only key 0
        Sgd::new(0.5).step(&mut theta, &g);
        assert_eq!(theta.get(&Key::k1(0)).unwrap().as_scalar(), 0.5);
        assert_eq!(theta.get(&Key::k1(1)).unwrap().as_scalar(), 2.0);
    }
}
