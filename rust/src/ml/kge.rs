//! Knowledge-graph embeddings (Appendix C / Figure 3): TransE-L2 and
//! TransR with margin ranking loss over corrupted-tail negatives.
//!
//! Embedding tables are relations (`E(⟨e⟩ → (1,D))`, `R(⟨r⟩ → (1,D'))`,
//! TransR adds `M(⟨r⟩ → (D,D'))`); a training batch becomes two constant
//! triple relations whose keys carry (tripleId, head, rel, tail), and
//! embedding lookup is a join with the `Snd` kernel — gradients flow back
//! through those joins into the tables, with the RJP's Σ accumulating
//! per-entity contributions across the batch.

use crate::kernels::{AggKernel, BinaryKernel, UnaryKernel};
use crate::ra::expr::{NodeId, Query, QueryBuilder};
use crate::ra::funcs::{JoinPred, KeyProj, KeyProj2, Sel2};
use crate::ra::{Chunk, Key, Relation};
use crate::util::Prng;
use std::sync::Arc;

pub const SLOT_E: usize = 0;
pub const SLOT_R: usize = 1;
/// TransR only.
pub const SLOT_M: usize = 2;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KgeVariant {
    TransE,
    /// Relation embeddings (and the projected space) have dimension 2D
    /// ("double entity embedding size"), with a (D × 2D) projection
    /// matrix per relation.
    TransR,
}

#[derive(Clone, Copy, Debug)]
pub struct KgeConfig {
    pub variant: KgeVariant,
    pub dim: usize,
    pub margin: f32,
}

impl KgeConfig {
    pub fn rel_dim(&self) -> usize {
        match self.variant {
            KgeVariant::TransE => self.dim,
            KgeVariant::TransR => self.dim * 2,
        }
    }
}

/// Initialize embedding tables.
pub fn init_tables(
    cfg: &KgeConfig,
    n_entities: usize,
    n_relations: usize,
    rng: &mut Prng,
) -> Vec<Relation> {
    let s = 1.0 / (cfg.dim as f32).sqrt();
    let mut e = Relation::with_capacity(n_entities);
    for i in 0..n_entities {
        e.insert(Key::k1(i as i64), Chunk::random(1, cfg.dim, rng, s));
    }
    let mut r = Relation::with_capacity(n_relations);
    for i in 0..n_relations {
        r.insert(Key::k1(i as i64), Chunk::random(1, cfg.rel_dim(), rng, s));
    }
    let mut out = vec![e, r];
    if cfg.variant == KgeVariant::TransR {
        let mut m = Relation::with_capacity(n_relations);
        for i in 0..n_relations {
            m.insert(
                Key::k1(i as i64),
                Chunk::random(cfg.dim, cfg.rel_dim(), rng, s),
            );
        }
        out.push(m);
    }
    out
}

/// Constant triple relations for one batch.
/// `pos`: `⟨t, h, r, tl⟩ → 1`; `neg`: `⟨t, n, tl'⟩ → 1`.
pub fn batch_relations(
    pos: &[(u32, u16, u32)],
    negs: &[Vec<u32>],
) -> (Relation, Relation) {
    let mut rp = Relation::with_capacity(pos.len());
    for (t, &(h, r, tl)) in pos.iter().enumerate() {
        rp.insert(
            Key::new(&[t as i64, h as i64, r as i64, tl as i64]),
            Chunk::scalar(1.0),
        );
    }
    let mut rn = Relation::with_capacity(pos.len() * negs[0].len());
    for (t, ns) in negs.iter().enumerate() {
        for (n, &tl) in ns.iter().enumerate() {
            rn.insert(
                Key::k3(t as i64, n as i64, tl as i64),
                Chunk::scalar(1.0),
            );
        }
    }
    (rp, rn)
}

/// Embedding lookup: `table(⟨id⟩) ⋈ triples` keyed by the triple id(s).
fn lookup(
    qb: &mut QueryBuilder,
    triples: NodeId,
    table: NodeId,
    id_comp: usize,
    out_sels: Vec<Sel2>,
) -> NodeId {
    qb.join(
        JoinPred::on(vec![(id_comp, 0)]),
        KeyProj2(out_sels),
        BinaryKernel::Snd,
        triples,
        table,
    )
}

/// Build the margin-ranking loss query for one batch.
pub fn loss_query(cfg: &KgeConfig, pos: Relation, neg: Relation, n_pairs: usize) -> Query {
    let mut qb = QueryBuilder::new();
    let e = qb.scan(SLOT_E, "E");
    let r = qb.scan(SLOT_R, "R");
    let m = (cfg.variant == KgeVariant::TransR).then(|| qb.scan(SLOT_M, "M"));
    let tp = qb.constant(Arc::new(pos), "Tpos");
    let tn = qb.constant(Arc::new(neg), "Tneg");

    let keep_t = vec![Sel2::L(0)];
    let keep_tn = vec![Sel2::L(0), Sel2::L(1)];
    // positive triple embeddings keyed ⟨t⟩
    let h_e = lookup(&mut qb, tp, e, 1, keep_t.clone());
    let r_e = lookup(&mut qb, tp, r, 2, keep_t.clone());
    let t_e = lookup(&mut qb, tp, e, 3, keep_t.clone());
    // negative tails keyed ⟨t, n⟩
    let tn_e = lookup(&mut qb, tn, e, 2, keep_tn.clone());

    // optional TransR projection of head/tails
    let (h_p, t_p, tn_p) = if let Some(m) = m {
        let m_t = lookup(&mut qb, tp, m, 2, keep_t.clone()); // ⟨t⟩ → (D, D')
        let hp = qb.join(
            JoinPred::on(vec![(0, 0)]),
            KeyProj2(vec![Sel2::L(0)]),
            BinaryKernel::MatMul,
            h_e,
            m_t,
        );
        let tpj = qb.join(
            JoinPred::on(vec![(0, 0)]),
            KeyProj2(vec![Sel2::L(0)]),
            BinaryKernel::MatMul,
            t_e,
            m_t,
        );
        let tnp = qb.join(
            JoinPred::on(vec![(0, 0)]),
            KeyProj2(vec![Sel2::L(0), Sel2::L(1)]),
            BinaryKernel::MatMul,
            tn_e,
            m_t,
        );
        (hp, tpj, tnp)
    } else {
        (h_e, t_e, tn_e)
    };

    // h + r keyed ⟨t⟩
    let hr = qb.join(
        JoinPred::on(vec![(0, 0)]),
        KeyProj2(vec![Sel2::L(0)]),
        BinaryKernel::Add,
        h_p,
        r_e,
    );
    // positive score ‖h + r − t‖² keyed ⟨t⟩
    let dp = qb.join(
        JoinPred::on(vec![(0, 0)]),
        KeyProj2(vec![Sel2::L(0)]),
        BinaryKernel::Sub,
        hr,
        t_p,
    );
    let dp2 = qb.map(UnaryKernel::Square, 1, dp);
    let pos_score = qb.map(UnaryKernel::SumAll, 1, dp2);
    // negative scores keyed ⟨t, n⟩
    let dn = qb.join(
        JoinPred::on(vec![(0, 0)]),
        KeyProj2(vec![Sel2::R(0), Sel2::R(1)]),
        BinaryKernel::Sub,
        hr,
        tn_p,
    );
    let dn2 = qb.map(UnaryKernel::Square, 2, dn);
    let neg_score = qb.map(UnaryKernel::SumAll, 2, dn2);
    // margin ranking: relu(γ + pos − neg), mean over pairs
    let pairs = qb.join(
        JoinPred::on(vec![(0, 0)]),
        KeyProj2(vec![Sel2::R(0), Sel2::R(1)]),
        BinaryKernel::Sub,
        pos_score,
        neg_score,
    );
    let shifted = qb.map(UnaryKernel::AddConst(cfg.margin), 2, pairs);
    let relu = qb.map(UnaryKernel::Relu, 2, shifted);
    let total = qb.agg(KeyProj::to_empty(), AggKernel::Sum, relu);
    let mean = qb.map(UnaryKernel::Scale(1.0 / n_pairs as f32), 0, total);
    qb.finish(mean)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::grad;
    use crate::data::KgDataset;
    use crate::kernels::NativeBackend;
    use crate::ml::Sgd;

    fn run_variant(variant: KgeVariant) -> Vec<f32> {
        let cfg = KgeConfig {
            variant,
            dim: 8,
            margin: 1.0,
        };
        let kg = KgDataset::freebase_scaled(50, 400, 4, 17);
        let mut rng = Prng::new(18);
        let mut tables = init_tables(&cfg, 50, 4, &mut rng);
        let sgd = Sgd::new(0.5);
        let mut losses = Vec::new();
        for _ in 0..15 {
            let (pos, negs) = kg.sample_batch(16, 4, &mut rng);
            let (rp, rn) = batch_relations(&pos, &negs);
            let q = loss_query(&cfg, rp, rn, 16 * 4);
            let refs: Vec<&Relation> = tables.iter().collect();
            let (tape, grads) = grad(&q, &refs, &NativeBackend).unwrap();
            losses.push(tape.output(&q).get(&Key::empty()).unwrap().as_scalar());
            for (i, t) in tables.iter_mut().enumerate() {
                sgd.step(t, grads.slot(i));
            }
        }
        losses
    }

    #[test]
    fn transe_loss_decreases() {
        let losses = run_variant(KgeVariant::TransE);
        let head: f32 = losses[..3].iter().sum::<f32>() / 3.0;
        let tail: f32 = losses[12..].iter().sum::<f32>() / 3.0;
        assert!(tail < head, "TransE no progress: {losses:?}");
    }

    #[test]
    fn transr_loss_decreases() {
        let losses = run_variant(KgeVariant::TransR);
        let head: f32 = losses[..3].iter().sum::<f32>() / 3.0;
        let tail: f32 = losses[12..].iter().sum::<f32>() / 3.0;
        assert!(tail < head, "TransR no progress: {losses:?}");
    }

    #[test]
    fn gradients_touch_only_batch_entities() {
        let cfg = KgeConfig {
            variant: KgeVariant::TransE,
            dim: 4,
            margin: 1.0,
        };
        let mut rng = Prng::new(19);
        let tables = init_tables(&cfg, 100, 3, &mut rng);
        let pos = vec![(1u32, 0u16, 2u32)];
        let negs = vec![vec![3u32, 4u32]];
        let (rp, rn) = batch_relations(&pos, &negs);
        let q = loss_query(&cfg, rp, rn, 2);
        let refs: Vec<&Relation> = tables.iter().collect();
        let (_, grads) = grad(&q, &refs, &NativeBackend).unwrap();
        let ge = grads.slot(SLOT_E);
        // only entities 1, 2, 3, 4 can receive gradient
        for (k, _) in ge.iter() {
            assert!([1, 2, 3, 4].contains(&k.get(0)), "unexpected grad at {k}");
        }
        assert!(ge.len() <= 4);
        assert_eq!(grads.slot(SLOT_R).len(), 1);
    }

    #[test]
    fn transe_gradient_matches_finite_differences() {
        let cfg = KgeConfig {
            variant: KgeVariant::TransE,
            dim: 3,
            margin: 2.0,
        };
        let mut rng = Prng::new(20);
        let tables = init_tables(&cfg, 6, 2, &mut rng);
        let pos = vec![(0u32, 0u16, 1u32), (2, 1, 3)];
        let negs = vec![vec![4u32], vec![5u32]];
        let (rp, rn) = batch_relations(&pos, &negs);
        let q = loss_query(&cfg, rp, rn, 2);
        let refs: Vec<&Relation> = tables.iter().collect();
        let (_, grads) = grad(&q, &refs, &NativeBackend).unwrap();
        let fd = crate::autodiff::check::finite_diff_grad(&q, &refs, SLOT_E, 1e-2, &NativeBackend)
            .unwrap();
        crate::autodiff::check::assert_grad_close(grads.slot(SLOT_E), &fd, 5e-2);
    }
}
