//! Distributed training driver: run the forward query distributed with
//! tape capture, feed the taped partitions into the generated backward
//! query (graph-mode autodiff), gather parameter gradients, apply the
//! optimizer — the full per-epoch path the Tables 2–3 / Figure 2–3
//! benches time on the virtual cluster.

use crate::autodiff::graph::{backward_graph, BackwardPlan};
use crate::dist::{
    dist_eval_multi, dist_eval_tape, ClusterConfig, DistError, ExecStats, PartitionedRelation,
};
use crate::kernels::KernelBackend;
use crate::ra::expr::{NodeId, Query};
use crate::ra::{Chunk, Key, Relation};
use anyhow::Result;

/// A compiled (forward, backward) pair for distributed training.
pub struct DistTrainer {
    pub fwd: Query,
    pub bwd: BackwardPlan,
    pub param_slots: Vec<usize>,
}

/// One step's outputs.
pub struct StepResult {
    pub loss: f32,
    /// (slot, gathered gradient relation)
    pub grads: Vec<(usize, Relation)>,
    pub stats: ExecStats,
}

impl DistTrainer {
    /// `in_arities[i]` = key width of input slot i.
    pub fn new(fwd: Query, in_arities: &[usize], param_slots: &[usize]) -> Result<DistTrainer> {
        let bwd = backward_graph(&fwd, in_arities, param_slots)?;
        Ok(DistTrainer {
            fwd,
            bwd,
            param_slots: param_slots.to_vec(),
        })
    }

    /// Execute forward + backward on the virtual cluster. `inputs` are
    /// the forward query's inputs, already partitioned.
    pub fn step(
        &self,
        inputs: &[PartitionedRelation],
        cfg: &ClusterConfig,
        backend: &dyn KernelBackend,
    ) -> Result<StepResult, DistError> {
        // Forward with tape.
        let (tape, mut stats) = dist_eval_tape(&self.fwd, inputs, cfg, backend)?;
        let out = tape.output(&self.fwd).gather();
        if out.len() != 1 {
            return Err(DistError::Other(anyhow::anyhow!(
                "loss query must produce one tuple, got {}",
                out.len()
            )));
        }
        let loss = out.iter().next().unwrap().1.as_scalar();

        // Seed: {(keyOut, 1)} on every worker that holds the output.
        let seed = Relation::from_pairs(vec![(Key::empty(), Chunk::scalar(1.0))]);
        let mut bwd_inputs =
            vec![PartitionedRelation::replicate(&seed, cfg.workers)];
        for &fwd_node in &self.bwd.tape_inputs {
            bwd_inputs.push(tape.rels[fwd_node].clone());
        }
        let outs: Vec<NodeId> = self.bwd.slot_outputs.iter().map(|&(_, id)| id).collect();
        let (grad_parts, bstats) =
            dist_eval_multi(&self.bwd.query, &bwd_inputs, &outs, cfg, backend)?;
        stats.merge(&bstats);
        let grads = self
            .bwd
            .slot_outputs
            .iter()
            .zip(grad_parts)
            .map(|(&(slot, _), p)| (slot, p.gather()))
            .collect();
        Ok(StepResult { loss, grads, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::grad_wrt;
    use crate::data::graphs::power_law_graph;
    use crate::kernels::NativeBackend;
    use crate::ml::gcn::{self, GcnConfig};
    use crate::util::Prng;

    #[test]
    fn dist_gcn_step_matches_single_node_gradients() {
        let g = power_law_graph("t", 50, 150, 8, 4, 0.5, 23);
        let cfg = GcnConfig {
            feat_dim: 8,
            hidden: 8,
            n_labels: 4,
            dropout: None,
            seed: 2,
        };
        let q = gcn::loss_query(&cfg, g.labels.len());
        let mut rng = Prng::new(24);
        let (w1, w2) = gcn::init_params(&cfg, &mut rng);
        let inputs_sn = [&w1, &w2, &g.edges, &g.feats, &g.labels];
        let (tape_sn, grads_sn) =
            grad_wrt(&q, &inputs_sn, &[gcn::SLOT_W1, gcn::SLOT_W2], &NativeBackend).unwrap();
        let loss_sn = tape_sn
            .output(&q)
            .get(&Key::empty())
            .unwrap()
            .as_scalar();

        let trainer =
            DistTrainer::new(q.clone(), &[1, 1, 2, 1, 1], &[gcn::SLOT_W1, gcn::SLOT_W2])
                .unwrap();
        let w = 4;
        let ccfg = ClusterConfig::new(w);
        let pins = vec![
            PartitionedRelation::replicate(&w1, w),
            PartitionedRelation::replicate(&w2, w),
            PartitionedRelation::hash_partition(&g.edges, &[0], w),
            PartitionedRelation::hash_full(&g.feats, w),
            PartitionedRelation::hash_full(&g.labels, w),
        ];
        let res = trainer.step(&pins, &ccfg, &NativeBackend).unwrap();
        assert!((res.loss - loss_sn).abs() < 1e-4, "{} vs {loss_sn}", res.loss);
        for (slot, grel) in &res.grads {
            assert!(
                grel.approx_eq(grads_sn.slot(*slot), 1e-3),
                "slot {slot} gradient mismatch"
            );
        }
        assert!(res.stats.virtual_time_s > 0.0);
    }
}
