//! Distributed training driver: run the forward query distributed with
//! tape capture, feed the taped partitions into the generated backward
//! query (graph-mode autodiff), gather parameter gradients, apply the
//! optimizer — the full per-epoch path the Tables 2–3 / Figure 2–3
//! benches time on the virtual cluster.
//!
//! # Worker-pool lifecycle
//!
//! Every threaded evaluation runs on a persistent
//! [`WorkerPool`](crate::dist::WorkerPool) — parked worker threads, one
//! `KernelBackend` instance each, minted exactly once per pool via
//! `for_worker`. [`DistTrainer::step`] builds one pool per step and
//! shares it between the forward and the generated backward evaluation
//! (and their gathers); [`TrainPipeline`] goes further and caches its
//! pool across steps, so a whole training loop mints `w` backends
//! *total* — which is the difference between one and dozens of PJRT
//! artifact loads under `--features xla`. The pipeline rebuilds the pool
//! only when the worker count or the backend changes, and drops it when
//! a step runs with threading disabled. Callers managing their own pool
//! use [`DistTrainer::step_in`].
//!
//! # Mini-batch pipelines and the partition cache
//!
//! Re-partitioning inputs on every optimizer step is pure waste: the
//! data relations (edges, features, labels, …) do not change between
//! steps — only the parameters do. [`TrainPipeline`] therefore
//! hash-partitions each *data* slot once, caches the
//! [`PartitionedRelation`] handles, and on every subsequent step re-homes
//! only the *parameter* slots (replicated, so the optimizer delta reaches
//! every worker). Ingest traffic is charged to
//! [`ExecStats::bytes_ingested`] — after the first step it drops to the
//! parameter bytes alone, and the data slots move **zero** bytes (the
//! cache test asserts this).
//!
//! Cache invariants:
//!
//! * a cached slot's `Relation` must not change while it is cached —
//!   call [`TrainPipeline::invalidate`] when switching to a new
//!   mini-batch sample;
//! * the cache is per worker count — a step with a different
//!   `cfg.workers` re-partitions (and re-charges) automatically;
//! * cached shards are `Arc` handles shared with the executor's tapes,
//!   so reuse is a pointer copy, never a deep copy.

use crate::autodiff::graph::{backward_graph, BackwardPlan};
use crate::dist::exec::{eval_multi_core, eval_tape_core};
use crate::dist::{ClusterConfig, DistError, ExecStats, PartitionedRelation, WorkerPool};
use crate::kernels::KernelBackend;
use crate::plan::factorize::factorize_query_gated;
use crate::ra::expr::{NodeId, Query};
use crate::ra::{Chunk, Key, Relation};
use anyhow::Result;

/// A compiled (forward, backward) pair for distributed training.
pub struct DistTrainer {
    pub fwd: Query,
    pub bwd: BackwardPlan,
    pub param_slots: Vec<usize>,
}

/// One step's outputs.
pub struct StepResult {
    pub loss: f32,
    /// (slot, gathered gradient relation)
    pub grads: Vec<(usize, Relation)>,
    pub stats: ExecStats,
}

impl DistTrainer {
    /// `in_arities[i]` = key width of input slot i.
    pub fn new(fwd: Query, in_arities: &[usize], param_slots: &[usize]) -> Result<DistTrainer> {
        let bwd = backward_graph(&fwd, in_arities, param_slots)?;
        Ok(DistTrainer {
            fwd,
            bwd,
            param_slots: param_slots.to_vec(),
        })
    }

    /// Execute forward + backward on the virtual cluster. `inputs` are
    /// the forward query's inputs, already partitioned. Builds one
    /// [`WorkerPool`] for the whole step when the configuration threads
    /// — forward, backward, and every gather share it, so `for_worker`
    /// runs exactly `cfg.workers` times per step.
    #[deprecated(
        since = "0.2.0",
        note = "use `session::Session::trainer` — the session owns the pool across every \
                step and accumulates per-step `ExecStats` (see the `session` migration note)"
    )]
    pub fn step(
        &self,
        inputs: &[PartitionedRelation],
        cfg: &ClusterConfig,
        backend: &dyn KernelBackend,
    ) -> Result<StepResult, DistError> {
        let pool = WorkerPool::maybe_new(cfg, backend);
        step_core(self, inputs, cfg, backend, pool.as_ref())
    }

    /// [`step`](Self::step) on a caller-provided worker pool (or `None`
    /// for the serial reference path).
    #[deprecated(
        since = "0.2.0",
        note = "use `session::Session::trainer` (see the `session` migration note)"
    )]
    pub fn step_in(
        &self,
        inputs: &[PartitionedRelation],
        cfg: &ClusterConfig,
        backend: &dyn KernelBackend,
        pool: Option<&WorkerPool>,
    ) -> Result<StepResult, DistError> {
        step_core(self, inputs, cfg, backend, pool)
    }

    /// Build a partition-caching pipeline over this trainer.
    /// `layouts[slot]` describes how slot `slot` lives on the cluster;
    /// parameter slots (per `param_slots`) are re-homed every step, all
    /// other slots are partitioned once and cached.
    #[deprecated(
        since = "0.2.0",
        note = "use `session::Session::trainer` with a `session::ModelSpec` — named \
                parameter slots replace the positional layout vector \
                (see the `session` migration note)"
    )]
    #[allow(deprecated)]
    pub fn pipeline(&self, layouts: Vec<SlotLayout>) -> TrainPipeline<'_> {
        assert_eq!(
            layouts.len(),
            self.fwd.n_slots,
            "one layout per forward input slot"
        );
        TrainPipeline {
            trainer: self,
            cached: vec![None; layouts.len()],
            layouts,
            pool: None,
        }
    }
}

/// One forward+backward training step on the shared execution core —
/// the body behind both `session::SessionTrainer::step` (the supported
/// front door) and the deprecated `DistTrainer::step{,_in}` wrappers.
/// Forward (taped), backward, and every gather share `pool`.
pub(crate) fn step_core(
    trainer: &DistTrainer,
    inputs: &[PartitionedRelation],
    cfg: &ClusterConfig,
    backend: &dyn KernelBackend,
    pool: Option<&WorkerPool>,
) -> Result<StepResult, DistError> {
    let comm_pool = if cfg.parallel && cfg.parallel_comm {
        pool
    } else {
        None
    };
    // Forward with tape. The forward runs as-written (its tape feeds the
    // backward scan slots by node id); factorization applies to the
    // backward query below.
    let (tape, mut stats) = eval_tape_core(&trainer.fwd, inputs, cfg, backend, pool, &[], None)?;
    let out = tape.output(&trainer.fwd).gather_in(comm_pool);
    if out.len() != 1 {
        return Err(DistError::Other(anyhow::anyhow!(
            "loss query must produce one tuple, got {}",
            out.len()
        )));
    }
    let loss = out.iter().next().unwrap().1.as_scalar();

    // Seed: {(keyOut, 1)} on every worker that holds the output.
    let seed = Relation::from_pairs(vec![(Key::empty(), Chunk::scalar(1.0))]);
    let mut bwd_inputs = vec![PartitionedRelation::replicate(&seed, cfg.workers)];
    for &fwd_node in &trainer.bwd.tape_inputs {
        bwd_inputs.push(tape.rels[fwd_node].clone());
    }
    let outs: Vec<NodeId> = trainer.bwd.slot_outputs.iter().map(|&(_, id)| id).collect();
    // Factorized evaluation (A/B: `cfg.factorize_agg`): the generated
    // backward query has the same Σ-over-⋈ shape as the forward, so push
    // partial Σ below its joins when the rewrite is legal and the live
    // layouts say it pays off.
    let fact = cfg
        .factorize_agg
        .then(|| {
            let arities: Vec<usize> = bwd_inputs.iter().map(|p| p.key_arity()).collect();
            factorize_query_gated(&trainer.bwd.query, &arities, &bwd_inputs)
        })
        .flatten();
    let (grad_parts, bstats) = match &fact {
        Some(f) => {
            let fouts: Vec<NodeId> = outs.iter().map(|&id| f.node_map[id]).collect();
            eval_multi_core(
                &f.query,
                &bwd_inputs,
                &fouts,
                cfg,
                backend,
                pool,
                &f.agg_exchange,
            )?
        }
        None => eval_multi_core(&trainer.bwd.query, &bwd_inputs, &outs, cfg, backend, pool, &[])?,
    };
    stats.merge(&bstats);
    let grads = trainer
        .bwd
        .slot_outputs
        .iter()
        .zip(grad_parts)
        .map(|(&(slot, _), p)| (slot, p.gather_in(comm_pool)))
        .collect();
    Ok(StepResult { loss, grads, stats })
}

/// How one input slot is laid out on the virtual cluster.
/// (`PartialEq`/`Eq` because checkpoint restore validates that the
/// manifest's recorded layouts match the spec's — see
/// `Session::restore_trainer`.)
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SlotLayout {
    /// Full copy on every worker (model parameters, gradient seeds).
    Replicated,
    /// Hash-partitioned on the given key components (e.g. edges on the
    /// destination vertex: `HashOn(vec![0])`).
    HashOn(Vec<usize>),
    /// Hash-partitioned on the full key.
    HashFull,
}

impl SlotLayout {
    /// Materialize a relation on the cluster under this layout.
    pub(crate) fn place(&self, rel: &Relation, w: usize) -> PartitionedRelation {
        match self {
            SlotLayout::Replicated => PartitionedRelation::replicate(rel, w),
            SlotLayout::HashOn(comps) => PartitionedRelation::hash_partition(rel, comps, w),
            SlotLayout::HashFull => PartitionedRelation::hash_full(rel, w),
        }
    }

    /// Bytes the driver ships to first place a relation of `nbytes`
    /// payload under this layout on `w` workers: one copy per worker for
    /// replication, one copy total for a hash scatter.
    pub(crate) fn ingest_bytes(&self, nbytes: u64, w: usize) -> u64 {
        match self {
            SlotLayout::Replicated => nbytes * w as u64,
            _ => nbytes,
        }
    }

    /// Modeled seconds to ship [`ingest_bytes`](Self::ingest_bytes)
    /// under this layout: replication is an allgather of one replica,
    /// anything else a hash scatter. The single home of this formula —
    /// `Session` registration, `SessionTrainer::step`, and the legacy
    /// `TrainPipeline` all charge through it, keeping their stats
    /// comparable.
    pub(crate) fn ingest_time(&self, net: &crate::dist::NetModel, ingest_bytes: u64, w: usize) -> f64 {
        match self {
            SlotLayout::Replicated => net.allgather_time(ingest_bytes / w as u64, w),
            _ => net.shuffle_time(ingest_bytes, w),
        }
    }
}

/// Mini-batch training pipeline: caches hash-partitioned data inputs
/// across [`DistTrainer::step`]s and re-homes only the parameter deltas
/// (see the module docs for the cache invariants).
#[deprecated(
    since = "0.2.0",
    note = "use `session::Session::trainer` — the session catalog is the partition cache \
            and the session owns the worker pool (see the `session` migration note)"
)]
pub struct TrainPipeline<'a> {
    trainer: &'a DistTrainer,
    layouts: Vec<SlotLayout>,
    cached: Vec<Option<PartitionedRelation>>,
    /// The persistent worker pool, built lazily on the first threaded
    /// step and reused across every subsequent step (and the
    /// forward/backward pair inside each) — `for_worker` runs `w` times
    /// per training *loop*, not per evaluation. Rebuilt when the worker
    /// count or backend changes; dropped when a step runs with threading
    /// disabled.
    pool: Option<WorkerPool>,
}

#[allow(deprecated)]
impl TrainPipeline<'_> {
    /// Drop every cached partition *and* the worker pool (e.g. when the
    /// mini-batch sample or the worker count changes). The next step
    /// re-partitions everything and re-mints the pool backends.
    ///
    /// The automatic pool-staleness check compares worker count and
    /// `KernelBackend::name()` only — it cannot tell apart two backend
    /// instances of the same type with different configuration (say, two
    /// XLA backends loaded from different artifact directories). Call
    /// `invalidate` when switching between same-named backends.
    pub fn invalidate(&mut self) {
        for c in &mut self.cached {
            *c = None;
        }
        self.pool = None;
    }

    /// True iff slot `slot` will be re-partitioned on the next step.
    pub fn is_cold(&self, slot: usize) -> bool {
        self.trainer.param_slots.contains(&slot) || self.cached[slot].is_none()
    }

    /// One training step. `inputs[slot]` is the current relation for
    /// each forward slot: parameter slots are re-homed (their values
    /// change every step), data slots are served from the cache after
    /// the first step — their relations must be unchanged since then.
    pub fn step(
        &mut self,
        inputs: &[&Relation],
        cfg: &ClusterConfig,
        backend: &dyn KernelBackend,
    ) -> Result<StepResult, DistError> {
        if inputs.len() != self.layouts.len() {
            return Err(DistError::Other(anyhow::anyhow!(
                "pipeline needs {} input(s), got {}",
                self.layouts.len(),
                inputs.len()
            )));
        }
        let w = cfg.workers;
        let mut ingest: u64 = 0;
        let mut ingest_s: f64 = 0.0;
        let mut placed: Vec<PartitionedRelation> = Vec::with_capacity(inputs.len());
        for (slot, rel) in inputs.iter().enumerate() {
            let is_param = self.trainer.param_slots.contains(&slot);
            let cached = if is_param { None } else { self.cached[slot].take() };
            let part = match cached {
                // Cache hit: reuse the shard handles, move zero bytes.
                Some(p) if p.workers() == w => p,
                _ => {
                    let p = self.layouts[slot].place(rel, w);
                    let bytes = self.layouts[slot].ingest_bytes(rel.nbytes() as u64, w);
                    ingest += bytes;
                    ingest_s += self.layouts[slot].ingest_time(&cfg.net, bytes, w);
                    p
                }
            };
            if !is_param {
                self.cached[slot] = Some(part.clone());
            }
            placed.push(part);
        }
        let pool_stale = match self.pool.as_ref() {
            None => true,
            Some(p) => {
                p.workers() != w
                    || p.backend_name() != backend.name()
                    // A changed spill setup (policy/budget presence or
                    // scratch root) must re-reserve the pool's scratch.
                    || !p.spill_matches(cfg)
            }
        };
        if !WorkerPool::engages(cfg) {
            self.pool = None;
        } else if pool_stale {
            // `new_for`: a budgeted-Spill cluster shape also reserves the
            // pool's spill scratch space (reused across the cached steps).
            self.pool = Some(WorkerPool::new_for(cfg, backend));
        }
        let mut res = step_core(self.trainer, &placed, cfg, backend, self.pool.as_ref())?;
        res.stats.bytes_ingested += ingest;
        res.stats.net_s += ingest_s;
        res.stats.virtual_time_s += ingest_s;
        Ok(res)
    }
}

#[cfg(test)]
// The legacy trainer surface stays covered until removal — these tests
// pin its behaviour (and the pipeline cache semantics the session
// catalog inherited). New code goes through `session::Session::trainer`.
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::autodiff::grad_wrt;
    use crate::data::graphs::power_law_graph;
    use crate::kernels::NativeBackend;
    use crate::ml::gcn::{self, GcnConfig};
    use crate::util::Prng;

    #[test]
    fn dist_gcn_step_matches_single_node_gradients() {
        let g = power_law_graph("t", 50, 150, 8, 4, 0.5, 23);
        let cfg = GcnConfig {
            feat_dim: 8,
            hidden: 8,
            n_labels: 4,
            dropout: None,
            seed: 2,
        };
        let q = gcn::loss_query(&cfg, g.labels.len());
        let mut rng = Prng::new(24);
        let (w1, w2) = gcn::init_params(&cfg, &mut rng);
        let inputs_sn = [&w1, &w2, &g.edges, &g.feats, &g.labels];
        let (tape_sn, grads_sn) =
            grad_wrt(&q, &inputs_sn, &[gcn::SLOT_W1, gcn::SLOT_W2], &NativeBackend).unwrap();
        let loss_sn = tape_sn
            .output(&q)
            .get(&Key::empty())
            .unwrap()
            .as_scalar();

        let trainer =
            DistTrainer::new(q.clone(), &[1, 1, 2, 1, 1], &[gcn::SLOT_W1, gcn::SLOT_W2])
                .unwrap();
        let w = 4;
        let ccfg = ClusterConfig::new(w);
        let pins = vec![
            PartitionedRelation::replicate(&w1, w),
            PartitionedRelation::replicate(&w2, w),
            PartitionedRelation::hash_partition(&g.edges, &[0], w),
            PartitionedRelation::hash_full(&g.feats, w),
            PartitionedRelation::hash_full(&g.labels, w),
        ];
        let res = trainer.step(&pins, &ccfg, &NativeBackend).unwrap();
        assert!((res.loss - loss_sn).abs() < 1e-4, "{} vs {loss_sn}", res.loss);
        for (slot, grel) in &res.grads {
            assert!(
                grel.approx_eq(grads_sn.slot(*slot), 1e-3),
                "slot {slot} gradient mismatch"
            );
        }
        assert!(res.stats.virtual_time_s > 0.0);
        assert!(res.stats.wall_s > 0.0);
    }

    /// In-place SGD: `target[k] -= lr * grad[k]` — shared by both runs
    /// of the pipeline test so their update arithmetic is identical.
    fn sgd_apply(target: &mut Relation, grel: &Relation, lr: f32) {
        for kv in target.iter_mut() {
            let (k, v) = (&kv.0, &mut kv.1);
            if let Some(g) = grel.get(k) {
                let mut d = g.clone();
                d.scale_assign(-lr);
                v.add_assign(&d);
            }
        }
    }

    #[test]
    fn pipeline_caches_data_partitions_and_rehomes_only_params() {
        let g = power_law_graph("p", 40, 120, 8, 4, 0.5, 31);
        let cfg = GcnConfig {
            feat_dim: 8,
            hidden: 8,
            n_labels: 4,
            dropout: None,
            seed: 5,
        };
        let q = gcn::loss_query(&cfg, g.labels.len());
        let mut rng = Prng::new(77);
        let (mut w1, mut w2) = gcn::init_params(&cfg, &mut rng);
        let trainer =
            DistTrainer::new(q, &[1, 1, 2, 1, 1], &[gcn::SLOT_W1, gcn::SLOT_W2]).unwrap();
        let mut pipe = trainer.pipeline(vec![
            SlotLayout::Replicated,          // W1 (param)
            SlotLayout::Replicated,          // W2 (param)
            SlotLayout::HashOn(vec![0]),     // edges
            SlotLayout::HashFull,            // feats
            SlotLayout::HashFull,            // labels
        ]);
        let w = 3;
        let ccfg = ClusterConfig::new(w);
        let param_bytes = (w1.nbytes() as u64 + w2.nbytes() as u64) * w as u64;
        let data_bytes =
            g.edges.nbytes() as u64 + g.feats.nbytes() as u64 + g.labels.nbytes() as u64;

        let mut losses = Vec::new();
        for step in 0..3 {
            let inputs: Vec<&Relation> = vec![&w1, &w2, &g.edges, &g.feats, &g.labels];
            let res = pipe.step(&inputs, &ccfg, &NativeBackend).unwrap();
            if step == 0 {
                // Cold cache: params + every data slot crossed the wire.
                assert_eq!(res.stats.bytes_ingested, param_bytes + data_bytes);
            } else {
                // Warm cache: only the parameter deltas are re-homed —
                // the data slots perform ZERO re-partitioning.
                assert_eq!(res.stats.bytes_ingested, param_bytes, "step {step}");
            }
            // Parameters move every step: apply a plain SGD delta.
            for (slot, grel) in &res.grads {
                let target = if *slot == gcn::SLOT_W1 { &mut w1 } else { &mut w2 };
                sgd_apply(target, grel, 0.1);
            }
            losses.push(res.loss);
        }
        // The warm steps reused the exact cached shard handles.
        assert!(!pipe.is_cold(2) && !pipe.is_cold(3) && !pipe.is_cold(4));
        assert!(pipe.is_cold(gcn::SLOT_W1) && pipe.is_cold(gcn::SLOT_W2));

        // A pipelined run computes the same losses as manual per-step
        // partitioning (bitwise: identical partitions ⇒ identical order).
        let (mut v1, mut v2) = {
            let mut rng = Prng::new(77);
            gcn::init_params(&cfg, &mut rng)
        };
        for (step, want) in losses.iter().enumerate() {
            let pins = vec![
                PartitionedRelation::replicate(&v1, w),
                PartitionedRelation::replicate(&v2, w),
                PartitionedRelation::hash_partition(&g.edges, &[0], w),
                PartitionedRelation::hash_full(&g.feats, w),
                PartitionedRelation::hash_full(&g.labels, w),
            ];
            let res = trainer.step(&pins, &ccfg, &NativeBackend).unwrap();
            assert_eq!(res.loss.to_bits(), want.to_bits(), "step {step}");
            for (slot, grel) in &res.grads {
                let target = if *slot == gcn::SLOT_W1 { &mut v1 } else { &mut v2 };
                sgd_apply(target, grel, 0.1);
            }
        }
    }

    /// A backend counting `for_worker` mints, for the pool-staleness
    /// coverage below (worker instances dispatch natively, identically
    /// to the root).
    struct CountingBackend(std::sync::Arc<std::sync::atomic::AtomicUsize>);

    impl KernelBackend for CountingBackend {
        fn unary(
            &self,
            k: &crate::kernels::UnaryKernel,
            key: &Key,
            x: &Chunk,
        ) -> Chunk {
            crate::kernels::native::apply_unary(k, key, x)
        }
        fn binary(
            &self,
            k: &crate::kernels::BinaryKernel,
            key: &Key,
            l: &Chunk,
            r: &Chunk,
        ) -> Chunk {
            crate::kernels::native::apply_binary(k, key, l, r)
        }
        fn name(&self) -> &'static str {
            "counting"
        }
        fn for_worker(&self) -> Box<dyn KernelBackend + Send + Sync> {
            self.0.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            Box::new(crate::kernels::NativeBackend)
        }
    }

    /// The legacy pipeline's pool-staleness path stays covered until the
    /// deprecated surface is removed: a serial step drops the cached
    /// pool (and mints nothing), and the next threaded step rebuilds it
    /// exactly once.
    #[test]
    fn pipeline_pool_drops_on_serial_step_and_rebuilds_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let g = power_law_graph("ps", 30, 90, 8, 4, 0.5, 13);
        let cfg = GcnConfig {
            feat_dim: 8,
            hidden: 8,
            n_labels: 4,
            dropout: None,
            seed: 5,
        };
        let q = gcn::loss_query(&cfg, g.labels.len());
        let trainer =
            DistTrainer::new(q, &[1, 1, 2, 1, 1], &[gcn::SLOT_W1, gcn::SLOT_W2]).unwrap();
        let w = 2;
        let ccfg = ClusterConfig::new(w);
        let expect = if WorkerPool::engages(&ccfg) { w } else { 0 };
        let minted = std::sync::Arc::new(AtomicUsize::new(0));
        let backend = CountingBackend(std::sync::Arc::clone(&minted));
        let mut rng = Prng::new(21);
        let (w1, w2) = gcn::init_params(&cfg, &mut rng);
        let mut pipe = trainer.pipeline(vec![
            SlotLayout::Replicated,
            SlotLayout::Replicated,
            SlotLayout::HashOn(vec![0]),
            SlotLayout::HashFull,
            SlotLayout::HashFull,
        ]);
        let inputs = [&w1, &w2, &g.edges, &g.feats, &g.labels];
        // Two threaded steps share one pool: `w` mints total.
        pipe.step(&inputs, &ccfg, &backend).unwrap();
        pipe.step(&inputs, &ccfg, &backend).unwrap();
        assert_eq!(minted.load(Ordering::SeqCst), expect, "pool reused across steps");
        // A serial step drops the pool and mints nothing.
        let serial = ClusterConfig::new(w).with_parallel(false);
        pipe.step(&inputs, &serial, &backend).unwrap();
        assert_eq!(minted.load(Ordering::SeqCst), expect, "serial step must not mint");
        // The next threaded step re-mints exactly once more.
        pipe.step(&inputs, &ccfg, &backend).unwrap();
        assert_eq!(
            minted.load(Ordering::SeqCst),
            expect * 2,
            "pool rebuilt exactly once after the serial step"
        );
    }
}
