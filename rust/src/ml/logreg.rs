//! §2.3's worked example: logistic regression with cross-entropy loss,
//! built exactly as the paper's `F_MatMul → F_Predict → F_Loss` pipeline.

use crate::kernels::{AggKernel, BinaryKernel, UnaryKernel};
use crate::ra::expr::{Query, QueryBuilder};
use crate::ra::funcs::{JoinPred, KeyProj, KeyProj2, Sel2};
use crate::ra::{Chunk, Key, Relation};
use crate::util::Prng;
use std::sync::Arc;

/// Build the loss query. Slots: 0 = Θ (`⟨col-block⟩ → (C,1)`).
/// X (`⟨row-block, col-block⟩ → (C,C)`) and y (`⟨row-block⟩ → (C,1)`)
/// are constants, as in the paper ("some relations must be constant").
///
/// ```text
/// F_MatMul  ≡ Σ(grp, +, ⋈const(pred, proj, ⊗=MatMul, R_x, τ(colID)))
/// F_Predict ≡ σ(true, id, logistic, F_MatMul)
/// F_Loss    ≡ Σ(⟨⟩, +, ⋈const(pred, proj, ⊗=BCE, F_Predict, R_y))
/// ```
pub fn loss_query(x: Arc<Relation>, y: Arc<Relation>, n_rows: usize) -> Query {
    let mut qb = QueryBuilder::new();
    // F_MatMul: X(ri, ci) ⋈ Θ(ci), per-block X·θ, Σ over ci.
    let xs = qb.constant(x, "R_x");
    let theta = qb.scan(0, "theta");
    let j = qb.join(
        JoinPred::on(vec![(1, 0)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1)]),
        BinaryKernel::MatMul,
        xs,
        theta,
    );
    let z = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, j);
    // F_Predict: logistic.
    let p = qb.map(UnaryKernel::Logistic, 1, z);
    // F_Loss: ⋈const with labels, BCE kernel, Σ to one tuple, mean.
    let ys = qb.constant(y, "R_y");
    let l = qb.join(
        JoinPred::on(vec![(0, 0)]),
        KeyProj2(vec![Sel2::L(0)]),
        BinaryKernel::BceLoss,
        p,
        ys,
    );
    let per_block = qb.map(UnaryKernel::SumAll, 1, l);
    let total = qb.agg(KeyProj::to_empty(), AggKernel::Sum, per_block);
    let mean = qb.map(UnaryKernel::Scale(1.0 / n_rows as f32), 0, total);
    qb.finish(mean)
}

/// A generated logistic-regression problem (blocked storage).
pub struct LogRegData {
    pub x: Relation,
    pub y: Relation,
    pub theta0: Relation,
    pub n_rows: usize,
    pub chunk: usize,
}

pub fn synthetic(n_rows: usize, n_cols: usize, chunk: usize, seed: u64) -> LogRegData {
    let mut rng = Prng::new(seed);
    let nb_r = n_rows.div_ceil(chunk);
    let nb_c = n_cols.div_ceil(chunk);
    // ground-truth weights
    let truth: Vec<f32> = (0..n_cols).map(|_| rng.normal()).collect();
    let mut xdense = vec![vec![0f32; n_cols]; n_rows];
    for row in xdense.iter_mut() {
        for v in row.iter_mut() {
            *v = rng.normal() * 0.5;
        }
    }
    let mut x = Relation::new();
    for bi in 0..nb_r {
        for bj in 0..nb_c {
            let mut c = Chunk::zeros(chunk, chunk);
            for i in 0..chunk {
                for j in 0..chunk {
                    let (gi, gj) = (bi * chunk + i, bj * chunk + j);
                    if gi < n_rows && gj < n_cols {
                        c.set(i, j, xdense[gi][gj]);
                    }
                }
            }
            x.insert(Key::k2(bi as i64, bj as i64), c);
        }
    }
    let mut y = Relation::new();
    for bi in 0..nb_r {
        let mut c = Chunk::zeros(chunk, 1);
        for i in 0..chunk {
            let gi = bi * chunk + i;
            if gi < n_rows {
                let logit: f32 = (0..n_cols).map(|j| xdense[gi][j] * truth[j]).sum();
                c.set(i, 0, if logit > 0.0 { 1.0 } else { 0.0 });
            }
        }
        y.insert(Key::k1(bi as i64), c);
    }
    let mut theta0 = Relation::new();
    for bj in 0..nb_c {
        theta0.insert(Key::k1(bj as i64), Chunk::zeros(chunk, 1));
    }
    LogRegData {
        x,
        y,
        theta0,
        n_rows,
        chunk,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::{check::finite_diff_grad, grad};
    use crate::kernels::NativeBackend;
    use crate::ml::Sgd;

    #[test]
    fn loss_decreases_under_sgd() {
        let d = synthetic(64, 16, 8, 5);
        let q = loss_query(Arc::new(d.x.clone()), Arc::new(d.y.clone()), d.n_rows);
        let mut theta = d.theta0.clone();
        let sgd = Sgd::new(1.0);
        let mut losses = Vec::new();
        for _ in 0..30 {
            let (tape, grads) = grad(&q, &[&theta], &NativeBackend).unwrap();
            losses.push(tape.output(&q).get(&Key::empty()).unwrap().as_scalar());
            sgd.step(&mut theta, grads.slot(0));
        }
        assert!(
            losses[29] < losses[0] * 0.5,
            "no convergence: {losses:?}"
        );
        // cross-entropy of a separable problem should go well below ln 2
        assert!(losses[29] < 0.4, "final loss too high: {}", losses[29]);
    }

    #[test]
    fn gradient_matches_closed_form() {
        // ∇θ = Xᵀ(σ(Xθ) − y)/n, assembled natively per block.
        let d = synthetic(16, 8, 4, 7);
        let mut rng = Prng::new(8);
        let mut theta = d.theta0.clone();
        for (_, c) in theta.iter_mut() {
            *c = Chunk::random(4, 1, &mut rng, 0.3);
        }
        let q = loss_query(Arc::new(d.x.clone()), Arc::new(d.y.clone()), d.n_rows);
        let (_, grads) = grad(&q, &[&theta], &NativeBackend).unwrap();

        // closed form
        use crate::kernels::native::{matmul, matmul_tn};
        let nb_r = 4;
        let nb_c = 2;
        let mut want = Relation::new();
        for bj in 0..nb_c {
            want.insert(Key::k1(bj), Chunk::zeros(4, 1));
        }
        for bi in 0..nb_r {
            // z_bi = Σ_bj X[bi,bj]·θ[bj]
            let mut z = Chunk::zeros(4, 1);
            for bj in 0..nb_c {
                let x = d.x.get(&Key::k2(bi, bj)).unwrap();
                let t = theta.get(&Key::k1(bj)).unwrap();
                z.add_assign(&matmul(x, t));
            }
            let p = z.map(|v| 1.0 / (1.0 + (-v).exp()));
            let y = d.y.get(&Key::k1(bi)).unwrap();
            let resid = p.zip_map(y, |a, b| (a - b) / 16.0);
            for bj in 0..nb_c {
                let x = d.x.get(&Key::k2(bi, bj)).unwrap();
                let w = want.iter_mut().find(|(k, _)| *k == Key::k1(bj)).unwrap();
                w.1.add_assign(&matmul_tn(x, &resid));
            }
        }
        assert!(
            grads.slot(0).approx_eq(&want, 1e-3),
            "autodiff {:?} vs closed form {:?}",
            grads.slot(0),
            want
        );
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let d = synthetic(8, 4, 4, 9);
        let mut rng = Prng::new(10);
        let mut theta = d.theta0.clone();
        for (_, c) in theta.iter_mut() {
            *c = Chunk::random(4, 1, &mut rng, 0.3);
        }
        let q = loss_query(Arc::new(d.x.clone()), Arc::new(d.y.clone()), d.n_rows);
        let (_, grads) = grad(&q, &[&theta], &NativeBackend).unwrap();
        let fd = finite_diff_grad(&q, &[&theta], 0, 1e-2, &NativeBackend).unwrap();
        crate::autodiff::check::assert_grad_close(grads.slot(0), &fd, 5e-2);
    }
}
