//! # relad — Auto-Differentiation of Relational Computations
//!
//! A tensor-relational engine with reverse-mode autodiff performed *in the
//! relational algebra*, reproducing "Auto-Differentiation of Relational
//! Computations for Very Large Scale Machine Learning" (ICML 2023).
//!
//! Architecture (three layers, Python never on the hot path):
//!
//! * **L3 (this crate)** — the relational engine: functional RA (`ra`),
//!   relational autodiff (`autodiff`), query planning (`plan`), the
//!   virtual-cluster distributed runtime (`dist`), SQL frontend (`sql`),
//!   models (`ml`), baseline systems (`baselines`).
//! * **L2 (build time)** — chunk kernel functions written in JAX
//!   (`python/compile/model.py`), AOT-lowered to HLO text artifacts.
//! * **L1 (build time)** — the blocked-matmul Pallas kernel the L2
//!   kernels call (`python/compile/kernels/matmul_pallas.py`).
//!
//! The `dist` layer executes any functional-RA query across `w` virtual
//! workers: relations are hash-partitioned/replicated
//! (`dist::PartitionedRelation`, `Arc`-backed shards), joins are
//! co-partitioned when the partitioning invariant matches and otherwise
//! planned cost-based (broadcast vs reshuffle, `dist::exec::plan_join`),
//! aggregation is two-phase, and per-worker memory budgets either
//! grace-spill (`MemPolicy::Spill`) or OOM (`MemPolicy::Fail`). Every
//! stage — compute shards, shuffle route/build, gathers, Σ merges —
//! runs as jobs on a persistent `dist::WorkerPool` of real OS threads
//! (one `KernelBackend` per worker, minted once per run), so `ExecStats`
//! reports measured `wall_s` next to the modeled `virtual_time_s`.
//! `ml::DistTrainer` runs the taped distributed forward and feeds the
//! captured partitions into the generated backward query — the full
//! per-epoch path the paper's Tables 2–3 / Figures 2–3 time;
//! `ml::TrainPipeline` caches the hash-partitioned data inputs across
//! steps (re-homing only the parameter deltas) and its worker pool
//! across the whole training loop.
//!
//! See the repository-root `README.md` for a quickstart and
//! `docs/ARCHITECTURE.md` for a worked SQL → RA → autodiff → BSP-stages
//! trace.
//!
//! `runtime` loads the artifacts via the PJRT C API (`xla` crate) behind
//! the non-default `xla` cargo feature — the default build is hermetic
//! and serves every kernel from the native implementations.

pub mod autodiff;
pub mod baselines;
pub mod bench_util;
pub mod data;
pub mod dist;
pub mod kernels;
pub mod ml;
pub mod plan;
pub mod ra;
pub mod runtime;
pub mod sql;
pub mod util;
