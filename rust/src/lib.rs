//! # relad — Auto-Differentiation of Relational Computations
//!
//! A tensor-relational engine with reverse-mode autodiff performed *in the
//! relational algebra*, reproducing "Auto-Differentiation of Relational
//! Computations for Very Large Scale Machine Learning" (ICML 2023).
//!
//! Architecture (three layers, Python never on the hot path):
//!
//! * **L3 (this crate)** — the relational engine: functional RA (`ra`),
//!   relational autodiff (`autodiff`), query planning (`plan`), a
//!   simulated distributed runtime (`dist`), SQL frontend (`sql`), models
//!   (`ml`), baseline systems (`baselines`).
//! * **L2 (build time)** — chunk kernel functions written in JAX
//!   (`python/compile/model.py`), AOT-lowered to HLO text artifacts.
//! * **L1 (build time)** — the blocked-matmul Pallas kernel the L2
//!   kernels call (`python/compile/kernels/matmul_pallas.py`).
//!
//! `runtime` loads the artifacts via the PJRT C API (`xla` crate) and the
//! kernel registry dispatches chunk kernels to them.

pub mod autodiff;
pub mod baselines;
pub mod bench_util;
pub mod data;
pub mod dist;
pub mod kernels;
pub mod ml;
pub mod plan;
pub mod ra;
pub mod runtime;
pub mod sql;
pub mod util;
