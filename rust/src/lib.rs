//! # relad — Auto-Differentiation of Relational Computations
//!
//! A tensor-relational engine with reverse-mode autodiff performed *in the
//! relational algebra*, reproducing "Auto-Differentiation of Relational
//! Computations for Very Large Scale Machine Learning" (ICML 2023).
//!
//! Architecture (three layers, Python never on the hot path):
//!
//! * **L3 (this crate)** — the relational engine: the stateful
//!   [`session`] front door (`Session`: catalog + worker pool + unified
//!   SQL/query/gradient/training execution), functional RA (`ra`),
//!   relational autodiff (`autodiff`), query planning (`plan`), the
//!   virtual-cluster distributed runtime (`dist`), SQL frontend (`sql`),
//!   models (`ml`), baseline systems (`baselines`).
//! * **L2 (build time)** — chunk kernel functions written in JAX
//!   (`python/compile/model.py`), AOT-lowered to HLO text artifacts.
//! * **L1 (build time)** — the blocked-matmul Pallas kernel the L2
//!   kernels call (`python/compile/kernels/matmul_pallas.py`).
//!
//! The `dist` layer executes any functional-RA query across `w` virtual
//! workers: relations are hash-partitioned/replicated
//! (`dist::PartitionedRelation`, `Arc`-backed shards), joins are
//! co-partitioned when the partitioning invariant matches and otherwise
//! planned cost-based (broadcast vs reshuffle, `dist::exec::plan_join`),
//! aggregation is two-phase, and per-worker memory budgets either
//! grace-spill through real temp files (`MemPolicy::Spill` +
//! `dist::spill`: build sides stream to per-worker scratch and back,
//! bitwise identical to in-memory execution) or OOM
//! (`MemPolicy::Fail`). Every
//! stage — compute shards, shuffle route/build, gathers, Σ merges —
//! runs as jobs on a persistent `dist::WorkerPool` of real OS threads
//! (one `KernelBackend` per worker, minted once per run), so `ExecStats`
//! reports measured `wall_s` next to the modeled `virtual_time_s`.
//! All of it is driven through one stateful engine surface:
//! [`session::Session`] owns the persistent worker pool, a named-table
//! catalog of partitioned relations, and the unified execution entry
//! points — `sess.sql(..)` / `sess.query(..)` return a lazy `Frame`
//! (`collect` / `explain` / `grad`), and `sess.trainer(spec)` runs
//! whole training loops with named parameter slots, the catalog acting
//! as the cross-step partition cache (data placed once, only parameter
//! deltas re-homed). The pre-session free functions (`dist_eval*`,
//! `DistTrainer::step*`, `TrainPipeline`) are deprecated thin wrappers
//! over the same execution core.
//!
//! The [`serve`] layer turns one session into a concurrent multi-client
//! engine: `serve::Engine` owns the shared pool and catalog, mints
//! `Send` `serve::Client` handles, admits queries through a bounded
//! fair scheduler, answers repeats from an epoch-aware plan/result
//! cache, and optionally speaks HTTP/JSON over `std::net`
//! (`Engine::serve_http`).
//!
//! See the repository-root `README.md` for a quickstart and
//! `docs/ARCHITECTURE.md` for a worked SQL → RA → autodiff → BSP-stages
//! trace.
//!
//! `runtime` loads the artifacts via the PJRT C API (`xla` crate) behind
//! the non-default `xla` cargo feature — the default build is hermetic
//! and serves every kernel from the native implementations.

pub mod autodiff;
pub mod baselines;
pub mod bench_util;
pub mod data;
pub mod dist;
pub mod kernels;
pub mod ml;
pub mod plan;
pub mod ra;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod sql;
pub mod util;
