//! `relad` — launcher CLI for the tensor-relational autodiff engine.
//!
//! Subcommands:
//!   info                       engine + artifact status
//!   sql "<SELECT …>"           parse a SQL query, print RA + gradient SQL
//!   gcn  [workers=N] [steps=N] train the GCN e2e workload on the virtual cluster
//!   table2 | table3 | fig2 | fig3   (hint: `cargo bench --bench …`)
//!
//! Flags: backend=native|xla (default native), artifacts=DIR.

use relad::autodiff::backward_graph;
use relad::kernels::registry::{make_backend, BackendKind};
use relad::sql::{parse_query, to_sql, Catalog};

fn arg_val(name: &str) -> Option<String> {
    std::env::args().find_map(|a| a.strip_prefix(&format!("{name}=")).map(str::to_string))
}

fn main() -> anyhow::Result<()> {
    let cmd = std::env::args().nth(1).unwrap_or_else(|| "info".into());
    let backend_kind = match arg_val("backend").as_deref() {
        Some("xla") => BackendKind::Xla,
        _ => BackendKind::Native,
    };
    let artifacts = arg_val("artifacts").unwrap_or_else(|| "artifacts".into());

    match cmd.as_str() {
        "info" => {
            println!("relad — auto-differentiation of relational computations");
            println!("kernel backends: native (rust), xla (AOT JAX/Pallas artifacts)");
            if cfg!(feature = "xla") {
                match make_backend(BackendKind::Xla, &artifacts) {
                    Ok(_) => println!("artifacts: loaded from {artifacts}/ ✓"),
                    Err(e) => println!("artifacts: unavailable ({e}); run `make artifacts`"),
                }
            } else {
                println!(
                    "artifacts: xla feature disabled (hermetic build; \
                     rebuild with --features xla)"
                );
            }
            println!("examples: quickstart, train_gcn, nnmf, kge, sql_autodiff");
            println!("benches:  table2_gcn, table3_gcn, fig2_nnmf, fig3_kge, micro");
        }
        "sql" => {
            let sql = std::env::args()
                .nth(2)
                .ok_or_else(|| anyhow::anyhow!("usage: relad sql \"SELECT …\""))?;
            // Default demo catalog: two blocked matrices.
            let catalog = Catalog::default()
                .table("A", 0, &["row", "col"])
                .table("B", 1, &["row", "col"])
                .table("X", 0, &["row", "col"])
                .table("W", 1, &["row", "col"])
                .table("P", 0, &["row"]);
            let q = parse_query(&sql, &catalog)?;
            println!("--- RA plan ---\n{}", q.render());
            let plan = backward_graph(&q, &[2, 2], &[0, 1])?;
            println!("--- gradient SQL (slot 0 & 1) ---\n{}", to_sql(&plan.query));
        }
        "gcn" => {
            // Defer to the example binary's logic via library calls.
            let _ = make_backend(backend_kind, &artifacts)?;
            println!("use `cargo run --release --example train_gcn` for the full driver");
        }
        other => {
            anyhow::bail!("unknown command {other}; try `relad info`");
        }
    }
    Ok(())
}
