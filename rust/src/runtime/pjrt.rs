//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them as
//! chunk kernels from the L3 hot path.
//!
//! `make artifacts` (build-time python/JAX/Pallas) writes
//! `artifacts/manifest.tsv` + one `<kernel>__<shapes>.hlo.txt` per
//! kernel/shape pair. `XlaRuntime` compiles each on the PJRT CPU client
//! once at load; `XlaBackend` dispatches `KernelBackend` calls to the
//! matching executable, falling back to the native implementation for
//! key-dependent kernels (dropout), parameterized kernels (scale) and
//! shapes outside the artifact set. HLO *text* is the interchange format
//! (jax ≥ 0.5 emits 64-bit-id protos that xla_extension 0.5.1 rejects).

use crate::kernels::{BinaryKernel, KernelBackend, UnaryKernel};
use crate::ra::{Chunk, Key};
use crate::util::FxHashMap;
use anyhow::{bail, Context, Result};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// Shape signature of a kernel invocation (rows, cols per operand).
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
struct Sig {
    name: &'static str,
    shapes: Vec<(u32, u32)>,
}

/// A compiled artifact store bound to one PJRT CPU client.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    execs: FxHashMap<Sig, xla::PjRtLoadedExecutable>,
}

impl XlaRuntime {
    /// Load every artifact listed in `dir/manifest.tsv` and compile it.
    pub fn load(dir: &str) -> Result<XlaRuntime> {
        let manifest = Path::new(dir).join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {manifest:?}; run `make artifacts` first"))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut execs = FxHashMap::default();
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            let mut parts = line.split('\t');
            let (name, arity, shapes_s, file) = (
                parts.next().context("manifest: name")?,
                parts.next().context("manifest: arity")?,
                parts.next().context("manifest: shapes")?,
                parts.next().context("manifest: file")?,
            );
            let arity: usize = arity.parse()?;
            let shapes = parse_shapes(shapes_s)?;
            if shapes.len() != arity {
                bail!("manifest arity mismatch on line: {line}");
            }
            let static_name = match intern_kernel_name(name) {
                Some(n) => n,
                // Artifact for a kernel this engine build doesn't know;
                // skip it (forward compatibility).
                None => continue,
            };
            let path = Path::new(dir).join(file);
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .with_context(|| format!("parsing {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {path:?}"))?;
            execs.insert(
                Sig {
                    name: static_name,
                    shapes,
                },
                exe,
            );
        }
        if execs.is_empty() {
            bail!("no artifacts loaded from {dir}");
        }
        Ok(XlaRuntime { client, execs })
    }

    pub fn n_executables(&self) -> usize {
        self.execs.len()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute a compiled kernel on chunk operands; `None` if no artifact
    /// matches the signature.
    fn run(&self, sig: &Sig, args: &[&Chunk]) -> Result<Option<Vec<f32>>> {
        let Some(exe) = self.execs.get(sig) else {
            return Ok(None);
        };
        let mut lits = Vec::with_capacity(args.len());
        for a in args {
            let lit = xla::Literal::vec1(a.data())
                .reshape(&[a.rows() as i64, a.cols() as i64])
                .context("building input literal")?;
            lits.push(lit);
        }
        let bufs = exe.execute::<xla::Literal>(&lits).context("execute")?;
        let result = bufs[0][0].to_literal_sync()?;
        // Artifacts are lowered with return_tuple=True.
        let out = result.to_tuple1()?;
        Ok(Some(out.to_vec::<f32>()?))
    }
}

/// Kernel backend over `XlaRuntime` with native fallback + hit counters.
pub struct XlaBackend {
    rt: XlaRuntime,
    dir: String,
    hits: AtomicU64,
    misses: AtomicU64,
}

// SAFETY: the raw PJRT handles inside `rt` are only touched through
// `&self` dispatch, and PJRT *CPU* clients are internally synchronized
// (execution serializes inside the client). The hit/miss counters are
// atomics and `dir` is immutable, so sharing an `XlaBackend` across
// threads — required since `Session` state became shareable — cannot
// race on the Rust side.
unsafe impl Send for XlaBackend {}
unsafe impl Sync for XlaBackend {}

impl XlaBackend {
    pub fn load(dir: &str) -> Result<XlaBackend> {
        Ok(XlaBackend {
            rt: XlaRuntime::load(dir)?,
            dir: dir.to_string(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        })
    }

    /// (artifact hits, native fallbacks) since load.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }

    pub fn runtime(&self) -> &XlaRuntime {
        &self.rt
    }
}

impl KernelBackend for XlaBackend {
    fn unary(&self, k: &UnaryKernel, key: &Key, x: &Chunk) -> Chunk {
        // Key-dependent / parameterized / trivial kernels never ship as
        // artifacts — go native directly.
        if unary_native_only(k) {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return crate::kernels::native::apply_unary(k, key, x);
        }
        let sig = Sig {
            name: k.name(),
            shapes: vec![(x.rows() as u32, x.cols() as u32)],
        };
        match self.rt.run(&sig, &[x]) {
            Ok(Some(data)) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                let (r, c) = k.out_shape(x.shape());
                Chunk::from_vec(r, c, data)
            }
            _ => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                crate::kernels::native::apply_unary(k, key, x)
            }
        }
    }

    fn binary(&self, k: &BinaryKernel, key: &Key, l: &Chunk, r: &Chunk) -> Chunk {
        if binary_native_only(k) {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return crate::kernels::native::apply_binary(k, key, l, r);
        }
        let sig = Sig {
            name: k.name(),
            shapes: vec![
                (l.rows() as u32, l.cols() as u32),
                (r.rows() as u32, r.cols() as u32),
            ],
        };
        match self.rt.run(&sig, &[l, r]) {
            Ok(Some(data)) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                let (rr, cc) = k
                    .out_shape(l.shape(), r.shape())
                    .expect("artifact executed on incompatible shapes");
                Chunk::from_vec(rr, cc, data)
            }
            _ => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                crate::kernels::native::apply_binary(k, key, l, r)
            }
        }
    }

    fn name(&self) -> &'static str {
        "xla"
    }

    fn for_worker(&self) -> Box<dyn KernelBackend + Send + Sync> {
        // Each worker loads its own client + executables from the same
        // artifact directory (the per-node runtime of a real deployment),
        // keeping PJRT handle traffic thread-local even though the
        // `Sync` assertion above would tolerate sharing. The worker pool
        // calls this once per worker per run — a trainer loop's pool
        // caches the minted instances across every stage, evaluation,
        // and step it serves, so this reload cost is paid once, not per
        // evaluation. A reload failure is fatal, not a fallback: silently
        // mixing native and XLA workers would produce run-dependent
        // float bits, violating the for_worker contract the determinism
        // tests rely on.
        match XlaBackend::load(&self.dir) {
            Ok(w) => Box::new(w),
            Err(e) => panic!(
                "for_worker: reloading XLA artifacts from {} failed: {e:#}",
                self.dir
            ),
        }
    }
}

fn unary_native_only(k: &UnaryKernel) -> bool {
    matches!(
        k,
        UnaryKernel::Id
            | UnaryKernel::Scale(_)
            | UnaryKernel::AddConst(_)
            | UnaryKernel::Dropout { .. }
    )
}

fn binary_native_only(k: &BinaryKernel) -> bool {
    matches!(
        k,
        BinaryKernel::ScaleFst(_)
            | BinaryKernel::DDropout { .. }
            | BinaryKernel::Fst
            | BinaryKernel::Snd
            | BinaryKernel::NegFst
            | BinaryKernel::TransposeFst
            | BinaryKernel::OnesLike
            | BinaryKernel::NegOnesLike
    )
}

fn parse_shapes(s: &str) -> Result<Vec<(u32, u32)>> {
    s.split(',')
        .map(|p| {
            let (r, c) = p
                .split_once('x')
                .with_context(|| format!("bad shape {p}"))?;
            Ok((r.parse()?, c.parse()?))
        })
        .collect()
}

/// Map a manifest kernel name to the engine's static name, if known.
fn intern_kernel_name(name: &str) -> Option<&'static str> {
    const KNOWN: &[&str] = &[
        "add", "sub", "mul", "div", "matmul", "matmul_tn", "matmul_nt",
        "bce_loss", "squared_diff", "softmax_xent_rows", "row_broadcast_mul",
        "scalar_mul", "sum_mul",
        "neg", "logistic", "relu", "tanh", "exp", "log", "square", "sqrt",
        "sum_all", "row_sum", "softmax_rows", "transpose", "d_logistic",
        "d_relu", "d_tanh", "d_exp", "d_log", "d_square", "d_sqrt",
        "d_softmax_rows", "broadcast_fst", "broadcast_rows_fst", "d_div_l",
        "d_div_r", "d_bce_dyhat", "d_squared_diff_l", "d_softmax_xent_dl",
    ];
    KNOWN.iter().find(|&&k| k == name).copied()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_shapes_ok() {
        assert_eq!(parse_shapes("64x64,64x1").unwrap(), vec![(64, 64), (64, 1)]);
        assert!(parse_shapes("64y64").is_err());
    }

    #[test]
    fn intern_known_names() {
        assert_eq!(intern_kernel_name("matmul"), Some("matmul"));
        assert_eq!(intern_kernel_name("bogus"), None);
    }

    #[test]
    fn load_fails_without_manifest() {
        assert!(XlaBackend::load("/nonexistent").is_err());
    }
}
