//! Kernel artifact runtime: execute the AOT-compiled HLO artifacts
//! (JAX/Pallas → HLO text → PJRT) as chunk kernels from the L3 hot path.
//!
//! The real implementation (`pjrt.rs`) binds the `xla` crate's PJRT C API
//! and is compiled only under the **non-default `xla` cargo feature**, so
//! the default build is hermetic: no PJRT shared library, no `xla` crate,
//! no `make artifacts` — `NativeBackend` serves every kernel. The stub
//! keeps the same surface: `XlaBackend::load` reports the missing
//! feature, and its `KernelBackend` impl (unreachable through `load`)
//! falls back to the native kernels.
//!
//! Enabling `--features xla` additionally requires adding the `xla`
//! dependency to `Cargo.toml` (see the feature note there).

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::{XlaBackend, XlaRuntime};

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::{XlaBackend, XlaRuntime};
