//! Hermetic stand-in for the PJRT runtime when the crate is built
//! without the `xla` feature (the default). Loading always fails with a
//! clear message; kernel dispatch — unreachable through `load`, but kept
//! so callers holding an `XlaBackend` type-check — delegates to the
//! native implementations.

use crate::kernels::{BinaryKernel, KernelBackend, UnaryKernel};
use crate::ra::{Chunk, Key};
use anyhow::{bail, Result};

/// Placeholder for the PJRT client + compiled-artifact store.
pub struct XlaRuntime {
    _private: (),
}

impl XlaRuntime {
    pub fn load(_dir: &str) -> Result<XlaRuntime> {
        bail!(
            "built without the `xla` feature: the PJRT artifact runtime is \
             unavailable (rebuild with `--features xla` and the `xla` crate \
             in Cargo.toml; kernels run on the native backend)"
        )
    }

    pub fn n_executables(&self) -> usize {
        0
    }

    pub fn platform(&self) -> String {
        "unavailable (xla feature disabled)".to_string()
    }
}

/// Stub `KernelBackend`: constructible only through `load`, which fails.
pub struct XlaBackend {
    rt: XlaRuntime,
}

impl XlaBackend {
    pub fn load(dir: &str) -> Result<XlaBackend> {
        XlaRuntime::load(dir).map(|rt| XlaBackend { rt })
    }

    /// (artifact hits, native fallbacks) since load.
    pub fn stats(&self) -> (u64, u64) {
        (0, 0)
    }

    pub fn runtime(&self) -> &XlaRuntime {
        &self.rt
    }
}

impl KernelBackend for XlaBackend {
    fn unary(&self, k: &UnaryKernel, key: &Key, x: &Chunk) -> Chunk {
        crate::kernels::native::apply_unary(k, key, x)
    }

    fn binary(&self, k: &BinaryKernel, key: &Key, l: &Chunk, r: &Chunk) -> Chunk {
        crate::kernels::native::apply_binary(k, key, l, r)
    }

    fn name(&self) -> &'static str {
        "xla-stub"
    }

    fn for_worker(&self) -> Box<dyn KernelBackend + Send + Sync> {
        // Unreachable through `load` (which always fails without the
        // feature); the stub dispatches natively, so workers do too.
        Box::new(crate::kernels::NativeBackend)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_feature() {
        let err = XlaBackend::load("artifacts").err().expect("stub must not load");
        assert!(format!("{err}").contains("xla"));
    }
}
