//! A dependency-free JSON value, parser, and renderer for the HTTP
//! facade. Hand-rolled on purpose: the container policy forbids adding
//! crates, and the serving wire format only needs objects, arrays,
//! strings, finite numbers, booleans, and null.
//!
//! Float fidelity: chunk payloads are `f32`. Rendering widens to `f64`
//! (exact) and prints Rust's shortest round-trip `Display`; parsing
//! reads an `f64` and narrows back. Because the printed text identifies
//! the exact widened value, `f32 → text → f32` is bitwise lossless —
//! the loopback tests compare served relations with `bitwise_eq`.

use std::fmt::Write as _;

/// A parsed JSON value. Objects preserve key order (insertion order of
/// the source text) — handy for stable rendering in tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (None on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && *n >= i64::MIN as f64 && *n <= i64::MAX as f64 => {
                Some(*n as i64)
            }
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Parse one JSON document (rejects trailing garbage).
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing input at byte {pos}"));
        }
        Ok(value)
    }

    /// Render to compact JSON text. Non-finite numbers (which JSON
    /// cannot express) render as `null`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        render_into(self, &mut out);
        out
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, ch: u8) -> Result<(), String> {
    if *pos < b.len() && b[*pos] == ch {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected '{}' at byte {}", ch as char, *pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".to_string()),
        Some(b'{') => parse_obj(b, pos),
        Some(b'[') => parse_arr(b, pos),
        Some(b'"') => parse_string(b, pos).map(Json::Str),
        Some(b't') => parse_lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_lit(b, pos, "null", Json::Null),
        Some(_) => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, value: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        Err(format!("invalid literal at byte {}", *pos))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    if matches!(b.get(*pos), Some(b'-')) {
        *pos += 1;
    }
    while let Some(&c) = b.get(*pos) {
        if !(c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')) {
            break;
        }
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos]).expect("ascii digits");
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("invalid number {text:?} at byte {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => return Err("unterminated string".to_string()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                let esc = b.get(*pos).ok_or("unterminated escape")?;
                *pos += 1;
                match esc {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let hex = b
                            .get(*pos..*pos + 4)
                            .ok_or("truncated \\u escape")
                            .and_then(|h| std::str::from_utf8(h).map_err(|_| "bad \\u escape"))?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| format!("bad \\u escape {hex:?}"))?;
                        *pos += 4;
                        // BMP only; surrogates render as the replacement
                        // character (the facade never emits them).
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => return Err(format!("bad escape \\{}", *other as char)),
                }
            }
            Some(_) => {
                // Consume one UTF-8 scalar (multi-byte sequences pass
                // through unchanged).
                let rest = std::str::from_utf8(&b[*pos..])
                    .map_err(|_| "invalid UTF-8 in string".to_string())?;
                let ch = rest.chars().next().expect("non-empty");
                out.push(ch);
                *pos += ch.len_utf8();
            }
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    expect(b, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(b, pos);
    if matches!(b.get(*pos), Some(b']')) {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(format!("expected ',' or ']' at byte {}", *pos)),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    expect(b, pos, b'{')?;
    let mut fields = Vec::new();
    skip_ws(b, pos);
    if matches!(b.get(*pos), Some(b'}')) {
        *pos += 1;
        return Ok(Json::Obj(fields));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        expect(b, pos, b':')?;
        let value = parse_value(b, pos)?;
        fields.push((key, value));
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            _ => return Err(format!("expected ',' or '}}' at byte {}", *pos)),
        }
    }
}

fn render_into(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) if n.is_finite() => {
            let _ = write!(out, "{n}");
        }
        Json::Num(_) => out.push_str("null"),
        Json::Str(s) => render_string(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                render_into(item, out);
            }
            out.push(']');
        }
        Json::Obj(fields) => {
            out.push('{');
            for (i, (k, val)) in fields.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                render_string(k, out);
                out.push(':');
                render_into(val, out);
            }
            out.push('}');
        }
    }
}

fn render_string(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Shorthand for building object values.
pub(crate) fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_nested_documents() {
        let text = r#"{"a":[1,2.5,-3e2],"b":{"c":true,"d":null},"e":"x\"y\nA"}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("b").unwrap().get("c"), Some(&Json::Bool(true)));
        assert_eq!(v.get("e").unwrap().as_str(), Some("x\"y\nA"));
        let again = Json::parse(&v.render()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn f32_survives_text_round_trip_bitwise() {
        let probes = [
            0.0f32,
            -0.0,
            1.0,
            0.1,
            std::f32::consts::PI,
            f32::MIN_POSITIVE,
            1.0e-42,
            3.4e38,
            -7.274_882_6e-3,
        ];
        for x in probes {
            let text = Json::Num(x as f64).render();
            let back = Json::parse(&text).unwrap().as_f64().unwrap() as f32;
            assert_eq!(x.to_bits(), back.to_bits(), "{x} → {text} → {back}");
        }
    }

    #[test]
    fn rejects_malformed_input() {
        for bad in ["{", "[1,", "\"abc", "{\"a\" 1}", "12 34", "{]"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn integers_narrow_exactly() {
        let v = Json::parse("{\"n\": 12345678}").unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(12_345_678));
        assert_eq!(v.get("n").unwrap().as_i64(), Some(12_345_678));
        assert_eq!(Json::parse("-4").unwrap().as_i64(), Some(-4));
        assert_eq!(Json::parse("-4").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
    }
}
