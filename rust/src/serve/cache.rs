//! The epoch-aware plan/result cache.
//!
//! Both caches key on the statement's **SQL fixpoint form** — the
//! canonical round-trip text from
//! [`stmt_to_sql`](crate::sql::unparse::stmt_to_sql) — so syntactic
//! variants (case, whitespace, predicate order produced by the
//! normalizing parser) of the same query share entries.
//!
//! - The **plan cache** memoizes lowering (`SelectStmt` → [`Query`]).
//!   Entries record the catalog *generations* they were lowered under:
//!   dropping and re-registering a table mints a new generation (and can
//!   change its key columns), so a generation mismatch forces a
//!   re-lower instead of replaying a plan against a different schema.
//! - The **result cache** memoizes collected relations. Entries key on
//!   the fixpoint form *and* the exact `(table, generation, epoch)`
//!   bindings the result was computed from, as reported by
//!   [`Frame::bindings`](crate::session::Frame). Catalog mutations bump
//!   the epoch under the catalog lock *before* they return, so a lookup
//!   snapshot taken afterwards can never match a pre-mutation entry —
//!   stale results are unreachable by construction rather than by
//!   invalidation callbacks.
//!
//! Eviction is least-recently-stamped with a bounded entry count; the
//! plan cache shares the stamp clock but is unbounded (plans are tiny —
//! one expression tree per distinct statement shape).

use std::sync::{Arc, Mutex};

use crate::ra::expr::Query;
use crate::ra::Relation;
use crate::util::FxHashMap;

/// A lowered statement, reusable while the tables it references keep
/// their catalog identity (generation).
#[derive(Clone)]
pub(crate) struct CachedPlan {
    pub(crate) query: Query,
    /// Slot-ordered distinct table names the plan binds.
    pub(crate) names: Vec<String>,
    /// `(table, generation)` at lowering time; a mismatch means the
    /// table was re-registered (possibly with new key columns) and the
    /// plan must be lowered again.
    pub(crate) gens: Vec<(String, u64)>,
    /// Per-table partitioning signature at lowering time, hot-key
    /// annotation included ([`Session::table_part_sigs`]). Skew metadata
    /// is part of the plan-cache key: a plan lowered against one hot-key
    /// annotation never serves a catalog carrying another.
    ///
    /// [`Session::table_part_sigs`]: crate::session::Session
    pub(crate) part_sigs: Vec<Option<String>>,
}

/// Result-cache key: fixpoint SQL × the exact per-table
/// `(name, generation, epoch)` bindings the result was computed from.
type ResultKey = (String, Vec<(String, u64, u64)>);

struct CacheInner {
    /// Monotone access clock for least-recently-used eviction.
    stamp: u64,
    plans: FxHashMap<String, (CachedPlan, u64)>,
    results: FxHashMap<ResultKey, (Arc<Relation>, u64)>,
}

/// Shared plan/result cache. All methods are `&self` and internally
/// locked; clients on any thread hit the same entries.
pub(crate) struct QueryCache {
    /// Max result entries (plans are unbounded; see module docs).
    result_cap: usize,
    inner: Mutex<CacheInner>,
}

impl QueryCache {
    pub(crate) fn new(result_cap: usize) -> QueryCache {
        QueryCache {
            result_cap,
            inner: Mutex::new(CacheInner {
                stamp: 0,
                plans: FxHashMap::default(),
                results: FxHashMap::default(),
            }),
        }
    }

    /// The cached result for `fixpoint` computed at exactly `versions`,
    /// if any. Refreshes the entry's LRU stamp.
    pub(crate) fn lookup_result(
        &self,
        fixpoint: &str,
        versions: &[(String, u64, u64)],
    ) -> Option<Arc<Relation>> {
        let mut inner = self.inner.lock().unwrap();
        inner.stamp += 1;
        let stamp = inner.stamp;
        let key: ResultKey = (fixpoint.to_string(), versions.to_vec());
        let (rel, at) = inner.results.get_mut(&key)?;
        *at = stamp;
        Some(Arc::clone(rel))
    }

    /// Store a collected result under the bindings it was computed from.
    /// Evicts the least-recently-used entry past the capacity.
    pub(crate) fn insert_result(
        &self,
        fixpoint: &str,
        bound: Vec<(String, u64, u64)>,
        rel: Arc<Relation>,
    ) {
        if self.result_cap == 0 {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.stamp += 1;
        let stamp = inner.stamp;
        inner.results.insert((fixpoint.to_string(), bound), (rel, stamp));
        while inner.results.len() > self.result_cap {
            let oldest = inner
                .results
                .iter()
                .min_by_key(|(_, (_, at))| *at)
                .map(|(k, _)| k.clone())
                .expect("non-empty above cap");
            inner.results.remove(&oldest);
        }
    }

    /// The cached plan for `fixpoint`, provided every referenced table
    /// still has the generation *and* partitioning signature it was
    /// lowered under.
    pub(crate) fn lookup_plan(
        &self,
        fixpoint: &str,
        gens: &[(String, u64)],
        part_sigs: &[Option<String>],
    ) -> Option<CachedPlan> {
        let mut inner = self.inner.lock().unwrap();
        inner.stamp += 1;
        let stamp = inner.stamp;
        let (plan, at) = inner.plans.get_mut(fixpoint)?;
        if plan.gens != gens || plan.part_sigs != part_sigs {
            return None;
        }
        *at = stamp;
        Some(plan.clone())
    }

    /// Store (or replace) the plan for `fixpoint`.
    pub(crate) fn insert_plan(&self, fixpoint: &str, plan: CachedPlan) {
        let mut inner = self.inner.lock().unwrap();
        inner.stamp += 1;
        let stamp = inner.stamp;
        inner.plans.insert(fixpoint.to_string(), (plan, stamp));
    }

    /// Entry counts `(plans, results)` — introspection for `explain`.
    pub(crate) fn sizes(&self) -> (usize, usize) {
        let inner = self.inner.lock().unwrap();
        (inner.plans.len(), inner.results.len())
    }
}

// The cache crosses threads inside `Arc`: assert at compile time that
// every stored type is `Send + Sync` (satellite: thread-safety audit).
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<QueryCache>();
    assert_send_sync::<CachedPlan>();
    assert_send_sync::<Arc<Relation>>();
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ra::expr::QueryBuilder;
    use crate::ra::{Chunk, Key};

    fn tiny_plan() -> Query {
        let mut b = QueryBuilder::new();
        let s = b.scan(0, "t");
        b.finish(s)
    }

    fn rel(v: f32) -> Arc<Relation> {
        let mut r = Relation::new();
        r.insert(Key::k1(0), Chunk::filled(1, 1, v));
        Arc::new(r)
    }

    #[test]
    fn result_hits_only_exact_versions() {
        let c = QueryCache::new(8);
        let v0 = vec![("t".to_string(), 0, 0)];
        c.insert_result("SELECT …", v0.clone(), rel(1.0));
        assert!(c.lookup_result("SELECT …", &v0).is_some());
        // An epoch bump (insert/delete) misses; so does a generation
        // bump (drop + re-register) and a different statement.
        assert!(c.lookup_result("SELECT …", &[("t".to_string(), 0, 1)]).is_none());
        assert!(c.lookup_result("SELECT …", &[("t".to_string(), 1, 0)]).is_none());
        assert!(c.lookup_result("SELECT other", &v0).is_none());
    }

    #[test]
    fn results_evict_least_recently_used() {
        let c = QueryCache::new(2);
        let v = |n: u64| vec![("t".to_string(), 0, n)];
        c.insert_result("q", v(0), rel(0.0));
        c.insert_result("q", v(1), rel(1.0));
        // Touch entry 0 so entry 1 is the LRU victim.
        assert!(c.lookup_result("q", &v(0)).is_some());
        c.insert_result("q", v(2), rel(2.0));
        assert!(c.lookup_result("q", &v(0)).is_some());
        assert!(c.lookup_result("q", &v(1)).is_none());
        assert!(c.lookup_result("q", &v(2)).is_some());
        assert_eq!(c.sizes().1, 2);
    }

    #[test]
    fn plan_invalidates_on_generation_or_skew_change() {
        let c = QueryCache::new(8);
        let sig = || vec![Some("Hash([0])".to_string())];
        let plan = CachedPlan {
            query: tiny_plan(),
            names: vec!["t".to_string()],
            gens: vec![("t".to_string(), 3)],
            part_sigs: sig(),
        };
        c.insert_plan("q", plan);
        assert!(c.lookup_plan("q", &[("t".to_string(), 3)], &sig()).is_some());
        // Re-registration minted generation 4: the plan must re-lower.
        assert!(c.lookup_plan("q", &[("t".to_string(), 4)], &sig()).is_none());
        // Same generation, different skew annotation: also a miss.
        let skewed = vec![Some("SkewHash { comps: [0], hot: [(7)] }".to_string())];
        assert!(c.lookup_plan("q", &[("t".to_string(), 3)], &skewed).is_none());
    }

    #[test]
    fn zero_capacity_disables_result_caching() {
        let c = QueryCache::new(0);
        let v = vec![("t".to_string(), 0, 0)];
        c.insert_result("q", v.clone(), rel(1.0));
        assert!(c.lookup_result("q", &v).is_none());
    }
}
