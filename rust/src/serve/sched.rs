//! Admission control: a bounded, fair scheduler for in-flight BSP work.
//!
//! Every cache-missing query must hold a [`Permit`] while it executes.
//! Permits are bounded (`max_inflight`) so concurrent clients cannot
//! oversubscribe the shared [`WorkerPool`](crate::dist::WorkerPool) with
//! interleaved BSP rounds, and waiting is bounded two ways: a full queue
//! refuses immediately ([`ServeError::Saturated`]) and a queued ticket
//! that outlives the admission timeout fails typed
//! ([`ServeError::Timeout`]).
//!
//! Fairness is per-client round-robin: each client id has its own FIFO
//! of waiting tickets, and freed slots grant across client ids in
//! cyclic order — a client streaming hundreds of queries cannot starve
//! a client waiting on its first, because the fast path only bypasses
//! the queue when the queue is empty.
//!
//! The scheduler never loses a slot: grants move a ticket queue→granted
//! atomically under the one state lock, and a waiter that wakes past its
//! deadline still claims a grant that raced in ahead of the timeout
//! check.

use std::collections::{BTreeMap, VecDeque};
use std::ops::Bound;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::ServeError;
use crate::util::FxHashSet;

/// The bounded fair admission scheduler. See the [module docs](self).
pub(crate) struct Scheduler {
    max_inflight: usize,
    queue_cap: usize,
    timeout: Duration,
    state: Mutex<SchedState>,
    cv: Condvar,
    /// The most permits ever held concurrently — the probe the
    /// acceptance tests assert never exceeds `max_inflight`.
    max_inflight_seen: AtomicUsize,
}

#[derive(Default)]
struct SchedState {
    /// Permits currently held (or granted and not yet picked up).
    inflight: usize,
    /// Tickets waiting in `queues` (granted tickets are not queued).
    queued: usize,
    next_ticket: u64,
    /// Per-client FIFO of waiting tickets, keyed by client id. A ticket
    /// is in exactly one of `queues` or `granted`.
    queues: BTreeMap<u64, VecDeque<u64>>,
    /// Tickets that own an `inflight` slot but whose waiter has not yet
    /// woken to claim it.
    granted: FxHashSet<u64>,
    /// The client id most recently granted from the queue — the
    /// round-robin cursor (grants go to the next client id after it,
    /// wrapping).
    rr_last: u64,
}

/// An admission slot, held for the duration of one query's execution.
/// Dropping it frees the slot and grants the next queued ticket.
pub(crate) struct Permit {
    sched: Arc<Scheduler>,
    queued: bool,
}

impl Permit {
    /// Whether this permit waited in the queue (vs fast-path admission).
    pub(crate) fn was_queued(&self) -> bool {
        self.queued
    }
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.sched.release();
    }
}

impl Scheduler {
    pub(crate) fn new(max_inflight: usize, queue_cap: usize, timeout: Duration) -> Scheduler {
        assert!(max_inflight >= 1, "admission needs at least one slot");
        Scheduler {
            max_inflight,
            queue_cap,
            timeout,
            state: Mutex::new(SchedState::default()),
            cv: Condvar::new(),
            max_inflight_seen: AtomicUsize::new(0),
        }
    }

    /// Acquire one admission slot for `client`, blocking fairly when the
    /// engine is busy. Fails typed: [`ServeError::Saturated`] when the
    /// wait queue is full, [`ServeError::Timeout`] when the admission
    /// timeout elapses first.
    pub(crate) fn acquire(self: &Arc<Self>, client: u64) -> Result<Permit, ServeError> {
        let mut st = self.state.lock().unwrap();
        // Fast path only when nobody is waiting: overtaking the queue
        // would starve queued clients.
        if st.inflight < self.max_inflight && st.queued == 0 {
            st.inflight += 1;
            self.note_inflight(st.inflight);
            return Ok(Permit {
                sched: Arc::clone(self),
                queued: false,
            });
        }
        if st.queued >= self.queue_cap {
            return Err(ServeError::Saturated {
                queued: st.queued,
                queue_cap: self.queue_cap,
            });
        }
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        st.queues.entry(client).or_default().push_back(ticket);
        st.queued += 1;
        // A slot may be free even though the queue was non-empty a
        // moment ago (we just joined it); grant eagerly so the slot is
        // never idle while anyone waits.
        self.grant_next(&mut st);
        let deadline = Instant::now() + self.timeout;
        loop {
            if st.granted.remove(&ticket) {
                return Ok(Permit {
                    sched: Arc::clone(self),
                    queued: true,
                });
            }
            let now = Instant::now();
            if now >= deadline {
                // Under the lock a ticket is queued XOR granted; the
                // granted case returned above, so withdraw from the
                // queue and fail typed.
                let q = st.queues.get_mut(&client).expect("ticket must be queued");
                let pos = q
                    .iter()
                    .position(|&t| t == ticket)
                    .expect("ticket must be queued");
                q.remove(pos);
                if q.is_empty() {
                    st.queues.remove(&client);
                }
                st.queued -= 1;
                return Err(ServeError::Timeout {
                    waited_s: self.timeout.as_secs_f64(),
                });
            }
            st = self.cv.wait_timeout(st, deadline - now).unwrap().0;
        }
    }

    /// Free one slot and grant the next queued ticket(s), round-robin
    /// across client ids.
    fn release(&self) {
        let mut st = self.state.lock().unwrap();
        st.inflight -= 1;
        self.grant_next(&mut st);
    }

    /// Grant free slots to waiting tickets: pick the next client id
    /// strictly after the round-robin cursor (wrapping), pop its oldest
    /// ticket, move it queue→granted, and charge the slot. Wakes every
    /// waiter when anything was granted.
    fn grant_next(&self, st: &mut SchedState) {
        let mut granted_any = false;
        while st.inflight < self.max_inflight && st.queued > 0 {
            let next = st
                .queues
                .range((Bound::Excluded(st.rr_last), Bound::Unbounded))
                .next()
                .map(|(k, _)| *k)
                .or_else(|| st.queues.keys().next().copied());
            let Some(cid) = next else { break };
            let q = st.queues.get_mut(&cid).expect("client has a queue");
            let ticket = q.pop_front().expect("queue is non-empty");
            if q.is_empty() {
                st.queues.remove(&cid);
            }
            st.queued -= 1;
            st.inflight += 1;
            st.granted.insert(ticket);
            st.rr_last = cid;
            granted_any = true;
            self.note_inflight(st.inflight);
        }
        if granted_any {
            self.cv.notify_all();
        }
    }

    fn note_inflight(&self, now: usize) {
        self.max_inflight_seen.fetch_max(now, Ordering::SeqCst);
    }

    /// The most admission slots ever held concurrently.
    pub(crate) fn max_inflight_seen(&self) -> usize {
        self.max_inflight_seen.load(Ordering::SeqCst)
    }

    /// Tickets currently waiting (test introspection).
    #[cfg(test)]
    pub(crate) fn queued_now(&self) -> usize {
        self.state.lock().unwrap().queued
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(cap: usize, queue: usize, ms: u64) -> Arc<Scheduler> {
        Arc::new(Scheduler::new(cap, queue, Duration::from_millis(ms)))
    }

    #[test]
    fn fast_path_admits_to_cap_then_saturates() {
        let s = sched(2, 0, 1000);
        let p0 = s.acquire(1).unwrap();
        let p1 = s.acquire(2).unwrap();
        assert!(!p0.was_queued() && !p1.was_queued());
        // Queue capacity 0: the third caller is refused immediately.
        match s.acquire(3) {
            Err(ServeError::Saturated { queued, queue_cap }) => {
                assert_eq!((queued, queue_cap), (0, 0));
            }
            other => panic!("expected Saturated, got {other:?}"),
        }
        drop(p0);
        let p2 = s.acquire(3).unwrap();
        assert!(!p2.was_queued());
        assert_eq!(s.max_inflight_seen(), 2);
    }

    #[test]
    fn queued_ticket_times_out_typed() {
        let s = sched(1, 4, 40);
        let _held = s.acquire(1).unwrap();
        let t0 = Instant::now();
        match s.acquire(2) {
            Err(ServeError::Timeout { waited_s }) => {
                assert!((waited_s - 0.04).abs() < 1e-9);
            }
            other => panic!("expected Timeout, got {other:?}"),
        }
        assert!(t0.elapsed() >= Duration::from_millis(40));
        // The withdrawn ticket left no residue: the slot still grants.
        drop(_held);
        assert!(s.acquire(2).is_ok());
        assert_eq!(s.queued_now(), 0);
    }

    #[test]
    fn grants_round_robin_across_clients() {
        // One slot, held; enqueue A, A, B in that order; the grant
        // sequence must be A, B, A — the second A ticket cannot starve B.
        let s = sched(1, 8, 5000);
        let held = s.acquire(0).unwrap();
        let order = Arc::new(Mutex::new(Vec::<&'static str>::new()));
        let waiter = |client: u64, tag: &'static str| {
            let s = Arc::clone(&s);
            let order = Arc::clone(&order);
            std::thread::spawn(move || {
                let p = s.acquire(client).unwrap();
                assert!(p.was_queued());
                order.lock().unwrap().push(tag);
                // Hold briefly so grants serialize through the one slot.
                std::thread::sleep(Duration::from_millis(5));
            })
        };
        let mut handles = Vec::new();
        for (client, tag, want_queued) in [(1, "A", 1), (1, "A", 2), (2, "B", 3)] {
            handles.push(waiter(client, tag));
            // Serialize enqueue order deterministically.
            while s.queued_now() < want_queued {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        drop(held);
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*order.lock().unwrap(), vec!["A", "B", "A"]);
        assert_eq!(s.max_inflight_seen(), 1, "one slot must never overlap");
    }
}
