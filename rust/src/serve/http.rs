//! A dependency-free HTTP/JSON facade over the serving engine.
//!
//! Hand-rolled on `std::net` (the container policy forbids new crates):
//! a single accept thread, one short-lived handler thread per
//! connection, one request per connection (`Connection: close`). Each
//! connection gets its own freshly minted [`Client`], so admission
//! fairness treats every connection as a distinct client id.
//!
//! Endpoints (all bodies JSON):
//!
//! | Method × path    | Body                         | Response        |
//! |------------------|------------------------------|-----------------|
//! | `POST /register` | `{name, key_cols, rows}`     | `{ok}`          |
//! | `POST /sql`      | `{sql}`                      | summary         |
//! | `POST /collect`  | `{sql}`                      | summary + data  |
//! | `GET /tables`    | —                            | `{tables:[…]}`  |
//! | `GET /stats`     | —                            | counters        |
//!
//! `rows` (register) and `data` (collect) encode a relation as
//! `[{key:[i64…], rows, cols, data:[f32…]}]`. Numbers cross the wire
//! via the widen-to-`f64`, shortest-`Display` scheme in [`super::json`],
//! so a collect round-trip is `f32`-bitwise lossless.
//!
//! Error mapping: session errors → 400, [`ServeError::Saturated`] → 429,
//! [`ServeError::Timeout`] → 504, unknown routes → 404; every error body
//! is `{"error": "…"}`.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::json::{obj, Json};
use super::{CacheStatus, Client, Engine, QueryOutcome, ServeError};
use crate::ra::{Chunk, Key, Relation};

/// A running HTTP server. Dropping it (or calling
/// [`HttpServer::shutdown`]) stops the accept loop and joins it.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl HttpServer {
    /// The bound address (useful with a `:0` ephemeral-port bind).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept thread. In-flight connection
    /// handlers finish on their own threads.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a no-op connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        if self.join.is_some() {
            self.stop_and_join();
        }
    }
}

impl Engine {
    /// Serve this engine over HTTP on `addr` (e.g. `"127.0.0.1:0"` for
    /// an ephemeral port — read it back from [`HttpServer::addr`]).
    pub fn serve_http(&self, addr: &str) -> std::io::Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let engine = self.handle();
        let stop_flag = Arc::clone(&stop);
        let join = std::thread::Builder::new()
            .name("relad-serve-http".to_string())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop_flag.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let client = engine.client();
                    std::thread::spawn(move || {
                        let _ = handle_conn(stream, &client);
                    });
                }
            })?;
        Ok(HttpServer {
            addr: local,
            stop,
            join: Some(join),
        })
    }
}

fn handle_conn(stream: TcpStream, client: &Client) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let (method, path) = match (parts.next(), parts.next()) {
        (Some(m), Some(p)) => (m.to_string(), p.to_string()),
        _ => return respond(&stream, 400, &err_body("malformed request line")),
    };
    let mut content_len = 0usize;
    loop {
        let mut header = String::new();
        reader.read_line(&mut header)?;
        let header = header.trim();
        if header.is_empty() {
            break;
        }
        if let Some((k, v)) = header.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_len = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_len];
    reader.read_exact(&mut body)?;
    let body = String::from_utf8_lossy(&body);
    let (status, reply) = route(client, &method, &path, &body);
    respond(&stream, status, &reply)
}

fn route(client: &Client, method: &str, path: &str, body: &str) -> (u16, Json) {
    match (method, path) {
        ("POST", "/register") => with_json(body, |req| {
            let name = req.get("name").and_then(Json::as_str).ok_or("missing name")?;
            let key_cols: Vec<String> = req
                .get("key_cols")
                .and_then(Json::as_arr)
                .ok_or("missing key_cols")?
                .iter()
                .map(|c| c.as_str().map(str::to_string).ok_or("key_cols: non-string"))
                .collect::<Result<_, _>>()?;
            let rel = relation_from_json(req.get("rows").ok_or("missing rows")?)?;
            let cols: Vec<&str> = key_cols.iter().map(String::as_str).collect();
            Ok(serve_result(client.register(name, &cols, &rel).map(|()| {
                obj(vec![("ok", Json::Bool(true)), ("rows", num(rel.len() as f64))])
            })))
        }),
        ("POST", "/sql") => with_json(body, |req| {
            let sql = req.get("sql").and_then(Json::as_str).ok_or("missing sql")?;
            Ok(serve_result(client.query(sql).map(|out| outcome_summary(&out))))
        }),
        ("POST", "/collect") => with_json(body, |req| {
            let sql = req.get("sql").and_then(Json::as_str).ok_or("missing sql")?;
            Ok(serve_result(client.query(sql).map(|out| {
                let Json::Obj(mut fields) = outcome_summary(&out) else {
                    unreachable!("summary is an object")
                };
                fields.push(("data".to_string(), relation_to_json(&out.result)));
                Json::Obj(fields)
            })))
        }),
        ("GET", "/tables") => {
            let tables = client
                .tables()
                .into_iter()
                .map(|t| {
                    obj(vec![
                        ("name", Json::Str(t.name)),
                        (
                            "key_cols",
                            Json::Arr(t.key_cols.into_iter().map(Json::Str).collect()),
                        ),
                        ("arity", num(t.arity as f64)),
                        ("rows", num(t.rows as f64)),
                        ("nbytes", num(t.nbytes as f64)),
                        ("epoch", num(t.epoch as f64)),
                        ("partitioning", Json::Str(t.partitioning)),
                    ])
                })
                .collect();
            (200, obj(vec![("tables", Json::Arr(tables))]))
        }
        ("GET", "/stats") => (200, stats_json(client)),
        _ => (404, err_body(&format!("no route {method} {path}"))),
    }
}

fn stats_json(client: &Client) -> Json {
    // Stats live on the shared counters; any client sees the engine's.
    let s = client.engine_stats();
    obj(vec![
        ("cache_hits", num(s.cache_hits as f64)),
        ("cache_misses", num(s.cache_misses as f64)),
        ("plan_hits", num(s.plan_hits as f64)),
        ("queries_admitted", num(s.queries_admitted as f64)),
        ("queries_queued", num(s.queries_queued as f64)),
        ("queue_wait_s", num(s.queue_wait_s)),
        ("max_inflight_seen", num(s.max_inflight_seen as f64)),
        (
            "pool_rounds_high_water",
            num(s.pool_rounds_high_water as f64),
        ),
        ("plan_entries", num(s.plan_entries as f64)),
        ("result_entries", num(s.result_entries as f64)),
    ])
}

fn outcome_summary(out: &QueryOutcome) -> Json {
    obj(vec![
        ("rows", num(out.result.len() as f64)),
        (
            "cache",
            Json::Str(
                match out.cache {
                    CacheStatus::Hit => "hit",
                    CacheStatus::Miss => "miss",
                }
                .to_string(),
            ),
        ),
        ("queue_wait_s", num(out.queue_wait_s)),
    ])
}

/// `[{key, rows, cols, data}]` → [`Relation`].
fn relation_from_json(rows: &Json) -> Result<Relation, &'static str> {
    let items = rows.as_arr().ok_or("rows: expected array")?;
    let mut rel = Relation::with_capacity(items.len());
    for item in items {
        let key: Vec<i64> = item
            .get("key")
            .and_then(Json::as_arr)
            .ok_or("row: missing key")?
            .iter()
            .map(|k| k.as_i64().ok_or("key: non-integer"))
            .collect::<Result<_, _>>()?;
        let r = item.get("rows").and_then(Json::as_u64).ok_or("row: missing rows")? as usize;
        let c = item.get("cols").and_then(Json::as_u64).ok_or("row: missing cols")? as usize;
        let data: Vec<f32> = item
            .get("data")
            .and_then(Json::as_arr)
            .ok_or("row: missing data")?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32).ok_or("data: non-number"))
            .collect::<Result<_, _>>()?;
        if key.len() > crate::ra::key::MAX_KEY {
            return Err("key too wide");
        }
        if data.len() != r * c {
            return Err("data length != rows*cols");
        }
        rel.insert(Key::new(&key), Chunk::from_vec(r, c, data));
    }
    Ok(rel)
}

/// [`Relation`] → `[{key, rows, cols, data}]` (deterministic key order).
fn relation_to_json(rel: &Relation) -> Json {
    let mut pairs: Vec<&(Key, Chunk)> = rel.iter().collect();
    pairs.sort_by(|a, b| a.0.as_slice().cmp(b.0.as_slice()));
    Json::Arr(
        pairs
            .into_iter()
            .map(|(k, v)| {
                obj(vec![
                    (
                        "key",
                        Json::Arr(k.as_slice().iter().map(|&x| num(x as f64)).collect()),
                    ),
                    ("rows", num(v.rows() as f64)),
                    ("cols", num(v.cols() as f64)),
                    (
                        "data",
                        Json::Arr(v.data().iter().map(|&x| num(x as f64)).collect()),
                    ),
                ])
            })
            .collect(),
    )
}

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn err_body(msg: &str) -> Json {
    obj(vec![("error", Json::Str(msg.to_string()))])
}

/// Parse the request body, run the handler, map malformed input to 400.
fn with_json(
    body: &str,
    f: impl FnOnce(&Json) -> Result<(u16, Json), String>,
) -> (u16, Json) {
    match Json::parse(body) {
        Ok(req) => match f(&req) {
            Ok(reply) => reply,
            Err(e) => (400, err_body(&e)),
        },
        Err(e) => (400, err_body(&format!("bad JSON body: {e}"))),
    }
}

/// Map a serving result onto an HTTP status + body.
fn serve_result(res: Result<Json, ServeError>) -> (u16, Json) {
    match res {
        Ok(body) => (200, body),
        Err(e) => {
            let status = match &e {
                ServeError::Saturated { .. } => 429,
                ServeError::Timeout { .. } => 504,
                ServeError::Session(_) => 400,
            };
            (status, err_body(&e.to_string()))
        }
    }
}

fn respond(mut stream: &TcpStream, status: u16, body: &Json) -> std::io::Result<()> {
    let text = body.render();
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        504 => "Gateway Timeout",
        _ => "Error",
    };
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{text}",
        text.len()
    )?;
    stream.flush()
}
