//! Serving layer: a concurrent multi-session engine over one shared
//! worker pool.
//!
//! An [`Engine`] owns exactly one [`Session`] — and through it the one
//! persistent [`WorkerPool`](crate::dist::WorkerPool) and table catalog —
//! and mints cheap, thread-safe [`Client`] handles. Any number of
//! clients on any threads issue SQL concurrently against the shared
//! catalog; the engine keeps them honest with two mechanisms:
//!
//! - **Admission control** (`sched.rs`): every cache-missing query
//!   holds one of `max_inflight` permits while it executes, so
//!   concurrent clients cannot oversubscribe the pool with interleaved
//!   BSP rounds. Waiters queue per-client and are granted round-robin;
//!   a full queue fails fast with [`ServeError::Saturated`] and a stuck
//!   queue with [`ServeError::Timeout`].
//! - **An epoch-aware plan/result cache** (`cache.rs`): entries key on
//!   the statement's canonical SQL fixpoint form × the exact
//!   `(table, generation, epoch)` bindings it was computed from, so a
//!   repeated query is served from memory — and any `insert`/`delete`/
//!   re-registration makes the old entries unreachable rather than
//!   stale.
//!
//! Results are [`Arc<Relation>`] snapshots: relations are immutable once
//! collected (catalog mutations build new partitions), so shared
//! ownership is safe and a cache hit costs one atomic increment.
//!
//! A dependency-free HTTP/JSON facade ([`http`]) exposes the same
//! surface over a socket; see [`Engine::serve_http`].
//!
//! ```no_run
//! use relad::dist::ClusterConfig;
//! use relad::serve::Engine;
//!
//! let engine = Engine::new(ClusterConfig::new(2));
//! let client = engine.client(); // Send: move it into any thread
//! // … client.register("A", &["row", "col"], &rel) …
//! let out = client.query("SELECT A.row, relu(A.val) FROM A").unwrap();
//! println!("{} rows ({:?})", out.result.len(), out.cache);
//! ```

pub(crate) mod cache;
pub mod http;
pub mod json;
pub(crate) mod sched;

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::dist::ClusterConfig;
use crate::ml::SlotLayout;
use crate::ra::{Chunk, Key, Relation};
use crate::session::{Session, SessionError, TableInfo};
use crate::sql;

use cache::{CachedPlan, QueryCache};
use sched::Scheduler;

pub use http::HttpServer;
pub use json::Json;

/// Serving-layer knobs. `Default` is sized for a small shared engine:
/// 4 in-flight queries, a 64-deep wait queue, 5 s admission timeout,
/// 128 cached results.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Max queries executing (holding BSP rounds) at once.
    pub max_inflight: usize,
    /// Max queries waiting for admission before `Saturated`.
    pub queue_cap: usize,
    /// How long a queued query waits before `Timeout`.
    pub admission_timeout: Duration,
    /// Result-cache capacity in entries (0 disables result caching).
    pub result_cache_entries: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            max_inflight: 4,
            queue_cap: 64,
            admission_timeout: Duration::from_secs(5),
            result_cache_entries: 128,
        }
    }
}

/// Typed serving failures. Session-level errors (unknown table, SQL
/// syntax, …) pass through as [`ServeError::Session`].
#[derive(Debug)]
pub enum ServeError {
    /// The admission queue is full; the query was refused immediately.
    Saturated { queued: usize, queue_cap: usize },
    /// The query waited `waited_s` for admission and gave up.
    Timeout { waited_s: f64 },
    /// The underlying session rejected the request.
    Session(SessionError),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Saturated { queued, queue_cap } => write!(
                f,
                "engine saturated: {queued} queries queued (capacity {queue_cap})"
            ),
            ServeError::Timeout { waited_s } => {
                write!(f, "admission timed out after {waited_s:.3}s")
            }
            ServeError::Session(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for ServeError {}

impl From<SessionError> for ServeError {
    fn from(e: SessionError) -> ServeError {
        ServeError::Session(e)
    }
}

/// Whether a query was answered from the result cache.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheStatus {
    Hit,
    Miss,
}

/// One served query: the collected relation (shared snapshot), how it
/// was answered, and how long it waited for admission.
#[derive(Clone)]
pub struct QueryOutcome {
    pub result: Arc<Relation>,
    pub cache: CacheStatus,
    pub queue_wait_s: f64,
}

/// Cumulative serving counters (monotone since engine construction).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ServeStats {
    /// Queries answered from the result cache (no admission needed).
    pub cache_hits: u64,
    /// Queries that executed (admitted through the scheduler).
    pub cache_misses: u64,
    /// Cache-missing queries that reused a cached lowered plan.
    pub plan_hits: u64,
    /// Admissions granted (= `cache_misses` that did not fail typed).
    pub queries_admitted: u64,
    /// Admissions that waited in the queue (vs fast path).
    pub queries_queued: u64,
    /// Total seconds spent waiting for admission.
    pub queue_wait_s: f64,
    /// Most admission slots ever held at once (≤ `max_inflight`).
    pub max_inflight_seen: usize,
    /// Pool probe: most BSP rounds ever in flight at once.
    pub pool_rounds_high_water: usize,
    /// Current plan-cache entries.
    pub plan_entries: usize,
    /// Current result-cache entries.
    pub result_entries: usize,
}

fn stats_snapshot(
    sess: &Session,
    sched: &Scheduler,
    cache: &QueryCache,
    counters: &ServeCounters,
) -> ServeStats {
    let (plan_entries, result_entries) = cache.sizes();
    ServeStats {
        cache_hits: counters.cache_hits.load(Ordering::Relaxed),
        cache_misses: counters.cache_misses.load(Ordering::Relaxed),
        plan_hits: counters.plan_hits.load(Ordering::Relaxed),
        queries_admitted: counters.queries_admitted.load(Ordering::Relaxed),
        queries_queued: counters.queries_queued.load(Ordering::Relaxed),
        queue_wait_s: counters.queue_wait_us.load(Ordering::Relaxed) as f64 / 1e6,
        max_inflight_seen: sched.max_inflight_seen(),
        pool_rounds_high_water: sess.pool().map_or(0, |p| p.rounds_high_water()),
        plan_entries,
        result_entries,
    }
}

#[derive(Default)]
struct ServeCounters {
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    plan_hits: AtomicU64,
    queries_admitted: AtomicU64,
    queries_queued: AtomicU64,
    queue_wait_us: AtomicU64,
}

/// The shared serving engine. See the [module docs](self).
///
/// `Engine` (like [`Client`]) is `Send + Sync`; the handles it mints
/// share one session, scheduler, and cache through `Arc`s.
pub struct Engine {
    sess: Session,
    cfg: ServeConfig,
    sched: Arc<Scheduler>,
    cache: Arc<QueryCache>,
    counters: Arc<ServeCounters>,
    next_client: Arc<AtomicU64>,
}

impl Engine {
    /// An engine over a fresh native-backend [`Session`] with default
    /// serving knobs.
    pub fn new(cluster: ClusterConfig) -> Engine {
        Engine::with_config(cluster, ServeConfig::default())
    }

    /// An engine over a fresh native-backend [`Session`] with explicit
    /// serving knobs.
    pub fn with_config(cluster: ClusterConfig, cfg: ServeConfig) -> Engine {
        Engine::from_session(Session::new(cluster), cfg)
    }

    /// Wrap an existing session (any backend, possibly pre-populated).
    /// The engine takes ownership; reach it back via [`Engine::session`].
    pub fn from_session(sess: Session, cfg: ServeConfig) -> Engine {
        let sched = Arc::new(Scheduler::new(
            cfg.max_inflight,
            cfg.queue_cap,
            cfg.admission_timeout,
        ));
        let cache = Arc::new(QueryCache::new(cfg.result_cache_entries));
        Engine {
            sess,
            cfg,
            sched,
            cache,
            counters: Arc::new(ServeCounters::default()),
            next_client: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Mint a client handle. Cheap (one `Arc` clone per shared part);
    /// the handle is `Send` — move it into any thread.
    pub fn client(&self) -> Client {
        Client {
            id: self.next_client.fetch_add(1, Ordering::Relaxed),
            sess: self.sess.share(),
            sched: Arc::clone(&self.sched),
            cache: Arc::clone(&self.cache),
            counters: Arc::clone(&self.counters),
        }
    }

    /// The underlying session — for trainers, direct frames, or stats
    /// beyond the serving counters. Catalog mutations through it are
    /// seen by every client (and invalidate cached results, exactly as
    /// client-side mutations do).
    pub fn session(&self) -> &Session {
        &self.sess
    }

    /// Snapshot of the serving counters and probes.
    pub fn stats(&self) -> ServeStats {
        stats_snapshot(&self.sess, &self.sched, &self.cache, &self.counters)
    }

    /// Explain-style introspection: a human-readable dump of the
    /// serving configuration, pool shape, and counters.
    pub fn explain(&self) -> String {
        let s = self.stats();
        let pool = match self.sess.pool() {
            Some(p) => format!("{} workers", p.workers()),
            None => "serial (no pool)".to_string(),
        };
        format!(
            "serve engine: backend={} pool={pool}\n\
             admission: max_inflight={} queue_cap={} timeout={:.1}s\n\
             cache: {} plans, {}/{} results\n\
             served: {} hits, {} misses ({} plan reuses)\n\
             admitted: {} ({} queued, {:.3}s total wait)\n\
             probes: max_inflight_seen={} pool_rounds_high_water={}",
            self.sess.backend_name(),
            self.cfg.max_inflight,
            self.cfg.queue_cap,
            self.cfg.admission_timeout.as_secs_f64(),
            s.plan_entries,
            s.result_entries,
            self.cfg.result_cache_entries,
            s.cache_hits,
            s.cache_misses,
            s.plan_hits,
            s.queries_admitted,
            s.queries_queued,
            s.queue_wait_s,
            s.max_inflight_seen,
            s.pool_rounds_high_water,
        )
    }

    /// Shallow handle sharing every part of this engine — the HTTP
    /// accept loop moves one into its thread.
    pub(crate) fn handle(&self) -> Engine {
        Engine {
            sess: self.sess.share(),
            cfg: self.cfg.clone(),
            sched: Arc::clone(&self.sched),
            cache: Arc::clone(&self.cache),
            counters: Arc::clone(&self.counters),
            next_client: Arc::clone(&self.next_client),
        }
    }
}

/// A thread-safe handle onto a shared [`Engine`]. Mint with
/// [`Engine::client`]; move freely across threads. All methods take
/// `&self`.
pub struct Client {
    id: u64,
    sess: Session,
    sched: Arc<Scheduler>,
    cache: Arc<QueryCache>,
    counters: Arc<ServeCounters>,
}

impl Client {
    /// This handle's id (admission fairness is round-robin across ids).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Serve one SQL statement: result-cache lookup first, then bounded
    /// admission, plan reuse, execution on the shared pool, and cache
    /// fill. Identical answers, bitwise, to running the statement on a
    /// fresh serial session over the same catalog.
    pub fn query(&self, statement: &str) -> Result<QueryOutcome, ServeError> {
        let stmt = sql::parse::parse(statement)
            .map_err(|e| ServeError::Session(SessionError::Sql(e)))?;
        let fixpoint = sql::unparse::stmt_to_sql(&stmt);
        // Slot-ordered distinct table names (same order lowering uses).
        let mut names: Vec<String> = Vec::new();
        for t in &stmt.tables {
            if !names.contains(t) {
                names.push(t.clone());
            }
        }
        // Atomic snapshot of the referenced tables' identity + epoch.
        // Catalog mutations bump these under the catalog lock before
        // returning, so a stale entry can never match this snapshot.
        let mut versions: Vec<(String, u64, u64)> = Vec::with_capacity(names.len());
        for (name, v) in names.iter().zip(self.sess.table_versions(&names)) {
            match v {
                Some((gen, epoch)) => versions.push((name.clone(), gen, epoch)),
                None => return Err(SessionError::UnknownTable(name.clone()).into()),
            }
        }
        if let Some(result) = self.cache.lookup_result(&fixpoint, &versions) {
            self.counters.cache_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(QueryOutcome {
                result,
                cache: CacheStatus::Hit,
                queue_wait_s: 0.0,
            });
        }
        self.counters.cache_misses.fetch_add(1, Ordering::Relaxed);

        // Admission: hold one permit for the whole execution.
        let t0 = Instant::now();
        let permit = self.sched.acquire(self.id)?;
        let queue_wait_s = t0.elapsed().as_secs_f64();
        self.counters.queries_admitted.fetch_add(1, Ordering::Relaxed);
        if permit.was_queued() {
            self.counters.queries_queued.fetch_add(1, Ordering::Relaxed);
        }
        self.counters
            .queue_wait_us
            .fetch_add((queue_wait_s * 1e6) as u64, Ordering::Relaxed);

        // Plan: reuse the lowered query unless a referenced table was
        // re-registered (generation change ⇒ schema may differ).
        let gens: Vec<(String, u64)> = versions.iter().map(|(n, g, _)| (n.clone(), *g)).collect();
        // Partitioning signatures (hot-key annotations included) join the
        // plan key: a plan costed under one skew annotation never serves
        // a catalog carrying another.
        let part_sigs = self.sess.table_part_sigs(&names);
        let plan = match self.cache.lookup_plan(&fixpoint, &gens, &part_sigs) {
            Some(plan) => {
                self.counters.plan_hits.fetch_add(1, Ordering::Relaxed);
                plan
            }
            None => {
                let (query, lowered_names) = self.sess.lower_stmt(&stmt)?;
                debug_assert_eq!(lowered_names, names);
                let plan = CachedPlan {
                    query,
                    names: lowered_names,
                    gens,
                    part_sigs,
                };
                self.cache.insert_plan(&fixpoint, plan.clone());
                plan
            }
        };

        // Execute on the shared session; the frame re-binds against the
        // live catalog, so `bindings()` afterwards reports exactly the
        // versions the result was computed from — the cache key.
        let frame = self.sess.bind_named(plan.query.clone(), &plan.names)?;
        let result = Arc::new(frame.collect()?);
        let bound = frame.bindings();
        drop(permit);
        self.cache.insert_result(&fixpoint, bound, Arc::clone(&result));
        Ok(QueryOutcome {
            result,
            cache: CacheStatus::Miss,
            queue_wait_s,
        })
    }

    /// [`Client::query`], returning just the relation.
    pub fn collect(&self, statement: &str) -> Result<Arc<Relation>, ServeError> {
        self.query(statement).map(|out| out.result)
    }

    /// Register a table in the shared catalog (visible to all clients).
    pub fn register(
        &self,
        name: &str,
        key_cols: &[&str],
        rel: &Relation,
    ) -> Result<(), ServeError> {
        Ok(self.sess.register(name, key_cols, rel)?)
    }

    /// [`Client::register`] with an explicit slot layout.
    pub fn register_with_layout(
        &self,
        name: &str,
        key_cols: &[&str],
        rel: &Relation,
        layout: &SlotLayout,
    ) -> Result<(), ServeError> {
        Ok(self.sess.register_with_layout(name, key_cols, rel, layout)?)
    }

    /// Apply an insert batch. Bumps the table's epoch, making every
    /// cached result that read it unreachable.
    pub fn insert(&self, name: &str, rows: Vec<(Key, Chunk)>) -> Result<(), ServeError> {
        Ok(self.sess.insert(name, rows)?)
    }

    /// Apply a delete batch (same invalidation semantics as `insert`).
    pub fn delete(&self, name: &str, keys: &[Key]) -> Result<(), ServeError> {
        Ok(self.sess.delete(name, keys)?)
    }

    /// Drop a table from the shared catalog.
    pub fn drop_table(&self, name: &str) -> Result<(), ServeError> {
        Ok(self.sess.drop_table(name)?)
    }

    /// The shared catalog's table listing.
    pub fn tables(&self) -> Vec<TableInfo> {
        self.sess.tables()
    }

    /// The engine-wide serving stats (counters are shared, so any
    /// client handle sees the same snapshot as [`Engine::stats`]).
    pub fn engine_stats(&self) -> ServeStats {
        stats_snapshot(&self.sess, &self.sched, &self.cache, &self.counters)
    }
}

// Compile-time thread-safety audit (satellite): the serving types must
// be `Send + Sync` — the whole design hands them across threads.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Engine>();
    assert_send_sync::<Client>();
    assert_send_sync::<ServeConfig>();
    assert_send_sync::<ServeError>();
    assert_send_sync::<ServeStats>();
    assert_send_sync::<QueryOutcome>();
};
