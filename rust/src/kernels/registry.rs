//! Backend selection: native Rust kernels vs AOT-compiled XLA artifacts.
//!
//! The `xla` backend (see `runtime/`) executes the HLO artifacts produced
//! by the JAX/Pallas build path for every kernel/shape pair listed in
//! `artifacts/manifest.tsv`, falling back to the native implementation for
//! kernels that are key-dependent (dropout) or shapes outside the
//! artifact set. `Backend::parse` backs the `--backend` CLI flag.

use super::{KernelBackend, NativeBackend};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Native,
    Xla,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "native" => Some(BackendKind::Native),
            "xla" => Some(BackendKind::Xla),
            _ => None,
        }
    }
}

/// Construct a backend. For `Xla`, artifacts are loaded from `dir`
/// (default `artifacts/`); kernels missing from the manifest fall back to
/// native execution. The PJRT runtime itself is compiled only under the
/// non-default `xla` cargo feature — without it, `XlaBackend` is the
/// hermetic stub (`runtime/stub.rs`) whose `load` fails with a message
/// explaining the missing feature, and callers stay on `NativeBackend`.
pub fn make_backend(
    kind: BackendKind,
    artifact_dir: &str,
) -> anyhow::Result<Box<dyn KernelBackend + Send + Sync>> {
    match kind {
        BackendKind::Native => Ok(Box::new(NativeBackend)),
        BackendKind::Xla => Ok(Box::new(crate::runtime::XlaBackend::load(artifact_dir)?)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_backend() {
        assert_eq!(BackendKind::parse("native"), Some(BackendKind::Native));
        assert_eq!(BackendKind::parse("xla"), Some(BackendKind::Xla));
        assert_eq!(BackendKind::parse("gpu"), None);
    }
}
