//! Kernel functions: the `⊙`/`⊗`/`⊕` functions attached to RA operators.
//!
//! The paper's scalar semantics extend to chunks (Appendix A) by letting
//! kernel functions operate on tensors; differentiating the RA then only
//! additionally requires *derivative kernels* for each kernel function —
//! which the paper delegates to a conventional tensor autodiff (JAX).
//! Here every kernel is a named enum variant with:
//!   * a native Rust implementation (`native.rs`),
//!   * an AOT-compiled XLA artifact produced by the JAX/Pallas build path
//!     (`python/compile/`, loaded by `runtime/`),
//!   * a `VjpSpec` describing how a relation-Jacobian product chains
//!     through it (Section 4).
//!
//! Dispatch goes through a `KernelBackend` so the engine can run on the
//! native implementations (baselines, tests) or the XLA artifacts (the
//! three-layer production path), and so the two can be cross-checked.

pub mod native;
pub mod registry;

use crate::ra::{Chunk, Key};

/// Unary value kernels (`⊙` of Selection).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum UnaryKernel {
    Id,
    Neg,
    /// `x * c`
    Scale(f32),
    /// `x + c`
    AddConst(f32),
    Logistic,
    Relu,
    Tanh,
    Exp,
    Log,
    Square,
    Sqrt,
    /// Sum every element down to a 1×1 chunk (turns a per-chunk loss into
    /// a scalar tuple so a constant-`grp` Σ can finish the reduction).
    SumAll,
    /// Row-wise sum: (r, c) → (r, 1).
    RowSum,
    /// Row-wise softmax.
    SoftmaxRows,
    /// Matrix transpose of the chunk.
    Transpose,
    /// Inverted dropout with a mask derived deterministically from
    /// (seed, tuple key, element index); native-backend only.
    Dropout { seed: u64, rate: f32 },
}

/// Binary value kernels (`⊗` of Join) — forward kernels, partial-derivative
/// kernels and chain (vjp) kernels live in one namespace: they are all just
/// binary chunk functions, and backward queries use them like any other.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BinaryKernel {
    // ---- forward ----
    Add,
    Sub,
    Mul,
    Div,
    /// `l · r`
    MatMul,
    /// `lᵀ · r`
    MatMulTN,
    /// `l · rᵀ`
    MatMulNT,
    /// Binary cross-entropy per element: `-r·ln(l) + (r-1)·ln(1-l)`
    /// (the paper's `⊗Loss(yhat, y)`).
    BceLoss,
    /// `(l - r)²` elementwise.
    SquaredDiff,
    /// Row-wise softmax cross entropy: logits (r,c) × one-hot (r,c) → (r,1).
    SoftmaxXentRows,
    /// Row-broadcast multiply: (r,1) × (r,c) → (r,c).
    RowBroadcastMul,
    /// Scalar-broadcast multiply: (1,1) × (r,c) → (r,c) — edge-weight ×
    /// embedding in per-node GCN message passing.
    ScalarMul,
    /// `(g, x) ↦ Σ(g∘x)` as 1×1 — the scalar-side vjp of `ScalarMul`.
    SumMul,

    // ---- vjp / chain kernels (first operand is the upstream gradient
    //      unless stated otherwise) ----
    /// `(g, _) ↦ g`
    Fst,
    /// `(_, x) ↦ x`
    Snd,
    /// `(g, _) ↦ -g`
    NegFst,
    /// `(g, _) ↦ c·g`
    ScaleFst(f32),
    /// `(g, x) ↦ g` broadcast from 1×1 to the shape of `x` (Σ-to-scalar /
    /// SumAll backward).
    BroadcastFst,
    /// `(g, x) ↦ g` broadcast from (r,1) across the columns of `x`.
    BroadcastRowsFst,
    /// `(g, _) ↦ gᵀ` (Transpose backward).
    TransposeFst,
    /// `(l, r) ↦ 1` shaped like `l` (∂(l+r)/∂l).
    OnesLike,
    /// `(l, r) ↦ -1` shaped like `l`.
    NegOnesLike,
    /// `(g, x) ↦ g · σ(x)(1-σ(x))`
    DLogistic,
    /// `(g, x) ↦ g · [x > 0]`
    DRelu,
    /// `(g, x) ↦ g · (1 - tanh²x)`
    DTanh,
    /// `(g, x) ↦ g · eˣ`
    DExp,
    /// `(g, x) ↦ g / x`
    DLog,
    /// `(g, x) ↦ 2xg`
    DSquare,
    /// `(g, x) ↦ g / (2√x)`
    DSqrt,
    /// `(g, x) ↦ g ∘ mask(seed, key)` — Dropout backward.
    DDropout { seed: u64, rate: f32 },
    /// `(g, x) ↦ softmax-rows vjp`: y∘(g - rowsum(g∘y)), y = softmax(x).
    DSoftmaxRows,
    /// `(l, r) ↦ ∂Div/∂l = 1/r` shaped like l.
    DDivL,
    /// `(l, r) ↦ ∂Div/∂r = -l/r²`.
    DDivR,
    /// `(l, r) ↦ ∂BceLoss/∂l = (l - r) / (l(1-l))`.
    DBceDyhat,
    /// `(l, r) ↦ ∂SquaredDiff/∂l = 2(l-r)`.
    DSquaredDiffL,
    /// `(l, r) ↦ ∂SoftmaxXentRows/∂l = softmax(l) - r` (r one-hot).
    DSoftmaxXentDl,
}

/// Aggregation kernels (`⊕` of Σ): commutative & associative.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggKernel {
    Sum,
    Max,
}

/// How the relation-Jacobian product chains through a binary kernel with
/// respect to one of its operands (Section 4, "RJP for Join").
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum VjpSpec {
    /// `grad = k(g, other)` — direct chain against the *other* operand
    /// (the paper's "⋈const can be optimized out" case: ⊗ ∈ {×, MatMul}).
    ChainOther(BinaryKernel),
    /// `grad = k(other, g)` — same, operand order swapped (e.g. the
    /// right-vjp of MatMul is `lᵀ·g = MatMulTN(l, g)`).
    ChainOtherRev(BinaryKernel),
    /// `grad = chain(g, partial(l, r))` — the general construction: an
    /// inner join computes the partial from both operands, an outer join
    /// against the upstream gradient applies the elementwise chain.
    Partial {
        partial: BinaryKernel,
        chain: BinaryKernel,
    },
    /// `grad = u(g)` — the kernel's partial is identically 1 (or -1, or a
    /// constant): the whole RJP join collapses to a selection over `g`.
    OfG(UnaryKernel),
    /// Gradient is not defined / not supported for this operand.
    None,
}

impl UnaryKernel {
    /// The binary chain kernel `k(g, x)` computing this kernel's vjp.
    pub fn vjp_kernel(&self) -> Option<BinaryKernel> {
        use BinaryKernel as B;
        use UnaryKernel as U;
        Some(match *self {
            U::Id => B::Fst,
            U::Neg => B::NegFst,
            U::Scale(c) => B::ScaleFst(c),
            U::AddConst(_) => B::Fst,
            U::Logistic => B::DLogistic,
            U::Relu => B::DRelu,
            U::Tanh => B::DTanh,
            U::Exp => B::DExp,
            U::Log => B::DLog,
            U::Square => B::DSquare,
            U::Sqrt => B::DSqrt,
            U::SumAll => B::BroadcastFst,
            U::RowSum => B::BroadcastRowsFst,
            U::SoftmaxRows => B::DSoftmaxRows,
            U::Transpose => B::TransposeFst,
            U::Dropout { seed, rate } => B::DDropout { seed, rate },
        })
    }

    /// Output shape given input shape (panics on unsupported input).
    pub fn out_shape(&self, s: (usize, usize)) -> (usize, usize) {
        match self {
            UnaryKernel::SumAll => (1, 1),
            UnaryKernel::RowSum => (s.0, 1),
            UnaryKernel::Transpose => (s.1, s.0),
            _ => s,
        }
    }

    pub fn name(&self) -> &'static str {
        use UnaryKernel::*;
        match self {
            Id => "id",
            Neg => "neg",
            Scale(_) => "scale",
            AddConst(_) => "add_const",
            Logistic => "logistic",
            Relu => "relu",
            Tanh => "tanh",
            Exp => "exp",
            Log => "log",
            Square => "square",
            Sqrt => "sqrt",
            SumAll => "sum_all",
            RowSum => "row_sum",
            SoftmaxRows => "softmax_rows",
            Transpose => "transpose",
            Dropout { .. } => "dropout",
        }
    }
}

impl BinaryKernel {
    /// Vjp w.r.t. the left operand.
    pub fn vjp_l(&self) -> VjpSpec {
        use BinaryKernel as B;
        use VjpSpec as V;
        match *self {
            B::Add => V::OfG(UnaryKernel::Id),
            B::Sub => V::OfG(UnaryKernel::Id),
            B::Mul => V::ChainOther(B::Mul),
            B::Div => V::Partial {
                partial: B::DDivL,
                chain: B::Mul,
            },
            // ∂(l·r)/∂l chained with g: g·rᵀ
            B::MatMul => V::ChainOther(B::MatMulNT),
            // ∂(lᵀ·r)/∂l chained with g: r·gᵀ ... (g = lᵀr grad, shape (c_l? ));
            // lᵀ·r : (k,m)ᵀ(k,n) -> (m,n); dL/dl = r·gᵀ : (k,n)(n,m) -> (k,m)
            B::MatMulTN => V::ChainOtherRev(B::MatMulNT),
            // l·rᵀ : (m,k)(n,k)ᵀ -> (m,n); dL/dl = g·r : (m,n)(n,k)
            B::MatMulNT => V::ChainOther(B::MatMul),
            B::BceLoss => V::Partial {
                partial: B::DBceDyhat,
                chain: B::Mul,
            },
            B::SquaredDiff => V::Partial {
                partial: B::DSquaredDiffL,
                chain: B::Mul,
            },
            B::SoftmaxXentRows => V::Partial {
                partial: B::DSoftmaxXentDl,
                chain: B::RowBroadcastMul,
            },
            // d(s·X)/ds chained with g: Σ(g∘X) — scalar shaped
            B::ScalarMul => V::ChainOther(B::SumMul),
            _ => V::None,
        }
    }

    /// Vjp w.r.t. the right operand.
    pub fn vjp_r(&self) -> VjpSpec {
        use BinaryKernel as B;
        use VjpSpec as V;
        match *self {
            B::Add => V::OfG(UnaryKernel::Id),
            B::Sub => V::OfG(UnaryKernel::Neg),
            B::Mul => V::ChainOther(B::Mul), // other = l here
            B::Div => V::Partial {
                partial: B::DDivR,
                chain: B::Mul,
            },
            // dL/dr = lᵀ·g = MatMulTN(l, g) with (other, g) order
            B::MatMul => V::ChainOtherRev(B::MatMulTN),
            // lᵀ·r: dL/dr = l·g
            B::MatMulTN => V::ChainOtherRev(B::MatMul),
            // l·rᵀ: (m,k)(n,k) -> (m,n); dL/dr = gᵀ·l : (n,m)(m,k) -> (n,k)
            B::MatMulNT => V::ChainOther(B::MatMulTN),
            // d(s·X)/dX chained with g: s·g
            B::ScalarMul => V::ChainOtherRev(B::ScalarMul),
            // `Snd` forwards its right operand (tuple-selection joins):
            // gradient passes straight through.
            B::Snd => V::OfG(UnaryKernel::Id),
            _ => V::None,
        }
    }

    /// Output shape for given operand shapes; `None` if incompatible.
    pub fn out_shape(&self, l: (usize, usize), r: (usize, usize)) -> Option<(usize, usize)> {
        use BinaryKernel as B;
        match self {
            B::MatMul => (l.1 == r.0).then_some((l.0, r.1)),
            B::MatMulTN => (l.0 == r.0).then_some((l.1, r.1)),
            B::MatMulNT => (l.1 == r.1).then_some((l.0, r.0)),
            B::SoftmaxXentRows => (l == r).then_some((l.0, 1)),
            B::RowBroadcastMul => (l.1 == 1 && l.0 == r.0).then_some(r),
            B::ScalarMul => (l == (1, 1)).then_some(r),
            B::SumMul => (l == r).then_some((1, 1)),
            B::Fst | B::NegFst | B::ScaleFst(_) => Some(l),
            B::TransposeFst => Some((l.1, l.0)),
            B::Snd | B::BroadcastFst | B::BroadcastRowsFst => Some(r),
            B::OnesLike | B::NegOnesLike | B::DDivL => Some(l),
            _ => (l == r).then_some(l),
        }
    }

    /// FLOPs estimate for the roofline/§Perf reporting.
    pub fn flops(&self, l: (usize, usize), r: (usize, usize)) -> u64 {
        use BinaryKernel as B;
        match self {
            B::MatMul => 2 * (l.0 * l.1 * r.1) as u64,
            B::MatMulTN => 2 * (l.1 * l.0 * r.1) as u64,
            B::MatMulNT => 2 * (l.0 * l.1 * r.0) as u64,
            _ => (l.0 * l.1).max(r.0 * r.1) as u64,
        }
    }

    /// Is the kernel linear in the given operand (`left = true` for the
    /// left one)? Linearity is what licenses the factorized-evaluation
    /// rewrite ([`crate::plan::factorize`]): partial sums may be pushed
    /// below the join on an operand only when
    /// `⊗(a + b, x) = ⊗(a, x) + ⊗(b, x)` (resp. on the right). The list
    /// is deliberately conservative — anything not provably linear
    /// answers `false`, which merely refuses an optimization.
    pub fn linear_in(&self, left: bool) -> bool {
        use BinaryKernel as B;
        match self {
            // Bilinear: products in every flavor.
            B::Mul
            | B::MatMul
            | B::MatMulTN
            | B::MatMulNT
            | B::ScalarMul
            | B::SumMul
            | B::RowBroadcastMul => true,
            // Pass-through / rescale of the left operand only.
            B::Fst
            | B::NegFst
            | B::ScaleFst(_)
            | B::TransposeFst
            | B::BroadcastFst
            | B::BroadcastRowsFst => left,
            // Pass-through of the right operand only.
            B::Snd => !left,
            // Add/Sub are affine in each operand but not linear
            // (`(a+b) ⊕ x ≠ (a ⊕ x) + (b ⊕ x)`); everything else is a
            // loss / derivative kernel with no useful algebra.
            _ => false,
        }
    }

    pub fn name(&self) -> &'static str {
        use BinaryKernel::*;
        match self {
            Add => "add",
            Sub => "sub",
            Mul => "mul",
            Div => "div",
            MatMul => "matmul",
            MatMulTN => "matmul_tn",
            MatMulNT => "matmul_nt",
            BceLoss => "bce_loss",
            SquaredDiff => "squared_diff",
            SoftmaxXentRows => "softmax_xent_rows",
            RowBroadcastMul => "row_broadcast_mul",
            ScalarMul => "scalar_mul",
            SumMul => "sum_mul",
            Fst => "fst",
            Snd => "snd",
            NegFst => "neg_fst",
            ScaleFst(_) => "scale_fst",
            BroadcastFst => "broadcast_fst",
            BroadcastRowsFst => "broadcast_rows_fst",
            TransposeFst => "transpose_fst",
            OnesLike => "ones_like",
            NegOnesLike => "neg_ones_like",
            DLogistic => "d_logistic",
            DRelu => "d_relu",
            DTanh => "d_tanh",
            DExp => "d_exp",
            DLog => "d_log",
            DSquare => "d_square",
            DSqrt => "d_sqrt",
            DDropout { .. } => "d_dropout",
            DSoftmaxRows => "d_softmax_rows",
            DDivL => "d_div_l",
            DDivR => "d_div_r",
            DBceDyhat => "d_bce_dyhat",
            DSquaredDiffL => "d_squared_diff_l",
            DSoftmaxXentDl => "d_softmax_xent_dl",
        }
    }
}

impl AggKernel {
    /// Combine in place: `acc = acc ⊕ x`.
    pub fn combine(&self, acc: &mut Chunk, x: &Chunk) {
        match self {
            AggKernel::Sum => acc.add_assign(x),
            AggKernel::Max => {
                assert_eq!(acc.shape(), x.shape(), "max agg shape mismatch");
                let d = acc.data_mut();
                for (a, b) in d.iter_mut().zip(x.data().iter()) {
                    *a = a.max(*b);
                }
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AggKernel::Sum => "sum",
            AggKernel::Max => "max",
        }
    }
}

/// Kernel dispatch: native Rust or AOT-compiled XLA artifacts.
///
/// The trait itself is deliberately *not* `Send`/`Sync`-bounded — a
/// backend holding thread-affine handles can still implement it for
/// single-threaded use. Instead, [`KernelBackend::for_worker`] mints an
/// independent `Send + Sync` instance per worker, and each thread of the
/// persistent `dist::pool::WorkerPool` owns its instance for the pool's
/// whole lifetime — one mint per worker per `session::Session` (or per
/// run of the deprecated free-function surface), however many stages,
/// evaluations and training steps the pool serves. This mirrors per-node
/// runtimes in a real deployment, and caps the cost of expensive mints
/// (a PJRT artifact load under `--features xla`) at once per worker. The
/// `Sync` half of the bound is what lets one minted root instance back a
/// shared [`crate::session::Session`] state (and the concurrent serving
/// clients of `crate::serve`) — dispatch goes through `&self`, so a
/// driver-side backend must tolerate concurrent calls.
pub trait KernelBackend {
    fn unary(&self, k: &UnaryKernel, key: &Key, x: &Chunk) -> Chunk;
    fn binary(&self, k: &BinaryKernel, key: &Key, l: &Chunk, r: &Chunk) -> Chunk;
    /// Backend name, for logs/benches (and the pool's rebuild-on-change
    /// check in `ml::TrainPipeline`).
    fn name(&self) -> &'static str;
    /// Mint an independent backend instance for one worker thread to own.
    /// Must dispatch identically to `self` (the determinism tests compare
    /// threaded and serial execution bitwise). Called once per worker at
    /// pool construction, never per stage or per evaluation.
    fn for_worker(&self) -> Box<dyn KernelBackend + Send + Sync>;
}

pub use native::NativeBackend;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vjp_specs_cover_forward_kernels() {
        // Every *forward* binary kernel must have a defined left vjp.
        for k in [
            BinaryKernel::Add,
            BinaryKernel::Sub,
            BinaryKernel::Mul,
            BinaryKernel::Div,
            BinaryKernel::MatMul,
            BinaryKernel::MatMulTN,
            BinaryKernel::MatMulNT,
            BinaryKernel::BceLoss,
            BinaryKernel::SquaredDiff,
            BinaryKernel::SoftmaxXentRows,
        ] {
            assert!(k.vjp_l() != VjpSpec::None, "no vjp_l for {:?}", k);
        }
    }

    #[test]
    fn out_shapes() {
        use BinaryKernel as B;
        assert_eq!(B::MatMul.out_shape((2, 3), (3, 4)), Some((2, 4)));
        assert_eq!(B::MatMul.out_shape((2, 3), (4, 4)), None);
        assert_eq!(B::MatMulTN.out_shape((3, 2), (3, 4)), Some((2, 4)));
        assert_eq!(B::MatMulNT.out_shape((2, 3), (4, 3)), Some((2, 4)));
        assert_eq!(B::SoftmaxXentRows.out_shape((4, 8), (4, 8)), Some((4, 1)));
        assert_eq!(B::Add.out_shape((2, 2), (2, 2)), Some((2, 2)));
        assert_eq!(B::Add.out_shape((2, 2), (2, 3)), None);
        assert_eq!(UnaryKernel::SumAll.out_shape((3, 5)), (1, 1));
        assert_eq!(UnaryKernel::Transpose.out_shape((3, 5)), (5, 3));
    }

    #[test]
    fn flops_matmul() {
        assert_eq!(BinaryKernel::MatMul.flops((64, 64), (64, 64)), 2 * 64 * 64 * 64);
    }

    #[test]
    fn linearity_classification() {
        use BinaryKernel as B;
        // Bilinear kernels collapse on either side.
        for k in [B::Mul, B::MatMul, B::MatMulTN, B::MatMulNT, B::ScalarMul] {
            assert!(k.linear_in(true), "{} left", k.name());
            assert!(k.linear_in(false), "{} right", k.name());
        }
        // One-sided pass-throughs.
        assert!(B::Fst.linear_in(true) && !B::Fst.linear_in(false));
        assert!(B::Snd.linear_in(false) && !B::Snd.linear_in(true));
        assert!(B::ScaleFst(2.0).linear_in(true));
        // Affine-but-not-linear and loss kernels refuse.
        for k in [B::Add, B::Sub, B::Div, B::BceLoss, B::SoftmaxXentRows, B::OnesLike] {
            assert!(!k.linear_in(true), "{} left", k.name());
            assert!(!k.linear_in(false), "{} right", k.name());
        }
    }
}
