//! Native Rust kernel implementations.
//!
//! These are the reference/baseline backend and the implementation behind
//! every baseline system; the production three-layer path dispatches the
//! same kernels to AOT-compiled XLA artifacts (`kernels::registry` +
//! `runtime`). Matmul is blocked/unrolled — it dominates every workload's
//! FLOPs and is the §Perf L3 hot path.

use super::{AggKernel, BinaryKernel, KernelBackend, UnaryKernel};
use crate::ra::{Chunk, Key};
use crate::util::fxhash::hash_u64;

pub struct NativeBackend;

impl KernelBackend for NativeBackend {
    fn unary(&self, k: &UnaryKernel, key: &Key, x: &Chunk) -> Chunk {
        apply_unary(k, key, x)
    }

    fn binary(&self, k: &BinaryKernel, key: &Key, l: &Chunk, r: &Chunk) -> Chunk {
        apply_binary(k, key, l, r)
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn for_worker(&self) -> Box<dyn KernelBackend + Send + Sync> {
        // Stateless: every worker instance dispatches identically.
        Box::new(NativeBackend)
    }
}

#[inline]
fn logistic(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Deterministic inverted-dropout mask value for element `idx` of the
/// chunk at `key`: 0 with probability `rate`, else `1/(1-rate)`.
#[inline]
fn dropout_mask(seed: u64, key: &Key, idx: usize, rate: f32) -> f32 {
    let h = hash_u64(seed ^ key.stable_hash() ^ (idx as u64).wrapping_mul(0x9e37_79b9));
    let u = (h >> 40) as f32 / (1u64 << 24) as f32;
    if u < rate {
        0.0
    } else {
        1.0 / (1.0 - rate)
    }
}

pub fn apply_unary(k: &UnaryKernel, key: &Key, x: &Chunk) -> Chunk {
    use UnaryKernel as U;
    match *k {
        U::Id => x.clone(),
        U::Neg => x.map(|v| -v),
        U::Scale(c) => x.map(|v| v * c),
        U::AddConst(c) => x.map(|v| v + c),
        U::Logistic => x.map(logistic),
        U::Relu => x.map(|v| v.max(0.0)),
        U::Tanh => x.map(f32::tanh),
        U::Exp => x.map(f32::exp),
        U::Log => x.map(|v| v.max(1e-12).ln()),
        U::Square => x.map(|v| v * v),
        U::Sqrt => x.map(|v| v.max(0.0).sqrt()),
        U::SumAll => Chunk::scalar(x.sum()),
        U::RowSum => {
            let (r, c) = x.shape();
            let d = x.data();
            let mut out = vec![0.0f32; r];
            for i in 0..r {
                out[i] = d[i * c..(i + 1) * c].iter().sum();
            }
            Chunk::from_vec(r, 1, out)
        }
        U::SoftmaxRows => softmax_rows(x),
        U::Transpose => x.transpose(),
        U::Dropout { seed, rate } => {
            let d = x.data();
            Chunk::from_vec(
                x.rows(),
                x.cols(),
                d.iter()
                    .enumerate()
                    .map(|(i, &v)| v * dropout_mask(seed, key, i, rate))
                    .collect(),
            )
        }
    }
}

fn softmax_rows(x: &Chunk) -> Chunk {
    let (r, c) = x.shape();
    let d = x.data();
    let mut out = vec![0.0f32; r * c];
    for i in 0..r {
        let row = &d[i * c..(i + 1) * c];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0;
        for j in 0..c {
            let e = (row[j] - m).exp();
            out[i * c + j] = e;
            z += e;
        }
        for j in 0..c {
            out[i * c + j] /= z;
        }
    }
    Chunk::from_vec(r, c, out)
}

pub fn apply_binary(k: &BinaryKernel, key: &Key, l: &Chunk, r: &Chunk) -> Chunk {
    use BinaryKernel as B;
    match *k {
        B::Add => l.zip_map(r, |a, b| a + b),
        B::Sub => l.zip_map(r, |a, b| a - b),
        B::Mul => l.zip_map(r, |a, b| a * b),
        B::Div => l.zip_map(r, |a, b| a / b),
        B::MatMul => matmul(l, r),
        B::MatMulTN => matmul_tn(l, r),
        B::MatMulNT => matmul_nt(l, r),
        B::BceLoss => l.zip_map(r, |yhat, y| {
            let yh = yhat.clamp(1e-7, 1.0 - 1e-7);
            -y * yh.ln() + (y - 1.0) * (1.0 - yh).ln()
        }),
        B::SquaredDiff => l.zip_map(r, |a, b| (a - b) * (a - b)),
        B::SoftmaxXentRows => softmax_xent_rows(l, r),
        B::RowBroadcastMul => row_broadcast_mul(l, r),
        B::ScalarMul => {
            let s = l.as_scalar();
            r.map(|v| s * v)
        }
        B::SumMul => {
            assert_eq!(l.shape(), r.shape(), "SumMul shape mismatch");
            Chunk::scalar(
                l.data()
                    .iter()
                    .zip(r.data().iter())
                    .map(|(a, b)| a * b)
                    .sum(),
            )
        }
        B::Fst => l.clone(),
        B::Snd => r.clone(),
        B::NegFst => l.map(|v| -v),
        B::ScaleFst(c) => l.map(|v| v * c),
        B::BroadcastFst => Chunk::filled(r.rows(), r.cols(), l.as_scalar()),
        B::BroadcastRowsFst => {
            assert_eq!(l.cols(), 1, "BroadcastRowsFst expects (r,1) gradient");
            assert_eq!(l.rows(), r.rows());
            let (rr, rc) = r.shape();
            let ld = l.data();
            let mut out = vec![0.0f32; rr * rc];
            for i in 0..rr {
                out[i * rc..(i + 1) * rc].fill(ld[i]);
            }
            Chunk::from_vec(rr, rc, out)
        }
        B::TransposeFst => l.transpose(),
        B::OnesLike => Chunk::filled(l.rows(), l.cols(), 1.0),
        B::NegOnesLike => Chunk::filled(l.rows(), l.cols(), -1.0),
        B::DLogistic => l.zip_map(r, |g, x| {
            let s = logistic(x);
            g * s * (1.0 - s)
        }),
        B::DRelu => l.zip_map(r, |g, x| if x > 0.0 { g } else { 0.0 }),
        B::DTanh => l.zip_map(r, |g, x| {
            let t = x.tanh();
            g * (1.0 - t * t)
        }),
        B::DExp => l.zip_map(r, |g, x| g * x.exp()),
        B::DLog => l.zip_map(r, |g, x| g / x.max(1e-12)),
        B::DSquare => l.zip_map(r, |g, x| 2.0 * x * g),
        B::DSqrt => l.zip_map(r, |g, x| g / (2.0 * x.max(1e-12).sqrt())),
        B::DDropout { seed, rate } => {
            assert_eq!(l.shape(), r.shape());
            let g = l.data();
            Chunk::from_vec(
                l.rows(),
                l.cols(),
                g.iter()
                    .enumerate()
                    .map(|(i, &gv)| gv * dropout_mask(seed, key, i, rate))
                    .collect(),
            )
        }
        B::DSoftmaxRows => d_softmax_rows(l, r),
        B::DDivL => r.map(|b| 1.0 / b),
        B::DDivR => l.zip_map(r, |a, b| -a / (b * b)),
        B::DBceDyhat => l.zip_map(r, |yhat, y| {
            let yh = yhat.clamp(1e-7, 1.0 - 1e-7);
            (yh - y) / (yh * (1.0 - yh))
        }),
        B::DSquaredDiffL => l.zip_map(r, |a, b| 2.0 * (a - b)),
        B::DSoftmaxXentDl => {
            let sm = softmax_rows(l);
            sm.zip_map(r, |p, y| p - y)
        }
    }
}

/// Row-wise softmax cross-entropy loss: `-Σ_j r_ij · ln softmax(l)_ij`,
/// output (rows, 1). Rows of `r` that are all-zero (unlabeled / masked
/// nodes) produce zero loss.
fn softmax_xent_rows(l: &Chunk, r: &Chunk) -> Chunk {
    assert_eq!(l.shape(), r.shape(), "softmax_xent shape mismatch");
    let sm = softmax_rows(l);
    let (rows, cols) = l.shape();
    let (s, y) = (sm.data(), r.data());
    let mut out = vec![0.0f32; rows];
    for i in 0..rows {
        let mut acc = 0.0;
        for j in 0..cols {
            let yij = y[i * cols + j];
            if yij != 0.0 {
                acc -= yij * s[i * cols + j].max(1e-12).ln();
            }
        }
        out[i] = acc;
    }
    Chunk::from_vec(rows, 1, out)
}

fn row_broadcast_mul(l: &Chunk, r: &Chunk) -> Chunk {
    assert_eq!(l.cols(), 1, "RowBroadcastMul expects (r,1) left operand");
    assert_eq!(l.rows(), r.rows());
    let (rr, rc) = r.shape();
    let (ld, rd) = (l.data(), r.data());
    let mut out = vec![0.0f32; rr * rc];
    for i in 0..rr {
        let gi = ld[i];
        for j in 0..rc {
            out[i * rc + j] = gi * rd[i * rc + j];
        }
    }
    Chunk::from_vec(rr, rc, out)
}

/// Softmax-rows vjp: with y = softmax(x), grad = y ∘ (g − rowdot(g,y)).
fn d_softmax_rows(g: &Chunk, x: &Chunk) -> Chunk {
    assert_eq!(g.shape(), x.shape());
    let y = softmax_rows(x);
    let (rows, cols) = x.shape();
    let (gd, yd) = (g.data(), y.data());
    let mut out = vec![0.0f32; rows * cols];
    for i in 0..rows {
        let mut dot = 0.0;
        for j in 0..cols {
            dot += gd[i * cols + j] * yd[i * cols + j];
        }
        for j in 0..cols {
            out[i * cols + j] = yd[i * cols + j] * (gd[i * cols + j] - dot);
        }
    }
    Chunk::from_vec(rows, cols, out)
}

// --------------------------------------------------- blocked matmul core

/// Panel sizes for the cache-blocked SAXPY microkernel: one KC×NC panel
/// of B (≤ 64 KiB) stays cache-resident while the rows of A and of the
/// output stream past it. Chunk shapes in this engine are typically
/// 32–128, so small matrices degenerate to a single panel with no
/// overhead.
const KC: usize = 64;
const NC: usize = 256;

/// Row-major blocked GEMM core: `out[i*n+j] = Σ_p a[i*k+p] · b[p*n+j]`.
///
/// Every output element accumulates its products strictly in increasing
/// `p` starting from `0.0` — blocking reorders *which elements* are
/// touched when, never the additions within one element — so the result
/// is bitwise identical to the naive triple loop (`matmul_naive` et al.)
/// on finite inputs, for every shape. The inner loop walks `b` and `out`
/// contiguously over `j`, which auto-vectorizes without needing a
/// (reassociating) reduction.
fn gemm_blocked(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for jc in (0..n).step_by(NC) {
        let je = (jc + NC).min(n);
        for pc in (0..k).step_by(KC) {
            let pe = (pc + KC).min(k);
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let orow = &mut out[i * n + jc..i * n + je];
                for p in pc..pe {
                    let av = arow[p];
                    // Skipping a zero multiplier leaves finite
                    // accumulators bit-identical and is a large win on
                    // sparse adjacency chunks: a ±0.0 product cannot
                    // change a nonzero accumulator, and an accumulator
                    // seeded at +0.0 can never become -0.0 (IEEE
                    // round-to-nearest: +0.0 + -0.0 = +0.0, and exact
                    // cancellation yields +0.0), so the skipped adds are
                    // all exact no-ops (tested incl. all-zero rows).
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[p * n + jc..p * n + je];
                    for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                        *o += av * bv;
                    }
                }
            }
        }
    }
    out
}

/// `(rows×cols)` row-major → `(cols×rows)` row-major transpose panel,
/// feeding the TN/NT variants into the same blocked core.
fn transpose_panel(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut dst = vec![0.0f32; src.len()];
    for r in 0..rows {
        let srow = &src[r * cols..(r + 1) * cols];
        for (c, &v) in srow.iter().enumerate() {
            dst[c * rows + r] = v;
        }
    }
    dst
}

/// `l · r`, cache-blocked (see `gemm_blocked`).
pub fn matmul(l: &Chunk, r: &Chunk) -> Chunk {
    let (m, k) = l.shape();
    let (k2, n) = r.shape();
    assert_eq!(k, k2, "matmul inner-dim mismatch: {:?}x{:?}", l.shape(), r.shape());
    Chunk::from_vec(m, n, gemm_blocked(l.data(), r.data(), m, k, n))
}

/// `lᵀ · r`: (k,m)ᵀ·(k,n) → (m,n). Transpose-panels `l` once, then runs
/// the same blocked core — identical accumulation order to
/// `matmul_tn_naive`.
pub fn matmul_tn(l: &Chunk, r: &Chunk) -> Chunk {
    let (k, m) = l.shape();
    let (k2, n) = r.shape();
    assert_eq!(k, k2, "matmul_tn inner-dim mismatch");
    let at = transpose_panel(l.data(), k, m);
    Chunk::from_vec(m, n, gemm_blocked(&at, r.data(), m, k, n))
}

/// `l · rᵀ`: (m,k)·(n,k)ᵀ → (m,n). Transpose-panels `r` once, then runs
/// the same blocked core — identical accumulation order to
/// `matmul_nt_naive`.
pub fn matmul_nt(l: &Chunk, r: &Chunk) -> Chunk {
    let (m, k) = l.shape();
    let (n, k2) = r.shape();
    assert_eq!(k, k2, "matmul_nt inner-dim mismatch");
    let bt = transpose_panel(r.data(), n, k);
    Chunk::from_vec(m, n, gemm_blocked(l.data(), &bt, m, k, n))
}

/// Reference `l · r`: the naive triple loop, accumulating over `p` in
/// increasing order. The blocked kernels must match it bitwise (tested).
pub fn matmul_naive(l: &Chunk, r: &Chunk) -> Chunk {
    let (m, k) = l.shape();
    let (k2, n) = r.shape();
    assert_eq!(k, k2, "matmul inner-dim mismatch");
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += l.data()[i * k + p] * r.data()[p * n + j];
            }
            out[i * n + j] = acc;
        }
    }
    Chunk::from_vec(m, n, out)
}

/// Reference `lᵀ · r` (naive; see `matmul_naive`).
pub fn matmul_tn_naive(l: &Chunk, r: &Chunk) -> Chunk {
    let (k, m) = l.shape();
    let (k2, n) = r.shape();
    assert_eq!(k, k2, "matmul_tn inner-dim mismatch");
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += l.data()[p * m + i] * r.data()[p * n + j];
            }
            out[i * n + j] = acc;
        }
    }
    Chunk::from_vec(m, n, out)
}

/// Reference `l · rᵀ` (naive; see `matmul_naive`).
pub fn matmul_nt_naive(l: &Chunk, r: &Chunk) -> Chunk {
    let (m, k) = l.shape();
    let (n, k2) = r.shape();
    assert_eq!(k, k2, "matmul_nt inner-dim mismatch");
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += l.data()[i * k + p] * r.data()[j * k + p];
            }
            out[i * n + j] = acc;
        }
    }
    Chunk::from_vec(m, n, out)
}

/// Aggregate helper used by evaluators.
pub fn agg_combine(k: &AggKernel, acc: &mut Chunk, x: &Chunk) {
    k.combine(acc, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn key() -> Key {
        Key::k1(0)
    }

    /// Bitwise equality of two chunks (shape + every element's bits).
    fn bits_eq(a: &Chunk, b: &Chunk) -> bool {
        a.shape() == b.shape()
            && a.data()
                .iter()
                .zip(b.data().iter())
                .all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn matmul_matches_naive() {
        // The blocked kernels must match the naive references within
        // 0 ULP: per output element the additions run in the same order,
        // so blocking must not change a single bit. Covers aligned
        // shapes, the KC=64 / NC=256 tile boundaries (±1), and random
        // ragged shapes; all three variants.
        let mut rng = Prng::new(0xB10C);
        let mut shapes = vec![
            (1usize, 1usize, 1usize),
            (2, 3, 4),
            (7, 5, 3),
            (16, 16, 16),
            (32, 32, 32),
            (64, 64, 64),
            // k across the KC=64 panel boundary
            (3, 63, 5),
            (3, 64, 5),
            (3, 65, 5),
            (2, 129, 7),
            // n across the NC=256 panel boundary
            (2, 8, 255),
            (2, 8, 256),
            (2, 8, 257),
            (5, 64, 260),
        ];
        for _ in 0..12 {
            shapes.push((
                1 + rng.below(40) as usize,
                1 + rng.below(90) as usize,
                1 + rng.below(90) as usize,
            ));
        }
        for (m, k, n) in shapes {
            let a = Chunk::random(m, k, &mut rng, 1.0);
            let b = Chunk::random(k, n, &mut rng, 1.0);
            assert!(
                bits_eq(&matmul(&a, &b), &matmul_naive(&a, &b)),
                "matmul ({m},{k},{n}) diverged from naive"
            );
            let at = a.transpose(); // (k, m)
            assert!(
                bits_eq(&matmul_tn(&at, &b), &matmul_tn_naive(&at, &b)),
                "matmul_tn ({m},{k},{n}) diverged from naive"
            );
            let bt = b.transpose(); // (n, k)
            assert!(
                bits_eq(&matmul_nt(&a, &bt), &matmul_nt_naive(&a, &bt)),
                "matmul_nt ({m},{k},{n}) diverged from naive"
            );
        }
    }

    #[test]
    fn matmul_variants_consistent() {
        let mut rng = Prng::new(2);
        let a = Chunk::random(4, 6, &mut rng, 1.0);
        let b = Chunk::random(6, 5, &mut rng, 1.0);
        let c = matmul(&a, &b);
        // lᵀ·r with l = aᵀ equals a·b
        assert!(matmul_tn(&a.transpose(), &b).approx_eq(&c, 1e-5));
        // l·rᵀ with r = bᵀ equals a·b
        assert!(matmul_nt(&a, &b.transpose()).approx_eq(&c, 1e-5));
        // And the TN/NT naive references agree with the matmul reference.
        assert!(matmul_tn_naive(&a.transpose(), &b).approx_eq(&c, 1e-5));
        assert!(matmul_nt_naive(&a, &b.transpose()).approx_eq(&c, 1e-5));
    }

    #[test]
    fn matmul_zero_rows_and_sparse_inputs_exact() {
        // The zero-multiplier skip must not change bits on sparse data.
        let mut rng = Prng::new(3);
        let mut a = Chunk::random(9, 70, &mut rng, 1.0);
        for p in 0..70 {
            if p % 3 != 0 {
                for i in 0..9 {
                    a.set(i, p, 0.0);
                }
            }
        }
        let b = Chunk::random(70, 11, &mut rng, 1.0);
        assert!(bits_eq(&matmul(&a, &b), &matmul_naive(&a, &b)));
        // Signed-zero edge: an all-zero row against negative values. The
        // naive path accumulates 0.0·(-x) = -0.0 terms, the blocked path
        // skips them; both must land on +0.0 (IEEE: +0.0 + -0.0 = +0.0).
        let z = Chunk::zeros(2, 8);
        let neg = Chunk::filled(8, 3, -2.5);
        let blocked = matmul(&z, &neg);
        let naive = matmul_naive(&z, &neg);
        assert!(bits_eq(&blocked, &naive));
        assert!(blocked.data().iter().all(|v| v.to_bits() == 0.0f32.to_bits()));
    }

    #[test]
    fn unary_kernels() {
        let x = Chunk::from_vec(1, 4, vec![-1.0, 0.0, 1.0, 2.0]);
        let k = key();
        assert_eq!(apply_unary(&UnaryKernel::Relu, &k, &x).data(), &[0., 0., 1., 2.]);
        let s = apply_unary(&UnaryKernel::Logistic, &k, &x);
        assert!((s.at(0, 1) - 0.5).abs() < 1e-6);
        assert_eq!(apply_unary(&UnaryKernel::SumAll, &k, &x).as_scalar(), 2.0);
        assert_eq!(
            apply_unary(&UnaryKernel::RowSum, &k, &Chunk::from_vec(2, 2, vec![1., 2., 3., 4.]))
                .data(),
            &[3., 7.]
        );
    }

    #[test]
    fn softmax_rows_sums_to_one() {
        let x = Chunk::from_vec(2, 3, vec![1., 2., 3., -1., 0., 100.]);
        let s = softmax_rows(&x);
        for i in 0..2 {
            let sum: f32 = (0..3).map(|j| s.at(i, j)).sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
        assert!(s.at(1, 2) > 0.999); // large logit dominates, no overflow
    }

    #[test]
    fn softmax_xent_matches_manual() {
        let logits = Chunk::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let onehot = Chunk::from_vec(1, 3, vec![0.0, 0.0, 1.0]);
        let loss = softmax_xent_rows(&logits, &onehot);
        let z: f32 = (1f32.exp() + 2f32.exp() + 3f32.exp()).ln();
        assert!((loss.at(0, 0) - (z - 3.0)).abs() < 1e-5);
        // masked row → zero loss
        let masked = softmax_xent_rows(&logits, &Chunk::zeros(1, 3));
        assert_eq!(masked.at(0, 0), 0.0);
    }

    #[test]
    fn bce_matches_paper_formula() {
        // ⊗Loss(yhat, y) = -y·log(yhat) + (y-1)·log(1-yhat)
        let yhat = Chunk::scalar(0.8);
        let y = Chunk::scalar(1.0);
        let l = apply_binary(&BinaryKernel::BceLoss, &key(), &yhat, &y);
        assert!((l.as_scalar() - (-(0.8f32.ln()))).abs() < 1e-5);
        let y0 = Chunk::scalar(0.0);
        let l0 = apply_binary(&BinaryKernel::BceLoss, &key(), &yhat, &y0);
        assert!((l0.as_scalar() - (-(0.2f32.ln()))).abs() < 1e-4);
    }

    #[test]
    fn dropout_deterministic_and_mask_consistent() {
        let x = Chunk::filled(4, 4, 1.0);
        let k = Key::k2(3, 7);
        let d = UnaryKernel::Dropout { seed: 42, rate: 0.5 };
        let a = apply_unary(&d, &k, &x);
        let b = apply_unary(&d, &k, &x);
        assert!(a.approx_eq(&b, 0.0));
        // Backward mask matches forward mask exactly.
        let g = Chunk::filled(4, 4, 1.0);
        let gb = apply_binary(&BinaryKernel::DDropout { seed: 42, rate: 0.5 }, &k, &g, &x);
        assert!(gb.approx_eq(&a, 0.0));
        // Different key → different mask (with overwhelming probability).
        let c = apply_unary(&d, &Key::k2(3, 8), &x);
        assert!(!c.approx_eq(&a, 0.0));
    }

    #[test]
    fn elementwise_derivative_kernels_match_finite_diff() {
        let mut rng = Prng::new(3);
        let x = Chunk::random(2, 3, &mut rng, 0.5);
        let g = Chunk::filled(2, 3, 1.0);
        let eps = 1e-3f32;
        let cases: Vec<(UnaryKernel, BinaryKernel)> = vec![
            (UnaryKernel::Logistic, BinaryKernel::DLogistic),
            (UnaryKernel::Tanh, BinaryKernel::DTanh),
            (UnaryKernel::Exp, BinaryKernel::DExp),
            (UnaryKernel::Square, BinaryKernel::DSquare),
        ];
        for (fwd, bwd) in cases {
            let d = apply_binary(&bwd, &key(), &g, &x);
            let xp = x.map(|v| v + eps);
            let xm = x.map(|v| v - eps);
            let fp = apply_unary(&fwd, &key(), &xp);
            let fm = apply_unary(&fwd, &key(), &xm);
            let fd = fp.zip_map(&fm, |a, b| (a - b) / (2.0 * eps));
            assert!(
                d.approx_eq(&fd, 2e-2),
                "kernel {:?}: analytic {:?} vs fd {:?}",
                fwd,
                d,
                fd
            );
        }
    }

    #[test]
    fn broadcast_kernels() {
        let g = Chunk::scalar(3.0);
        let x = Chunk::zeros(2, 2);
        let b = apply_binary(&BinaryKernel::BroadcastFst, &key(), &g, &x);
        assert_eq!(b.data(), &[3., 3., 3., 3.]);
        let gr = Chunk::from_vec(2, 1, vec![1.0, 2.0]);
        let br = apply_binary(&BinaryKernel::BroadcastRowsFst, &key(), &gr, &x);
        assert_eq!(br.data(), &[1., 1., 2., 2.]);
        let rbm = apply_binary(
            &BinaryKernel::RowBroadcastMul,
            &key(),
            &gr,
            &Chunk::filled(2, 2, 5.0),
        );
        assert_eq!(rbm.data(), &[5., 5., 10., 10.]);
    }

    #[test]
    fn max_agg() {
        let mut acc = Chunk::from_vec(1, 2, vec![1.0, 5.0]);
        AggKernel::Max.combine(&mut acc, &Chunk::from_vec(1, 2, vec![3.0, 2.0]));
        assert_eq!(acc.data(), &[3.0, 5.0]);
    }
}
