//! Deterministic fault injection for the BSP executor — the test rig
//! behind the engine's fault-tolerance story.
//!
//! A real deployment loses workers mid-join, gets transient I/O errors
//! from spill devices, and sees stragglers. The virtual cluster cannot
//! wait for those to happen: a [`FaultPlan`] *scripts* them. Each plan
//! entry names an [`InjectionPoint`] (where in the stage lifecycle the
//! fault fires), a worker, a 1-based occurrence count, and a
//! [`FaultKind`] (how it fails). The executor threads one
//! [`FaultInjector`] through every stage when
//! `ClusterConfig::fault_plan` is set; each instrumented site calls
//! [`FaultInjector::probe`] with its point and worker index, and the
//! injector fires exactly at the scripted coordinates — every failure
//! scenario is a reproducible unit test, never a flake.
//!
//! Three design rules keep this honest:
//!
//! 1. **Deterministic.** Occurrence counters are per `(point, worker)`
//!    and count *probes at that site*, which the executor visits in a
//!    deterministic order; the rate mode hashes
//!    `(seed, point, worker, occurrence)` with a splitmix-style mixer,
//!    so the same seed fires the same faults on every run.
//! 2. **Off by default, zero cost when off.** With no plan the executor
//!    holds no injector and the probe call sites are skipped entirely —
//!    the global [`probes`] counter (incremented only inside
//!    [`FaultInjector::probe`]) stays at zero across fault-free runs,
//!    and `tests/fault_hotpath.rs` asserts exactly that.
//! 3. **Typed payloads.** An injected panic carries an [`InjectedFault`]
//!    value via `std::panic::panic_any`, so the pool's catch-unwind can
//!    *downcast* and classify it as retryable; a genuine bug's panic
//!    payload (a `&str`/`String` from `panic!`/`assert!`) never
//!    downcasts to `InjectedFault` and is reported fatal, never retried.
//!
//! What each [`FaultKind`] does at the probe:
//!
//! * [`FaultKind::TransientError`] — returns `Err(InjectedFault)`; the
//!   site maps it to `DistError::Transient` and the stage retry loop
//!   replays the stage from its immutable lineage inputs.
//! * [`FaultKind::PanicJob`] — `panic_any(InjectedFault)`; exercises the
//!   pool's catch-unwind path end to end (classified retryable).
//! * [`FaultKind::Slow`] — sleeps `delay_ms` then succeeds; a straggler,
//!   not a failure. Counted in [`FaultInjector::injected`] but never
//!   retried (the result is still correct, just late).

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Where in a stage's lifecycle a fault can fire. Every instrumented
/// site in `dist/exec.rs` (and the grace-spill loop) probes exactly one
/// of these.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum InjectionPoint {
    /// Entry of a worker's join shard, before the build side is hashed.
    JoinBuild,
    /// Immediately before the probe phase (in-memory or grace passes).
    JoinProbe,
    /// A worker's part in the two-phase Σ exchange/final merge.
    SigmaMerge,
    /// A worker's send leg of a reshuffle or broadcast.
    ShuffleSend,
    /// Before a grace run is written to spill scratch.
    SpillWrite,
    /// Before spilled runs are streamed back.
    SpillRead,
    /// Before a delta-maintained stage serves reused shards or applies an
    /// insert-only suffix from the previous tape (`dist::delta`). Probed
    /// once per worker, inside the stage retry loop — reuse/append steps
    /// are pure functions of immutable inputs, so a retried delta stage
    /// replays bitwise like any other.
    DeltaApply,
}

impl InjectionPoint {
    /// Number of variants (sizing per-`(point, worker)` counter tables).
    pub const COUNT: usize = 7;

    /// All variants, in `idx` order.
    pub const ALL: [InjectionPoint; InjectionPoint::COUNT] = [
        InjectionPoint::JoinBuild,
        InjectionPoint::JoinProbe,
        InjectionPoint::SigmaMerge,
        InjectionPoint::ShuffleSend,
        InjectionPoint::SpillWrite,
        InjectionPoint::SpillRead,
        InjectionPoint::DeltaApply,
    ];

    /// Dense index of this point, `0..COUNT`.
    pub fn idx(self) -> usize {
        match self {
            InjectionPoint::JoinBuild => 0,
            InjectionPoint::JoinProbe => 1,
            InjectionPoint::SigmaMerge => 2,
            InjectionPoint::ShuffleSend => 3,
            InjectionPoint::SpillWrite => 4,
            InjectionPoint::SpillRead => 5,
            InjectionPoint::DeltaApply => 6,
        }
    }
}

impl fmt::Display for InjectionPoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            InjectionPoint::JoinBuild => "JoinBuild",
            InjectionPoint::JoinProbe => "JoinProbe",
            InjectionPoint::SigmaMerge => "SigmaMerge",
            InjectionPoint::ShuffleSend => "ShuffleSend",
            InjectionPoint::SpillWrite => "SpillWrite",
            InjectionPoint::SpillRead => "SpillRead",
            InjectionPoint::DeltaApply => "DeltaApply",
        };
        f.write_str(s)
    }
}

/// How an injected fault manifests at its probe site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The job panics with an [`InjectedFault`] payload
    /// (`std::panic::panic_any`) — exercises the pool's catch-unwind
    /// classification. Retryable.
    PanicJob,
    /// The probe returns `Err(InjectedFault)` — a transient error (failed
    /// spill I/O, dropped exchange, …). Retryable.
    TransientError,
    /// The probe sleeps `delay_ms` milliseconds, then succeeds — a
    /// straggler. Counted, never retried.
    Slow {
        /// Injected delay in milliseconds.
        delay_ms: u64,
    },
}

/// One scripted fault: fire `kind` at `point` on `worker`, starting at
/// the `occurrence`-th probe (1-based) of that `(point, worker)` site,
/// for `times` consecutive probes.
#[derive(Clone, Copy, Debug)]
pub struct FaultSpec {
    pub point: InjectionPoint,
    pub worker: usize,
    /// 1-based first occurrence to hit. `occurrence = 1` fires on the
    /// very first probe of the site.
    pub occurrence: u64,
    /// How many consecutive occurrences fire (`u64::MAX` = permanent —
    /// the fault survives every retry, which is how tests drive
    /// `DistError::StageFailed`).
    pub times: u64,
    pub kind: FaultKind,
}

/// A deterministic fault script: explicit [`FaultSpec`]s plus an
/// optional seeded background rate of transient errors. Immutable once
/// handed to `ClusterConfig::with_fault_plan`; shared by `Arc`.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    specs: Vec<FaultSpec>,
    seed: u64,
    /// Probability in `[0, 1]` that any given probe fires a
    /// `TransientError`, decided by hashing
    /// `(seed, point, worker, occurrence)` — reproducible per seed.
    rate: f64,
}

impl FaultPlan {
    /// An empty plan (no faults — useful as a base for the builders).
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// A plan with no explicit specs that fires `TransientError` on a
    /// `rate` fraction of probes, deterministically per `seed`.
    pub fn seeded(seed: u64, rate: f64) -> FaultPlan {
        FaultPlan {
            specs: Vec::new(),
            seed,
            rate: rate.clamp(0.0, 1.0),
        }
    }

    /// Fire `kind` once: at the `occurrence`-th probe (1-based) of
    /// `(point, worker)`.
    pub fn once(
        self,
        point: InjectionPoint,
        worker: usize,
        occurrence: u64,
        kind: FaultKind,
    ) -> FaultPlan {
        self.during(point, worker, occurrence, 1, kind)
    }

    /// Fire `kind` on `times` consecutive probes of `(point, worker)`,
    /// starting at the `occurrence`-th.
    pub fn during(
        mut self,
        point: InjectionPoint,
        worker: usize,
        occurrence: u64,
        times: u64,
        kind: FaultKind,
    ) -> FaultPlan {
        self.specs.push(FaultSpec {
            point,
            worker,
            occurrence: occurrence.max(1),
            times: times.max(1),
            kind,
        });
        self
    }

    /// Fire `kind` on *every* probe of `(point, worker)` — a permanent
    /// fault that survives all retries (drives `StageFailed` in tests).
    pub fn always(self, point: InjectionPoint, worker: usize, kind: FaultKind) -> FaultPlan {
        self.during(point, worker, 1, u64::MAX, kind)
    }

    /// The scripted specs (test introspection).
    pub fn specs(&self) -> &[FaultSpec] {
        &self.specs
    }
}

/// The typed payload of an injected fault: which site fired, on which
/// worker, at which occurrence. Carried through `Err` returns *and*
/// through injected panics (`panic_any`), so the pool's catch-unwind
/// downcast can tell scripted faults from genuine bugs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct InjectedFault {
    pub point: InjectionPoint,
    pub worker: usize,
    /// 1-based occurrence of the probe that fired.
    pub occurrence: u64,
}

impl fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "injected fault at {} on worker {} (occurrence {})",
            self.point, self.worker, self.occurrence
        )
    }
}

/// Global count of [`FaultInjector::probe`] calls across the process —
/// the *only* code path that increments it. A fault-free configuration
/// (`fault_plan: None`) constructs no injector and therefore never
/// probes; `tests/fault_hotpath.rs` pins that to zero.
static PROBES: AtomicU64 = AtomicU64::new(0);

/// Process-wide probe count (see [`PROBES`]). Monotonic; only ever
/// incremented by [`FaultInjector::probe`].
pub fn probes() -> u64 {
    PROBES.load(Ordering::Relaxed)
}

/// The live injector the executor threads through a run: the shared
/// plan plus per-`(point, worker)` occurrence counters. One injector
/// per *execution*, so occurrence coordinates restart at 1 for each
/// query/step — scripts compose with the retry loop predictably
/// (a retried stage re-probes the same site at the *next* occurrence).
#[derive(Debug)]
pub struct FaultInjector {
    plan: Arc<FaultPlan>,
    workers: usize,
    /// `InjectionPoint::COUNT × workers` occurrence counters, indexed
    /// `point.idx() * workers + worker`.
    counters: Vec<AtomicU64>,
    injected: AtomicU64,
}

impl FaultInjector {
    pub fn new(plan: Arc<FaultPlan>, workers: usize) -> FaultInjector {
        let workers = workers.max(1);
        let counters = (0..InjectionPoint::COUNT * workers)
            .map(|_| AtomicU64::new(0))
            .collect();
        FaultInjector {
            plan,
            workers,
            counters,
            injected: AtomicU64::new(0),
        }
    }

    /// Faults actually fired by this injector (all kinds, including
    /// `Slow`). Feeds `ExecStats::faults_injected`.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// One instrumented site announcing "worker `wi` is about to do
    /// `point`". Returns `Ok(())` (possibly after an injected delay),
    /// `Err(InjectedFault)` for a transient error, or panics with an
    /// [`InjectedFault`] payload for [`FaultKind::PanicJob`].
    pub fn probe(&self, point: InjectionPoint, wi: usize) -> Result<(), InjectedFault> {
        PROBES.fetch_add(1, Ordering::Relaxed);
        let wi = wi.min(self.workers - 1);
        let slot = point.idx() * self.workers + wi;
        let occ = self.counters[slot].fetch_add(1, Ordering::Relaxed) + 1;
        for spec in &self.plan.specs {
            if spec.point == point
                && spec.worker == wi
                && occ >= spec.occurrence
                && occ - spec.occurrence < spec.times
            {
                return self.fire(spec.kind, point, wi, occ);
            }
        }
        if self.plan.rate > 0.0 {
            let h = mix(self.plan.seed, point.idx() as u64, wi as u64, occ);
            // Map the hash to [0, 1); compare against the rate.
            if (h >> 11) as f64 / (1u64 << 53) as f64 < self.plan.rate {
                return self.fire(FaultKind::TransientError, point, wi, occ);
            }
        }
        Ok(())
    }

    fn fire(
        &self,
        kind: FaultKind,
        point: InjectionPoint,
        worker: usize,
        occurrence: u64,
    ) -> Result<(), InjectedFault> {
        self.injected.fetch_add(1, Ordering::Relaxed);
        let fault = InjectedFault {
            point,
            worker,
            occurrence,
        };
        match kind {
            FaultKind::Slow { delay_ms } => {
                std::thread::sleep(std::time::Duration::from_millis(delay_ms));
                Ok(())
            }
            FaultKind::TransientError => Err(fault),
            FaultKind::PanicJob => std::panic::panic_any(fault),
        }
    }
}

/// splitmix64-style avalanche over the fault coordinates — the same
/// `(seed, point, worker, occurrence)` always hashes the same, so
/// seeded-rate plans are exactly reproducible.
fn mix(seed: u64, point: u64, worker: u64, occ: u64) -> u64 {
    let mut z = seed
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add(point.wrapping_mul(0xbf58_476d_1ce4_e5b9))
        .wrapping_add(worker.wrapping_mul(0x94d0_49bb_1331_11eb))
        .wrapping_add(occ);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_exactly_at_scripted_coordinates() {
        let plan = Arc::new(FaultPlan::new().once(
            InjectionPoint::JoinBuild,
            1,
            3,
            FaultKind::TransientError,
        ));
        let inj = FaultInjector::new(plan, 2);
        // Worker 0 never fires; worker 1 fires only on its 3rd probe.
        for _ in 0..5 {
            assert!(inj.probe(InjectionPoint::JoinBuild, 0).is_ok());
        }
        assert!(inj.probe(InjectionPoint::JoinBuild, 1).is_ok());
        assert!(inj.probe(InjectionPoint::JoinBuild, 1).is_ok());
        let f = inj.probe(InjectionPoint::JoinBuild, 1).unwrap_err();
        assert_eq!(f.point, InjectionPoint::JoinBuild);
        assert_eq!(f.worker, 1);
        assert_eq!(f.occurrence, 3);
        assert!(inj.probe(InjectionPoint::JoinBuild, 1).is_ok());
        // Other points on the same worker are independent counters.
        assert!(inj.probe(InjectionPoint::SigmaMerge, 1).is_ok());
        assert_eq!(inj.injected(), 1);
    }

    #[test]
    fn during_and_always_windows() {
        let plan = Arc::new(
            FaultPlan::new()
                .during(InjectionPoint::SpillWrite, 0, 2, 2, FaultKind::TransientError)
                .always(InjectionPoint::SpillRead, 0, FaultKind::TransientError),
        );
        let inj = FaultInjector::new(plan, 1);
        assert!(inj.probe(InjectionPoint::SpillWrite, 0).is_ok());
        assert!(inj.probe(InjectionPoint::SpillWrite, 0).is_err());
        assert!(inj.probe(InjectionPoint::SpillWrite, 0).is_err());
        assert!(inj.probe(InjectionPoint::SpillWrite, 0).is_ok());
        for _ in 0..4 {
            assert!(inj.probe(InjectionPoint::SpillRead, 0).is_err());
        }
    }

    #[test]
    fn panic_kind_carries_downcastable_payload() {
        let plan = Arc::new(FaultPlan::new().once(
            InjectionPoint::JoinProbe,
            0,
            1,
            FaultKind::PanicJob,
        ));
        let inj = FaultInjector::new(plan, 1);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = inj.probe(InjectionPoint::JoinProbe, 0);
        }));
        let payload = r.unwrap_err();
        let f = payload
            .downcast_ref::<InjectedFault>()
            .expect("injected panic payload must downcast to InjectedFault");
        assert_eq!(f.point, InjectionPoint::JoinProbe);
        assert_eq!(f.occurrence, 1);
        assert_eq!(inj.injected(), 1);
    }

    #[test]
    fn slow_counts_but_succeeds() {
        let plan = Arc::new(FaultPlan::new().once(
            InjectionPoint::ShuffleSend,
            0,
            1,
            FaultKind::Slow { delay_ms: 1 },
        ));
        let inj = FaultInjector::new(plan, 1);
        assert!(inj.probe(InjectionPoint::ShuffleSend, 0).is_ok());
        assert_eq!(inj.injected(), 1);
    }

    #[test]
    fn seeded_rate_is_reproducible_and_seed_sensitive() {
        let fired = |seed: u64| -> Vec<u64> {
            let inj = FaultInjector::new(Arc::new(FaultPlan::seeded(seed, 0.25)), 1);
            (1..=64u64)
                .filter(|_| inj.probe(InjectionPoint::JoinBuild, 0).is_err())
                .collect()
        };
        let a = fired(7);
        let b = fired(7);
        assert_eq!(a, b, "same seed, same fault set");
        assert!(!a.is_empty(), "a 25% rate over 64 probes should fire");
        assert!(a.len() < 64, "and should not fire on every probe");
        let c = fired(8);
        assert_ne!(a, c, "different seed, different fault set");
    }

    #[test]
    fn probes_counter_is_monotonic_and_probe_only() {
        let before = probes();
        let inj = FaultInjector::new(Arc::new(FaultPlan::new()), 2);
        // Construction alone must not count.
        assert_eq!(probes(), before);
        inj.probe(InjectionPoint::JoinBuild, 0).unwrap();
        inj.probe(InjectionPoint::SpillRead, 1).unwrap();
        assert_eq!(probes(), before + 2);
    }

    #[test]
    fn injection_point_idx_matches_all_order() {
        for (i, p) in InjectionPoint::ALL.iter().enumerate() {
            assert_eq!(p.idx(), i);
        }
        assert_eq!(InjectionPoint::ALL.len(), InjectionPoint::COUNT);
    }
}
