//! Incremental (delta) maintenance of a previously executed tape.
//!
//! When a `Session` frame re-collects after catalog inserts/deletes, it
//! does not evaluate the query from scratch: it hands the executor the
//! previous [`DistTape`](super::DistTape) plus a per-slot change
//! descriptor ([`SlotDelta`]), and the node loop consults [`plan_node`]
//! to decide, per stage, one of three *bitwise-safe* mechanisms:
//!
//! 1. **Clean-subtree reuse** — every transitive input of the node is
//!    unchanged, so the previous run's output shards are served verbatim
//!    (`Arc` clones; kernel-agnostic, sound because evaluation is
//!    deterministic). Counted in `ExecStats::shards_reused`.
//! 2. **Insert-only append** — exactly one input grew by a suffix of new
//!    tuples. σ is per-tuple and order-preserving, ⋈ probes the appended
//!    side in order against a build table over the clean side, and Σ is
//!    an in-order left fold — so replaying *only the suffix* into a clone
//!    of the previous output reproduces the full recompute bit for bit
//!    (same float ops, same order, same emission order). The
//!    [`plan_node`] preconditions below exist purely to guarantee that
//!    equivalence (e.g. the ⋈ build side must be the clean side in both
//!    runs).
//! 3. **Dirty recompute** — anything else falls through to the ordinary
//!    stage execution over the merged heads, trivially bitwise.
//!
//! Deletes (and any shape the append preconditions reject) mark the slot
//! [`SlotDelta::Dirty`], which dirties the nodes it reaches; untouched
//! sibling subtrees still reuse. The plan-level policy gate
//! ([`crate::plan::delta_gate`]) sits *above* this module: it decides
//! whether a frame may take the delta path at all, while this module
//! guarantees that whatever path is taken, the bits match.

use anyhow::{bail, Result};

use super::exec::{join_output_part, plan_join, preserved_positions, DistTape, JoinStrategy};
use super::partition::{PartitionedRelation, Partitioning};
use super::ClusterConfig;
use crate::kernels::{AggKernel, BinaryKernel, KernelBackend, UnaryKernel};
use crate::ra::eval::subkey;
use crate::ra::expr::{Node, NodeId, Op};
use crate::ra::funcs::{JoinPred, KeyPred, KeyProj, KeyProj2};
use crate::ra::{Key, Relation};
use crate::util::FxHashMap;

/// How one input slot changed relative to the tape being maintained.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SlotDelta {
    /// The slot's shards are the same handles the previous run saw.
    Clean,
    /// The slot grew by an insert-only suffix: shard `wi` of the current
    /// input starts with the `prev_rows[wi]` tuples the previous run saw,
    /// in the same order, followed only by new tuples.
    Appended { prev_rows: Vec<usize> },
    /// Anything else (deletes, reordered rows, replicated-layout
    /// updates): nodes reached by this slot recompute from the merged
    /// head.
    Dirty,
}

/// The previous execution a delta run maintains: its full tape plus the
/// per-slot change descriptors. The tape must come from the same query
/// under the same `ClusterConfig` (same worker count) — the session
/// frame guarantees this; [`plan_node`] degrades to full recompute if it
/// does not hold.
#[derive(Clone)]
pub struct DeltaCtx {
    pub prev: DistTape,
    pub slots: Vec<SlotDelta>,
}

/// Change status of one node's *output* in the current delta run,
/// derived bottom-up by [`plan_node`]. `Appended::prev_rows` carries the
/// node's previous per-shard output row counts — the prefix a downstream
/// append stage may skip.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum NodeStatus {
    Clean,
    Appended { prev_rows: Vec<usize> },
    Dirty,
}

/// How the executor should produce one node of a delta run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum DeltaStep {
    /// Ordinary stage execution over the (merged) current inputs.
    Compute,
    /// Serve the previous run's output shards verbatim.
    Reuse,
    /// σ over only the appended suffix, into a clone of the previous
    /// output.
    SelectAppend,
    /// Probe only the appended side's suffix against a build table over
    /// the clean side, into a clone of the previous output.
    JoinAppend { appended_left: bool },
    /// Σ-fold only the appended suffix into a clone of the previous
    /// output (no exchange: the input is already hash-placed on a group
    /// key prefix).
    AggFold,
}

/// Derive `(output status, execution step)` for node `id`, given the
/// statuses of its children and the current-run child outputs in `rels`.
///
/// Every append precondition here is a *bitwise* precondition: it holds
/// exactly when replaying the suffix reproduces what a fresh stage over
/// the merged inputs would compute, bit for bit — including which side a
/// ⋈ would build on, which partitioning the output would carry, and
/// whether a fresh σ/⋈ would have run a cross-shard disjointness check
/// the append path cannot replay. When in doubt the answer is
/// `(Dirty, Compute)`: slower, never wrong.
pub(crate) fn plan_node(
    id: NodeId,
    node: &Node,
    statuses: &[NodeStatus],
    d: &DeltaCtx,
    rels: &[PartitionedRelation],
    cfg: &ClusterConfig,
) -> (NodeStatus, DeltaStep) {
    let w = cfg.workers;
    let prev = match d.prev.rels.get(id) {
        Some(p) if p.workers() == w => p,
        _ => return (NodeStatus::Dirty, DeltaStep::Compute),
    };
    let prev_out_rows = || prev.shards.iter().map(|s| s.len()).collect::<Vec<usize>>();
    // The appended child's current output must really extend its previous
    // output (defensive: the frame constructs `prev_rows` this way).
    let extends = |input: &PartitionedRelation, prev_rows: &[usize]| {
        input.workers() == w
            && prev_rows.len() == w
            && (0..w).all(|wi| input.shards[wi].len() >= prev_rows[wi])
    };

    match &node.op {
        Op::Scan { slot, .. } => {
            let st = match d.slots.get(*slot) {
                Some(SlotDelta::Clean) => NodeStatus::Clean,
                Some(SlotDelta::Appended { prev_rows }) => NodeStatus::Appended {
                    prev_rows: prev_rows.clone(),
                },
                _ => NodeStatus::Dirty,
            };
            (st, DeltaStep::Compute)
        }
        Op::Const { .. } => (NodeStatus::Clean, DeltaStep::Compute),
        Op::Select { proj, .. } => {
            let c = node.children[0];
            match &statuses[c] {
                NodeStatus::Clean => (NodeStatus::Clean, DeltaStep::Reuse),
                NodeStatus::Appended { prev_rows } => {
                    let input = &rels[c];
                    // A fresh σ keeps Hash placement only when the
                    // projection preserves the partition key; otherwise
                    // the output is Arbitrary and, for a non-injective
                    // projection, the fresh path runs a cross-shard
                    // disjointness check the suffix replay cannot.
                    let ok = !input.is_replicated()
                        && extends(input, prev_rows)
                        && match &input.part {
                            Partitioning::Hash(comps) => {
                                preserved_positions(comps, proj).is_some()
                                    || proj.is_injective(input.key_arity())
                            }
                            Partitioning::Arbitrary => proj.is_injective(input.key_arity()),
                            Partitioning::Replicated => false,
                            // A delta batch shifts key frequencies, so the
                            // hot-key annotation is stale; the session
                            // frame already dirties skew-partitioned slots
                            // (bitwise full recompute) — refuse defensively
                            // if one ever reaches this gate.
                            Partitioning::SkewHash { .. } => false,
                        };
                    if ok {
                        (
                            NodeStatus::Appended {
                                prev_rows: prev_out_rows(),
                            },
                            DeltaStep::SelectAppend,
                        )
                    } else {
                        (NodeStatus::Dirty, DeltaStep::Compute)
                    }
                }
                NodeStatus::Dirty => (NodeStatus::Dirty, DeltaStep::Compute),
            }
        }
        Op::Join { pred, proj, .. } => {
            let (l, r) = (node.children[0], node.children[1]);
            match (&statuses[l], &statuses[r]) {
                (NodeStatus::Clean, NodeStatus::Clean) => (NodeStatus::Clean, DeltaStep::Reuse),
                (NodeStatus::Appended { prev_rows }, NodeStatus::Clean)
                | (NodeStatus::Clean, NodeStatus::Appended { prev_rows }) => {
                    let appended_left = matches!(statuses[l], NodeStatus::Appended { .. });
                    let (lrel, rrel) = (&rels[l], &rels[r]);
                    let shape_ok = !pred.eqs.is_empty()
                        && pred.l_lits.is_empty()
                        && pred.r_lits.is_empty()
                        && cfg.budget.is_none()
                        && !lrel.is_replicated()
                        && !rrel.is_replicated()
                        && lrel.workers() == w
                        && rrel.workers() == w
                        && extends(if appended_left { lrel } else { rrel }, prev_rows)
                        && matches!(
                            plan_join(lrel, rrel, pred, &cfg.net, w).strategy,
                            JoinStrategy::Local
                        );
                    // A fresh Arbitrary-partitioned ⋈ output runs the
                    // cross-shard disjointness check (w > 1) the suffix
                    // replay cannot replicate.
                    let part_ok = w <= 1
                        || !matches!(
                            join_output_part(&lrel.part, &rrel.part, proj),
                            Partitioning::Arbitrary
                        );
                    // `hash_join` builds on the right side iff
                    // `right.len() <= left.len()`. The suffix replay
                    // always builds on the clean side, so it is bitwise
                    // only when the fresh run — previous *and* current —
                    // would have made the same choice on every shard.
                    let build_ok = if appended_left {
                        (0..w).all(|wi| rrel.shards[wi].len() <= prev_rows[wi])
                    } else {
                        (0..w).all(|wi| prev_rows[wi] > lrel.shards[wi].len())
                    };
                    if shape_ok && part_ok && build_ok {
                        (
                            NodeStatus::Appended {
                                prev_rows: prev_out_rows(),
                            },
                            DeltaStep::JoinAppend { appended_left },
                        )
                    } else {
                        (NodeStatus::Dirty, DeltaStep::Compute)
                    }
                }
                _ => (NodeStatus::Dirty, DeltaStep::Compute),
            }
        }
        Op::Agg { grp, agg } => {
            let c = node.children[0];
            match &statuses[c] {
                NodeStatus::Clean => (NodeStatus::Clean, DeltaStep::Reuse),
                NodeStatus::Appended { prev_rows } => {
                    let input = &rels[c];
                    // Fold-append only on the no-exchange fast path (the
                    // input is already placed on a preserved group-key
                    // prefix) and only for Sum — the policy gate refuses
                    // non-Sum kernels on touched paths anyway, and an
                    // exchange would interleave suffix tuples with base
                    // tuples, breaking the fold-order equivalence.
                    let ok = *agg == AggKernel::Sum
                        && !input.is_replicated()
                        && extends(input, prev_rows)
                        && matches!(&input.part, Partitioning::Hash(comps)
                            if preserved_positions(comps, grp).is_some());
                    if ok {
                        // Existing groups' values mutate in place, so the
                        // output is not a prefix extension: downstream
                        // stages recompute.
                        (NodeStatus::Dirty, DeltaStep::AggFold)
                    } else {
                        (NodeStatus::Dirty, DeltaStep::Compute)
                    }
                }
                NodeStatus::Dirty => (NodeStatus::Dirty, DeltaStep::Compute),
            }
        }
        Op::AddQ => {
            let (l, r) = (node.children[0], node.children[1]);
            match (&statuses[l], &statuses[r]) {
                (NodeStatus::Clean, NodeStatus::Clean) => (NodeStatus::Clean, DeltaStep::Reuse),
                _ => (NodeStatus::Dirty, DeltaStep::Compute),
            }
        }
    }
}

/// σ over only `input.pairs()[from..]`, into a clone of the previous
/// output shard. Mirrors `ra::eval::apply_select` tuple-for-tuple
/// (including the injectivity error) so the result is bitwise what a
/// fresh σ over the whole shard would produce.
pub(crate) fn select_append_shard(
    prev_out: &Relation,
    input: &Relation,
    from: usize,
    pred: &KeyPred,
    proj: &KeyProj,
    kernel: &UnaryKernel,
    backend: &dyn KernelBackend,
) -> Result<Relation> {
    let mut out = prev_out.clone();
    for (k, v) in &input.pairs()[from..] {
        if !pred.matches(k) {
            continue;
        }
        let nk = proj.apply(k);
        let nv = backend.unary(kernel, k, v);
        if out.contains(&nk) {
            bail!("σ projection {proj} is not injective: key {nk} collides");
        }
        out.insert(nk, nv);
    }
    Ok(out)
}

/// ⋈ of only the appended side's suffix against the clean side, into a
/// clone of the previous output shard. Builds over the clean side (the
/// planner guaranteed a fresh `ra::eval::hash_join` would too, in both
/// runs) and probes the suffix in order, so matches emit in exactly the
/// order the fresh run would append them. Only pure equi-joins reach
/// this path (no literal prefilters).
#[allow(clippy::too_many_arguments)]
pub(crate) fn join_append_shard(
    prev_out: &Relation,
    clean: &Relation,
    appended: &Relation,
    from: usize,
    appended_left: bool,
    pred: &JoinPred,
    proj: &KeyProj2,
    kernel: &BinaryKernel,
    backend: &dyn KernelBackend,
) -> Result<Relation> {
    let mut out = prev_out.clone();
    let (ccomps, pcomps) = if appended_left {
        (pred.right_comps(), pred.left_comps())
    } else {
        (pred.left_comps(), pred.right_comps())
    };
    let mut table: FxHashMap<Key, Vec<u32>> = FxHashMap::default();
    for (idx, (ck, _)) in clean.iter().enumerate() {
        table.entry(subkey(ck, &ccomps)).or_default().push(idx as u32);
    }
    for (pk, pv) in &appended.pairs()[from..] {
        let jk = subkey(pk, &pcomps);
        if let Some(matches) = table.get(&jk) {
            for &ci in matches {
                let (ck, cv) = &clean.pairs()[ci as usize];
                let (lk, lv, rk, rv) = if appended_left {
                    (pk, pv, ck, cv)
                } else {
                    (ck, cv, pk, pv)
                };
                let nk = proj.apply(lk, rk);
                let nv = backend.binary(kernel, &nk, lv, rv);
                if out.contains(&nk) {
                    bail!("⋈ projection {proj} is not injective on matches: key {nk} collides (add a Σ to aggregate)");
                }
                out.insert(nk, nv);
            }
        }
    }
    Ok(out)
}

/// Σ-fold of only `input.pairs()[from..]` into a clone of the previous
/// output shard. `ra::eval::aggregate` is an in-order left fold, so
/// folding the suffix onto the prefix's result replays exactly the float
/// ops (and group first-occurrence order) of a fresh fold over the whole
/// shard.
pub(crate) fn agg_fold_shard(
    prev_out: &Relation,
    input: &Relation,
    from: usize,
    grp: &KeyProj,
    agg: &AggKernel,
) -> Relation {
    let mut out = prev_out.clone();
    for (k, v) in &input.pairs()[from..] {
        out.merge(grp.apply(k), v.clone(), |acc, x| agg.combine(acc, x));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{NativeBackend, UnaryKernel};
    use crate::ra::eval::{aggregate, apply_select, hash_join};
    use crate::ra::expr::QueryBuilder;
    use crate::ra::funcs::Sel2;
    use crate::ra::Chunk;

    fn rel(range: std::ops::Range<i64>) -> Relation {
        let mut r = Relation::new();
        for i in range {
            r.insert(
                Key::k2(i, i % 3),
                Chunk::from_vec(1, 2, vec![i as f32 + 0.5, i as f32 * 0.25]),
            );
        }
        r
    }

    fn assert_bitwise(a: &Relation, b: &Relation) {
        assert_eq!(a.len(), b.len(), "row counts differ");
        for ((ka, va), (kb, vb)) in a.iter().zip(b.iter()) {
            assert_eq!(ka, kb, "key order differs");
            let ba: Vec<u32> = va.data().iter().map(|x| x.to_bits()).collect();
            let bb: Vec<u32> = vb.data().iter().map(|x| x.to_bits()).collect();
            assert_eq!(ba, bb, "values differ at key {ka}");
        }
    }

    #[test]
    fn select_append_matches_full_reevaluation() {
        let backend = NativeBackend;
        let base = rel(0..6);
        let merged = rel(0..9);
        let pred = KeyPred::always();
        let proj = KeyProj::identity(2);
        let kernel = UnaryKernel::Scale(0.5);
        let prev = apply_select(&base, &pred, &proj, &kernel, &backend).unwrap();
        let inc =
            select_append_shard(&prev, &merged, base.len(), &pred, &proj, &kernel, &backend)
                .unwrap();
        let full = apply_select(&merged, &pred, &proj, &kernel, &backend).unwrap();
        assert_bitwise(&inc, &full);
    }

    #[test]
    fn join_append_matches_full_reevaluation_both_sides() {
        let backend = NativeBackend;
        let base = rel(0..6);
        let merged = rel(0..9);
        let pred = JoinPred::on(vec![(0, 0)]);
        let proj = KeyProj2(vec![Sel2::L(0), Sel2::L(1)]);
        let kernel = BinaryKernel::Mul;

        // Appended left: clean right is smaller in both runs → the fresh
        // join builds right both times.
        let clean_r = rel(0..4);
        let prev = hash_join(&base, &clean_r, &pred, &proj, &kernel, &backend).unwrap();
        let inc = join_append_shard(
            &prev, &clean_r, &merged, base.len(), true, &pred, &proj, &kernel, &backend,
        )
        .unwrap();
        let full = hash_join(&merged, &clean_r, &pred, &proj, &kernel, &backend).unwrap();
        assert_bitwise(&inc, &full);

        // Appended right: clean left is strictly smaller than the previous
        // right → the fresh join builds left both times.
        let clean_l = rel(0..3);
        let proj_r = KeyProj2(vec![Sel2::R(0), Sel2::R(1)]);
        let prev = hash_join(&clean_l, &base, &pred, &proj_r, &kernel, &backend).unwrap();
        let inc = join_append_shard(
            &prev, &clean_l, &merged, base.len(), false, &pred, &proj_r, &kernel, &backend,
        )
        .unwrap();
        let full = hash_join(&clean_l, &merged, &pred, &proj_r, &kernel, &backend).unwrap();
        assert_bitwise(&inc, &full);
    }

    #[test]
    fn agg_fold_matches_full_reevaluation() {
        let base = rel(0..6);
        let merged = rel(0..9);
        let grp = KeyProj::take(&[1]);
        let prev = aggregate(&base, &grp, &AggKernel::Sum);
        let inc = agg_fold_shard(&prev, &merged, base.len(), &grp, &AggKernel::Sum);
        let full = aggregate(&merged, &grp, &AggKernel::Sum);
        assert_bitwise(&inc, &full);
    }

    #[test]
    fn plan_node_reuses_clean_appends_suffixes_and_degrades() {
        let backend = NativeBackend;
        let w = 2;
        let cfg = ClusterConfig::new(w);
        let mut qb = QueryBuilder::new();
        let r = qb.scan(0, "R");
        let s = qb.scan(1, "S");
        let j = qb.join(
            JoinPred::on(vec![(0, 0)]),
            KeyProj2(vec![Sel2::L(0), Sel2::L(1)]),
            BinaryKernel::Mul,
            r,
            s,
        );
        let a = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, j);
        let q = qb.finish(a);

        // Base run: R has 8 rows, S (clean) 4 — per shard the clean side
        // stays the build side after the append.
        let r_base = PartitionedRelation::hash_partition(&rel(0..8), &[0], w);
        let r_merged = PartitionedRelation::hash_partition(&rel(0..12), &[0], w);
        let s_pr = PartitionedRelation::hash_partition(&rel(0..4), &[0], w);
        let pred = JoinPred::on(vec![(0, 0)]);
        let proj = KeyProj2(vec![Sel2::L(0), Sel2::L(1)]);
        let join_of = |l: &PartitionedRelation| {
            let shards: Vec<Relation> = l
                .shards
                .iter()
                .zip(&s_pr.shards)
                .map(|(ls, rs)| {
                    hash_join(ls, rs, &pred, &proj, &BinaryKernel::Mul, &backend).unwrap()
                })
                .collect();
            PartitionedRelation::from_shards(shards, Partitioning::Hash(vec![0]))
        };
        let prev_join = join_of(&r_base);
        let cur_join = join_of(&r_merged);
        let prev_agg = PartitionedRelation::from_shards(
            prev_join
                .shards
                .iter()
                .map(|sh| aggregate(sh, &KeyProj::take(&[0]), &AggKernel::Sum))
                .collect(),
            Partitioning::Hash(vec![0]),
        );

        let prev_rows: Vec<usize> = r_base.shards.iter().map(|s| s.len()).collect();
        let d = DeltaCtx {
            prev: DistTape {
                rels: vec![
                    r_base.clone(),
                    s_pr.clone(),
                    prev_join.clone(),
                    prev_agg.clone(),
                ],
            },
            slots: vec![
                SlotDelta::Appended {
                    prev_rows: prev_rows.clone(),
                },
                SlotDelta::Clean,
            ],
        };

        let rels = vec![r_merged.clone(), s_pr.clone(), cur_join.clone()];
        let mut statuses = Vec::new();
        let (st, step) = plan_node(0, q.node(0), &statuses, &d, &rels, &cfg);
        assert_eq!(step, DeltaStep::Compute);
        assert_eq!(
            st,
            NodeStatus::Appended {
                prev_rows: prev_rows.clone()
            }
        );
        statuses.push(st);
        let (st, step) = plan_node(1, q.node(1), &statuses, &d, &rels, &cfg);
        assert_eq!((st.clone(), step), (NodeStatus::Clean, DeltaStep::Compute));
        statuses.push(st);
        let (st, step) = plan_node(2, q.node(2), &statuses, &d, &rels, &cfg);
        assert_eq!(step, DeltaStep::JoinAppend { appended_left: true });
        assert_eq!(
            st,
            NodeStatus::Appended {
                prev_rows: prev_join.shards.iter().map(|s| s.len()).collect()
            }
        );
        statuses.push(st);
        let (st, step) = plan_node(3, q.node(3), &statuses, &d, &rels, &cfg);
        assert_eq!((st, step), (NodeStatus::Dirty, DeltaStep::AggFold));

        // All-clean slots: every compute node reuses.
        let d_clean = DeltaCtx {
            prev: d.prev.clone(),
            slots: vec![SlotDelta::Clean, SlotDelta::Clean],
        };
        let rels_clean = vec![r_base.clone(), s_pr.clone(), prev_join.clone()];
        let mut sts = Vec::new();
        for id in 0..q.len() {
            let (st, step) = plan_node(id, q.node(id), &sts, &d_clean, &rels_clean, &cfg);
            if id >= 2 {
                assert_eq!(step, DeltaStep::Reuse);
                assert_eq!(st, NodeStatus::Clean);
            }
            sts.push(st);
        }

        // A dirty slot dirties everything it reaches, and a spill budget
        // disables the join append.
        let d_dirty = DeltaCtx {
            prev: d.prev.clone(),
            slots: vec![SlotDelta::Dirty, SlotDelta::Clean],
        };
        let mut sts = Vec::new();
        for id in 0..q.len() {
            let (st, step) = plan_node(id, q.node(id), &sts, &d_dirty, &rels, &cfg);
            if id >= 2 {
                assert_eq!(step, DeltaStep::Compute);
                assert_eq!(st, NodeStatus::Dirty);
            }
            sts.push(st);
        }
        let cfg_budget = ClusterConfig::new(w).with_budget(1 << 20);
        let sts = vec![
            NodeStatus::Appended {
                prev_rows: prev_rows.clone(),
            },
            NodeStatus::Clean,
        ];
        let (st, step) = plan_node(2, q.node(2), &sts, &d, &rels, &cfg_budget);
        assert_eq!((st, step), (NodeStatus::Dirty, DeltaStep::Compute));
    }
}
