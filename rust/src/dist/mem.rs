//! Per-worker memory accounting: the budget policies, the grace-pass
//! arithmetic, and the modeled spill clock.
//!
//! The executor charges each join stage a per-worker working set of
//! `build + probe + output` bytes (`exec`'s `join_needed_bytes`, with
//! the build/probe split from one shared helper so both policies flip at
//! the same threshold). When that exceeds the budget,
//! [`MemPolicy::Fail`] reports `DistError::Oom` (what the comparator
//! systems do), while [`MemPolicy::Spill`] runs a **real** out-of-core
//! grace join: the build side is written to the worker's spill scratch
//! (`super::spill`) in budget-sized columnar runs and streamed back one
//! pass at a time, re-scanning the probe side per pass — slower, never
//! dead. This is the paper's headline asymmetry: the relational engine
//! degrades where the custom systems OOM.
//!
//! One spilled stage reports along two axes — the *modeled* virtual
//! cluster and the *measured* host run:
//!
//! | quantity | kind | source |
//! |---|---|---|
//! | `ExecStats::spill_s` | modeled | [`spill_io_s`] at [`SPILL_BPS`]: per-pass probe rescans + working-set overflow, the virtual cluster's disk seconds (feeds `virtual_time_s`) |
//! | `ExecStats::spill_passes` | exact | grace passes actually *executed* (the spill file's run count — pass-size rounding can land below the [`grace_passes`] model), beyond the first |
//! | `ExecStats::spill_bytes_written` / `spill_bytes_read` | measured | actual run-file bytes, counted by `super::spill`'s writer and reader |
//! | `ExecStats::wall_s` | measured | end-to-end host seconds — the real temp-file I/O shows up here |
//!
//! The modeled clock deliberately prices a fully disk-resident cluster
//! (probe rescans hit disk every pass), while the measured counters
//! record exactly what this host's execution wrote and re-read — the
//! build runs. (The virtual cluster keeps every worker's shards
//! resident in one process by design, so the spill path realizes the
//! disk mechanics of out-of-core execution without shrinking process
//! RSS; see the ROADMAP's resident-set reduction item.)
//! Degenerate budgets are pinned, not errors: a zero-byte budget under
//! `Spill` degrades to the maximal grace — one build tuple per pass —
//! and a budget exactly equal to the working set does not spill at all
//! (the threshold is strictly "needed > budget").

/// What a worker does when a stage's working set exceeds its budget.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemPolicy {
    /// Grace-style degradation: split the join build side into passes,
    /// spill intermediates to local disk, keep going.
    Spill,
    /// Report OOM, like the comparator systems in Tables 2–3.
    Fail,
}

/// Modeled local-disk (spill) bandwidth, bytes/second — NVMe-class.
pub const SPILL_BPS: f64 = 2.0e9;

/// Number of grace passes needed to stream a `needed`-byte working set
/// through a `budget`-byte memory (≥ 1). A zero budget prices one pass
/// per byte — the executor clamps passes to the build side's tuple
/// count, so `budget = 0` pins to "one tuple per pass", the maximal
/// grace, never an error.
pub fn grace_passes(needed: u64, budget: u64) -> u64 {
    needed.div_ceil(budget.max(1)).max(1)
}

/// Virtual seconds charged for writing `bytes` to the spill device and
/// reading them back.
pub fn spill_io_s(bytes: u64) -> f64 {
    2.0 * bytes as f64 / SPILL_BPS
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pass_counts() {
        assert_eq!(grace_passes(100, 1000), 1);
        assert_eq!(grace_passes(1000, 1000), 1);
        assert_eq!(grace_passes(1001, 1000), 2);
        assert_eq!(grace_passes(10_000, 1000), 10);
        // Degenerate budget never divides by zero.
        assert_eq!(grace_passes(5, 0), 5);
    }

    #[test]
    fn spill_io_is_linear_and_positive() {
        assert_eq!(spill_io_s(0), 0.0);
        let a = spill_io_s(1 << 20);
        let b = spill_io_s(1 << 21);
        assert!(a > 0.0);
        assert!((b - 2.0 * a).abs() < 1e-12);
    }
}
