//! Per-worker memory accounting: the budget policies and the grace-spill
//! cost model.
//!
//! Spill I/O is part of the **modeled** clock: [`spill_io_s`] feeds
//! `ExecStats::spill_s` (and through it `virtual_time_s`), priced at
//! [`SPILL_BPS`], while the grace passes themselves run for real and are
//! therefore also visible in the measured `wall_s`. See the `dist`
//! module docs for the measured/modeled/checked contract.
//!
//! The executor charges each join stage a per-worker working set of
//! `build + probe + output` bytes. When that exceeds the budget,
//! [`MemPolicy::Fail`] reports `DistError::Oom` (what the comparator
//! systems do), while [`MemPolicy::Spill`] splits the build side into
//! grace passes small enough to stream through memory, re-reading the
//! probe side per pass and spilling the output — slower, never dead.
//! This is the paper's headline asymmetry: the relational engine
//! degrades where the custom systems OOM.

/// What a worker does when a stage's working set exceeds its budget.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemPolicy {
    /// Grace-style degradation: split the join build side into passes,
    /// spill intermediates to local disk, keep going.
    Spill,
    /// Report OOM, like the comparator systems in Tables 2–3.
    Fail,
}

/// Modeled local-disk (spill) bandwidth, bytes/second — NVMe-class.
pub const SPILL_BPS: f64 = 2.0e9;

/// Number of grace passes needed to stream a `needed`-byte working set
/// through a `budget`-byte memory (≥ 1).
pub fn grace_passes(needed: u64, budget: u64) -> u64 {
    needed.div_ceil(budget.max(1)).max(1)
}

/// Virtual seconds charged for writing `bytes` to the spill device and
/// reading them back.
pub fn spill_io_s(bytes: u64) -> f64 {
    2.0 * bytes as f64 / SPILL_BPS
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pass_counts() {
        assert_eq!(grace_passes(100, 1000), 1);
        assert_eq!(grace_passes(1000, 1000), 1);
        assert_eq!(grace_passes(1001, 1000), 2);
        assert_eq!(grace_passes(10_000, 1000), 10);
        // Degenerate budget never divides by zero.
        assert_eq!(grace_passes(5, 0), 5);
    }

    #[test]
    fn spill_io_is_linear_and_positive() {
        assert_eq!(spill_io_s(0), 0.0);
        let a = spill_io_s(1 << 20);
        let b = spill_io_s(1 << 21);
        assert!(a > 0.0);
        assert!((b - 2.0 * a).abs() < 1e-12);
    }
}
