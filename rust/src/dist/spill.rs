//! Real temp-file spill: the disk half of `MemPolicy::Spill`.
//!
//! Until PR 5 the grace join only *modeled* its I/O (`mem::spill_io_s`):
//! pass counts and spill seconds were computed, but no byte ever
//! touched a disk. This module backs the grace passes with real files —
//! build-side runs are serialized out and streamed back pass by pass,
//! the way Jankov et al.'s RDBMS-hosted execution spills hash-join
//! partitions, with the traffic *measured* rather than assumed. (The
//! virtual cluster still keeps every worker's shards resident in one
//! process by design, so this is the real disk mechanics and
//! accounting of out-of-core execution, not a smaller process RSS —
//! see the ROADMAP open item on resident-set reduction.)
//!
//! * [`SpillSpace`] — one scratch tree per run (a worker pool owns one
//!   for its whole lifetime; a pool-less evaluation creates one per
//!   evaluation), with a subdirectory per worker. The tree is removed
//!   when the space drops.
//! * [`SpillWriter`] — streams *runs* (the build-side slice of one grace
//!   pass) into a spill file in a columnar layout: key widths, key
//!   components, chunk shapes, then the flat f32 payload column, each
//!   section contiguous, little-endian. Byte counts are measured from
//!   what actually hits the file.
//! * [`SpillFile`] — the finished on-disk artifact. Deleted on drop, so
//!   a worker that errors or panics mid-stage leaves no orphans (the
//!   pool catches the unwind; the locals unwind with it).
//! * [`SpillReader`] — re-reads the runs in write order, bit-exact:
//!   f32/i64 round-trip through `to_le_bytes`/`from_le_bytes`, so a
//!   spilled execution is bitwise identical to an in-memory one (the
//!   `tests/spill.rs` property suite asserts this end to end).
//!
//! Accounting contract: writers and readers report the exact file bytes
//! they moved; `dist::exec` surfaces the totals as
//! `ExecStats::spill_bytes_written` / `spill_bytes_read` — the
//! **measured** counters — while the **modeled** clock keeps charging
//! `mem::spill_io_s` for the virtual cluster (see `mem` for the
//! modeled/measured table).
//!
//! The chunk payload column is f32 because that is the engine's chunk
//! dtype (`ra::Chunk`); the layout is otherwise the classic columnar
//! run file of an external hash join.
//!
//! The same codec doubles as the trainer checkpoint format
//! (`session::trainer`): [`SpillWriter::create_at`] writes a parameter
//! relation to a caller-named file, [`SpillFile::keep`] defuses
//! delete-on-drop to make it durable, and [`SpillFile::attach`] +
//! [`SpillReader`] re-read it bit-exactly on restore. Scratch hygiene
//! across *process kills* is handled at [`SpillSpace::create`], which
//! sweeps dead-pid trees left by SIGKILLed runs (`Drop` never ran).

use std::fs::{self, File};
use std::io::{self, BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::ra::key::MAX_KEY;
use crate::ra::{Chunk, Key};

/// Process-wide sequence for collision-free scratch names (several pools
/// and evaluations may spill concurrently under one temp root).
static SEQ: AtomicU64 = AtomicU64::new(0);

fn next_seq() -> u64 {
    SEQ.fetch_add(1, Ordering::Relaxed)
}

/// Environment variable consulted (after the explicit
/// `ClusterConfig::spill_dir`) for where spill scratch trees go; the
/// final fallback is the OS temp directory. CI points this at a
/// job-scoped directory so the low-memory suite can assert emptiness.
pub const SPILL_DIR_ENV: &str = "RELAD_SPILL_DIR";

/// One run's scratch tree: a unique directory under the configured
/// root, with one subdirectory per worker (`w0/`, `w1/`, …) created on
/// first spill. Removing the space removes the whole tree — the
/// "no orphaned temp files" guarantee at the coarsest granularity
/// (individual [`SpillFile`]s already delete themselves on drop).
#[derive(Debug)]
pub struct SpillSpace {
    root: PathBuf,
}

impl SpillSpace {
    /// Create a fresh scratch tree. The root is resolved as: `hint`
    /// (from `ClusterConfig::spill_dir`) → `$RELAD_SPILL_DIR` → the OS
    /// temp directory; a unique `relad-spill-<pid>-<seq>` child is
    /// created inside it. Before creating its own child, the call sweeps
    /// *dead-process* scratch trees left under the same base — `Drop`
    /// cleanup cannot run in a SIGKILLed process, so the pid baked into
    /// each tree name is the recovery handle (see [`sweep_orphans`]).
    pub fn create(hint: Option<&Path>) -> io::Result<SpillSpace> {
        let base = match hint {
            Some(p) => p.to_path_buf(),
            None => std::env::var_os(SPILL_DIR_ENV)
                .map(PathBuf::from)
                .unwrap_or_else(std::env::temp_dir),
        };
        sweep_orphans(&base);
        let root = base.join(format!(
            "relad-spill-{}-{}",
            std::process::id(),
            next_seq()
        ));
        fs::create_dir_all(&root)?;
        Ok(SpillSpace { root })
    }

    /// The unique scratch root of this space.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Worker `wi`'s scratch directory (path arithmetic only — see
    /// [`ensure_worker_dir`](Self::ensure_worker_dir) to create it).
    pub fn worker_dir(&self, wi: usize) -> PathBuf {
        self.root.join(format!("w{wi}"))
    }

    /// Create (idempotently) and return worker `wi`'s scratch directory.
    /// Called by the worker itself on its first spill, so unspilled runs
    /// never touch the filesystem beyond the root `mkdir`.
    pub fn ensure_worker_dir(&self, wi: usize) -> io::Result<PathBuf> {
        let dir = self.worker_dir(wi);
        fs::create_dir_all(&dir)?;
        Ok(dir)
    }

    /// Number of regular files anywhere under the space — the test probe
    /// behind "no orphaned temp files after a failed stage".
    pub fn file_count(&self) -> usize {
        file_count(&self.root)
    }
}

/// Remove scratch trees under `base` whose owning process is dead. A
/// process that exits cleanly removes its trees via `Drop`; a SIGKILLed
/// one cannot, so every `relad-spill-<pid>-<seq>` child is checked
/// against procfs and reclaimed when `<pid>` no longer exists. The
/// current process's own trees and any live sibling's are never
/// touched, and on hosts without `/proc` the sweep is a no-op —
/// leaking a dead tree is recoverable, deleting a live one is not.
/// Best-effort throughout: unreadable entries and racing removals are
/// skipped silently.
fn sweep_orphans(base: &Path) {
    let proc_root = Path::new("/proc");
    if !proc_root.is_dir() {
        return;
    }
    let me = std::process::id();
    let Ok(entries) = fs::read_dir(base) else {
        return;
    };
    for e in entries.flatten() {
        let name = e.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(rest) = name.strip_prefix("relad-spill-") else {
            continue;
        };
        let Some((pid_s, _seq)) = rest.split_once('-') else {
            continue;
        };
        let Ok(pid) = pid_s.parse::<u32>() else { continue };
        if pid == me || proc_root.join(pid_s).exists() {
            continue;
        }
        let _ = fs::remove_dir_all(e.path());
    }
}

/// Regular files anywhere under `dir` (recursive; unreadable directories
/// count as empty). Scratch *directories* may legitimately exist while
/// their owner is alive — *files* must never outlive their pass, which
/// is what the spill test suite asserts with this probe.
pub fn file_count(dir: &Path) -> usize {
    fn walk(dir: &Path, n: &mut usize) {
        let Ok(entries) = fs::read_dir(dir) else {
            return;
        };
        for e in entries.flatten() {
            let p = e.path();
            if p.is_dir() {
                walk(&p, n);
            } else {
                *n += 1;
            }
        }
    }
    let mut n = 0;
    walk(dir, &mut n);
    n
}

impl Drop for SpillSpace {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.root);
    }
}

/// Magic prefixing every run section (format versioning + a cheap
/// corruption check on re-read).
const RUN_MAGIC: [u8; 4] = *b"RSP1";

/// A finished spill file: `runs` columnar runs, `nbytes` on disk.
/// Deleting is automatic on drop — including unwinds, which is what
/// keeps a panicking worker from orphaning scratch.
#[derive(Debug)]
pub struct SpillFile {
    path: PathBuf,
    nbytes: u64,
    runs: u64,
}

impl SpillFile {
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Exact file size written, in bytes.
    pub fn nbytes(&self) -> u64 {
        self.nbytes
    }

    /// Number of runs (grace passes) the file holds.
    pub fn runs(&self) -> u64 {
        self.runs
    }

    /// Defuse delete-on-drop and return the file's path: the file now
    /// belongs to the caller. This is what turns a scratch-run artifact
    /// into a *durable* one — the trainer checkpoint writer seals each
    /// parameter file with [`SpillWriter::finish`] and then `keep`s it.
    pub fn keep(mut self) -> PathBuf {
        let path = std::mem::take(&mut self.path);
        // `path` is already empty; skipping Drop just avoids an
        // `remove_file("")` syscall on the way out.
        std::mem::forget(self);
        path
    }

    /// Re-adopt a durable file previously [`keep`](Self::keep)-ed (the
    /// checkpoint restore path). `runs` comes from the checkpoint
    /// manifest — the run count is not recorded in the file itself. The
    /// returned handle deletes on drop like any spill file, so a restore
    /// that wants the checkpoint to survive must `keep` it again after
    /// reading.
    pub fn attach(path: &Path, runs: u64) -> io::Result<SpillFile> {
        let nbytes = fs::metadata(path)?.len();
        Ok(SpillFile {
            path: path.to_path_buf(),
            nbytes,
            runs,
        })
    }
}

impl Drop for SpillFile {
    fn drop(&mut self) {
        let _ = fs::remove_file(&self.path);
    }
}

/// Streams columnar runs into a fresh spill file inside a scratch
/// directory. [`finish`](Self::finish) yields the [`SpillFile`]; a
/// writer dropped *without* finishing (error paths, panics) deletes the
/// partial file.
pub struct SpillWriter {
    w: Option<BufWriter<File>>,
    path: PathBuf,
    bytes: u64,
    runs: u64,
}

impl SpillWriter {
    /// Open a uniquely named spill file in `dir` (which must exist —
    /// workers go through [`SpillSpace::ensure_worker_dir`]).
    pub fn create(dir: &Path) -> io::Result<SpillWriter> {
        Self::create_at(&dir.join(format!("run-{}.spill", next_seq())))
    }

    /// Open a writer at an explicit path (truncating any existing file)
    /// — the trainer checkpoint codec, which needs caller-chosen names
    /// (`p0.spill`, `p1.spill`, …) instead of sequence-numbered scratch
    /// runs. Same format, same delete-on-drop until
    /// [`finish`](Self::finish) + [`SpillFile::keep`].
    pub fn create_at(path: &Path) -> io::Result<SpillWriter> {
        let file = File::create(path)?;
        Ok(SpillWriter {
            w: Some(BufWriter::new(file)),
            path: path.to_path_buf(),
            bytes: 0,
            runs: 0,
        })
    }

    fn put(&mut self, buf: &[u8]) -> io::Result<()> {
        self.w
            .as_mut()
            .expect("writer already finished")
            .write_all(buf)?;
        self.bytes += buf.len() as u64;
        Ok(())
    }

    /// Append one run — the tuples of one grace pass — in columnar
    /// layout: magic, count, key widths, key components, chunk shapes,
    /// then the flat f32 payload column. Empty runs are legal (an empty
    /// build side still records that the stage ran out-of-core).
    pub fn write_run(&mut self, pairs: &[(Key, Chunk)]) -> io::Result<()> {
        self.put(&RUN_MAGIC)?;
        self.put(&(pairs.len() as u64).to_le_bytes())?;
        for (k, _) in pairs {
            self.put(&[k.len() as u8])?;
        }
        for (k, _) in pairs {
            for &c in k.as_slice() {
                self.put(&c.to_le_bytes())?;
            }
        }
        for (_, v) in pairs {
            self.put(&(v.rows() as u32).to_le_bytes())?;
            self.put(&(v.cols() as u32).to_le_bytes())?;
        }
        // Payload column: serialize each chunk's floats into one reused
        // buffer and write it as a single section — per-chunk calls, not
        // per-element (this loop dominates spill wall time).
        let mut buf: Vec<u8> = Vec::new();
        for (_, v) in pairs {
            buf.clear();
            buf.reserve(v.nbytes());
            for &x in v.data() {
                buf.extend_from_slice(&x.to_le_bytes());
            }
            self.put(&buf)?;
        }
        self.runs += 1;
        Ok(())
    }

    /// Bytes written so far (exactly what [`SpillFile::nbytes`] will
    /// report after [`finish`](Self::finish)).
    pub fn bytes_written(&self) -> u64 {
        self.bytes
    }

    /// Flush and seal the file.
    pub fn finish(mut self) -> io::Result<SpillFile> {
        let mut w = self.w.take().expect("writer already finished");
        w.flush()?;
        drop(w);
        Ok(SpillFile {
            path: std::mem::take(&mut self.path),
            nbytes: self.bytes,
            runs: self.runs,
        })
    }
}

impl Drop for SpillWriter {
    fn drop(&mut self) {
        // Still holding the handle ⇒ `finish` never ran: unwind or early
        // return. Close and delete the partial file.
        if self.w.take().is_some() {
            let _ = fs::remove_file(&self.path);
        }
    }
}

/// Re-reads a [`SpillFile`]'s runs in write order, counting the bytes it
/// pulls back off disk. Round-trips are bit-exact: every i64/u32/f32 is
/// reconstructed from the same little-endian bytes it was written as.
pub struct SpillReader<'f> {
    r: BufReader<File>,
    file: &'f SpillFile,
    bytes: u64,
    runs_read: u64,
}

impl<'f> SpillReader<'f> {
    pub fn open(file: &'f SpillFile) -> io::Result<SpillReader<'f>> {
        Ok(SpillReader {
            r: BufReader::new(File::open(&file.path)?),
            file,
            bytes: 0,
            runs_read: 0,
        })
    }

    fn take<const N: usize>(&mut self) -> io::Result<[u8; N]> {
        let mut buf = [0u8; N];
        self.r.read_exact(&mut buf)?;
        self.bytes += N as u64;
        Ok(buf)
    }

    /// Read `n` bytes as one section (the chunk-payload fast path).
    fn take_vec(&mut self, n: usize) -> io::Result<Vec<u8>> {
        let mut buf = vec![0u8; n];
        self.r.read_exact(&mut buf)?;
        self.bytes += n as u64;
        Ok(buf)
    }

    /// The next run's tuples, or `None` once every written run has been
    /// consumed. A short or corrupt file is an `InvalidData` error, never
    /// a silently truncated run.
    pub fn next_run(&mut self) -> io::Result<Option<Vec<(Key, Chunk)>>> {
        if self.runs_read == self.file.runs() {
            return Ok(None);
        }
        let magic: [u8; 4] = self.take()?;
        if magic != RUN_MAGIC {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "spill run magic mismatch",
            ));
        }
        let n = u64::from_le_bytes(self.take()?) as usize;
        let mut lens = Vec::with_capacity(n);
        for _ in 0..n {
            let [l] = self.take::<1>()?;
            if l as usize > MAX_KEY {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    "spill run key width out of range",
                ));
            }
            lens.push(l as usize);
        }
        let mut keys = Vec::with_capacity(n);
        for &l in &lens {
            let mut comps = [0i64; MAX_KEY];
            for c in comps.iter_mut().take(l) {
                *c = i64::from_le_bytes(self.take()?);
            }
            keys.push(Key::new(&comps[..l]));
        }
        let mut shapes = Vec::with_capacity(n);
        for _ in 0..n {
            let rows = u32::from_le_bytes(self.take()?) as usize;
            let cols = u32::from_le_bytes(self.take()?) as usize;
            shapes.push((rows, cols));
        }
        let mut out = Vec::with_capacity(n);
        for (key, (rows, cols)) in keys.into_iter().zip(shapes) {
            // One read per chunk payload, then a bit-exact reassembly.
            let raw = self.take_vec(rows * cols * std::mem::size_of::<f32>())?;
            let data: Vec<f32> = raw
                .chunks_exact(std::mem::size_of::<f32>())
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            out.push((key, Chunk::from_vec(rows, cols, data)));
        }
        self.runs_read += 1;
        Ok(Some(out))
    }

    /// Bytes re-read off disk so far.
    pub fn bytes_read(&self) -> u64 {
        self.bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    fn pairs(n: i64, rng: &mut Prng) -> Vec<(Key, Chunk)> {
        (0..n)
            .map(|i| (Key::k2(i, i * 3 % 7), Chunk::random(2, 3, rng, 1.0)))
            .collect()
    }

    fn bits(p: &[(Key, Chunk)]) -> Vec<(Key, Vec<u32>)> {
        p.iter()
            .map(|(k, v)| (*k, v.data().iter().map(|x| x.to_bits()).collect()))
            .collect()
    }

    #[test]
    fn runs_round_trip_bitwise_including_empty_and_single() {
        let mut rng = Prng::new(0x5B11);
        let space = SpillSpace::create(None).unwrap();
        let dir = space.ensure_worker_dir(0).unwrap();
        let runs: Vec<Vec<(Key, Chunk)>> = vec![
            vec![],                                       // empty relation
            pairs(1, &mut rng),                           // single row
            pairs(17, &mut rng),                          // a real pass
            vec![(Key::empty(), Chunk::scalar(f32::NAN))], // empty key + NaN payload
        ];
        let mut w = SpillWriter::create(&dir).unwrap();
        for r in &runs {
            w.write_run(r).unwrap();
        }
        let written = w.bytes_written();
        let file = w.finish().unwrap();
        assert_eq!(file.nbytes(), written);
        assert_eq!(file.runs(), runs.len() as u64);
        assert!(written > 0);

        let mut r = SpillReader::open(&file).unwrap();
        for want in &runs {
            let got = r.next_run().unwrap().expect("run missing");
            assert_eq!(bits(&got), bits(want), "round trip changed bits");
        }
        assert!(r.next_run().unwrap().is_none(), "phantom extra run");
        assert_eq!(r.bytes_read(), written, "read bytes ≠ written bytes");

        // The file disappears with its handle; the tree with the space.
        let path = file.path().to_path_buf();
        assert!(path.exists());
        drop(r);
        drop(file);
        assert!(!path.exists(), "SpillFile drop must delete the file");
        let root = space.root().to_path_buf();
        drop(space);
        assert!(!root.exists(), "SpillSpace drop must remove the tree");
    }

    #[test]
    fn unfinished_writer_deletes_partial_file() {
        let mut rng = Prng::new(0x5B12);
        let space = SpillSpace::create(None).unwrap();
        let dir = space.ensure_worker_dir(3).unwrap();
        let mut w = SpillWriter::create(&dir).unwrap();
        w.write_run(&pairs(5, &mut rng)).unwrap();
        drop(w); // no finish(): error-path semantics
        assert_eq!(space.file_count(), 0, "partial spill file orphaned");
    }

    #[test]
    fn panic_mid_spill_leaves_no_files() {
        // The pool catches worker unwinds; the worker's spill locals
        // unwind with it and must take their files along.
        let mut rng = Prng::new(0x5B13);
        let space = SpillSpace::create(None).unwrap();
        let run = pairs(8, &mut rng);
        let res = catch_unwind(AssertUnwindSafe(|| {
            let dir = space.ensure_worker_dir(1).unwrap();
            let mut w = SpillWriter::create(&dir).unwrap();
            w.write_run(&run).unwrap();
            let file = w.finish().unwrap();
            let _reader = SpillReader::open(&file).unwrap();
            panic!("stage shard failed mid-spill");
        }));
        assert!(res.is_err());
        assert_eq!(
            space.file_count(),
            0,
            "panicking worker orphaned spill files"
        );
    }

    #[test]
    fn spaces_are_unique_and_worker_scoped() {
        let a = SpillSpace::create(None).unwrap();
        let b = SpillSpace::create(None).unwrap();
        assert_ne!(a.root(), b.root());
        assert_ne!(a.worker_dir(0), a.worker_dir(1));
        assert!(a.worker_dir(2).starts_with(a.root()));
        // Worker dirs are lazy: nothing on disk until a worker spills.
        assert!(!a.worker_dir(0).exists());
        let d = a.ensure_worker_dir(0).unwrap();
        assert!(d.is_dir());
        // Idempotent.
        assert_eq!(a.ensure_worker_dir(0).unwrap(), d);
    }

    #[test]
    fn create_sweeps_dead_pid_trees_but_spares_live_and_own() {
        if !Path::new("/proc").is_dir() {
            return; // sweep is a deliberate no-op without procfs
        }
        let base = std::env::temp_dir().join(format!(
            "relad-sweep-{}-{}",
            std::process::id(),
            next_seq()
        ));
        // A stale tree from a "SIGKILLed" process: pid u32::MAX is not a
        // valid Linux pid, so it is reliably dead.
        let stale = base.join("relad-spill-4294967295-0");
        fs::create_dir_all(stale.join("w0")).unwrap();
        fs::write(stale.join("w0").join("run-0.spill"), b"junk").unwrap();
        // A live sibling's tree (pid 1 always exists) and one of our own:
        // both must survive the sweep.
        let live = base.join("relad-spill-1-0");
        fs::create_dir_all(&live).unwrap();
        let own = base.join(format!("relad-spill-{}-999999", std::process::id()));
        fs::create_dir_all(&own).unwrap();
        // Non-matching names are never touched.
        let other = base.join("user-data");
        fs::create_dir_all(&other).unwrap();

        let space = SpillSpace::create(Some(&base)).unwrap();
        assert!(!stale.exists(), "dead-pid tree not swept");
        assert!(live.exists(), "live sibling's tree swept");
        assert!(own.exists(), "own tree swept");
        assert!(other.exists(), "unrelated directory swept");
        assert!(space.root().exists());
        drop(space);
        let _ = fs::remove_dir_all(&base);
    }

    #[test]
    fn keep_attach_round_trip_is_durable_and_bitwise() {
        let mut rng = Prng::new(0x5B14);
        let space = SpillSpace::create(None).unwrap();
        let dir = space.ensure_worker_dir(0).unwrap();
        let runs: Vec<Vec<(Key, Chunk)>> = vec![pairs(6, &mut rng), pairs(3, &mut rng)];
        let target = dir.join("p0.spill");
        let mut w = SpillWriter::create_at(&target).unwrap();
        for r in &runs {
            w.write_run(r).unwrap();
        }
        let file = w.finish().unwrap();
        assert_eq!(file.path(), target.as_path());
        let nbytes = file.nbytes();
        let kept = file.keep();
        assert_eq!(kept, target);
        assert!(target.exists(), "keep() must defuse delete-on-drop");

        let file = SpillFile::attach(&target, runs.len() as u64).unwrap();
        assert_eq!(file.nbytes(), nbytes, "attach must see the exact size");
        let mut r = SpillReader::open(&file).unwrap();
        for want in &runs {
            let got = r.next_run().unwrap().expect("run missing");
            assert_eq!(bits(&got), bits(want), "durable round trip changed bits");
        }
        assert!(r.next_run().unwrap().is_none());
        drop(r);
        // An attached handle deletes on drop like any spill file.
        drop(file);
        assert!(!target.exists(), "attached file must delete on drop");
    }

    #[test]
    fn explicit_root_hint_is_honoured() {
        let base = std::env::temp_dir().join(format!("relad-hint-{}", std::process::id()));
        std::fs::create_dir_all(&base).unwrap();
        let s = SpillSpace::create(Some(&base)).unwrap();
        assert!(s.root().starts_with(&base));
        drop(s);
        // The hint directory itself is the user's; only our child goes.
        assert!(base.exists());
        let _ = std::fs::remove_dir_all(&base);
    }
}
