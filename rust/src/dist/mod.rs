//! The virtual-cluster distributed runtime — the paper's scaling layer.
//!
//! A functional-RA query runs unchanged on `w` *virtual workers*: every
//! relation is a [`PartitionedRelation`] (hash-partitioned, replicated,
//! or arbitrarily sharded), and the stage-by-stage BSP executor in
//! [`exec`] runs the query — driven through `session::Session`, the
//! engine's stateful front door (the deprecated [`exec::dist_eval`]
//! wrappers funnel into the same core). Worker shards of each stage — compute,
//! shuffle route/build, gather, and the two-phase Σ final merge — run as
//! jobs on a persistent [`WorkerPool`] of real OS threads, each owning
//! one [`KernelBackend`] instance minted exactly once per pool via
//! `for_worker` (see [`pool`] for the lifecycle: one pool per
//! `session::Session`, held for the session's whole lifetime), so
//! the runtime reports **two clocks**:
//!
//! * **measured** — [`ExecStats::wall_s`] is the real elapsed time of the
//!   whole distributed execution on this host, and
//!   [`ExecStats::compute_s`] the per-stage max over workers of measured
//!   kernel time (the BSP barrier model);
//! * **modeled** — communication is priced by [`NetModel`] (per-byte
//!   bandwidth + per-message latency), spill I/O by `mem::SPILL_BPS`, and
//!   [`ExecStats::virtual_time_s`] = compute + net + spill is the modeled
//!   end-to-end time on the virtual cluster. Grace spill additionally
//!   reports **measured** temp-file traffic
//!   ([`ExecStats::spill_bytes_written`]/[`spill_bytes_read`](ExecStats::spill_bytes_read)):
//!   over-budget build sides really go to disk through [`spill`].
//!
//! Memory is *checked* against a per-worker budget — the same
//! measured/modeled/checked contract the `baselines` use, so the
//! Tables 2–3 / Figures 2–3 comparisons are apples to apples. The
//! `bench_dist` binary records both clocks per worker count
//! (`BENCH_dist.json`): `wall_s` demonstrates real speedup on a
//! multi-core host, `virtual_time_s` the modeled cluster scaling.
//!
//! [`KernelBackend`]: crate::kernels::KernelBackend
//!
//! Layout:
//!
//! * [`partition`] — `PartitionedRelation` and the partitioning
//!   invariants the planner reasons about,
//! * [`exec`] — the stage-by-stage evaluator: co-partitioned joins,
//!   cost-based broadcast-vs-reshuffle ([`exec::plan_join`], which
//!   prices both against [`NetModel`] and resolves exact price ties in
//!   favour of reshuffle), two-phase aggregation, partition-memoized
//!   shuffle elision, grace-style spilling. Within a worker shard the
//!   build side is the smaller-by-tuple-count side, ties building on
//!   the *right* — `exec::build_probe_split` mirrors
//!   `ra::eval::hash_join` exactly so distributed and single-node
//!   results match bitwise,
//! * [`pool`] — the persistent worker pool (parked threads + per-worker
//!   backends) every stage dispatches to,
//! * [`shuffle`] — tuple routing with exact moved-byte accounting,
//!   serial and pooled-all-to-all paths,
//! * [`net`] — the network cost model (shared with `baselines`),
//! * [`mem`] — memory policies, budget accounting, and the modeled spill
//!   clock,
//! * [`spill`] — the real temp-file spill backing grace passes
//!   (scratch spaces, columnar run files, measured byte counters),
//! * [`fault`] — deterministic fault injection (off by default): the
//!   scripted faults behind the stage-retry/lineage-replay machinery
//!   and its tests,
//! * [`delta`] — incremental (delta) maintenance of a previously
//!   executed tape under catalog inserts/deletes: clean-subtree reuse,
//!   insert-only append paths through σ/⋈/Σ, and the per-slot change
//!   descriptors `Session` frames hand the executor.
//!
//! The headline asymmetry of the paper lives in [`MemPolicy`]: the RA
//! engine under `Spill` degrades (grace passes out of real temp files,
//! `spill_passes > 0` and `spill_bytes_written > 0` in [`ExecStats`])
//! where the comparator systems return [`DistError::Oom`].

pub mod delta;
pub mod exec;
pub mod fault;
pub mod mem;
pub mod net;
pub mod partition;
pub mod pool;
pub mod shuffle;
pub mod spill;

pub use delta::{DeltaCtx, SlotDelta};
pub use exec::{plan_join, DistTape, JoinPlan, JoinSide, JoinStrategy, StageTrace};
pub use fault::{FaultInjector, FaultKind, FaultPlan, InjectedFault, InjectionPoint};
// The free-function evaluation surface is deprecated in favour of the
// stateful `session::Session` front door; the re-exports stay so existing
// callers keep compiling (with a deprecation nudge) until removal.
#[allow(deprecated)]
pub use exec::{
    dist_eval, dist_eval_in, dist_eval_multi, dist_eval_multi_in, dist_eval_tape,
    dist_eval_tape_in,
};
pub use mem::MemPolicy;
pub use net::NetModel;
pub use partition::{PartitionedRelation, Partitioning};
pub use pool::{JobFailure, WorkerPool};
pub use shuffle::ShuffleStats;
pub use spill::{SpillFile, SpillReader, SpillSpace, SpillWriter};

use std::fmt;
use std::path::PathBuf;
use std::sync::Arc;

/// Errors from distributed execution.
#[derive(Debug)]
pub enum DistError {
    /// A worker's working set exceeded its memory budget under
    /// [`MemPolicy::Fail`] — the OOM cells of Tables 2–3.
    Oom {
        /// Worker that hit the limit.
        worker: usize,
        /// Peak working-set bytes it would have needed.
        needed: u64,
        /// Its budget in bytes.
        budget: u64,
    },
    /// A retryable per-shard failure (injected fault, transient spill
    /// I/O, dropped exchange). Consumed by the stage retry loop in
    /// `exec::eval_tape_core`, which replays the stage from its
    /// immutable lineage inputs; callers only see it if a stage body is
    /// run outside the retry loop.
    Transient {
        /// Worker whose shard failed.
        worker: usize,
        /// What failed, rendered.
        what: String,
    },
    /// A BSP stage failed for good: either its transient faults survived
    /// every allowed replay (`max_stage_retries`), or a shard hit a
    /// non-retryable [`StageFailure::FatalJob`]. The driver never
    /// panics; the pool stays usable.
    StageFailed {
        /// Query node id of the failed stage.
        stage: usize,
        /// Worker whose shard failed last.
        worker: usize,
        /// Attempts executed (1 = the initial run, no retries).
        attempts: u32,
        /// Why the stage could not complete.
        source: StageFailure,
    },
    /// Any other failure (planning, query semantics, …).
    Other(anyhow::Error),
}

/// Terminal classification behind [`DistError::StageFailed`].
#[derive(Debug)]
pub enum StageFailure {
    /// Transient faults persisted through every allowed lineage replay.
    RetriesExhausted(String),
    /// A worker job panicked with a non-injected payload — a genuine
    /// bug, surfaced immediately and never retried.
    FatalJob(String),
}

impl fmt::Display for StageFailure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StageFailure::RetriesExhausted(what) => {
                write!(f, "retries exhausted: {what}")
            }
            StageFailure::FatalJob(what) => write!(f, "fatal job panic: {what}"),
        }
    }
}

impl fmt::Display for DistError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DistError::Oom {
                worker,
                needed,
                budget,
            } => write!(
                f,
                "worker {worker} out of memory: needed {needed} B, budget {budget} B"
            ),
            DistError::Transient { worker, what } => {
                write!(f, "transient failure on worker {worker}: {what}")
            }
            DistError::StageFailed {
                stage,
                worker,
                attempts,
                source,
            } => write!(
                f,
                "stage v{stage} failed on worker {worker} after {attempts} attempt(s): {source}"
            ),
            DistError::Other(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for DistError {}

impl From<anyhow::Error> for DistError {
    fn from(e: anyhow::Error) -> DistError {
        DistError::Other(e)
    }
}

/// Virtual-cluster shape: worker count, per-worker memory budget and
/// policy, the network cost model, and the threading switches.
///
/// `#[non_exhaustive]`: construct through [`ClusterConfig::new`] /
/// [`ClusterConfig::default`] and the `with_*` builders — session-era
/// additions then never break downstream constructors.
#[derive(Clone, Debug)]
#[non_exhaustive]
pub struct ClusterConfig {
    /// Number of virtual workers (`w`). Every input
    /// [`PartitionedRelation`] must be sharded across exactly this many.
    pub workers: usize,
    /// Per-worker memory budget in bytes (`None` = unbounded).
    pub budget: Option<u64>,
    /// What a worker does when a stage exceeds `budget`: grace-spill or
    /// OOM (see [`MemPolicy`]).
    pub policy: MemPolicy,
    /// Where spill scratch trees are created under [`MemPolicy::Spill`]
    /// (`None` = `$RELAD_SPILL_DIR`, falling back to the OS temp
    /// directory — see [`spill::SpillSpace::create`]). Each run's tree
    /// is uniquely named, worker-scoped, and removed when its owner (the
    /// worker pool, or a pool-less evaluation) drops.
    pub spill_dir: Option<PathBuf>,
    /// The modeled fabric communication is priced on.
    pub net: NetModel,
    /// Run worker shards on a [`WorkerPool`] of real OS threads
    /// (default). The pool only engages while `workers` ≤ the host's
    /// core count — oversubscribed shards would time-share cores and
    /// corrupt the measured per-shard compute behind `virtual_time_s` —
    /// so large virtual clusters on small hosts keep the serial
    /// reference semantics. `false` forces the serial reference path
    /// unconditionally — same results bitwise (the determinism tests
    /// assert this).
    pub parallel: bool,
    /// Also shard the communication steps — `shuffle::exchange*`
    /// route/build, `gather`, and the two-phase Σ final merge — across
    /// the pool (default). `false` keeps stage compute threaded but runs
    /// all communication on the driver thread (the pre-pool executor,
    /// kept as the A/B baseline `bench_dist` compares against); results
    /// are bitwise identical either way.
    pub parallel_comm: bool,
    /// Factorized evaluation (default on): session-level paths rewrite
    /// legal `Σ-over-⋈` pairs to push partial Σ below the join
    /// ([`crate::plan::factorize`]). `false` runs every plan exactly as
    /// written — the A/B baseline the factorization benches compare
    /// against.
    pub factorize_agg: bool,
    /// Partition-aware shuffle elision (default on): the executor
    /// memoizes each node's reshuffles/broadcasts per target key within
    /// one tape execution, so a node that two stages move the same way
    /// crosses the fabric once. Elided movement is counted in
    /// [`ExecStats::shuffles_elided`] /
    /// [`ExecStats::bytes_shuffle_elided`] instead of `bytes_shuffled`;
    /// results are bitwise identical either way (the memo returns the
    /// exact relation a fresh movement would rebuild).
    pub elide_shuffles: bool,
    /// Deterministic fault script ([`fault::FaultPlan`]), `None` by
    /// default. When set, the executor threads a [`FaultInjector`]
    /// through every stage and the scripted faults fire at their exact
    /// `(point, worker, occurrence)` coordinates; when `None`, no
    /// injector exists and the probe sites are never visited
    /// (`fault::probes()` stays flat — the hot path is untouched).
    pub fault_plan: Option<Arc<fault::FaultPlan>>,
    /// How many times a BSP stage may be *replayed* after a transient
    /// shard failure before surfacing [`DistError::StageFailed`]
    /// (default 2 — up to 3 attempts total). Lineage replay recomputes
    /// the stage from its immutable `Arc<Relation>` tape inputs; fatal
    /// job panics are never retried regardless of this knob.
    pub max_stage_retries: u32,
    /// Heavy-hitter detection threshold for `Session::register`
    /// (default `None` = sampler off, every table gets plain
    /// [`Partitioning::Hash`]). When `Some(t)`, registration samples key
    /// frequencies on the partitioning components and records projected
    /// sub-keys whose sampled frequency exceeds `t` in a
    /// [`Partitioning::SkewHash`] annotation — placement is unchanged,
    /// but `plan_join` may then choose the salted/replicated skew
    /// strategies (results stay bitwise identical to the oblivious
    /// plan).
    pub skew_threshold: Option<f64>,
    /// Salt-bucket fan-out `s` for the salted skew-join strategy
    /// (`0` = auto: `min(workers, 4)`). Hot probe rows split
    /// round-robin across `s` consecutive workers starting at the hot
    /// key's hash owner; the other side's hot rows are replicated to
    /// those buckets. Affects load spread only, never result bits.
    pub skew_salts: usize,
}

impl Default for ClusterConfig {
    /// A single-worker cluster with unbounded memory, `Spill` policy and
    /// threading switches on — the shape `session::Session::new` runs
    /// "local" workloads with.
    fn default() -> ClusterConfig {
        ClusterConfig::new(1)
    }
}

impl ClusterConfig {
    pub fn new(workers: usize) -> ClusterConfig {
        assert!(workers >= 1, "a cluster needs at least one worker");
        ClusterConfig {
            workers,
            budget: None,
            policy: MemPolicy::Spill,
            spill_dir: None,
            net: NetModel::default(),
            parallel: true,
            parallel_comm: true,
            factorize_agg: true,
            elide_shuffles: true,
            fault_plan: None,
            max_stage_retries: 2,
            skew_threshold: None,
            skew_salts: 0,
        }
    }

    pub fn with_parallel(mut self, parallel: bool) -> ClusterConfig {
        self.parallel = parallel;
        self
    }

    pub fn with_parallel_comm(mut self, parallel_comm: bool) -> ClusterConfig {
        self.parallel_comm = parallel_comm;
        self
    }

    pub fn with_budget(mut self, bytes: u64) -> ClusterConfig {
        self.budget = Some(bytes);
        self
    }

    pub fn with_policy(mut self, policy: MemPolicy) -> ClusterConfig {
        self.policy = policy;
        self
    }

    /// Root directory for spill scratch trees (see
    /// [`ClusterConfig::spill_dir`]).
    pub fn with_spill_dir(mut self, dir: impl Into<PathBuf>) -> ClusterConfig {
        self.spill_dir = Some(dir.into());
        self
    }

    pub fn with_net(mut self, net: NetModel) -> ClusterConfig {
        self.net = net;
        self
    }

    pub fn with_factorize_agg(mut self, on: bool) -> ClusterConfig {
        self.factorize_agg = on;
        self
    }

    pub fn with_elide_shuffles(mut self, on: bool) -> ClusterConfig {
        self.elide_shuffles = on;
        self
    }

    /// Switch the whole factorized-evaluation package (the Σ-pushdown
    /// rewrite *and* shuffle elision) on or off — the A/B knob.
    pub fn with_factorize(self, on: bool) -> ClusterConfig {
        self.with_factorize_agg(on).with_elide_shuffles(on)
    }

    /// Script deterministic fault injection for every execution under
    /// this config (see [`ClusterConfig::fault_plan`]).
    pub fn with_fault_plan(mut self, plan: fault::FaultPlan) -> ClusterConfig {
        self.fault_plan = Some(Arc::new(plan));
        self
    }

    /// Bound on lineage replays per stage (see
    /// [`ClusterConfig::max_stage_retries`]).
    pub fn with_max_stage_retries(mut self, retries: u32) -> ClusterConfig {
        self.max_stage_retries = retries;
        self
    }

    /// Turn on ingest-time heavy-hitter sampling (see
    /// [`ClusterConfig::skew_threshold`]).
    pub fn with_skew_threshold(mut self, threshold: f64) -> ClusterConfig {
        assert!(
            threshold > 0.0 && threshold <= 1.0,
            "skew threshold is a sampled frequency in (0, 1]"
        );
        self.skew_threshold = Some(threshold);
        self
    }

    /// Salt-bucket fan-out for salted skew joins (see
    /// [`ClusterConfig::skew_salts`]; `0` = auto).
    pub fn with_skew_salts(mut self, salts: usize) -> ClusterConfig {
        self.skew_salts = salts;
        self
    }
}

/// Per-execution accounting: the *measured* wall clock of this run, the
/// *modeled* virtual wall clock (max-over-workers compute per BSP stage +
/// modeled network + modeled spill I/O), and the raw counters behind it.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ExecStats {
    /// Modeled end-to-end seconds on the virtual cluster.
    pub virtual_time_s: f64,
    /// Measured end-to-end seconds of this execution on this host —
    /// worker shards run on real threads, so `wall_s` shrinks with
    /// worker count up to the core count.
    pub wall_s: f64,
    /// Measured kernel compute (max over workers, summed over stages).
    pub compute_s: f64,
    /// Modeled network seconds.
    pub net_s: f64,
    /// Modeled spill (disk) seconds.
    pub spill_s: f64,
    /// Bytes that crossed the network in shuffles/broadcasts.
    pub bytes_shuffled: u64,
    /// Bytes that *would* have crossed the network but were elided by
    /// the partition memo ([`ClusterConfig::elide_shuffles`]) — the
    /// factorized-evaluation headline delta: `bytes_shuffled` for a
    /// factorized run plus this field equals the materialized run's
    /// `bytes_shuffled`.
    pub bytes_shuffle_elided: u64,
    /// Reshuffle/broadcast movements satisfied from the partition memo.
    pub shuffles_elided: u64,
    /// Bytes scattered from the driver to first place (or re-place)
    /// *input* relations on workers — charged by `DistTrainer`'s
    /// partition cache; zero when cached partitions are reused.
    pub bytes_ingested: u64,
    /// Point-to-point messages (latency units) those bytes travelled in.
    pub msgs: u64,
    /// Spill events, summed over workers: grace-join passes beyond the
    /// first, plus one for any over-budget stage whose build side was
    /// too small to split (it still ran out-of-core).
    pub spill_passes: u64,
    /// **Measured** bytes actually written to spill temp files (grace
    /// build-side runs), summed over workers. Zero whenever every stage
    /// fit its budget.
    pub spill_bytes_written: u64,
    /// **Measured** bytes re-read from spill temp files, summed over
    /// workers. A completed run re-reads everything it wrote, so this
    /// equals [`spill_bytes_written`](Self::spill_bytes_written) unless
    /// a stage failed mid-pass.
    pub spill_bytes_read: u64,
    /// Query nodes executed.
    pub stages: u64,
    /// Faults fired by the configured [`fault::FaultInjector`] during
    /// this execution (all kinds, including `Slow`). Zero whenever
    /// `fault_plan` is `None`.
    pub faults_injected: u64,
    /// Stage replays executed by the retry loop after transient shard
    /// failures. A fault-free run — and a faulty run whose every fault
    /// was absorbed — reports its results bitwise identical regardless
    /// of this count.
    pub stage_retries: u64,
    /// Worker shards recomputed by lineage replay (each retry replays
    /// all `w` shards of the stage from its immutable inputs).
    pub shards_recomputed: u64,
    /// **Measured** bytes written by trainer checkpoints through the
    /// spill columnar codec (manifest + parameter runs).
    pub checkpoint_bytes: u64,
    /// Delta rows applied: rows of `Session::insert`/`delete` batches
    /// merged into the catalog heads, plus rows replayed into bound
    /// frames/trainers when they refresh to a newer epoch. Zero for a
    /// static catalog.
    pub delta_rows_applied: u64,
    /// Worker-shard results served verbatim from the previous tape by a
    /// delta-maintained execution (clean-subtree reuse and insert-only
    /// append paths, `w` per skipped stage) — the work incremental
    /// evaluation did *not* redo.
    pub shards_reused: u64,
    /// Delta maintenance attempts refused by the legality gate
    /// ([`crate::plan::delta_gate`]) and satisfied by a bitwise-equal
    /// full recompute from the merged heads instead.
    pub delta_fallbacks: u64,
    /// Heavy hitters flagged by the ingest-time sampler at
    /// `Session::register` ([`ClusterConfig::skew_threshold`]) — the
    /// total size of every `SkewHash` hot set minted. Zero when the
    /// sampler is off or no key crossed the threshold (the catalog then
    /// holds plain `Hash` parts and the skew machinery never engages).
    pub hot_keys_detected: u64,
    /// Hot probe-side rows the skew join strategies routed by the salt
    /// rule instead of the oblivious hash home (salted fan-out) or kept
    /// at their source against a replicated build side (broadcast-hot).
    pub rows_salted: u64,
    /// Bytes of hot build-side rows replicated beyond their first copy
    /// by the skew strategies — the traffic paid to flatten the hot
    /// shard (also included in `bytes_shuffled`).
    pub bytes_hot_replicated: u64,
}

impl ExecStats {
    /// Accumulate another execution (e.g. backward after forward).
    pub fn merge(&mut self, other: &ExecStats) {
        self.virtual_time_s += other.virtual_time_s;
        self.wall_s += other.wall_s;
        self.compute_s += other.compute_s;
        self.net_s += other.net_s;
        self.spill_s += other.spill_s;
        self.bytes_shuffled += other.bytes_shuffled;
        self.bytes_shuffle_elided += other.bytes_shuffle_elided;
        self.shuffles_elided += other.shuffles_elided;
        self.bytes_ingested += other.bytes_ingested;
        self.msgs += other.msgs;
        self.spill_passes += other.spill_passes;
        self.spill_bytes_written += other.spill_bytes_written;
        self.spill_bytes_read += other.spill_bytes_read;
        self.stages += other.stages;
        self.faults_injected += other.faults_injected;
        self.stage_retries += other.stage_retries;
        self.shards_recomputed += other.shards_recomputed;
        self.checkpoint_bytes += other.checkpoint_bytes;
        self.delta_rows_applied += other.delta_rows_applied;
        self.shards_reused += other.shards_reused;
        self.delta_fallbacks += other.delta_fallbacks;
        self.hot_keys_detected += other.hot_keys_detected;
        self.rows_salted += other.rows_salted;
        self.bytes_hot_replicated += other.bytes_hot_replicated;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_stats_merge_sums_every_field() {
        let mut a = ExecStats {
            virtual_time_s: 1.5,
            wall_s: 2.5,
            compute_s: 1.0,
            net_s: 0.25,
            spill_s: 0.25,
            bytes_shuffled: 100,
            bytes_shuffle_elided: 20,
            shuffles_elided: 1,
            bytes_ingested: 50,
            msgs: 4,
            spill_passes: 2,
            spill_bytes_written: 300,
            spill_bytes_read: 300,
            stages: 7,
            faults_injected: 2,
            stage_retries: 1,
            shards_recomputed: 4,
            checkpoint_bytes: 128,
            delta_rows_applied: 10,
            shards_reused: 6,
            delta_fallbacks: 1,
            hot_keys_detected: 2,
            rows_salted: 60,
            bytes_hot_replicated: 900,
        };
        let b = ExecStats {
            virtual_time_s: 0.5,
            wall_s: 0.5,
            compute_s: 0.25,
            net_s: 0.125,
            spill_s: 0.125,
            bytes_shuffled: 11,
            bytes_shuffle_elided: 7,
            shuffles_elided: 2,
            bytes_ingested: 5,
            msgs: 3,
            spill_passes: 1,
            spill_bytes_written: 40,
            spill_bytes_read: 30,
            stages: 5,
            faults_injected: 3,
            stage_retries: 2,
            shards_recomputed: 8,
            checkpoint_bytes: 72,
            delta_rows_applied: 5,
            shards_reused: 3,
            delta_fallbacks: 2,
            hot_keys_detected: 1,
            rows_salted: 7,
            bytes_hot_replicated: 100,
        };
        a.merge(&b);
        assert_eq!(a.virtual_time_s, 2.0);
        assert_eq!(a.wall_s, 3.0);
        assert_eq!(a.compute_s, 1.25);
        assert_eq!(a.net_s, 0.375);
        assert_eq!(a.spill_s, 0.375);
        assert_eq!(a.bytes_shuffled, 111);
        assert_eq!(a.bytes_shuffle_elided, 27);
        assert_eq!(a.shuffles_elided, 3);
        assert_eq!(a.bytes_ingested, 55);
        assert_eq!(a.msgs, 7);
        assert_eq!(a.spill_passes, 3);
        assert_eq!(a.spill_bytes_written, 340);
        assert_eq!(a.spill_bytes_read, 330);
        assert_eq!(a.stages, 12);
        assert_eq!(a.faults_injected, 5);
        assert_eq!(a.stage_retries, 3);
        assert_eq!(a.shards_recomputed, 12);
        assert_eq!(a.checkpoint_bytes, 200);
        assert_eq!(a.delta_rows_applied, 15);
        assert_eq!(a.shards_reused, 9);
        assert_eq!(a.delta_fallbacks, 3);
        assert_eq!(a.hot_keys_detected, 3);
        assert_eq!(a.rows_salted, 67);
        assert_eq!(a.bytes_hot_replicated, 1000);
        // merging a default is the identity
        let before = a;
        a.merge(&ExecStats::default());
        assert_eq!(a, before);
    }

    #[test]
    fn cluster_config_builders() {
        let c = ClusterConfig::new(4).with_budget(1 << 20).with_policy(MemPolicy::Fail);
        assert_eq!(c.workers, 4);
        assert_eq!(c.budget, Some(1 << 20));
        assert_eq!(c.policy, MemPolicy::Fail);
        assert_eq!(c.spill_dir, None);
        let c2 = c.clone().with_spill_dir("/tmp/relad-scratch");
        assert_eq!(
            c2.spill_dir.as_deref(),
            Some(std::path::Path::new("/tmp/relad-scratch"))
        );
        assert!(c.parallel && c.parallel_comm, "threading defaults on");
        let c = c.with_parallel_comm(false);
        assert!(c.parallel && !c.parallel_comm);
        let c = c.with_parallel(false);
        assert!(!c.parallel);
        assert!(
            c.factorize_agg && c.elide_shuffles,
            "factorized evaluation defaults on"
        );
        let c = c.with_factorize_agg(false);
        assert!(!c.factorize_agg && c.elide_shuffles);
        let c = c.with_elide_shuffles(false).with_factorize(true);
        assert!(c.factorize_agg && c.elide_shuffles);
        let c = c.with_factorize(false);
        assert!(!c.factorize_agg && !c.elide_shuffles);
        assert!(c.fault_plan.is_none(), "fault injection defaults off");
        assert_eq!(c.max_stage_retries, 2);
        let c = c
            .with_fault_plan(fault::FaultPlan::seeded(9, 0.1))
            .with_max_stage_retries(5);
        assert!(c.fault_plan.is_some());
        assert_eq!(c.max_stage_retries, 5);
        assert_eq!(c.skew_threshold, None, "skew sampler defaults off");
        assert_eq!(c.skew_salts, 0, "salt fan-out defaults to auto");
        let c = c.with_skew_threshold(0.05).with_skew_salts(3);
        assert_eq!(c.skew_threshold, Some(0.05));
        assert_eq!(c.skew_salts, 3);
    }

    #[test]
    #[should_panic(expected = "skew threshold")]
    fn skew_threshold_rejects_out_of_range() {
        let _ = ClusterConfig::new(2).with_skew_threshold(1.5);
    }

    #[test]
    fn cluster_config_default_is_one_local_worker() {
        let c = ClusterConfig::default();
        assert_eq!(c.workers, 1);
        assert_eq!(c.budget, None);
        assert_eq!(c.policy, MemPolicy::Spill);
        assert!(c.parallel && c.parallel_comm);
        assert!(c.factorize_agg && c.elide_shuffles);
        assert_eq!(c.skew_threshold, None);
        assert_eq!(c.skew_salts, 0);
    }

    #[test]
    fn dist_error_display() {
        let e = DistError::Oom {
            worker: 3,
            needed: 2048,
            budget: 1024,
        };
        let s = format!("{e}");
        assert!(s.contains("worker 3"));
        assert!(s.contains("2048"));
        let o: DistError = anyhow::anyhow!("boom").into();
        assert_eq!(format!("{o}"), "boom");
        let t = DistError::Transient {
            worker: 1,
            what: "spill read failed".into(),
        };
        assert!(format!("{t}").contains("transient failure on worker 1"));
        let sf = DistError::StageFailed {
            stage: 4,
            worker: 2,
            attempts: 3,
            source: StageFailure::RetriesExhausted("injected fault".into()),
        };
        let s = format!("{sf}");
        assert!(s.contains("stage v4") && s.contains("worker 2") && s.contains("3 attempt(s)"));
        assert!(s.contains("retries exhausted"));
        let ff = StageFailure::FatalJob("index out of bounds".into());
        assert!(format!("{ff}").contains("fatal job panic"));
    }
}
