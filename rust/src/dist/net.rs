//! Network cost model for the virtual cluster: per-byte bandwidth plus
//! per-message latency, with closed forms for the collectives the
//! executor and the `baselines` charge. Compute on the virtual cluster
//! is *measured*; communication is *modeled* through this one struct so
//! the RA engine and every comparator system pay the same prices.
//!
//! The model prices `ExecStats::net_s` (a `virtual_time_s` term) from
//! the exact byte/message counts `shuffle` reports; those counts are
//! independent of *how* an exchange executed — the pooled all-to-all and
//! the driver-serial path move identical tuples, so `net_s` is identical
//! on both. (The *compute* terms of `virtual_time_s` are measured, so
//! they differ between execution modes the way any two measurements do —
//! see the Σ-merge accounting note in `exec::Executor::eval_agg`.)

/// A symmetric full-bisection fabric: every worker has one `bandwidth_bps`
/// link, and every point-to-point message pays `latency_s` up front.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetModel {
    /// Sustained per-link bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// Per-message latency, seconds.
    pub latency_s: f64,
}

impl Default for NetModel {
    /// 10 GbE-class fabric (the paper's m5.4xlarge cluster): 1.25 GB/s
    /// per link, 50 µs per message.
    fn default() -> NetModel {
        NetModel {
            bandwidth_bps: 1.25e9,
            latency_s: 50e-6,
        }
    }
}

impl NetModel {
    /// Raw serialized transfer: `bytes` over one link in `msgs` messages.
    pub fn xfer_time(&self, bytes: u64, msgs: u64) -> f64 {
        self.latency_s * msgs as f64 + bytes as f64 / self.bandwidth_bps
    }

    /// All-to-all re-partition of a relation totalling `bytes`, spread
    /// evenly across `workers`: each worker re-homes the `(w-1)/w`
    /// fraction of its `bytes/w` share, all links in parallel.
    pub fn shuffle_time(&self, bytes: u64, workers: usize) -> f64 {
        if workers <= 1 {
            return 0.0;
        }
        let w = workers as f64;
        self.latency_s * (w - 1.0) + bytes as f64 * (w - 1.0) / (w * w * self.bandwidth_bps)
    }

    /// Measured all-to-all: `bytes` actually crossed the network in
    /// `msgs` point-to-point messages, links in parallel. Used by the
    /// executor with the exact counts from `shuffle::exchange`.
    pub fn alltoall_time(&self, bytes: u64, msgs: u64, workers: usize) -> f64 {
        if workers <= 1 || (bytes == 0 && msgs == 0) {
            return 0.0;
        }
        self.latency_s * msgs as f64 + bytes as f64 / (self.bandwidth_bps * workers as f64)
    }

    /// Ring allgather: every worker ends up holding the full
    /// `bytes`-size relation.
    pub fn allgather_time(&self, bytes: u64, workers: usize) -> f64 {
        if workers <= 1 {
            return 0.0;
        }
        let w = workers as f64;
        self.latency_s * (w - 1.0) + bytes as f64 * (w - 1.0) / (w * self.bandwidth_bps)
    }

    /// BSP straggler wait: how long the barrier sits idle because one
    /// worker holds `max_bytes` of join input while the even share is
    /// `total_bytes / workers`. The excess is priced as a serialized
    /// single-link transfer — the time the overloaded worker spends
    /// processing bytes the others have already finished with. This is
    /// what the skew strategies buy back when they pay
    /// `bytes_hot_replicated` to flatten the load.
    pub fn straggler_wait(&self, max_bytes: u64, total_bytes: u64, workers: usize) -> f64 {
        if workers <= 1 {
            return 0.0;
        }
        let fair = total_bytes / workers as u64;
        let excess = max_bytes.saturating_sub(fair);
        if excess == 0 {
            return 0.0;
        }
        self.xfer_time(excess, 1)
    }

    /// Ring allreduce of a `bytes`-size buffer replicated on every
    /// worker (reduce-scatter + allgather).
    pub fn allreduce_time(&self, bytes: u64, workers: usize) -> f64 {
        if workers <= 1 {
            return 0.0;
        }
        let w = workers as f64;
        2.0 * self.latency_s * (w - 1.0)
            + 2.0 * bytes as f64 * (w - 1.0) / (w * self.bandwidth_bps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_worker_communicates_nothing() {
        let n = NetModel::default();
        assert_eq!(n.shuffle_time(1 << 30, 1), 0.0);
        assert_eq!(n.allgather_time(1 << 30, 1), 0.0);
        assert_eq!(n.allreduce_time(1 << 30, 1), 0.0);
        assert_eq!(n.alltoall_time(1 << 30, 99, 1), 0.0);
    }

    #[test]
    fn latency_and_bandwidth_terms_separate() {
        let n = NetModel {
            bandwidth_bps: 1e9,
            latency_s: 1e-4,
        };
        // Zero bytes: pure latency.
        assert!((n.shuffle_time(0, 5) - 4e-4).abs() < 1e-12);
        // Bandwidth term grows linearly in bytes.
        let t1 = n.shuffle_time(1_000_000, 5);
        let t2 = n.shuffle_time(2_000_000, 5);
        let bw1 = t1 - 4e-4;
        let bw2 = t2 - 4e-4;
        assert!((bw2 - 2.0 * bw1).abs() < 1e-12);
    }

    #[test]
    fn alltoall_charges_exact_message_count() {
        let n = NetModel {
            bandwidth_bps: 1e9,
            latency_s: 1e-3,
        };
        let t = n.alltoall_time(0, 7, 4);
        assert!((t - 7e-3).abs() < 1e-12);
        // bytes ride parallel links
        let t = n.alltoall_time(4_000_000, 0, 4);
        assert!((t - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn straggler_wait_prices_only_the_excess() {
        let n = NetModel {
            bandwidth_bps: 1e9,
            latency_s: 1e-4,
        };
        // Balanced load, or a single worker: nothing to wait on.
        assert_eq!(n.straggler_wait(250, 1000, 4), 0.0);
        assert_eq!(n.straggler_wait(1000, 1000, 1), 0.0);
        // One worker holds half the bytes across 4 workers: the wait is
        // a serialized transfer of the 250-byte excess.
        let t = n.straggler_wait(500, 1000, 4);
        assert!((t - n.xfer_time(250, 1)).abs() < 1e-15);
        // More skew, longer wait.
        assert!(n.straggler_wait(900, 1000, 4) > t);
    }

    #[test]
    fn allreduce_costs_about_twice_allgather() {
        let n = NetModel::default();
        let ag = n.allgather_time(1 << 20, 8);
        let ar = n.allreduce_time(1 << 20, 8);
        assert!((ar - 2.0 * ag).abs() < 1e-9);
    }
}
