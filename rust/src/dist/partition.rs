//! Relations sharded across virtual workers, and the partitioning
//! invariants the distributed planner reasons about.
//!
//! A [`PartitionedRelation`] is the unit every `dist::exec` stage
//! consumes and produces. Its [`Partitioning`] tag records *where each
//! tuple provably lives*, which is what lets `plan_join` recognise
//! co-partitioned joins (no traffic) and lets two-phase aggregation skip
//! its exchange when the grouping key already determines the worker.
//!
//! Shards are `Arc<Relation>` handles: cloning a `PartitionedRelation`
//! (tape capture, `dist_eval` returning tape outputs, replication) is a
//! reference-count bump, never a deep copy of chunk data. The executor's
//! worker threads read the same shard storage they would mmap on a real
//! node.
//!
//! Data movement between layouts has a serial reference implementation
//! and a pooled one ([`reshuffle_in`](PartitionedRelation::reshuffle_in),
//! [`gather_in`](PartitionedRelation::gather_in) with a
//! [`WorkerPool`]) that shards the route/build work across the pool's
//! worker threads while producing byte-identical relations — the
//! executor picks the pooled path whenever a pool of matching width is
//! running and `ClusterConfig::parallel_comm` is on.

use std::sync::Arc;

use super::pool::WorkerPool;
use super::shuffle::{self, ShuffleStats};
use crate::ra::Relation;
use crate::util::{FxHashMap, FxHashSet};

/// Where tuples of a sharded relation live.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Partitioning {
    /// Tuple with key `k` lives on worker
    /// `k.stable_hash_of(comps) % w` — the invariant `hash_partition`
    /// establishes and `reshuffle` restores.
    Hash(Vec<usize>),
    /// Every worker holds a complete copy (model parameters, constants,
    /// gradient seeds).
    Replicated,
    /// Each tuple lives on exactly one worker, but no invariant relates
    /// key to worker (e.g. a join output whose projection dropped the
    /// partitioning components).
    Arbitrary,
    /// Hash-partitioned exactly like [`Hash`](Partitioning::Hash) on
    /// `comps` — tuple placement is bit-identical — but the ingest-time
    /// sampler flagged `hot` as heavy hitters: projected sub-keys
    /// (arity `comps.len()`, sorted, deduplicated) whose sampled
    /// frequency crossed `ClusterConfig::skew_threshold`. The planner
    /// uses the annotation to consider salted/replicated join
    /// strategies; every operator otherwise treats this exactly like
    /// `Hash(comps)` (see [`hash_comps`](Partitioning::hash_comps)), so
    /// the metadata degrades to plain `Hash` through joins, Σ, and
    /// reshuffles. The hot set is frozen at `register` time; deltas
    /// route by the same hash and never update it.
    SkewHash {
        comps: Vec<usize>,
        hot: Arc<[crate::ra::Key]>,
    },
}

impl Partitioning {
    /// The hash components when tuples provably live at
    /// `owner(key, comps, w)` — `Some` for both `Hash` and `SkewHash`
    /// (whose placement is identical), `None` otherwise. Operators that
    /// reason about hash placement (Σ fast path, aligned `+`, factorize
    /// legality, join output parts) must go through this so a skew
    /// annotation never changes plan shape relative to plain `Hash`.
    pub fn hash_comps(&self) -> Option<&[usize]> {
        match self {
            Partitioning::Hash(c) => Some(c),
            Partitioning::SkewHash { comps, .. } => Some(comps),
            _ => None,
        }
    }

    /// The sampled heavy-hitter sub-keys, if any (`SkewHash` only).
    pub fn hot_keys(&self) -> Option<&[crate::ra::Key]> {
        match self {
            Partitioning::SkewHash { hot, .. } => Some(hot),
            _ => None,
        }
    }
}

/// A relation split across `w` virtual workers.
#[derive(Clone)]
pub struct PartitionedRelation {
    /// One shard handle per worker. Under `Replicated`, each handle is
    /// the full relation (typically the *same* `Arc`); otherwise shards
    /// are disjoint by key.
    pub shards: Vec<Arc<Relation>>,
    pub part: Partitioning,
}

impl PartitionedRelation {
    pub fn from_shards(shards: Vec<Relation>, part: Partitioning) -> PartitionedRelation {
        PartitionedRelation::from_shard_handles(shards.into_iter().map(Arc::new).collect(), part)
    }

    pub fn from_shard_handles(
        shards: Vec<Arc<Relation>>,
        part: Partitioning,
    ) -> PartitionedRelation {
        assert!(!shards.is_empty(), "a cluster needs at least one worker");
        PartitionedRelation { shards, part }
    }

    /// Hash-partition on a subset of key components (e.g. edges on the
    /// source vertex: `hash_partition(&edges, &[0], w)`).
    pub fn hash_partition(rel: &Relation, comps: &[usize], w: usize) -> PartitionedRelation {
        assert!(w >= 1, "a cluster needs at least one worker");
        let mut shards: Vec<Relation> = (0..w).map(|_| Relation::new()).collect();
        for (k, v) in rel.iter() {
            shards[shuffle::owner(k, comps, w)].insert(*k, v.clone());
        }
        PartitionedRelation::from_shards(shards, Partitioning::Hash(comps.to_vec()))
    }

    /// Hash-partition on the full key.
    pub fn hash_full(rel: &Relation, w: usize) -> PartitionedRelation {
        let arity = rel.key_arity().unwrap_or(0);
        let comps: Vec<usize> = (0..arity).collect();
        PartitionedRelation::hash_partition(rel, &comps, w)
    }

    /// Full copy on every worker — one shared allocation, `w` handles.
    pub fn replicate(rel: &Relation, w: usize) -> PartitionedRelation {
        PartitionedRelation::replicate_handle(Arc::new(rel.clone()), w)
    }

    /// As [`replicate`](Self::replicate), from an existing handle (no
    /// copy at all).
    pub fn replicate_handle(rel: Arc<Relation>, w: usize) -> PartitionedRelation {
        assert!(w >= 1, "a cluster needs at least one worker");
        PartitionedRelation {
            shards: vec![rel; w],
            part: Partitioning::Replicated,
        }
    }

    pub fn workers(&self) -> usize {
        self.shards.len()
    }

    pub fn is_replicated(&self) -> bool {
        matches!(self.part, Partitioning::Replicated)
    }

    /// Is this relation hash-partitioned on exactly `comps`?
    /// `SkewHash` qualifies: its placement is identical to `Hash`.
    pub fn is_hash_on(&self, comps: &[usize]) -> bool {
        matches!(self.part.hash_comps(), Some(c) if c == comps)
    }

    /// Number of distinct tuples.
    pub fn len(&self) -> usize {
        if self.is_replicated() {
            self.shards[0].len()
        } else {
            self.shards.iter().map(|s| s.len()).sum()
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Payload bytes of the distinct tuples (one replica).
    pub fn nbytes(&self) -> u64 {
        if self.is_replicated() {
            self.shards[0].nbytes() as u64
        } else {
            self.shards.iter().map(|s| s.nbytes() as u64).sum()
        }
    }

    /// Largest single-shard payload, in bytes — the per-worker resident
    /// cost the memory policies meter. Budget pickers (the spill tests
    /// and `bench_dist`'s low-memory column) size per-worker budgets
    /// against this to force a known number of grace passes.
    pub fn max_shard_bytes(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.nbytes() as u64)
            .max()
            .unwrap_or(0)
    }

    /// Key width, 0 when empty.
    pub fn key_arity(&self) -> usize {
        self.shards
            .iter()
            .find_map(|s| s.key_arity())
            .unwrap_or(0)
    }

    /// Collect the full relation back on the driver. Non-replicated
    /// shards must be key-disjoint (the executor maintains this).
    pub fn gather(&self) -> Relation {
        self.gather_in(None)
    }

    /// As [`gather`](Self::gather), optionally sharding the work across
    /// a worker pool of matching width. The pooled arm parallelises the
    /// *index build* too: per-shard prefix sums give each worker its
    /// slice of the concatenated relation, so every worker hashes its
    /// own keys into a map of **global** positions and the driver's only
    /// serial work is concatenating pairs (chunk handle bumps) and
    /// unioning the maps — growing the largest one in place rather than
    /// re-hashing every key. The output relation is bitwise identical to
    /// the serial path, including the duplicate-key panic: a shrunken
    /// union means two shards shared a key, and a serial re-scan in
    /// worker order reports the exact first offender.
    pub fn gather_in(&self, pool: Option<&WorkerPool>) -> Relation {
        if self.is_replicated() {
            return (*self.shards[0]).clone();
        }
        match pool {
            Some(p) if p.workers() == self.shards.len() && self.shards.len() > 1 => {
                let mut base = 0u32;
                let jobs: Vec<(Arc<Relation>, u32)> = self
                    .shards
                    .iter()
                    .map(|s| {
                        let job = (s.clone(), base);
                        base += s.len() as u32;
                        job
                    })
                    .collect();
                let mut parts =
                    p.run_with(jobs, |_, (shard, base): (Arc<Relation>, u32), _| {
                        let pairs = shard.pairs().to_vec();
                        let mut index = FxHashMap::with_capacity_and_hasher(
                            pairs.len(),
                            Default::default(),
                        );
                        for (i, (k, _)) in pairs.iter().enumerate() {
                            index.insert(*k, base + i as u32);
                        }
                        (pairs, index)
                    });
                let total: usize = parts.iter().map(|(pairs, _)| pairs.len()).sum();
                // Values are global positions, so union order is
                // irrelevant; start from the largest map to move the
                // fewest entries.
                let largest = parts
                    .iter()
                    .enumerate()
                    .max_by_key(|(_, (_, m))| m.len())
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                let mut index = std::mem::take(&mut parts[largest].1);
                for (i, (_, m)) in parts.iter_mut().enumerate() {
                    if i != largest {
                        for (k, id) in m.drain() {
                            index.insert(k, id);
                        }
                    }
                }
                if index.len() != total {
                    // Duplicate across shards: find the first offender in
                    // worker order so the panic matches serial `insert`.
                    let mut seen = FxHashSet::default();
                    for (pairs, _) in &parts {
                        for (k, _) in pairs {
                            assert!(
                                seen.insert(*k),
                                "duplicate key {k} inserted into relation"
                            );
                        }
                    }
                    unreachable!("index union shrank but no duplicate found");
                }
                let mut pairs = Vec::with_capacity(total);
                for (part, _) in parts {
                    pairs.extend(part);
                }
                Relation::from_pairs_indexed(pairs, index)
            }
            _ => {
                let mut out = Relation::with_capacity(self.len());
                for shard in &self.shards {
                    for (k, v) in shard.iter() {
                        out.insert(*k, v.clone());
                    }
                }
                out
            }
        }
    }

    /// Re-home every tuple by the hash of `comps` across `w` workers,
    /// returning the moved-byte accounting the executor charges to the
    /// network model. Deterministic: assignment depends only on
    /// (key, comps, w).
    pub fn reshuffle(&self, comps: &[usize], w: usize) -> (PartitionedRelation, ShuffleStats) {
        self.reshuffle_in(comps, w, None)
    }

    /// As [`reshuffle`](Self::reshuffle), optionally as a parallel
    /// all-to-all on a worker pool of matching width (every source
    /// worker routes its shard concurrently, every destination worker
    /// builds its new shard concurrently). Shards and traffic counters
    /// are bitwise identical to the serial exchange.
    pub fn reshuffle_in(
        &self,
        comps: &[usize],
        w: usize,
        pool: Option<&WorkerPool>,
    ) -> (PartitionedRelation, ShuffleStats) {
        if self.is_replicated() {
            // Every worker already holds every tuple: each keeps its hash
            // share and drops the rest — no traffic.
            return (
                PartitionedRelation::hash_partition(&self.shards[0], comps, w),
                ShuffleStats::default(),
            );
        }
        if self.shards.len() == w && self.is_hash_on(comps) {
            return (self.clone(), ShuffleStats::default());
        }
        let (shards, stats) = match pool {
            Some(p) if p.workers() == w && self.shards.len() == w => {
                let (shards, stats, _timing) =
                    shuffle::exchange_pooled(self.shards.clone(), comps, w, p);
                (shards, stats)
            }
            _ => shuffle::exchange(&self.shards, comps, w),
        };
        (
            PartitionedRelation::from_shards(shards, Partitioning::Hash(comps.to_vec())),
            stats,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ra::{Chunk, Key};
    use crate::util::Prng;

    fn sample(seed: u64, n: i64) -> Relation {
        let mut rng = Prng::new(seed);
        let mut r = Relation::new();
        for i in 0..n {
            r.insert(
                Key::k2(i, (i * 7) % 5),
                Chunk::random(2, 2, &mut rng, 1.0),
            );
        }
        r
    }

    #[test]
    fn partition_gather_roundtrip_and_len() {
        let r = sample(1, 30);
        for w in [1usize, 2, 5, 8] {
            let p = PartitionedRelation::hash_partition(&r, &[1], w);
            assert_eq!(p.workers(), w);
            assert_eq!(p.len(), r.len());
            assert_eq!(p.nbytes(), r.nbytes() as u64);
            assert!(p.gather().approx_eq(&r, 0.0));
            // The biggest shard is between the ideal share and the whole.
            let m = p.max_shard_bytes();
            assert!(m >= p.nbytes() / w as u64);
            assert!(m <= p.nbytes());
        }
        // Replicated: every "shard" is the full relation.
        let p = PartitionedRelation::replicate(&r, 3);
        assert_eq!(p.max_shard_bytes(), r.nbytes() as u64);
    }

    #[test]
    fn replicate_holds_full_copies() {
        let r = sample(2, 10);
        let p = PartitionedRelation::replicate(&r, 4);
        assert!(p.is_replicated());
        assert_eq!(p.len(), r.len());
        for s in &p.shards {
            assert!(s.approx_eq(&r, 0.0));
        }
        assert!(p.gather().approx_eq(&r, 0.0));
    }

    #[test]
    fn replicate_shares_one_allocation() {
        let r = sample(4, 10);
        let p = PartitionedRelation::replicate(&r, 4);
        for s in &p.shards[1..] {
            assert!(Arc::ptr_eq(&p.shards[0], s));
        }
        // Cloning the partitioned relation is a handle copy too.
        let q = p.clone();
        assert!(Arc::ptr_eq(&p.shards[0], &q.shards[0]));
    }

    #[test]
    fn reshuffle_is_deterministic() {
        // Same seed + comps ⇒ bit-identical partition assignment, run to
        // run and copy to copy.
        let a = sample(42, 40);
        let b = sample(42, 40);
        let pa = PartitionedRelation::hash_full(&a, 6);
        let pb = PartitionedRelation::hash_full(&b, 6);
        let (ra, _) = pa.reshuffle(&[1], 6);
        let (rb, _) = pb.reshuffle(&[1], 6);
        assert!(ra.is_hash_on(&[1]));
        for (sa, sb) in ra.shards.iter().zip(rb.shards.iter()) {
            assert_eq!(sa.len(), sb.len());
            assert!(sa.approx_eq(sb, 0.0));
        }
        // And a second reshuffle of the same data is a no-op move.
        let (rc, st) = ra.reshuffle(&[1], 6);
        assert_eq!(st, ShuffleStats::default());
        assert!(rc.gather().approx_eq(&a, 0.0));
    }

    #[test]
    fn pooled_gather_and_reshuffle_match_serial() {
        let r = sample(9, 50);
        let w = 4;
        let pool = WorkerPool::new(w, &crate::kernels::NativeBackend);
        let p = PartitionedRelation::hash_partition(&r, &[0], w);
        // Pooled gather: same tuples in the same insertion order.
        let gs = p.gather();
        let gp = p.gather_in(Some(&pool));
        assert_eq!(gs.len(), gp.len());
        for (a, b) in gs.iter().zip(gp.iter()) {
            assert_eq!(a.0, b.0);
            assert!(a.1.approx_eq(&b.1, 0.0));
        }
        // Pooled reshuffle: same shards, same traffic counters.
        let (qs, sts) = p.reshuffle(&[1], w);
        let (qp, stp) = p.reshuffle_in(&[1], w, Some(&pool));
        assert_eq!(sts, stp);
        assert!(qp.is_hash_on(&[1]));
        for (a, b) in qs.shards.iter().zip(qp.shards.iter()) {
            assert_eq!(a.len(), b.len());
            assert!(a.approx_eq(b, 0.0));
        }
        // Width mismatch falls back to the serial path (still correct).
        let (qf, stf) = p.reshuffle_in(&[1], w + 1, Some(&pool));
        assert!(qf.gather().approx_eq(&r, 0.0));
        assert!(stf.bytes > 0);
    }

    #[test]
    fn pooled_gather_index_serves_lookups() {
        // The merged global-id index must answer `get` for every key —
        // exercised across shard-count > 2 so the largest-map-base merge
        // actually unions several maps.
        let r = sample(13, 60);
        let w = 4;
        let pool = WorkerPool::new(w, &crate::kernels::NativeBackend);
        let p = PartitionedRelation::hash_partition(&r, &[0], w);
        let g = p.gather_in(Some(&pool));
        assert_eq!(g.len(), r.len());
        for (k, v) in r.iter() {
            assert!(g.get(k).unwrap().approx_eq(v, 0.0));
        }
    }

    #[test]
    #[should_panic(expected = "duplicate key")]
    fn pooled_gather_panics_on_cross_shard_duplicate() {
        let w = 2;
        let pool = WorkerPool::new(w, &crate::kernels::NativeBackend);
        let mut a = Relation::new();
        a.insert(Key::k1(7), Chunk::scalar(1.0));
        let mut b = Relation::new();
        b.insert(Key::k1(7), Chunk::scalar(2.0));
        let p = PartitionedRelation::from_shards(vec![a, b], Partitioning::Arbitrary);
        let _ = p.gather_in(Some(&pool));
    }

    #[test]
    fn skew_hash_places_like_hash_and_survives_noop_reshuffle() {
        let r = sample(7, 40);
        let w = 4;
        let hash = PartitionedRelation::hash_partition(&r, &[1], w);
        let mut skew = hash.clone();
        skew.part = Partitioning::SkewHash {
            comps: vec![1],
            hot: vec![Key::k1(0)].into(),
        };
        // Same hash contract: is_hash_on and hash_comps agree with Hash.
        assert!(skew.is_hash_on(&[1]));
        assert!(!skew.is_hash_on(&[0]));
        assert_eq!(skew.part.hash_comps(), Some(&[1usize][..]));
        assert_eq!(skew.part.hot_keys(), Some(&[Key::k1(0)][..]));
        assert_eq!(hash.part.hot_keys(), None);
        // A no-op reshuffle onto the same comps keeps the annotation.
        let (same, st) = skew.reshuffle(&[1], w);
        assert_eq!(st, ShuffleStats::default());
        assert_eq!(same.part, skew.part);
        // Moving onto other comps degrades to plain Hash.
        let (moved, _) = skew.reshuffle(&[0], w);
        assert_eq!(moved.part, Partitioning::Hash(vec![0]));
        // Arc<[Key]> compares by contents, not pointer.
        let again = Partitioning::SkewHash {
            comps: vec![1],
            hot: vec![Key::k1(0)].into(),
        };
        assert_eq!(skew.part, again);
    }

    #[test]
    fn replicated_reshuffle_moves_no_bytes() {
        let r = sample(3, 20);
        let p = PartitionedRelation::replicate(&r, 3);
        let (q, st) = p.reshuffle(&[0], 3);
        assert_eq!(st, ShuffleStats::default());
        assert!(q.is_hash_on(&[0]));
        assert!(q.gather().approx_eq(&r, 0.0));
    }
}
