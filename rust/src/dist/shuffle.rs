//! Tuple routing between virtual workers, with exact accounting of the
//! bytes and point-to-point messages that would cross a real network.
//! Routing is by the *stable* key hash (`Key::stable_hash_of`), so the
//! assignment is a pure function of (key, comps, w): identical on every
//! worker, across runs, and across re-executions — the property the
//! partition-invariance tests and tape replay rely on.

use crate::ra::{Chunk, Key, Relation};

/// Bytes/messages moved by one exchange. Messages are counted per
/// (source, destination) pair that carried at least one tuple — the
/// batching a real shuffle service does.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShuffleStats {
    /// Payload bytes that left their worker.
    pub bytes: u64,
    /// Distinct (src, dst) links used, src ≠ dst.
    pub msgs: u64,
}

/// Worker owning `key` under a hash partitioning on `comps`.
#[inline]
pub fn owner(key: &Key, comps: &[usize], w: usize) -> usize {
    (key.stable_hash_of(comps) % w as u64) as usize
}

/// Serialized size of one tuple (key + chunk payload).
#[inline]
pub fn tuple_bytes(v: &Chunk) -> u64 {
    (v.nbytes() + std::mem::size_of::<Key>()) as u64
}

/// Route every tuple of `shards` to `owner(key, comps, w)`. Keys must be
/// globally unique (relations are functions); duplicates panic. Generic
/// over the shard handle (`Relation` or `Arc<Relation>`): routing only
/// copies chunk *handles*, never chunk data.
pub fn exchange<S: std::borrow::Borrow<Relation>>(
    shards: &[S],
    comps: &[usize],
    w: usize,
) -> (Vec<Relation>, ShuffleStats) {
    exchange_with(shards, comps, w, |dst, k, v| dst.insert(k, v))
}

/// As `exchange`, but colliding keys at a destination are combined — the
/// final merge of a two-phase aggregation, where each source worker
/// holds a partial value per group key.
pub fn exchange_merge<S: std::borrow::Borrow<Relation>>(
    shards: &[S],
    comps: &[usize],
    w: usize,
    combine: impl Fn(&mut Chunk, &Chunk),
) -> (Vec<Relation>, ShuffleStats) {
    exchange_with(shards, comps, w, |dst, k, v| {
        dst.merge(k, v, |acc, x| combine(acc, x))
    })
}

fn exchange_with<S: std::borrow::Borrow<Relation>>(
    shards: &[S],
    comps: &[usize],
    w: usize,
    deposit: impl Fn(&mut Relation, Key, Chunk),
) -> (Vec<Relation>, ShuffleStats) {
    let n_src = shards.len();
    let mut out: Vec<Relation> = (0..w).map(|_| Relation::new()).collect();
    let mut stats = ShuffleStats::default();
    let mut link = vec![false; n_src * w];
    for (src, shard) in shards.iter().enumerate() {
        for (k, v) in shard.borrow().iter() {
            let dst = owner(k, comps, w);
            if dst != src {
                stats.bytes += tuple_bytes(v);
                if !link[src * w + dst] {
                    link[src * w + dst] = true;
                    stats.msgs += 1;
                }
            }
            deposit(&mut out[dst], *k, v.clone());
        }
    }
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn exchange_accounts_moved_bytes_exactly() {
        let mut rng = Prng::new(0x5AFE);
        let mut r = Relation::new();
        for i in 0..24 {
            r.insert(Key::k1(i), Chunk::random(2, 3, &mut rng, 1.0));
        }
        let w = 3;
        // Everything starts on worker 0; each tuple not owned by 0 moves.
        let mut shards: Vec<Relation> = (0..w).map(|_| Relation::new()).collect();
        shards[0] = r.clone();
        let mut want_bytes = 0u64;
        let mut want_links = std::collections::BTreeSet::new();
        for (k, v) in r.iter() {
            let d = owner(k, &[0], w);
            if d != 0 {
                want_bytes += tuple_bytes(v);
                want_links.insert(d);
            }
        }
        assert!(want_bytes > 0, "degenerate test: nothing moved");
        let (out, st) = exchange(&shards, &[0], w);
        assert_eq!(st.bytes, want_bytes);
        assert_eq!(st.msgs, want_links.len() as u64);
        assert_eq!(out.iter().map(|s| s.len()).sum::<usize>(), r.len());
        // Already-placed tuples move for free.
        let (out2, st2) = exchange(&out, &[0], w);
        assert_eq!(st2, ShuffleStats::default());
        assert_eq!(out2.iter().map(|s| s.len()).sum::<usize>(), r.len());
    }

    #[test]
    fn exchange_merge_combines_partials() {
        // Two workers each hold a partial for the same group key.
        let a = Relation::from_pairs(vec![(Key::k1(7), Chunk::scalar(1.0))]);
        let b = Relation::from_pairs(vec![(Key::k1(7), Chunk::scalar(2.0))]);
        let (out, _) = exchange_merge(&[a, b], &[0], 2, |acc, x| acc.add_assign(x));
        let total: usize = out.iter().map(|s| s.len()).sum();
        assert_eq!(total, 1);
        let d = owner(&Key::k1(7), &[0], 2);
        assert_eq!(out[d].get(&Key::k1(7)).unwrap().as_scalar(), 3.0);
    }

    #[test]
    fn owner_is_stable_and_respects_comps() {
        // Same comp values ⇒ same owner, regardless of other comps.
        let a = Key::k2(5, 1);
        let b = Key::k2(5, 9);
        for w in [1usize, 2, 3, 7, 8] {
            assert_eq!(owner(&a, &[0], w), owner(&b, &[0], w));
            assert!(owner(&a, &[0], w) < w);
        }
    }
}
