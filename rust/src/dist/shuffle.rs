//! Tuple routing between virtual workers, with exact accounting of the
//! bytes and point-to-point messages that would cross a real network.
//! Routing is by the *stable* key hash (`Key::stable_hash_of`), so the
//! assignment is a pure function of (key, comps, w): identical on every
//! worker, across runs, and across re-executions — the property the
//! partition-invariance tests and tape replay rely on.
//!
//! Two execution paths produce byte-identical results:
//!
//! * **serial** ([`exchange`] / [`exchange_merge`]) — one driver-thread
//!   loop over every source shard, the reference semantics;
//! * **pooled** ([`exchange_pooled`] / [`exchange_merge_pooled`]) — a
//!   parallel all-to-all on a [`WorkerPool`]: phase 1 has every *source*
//!   worker hash-route its own shard into per-destination buckets
//!   concurrently, phase 2 has every *destination* worker concatenate
//!   its inbound buckets (in source-index order, each bucket in shard
//!   order — exactly the serial deposit sequence per destination, so the
//!   built shards, the merge combine order, and the moved-byte counters
//!   are all identical to the serial path).
//!
//! Exchange outputs are exactly the per-worker join inputs the memory
//! policies meter: a reshuffled build side that exceeds its worker's
//! budget goes straight from the exchange into `dist::spill`'s grace
//! runs (the spill-aware join in `dist::exec`), so determinism here —
//! identical shards in identical order — is what makes spilled and
//! in-memory executions bitwise comparable.

use std::sync::Arc;

use super::pool::WorkerPool;
use crate::ra::{Chunk, Key, Relation};

/// Bytes/messages moved by one exchange. Messages are counted per
/// (source, destination) pair that carried at least one tuple — the
/// batching a real shuffle service does.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShuffleStats {
    /// Payload bytes that left their worker.
    pub bytes: u64,
    /// Distinct (src, dst) links used, src ≠ dst.
    pub msgs: u64,
}

/// Worker owning `key` under a hash partitioning on `comps`.
#[inline]
pub fn owner(key: &Key, comps: &[usize], w: usize) -> usize {
    (key.stable_hash_of(comps) % w as u64) as usize
}

/// Serialized size of one tuple (key + chunk payload).
#[inline]
pub fn tuple_bytes(v: &Chunk) -> u64 {
    (v.nbytes() + std::mem::size_of::<Key>()) as u64
}

/// Route every tuple of `shards` to `owner(key, comps, w)`. Keys must be
/// globally unique (relations are functions); duplicates panic. Generic
/// over the shard handle (`Relation` or `Arc<Relation>`): routing only
/// copies chunk *handles*, never chunk data.
pub fn exchange<S: std::borrow::Borrow<Relation>>(
    shards: &[S],
    comps: &[usize],
    w: usize,
) -> (Vec<Relation>, ShuffleStats) {
    exchange_with(shards, comps, w, |dst, k, v| dst.insert(k, v))
}

/// As `exchange`, but colliding keys at a destination are combined — the
/// final merge of a two-phase aggregation, where each source worker
/// holds a partial value per group key.
pub fn exchange_merge<S: std::borrow::Borrow<Relation>>(
    shards: &[S],
    comps: &[usize],
    w: usize,
    combine: impl Fn(&mut Chunk, &Chunk),
) -> (Vec<Relation>, ShuffleStats) {
    exchange_with(shards, comps, w, |dst, k, v| {
        dst.merge(k, v, |acc, x| combine(acc, x))
    })
}

fn exchange_with<S: std::borrow::Borrow<Relation>>(
    shards: &[S],
    comps: &[usize],
    w: usize,
    deposit: impl Fn(&mut Relation, Key, Chunk),
) -> (Vec<Relation>, ShuffleStats) {
    let n_src = shards.len();
    let mut out: Vec<Relation> = (0..w).map(|_| Relation::new()).collect();
    let mut stats = ShuffleStats::default();
    let mut link = vec![false; n_src * w];
    for (src, shard) in shards.iter().enumerate() {
        for (k, v) in shard.borrow().iter() {
            let dst = owner(k, comps, w);
            if dst != src {
                stats.bytes += tuple_bytes(v);
                if !link[src * w + dst] {
                    link[src * w + dst] = true;
                    stats.msgs += 1;
                }
            }
            deposit(&mut out[dst], *k, v.clone());
        }
    }
    (out, stats)
}

/// Where every tuple of `shards` *would* land — destination worker and
/// deposit position — under [`exchange`] on `comps`, computed without
/// moving a byte. Returns one `(dst, pos)` per row per source shard (in
/// shard scan order) plus the per-destination row totals.
///
/// The deposit sequence per destination is sources in index order, each
/// source in scan order — exactly the serial loop above and the pooled
/// phase-2 concatenation, so `pos` is the row's index in the exchanged
/// shard both paths build. The skew-aware join uses this to tag hot
/// probe rows it *keeps at their source* with the position the
/// oblivious reshuffled plan would have given them, which is what lets
/// its merge reproduce oblivious `hash_join` emission order bitwise.
pub fn routed_positions<S: std::borrow::Borrow<Relation>>(
    shards: &[S],
    comps: &[usize],
    w: usize,
) -> (Vec<Vec<(u32, u32)>>, Vec<u32>) {
    let mut next = vec![0u32; w];
    let mut tags: Vec<Vec<(u32, u32)>> = Vec::with_capacity(shards.len());
    for shard in shards {
        let shard = shard.borrow();
        let mut t = Vec::with_capacity(shard.len());
        for (k, _) in shard.iter() {
            let dst = owner(k, comps, w);
            t.push((dst as u32, next[dst]));
            next[dst] += 1;
        }
        tags.push(t);
    }
    (tags, next)
}

// ------------------------------------------------- pooled all-to-all path

/// Measured clocks of a pooled exchange, each the max over the workers of
/// its phase (the BSP barrier model: a phase is as slow as its slowest
/// worker).
#[derive(Clone, Copy, Debug, Default)]
pub struct ExchangeTiming {
    /// Slowest worker's partition/route phase, seconds.
    pub route_s: f64,
    /// Slowest destination worker's bucket-concatenation/build phase.
    pub build_s: f64,
}

/// Phase-1 output of one source worker: its shard hash-routed into one
/// bucket per destination, plus the moved-byte/link accounting.
struct RoutedShard {
    buckets: Vec<Vec<(Key, Chunk)>>,
    bytes: u64,
    links: u64,
    secs: f64,
}

fn route_shard(src: usize, shard: &Relation, comps: &[usize], w: usize) -> RoutedShard {
    let t0 = std::time::Instant::now();
    let mut buckets: Vec<Vec<(Key, Chunk)>> = (0..w).map(|_| Vec::new()).collect();
    let mut bytes = 0u64;
    let mut linked = vec![false; w];
    let mut links = 0u64;
    for (k, v) in shard.iter() {
        let dst = owner(k, comps, w);
        if dst != src {
            bytes += tuple_bytes(v);
            if !linked[dst] {
                linked[dst] = true;
                links += 1;
            }
        }
        buckets[dst].push((*k, v.clone()));
    }
    RoutedShard {
        buckets,
        bytes,
        links,
        secs: t0.elapsed().as_secs_f64(),
    }
}

fn exchange_pooled_with<S>(
    shards: Vec<S>,
    comps: &[usize],
    w: usize,
    pool: &WorkerPool,
    deposit: impl Fn(&mut Relation, Key, Chunk) + Send + Sync + 'static,
) -> (Vec<Relation>, ShuffleStats, ExchangeTiming)
where
    S: std::borrow::Borrow<Relation> + Send + 'static,
{
    assert_eq!(
        shards.len(),
        w,
        "pooled exchange needs one source shard per worker"
    );
    assert_eq!(
        pool.workers(),
        w,
        "pooled exchange needs a pool of matching width"
    );
    // Phase 1: every source worker routes its own shard concurrently.
    let comps: Arc<[usize]> = comps.into();
    let routed = pool.run_with(shards, move |src, shard: S, _| {
        route_shard(src, shard.borrow(), &comps, w)
    });
    // Barrier: transpose the bucket matrix (Vec handle moves only) and
    // total the traffic counters — identical to the serial accounting,
    // since routing is the same pure function of (key, comps, w).
    let mut stats = ShuffleStats::default();
    let mut timing = ExchangeTiming::default();
    let mut inbound: Vec<Vec<Vec<(Key, Chunk)>>> =
        (0..w).map(|_| Vec::with_capacity(w)).collect();
    for r in routed {
        stats.bytes += r.bytes;
        stats.msgs += r.links;
        timing.route_s = timing.route_s.max(r.secs);
        for (dst, bucket) in r.buckets.into_iter().enumerate() {
            inbound[dst].push(bucket);
        }
    }
    // Phase 2: every destination worker concatenates its inbound buckets
    // in source order — the serial deposit sequence, bit for bit.
    let built = pool.run_with(inbound, move |_, buckets: Vec<Vec<(Key, Chunk)>>, _| {
        let t0 = std::time::Instant::now();
        let mut out = Relation::new();
        for bucket in buckets {
            for (k, v) in bucket {
                deposit(&mut out, k, v);
            }
        }
        (out, t0.elapsed().as_secs_f64())
    });
    let mut out = Vec::with_capacity(w);
    for (rel, secs) in built {
        timing.build_s = timing.build_s.max(secs);
        out.push(rel);
    }
    (out, stats, timing)
}

/// [`exchange`] executed as a parallel all-to-all on `pool` — bitwise
/// identical shards and traffic counters, with the route and build work
/// sharded across the worker threads instead of serialized on the
/// driver. Requires one source shard per pool worker.
pub fn exchange_pooled(
    shards: Vec<Arc<Relation>>,
    comps: &[usize],
    w: usize,
    pool: &WorkerPool,
) -> (Vec<Relation>, ShuffleStats, ExchangeTiming) {
    exchange_pooled_with(shards, comps, w, pool, |dst, k, v| dst.insert(k, v))
}

/// [`exchange_merge`] on `pool`: the final merge of a two-phase Σ, with
/// every destination worker combining its inbound partials concurrently.
/// Combine order per group is the serial source order, so float results
/// are bit-identical to the driver-thread path.
pub fn exchange_merge_pooled(
    shards: Vec<Relation>,
    comps: &[usize],
    w: usize,
    combine: impl Fn(&mut Chunk, &Chunk) + Send + Sync + 'static,
    pool: &WorkerPool,
) -> (Vec<Relation>, ShuffleStats, ExchangeTiming) {
    exchange_pooled_with(shards, comps, w, pool, move |dst, k, v| {
        dst.merge(k, v, |acc, x| combine(acc, x))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn exchange_accounts_moved_bytes_exactly() {
        let mut rng = Prng::new(0x5AFE);
        let mut r = Relation::new();
        for i in 0..24 {
            r.insert(Key::k1(i), Chunk::random(2, 3, &mut rng, 1.0));
        }
        let w = 3;
        // Everything starts on worker 0; each tuple not owned by 0 moves.
        let mut shards: Vec<Relation> = (0..w).map(|_| Relation::new()).collect();
        shards[0] = r.clone();
        let mut want_bytes = 0u64;
        let mut want_links = std::collections::BTreeSet::new();
        for (k, v) in r.iter() {
            let d = owner(k, &[0], w);
            if d != 0 {
                want_bytes += tuple_bytes(v);
                want_links.insert(d);
            }
        }
        assert!(want_bytes > 0, "degenerate test: nothing moved");
        let (out, st) = exchange(&shards, &[0], w);
        assert_eq!(st.bytes, want_bytes);
        assert_eq!(st.msgs, want_links.len() as u64);
        assert_eq!(out.iter().map(|s| s.len()).sum::<usize>(), r.len());
        // Already-placed tuples move for free.
        let (out2, st2) = exchange(&out, &[0], w);
        assert_eq!(st2, ShuffleStats::default());
        assert_eq!(out2.iter().map(|s| s.len()).sum::<usize>(), r.len());
    }

    #[test]
    fn exchange_merge_combines_partials() {
        // Two workers each hold a partial for the same group key.
        let a = Relation::from_pairs(vec![(Key::k1(7), Chunk::scalar(1.0))]);
        let b = Relation::from_pairs(vec![(Key::k1(7), Chunk::scalar(2.0))]);
        let (out, _) = exchange_merge(&[a, b], &[0], 2, |acc, x| acc.add_assign(x));
        let total: usize = out.iter().map(|s| s.len()).sum();
        assert_eq!(total, 1);
        let d = owner(&Key::k1(7), &[0], 2);
        assert_eq!(out[d].get(&Key::k1(7)).unwrap().as_scalar(), 3.0);
    }

    #[test]
    fn pooled_exchange_matches_serial_bitwise() {
        let mut rng = Prng::new(0x9001_5EED);
        let w = 3;
        let mut shards: Vec<Relation> = (0..w).map(|_| Relation::new()).collect();
        for i in 0..30i64 {
            shards[(i % w as i64) as usize]
                .insert(Key::k2(i, i * 3 % 7), Chunk::random(2, 2, &mut rng, 1.0));
        }
        let (want, want_st) = exchange(&shards, &[1], w);
        let pool = WorkerPool::new(w, &crate::kernels::NativeBackend);
        let handles: Vec<std::sync::Arc<Relation>> =
            shards.iter().cloned().map(std::sync::Arc::new).collect();
        let (got, got_st, _) = exchange_pooled(handles, &[1], w, &pool);
        assert_eq!(got_st, want_st);
        assert_eq!(got.len(), want.len());
        for (g, s) in got.iter().zip(want.iter()) {
            // Same tuples in the same deposit order per destination.
            assert_eq!(g.len(), s.len());
            for (a, b) in g.iter().zip(s.iter()) {
                assert_eq!(a.0, b.0);
                assert!(a.1.approx_eq(&b.1, 0.0));
            }
        }
    }

    #[test]
    fn pooled_merge_combines_in_source_order() {
        // Three workers hold partials for one group: the pooled merge must
        // combine them in source order (1 + 2) + 4, same as serial.
        let w = 3;
        let parts: Vec<Relation> = [1.0f32, 2.0, 4.0]
            .iter()
            .map(|&x| Relation::from_pairs(vec![(Key::k1(9), Chunk::scalar(x))]))
            .collect();
        let (want, want_st) = exchange_merge(&parts, &[0], w, |acc, x| acc.add_assign(x));
        let pool = WorkerPool::new(w, &crate::kernels::NativeBackend);
        let (got, got_st, _) =
            exchange_merge_pooled(parts, &[0], w, |acc, x| acc.add_assign(x), &pool);
        assert_eq!(got_st, want_st);
        let d = owner(&Key::k1(9), &[0], w);
        assert_eq!(got[d].get(&Key::k1(9)).unwrap().as_scalar(), 7.0);
        assert!(got[d].approx_eq(&want[d], 0.0));
    }

    #[test]
    fn routed_positions_match_exchange_deposit_order() {
        let mut rng = Prng::new(0xD15C);
        let w = 3;
        let mut shards: Vec<Relation> = (0..w).map(|_| Relation::new()).collect();
        for i in 0..40i64 {
            shards[(i % w as i64) as usize]
                .insert(Key::k2(i, i % 5), Chunk::random(1, 2, &mut rng, 1.0));
        }
        let (tags, totals) = routed_positions(&shards, &[1], w);
        let (out, _) = exchange(&shards, &[1], w);
        for (dst, total) in totals.iter().enumerate() {
            assert_eq!(*total as usize, out[dst].len());
        }
        for (src, shard) in shards.iter().enumerate() {
            for ((k, _), &(dst, pos)) in shard.iter().zip(&tags[src]) {
                // The tagged position is exactly where the exchange put
                // this key in the destination shard's scan order.
                let (got_k, _) = out[dst as usize]
                    .iter()
                    .nth(pos as usize)
                    .expect("position within exchanged shard");
                assert_eq!(got_k, k);
            }
        }
    }

    #[test]
    fn owner_is_stable_and_respects_comps() {
        // Same comp values ⇒ same owner, regardless of other comps.
        let a = Key::k2(5, 1);
        let b = Key::k2(5, 9);
        for w in [1usize, 2, 3, 7, 8] {
            assert_eq!(owner(&a, &[0], w), owner(&b, &[0], w));
            assert!(owner(&a, &[0], w) < w);
        }
    }
}
