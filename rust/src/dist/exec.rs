//! Stage-by-stage BSP execution of a functional-RA query across virtual
//! workers, with the per-worker shards of every stage running on real OS
//! threads.
//!
//! Every query node becomes one cluster stage:
//!
//! * **σ / value maps** run worker-local; the partitioning invariant is
//!   propagated through the key projection.
//! * **⋈** goes through [`plan_join`]: if both sides are already
//!   partitioned on their join components (or a side is replicated) the
//!   join is worker-local; otherwise the planner prices *reshuffle*
//!   (re-home the misplaced side(s) by join-key hash) against
//!   *broadcast* (allgather one side) on the [`NetModel`] and picks the
//!   cheaper, using `plan::join_cardinality` to bias broadcast toward
//!   the unique side of a 1-n join. Per worker, the stage working set
//!   (`build + probe + estimated output`) is checked against the memory
//!   budget — over budget, [`MemPolicy::Fail`] returns
//!   [`DistError::Oom`] while [`MemPolicy::Spill`] executes the join as
//!   a grace hash join: the build side is split into passes that fit,
//!   the probe side is rescanned per pass, and the overflow is charged
//!   to the spill model.
//! * **Σ** is two-phase: local pre-aggregation, a hash exchange on the
//!   group key, and a final merge — except when the input partitioning
//!   already co-locates every group, where the local phase is final.
//! * **add** runs worker-local when both sides share a hash layout, and
//!   re-homes both by the full key otherwise.
//!
//! **Threading model.** A persistent [`WorkerPool`](super::pool) fans
//! every stage out to `w` parked worker threads, each owning a
//! [`KernelBackend`] instance minted *once per pool* by
//! `KernelBackend::for_worker` (the per-node runtime of a real
//! deployment; PJRT handles never cross threads). The pool lives for the
//! whole evaluation — or, driven through `ml::DistTrainer` /
//! `ml::TrainPipeline`, for the whole forward+backward step or training
//! loop — so stages pay job dispatch, not thread spawn/join, and
//! backends are never re-minted per stage or per evaluation. Stage
//! compute, the `shuffle::exchange*` route/build phases, `gather_in`,
//! and the two-phase Σ final merge all run as sharded pool jobs; only
//! the cheap planning/accounting glue stays on the driver thread.
//! Results are collected in worker-index order, so pooled execution is
//! *bitwise identical* to the serial reference path
//! (`ClusterConfig::parallel = false`, or `parallel_comm = false` for
//! the communication steps alone): same shard relations, same iteration
//! order, same float associativity. `ExecStats` reports both the modeled
//! `virtual_time_s` (max-over-workers compute + modeled net/spill) and
//! the measured `wall_s` of the run, which shrinks with worker count up
//! to the host's core count.
//!
//! Results are partition-invariant: `dist_eval(q, parts).gather()`
//! equals single-node `eval_query(q, inputs)` (up to float reassociation
//! in Σ) for every worker count and input layout.

use std::borrow::Cow;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use super::mem::{self, MemPolicy};
use super::net::NetModel;
use super::partition::{PartitionedRelation, Partitioning};
use super::pool::WorkerPool;
use super::shuffle::{self, ShuffleStats};
use super::{ClusterConfig, DistError, ExecStats};
use crate::kernels::{AggKernel, BinaryKernel, KernelBackend, UnaryKernel};
use crate::plan::{join_cardinality, JoinCard};
use crate::ra::eval::{add_relations, aggregate, apply_select, hash_join, subkey};
use crate::ra::expr::{Node, NodeId, Op, Query};
use crate::ra::funcs::{JoinPred, KeyPred, KeyProj, KeyProj2, Sel, Sel2};
use crate::ra::{Key, Relation};
use crate::util::FxHashMap;

/// Intermediate partitioned relations per query node, as captured by a
/// distributed forward execution — the distributed analogue of
/// `ra::eval::Tape`, feeding the generated backward query. Shards are
/// `Arc` handles, so cloning tape entries is reference counting, not
/// data movement.
#[derive(Clone)]
pub struct DistTape {
    pub rels: Vec<PartitionedRelation>,
}

impl DistTape {
    pub fn rel(&self, id: NodeId) -> &PartitionedRelation {
        &self.rels[id]
    }

    pub fn output(&self, q: &Query) -> &PartitionedRelation {
        &self.rels[q.output]
    }

    pub fn nbytes(&self) -> u64 {
        self.rels.iter().map(|r| r.nbytes()).sum()
    }
}

/// One stage of an executed plan, as recorded by the tracing executor —
/// the physical decisions `Session::query(..)?.explain()` renders: which
/// operator ran, the join strategy the cost-based planner picked, the
/// partitioning invariant of the stage output, and the shuffle traffic
/// the stage generated.
#[derive(Clone, Debug)]
pub struct StageTrace {
    /// Query node this stage executed.
    pub node: NodeId,
    /// Operator kind (`τ`, `σ`, `⋈`, `Σ`, `add`, `const`).
    pub op: &'static str,
    /// The physical join decision, for `⋈` stages.
    pub strategy: Option<JoinStrategy>,
    /// Output partitioning invariant (rendered).
    pub out_part: String,
    /// Bytes this stage moved across the (modeled) network.
    pub bytes_shuffled: u64,
    /// Point-to-point messages those bytes travelled in.
    pub msgs: u64,
    /// Measured compute seconds this stage added (max over workers).
    pub compute_s: f64,
    /// Spill events this stage charged.
    pub spill_passes: u64,
}

/// Evaluate a query distributed; return the output relation (still
/// partitioned, a cheap handle copy out of the tape) and the execution
/// stats. Builds a fresh [`WorkerPool`] for this one evaluation when the
/// configuration threads.
#[deprecated(
    since = "0.2.0",
    note = "use `session::Session`: register tables once, then `sess.query(&q)?.collect()` \
            (see the `session` module migration note)"
)]
pub fn dist_eval(
    q: &Query,
    inputs: &[PartitionedRelation],
    cfg: &ClusterConfig,
    backend: &dyn KernelBackend,
) -> Result<(PartitionedRelation, ExecStats), DistError> {
    let pool = WorkerPool::maybe_new(cfg, backend);
    let (tape, stats) = eval_tape_core(q, inputs, cfg, backend, pool.as_ref(), None)?;
    Ok((tape.rels[q.output].clone(), stats))
}

/// [`dist_eval`] on a caller-provided worker pool (or `None` for the
/// serial reference path).
#[deprecated(
    since = "0.2.0",
    note = "use `session::Session`, which owns the pool for its whole lifetime \
            (see the `session` module migration note)"
)]
pub fn dist_eval_in(
    q: &Query,
    inputs: &[PartitionedRelation],
    cfg: &ClusterConfig,
    backend: &dyn KernelBackend,
    pool: Option<&WorkerPool>,
) -> Result<(PartitionedRelation, ExecStats), DistError> {
    let (tape, stats) = eval_tape_core(q, inputs, cfg, backend, pool, None)?;
    Ok((tape.rels[q.output].clone(), stats))
}

/// Evaluate a query distributed, returning the relations of several
/// nodes (the backward plan's per-slot gradient outputs share one DAG).
/// The returned relations are handle copies out of the tape.
#[deprecated(
    since = "0.2.0",
    note = "use `session::Session` — `sess.query(&q)?.grad(..)` runs the multi-output \
            backward plan through the session pool (see the `session` module migration note)"
)]
pub fn dist_eval_multi(
    q: &Query,
    inputs: &[PartitionedRelation],
    outputs: &[NodeId],
    cfg: &ClusterConfig,
    backend: &dyn KernelBackend,
) -> Result<(Vec<PartitionedRelation>, ExecStats), DistError> {
    let pool = WorkerPool::maybe_new(cfg, backend);
    eval_multi_core(q, inputs, outputs, cfg, backend, pool.as_ref())
}

/// [`dist_eval_multi`] on a caller-provided worker pool.
#[deprecated(
    since = "0.2.0",
    note = "use `session::Session` (see the `session` module migration note)"
)]
pub fn dist_eval_multi_in(
    q: &Query,
    inputs: &[PartitionedRelation],
    outputs: &[NodeId],
    cfg: &ClusterConfig,
    backend: &dyn KernelBackend,
    pool: Option<&WorkerPool>,
) -> Result<(Vec<PartitionedRelation>, ExecStats), DistError> {
    eval_multi_core(q, inputs, outputs, cfg, backend, pool)
}

/// Evaluate a query distributed, capturing every intermediate
/// partitioned relation (the forward pass of distributed training).
/// Builds a fresh [`WorkerPool`] for this one evaluation when the
/// configuration threads.
#[deprecated(
    since = "0.2.0",
    note = "use `session::Session` (see the `session` module migration note)"
)]
pub fn dist_eval_tape(
    q: &Query,
    inputs: &[PartitionedRelation],
    cfg: &ClusterConfig,
    backend: &dyn KernelBackend,
) -> Result<(DistTape, ExecStats), DistError> {
    let pool = WorkerPool::maybe_new(cfg, backend);
    eval_tape_core(q, inputs, cfg, backend, pool.as_ref(), None)
}

/// [`dist_eval_tape`] on a caller-provided worker pool.
#[deprecated(
    since = "0.2.0",
    note = "use `session::Session` (see the `session` module migration note)"
)]
pub fn dist_eval_tape_in(
    q: &Query,
    inputs: &[PartitionedRelation],
    cfg: &ClusterConfig,
    backend: &dyn KernelBackend,
    pool: Option<&WorkerPool>,
) -> Result<(DistTape, ExecStats), DistError> {
    eval_tape_core(q, inputs, cfg, backend, pool, None)
}

/// [`dist_eval_multi`]'s body on the shared core: tape + handle-copy the
/// requested outputs.
pub(crate) fn eval_multi_core(
    q: &Query,
    inputs: &[PartitionedRelation],
    outputs: &[NodeId],
    cfg: &ClusterConfig,
    backend: &dyn KernelBackend,
    pool: Option<&WorkerPool>,
) -> Result<(Vec<PartitionedRelation>, ExecStats), DistError> {
    let (tape, stats) = eval_tape_core(q, inputs, cfg, backend, pool, None)?;
    Ok((
        outputs.iter().map(|&id| tape.rels[id].clone()).collect(),
        stats,
    ))
}

/// The one stage-by-stage evaluator behind every entry point —
/// `session::Session` (the supported front door), the deprecated
/// `dist_eval*` wrappers, and `ml`'s training step all funnel here.
/// Every stage of the evaluation runs on `pool`'s parked threads and
/// their already-minted backends; passing `None` — or a `cfg` with
/// `parallel = false` — takes the serial reference path; a pool of the
/// wrong width is an error. When `trace` is given, the executor records
/// one [`StageTrace`] per query node (the raw material of
/// `Frame::explain`).
pub(crate) fn eval_tape_core(
    q: &Query,
    inputs: &[PartitionedRelation],
    cfg: &ClusterConfig,
    backend: &dyn KernelBackend,
    pool: Option<&WorkerPool>,
    mut trace: Option<&mut Vec<StageTrace>>,
) -> Result<(DistTape, ExecStats), DistError> {
    if inputs.len() < q.n_slots {
        return Err(DistError::Other(anyhow!(
            "query needs {} input(s), got {}",
            q.n_slots,
            inputs.len()
        )));
    }
    for (i, pr) in inputs.iter().enumerate() {
        if pr.workers() != cfg.workers {
            return Err(DistError::Other(anyhow!(
                "input slot {i} is sharded across {} worker(s), cluster has {}",
                pr.workers(),
                cfg.workers
            )));
        }
    }
    if let Some(p) = pool {
        if p.workers() != cfg.workers {
            return Err(DistError::Other(anyhow!(
                "worker pool has {} worker(s), cluster config has {}",
                p.workers(),
                cfg.workers
            )));
        }
    }
    let mut ex = Executor {
        cfg,
        backend,
        // `parallel = false` forces the serial reference path even when a
        // caller hands us a live pool (the determinism A/B switch).
        pool: if cfg.parallel { pool } else { None },
        stats: ExecStats::default(),
        last_join: None,
    };
    // Clock started after pool/backend setup: wall_s measures execution,
    // not per-worker runtime instantiation (which, with a caller-held
    // pool, is amortized over every evaluation the pool serves).
    let t0 = std::time::Instant::now();
    let mut rels: Vec<PartitionedRelation> = Vec::with_capacity(q.len());
    for (id, node) in q.nodes.iter().enumerate() {
        let before = ex.stats;
        let r = ex.eval_node(node, &rels, inputs).map_err(|e| match e {
            DistError::Other(err) => DistError::Other(
                err.context(format!("evaluating node v{id} ({}) distributed", node.op.kind())),
            ),
            oom => oom,
        })?;
        if let Some(t) = trace.as_mut() {
            t.push(StageTrace {
                node: id,
                op: node.op.kind(),
                strategy: ex.last_join.take().map(|p| p.strategy),
                out_part: format!("{:?}", r.part),
                bytes_shuffled: ex.stats.bytes_shuffled - before.bytes_shuffled,
                msgs: ex.stats.msgs - before.msgs,
                compute_s: ex.stats.compute_s - before.compute_s,
                spill_passes: ex.stats.spill_passes - before.spill_passes,
            });
        }
        rels.push(r);
        ex.stats.stages += 1;
    }
    let mut stats = ex.stats;
    stats.virtual_time_s = stats.compute_s + stats.net_s + stats.spill_s;
    stats.wall_s = t0.elapsed().as_secs_f64();
    Ok((DistTape { rels }, stats))
}

// ---------------------------------------------------------------- planner

/// Which operand a physical decision applies to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JoinSide {
    Left,
    Right,
}

/// The physical execution strategy for one join stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JoinStrategy {
    /// The partitionings already co-locate every match (or a side is
    /// replicated, or there is a single worker): no traffic.
    Local,
    /// Re-home the flagged side(s) by the hash of their join components.
    Reshuffle { left: bool, right: bool },
    /// Allgather one side onto every worker; the other side stays put.
    Broadcast { side: JoinSide },
}

/// A costed physical join decision.
#[derive(Clone, Copy, Debug)]
pub struct JoinPlan {
    pub strategy: JoinStrategy,
    /// Cardinality class from `plan::join_cardinality` — also used to
    /// bias broadcast toward the unique side of a 1-n join.
    pub card: JoinCard,
}

/// Cost-based physical planning for one distributed join: co-partitioned
/// when the partitioning invariant already matches, otherwise the
/// cheaper of reshuffle and broadcast under `net`.
pub fn plan_join(
    left: &PartitionedRelation,
    right: &PartitionedRelation,
    pred: &JoinPred,
    net: &NetModel,
    workers: usize,
) -> JoinPlan {
    let card = join_cardinality(pred, left.key_arity(), right.key_arity());
    if workers <= 1 || left.is_replicated() || right.is_replicated() {
        return JoinPlan {
            strategy: JoinStrategy::Local,
            card,
        };
    }
    let lb = left.nbytes();
    let rb = right.nbytes();
    if pred.eqs.is_empty() {
        // No equality to hash on (literal-pinned ⋈const plumbing, cross
        // joins): replicate the smaller side.
        let side = if lb <= rb {
            JoinSide::Left
        } else {
            JoinSide::Right
        };
        return JoinPlan {
            strategy: JoinStrategy::Broadcast { side },
            card,
        };
    }
    let l_ok = left.is_hash_on(&pred.left_comps());
    let r_ok = right.is_hash_on(&pred.right_comps());
    if l_ok && r_ok {
        return JoinPlan {
            strategy: JoinStrategy::Local,
            card,
        };
    }
    // Price the three physical options with the shared network model.
    let mut resh = 0.0;
    if !l_ok {
        resh += net.shuffle_time(lb, workers);
    }
    if !r_ok {
        resh += net.shuffle_time(rb, workers);
    }
    let mut bl = net.allgather_time(lb, workers);
    let mut br = net.allgather_time(rb, workers);
    // Broadcasting the unique side of a 1-n join leaves the fan-out side
    // (and its partitioning invariant) untouched: bias toward it.
    match card {
        JoinCard::ManyOne => br *= 0.75,
        JoinCard::OneMany => bl *= 0.75,
        _ => {}
    }
    let strategy = if resh <= bl && resh <= br {
        JoinStrategy::Reshuffle {
            left: !l_ok,
            right: !r_ok,
        }
    } else if bl <= br {
        JoinStrategy::Broadcast {
            side: JoinSide::Left,
        }
    } else {
        JoinStrategy::Broadcast {
            side: JoinSide::Right,
        }
    };
    JoinPlan { strategy, card }
}

// --------------------------------------------------------------- executor

struct Executor<'a> {
    cfg: &'a ClusterConfig,
    /// The caller's backend, used directly on every serial path (one
    /// worker, `parallel = false`, replicated run-once stages).
    backend: &'a dyn KernelBackend,
    /// The persistent worker pool every stage dispatches to — `None` on
    /// the serial reference path. The pool (and the one backend instance
    /// each of its threads owns) outlives this executor when the caller
    /// holds it across evaluations.
    pool: Option<&'a WorkerPool>,
    stats: ExecStats,
    /// The physical plan of the most recent ⋈ stage, taken by the tracing
    /// node loop right after that stage completes.
    last_join: Option<JoinPlan>,
}

fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = std::time::Instant::now();
    let v = f();
    (v, t0.elapsed().as_secs_f64())
}

/// Run one BSP stage: `f(worker_index, backend)` once per worker — as
/// pool jobs when a pool of matching width is running, serially on
/// `fallback` otherwise. Results come back in worker-index order either
/// way, so the two paths are bitwise interchangeable. Worker panics
/// propagate. Stage closures capture `Arc` shard handles and cloned key
/// functions (refcount bumps and a few component indices), never tuple
/// data.
fn par_stage<T: Send + 'static>(
    pool: Option<&WorkerPool>,
    w: usize,
    fallback: &dyn KernelBackend,
    f: impl Fn(usize, &dyn KernelBackend) -> T + Send + Sync + 'static,
) -> Vec<T> {
    match pool {
        Some(p) if p.workers() == w => p.run(f),
        _ => (0..w).map(|wi| f(wi, fallback)).collect(),
    }
}

impl<'a> Executor<'a> {
    /// Pool for the communication steps (shuffle route/build, gather,
    /// Σ merge) — gated separately by `ClusterConfig::parallel_comm` so
    /// `bench_dist` can A/B the pooled all-to-all against the
    /// driver-serial exchange with stage compute threaded either way.
    fn comm_pool(&self) -> Option<&'a WorkerPool> {
        if self.cfg.parallel_comm {
            self.pool
        } else {
            None
        }
    }

    fn eval_node(
        &mut self,
        node: &Node,
        rels: &[PartitionedRelation],
        inputs: &[PartitionedRelation],
    ) -> Result<PartitionedRelation, DistError> {
        let w = self.cfg.workers;
        match &node.op {
            // Handle copies: inputs and plan constants are never deep-
            // copied into the tape.
            Op::Scan { slot, .. } => Ok(inputs[*slot].clone()),
            Op::Const { rel, .. } => Ok(PartitionedRelation::replicate_handle(rel.clone(), w)),
            Op::Select { pred, proj, kernel } => {
                self.eval_select(pred, proj, kernel, &rels[node.children[0]])
            }
            Op::Join { pred, proj, kernel } => self.eval_join(
                pred,
                proj,
                kernel,
                &rels[node.children[0]],
                &rels[node.children[1]],
            ),
            Op::Agg { grp, agg } => self.eval_agg(grp, agg, &rels[node.children[0]]),
            Op::AddQ => self.eval_add(&rels[node.children[0]], &rels[node.children[1]]),
        }
    }

    fn eval_select(
        &mut self,
        pred: &KeyPred,
        proj: &KeyProj,
        kernel: &UnaryKernel,
        input: &PartitionedRelation,
    ) -> Result<PartitionedRelation, DistError> {
        let w = self.cfg.workers;
        if input.is_replicated() {
            // Identical work everywhere: run once, charge once.
            let b0 = self.backend;
            let (out, t) = time(|| apply_select(&input.shards[0], pred, proj, kernel, b0));
            let out = out.map_err(DistError::Other)?;
            self.stats.compute_s += t;
            return Ok(PartitionedRelation::replicate_handle(Arc::new(out), w));
        }
        let in_shards = input.shards.clone();
        let (pred_c, proj_c, kernel_c) = (pred.clone(), proj.clone(), *kernel);
        let results = par_stage(self.pool, w, self.backend, move |wi, be| {
            time(|| apply_select(&in_shards[wi], &pred_c, &proj_c, &kernel_c, be))
        });
        let mut shards = Vec::with_capacity(w);
        let mut maxt = 0.0f64;
        for (out, t) in results {
            shards.push(out.map_err(DistError::Other)?);
            maxt = maxt.max(t);
        }
        self.stats.compute_s += maxt;
        // The invariant survives iff every partitioning component is
        // carried through the projection.
        let part = match &input.part {
            Partitioning::Hash(c) => match preserved_positions(c, proj) {
                Some(pos) => Partitioning::Hash(pos),
                None => Partitioning::Arbitrary,
            },
            _ => Partitioning::Arbitrary,
        };
        // A statically non-injective projection can collide *across*
        // workers, which the per-shard checks cannot see — verify, so the
        // distributed run errors exactly where single-node does.
        if matches!(part, Partitioning::Arbitrary) && !proj.is_injective(input.key_arity()) {
            check_disjoint(&shards, format_args!("σ projection {proj}"))
                .map_err(DistError::Other)?;
        }
        Ok(PartitionedRelation::from_shards(shards, part))
    }

    fn eval_join(
        &mut self,
        pred: &JoinPred,
        proj: &KeyProj2,
        kernel: &BinaryKernel,
        left: &PartitionedRelation,
        right: &PartitionedRelation,
    ) -> Result<PartitionedRelation, DistError> {
        let w = self.cfg.workers;
        if left.is_replicated() && right.is_replicated() {
            let shard = join_worker_shard(
                self.cfg.budget,
                self.cfg.policy,
                0,
                &left.shards[0],
                &right.shards[0],
                pred,
                proj,
                kernel,
                self.backend,
            )?;
            self.stats.compute_s += shard.compute_s;
            self.stats.spill_s += shard.spill_s;
            self.stats.spill_passes += shard.spill_events;
            return Ok(PartitionedRelation::replicate_handle(
                Arc::new(shard.out),
                w,
            ));
        }
        let plan = plan_join(left, right, pred, &self.cfg.net, w);
        self.last_join = Some(plan);
        let (lv, rv): (Cow<PartitionedRelation>, Cow<PartitionedRelation>) = match plan.strategy {
            JoinStrategy::Local => (Cow::Borrowed(left), Cow::Borrowed(right)),
            JoinStrategy::Reshuffle {
                left: move_l,
                right: move_r,
            } => {
                let lv = if move_l {
                    let (p, st) = left.reshuffle_in(&pred.left_comps(), w, self.comm_pool());
                    self.account_shuffle(st);
                    Cow::Owned(p)
                } else {
                    Cow::Borrowed(left)
                };
                let rv = if move_r {
                    let (p, st) = right.reshuffle_in(&pred.right_comps(), w, self.comm_pool());
                    self.account_shuffle(st);
                    Cow::Owned(p)
                } else {
                    Cow::Borrowed(right)
                };
                (lv, rv)
            }
            JoinStrategy::Broadcast {
                side: JoinSide::Left,
            } => (Cow::Owned(self.broadcast(left)), Cow::Borrowed(right)),
            JoinStrategy::Broadcast {
                side: JoinSide::Right,
            } => (Cow::Borrowed(left), Cow::Owned(self.broadcast(right))),
        };
        // Fail-fast OOM: under `MemPolicy::Fail` check every worker's
        // budget *before* any join compute runs, so an over-budget stage
        // errors immediately (and on the lowest worker index) instead of
        // after the within-budget workers finished their joins.
        if let Some(budget) = self.cfg.budget {
            if self.cfg.policy == MemPolicy::Fail {
                for wi in 0..w {
                    let needed = join_needed_bytes(&lv.shards[wi], &rv.shards[wi], pred, kernel);
                    if needed > budget {
                        return Err(DistError::Oom {
                            worker: wi,
                            needed,
                            budget,
                        });
                    }
                }
            }
        }
        let (lsh, rsh) = (lv.shards.clone(), rv.shards.clone());
        let (pred_c, proj_c, kernel_c) = (pred.clone(), proj.clone(), *kernel);
        let (budget, policy) = (self.cfg.budget, self.cfg.policy);
        let results = par_stage(self.pool, w, self.backend, move |wi, be| {
            join_worker_shard(
                budget, policy, wi, &lsh[wi], &rsh[wi], &pred_c, &proj_c, &kernel_c, be,
            )
        });
        let mut shards = Vec::with_capacity(w);
        let mut maxt = 0.0f64;
        let mut max_spill = 0.0f64;
        for res in results {
            let shard = res?;
            maxt = maxt.max(shard.compute_s);
            max_spill = max_spill.max(shard.spill_s);
            self.stats.spill_passes += shard.spill_events;
            shards.push(shard.out);
        }
        self.stats.compute_s += maxt;
        self.stats.spill_s += max_spill;
        let part = join_output_part(&lv.part, &rv.part, proj);
        // No surviving hash invariant ⇒ equal output keys could land on
        // different workers; verify disjointness so the distributed run
        // errors exactly where single-node does instead of corrupting a
        // later gather.
        if matches!(part, Partitioning::Arbitrary) {
            check_disjoint(&shards, format_args!("⋈ projection {proj}"))
                .map_err(DistError::Other)?;
        }
        Ok(PartitionedRelation::from_shards(shards, part))
    }

    fn eval_agg(
        &mut self,
        grp: &KeyProj,
        agg: &AggKernel,
        input: &PartitionedRelation,
    ) -> Result<PartitionedRelation, DistError> {
        let w = self.cfg.workers;
        if input.is_replicated() {
            let (out, t) = time(|| aggregate(&input.shards[0], grp, agg));
            self.stats.compute_s += t;
            return Ok(PartitionedRelation::replicate_handle(Arc::new(out), w));
        }
        // Local phase (always runs): per-worker pre-aggregation.
        let in_shards = input.shards.clone();
        let (grp_c, agg_c) = (grp.clone(), *agg);
        let results = par_stage(self.pool, w, self.backend, move |wi, _| {
            time(|| aggregate(&in_shards[wi], &grp_c, &agg_c))
        });
        let mut pre = Vec::with_capacity(w);
        let mut maxt = 0.0f64;
        for (out, t) in results {
            maxt = maxt.max(t);
            pre.push(out);
        }
        self.stats.compute_s += maxt;
        // If the partition hash is a function of the group key, every
        // group is already worker-local and the pre-aggregation is final.
        if let Partitioning::Hash(c) = &input.part {
            if let Some(pos) = preserved_positions(c, grp) {
                return Ok(PartitionedRelation::from_shards(pre, Partitioning::Hash(pos)));
            }
        }
        // Exchange partials by group-key hash and merge — the final merge
        // of the two-phase Σ. Both arms charge a *measured* estimate of
        // the per-worker exchange share to compute_s, but they estimate
        // it differently (per-phase max-over-workers vs total/w), so the
        // modeled clock of the two execution modes agrees approximately;
        // the exact-counter stats (bytes, msgs) and the results are
        // identical.
        let out_comps: Vec<usize> = (0..grp.out_arity()).collect();
        let agg2 = *agg;
        let shards = match self.comm_pool() {
            Some(p) if p.workers() == w && pre.len() == w => {
                // Pooled: route and merge each run as a barriered phase,
                // so charge the slowest worker of each (the BSP model).
                let (shards, st, timing) = shuffle::exchange_merge_pooled(
                    pre,
                    &out_comps,
                    w,
                    move |acc, x| agg2.combine(acc, x),
                    p,
                );
                self.account_shuffle(st);
                self.stats.compute_s += timing.route_s + timing.build_s;
                shards
            }
            _ => {
                // Serial reference: the merge runs on the driver over every
                // worker's partials; on the cluster the destinations merge
                // their shares in parallel, so charge the per-worker share.
                let ((shards, st), t) = time(|| {
                    shuffle::exchange_merge(&pre, &out_comps, w, |acc, x| agg2.combine(acc, x))
                });
                self.account_shuffle(st);
                self.stats.compute_s += t / w as f64;
                shards
            }
        };
        Ok(PartitionedRelation::from_shards(
            shards,
            Partitioning::Hash(out_comps),
        ))
    }

    fn eval_add(
        &mut self,
        left: &PartitionedRelation,
        right: &PartitionedRelation,
    ) -> Result<PartitionedRelation, DistError> {
        let w = self.cfg.workers;
        if left.is_replicated() && right.is_replicated() {
            let (out, t) = time(|| add_relations(&left.shards[0], &right.shards[0]));
            self.stats.compute_s += t;
            return Ok(PartitionedRelation::replicate_handle(Arc::new(out), w));
        }
        // Identical hash layouts add worker-local; anything else re-homes
        // both sides by the full key. (`part.clone()` copies a few
        // component indices, never tuple data; shard clones are handle
        // bumps.)
        let aligned = matches!(
            (&left.part, &right.part),
            (Partitioning::Hash(a), Partitioning::Hash(b)) if a == b
        );
        let (lsh, rsh, part): (Vec<Arc<Relation>>, Vec<Arc<Relation>>, Partitioning) =
            if aligned {
                (left.shards.clone(), right.shards.clone(), left.part.clone())
            } else {
                let arity = left.key_arity().max(right.key_arity());
                let comps: Vec<usize> = (0..arity).collect();
                let (lp, st_l) = left.reshuffle_in(&comps, w, self.comm_pool());
                self.account_shuffle(st_l);
                let (rp, st_r) = right.reshuffle_in(&comps, w, self.comm_pool());
                self.account_shuffle(st_r);
                (lp.shards, rp.shards, Partitioning::Hash(comps))
            };
        let results = par_stage(self.pool, w, self.backend, move |wi, _| {
            time(|| add_relations(&lsh[wi], &rsh[wi]))
        });
        let mut shards = Vec::with_capacity(w);
        let mut maxt = 0.0f64;
        for (out, t) in results {
            maxt = maxt.max(t);
            shards.push(out);
        }
        self.stats.compute_s += maxt;
        Ok(PartitionedRelation::from_shards(shards, part))
    }

    /// Allgather a partitioned relation onto every worker.
    fn broadcast(&mut self, pr: &PartitionedRelation) -> PartitionedRelation {
        if pr.is_replicated() {
            return pr.clone();
        }
        let w = self.cfg.workers;
        let full = pr.gather_in(self.comm_pool());
        let bytes = full.nbytes() as u64;
        self.stats.net_s += self.cfg.net.allgather_time(bytes, w);
        if w > 1 {
            self.stats.bytes_shuffled += bytes * (w as u64 - 1);
            self.stats.msgs += w as u64 - 1;
        }
        PartitionedRelation::replicate_handle(Arc::new(full), w)
    }

    fn account_shuffle(&mut self, st: ShuffleStats) {
        self.stats.bytes_shuffled += st.bytes;
        self.stats.msgs += st.msgs;
        self.stats.net_s += self
            .cfg
            .net
            .alltoall_time(st.bytes, st.msgs, self.cfg.workers);
    }
}

// ------------------------------------------------------------ primitives

/// One worker's join-stage output with its measured/modeled accounting.
struct JoinShard {
    out: Relation,
    /// Measured compute seconds (the caller maxes over the stage's
    /// workers, who run in parallel).
    compute_s: f64,
    /// Modeled spill seconds (maxed over workers likewise).
    spill_s: f64,
    /// Spill events: grace passes beyond the first, or one if the stage
    /// ran over budget with an unsplittable build side.
    spill_events: u64,
}

/// One worker's share of a join stage: budget check, grace spilling,
/// measured compute. Runs on the worker's own thread with the worker's
/// own backend (budget/policy are passed by value so the pool job owns
/// its captures). Under `MemPolicy::Fail` the sharded caller pre-checks
/// every worker's budget before launching the stage, so the `Oom` arm
/// below fires only on the replicated run-once path (it is kept as a
/// defensive invariant for any future caller that skips the pre-check).
#[allow(clippy::too_many_arguments)]
fn join_worker_shard(
    budget: Option<u64>,
    policy: MemPolicy,
    wi: usize,
    l: &Relation,
    r: &Relation,
    pred: &JoinPred,
    proj: &KeyProj2,
    kernel: &BinaryKernel,
    backend: &dyn KernelBackend,
) -> Result<JoinShard, DistError> {
    let mut passes: u64 = 1;
    let mut spill = 0.0f64;
    let mut spill_events = 0u64;
    if let Some(budget) = budget {
        let lb = l.nbytes() as u64;
        let rb = r.nbytes() as u64;
        let needed = join_needed_bytes(l, r, pred, kernel);
        if needed > budget {
            match policy {
                MemPolicy::Fail => {
                    return Err(DistError::Oom {
                        worker: wi,
                        needed,
                        budget,
                    });
                }
                MemPolicy::Spill => {
                    // Grace hash join: the build side streams through
                    // memory in budget-sized passes; the probe side is
                    // rescanned per pass; overflow goes through disk.
                    // A build side too small to split still counts one
                    // spill event: the stage ran out-of-core.
                    let build_len = l.len().min(r.len()).max(1) as u64;
                    passes = mem::grace_passes(needed, budget).min(build_len);
                    spill_events = passes.max(2) - 1;
                    // Probe = the side grace_join will actually rescan
                    // (it builds on the smaller-by-count side).
                    let probe_b = if l.len() <= r.len() { rb } else { lb };
                    spill =
                        mem::spill_io_s((passes - 1) * probe_b + needed.saturating_sub(budget));
                }
            }
        }
    }
    let (out, t) = time(|| grace_join(l, r, pred, proj, kernel, passes as usize, backend));
    Ok(JoinShard {
        out: out.map_err(DistError::Other)?,
        compute_s: t,
        spill_s: spill,
        spill_events,
    })
}

/// Worker-local ⋈, optionally in grace passes: the build (smaller) side
/// is split into `passes` groups, each joined against the full probe
/// side — identical output to a single pass, with a bounded-resident
/// build table.
fn grace_join(
    l: &Relation,
    r: &Relation,
    pred: &JoinPred,
    proj: &KeyProj2,
    kernel: &BinaryKernel,
    passes: usize,
    backend: &dyn KernelBackend,
) -> Result<Relation> {
    if passes <= 1 {
        return hash_join(l, r, pred, proj, kernel, backend);
    }
    let build_left = l.len() <= r.len();
    let (build, probe) = if build_left { (l, r) } else { (r, l) };
    let per = build.len().div_ceil(passes).max(1);
    let mut out = Relation::with_capacity(probe.len());
    for group in build.pairs().chunks(per) {
        let sub = Relation::from_pairs(group.to_vec());
        let part = if build_left {
            hash_join(&sub, probe, pred, proj, kernel, backend)?
        } else {
            hash_join(probe, &sub, pred, proj, kernel, backend)?
        };
        for (k, v) in part.into_pairs() {
            if out.contains(&k) {
                bail!(
                    "⋈ projection {proj} is not injective on matches: key {k} collides (add a Σ to aggregate)"
                );
            }
            out.insert(k, v);
        }
    }
    Ok(out)
}

/// Cross-worker key-disjointness check for `Arbitrary` outputs, matching
/// the single-node injectivity error. `Hash`/`Replicated` outputs need no
/// check: equal keys co-locate, so the per-worker checks already caught
/// any collision.
fn check_disjoint(shards: &[Relation], what: impl std::fmt::Display) -> Result<()> {
    let total: usize = shards.iter().map(|s| s.len()).sum();
    let mut seen = crate::util::FxHashSet::default();
    seen.reserve(total);
    for shard in shards {
        for (k, _) in shard.iter() {
            if !seen.insert(*k) {
                bail!("{what} is not injective across workers: key {k} collides");
            }
        }
    }
    Ok(())
}

/// Positions in `proj`'s output carrying each of `comps` (in order);
/// `None` if any component is dropped.
fn preserved_positions(comps: &[usize], proj: &KeyProj) -> Option<Vec<usize>> {
    comps
        .iter()
        .map(|&c| proj.0.iter().position(|s| *s == Sel::C(c)))
        .collect()
}

/// As `preserved_positions`, for one side of a binary projection.
fn preserved_positions2(comps: &[usize], proj: &KeyProj2, left: bool) -> Option<Vec<usize>> {
    comps
        .iter()
        .map(|&c| {
            let want = if left { Sel2::L(c) } else { Sel2::R(c) };
            proj.0.iter().position(|s| *s == want)
        })
        .collect()
}

/// Partitioning of a join output: replicated iff both sides are; else
/// the surviving hash invariant of either stored side, if its components
/// are carried through the projection.
fn join_output_part(lpart: &Partitioning, rpart: &Partitioning, proj: &KeyProj2) -> Partitioning {
    if matches!(
        (lpart, rpart),
        (Partitioning::Replicated, Partitioning::Replicated)
    ) {
        return Partitioning::Replicated;
    }
    if let Partitioning::Hash(c) = lpart {
        if let Some(pos) = preserved_positions2(c, proj, true) {
            return Partitioning::Hash(pos);
        }
    }
    if let Partitioning::Hash(c) = rpart {
        if let Some(pos) = preserved_positions2(c, proj, false) {
            return Partitioning::Hash(pos);
        }
    }
    Partitioning::Arbitrary
}

#[inline]
fn tuple_out_bytes(shape: (usize, usize)) -> u64 {
    (4 * shape.0 * shape.1 + std::mem::size_of::<Key>()) as u64
}

/// One worker's join working set: build + probe + estimated output.
fn join_needed_bytes(l: &Relation, r: &Relation, pred: &JoinPred, kernel: &BinaryKernel) -> u64 {
    l.nbytes() as u64 + r.nbytes() as u64 + estimate_join_out_bytes(l, r, pred, kernel)
}

/// Bytes the join output will occupy on this worker — exact match
/// counting per join key for equi-joins, an upper bound for cross joins.
fn estimate_join_out_bytes(
    l: &Relation,
    r: &Relation,
    pred: &JoinPred,
    kernel: &BinaryKernel,
) -> u64 {
    if l.is_empty() || r.is_empty() {
        return 0;
    }
    let lv0 = &l.pairs()[0].1;
    let rv0 = &r.pairs()[0].1;
    let default_shape = kernel.out_shape(lv0.shape(), rv0.shape()).unwrap_or(lv0.shape());
    if pred.eqs.is_empty() {
        return (l.len() as u64) * (r.len() as u64) * tuple_out_bytes(default_shape);
    }
    let lcomps = pred.left_comps();
    let rcomps = pred.right_comps();
    let mut groups: FxHashMap<Key, (u64, (usize, usize))> = FxHashMap::default();
    for (rk, rv) in r.iter() {
        if !pred.r_lits.iter().all(|&(j, v)| rk.get(j) == v) {
            continue;
        }
        let e = groups.entry(subkey(rk, &rcomps)).or_insert((0, rv.shape()));
        e.0 += 1;
    }
    let mut total = 0u64;
    for (lk, lv) in l.iter() {
        if !pred.l_lits.iter().all(|&(i, v)| lk.get(i) == v) {
            continue;
        }
        if let Some(&(cnt, rshape)) = groups.get(&subkey(lk, &lcomps)) {
            let shape = kernel.out_shape(lv.shape(), rshape).unwrap_or(default_shape);
            total += cnt * tuple_out_bytes(shape);
        }
    }
    total
}

#[cfg(test)]
// These unit tests exercise the deprecated free-function surface on
// purpose: it must keep working (and keep matching the session path)
// until it is removed. New code goes through `session::Session` — see
// the migration note on the `session` module.
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::kernels::NativeBackend;
    use crate::ra::eval::eval_query;
    use crate::ra::expr::{matmul_query, QueryBuilder};
    use crate::ra::Chunk;
    use crate::util::Prng;

    fn blocked(n: i64, m: i64, c: usize, rng: &mut Prng) -> Relation {
        let mut r = Relation::new();
        for i in 0..n {
            for j in 0..m {
                r.insert(Key::k2(i, j), Chunk::random(c, c, rng, 1.0));
            }
        }
        r
    }

    #[test]
    fn dist_matmul_matches_single_node_across_worker_counts() {
        let mut rng = Prng::new(71);
        let a = blocked(3, 2, 4, &mut rng);
        let b = blocked(2, 3, 4, &mut rng);
        let q = matmul_query();
        let want = eval_query(&q, &[&a, &b], &NativeBackend).unwrap();
        for w in [1usize, 2, 4, 7] {
            let pa = PartitionedRelation::hash_full(&a, w);
            let pb = PartitionedRelation::hash_full(&b, w);
            let (got, stats) =
                dist_eval(&q, &[pa, pb], &ClusterConfig::new(w), &NativeBackend).unwrap();
            assert!(got.gather().approx_eq(&want, 1e-4), "w={w}");
            assert_eq!(stats.spill_passes, 0, "w={w}: unbudgeted run spilled");
            assert!(stats.virtual_time_s > 0.0);
            assert!(stats.wall_s > 0.0);
        }
    }

    #[test]
    fn co_partitioned_inputs_join_locally() {
        let mut rng = Prng::new(72);
        let a = blocked(4, 3, 2, &mut rng);
        let b = blocked(3, 4, 2, &mut rng);
        let q = matmul_query();
        // Matmul joins on A[1] = B[0]: partition A by col, B by row.
        let pa = PartitionedRelation::hash_partition(&a, &[1], 3);
        let pb = PartitionedRelation::hash_partition(&b, &[0], 3);
        let plan = plan_join(
            &pa,
            &pb,
            &crate::ra::funcs::JoinPred::on(vec![(1, 0)]),
            &NetModel::default(),
            3,
        );
        assert_eq!(plan.strategy, JoinStrategy::Local);
        // And the full query still matches single node.
        let want = eval_query(&q, &[&a, &b], &NativeBackend).unwrap();
        let (got, _) =
            dist_eval(&q, &[pa, pb], &ClusterConfig::new(3), &NativeBackend).unwrap();
        assert!(got.gather().approx_eq(&want, 1e-4));
    }

    #[test]
    fn replicated_side_never_moves() {
        let mut rng = Prng::new(73);
        let a = blocked(4, 2, 2, &mut rng);
        let b = blocked(2, 2, 2, &mut rng);
        let pa = PartitionedRelation::hash_partition(&a, &[0], 4);
        let pb = PartitionedRelation::replicate(&b, 4);
        let plan = plan_join(
            &pa,
            &pb,
            &crate::ra::funcs::JoinPred::on(vec![(1, 0)]),
            &NetModel::default(),
            4,
        );
        assert_eq!(plan.strategy, JoinStrategy::Local);
    }

    #[test]
    fn spill_results_identical_and_fail_ooms() {
        let mut rng = Prng::new(74);
        let a = blocked(4, 4, 8, &mut rng);
        let b = blocked(4, 4, 8, &mut rng);
        let q = matmul_query();
        let want = {
            let pa = PartitionedRelation::hash_full(&a, 3);
            let pb = PartitionedRelation::hash_full(&b, 3);
            let (got, stats) =
                dist_eval(&q, &[pa, pb], &ClusterConfig::new(3), &NativeBackend).unwrap();
            assert_eq!(stats.spill_passes, 0);
            got.gather()
        };
        let pa = PartitionedRelation::hash_full(&a, 3);
        let pb = PartitionedRelation::hash_full(&b, 3);
        let spill_cfg = ClusterConfig::new(3)
            .with_budget(2048)
            .with_policy(MemPolicy::Spill);
        let (got, stats) =
            dist_eval(&q, &[pa.clone(), pb.clone()], &spill_cfg, &NativeBackend).unwrap();
        assert!(stats.spill_passes > 0, "tight budget must spill");
        assert!(stats.spill_s > 0.0);
        assert!(got.gather().approx_eq(&want, 0.0), "spill changed results");
        let fail_cfg = ClusterConfig::new(3)
            .with_budget(2048)
            .with_policy(MemPolicy::Fail);
        match dist_eval(&q, &[pa, pb], &fail_cfg, &NativeBackend) {
            Err(DistError::Oom { needed, budget, .. }) => {
                assert!(needed > budget);
            }
            other => panic!("expected OOM, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn two_phase_agg_merges_cross_worker_groups() {
        // All tuples share one group: partials live on several workers and
        // must be merged by the exchange.
        let mut rng = Prng::new(75);
        let mut x = Relation::new();
        for i in 0..20 {
            x.insert(Key::k1(i), Chunk::random(1, 1, &mut rng, 1.0));
        }
        let q = {
            let mut qb = QueryBuilder::new();
            let s = qb.scan(0, "x");
            let a = qb.agg(KeyProj::to_empty(), AggKernel::Sum, s);
            qb.finish(a)
        };
        let want = eval_query(&q, &[&x], &NativeBackend).unwrap();
        for w in [1usize, 3, 6] {
            let px = PartitionedRelation::hash_full(&x, w);
            let (got, _) =
                dist_eval(&q, &[px], &ClusterConfig::new(w), &NativeBackend).unwrap();
            let g = got.gather();
            assert_eq!(g.len(), 1);
            assert!(g.approx_eq(&want, 1e-5), "w={w}");
        }
    }

    #[test]
    fn estimate_counts_equi_join_output_exactly() {
        let mut rng = Prng::new(76);
        let a = blocked(3, 2, 2, &mut rng);
        let b = blocked(2, 3, 2, &mut rng);
        let pred = crate::ra::funcs::JoinPred::on(vec![(1, 0)]);
        let proj = KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]);
        let kernel = BinaryKernel::MatMul;
        let est = estimate_join_out_bytes(&a, &b, &pred, &kernel);
        let out = hash_join(&a, &b, &pred, &proj, &kernel, &NativeBackend).unwrap();
        assert_eq!(est, out.nbytes() as u64);
    }
}
