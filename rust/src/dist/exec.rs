//! Stage-by-stage BSP execution of a functional-RA query across virtual
//! workers, with the per-worker shards of every stage running on real OS
//! threads.
//!
//! Every query node becomes one cluster stage:
//!
//! * **σ / value maps** run worker-local; the partitioning invariant is
//!   propagated through the key projection.
//! * **⋈** goes through [`plan_join`]: if both sides are already
//!   partitioned on their join components (or a side is replicated) the
//!   join is worker-local; otherwise the planner prices *reshuffle*
//!   (re-home the misplaced side(s) by join-key hash) against
//!   *broadcast* (allgather one side) on the [`NetModel`] and picks the
//!   cheaper, using `plan::join_cardinality` to bias broadcast toward
//!   the unique side of a 1-n join. Per worker, the stage working set
//!   (`build + probe + estimated output`) is checked against the memory
//!   budget — over budget, [`MemPolicy::Fail`] returns
//!   [`DistError::Oom`] while [`MemPolicy::Spill`] executes the join as
//!   a *real* grace hash join: the build side is written to the worker's
//!   spill scratch (`dist::spill`) in budget-sized columnar runs and
//!   streamed back pass by pass, the probe side is rescanned per pass,
//!   the measured temp-file traffic lands in
//!   `ExecStats::spill_bytes_written`/`spill_bytes_read`, and the
//!   virtual cluster's disk time is charged to the modeled spill clock.
//! * **Σ** is two-phase: local pre-aggregation, a hash exchange on the
//!   group key, and a final merge — except when the input partitioning
//!   already co-locates every group, where the local phase is final.
//!   A factorized plan (`plan::factorize`) may hand the executor an
//!   *exchange hint* for a partial Σ: hash the exchange on the
//!   join-predicate components (a subset of the group key, which still
//!   co-locates every group) so the Σ's one shuffle lands its output
//!   co-partitioned for the join above.
//! * **add** runs worker-local when both sides share a hash layout, and
//!   re-homes both by the full key otherwise.
//! * **shuffle elision** (`ClusterConfig::elide_shuffles`): within one
//!   tape execution the executor memoizes every reshuffle/broadcast by
//!   (source node, target components); a node that two stages would
//!   move the same way crosses the fabric once, and the repeat is
//!   counted in `ExecStats::{shuffles_elided, bytes_shuffle_elided}`.
//!   The memo returns the exact relation a fresh movement would
//!   rebuild (`shuffle::owner` is pure and routing is deterministic),
//!   so elision never changes results, bitwise.
//!
//! **Threading model.** A persistent [`WorkerPool`](super::pool) fans
//! every stage out to `w` parked worker threads, each owning a
//! [`KernelBackend`] instance minted *once per pool* by
//! `KernelBackend::for_worker` (the per-node runtime of a real
//! deployment; PJRT handles never cross threads). The pool lives for the
//! whole evaluation — or, driven through `ml::DistTrainer` /
//! `ml::TrainPipeline`, for the whole forward+backward step or training
//! loop — so stages pay job dispatch, not thread spawn/join, and
//! backends are never re-minted per stage or per evaluation. Stage
//! compute, the `shuffle::exchange*` route/build phases, `gather_in`,
//! and the two-phase Σ final merge all run as sharded pool jobs; only
//! the cheap planning/accounting glue stays on the driver thread.
//! Results are collected in worker-index order, so pooled execution is
//! *bitwise identical* to the serial reference path
//! (`ClusterConfig::parallel = false`, or `parallel_comm = false` for
//! the communication steps alone): same shard relations, same iteration
//! order, same float associativity. `ExecStats` reports both the modeled
//! `virtual_time_s` (max-over-workers compute + modeled net/spill) and
//! the measured `wall_s` of the run, which shrinks with worker count up
//! to the host's core count.
//!
//! **Fault tolerance.** Every stage body runs with per-shard panic
//! containment ([`try_par_stage`]): a panicking worker job lands as a
//! typed failure in its result slot, never unwinding the driver, and
//! the pool survives for the next stage. Transient failures — injected
//! faults from a configured [`ClusterConfig::fault_plan`], or genuine
//! spill-file I/O errors — trigger *bounded retry with lineage replay*:
//! stage inputs are immutable `Arc<Relation>` shards already on the
//! tape, so the node loop simply re-runs the stage from them, up to
//! [`ClusterConfig::max_stage_retries`] times, restoring the stats and
//! shuffle-memo snapshots taken before the attempt (no double-counted
//! traffic, no half-installed memo entries, and the aborted attempt's
//! spill runs are removed by delete-on-drop). Exhausted retries and
//! fatal (non-injected) job panics surface as typed
//! [`DistError::StageFailed`] with exact stage/worker/attempt
//! coordinates. Because a replay recomputes from the same immutable
//! inputs with the same deterministic kernels and routing, a
//! faulted-but-retried run is **bitwise identical** to the fault-free
//! run. Without a fault plan (the default) no injector exists and no
//! probe site executes — `dist::fault::probes()` stays zero.
//!
//! Results are partition-invariant: `dist_eval(q, parts).gather()`
//! equals single-node `eval_query(q, inputs)` (up to float reassociation
//! in Σ) for every worker count and input layout.

use std::borrow::Cow;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

use anyhow::{anyhow, bail, Result};

use super::delta::{self, DeltaCtx, DeltaStep, NodeStatus};
use super::fault::{FaultInjector, InjectionPoint};
use super::mem::{self, MemPolicy};
use super::net::NetModel;
use super::partition::{PartitionedRelation, Partitioning};
use super::pool::{classify_panic, JobFailure, WorkerPool};
use super::shuffle::{self, ShuffleStats};
use super::spill::{SpillReader, SpillSpace, SpillWriter};
use super::{ClusterConfig, DistError, ExecStats, StageFailure};
use crate::kernels::{AggKernel, BinaryKernel, KernelBackend, UnaryKernel};
use crate::plan::{join_cardinality, JoinCard};
use crate::ra::eval::{add_relations, aggregate, apply_select, hash_join, subkey};
use crate::ra::expr::{Node, NodeId, Op, Query};
use crate::ra::funcs::{JoinPred, KeyPred, KeyProj, KeyProj2, Sel, Sel2};
use crate::ra::{Chunk, Key, Relation};
use crate::util::FxHashMap;

/// Intermediate partitioned relations per query node, as captured by a
/// distributed forward execution — the distributed analogue of
/// `ra::eval::Tape`, feeding the generated backward query. Shards are
/// `Arc` handles, so cloning tape entries is reference counting, not
/// data movement.
#[derive(Clone)]
pub struct DistTape {
    pub rels: Vec<PartitionedRelation>,
}

impl DistTape {
    pub fn rel(&self, id: NodeId) -> &PartitionedRelation {
        &self.rels[id]
    }

    pub fn output(&self, q: &Query) -> &PartitionedRelation {
        &self.rels[q.output]
    }

    pub fn nbytes(&self) -> u64 {
        self.rels.iter().map(|r| r.nbytes()).sum()
    }
}

/// One stage of an executed plan, as recorded by the tracing executor —
/// the physical decisions `Session::query(..)?.explain()` renders: which
/// operator ran, the join strategy the cost-based planner picked, the
/// partitioning invariant of the stage output, and the shuffle traffic
/// the stage generated.
#[derive(Clone, Debug)]
pub struct StageTrace {
    /// Query node this stage executed.
    pub node: NodeId,
    /// Operator kind (`τ`, `σ`, `⋈`, `Σ`, `add`, `const`).
    pub op: &'static str,
    /// The physical join decision, for `⋈` stages.
    pub strategy: Option<JoinStrategy>,
    /// Output partitioning invariant (rendered).
    pub out_part: String,
    /// Bytes this stage moved across the (modeled) network.
    pub bytes_shuffled: u64,
    /// Bytes this stage would have moved but served from the partition
    /// memo instead ([`ClusterConfig::elide_shuffles`]).
    pub bytes_shuffle_elided: u64,
    /// Reshuffles/broadcasts this stage satisfied from the memo.
    pub shuffles_elided: u64,
    /// Point-to-point messages those bytes travelled in.
    pub msgs: u64,
    /// Measured compute seconds this stage added (max over workers).
    pub compute_s: f64,
    /// Spill events this stage charged.
    pub spill_passes: u64,
    /// Measured bytes this stage wrote to spill temp files (summed over
    /// workers).
    pub spill_bytes_written: u64,
    /// Measured bytes this stage re-read from spill temp files.
    pub spill_bytes_read: u64,
    /// Faults the configured injector fired during this stage (all
    /// attempts). Zero without a `ClusterConfig::fault_plan`.
    pub faults_injected: u64,
    /// Times this stage was replayed after a transient shard failure.
    pub stage_retries: u64,
    /// Worker shards recomputed by those replays (`w` per retry).
    pub shards_recomputed: u64,
    /// Checkpoint bytes charged while this stage ran — always zero for
    /// query stages today (trainer checkpoints write between
    /// executions); kept so the trace mirrors every `ExecStats` counter.
    pub checkpoint_bytes: u64,
    /// Delta rows charged while this stage ran — always zero at stage
    /// granularity (ingest and replay charge at the session layer); kept
    /// so the trace mirrors every `ExecStats` counter.
    pub delta_rows_applied: u64,
    /// Worker shards this stage served from the previous tape instead of
    /// recomputing — `w` for a reused or suffix-appended delta stage,
    /// zero for a computed one.
    pub shards_reused: u64,
    /// Delta-gate fallbacks charged while this stage ran — always zero
    /// at stage granularity (the gate refuses whole frames, before any
    /// stage runs); kept so the trace mirrors every `ExecStats` counter.
    pub delta_fallbacks: u64,
    /// Hot probe-side rows this stage routed by the skew salt rule
    /// (zero for every non-skew stage).
    pub rows_salted: u64,
    /// Bytes of hot build-side rows this stage replicated beyond their
    /// first copy under a skew strategy.
    pub bytes_hot_replicated: u64,
    /// Largest per-worker join-input load (build + probe bytes after
    /// movement) of a ⋈ stage — the quantity the skew strategies
    /// flatten; zero for non-join stages. Recorded for *every* join
    /// strategy, so a skew run's trace can be compared against the
    /// oblivious run's to see the hot shard shrink.
    pub max_shard_bytes: u64,
}

/// Evaluate a query distributed; return the output relation (still
/// partitioned, a cheap handle copy out of the tape) and the execution
/// stats. Builds a fresh [`WorkerPool`] for this one evaluation when the
/// configuration threads.
#[deprecated(
    since = "0.2.0",
    note = "use `session::Session`: register tables once, then `sess.query(&q)?.collect()` \
            (see the `session` module migration note)"
)]
pub fn dist_eval(
    q: &Query,
    inputs: &[PartitionedRelation],
    cfg: &ClusterConfig,
    backend: &dyn KernelBackend,
) -> Result<(PartitionedRelation, ExecStats), DistError> {
    let pool = WorkerPool::maybe_new(cfg, backend);
    let (tape, stats) = eval_tape_core(q, inputs, cfg, backend, pool.as_ref(), &[], None)?;
    Ok((tape.rels[q.output].clone(), stats))
}

/// [`dist_eval`] on a caller-provided worker pool (or `None` for the
/// serial reference path).
#[deprecated(
    since = "0.2.0",
    note = "use `session::Session`, which owns the pool for its whole lifetime \
            (see the `session` module migration note)"
)]
pub fn dist_eval_in(
    q: &Query,
    inputs: &[PartitionedRelation],
    cfg: &ClusterConfig,
    backend: &dyn KernelBackend,
    pool: Option<&WorkerPool>,
) -> Result<(PartitionedRelation, ExecStats), DistError> {
    let (tape, stats) = eval_tape_core(q, inputs, cfg, backend, pool, &[], None)?;
    Ok((tape.rels[q.output].clone(), stats))
}

/// Evaluate a query distributed, returning the relations of several
/// nodes (the backward plan's per-slot gradient outputs share one DAG).
/// The returned relations are handle copies out of the tape.
#[deprecated(
    since = "0.2.0",
    note = "use `session::Session` — `sess.query(&q)?.grad(..)` runs the multi-output \
            backward plan through the session pool (see the `session` module migration note)"
)]
pub fn dist_eval_multi(
    q: &Query,
    inputs: &[PartitionedRelation],
    outputs: &[NodeId],
    cfg: &ClusterConfig,
    backend: &dyn KernelBackend,
) -> Result<(Vec<PartitionedRelation>, ExecStats), DistError> {
    let pool = WorkerPool::maybe_new(cfg, backend);
    eval_multi_core(q, inputs, outputs, cfg, backend, pool.as_ref(), &[])
}

/// [`dist_eval_multi`] on a caller-provided worker pool.
#[deprecated(
    since = "0.2.0",
    note = "use `session::Session` (see the `session` module migration note)"
)]
pub fn dist_eval_multi_in(
    q: &Query,
    inputs: &[PartitionedRelation],
    outputs: &[NodeId],
    cfg: &ClusterConfig,
    backend: &dyn KernelBackend,
    pool: Option<&WorkerPool>,
) -> Result<(Vec<PartitionedRelation>, ExecStats), DistError> {
    eval_multi_core(q, inputs, outputs, cfg, backend, pool, &[])
}

/// Evaluate a query distributed, capturing every intermediate
/// partitioned relation (the forward pass of distributed training).
/// Builds a fresh [`WorkerPool`] for this one evaluation when the
/// configuration threads.
#[deprecated(
    since = "0.2.0",
    note = "use `session::Session` (see the `session` module migration note)"
)]
pub fn dist_eval_tape(
    q: &Query,
    inputs: &[PartitionedRelation],
    cfg: &ClusterConfig,
    backend: &dyn KernelBackend,
) -> Result<(DistTape, ExecStats), DistError> {
    let pool = WorkerPool::maybe_new(cfg, backend);
    eval_tape_core(q, inputs, cfg, backend, pool.as_ref(), &[], None)
}

/// [`dist_eval_tape`] on a caller-provided worker pool.
#[deprecated(
    since = "0.2.0",
    note = "use `session::Session` (see the `session` module migration note)"
)]
pub fn dist_eval_tape_in(
    q: &Query,
    inputs: &[PartitionedRelation],
    cfg: &ClusterConfig,
    backend: &dyn KernelBackend,
    pool: Option<&WorkerPool>,
) -> Result<(DistTape, ExecStats), DistError> {
    eval_tape_core(q, inputs, cfg, backend, pool, &[], None)
}

/// [`dist_eval_multi`]'s body on the shared core: tape + handle-copy the
/// requested outputs.
pub(crate) fn eval_multi_core(
    q: &Query,
    inputs: &[PartitionedRelation],
    outputs: &[NodeId],
    cfg: &ClusterConfig,
    backend: &dyn KernelBackend,
    pool: Option<&WorkerPool>,
    agg_exchange: &[(NodeId, Vec<usize>)],
) -> Result<(Vec<PartitionedRelation>, ExecStats), DistError> {
    let (tape, stats) = eval_tape_core(q, inputs, cfg, backend, pool, agg_exchange, None)?;
    Ok((
        outputs.iter().map(|&id| tape.rels[id].clone()).collect(),
        stats,
    ))
}

/// The one stage-by-stage evaluator behind every entry point —
/// `session::Session` (the supported front door), the deprecated
/// `dist_eval*` wrappers, and `ml`'s training step all funnel here.
/// Every stage of the evaluation runs on `pool`'s parked threads and
/// their already-minted backends; passing `None` — or a `cfg` with
/// `parallel = false` — takes the serial reference path; a pool of the
/// wrong width is an error. When `trace` is given, the executor records
/// one [`StageTrace`] per query node (the raw material of
/// `Frame::explain`).
pub(crate) fn eval_tape_core(
    q: &Query,
    inputs: &[PartitionedRelation],
    cfg: &ClusterConfig,
    backend: &dyn KernelBackend,
    pool: Option<&WorkerPool>,
    agg_exchange: &[(NodeId, Vec<usize>)],
    trace: Option<&mut Vec<StageTrace>>,
) -> Result<(DistTape, ExecStats), DistError> {
    eval_tape_delta(q, inputs, cfg, backend, pool, agg_exchange, trace, None)
        .map(|(tape, stats, _)| (tape, stats))
}

/// As [`eval_tape_core`], plus incremental maintenance: when `delta`
/// carries the previous run's tape and per-slot change descriptors, each
/// stage consults [`delta::plan_node`] and — where bitwise-safe — serves
/// the previous output verbatim or replays only the appended suffix
/// instead of recomputing ([`Executor::eval_node_delta`]). The derived
/// per-node [`NodeStatus`]es are returned alongside the tape so a caller
/// can thread change information into a dependent (backward) run.
#[allow(clippy::too_many_arguments)]
pub(crate) fn eval_tape_delta(
    q: &Query,
    inputs: &[PartitionedRelation],
    cfg: &ClusterConfig,
    backend: &dyn KernelBackend,
    pool: Option<&WorkerPool>,
    agg_exchange: &[(NodeId, Vec<usize>)],
    mut trace: Option<&mut Vec<StageTrace>>,
    delta: Option<&DeltaCtx>,
) -> Result<(DistTape, ExecStats, Vec<NodeStatus>), DistError> {
    if inputs.len() < q.n_slots {
        return Err(DistError::Other(anyhow!(
            "query needs {} input(s), got {}",
            q.n_slots,
            inputs.len()
        )));
    }
    for (i, pr) in inputs.iter().enumerate() {
        if pr.workers() != cfg.workers {
            return Err(DistError::Other(anyhow!(
                "input slot {i} is sharded across {} worker(s), cluster has {}",
                pr.workers(),
                cfg.workers
            )));
        }
    }
    if let Some(p) = pool {
        if p.workers() != cfg.workers {
            return Err(DistError::Other(anyhow!(
                "worker pool has {} worker(s), cluster config has {}",
                p.workers(),
                cfg.workers
            )));
        }
    }
    // Spill scratch: only a budgeted `Spill` configuration can ever
    // write. The pool's session-lifetime space is used when one exists;
    // otherwise a per-evaluation space is created *lazily by the first
    // over-budget stage* and removed when the evaluation finishes — a
    // within-budget run never touches the scratch device, and an
    // unwritable spill root only fails queries that actually spill.
    let spill: Option<Arc<LazySpill>> = (cfg.policy == MemPolicy::Spill
        && cfg.budget.is_some())
    .then(|| {
        Arc::new(LazySpill {
            hint: cfg.spill_dir.clone(),
            pool_space: pool.and_then(|p| p.spill_space()),
            own: OnceLock::new(),
        })
    });
    // Fault injection: one injector per execution (occurrence counters
    // restart at 1 for each query/step), `None` — and therefore zero
    // probes anywhere — without a configured plan.
    let faults: Option<Arc<FaultInjector>> = cfg
        .fault_plan
        .as_ref()
        .map(|p| Arc::new(FaultInjector::new(Arc::clone(p), cfg.workers)));
    let mut ex = Executor {
        cfg,
        backend,
        // `parallel = false` forces the serial reference path even when a
        // caller hands us a live pool (the determinism A/B switch).
        pool: if cfg.parallel { pool } else { None },
        spill,
        faults,
        stats: ExecStats::default(),
        last_join: None,
        last_join_load: None,
        agg_exchange,
        resh_memo: FxHashMap::default(),
        bcast_memo: FxHashMap::default(),
    };
    // Clock started after pool/backend setup: wall_s measures execution,
    // not per-worker runtime instantiation (which, with a caller-held
    // pool, is amortized over every evaluation the pool serves).
    let t0 = std::time::Instant::now();
    let max_retries = cfg.max_stage_retries;
    let w = cfg.workers;
    let mut rels: Vec<PartitionedRelation> = Vec::with_capacity(q.len());
    let mut statuses: Vec<NodeStatus> = Vec::with_capacity(q.len());
    for (id, node) in q.nodes.iter().enumerate() {
        // Delta planning happens outside the retry loop: the decision is
        // a pure function of the previous tape and the already-computed
        // child outputs, so a replayed attempt takes the same step.
        let (status, step) = match delta {
            Some(d) => delta::plan_node(id, node, &statuses, d, &rels, cfg),
            None => (NodeStatus::Dirty, DeltaStep::Compute),
        };
        let before = ex.stats;
        let mut attempt: u32 = 1;
        // Bounded retry with lineage replay: a stage's inputs are the
        // immutable `Arc<Relation>` shards already on the tape, so a
        // transiently-failed stage simply reruns from them. Each attempt
        // snapshots the accounting and the shuffle memos (Arc-handle
        // clones, not data) and restores them before a replay — an
        // aborted attempt neither double-counts traffic nor leaves
        // half-installed memo entries behind.
        let r = loop {
            let stats_snap = ex.stats;
            let resh_snap = ex.resh_memo.clone();
            let bcast_snap = ex.bcast_memo.clone();
            let res = match (step, delta) {
                (DeltaStep::Compute, _) | (_, None) => ex.eval_node(id, node, &rels, inputs),
                (step, Some(d)) => ex.eval_node_delta(id, node, &rels, step, d),
            };
            if let Some(inj) = &ex.faults {
                ex.stats.faults_injected = inj.injected();
            }
            match res {
                Ok(r) => break Ok(r),
                Err(DistError::Transient { worker, what }) => {
                    if attempt > max_retries {
                        break Err(DistError::StageFailed {
                            stage: id,
                            worker,
                            attempts: attempt,
                            source: StageFailure::RetriesExhausted(what),
                        });
                    }
                    ex.resh_memo = resh_snap;
                    ex.bcast_memo = bcast_snap;
                    ex.stats = stats_snap;
                    if let Some(inj) = &ex.faults {
                        ex.stats.faults_injected = inj.injected();
                    }
                    ex.last_join = None;
                    ex.last_join_load = None;
                    ex.stats.stage_retries += 1;
                    ex.stats.shards_recomputed += w as u64;
                    attempt += 1;
                }
                // A fatal shard failure carries placeholder coordinates
                // from the dispatch layer; stamp the real stage id and
                // attempt count here.
                Err(DistError::StageFailed { worker, source, .. }) => {
                    break Err(DistError::StageFailed {
                        stage: id,
                        worker,
                        attempts: attempt,
                        source,
                    });
                }
                Err(DistError::Other(err)) => {
                    break Err(DistError::Other(err.context(format!(
                        "evaluating node v{id} ({}) distributed",
                        node.op.kind()
                    ))));
                }
                Err(oom) => break Err(oom),
            }
        };
        let r = r?;
        if let Some(t) = trace.as_mut() {
            t.push(StageTrace {
                node: id,
                op: node.op.kind(),
                strategy: ex.last_join.take().map(|p| p.strategy),
                out_part: format!("{:?}", r.part),
                bytes_shuffled: ex.stats.bytes_shuffled - before.bytes_shuffled,
                bytes_shuffle_elided: ex.stats.bytes_shuffle_elided
                    - before.bytes_shuffle_elided,
                shuffles_elided: ex.stats.shuffles_elided - before.shuffles_elided,
                msgs: ex.stats.msgs - before.msgs,
                compute_s: ex.stats.compute_s - before.compute_s,
                spill_passes: ex.stats.spill_passes - before.spill_passes,
                spill_bytes_written: ex.stats.spill_bytes_written - before.spill_bytes_written,
                spill_bytes_read: ex.stats.spill_bytes_read - before.spill_bytes_read,
                faults_injected: ex.stats.faults_injected - before.faults_injected,
                stage_retries: ex.stats.stage_retries - before.stage_retries,
                shards_recomputed: ex.stats.shards_recomputed - before.shards_recomputed,
                checkpoint_bytes: 0,
                delta_rows_applied: ex.stats.delta_rows_applied - before.delta_rows_applied,
                shards_reused: ex.stats.shards_reused - before.shards_reused,
                delta_fallbacks: ex.stats.delta_fallbacks - before.delta_fallbacks,
                rows_salted: ex.stats.rows_salted - before.rows_salted,
                bytes_hot_replicated: ex.stats.bytes_hot_replicated
                    - before.bytes_hot_replicated,
                max_shard_bytes: ex.last_join_load.take().unwrap_or(0),
            });
        }
        rels.push(r);
        statuses.push(status);
        ex.stats.stages += 1;
    }
    let mut stats = ex.stats;
    stats.virtual_time_s = stats.compute_s + stats.net_s + stats.spill_s;
    stats.wall_s = t0.elapsed().as_secs_f64();
    Ok((DistTape { rels }, stats, statuses))
}

// ---------------------------------------------------------------- planner

/// Which operand a physical decision applies to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JoinSide {
    Left,
    Right,
}

/// The physical execution strategy for one join stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JoinStrategy {
    /// The partitionings already co-locate every match (or a side is
    /// replicated, or there is a single worker): no traffic.
    Local,
    /// Re-home the flagged side(s) by the hash of their join components.
    Reshuffle { left: bool, right: bool },
    /// Allgather one side onto every worker; the other side stays put.
    Broadcast { side: JoinSide },
    /// Skew strategy over a co-partitioned join whose `side` carries a
    /// [`Partitioning::SkewHash`] annotation: that side's hot-key rows
    /// fan out across `salts` salted buckets (deterministic round-robin
    /// from the row's home worker), the other side's hot rows are
    /// replicated to those buckets, and cold rows of both sides stay
    /// put. The oblivious baseline it must reproduce bitwise is
    /// [`JoinStrategy::Local`].
    SkewSalt { side: JoinSide, salts: usize },
    /// Skew strategy for a join the oblivious planner would execute by
    /// reshuffling the *other* side onto `side`'s skew-hashed layout:
    /// `side`'s hot rows are replicated to every worker, the other
    /// side's hot rows stay at their source shard (joining against the
    /// replicas), and only its cold tail is hash-routed. The oblivious
    /// baseline it must reproduce bitwise is
    /// `Reshuffle` of the other side alone.
    SkewBroadcast { side: JoinSide },
}

/// A costed physical join decision.
#[derive(Clone, Copy, Debug)]
pub struct JoinPlan {
    pub strategy: JoinStrategy,
    /// Cardinality class from `plan::join_cardinality` — also used to
    /// bias broadcast toward the unique side of a 1-n join.
    pub card: JoinCard,
}

/// Cost-based physical planning for one distributed join: co-partitioned
/// when the partitioning invariant already matches, otherwise the
/// cheaper of reshuffle and broadcast under `net`.
pub fn plan_join(
    left: &PartitionedRelation,
    right: &PartitionedRelation,
    pred: &JoinPred,
    net: &NetModel,
    workers: usize,
) -> JoinPlan {
    let card = join_cardinality(pred, left.key_arity(), right.key_arity());
    if workers <= 1 || left.is_replicated() || right.is_replicated() {
        return JoinPlan {
            strategy: JoinStrategy::Local,
            card,
        };
    }
    let lb = left.nbytes();
    let rb = right.nbytes();
    if pred.eqs.is_empty() {
        // No equality to hash on (literal-pinned ⋈const plumbing, cross
        // joins): replicate the smaller side.
        let side = if lb <= rb {
            JoinSide::Left
        } else {
            JoinSide::Right
        };
        return JoinPlan {
            strategy: JoinStrategy::Broadcast { side },
            card,
        };
    }
    let l_ok = left.is_hash_on(&pred.left_comps());
    let r_ok = right.is_hash_on(&pred.right_comps());
    // Heavy-hitter strategies are considered before the oblivious
    // choices: a side annotated `SkewHash` on its join components may
    // pay replicated hot bytes to flatten the hot worker's load.
    if let Some(strategy) = plan_join_skew(left, right, pred, net, workers, card, l_ok, r_ok) {
        return JoinPlan { strategy, card };
    }
    if l_ok && r_ok {
        return JoinPlan {
            strategy: JoinStrategy::Local,
            card,
        };
    }
    // Price the three physical options with the shared network model.
    let mut resh = 0.0;
    if !l_ok {
        resh += net.shuffle_time(lb, workers);
    }
    if !r_ok {
        resh += net.shuffle_time(rb, workers);
    }
    let mut bl = net.allgather_time(lb, workers);
    let mut br = net.allgather_time(rb, workers);
    // Broadcasting the unique side of a 1-n join leaves the fan-out side
    // (and its partitioning invariant) untouched: bias toward it.
    match card {
        JoinCard::ManyOne => br *= 0.75,
        JoinCard::OneMany => bl *= 0.75,
        _ => {}
    }
    let strategy = if resh <= bl && resh <= br {
        JoinStrategy::Reshuffle {
            left: !l_ok,
            right: !r_ok,
        }
    } else if bl <= br {
        JoinStrategy::Broadcast {
            side: JoinSide::Left,
        }
    } else {
        JoinStrategy::Broadcast {
            side: JoinSide::Right,
        }
    };
    JoinPlan { strategy, card }
}

/// Default salted fan-out width when [`ClusterConfig::skew_salts`] is 0
/// (auto): spread each hot key across up to four workers.
pub(crate) fn default_salts(w: usize) -> usize {
    w.min(4)
}

/// Consider the two skew strategies for a join where one side carries a
/// heavy-hitter annotation ([`Partitioning::SkewHash`]) on exactly its
/// join components. A strategy is returned only when the [`NetModel`]
/// prices the extra traffic (salted fan-out, replicated hot bytes)
/// below the [`NetModel::straggler_wait`] it removes — otherwise the
/// oblivious plan stands. Planning scans the stage inputs once to
/// classify per-home hot/cold bytes; that is the same order of work as
/// the exchange the oblivious plan would run.
#[allow(clippy::too_many_arguments)]
fn plan_join_skew(
    left: &PartitionedRelation,
    right: &PartitionedRelation,
    pred: &JoinPred,
    net: &NetModel,
    w: usize,
    card: JoinCard,
    l_ok: bool,
    r_ok: bool,
) -> Option<JoinStrategy> {
    for side in [JoinSide::Left, JoinSide::Right] {
        let (srel, orel, s_ok, o_ok) = match side {
            JoinSide::Left => (left, right, l_ok, r_ok),
            JoinSide::Right => (right, left, r_ok, l_ok),
        };
        let (scomps, ocomps) = match side {
            JoinSide::Left => (pred.left_comps(), pred.right_comps()),
            JoinSide::Right => (pred.right_comps(), pred.left_comps()),
        };
        // The annotation must sit on exactly the join components (which
        // `is_hash_on` certifies) — hotness of some other partition key
        // says nothing about join-key collisions.
        if !s_ok || scomps.is_empty() {
            continue;
        }
        let hot_keys = match srel.part.hot_keys() {
            Some(h) if !h.is_empty() => h,
            _ => continue,
        };
        let hot: crate::util::FxHashSet<Key> = hot_keys.iter().copied().collect();
        // Per-home total/hot bytes of the annotated (resident) side.
        let mut s_tot = vec![0u64; w];
        let mut s_hot = vec![0u64; w];
        for (h, shard) in srel.shards.iter().enumerate() {
            for (k, v) in shard.iter() {
                let b = shuffle::tuple_bytes(v);
                s_tot[h] += b;
                if hot.contains(&subkey(k, &scomps)) {
                    s_hot[h] += b;
                }
            }
        }
        if s_hot.iter().all(|&b| b == 0) {
            continue;
        }
        if o_ok {
            // Both sides co-partitioned: the oblivious baseline is
            // `Local`, whose cost is the straggler wait of the hot
            // home. Salting spreads each home's hot rows over `salts`
            // buckets and replicates the other side's hot rows to them.
            let salts = default_salts(w);
            let mut o_tot = vec![0u64; w];
            let mut o_hot = vec![0u64; w];
            for (h, shard) in orel.shards.iter().enumerate() {
                for (k, v) in shard.iter() {
                    let b = shuffle::tuple_bytes(v);
                    o_tot[h] += b;
                    if hot.contains(&subkey(k, &ocomps)) {
                        o_hot[h] += b;
                    }
                }
            }
            let base_max = (0..w).map(|h| s_tot[h] + o_tot[h]).max().unwrap_or(0);
            let total: u64 = s_tot.iter().sum::<u64>() + o_tot.iter().sum::<u64>();
            let base_wait = net.straggler_wait(base_max, total, w);
            let mut post: Vec<u64> = (0..w)
                .map(|h| (s_tot[h] - s_hot[h]) + (o_tot[h] - o_hot[h]))
                .collect();
            let mut moved = 0u64;
            for h in 0..w {
                for i in 0..salts {
                    post[(h + i) % w] += s_hot[h] / salts as u64 + o_hot[h];
                }
                // Salted fan-out: the 1/salts share at bucket 0 stays home.
                moved += s_hot[h] - s_hot[h] / salts as u64;
                // Hot replicas beyond the local copy.
                moved += o_hot[h] * (salts as u64 - 1);
            }
            let post_total: u64 = post.iter().sum();
            let post_wait =
                net.straggler_wait(post.iter().copied().max().unwrap_or(0), post_total, w);
            let msgs = (salts as u64 - 1) * w as u64;
            if net.alltoall_time(moved, msgs, w) + post_wait < base_wait {
                return Some(JoinStrategy::SkewSalt { side, salts });
            }
            continue;
        }
        // The other side is misplaced. Only emulate the oblivious plan
        // when it would be `Reshuffle` of that side alone (mirroring
        // `plan_join`'s arithmetic, tie rules included) — the broadcast
        // plans replicate a whole side and leave no hot home to fix.
        let lb = left.nbytes();
        let rb = right.nbytes();
        let resh = net.shuffle_time(orel.nbytes(), w);
        let mut bl = net.allgather_time(lb, w);
        let mut br = net.allgather_time(rb, w);
        match card {
            JoinCard::ManyOne => br *= 0.75,
            JoinCard::OneMany => bl *= 0.75,
            _ => {}
        }
        if !(resh <= bl && resh <= br) {
            continue;
        }
        // Classify the other side by its routed home: the baseline
        // routes everything; the skew plan routes only the cold tail,
        // pins hot rows at their source, and allgathers the annotated
        // side's hot rows to meet them.
        let mut o_route = vec![0u64; w];
        let mut o_cold = vec![0u64; w];
        let mut o_hot_src = vec![0u64; w];
        let mut o_cold_total = 0u64;
        for (src, shard) in orel.shards.iter().enumerate() {
            for (k, v) in shard.iter() {
                let b = shuffle::tuple_bytes(v);
                let home = shuffle::owner(k, &ocomps, w);
                o_route[home] += b;
                if hot.contains(&subkey(k, &ocomps)) {
                    o_hot_src[src] += b;
                } else {
                    o_cold[home] += b;
                    o_cold_total += b;
                }
            }
        }
        let s_hot_total: u64 = s_hot.iter().sum();
        let base_max = (0..w).map(|h| s_tot[h] + o_route[h]).max().unwrap_or(0);
        let total: u64 = s_tot.iter().sum::<u64>() + o_route.iter().sum::<u64>();
        let base_cost =
            net.shuffle_time(orel.nbytes(), w) + net.straggler_wait(base_max, total, w);
        let post: Vec<u64> = (0..w)
            .map(|h| s_tot[h] - s_hot[h] + s_hot_total + o_cold[h] + o_hot_src[h])
            .collect();
        let post_total: u64 = post.iter().sum();
        let skew_cost = net.shuffle_time(o_cold_total, w)
            + net.allgather_time(s_hot_total, w)
            + net.straggler_wait(post.iter().copied().max().unwrap_or(0), post_total, w);
        if skew_cost < base_cost {
            return Some(JoinStrategy::SkewBroadcast { side });
        }
    }
    None
}

// --------------------------------------------------------------- executor

struct Executor<'a> {
    cfg: &'a ClusterConfig,
    /// The caller's backend, used directly on every serial path (one
    /// worker, `parallel = false`, replicated run-once stages).
    backend: &'a dyn KernelBackend,
    /// The persistent worker pool every stage dispatches to — `None` on
    /// the serial reference path. The pool (and the one backend instance
    /// each of its threads owns) outlives this executor when the caller
    /// holds it across evaluations.
    pool: Option<&'a WorkerPool>,
    /// Spill scratch for over-budget join stages (`Some` iff the
    /// configuration is budgeted `Spill`): the pool's session-lifetime
    /// space, or a lazily-created per-evaluation one. `Arc` so stage
    /// closures shipped to worker threads can hold it.
    spill: Option<Arc<LazySpill>>,
    /// Deterministic fault injector (`Some` iff the configuration carries
    /// a [`FaultPlan`]). `Arc` so worker-job closures can probe it; its
    /// occurrence counters span the whole evaluation, so a replayed stage
    /// probes *new* occurrences and a once-spec fault does not refire.
    faults: Option<Arc<FaultInjector>>,
    stats: ExecStats,
    /// The physical plan of the most recent ⋈ stage, taken by the tracing
    /// node loop right after that stage completes.
    last_join: Option<JoinPlan>,
    /// Largest per-worker join-input load (build + probe bytes after
    /// movement) of the most recent ⋈ stage — the `StageTrace::
    /// max_shard_bytes` raw material, recorded for every join strategy
    /// and taken alongside `last_join`.
    last_join_load: Option<u64>,
    /// Factorized-plan exchange hints: Σ nodes whose two-phase exchange
    /// should hash on these group-key components (a subset that still
    /// co-locates every group) instead of the full group key. Empty on
    /// every non-factorized path.
    agg_exchange: &'a [(NodeId, Vec<usize>)],
    /// Reshuffle memo, `(source node, target components) → (moved
    /// relation, what moving it cost)` — the shuffle-elision cache
    /// (`ClusterConfig::elide_shuffles`). Entries are only installed for
    /// movements that actually carried bytes; a tape node is immutable
    /// once computed, so a hit returns exactly what re-moving would.
    resh_memo: FxHashMap<(NodeId, Vec<usize>), (PartitionedRelation, ShuffleStats)>,
    /// Broadcast memo, `source node → (replicated relation, bytes the
    /// allgather moved)`.
    bcast_memo: FxHashMap<NodeId, (PartitionedRelation, u64)>,
}

fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = std::time::Instant::now();
    let v = f();
    (v, t0.elapsed().as_secs_f64())
}

/// Spill scratch shared by an evaluation's worker jobs: the pool's
/// session-lifetime space when one exists, otherwise a per-evaluation
/// space created by the *first worker that actually spills* (so
/// within-budget runs never touch the scratch device, and an unwritable
/// spill root fails only queries that genuinely need it). The
/// per-evaluation space drops — removing its tree — with the executor.
struct LazySpill {
    /// Root hint from `ClusterConfig::spill_dir`.
    hint: Option<PathBuf>,
    /// The pool's already-created space, preferred when present.
    pool_space: Option<Arc<SpillSpace>>,
    /// Per-evaluation space, created on first use. The error is kept as
    /// a string because `io::Error` is not `Clone` and every spilling
    /// worker of the stage reports the same failure.
    own: OnceLock<Result<Arc<SpillSpace>, String>>,
}

impl LazySpill {
    fn space(&self) -> Result<Arc<SpillSpace>> {
        if let Some(s) = &self.pool_space {
            return Ok(Arc::clone(s));
        }
        match self.own.get_or_init(|| {
            SpillSpace::create(self.hint.as_deref())
                .map(Arc::new)
                .map_err(|e| e.to_string())
        }) {
            Ok(s) => Ok(Arc::clone(s)),
            Err(e) => Err(anyhow!("creating spill scratch space: {e}")),
        }
    }
}

/// Run one BSP stage: `f(worker_index, backend)` once per worker — as
/// pool jobs when a pool of matching width is running, serially on
/// `fallback` otherwise. Results come back in worker-index order either
/// way, so the two paths are bitwise interchangeable. Stage closures
/// capture `Arc` shard handles and cloned key functions (refcount bumps
/// and a few component indices), never tuple data.
///
/// Panic containment: a panicking worker job becomes `Err(JobFailure)`
/// in its slot instead of unwinding the driver, on both the pooled path
/// ([`WorkerPool::try_run`]) and the serial fallback (driver-side
/// `catch_unwind`) — the pool stays usable for the next stage either
/// way.
fn try_par_stage<T: Send + 'static>(
    pool: Option<&WorkerPool>,
    w: usize,
    fallback: &dyn KernelBackend,
    f: impl Fn(usize, &dyn KernelBackend) -> T + Send + Sync + 'static,
) -> Vec<Result<T, JobFailure>> {
    match pool {
        Some(p) if p.workers() == w => p.try_run(f),
        _ => (0..w)
            .map(|wi| catch_unwind(AssertUnwindSafe(|| f(wi, fallback))).map_err(classify_panic))
            .collect(),
    }
}

/// Lift a contained shard failure into the typed error the stage retry
/// loop consumes: an injected fault is a *transient* class (retried up
/// to `max_stage_retries`); a genuine panic is fatal — never retried,
/// surfaced as `StageFailed` with a [`StageFailure::FatalJob`] source.
/// Stage id and attempt count are placeholders here; the node loop
/// stamps the real coordinates.
fn job_failure_err(wi: usize, jf: JobFailure) -> DistError {
    match jf {
        JobFailure::Injected(f) => DistError::Transient {
            worker: f.worker,
            what: f.to_string(),
        },
        JobFailure::Fatal(msg) => DistError::StageFailed {
            stage: 0,
            worker: wi,
            attempts: 0,
            source: StageFailure::FatalJob(msg),
        },
    }
}

/// Probe one injection point on one worker, lifting a transient
/// injected error into [`DistError::Transient`]. A `PanicJob` spec fires
/// as a panic inside `probe` and is contained by the enclosing
/// `try_par_stage`/`try_run` instead. No-op (and zero probe-counter
/// traffic) when `faults` is `None`.
fn probe_fault(
    faults: Option<&FaultInjector>,
    point: InjectionPoint,
    wi: usize,
) -> Result<(), DistError> {
    if let Some(inj) = faults {
        inj.probe(point, wi).map_err(|f| DistError::Transient {
            worker: f.worker,
            what: f.to_string(),
        })?;
    }
    Ok(())
}

impl<'a> Executor<'a> {
    /// Pool for the communication steps (shuffle route/build, gather,
    /// Σ merge) — gated separately by `ClusterConfig::parallel_comm` so
    /// `bench_dist` can A/B the pooled all-to-all against the
    /// driver-serial exchange with stage compute threaded either way.
    fn comm_pool(&self) -> Option<&'a WorkerPool> {
        if self.cfg.parallel_comm {
            self.pool
        } else {
            None
        }
    }

    /// One fault-probe round at a driver-orchestrated communication
    /// point (`ShuffleSend`, `SigmaMerge`): every worker probes once, in
    /// shard jobs so a `PanicJob` spec unwinds a worker — not the driver
    /// — and is classified like any stage-body panic. Returns
    /// immediately (no probes, no branches taken) without a configured
    /// fault plan.
    fn probe_round(&self, point: InjectionPoint) -> Result<(), DistError> {
        let Some(inj) = &self.faults else {
            return Ok(());
        };
        let inj = Arc::clone(inj);
        let w = self.cfg.workers;
        let results = try_par_stage(self.comm_pool(), w, self.backend, move |wi, _| {
            inj.probe(point, wi)
        });
        for (wi, res) in results.into_iter().enumerate() {
            match res {
                Ok(probed) => probed.map_err(|f| DistError::Transient {
                    worker: f.worker,
                    what: f.to_string(),
                })?,
                Err(jf) => return Err(job_failure_err(wi, jf)),
            }
        }
        Ok(())
    }

    fn eval_node(
        &mut self,
        id: NodeId,
        node: &Node,
        rels: &[PartitionedRelation],
        inputs: &[PartitionedRelation],
    ) -> Result<PartitionedRelation, DistError> {
        let w = self.cfg.workers;
        match &node.op {
            // Handle copies: inputs and plan constants are never deep-
            // copied into the tape.
            Op::Scan { slot, .. } => Ok(inputs[*slot].clone()),
            Op::Const { rel, .. } => Ok(PartitionedRelation::replicate_handle(rel.clone(), w)),
            Op::Select { pred, proj, kernel } => {
                self.eval_select(pred, proj, kernel, &rels[node.children[0]])
            }
            Op::Join { pred, proj, kernel } => self.eval_join(
                pred,
                proj,
                kernel,
                (node.children[0], &rels[node.children[0]]),
                (node.children[1], &rels[node.children[1]]),
            ),
            Op::Agg { grp, agg } => self.eval_agg(id, grp, agg, &rels[node.children[0]]),
            Op::AddQ => self.eval_add(
                (node.children[0], &rels[node.children[0]]),
                (node.children[1], &rels[node.children[1]]),
            ),
        }
    }

    /// Produce node `id` of a delta run without a full stage execution,
    /// per the step [`delta::plan_node`] chose. Every path first probes
    /// [`InjectionPoint::DeltaApply`] (one round, all workers) and is a
    /// pure function of the previous tape and the already-computed child
    /// outputs, so the surrounding stage retry loop replays it after a
    /// transient fault exactly like a computed stage.
    fn eval_node_delta(
        &mut self,
        id: NodeId,
        node: &Node,
        rels: &[PartitionedRelation],
        step: DeltaStep,
        d: &DeltaCtx,
    ) -> Result<PartitionedRelation, DistError> {
        let w = self.cfg.workers;
        self.probe_round(InjectionPoint::DeltaApply)?;
        match (step, &node.op) {
            (DeltaStep::Reuse, _) => {
                self.stats.shards_reused += w as u64;
                Ok(d.prev.rels[id].clone())
            }
            (DeltaStep::SelectAppend, Op::Select { pred, proj, kernel }) => {
                let c = node.children[0];
                let input = rels[c].shards.clone();
                let prev_in = d.prev.rels[c].shards.clone();
                let prev_out = d.prev.rels[id].shards.clone();
                let (pred_c, proj_c, kernel_c) = (pred.clone(), proj.clone(), *kernel);
                let results = try_par_stage(self.pool, w, self.backend, move |wi, be| {
                    time(|| {
                        delta::select_append_shard(
                            &prev_out[wi],
                            &input[wi],
                            prev_in[wi].len(),
                            &pred_c,
                            &proj_c,
                            &kernel_c,
                            be,
                        )
                    })
                });
                let mut shards = Vec::with_capacity(w);
                let mut maxt = 0.0f64;
                for (wi, res) in results.into_iter().enumerate() {
                    let (out, t) = res.map_err(|jf| job_failure_err(wi, jf))?;
                    shards.push(out.map_err(DistError::Other)?);
                    maxt = maxt.max(t);
                }
                self.stats.compute_s += maxt;
                self.stats.shards_reused += w as u64;
                // Same invariant derivation as `eval_select`; the planner
                // only admitted the append when a fresh σ would not have
                // needed the cross-shard disjointness check.
                let part = match rels[c].part.hash_comps() {
                    Some(comps) => match preserved_positions(comps, proj) {
                        Some(pos) => Partitioning::Hash(pos),
                        None => Partitioning::Arbitrary,
                    },
                    None => Partitioning::Arbitrary,
                };
                Ok(PartitionedRelation::from_shards(shards, part))
            }
            (DeltaStep::JoinAppend { appended_left }, Op::Join { pred, proj, kernel }) => {
                let (l, r) = (node.children[0], node.children[1]);
                // The planner required a co-partitioned Local join; record
                // the plan so the trace renders the strategy like a fresh
                // stage would.
                self.last_join = Some(plan_join(&rels[l], &rels[r], pred, &self.cfg.net, w));
                let (a, c) = if appended_left { (l, r) } else { (r, l) };
                let appended = rels[a].shards.clone();
                let clean = rels[c].shards.clone();
                let prev_in = d.prev.rels[a].shards.clone();
                let prev_out = d.prev.rels[id].shards.clone();
                let (pred_c, proj_c, kernel_c) = (pred.clone(), proj.clone(), *kernel);
                let results = try_par_stage(self.pool, w, self.backend, move |wi, be| {
                    time(|| {
                        delta::join_append_shard(
                            &prev_out[wi],
                            &clean[wi],
                            &appended[wi],
                            prev_in[wi].len(),
                            appended_left,
                            &pred_c,
                            &proj_c,
                            &kernel_c,
                            be,
                        )
                    })
                });
                let mut shards = Vec::with_capacity(w);
                let mut maxt = 0.0f64;
                for (wi, res) in results.into_iter().enumerate() {
                    let (out, t) = res.map_err(|jf| job_failure_err(wi, jf))?;
                    shards.push(out.map_err(DistError::Other)?);
                    maxt = maxt.max(t);
                }
                self.stats.compute_s += maxt;
                self.stats.shards_reused += w as u64;
                let part = join_output_part(&rels[l].part, &rels[r].part, proj);
                Ok(PartitionedRelation::from_shards(shards, part))
            }
            (DeltaStep::AggFold, Op::Agg { grp, agg }) => {
                let c = node.children[0];
                let input = rels[c].shards.clone();
                let prev_in = d.prev.rels[c].shards.clone();
                let prev_out = d.prev.rels[id].shards.clone();
                let (grp_c, agg_c) = (grp.clone(), *agg);
                let results = try_par_stage(self.pool, w, self.backend, move |wi, _| {
                    time(|| {
                        delta::agg_fold_shard(
                            &prev_out[wi],
                            &input[wi],
                            prev_in[wi].len(),
                            &grp_c,
                            &agg_c,
                        )
                    })
                });
                let mut shards = Vec::with_capacity(w);
                let mut maxt = 0.0f64;
                for (wi, res) in results.into_iter().enumerate() {
                    let (out, t) = res.map_err(|jf| job_failure_err(wi, jf))?;
                    shards.push(out);
                    maxt = maxt.max(t);
                }
                self.stats.compute_s += maxt;
                self.stats.shards_reused += w as u64;
                // The planner admitted the fold only on the no-exchange
                // fast path, whose fresh output keeps Hash placement on
                // the preserved group-key positions.
                let part = match rels[c].part.hash_comps() {
                    Some(comps) => match preserved_positions(comps, grp) {
                        Some(pos) => Partitioning::Hash(pos),
                        None => Partitioning::Arbitrary,
                    },
                    None => Partitioning::Arbitrary,
                };
                Ok(PartitionedRelation::from_shards(shards, part))
            }
            _ => Err(DistError::Other(anyhow!(
                "delta step {step:?} does not apply to node v{id} ({})",
                node.op.kind()
            ))),
        }
    }

    fn eval_select(
        &mut self,
        pred: &KeyPred,
        proj: &KeyProj,
        kernel: &UnaryKernel,
        input: &PartitionedRelation,
    ) -> Result<PartitionedRelation, DistError> {
        let w = self.cfg.workers;
        if input.is_replicated() {
            // Identical work everywhere: run once, charge once.
            let b0 = self.backend;
            let (out, t) = time(|| apply_select(&input.shards[0], pred, proj, kernel, b0));
            let out = out.map_err(DistError::Other)?;
            self.stats.compute_s += t;
            return Ok(PartitionedRelation::replicate_handle(Arc::new(out), w));
        }
        let in_shards = input.shards.clone();
        let (pred_c, proj_c, kernel_c) = (pred.clone(), proj.clone(), *kernel);
        let results = try_par_stage(self.pool, w, self.backend, move |wi, be| {
            time(|| apply_select(&in_shards[wi], &pred_c, &proj_c, &kernel_c, be))
        });
        let mut shards = Vec::with_capacity(w);
        let mut maxt = 0.0f64;
        for (wi, res) in results.into_iter().enumerate() {
            let (out, t) = res.map_err(|jf| job_failure_err(wi, jf))?;
            shards.push(out.map_err(DistError::Other)?);
            maxt = maxt.max(t);
        }
        self.stats.compute_s += maxt;
        // The invariant survives iff every partitioning component is
        // carried through the projection. (`hash_comps` lets a `SkewHash`
        // input behave exactly like its `Hash` core — the σ output
        // degrades to plain `Hash`, dropping the hot-key annotation,
        // which keeps skewed and oblivious sessions planning every
        // downstream stage identically.)
        let part = match input.part.hash_comps() {
            Some(c) => match preserved_positions(c, proj) {
                Some(pos) => Partitioning::Hash(pos),
                None => Partitioning::Arbitrary,
            },
            None => Partitioning::Arbitrary,
        };
        // A statically non-injective projection can collide *across*
        // workers, which the per-shard checks cannot see — verify, so the
        // distributed run errors exactly where single-node does.
        if matches!(part, Partitioning::Arbitrary) && !proj.is_injective(input.key_arity()) {
            check_disjoint(&shards, format_args!("σ projection {proj}"))
                .map_err(DistError::Other)?;
        }
        Ok(PartitionedRelation::from_shards(shards, part))
    }

    fn eval_join(
        &mut self,
        pred: &JoinPred,
        proj: &KeyProj2,
        kernel: &BinaryKernel,
        (l_id, left): (NodeId, &PartitionedRelation),
        (r_id, right): (NodeId, &PartitionedRelation),
    ) -> Result<PartitionedRelation, DistError> {
        let w = self.cfg.workers;
        if left.is_replicated() && right.is_replicated() {
            // Run-once path executes on the driver thread: contain a
            // `PanicJob` injection (or a genuine shard panic) here, like
            // the pool does for sharded stages.
            let shard = catch_unwind(AssertUnwindSafe(|| {
                join_worker_shard(
                    self.cfg.budget,
                    self.cfg.policy,
                    self.spill.as_deref(),
                    self.faults.as_deref(),
                    0,
                    &left.shards[0],
                    &right.shards[0],
                    pred,
                    proj,
                    kernel,
                    self.backend,
                )
            }))
            .map_err(|p| job_failure_err(0, classify_panic(p)))??;
            self.stats.compute_s += shard.compute_s;
            self.stats.spill_s += shard.spill_s;
            self.stats.spill_passes += shard.spill_events;
            self.stats.spill_bytes_written += shard.spill_written;
            self.stats.spill_bytes_read += shard.spill_read;
            return Ok(PartitionedRelation::replicate_handle(
                Arc::new(shard.out),
                w,
            ));
        }
        let mut plan = plan_join(left, right, pred, &self.cfg.net, w);
        if let JoinStrategy::SkewSalt { side, .. } = plan.strategy {
            // `skew_salts = 0` means auto (the planner's default fan-out);
            // a nonzero configuration overrides it, clamped to the worker
            // count. Every salt count routes the same tuples to a bitwise
            // merge — it changes how far a hot key fans out, never the
            // output.
            if self.cfg.skew_salts > 0 {
                plan.strategy = JoinStrategy::SkewSalt {
                    side,
                    salts: self.cfg.skew_salts.min(w),
                };
            }
        }
        self.last_join = Some(plan);
        if matches!(
            plan.strategy,
            JoinStrategy::SkewSalt { .. } | JoinStrategy::SkewBroadcast { .. }
        ) {
            return self.eval_join_skew(pred, proj, kernel, left, right, plan.strategy);
        }
        let (lv, rv): (Cow<PartitionedRelation>, Cow<PartitionedRelation>) = match plan.strategy {
            JoinStrategy::Local => (Cow::Borrowed(left), Cow::Borrowed(right)),
            JoinStrategy::Reshuffle {
                left: move_l,
                right: move_r,
            } => {
                let lv = if move_l {
                    Cow::Owned(self.reshuffle_memo(l_id, left, &pred.left_comps())?)
                } else {
                    Cow::Borrowed(left)
                };
                let rv = if move_r {
                    Cow::Owned(self.reshuffle_memo(r_id, right, &pred.right_comps())?)
                } else {
                    Cow::Borrowed(right)
                };
                (lv, rv)
            }
            JoinStrategy::Broadcast {
                side: JoinSide::Left,
            } => (
                Cow::Owned(self.broadcast_memo(l_id, left)?),
                Cow::Borrowed(right),
            ),
            JoinStrategy::Broadcast {
                side: JoinSide::Right,
            } => (
                Cow::Borrowed(left),
                Cow::Owned(self.broadcast_memo(r_id, right)?),
            ),
            JoinStrategy::SkewSalt { .. } | JoinStrategy::SkewBroadcast { .. } => {
                unreachable!("skew strategies dispatch to eval_join_skew above")
            }
        };
        // The per-worker join-input load after movement — what a skew
        // strategy would flatten; recorded for every join so traces can
        // compare the two.
        self.last_join_load = Some(
            (0..w)
                .map(|wi| (lv.shards[wi].nbytes() + rv.shards[wi].nbytes()) as u64)
                .max()
                .unwrap_or(0),
        );
        // Fail-fast OOM: under `MemPolicy::Fail` check every worker's
        // budget *before* any join compute runs, so an over-budget stage
        // errors immediately (and on the lowest worker index) instead of
        // after the within-budget workers finished their joins.
        if let Some(budget) = self.cfg.budget {
            if self.cfg.policy == MemPolicy::Fail {
                for wi in 0..w {
                    let needed = join_needed_bytes(&lv.shards[wi], &rv.shards[wi], pred, kernel);
                    if needed > budget {
                        return Err(DistError::Oom {
                            worker: wi,
                            needed,
                            budget,
                        });
                    }
                }
            }
        }
        let (lsh, rsh) = (lv.shards.clone(), rv.shards.clone());
        let (pred_c, proj_c, kernel_c) = (pred.clone(), proj.clone(), *kernel);
        let (budget, policy) = (self.cfg.budget, self.cfg.policy);
        let spill_c = self.spill.clone();
        let faults_c = self.faults.clone();
        let results = try_par_stage(self.pool, w, self.backend, move |wi, be| {
            join_worker_shard(
                budget,
                policy,
                spill_c.as_deref(),
                faults_c.as_deref(),
                wi,
                &lsh[wi],
                &rsh[wi],
                &pred_c,
                &proj_c,
                &kernel_c,
                be,
            )
        });
        let mut shards = Vec::with_capacity(w);
        let mut maxt = 0.0f64;
        let mut max_spill = 0.0f64;
        for (wi, res) in results.into_iter().enumerate() {
            let shard = res.map_err(|jf| job_failure_err(wi, jf))??;
            maxt = maxt.max(shard.compute_s);
            max_spill = max_spill.max(shard.spill_s);
            self.stats.spill_passes += shard.spill_events;
            self.stats.spill_bytes_written += shard.spill_written;
            self.stats.spill_bytes_read += shard.spill_read;
            shards.push(shard.out);
        }
        self.stats.compute_s += maxt;
        self.stats.spill_s += max_spill;
        let part = join_output_part(&lv.part, &rv.part, proj);
        // No surviving hash invariant ⇒ equal output keys could land on
        // different workers; verify disjointness so the distributed run
        // errors exactly where single-node does instead of corrupting a
        // later gather.
        if matches!(part, Partitioning::Arbitrary) {
            check_disjoint(&shards, format_args!("⋈ projection {proj}"))
                .map_err(DistError::Other)?;
        }
        Ok(PartitionedRelation::from_shards(shards, part))
    }

    /// Execute a ⋈ stage under a skew strategy, reproducing the
    /// oblivious plan's per-shard output **bitwise**.
    ///
    /// Hotness is a property of the projected join-subkey *value*, so it
    /// translates across sides: a probe row's match set is entirely hot
    /// or entirely cold, and the join decomposes disjointly into
    /// cold×cold at each key's home worker plus hot×hot at the workers
    /// the skew routing chose. Every row is tagged with its *oblivious*
    /// coordinates — the shard index and scan position it would occupy
    /// under the strategy being emulated ([`JoinStrategy::Local`] for
    /// `SkewSalt`; reshuffle-the-other-side for `SkewBroadcast`, whose
    /// routed positions [`shuffle::routed_positions`] reproduces without
    /// moving the data). Workers join whatever material the skew routing
    /// assigned them, emitting `(home, left pos, right pos, key, value)`
    /// tuples; the driver then sorts each home's matches into
    /// `hash_join`'s probe-major emission order (probe side chosen per
    /// home from the oblivious row counts, ties building right like
    /// [`build_probe_split`]) and inserts them in that order. Per-shard
    /// outputs — and therefore downstream Σ float merges, gradients, and
    /// whole training loops — are bitwise identical to the oblivious
    /// plan's. Per-tuple kernels are pure, so the altered evaluation
    /// order cannot change values, only the (re-imposed) order.
    fn eval_join_skew(
        &mut self,
        pred: &JoinPred,
        proj: &KeyProj2,
        kernel: &BinaryKernel,
        left: &PartitionedRelation,
        right: &PartitionedRelation,
        strategy: JoinStrategy,
    ) -> Result<PartitionedRelation, DistError> {
        let w = self.cfg.workers;
        let (skew_left, salts, broadcast_mode) = match strategy {
            JoinStrategy::SkewSalt { side, salts } => {
                (side == JoinSide::Left, salts.clamp(1, w), false)
            }
            JoinStrategy::SkewBroadcast { side } => (side == JoinSide::Left, w, true),
            _ => unreachable!("eval_join_skew requires a skew strategy"),
        };
        let lcomps = pred.left_comps();
        let rcomps = pred.right_comps();
        let (scomps, ocomps) = if skew_left {
            (&lcomps, &rcomps)
        } else {
            (&rcomps, &lcomps)
        };
        let (srel, orel) = if skew_left {
            (left, right)
        } else {
            (right, left)
        };
        let hot: crate::util::FxHashSet<Key> = srel
            .part
            .hot_keys()
            .unwrap_or(&[])
            .iter()
            .copied()
            .collect();
        // Movement is about to start: probe `ShuffleSend` first, like
        // the exchange this routing replaces — a faulted stage charges
        // nothing and replays from the immutable inputs.
        self.probe_round(InjectionPoint::ShuffleSend)?;
        // Oblivious per-home row counts fix which side `hash_join`
        // would build on each home shard. Under `SkewBroadcast` the
        // other side's oblivious coordinates are its exchange deposit
        // positions, computed without moving anything.
        let o_tags = broadcast_mode.then(|| shuffle::routed_positions(&orel.shards, ocomps, w));
        let o_counts: Vec<u32> = match &o_tags {
            Some((_, counts)) => counts.clone(),
            None => orel.shards.iter().map(|s| s.len() as u32).collect(),
        };
        let s_counts: Vec<u32> = srel.shards.iter().map(|s| s.len() as u32).collect();
        let build_right: Vec<bool> = (0..w)
            .map(|h| {
                let (lc, rc) = if skew_left {
                    (s_counts[h], o_counts[h])
                } else {
                    (o_counts[h], s_counts[h])
                };
                rc <= lc
            })
            .collect();
        // Tag and route every row. Material per assigned worker:
        // `(key, value, home, pos)` with the oblivious coordinates the
        // merge sorts back into emission order.
        let mut s_mat: Vec<Vec<(Key, Chunk, u32, u32)>> = (0..w).map(|_| Vec::new()).collect();
        let mut o_mat: Vec<Vec<(Key, Chunk, u32, u32)>> = (0..w).map(|_| Vec::new()).collect();
        let mut moved = 0u64;
        let mut links = vec![false; w * w];
        let mut rows_salted = 0u64;
        let mut bytes_hot_repl = 0u64;
        // Salted fan-out is deterministic: a hot row's bucket follows
        // from its home shard and its per-key arrival rank in catalog
        // scan order, so a retried stage replays the identical routing.
        let mut salt_rank: FxHashMap<Key, u32> = FxHashMap::default();
        for (h, shard) in srel.shards.iter().enumerate() {
            for (pos, (k, v)) in shard.iter().enumerate() {
                let tag = (*k, v.clone(), h as u32, pos as u32);
                if !hot.contains(&subkey(k, scomps)) {
                    s_mat[h].push(tag);
                } else if broadcast_mode {
                    // Hot build rows replicate to every worker.
                    let b = shuffle::tuple_bytes(v);
                    bytes_hot_repl += b * (w as u64 - 1);
                    for (a, mat) in s_mat.iter_mut().enumerate() {
                        if a != h {
                            moved += b;
                            links[h * w + a] = true;
                        }
                        mat.push(tag.clone());
                    }
                } else {
                    // Hot probe rows fan out round-robin over the salted
                    // buckets anchored at their home.
                    let rank = salt_rank.entry(subkey(k, scomps)).or_insert(0);
                    let a = (h + (*rank as usize % salts)) % w;
                    *rank += 1;
                    rows_salted += 1;
                    if a != h {
                        moved += shuffle::tuple_bytes(v);
                        links[h * w + a] = true;
                    }
                    s_mat[a].push(tag);
                }
            }
        }
        for (src, shard) in orel.shards.iter().enumerate() {
            for (pos, (k, v)) in shard.iter().enumerate() {
                let is_hot = hot.contains(&subkey(k, ocomps));
                if broadcast_mode {
                    let (home, rpos) = o_tags.as_ref().expect("broadcast tags").0[src][pos];
                    let tag = (*k, v.clone(), home, rpos);
                    if is_hot {
                        // Hot probe rows stay at their source and join
                        // the replicated build rows there.
                        rows_salted += 1;
                        o_mat[src].push(tag);
                    } else {
                        let a = home as usize;
                        if a != src {
                            moved += shuffle::tuple_bytes(v);
                            links[src * w + a] = true;
                        }
                        o_mat[a].push(tag);
                    }
                } else {
                    let tag = (*k, v.clone(), src as u32, pos as u32);
                    if is_hot {
                        // Hot build rows replicate to the salted buckets
                        // their key's probe rows fan out across.
                        let b = shuffle::tuple_bytes(v);
                        bytes_hot_repl += b * (salts as u64 - 1);
                        for i in 0..salts {
                            let a = (src + i) % w;
                            if a != src {
                                moved += b;
                                links[src * w + a] = true;
                            }
                            o_mat[a].push(tag.clone());
                        }
                    } else {
                        o_mat[src].push(tag);
                    }
                }
            }
        }
        let msgs = links.iter().filter(|&&l| l).count() as u64;
        self.stats.bytes_shuffled += moved;
        self.stats.msgs += msgs;
        self.stats.net_s += self.cfg.net.alltoall_time(moved, msgs, w);
        self.stats.rows_salted += rows_salted;
        self.stats.bytes_hot_replicated += bytes_hot_repl;
        let (l_mat, r_mat) = if skew_left {
            (s_mat, o_mat)
        } else {
            (o_mat, s_mat)
        };
        let mat_bytes = |m: &[(Key, Chunk, u32, u32)]| {
            m.iter()
                .map(|(_, v, _, _)| shuffle::tuple_bytes(v))
                .sum::<u64>()
        };
        self.last_join_load = Some(
            (0..w)
                .map(|a| mat_bytes(&l_mat[a]) + mat_bytes(&r_mat[a]))
                .max()
                .unwrap_or(0),
        );
        // Fail-fast OOM: like the oblivious stage, check every worker
        // before any join compute runs. The skew working set is the
        // assigned material itself (the routing is already paid).
        if let Some(budget) = self.cfg.budget {
            if self.cfg.policy == MemPolicy::Fail {
                for a in 0..w {
                    let needed = mat_bytes(&l_mat[a]) + mat_bytes(&r_mat[a]);
                    if needed > budget {
                        return Err(DistError::Oom {
                            worker: a,
                            needed,
                            budget,
                        });
                    }
                }
            }
        }
        let l_mat = Arc::new(l_mat);
        let r_mat = Arc::new(r_mat);
        let (pred_c, proj_c, kernel_c) = (pred.clone(), proj.clone(), *kernel);
        let (budget, policy) = (self.cfg.budget, self.cfg.policy);
        let spill_c = self.spill.clone();
        let faults_c = self.faults.clone();
        let (lm, rm) = (Arc::clone(&l_mat), Arc::clone(&r_mat));
        let results = try_par_stage(self.pool, w, self.backend, move |wi, be| {
            skew_join_worker(
                budget,
                policy,
                spill_c.as_deref(),
                faults_c.as_deref(),
                wi,
                &lm[wi],
                &rm[wi],
                &pred_c,
                &proj_c,
                &kernel_c,
                be,
            )
        });
        let mut maxt = 0.0f64;
        let mut max_spill = 0.0f64;
        let mut per_home: Vec<Vec<(u32, u32, Key, Chunk)>> = (0..w).map(|_| Vec::new()).collect();
        for (wi, res) in results.into_iter().enumerate() {
            let shard = res.map_err(|jf| job_failure_err(wi, jf))??;
            maxt = maxt.max(shard.compute_s);
            max_spill = max_spill.max(shard.spill_s);
            self.stats.spill_passes += shard.spill_events;
            self.stats.spill_bytes_written += shard.spill_written;
            self.stats.spill_bytes_read += shard.spill_read;
            for (home, lpos, rpos, k, v) in shard.matches {
                per_home[home as usize].push((lpos, rpos, k, v));
            }
        }
        self.stats.compute_s += maxt;
        self.stats.spill_s += max_spill;
        // Merge: re-impose `hash_join`'s emission order per home shard —
        // probe-major with matches in build order, i.e. ascending
        // (probe pos, build pos) — then insert with the same injectivity
        // check. On the cluster each home merges its own matches, so
        // charge the slowest home.
        let mut shards = Vec::with_capacity(w);
        let mut merge_max = 0.0f64;
        for (h, mut matches) in per_home.into_iter().enumerate() {
            let (res, t) = time(|| -> Result<Relation> {
                if build_right[h] {
                    matches.sort_unstable_by_key(|&(lpos, rpos, ..)| (lpos, rpos));
                } else {
                    matches.sort_unstable_by_key(|&(lpos, rpos, ..)| (rpos, lpos));
                }
                let mut out = Relation::with_capacity(matches.len());
                for (_, _, k, v) in matches {
                    if out.contains(&k) {
                        bail!(
                            "⋈ projection {proj} is not injective on matches: key {k} collides (add a Σ to aggregate)"
                        );
                    }
                    out.insert(k, v);
                }
                Ok(out)
            });
            merge_max = merge_max.max(t);
            shards.push(res.map_err(DistError::Other)?);
        }
        self.stats.compute_s += merge_max;
        // Output partitioning of the *emulated oblivious* plan: the
        // at-rest parts for the `Local` baseline; the other side lands
        // hash-placed on its join components for the reshuffle baseline.
        // `join_output_part` degrades `SkewHash` to its `Hash` core, so
        // the output part — and all downstream planning — matches the
        // oblivious session exactly.
        let part = if broadcast_mode {
            let routed = Partitioning::Hash(ocomps.clone());
            if skew_left {
                join_output_part(&left.part, &routed, proj)
            } else {
                join_output_part(&routed, &right.part, proj)
            }
        } else {
            join_output_part(&left.part, &right.part, proj)
        };
        if matches!(part, Partitioning::Arbitrary) {
            check_disjoint(&shards, format_args!("⋈ projection {proj}"))
                .map_err(DistError::Other)?;
        }
        Ok(PartitionedRelation::from_shards(shards, part))
    }

    fn eval_agg(
        &mut self,
        id: NodeId,
        grp: &KeyProj,
        agg: &AggKernel,
        input: &PartitionedRelation,
    ) -> Result<PartitionedRelation, DistError> {
        let w = self.cfg.workers;
        if input.is_replicated() {
            let (out, t) = time(|| aggregate(&input.shards[0], grp, agg));
            self.stats.compute_s += t;
            return Ok(PartitionedRelation::replicate_handle(Arc::new(out), w));
        }
        // Local phase (always runs): per-worker pre-aggregation.
        let in_shards = input.shards.clone();
        let (grp_c, agg_c) = (grp.clone(), *agg);
        let results = try_par_stage(self.pool, w, self.backend, move |wi, _| {
            time(|| aggregate(&in_shards[wi], &grp_c, &agg_c))
        });
        let mut pre = Vec::with_capacity(w);
        let mut maxt = 0.0f64;
        for (wi, res) in results.into_iter().enumerate() {
            let (out, t) = res.map_err(|jf| job_failure_err(wi, jf))?;
            maxt = maxt.max(t);
            pre.push(out);
        }
        self.stats.compute_s += maxt;
        // If the partition hash is a function of the group key, every
        // group is already worker-local and the pre-aggregation is final
        // (`hash_comps`: a `SkewHash` input qualifies like its `Hash`
        // core, so skewed and oblivious sessions take the same path).
        if let Some(c) = input.part.hash_comps() {
            if let Some(pos) = preserved_positions(c, grp) {
                return Ok(PartitionedRelation::from_shards(pre, Partitioning::Hash(pos)));
            }
        }
        // Exchange partials by group-key hash and merge — the final merge
        // of the two-phase Σ. Both arms charge a *measured* estimate of
        // the per-worker exchange share to compute_s, but they estimate
        // it differently (per-phase max-over-workers vs total/w), so the
        // modeled clock of the two execution modes agrees approximately;
        // the exact-counter stats (bytes, msgs) and the results are
        // identical.
        //
        // A factorized plan may override the exchange key with a subset
        // of group-key components (the join-predicate positions): every
        // tuple of a group shares the full group key, hence the subset,
        // so the exchange still co-locates each group whole and the
        // destination merges the same partials in the same worker order
        // — per-key bitwise-identical output, but landed co-partitioned
        // for the join above (its one shuffle serves both stages).
        let out_comps: Vec<usize> = match self
            .agg_exchange
            .iter()
            .find(|(n, _)| *n == id)
            .filter(|(_, c)| c.iter().all(|&p| p < grp.out_arity()))
        {
            Some((_, comps)) => comps.clone(),
            None => (0..grp.out_arity()).collect(),
        };
        // The Σ merge exchange is about to run: every participating
        // worker probes `SigmaMerge` once (no-op without a fault plan).
        self.probe_round(InjectionPoint::SigmaMerge)?;
        let agg2 = *agg;
        let shards = match self.comm_pool() {
            Some(p) if p.workers() == w && pre.len() == w => {
                // Pooled: route and merge each run as a barriered phase,
                // so charge the slowest worker of each (the BSP model).
                let (shards, st, timing) = shuffle::exchange_merge_pooled(
                    pre,
                    &out_comps,
                    w,
                    move |acc, x| agg2.combine(acc, x),
                    p,
                );
                self.account_shuffle(st);
                self.stats.compute_s += timing.route_s + timing.build_s;
                shards
            }
            _ => {
                // Serial reference: the merge runs on the driver over every
                // worker's partials; on the cluster the destinations merge
                // their shares in parallel, so charge the per-worker share.
                let ((shards, st), t) = time(|| {
                    shuffle::exchange_merge(&pre, &out_comps, w, |acc, x| agg2.combine(acc, x))
                });
                self.account_shuffle(st);
                self.stats.compute_s += t / w as f64;
                shards
            }
        };
        Ok(PartitionedRelation::from_shards(
            shards,
            Partitioning::Hash(out_comps),
        ))
    }

    fn eval_add(
        &mut self,
        (l_id, left): (NodeId, &PartitionedRelation),
        (r_id, right): (NodeId, &PartitionedRelation),
    ) -> Result<PartitionedRelation, DistError> {
        let w = self.cfg.workers;
        if left.is_replicated() && right.is_replicated() {
            let (out, t) = time(|| add_relations(&left.shards[0], &right.shards[0]));
            self.stats.compute_s += t;
            return Ok(PartitionedRelation::replicate_handle(Arc::new(out), w));
        }
        // Identical hash layouts add worker-local; anything else re-homes
        // both sides by the full key. (`part.clone()` copies a few
        // component indices, never tuple data; shard clones are handle
        // bumps.)
        let aligned = matches!(
            (left.part.hash_comps(), right.part.hash_comps()),
            (Some(a), Some(b)) if a == b
        );
        let (lsh, rsh, part): (Vec<Arc<Relation>>, Vec<Arc<Relation>>, Partitioning) =
            if aligned {
                // Output part degrades to the plain `Hash` core: adding
                // rows changes key frequencies, so a `SkewHash` input's
                // hot-key annotation is not carried through.
                let comps = left.part.hash_comps().expect("aligned implies hash").to_vec();
                (
                    left.shards.clone(),
                    right.shards.clone(),
                    Partitioning::Hash(comps),
                )
            } else {
                let arity = left.key_arity().max(right.key_arity());
                let comps: Vec<usize> = (0..arity).collect();
                let lp = self.reshuffle_memo(l_id, left, &comps)?;
                let rp = self.reshuffle_memo(r_id, right, &comps)?;
                (lp.shards, rp.shards, Partitioning::Hash(comps))
            };
        let results = try_par_stage(self.pool, w, self.backend, move |wi, _| {
            time(|| add_relations(&lsh[wi], &rsh[wi]))
        });
        let mut shards = Vec::with_capacity(w);
        let mut maxt = 0.0f64;
        for (wi, res) in results.into_iter().enumerate() {
            let (out, t) = res.map_err(|jf| job_failure_err(wi, jf))?;
            maxt = maxt.max(t);
            shards.push(out);
        }
        self.stats.compute_s += maxt;
        Ok(PartitionedRelation::from_shards(shards, part))
    }

    /// Re-home `pr` (the relation of tape node `src`) by the hash of
    /// `comps`, serving repeats from the elision memo: a tape node is
    /// immutable once computed and `shuffle::owner` is a pure function
    /// of (key, comps, w), so re-moving the same node the same way
    /// rebuilds byte-for-byte what the memo already holds. A hit skips
    /// the movement and its network charge, counting the saved bytes in
    /// `shuffles_elided`/`bytes_shuffle_elided` instead.
    fn reshuffle_memo(
        &mut self,
        src: NodeId,
        pr: &PartitionedRelation,
        comps: &[usize],
    ) -> Result<PartitionedRelation, DistError> {
        let w = self.cfg.workers;
        if self.cfg.elide_shuffles {
            if let Some((p, st)) = self.resh_memo.get(&(src, comps.to_vec())) {
                self.stats.shuffles_elided += 1;
                self.stats.bytes_shuffle_elided += st.bytes;
                return Ok(p.clone());
            }
        }
        // Only an actual movement probes `ShuffleSend` — a memo hit
        // crosses no fabric. A faulted exchange fails *before* any
        // traffic is accounted or any memo entry installed, so a stage
        // replay re-runs the movement from the immutable source shards.
        self.probe_round(InjectionPoint::ShuffleSend)?;
        let (p, st) = pr.reshuffle_in(comps, w, self.comm_pool());
        self.account_shuffle(st);
        // Only movements that carried traffic are worth remembering — a
        // no-op reshuffle (already hash-placed) is cheaper to recompute
        // than to cache, and caching it would inflate the elision
        // counters with zero-byte "savings".
        if self.cfg.elide_shuffles && (st.bytes > 0 || st.msgs > 0) {
            self.resh_memo
                .insert((src, comps.to_vec()), (p.clone(), st));
        }
        Ok(p)
    }

    /// As [`Self::reshuffle_memo`], for allgather broadcasts.
    fn broadcast_memo(
        &mut self,
        src: NodeId,
        pr: &PartitionedRelation,
    ) -> Result<PartitionedRelation, DistError> {
        if pr.is_replicated() {
            return Ok(pr.clone());
        }
        if self.cfg.elide_shuffles {
            if let Some((p, bytes)) = self.bcast_memo.get(&src) {
                self.stats.shuffles_elided += 1;
                self.stats.bytes_shuffle_elided += *bytes;
                return Ok(p.clone());
            }
        }
        let before = self.stats.bytes_shuffled;
        let p = self.broadcast(pr)?;
        let moved = self.stats.bytes_shuffled - before;
        if self.cfg.elide_shuffles && moved > 0 {
            self.bcast_memo.insert(src, (p.clone(), moved));
        }
        Ok(p)
    }

    /// Allgather a partitioned relation onto every worker.
    fn broadcast(&mut self, pr: &PartitionedRelation) -> Result<PartitionedRelation, DistError> {
        if pr.is_replicated() {
            return Ok(pr.clone());
        }
        // Like the reshuffle: probe before the allgather moves anything,
        // so a faulted broadcast charges nothing and leaves no memo.
        self.probe_round(InjectionPoint::ShuffleSend)?;
        let w = self.cfg.workers;
        let full = pr.gather_in(self.comm_pool());
        let bytes = full.nbytes() as u64;
        self.stats.net_s += self.cfg.net.allgather_time(bytes, w);
        if w > 1 {
            self.stats.bytes_shuffled += bytes * (w as u64 - 1);
            self.stats.msgs += w as u64 - 1;
        }
        Ok(PartitionedRelation::replicate_handle(Arc::new(full), w))
    }

    fn account_shuffle(&mut self, st: ShuffleStats) {
        self.stats.bytes_shuffled += st.bytes;
        self.stats.msgs += st.msgs;
        self.stats.net_s += self
            .cfg
            .net
            .alltoall_time(st.bytes, st.msgs, self.cfg.workers);
    }
}

// ------------------------------------------------------------ primitives

/// One worker's join-stage output with its measured/modeled accounting.
struct JoinShard {
    out: Relation,
    /// Measured compute seconds (the caller maxes over the stage's
    /// workers, who run in parallel). Spill file I/O is excluded — it is
    /// charged to the modeled spill clock, and shows up for real in the
    /// evaluation's `wall_s`.
    compute_s: f64,
    /// Modeled spill seconds (maxed over workers likewise).
    spill_s: f64,
    /// Spill events: grace passes beyond the first, or one if the stage
    /// ran over budget with an unsplittable build side.
    spill_events: u64,
    /// Measured bytes written to this worker's spill run file.
    spill_written: u64,
    /// Measured bytes re-read from it.
    spill_read: u64,
}

/// One worker's share of a join stage: budget check, grace spilling
/// through real temp files, measured compute. Runs on the worker's own
/// thread with the worker's own backend (budget/policy are passed by
/// value so the pool job owns its captures; the scratch space arrives as
/// a shared handle). Under `MemPolicy::Fail` the sharded caller
/// pre-checks every worker's budget before launching the stage, so the
/// `Oom` arm below fires only on the replicated run-once path (it is
/// kept as a defensive invariant for any future caller that skips the
/// pre-check).
#[allow(clippy::too_many_arguments)]
fn join_worker_shard(
    budget: Option<u64>,
    policy: MemPolicy,
    spill: Option<&LazySpill>,
    faults: Option<&FaultInjector>,
    wi: usize,
    l: &Relation,
    r: &Relation,
    pred: &JoinPred,
    proj: &KeyProj2,
    kernel: &BinaryKernel,
    backend: &dyn KernelBackend,
) -> Result<JoinShard, DistError> {
    // This worker is about to build its join hash table (in-memory) or
    // its spill runs (grace path) — the `JoinBuild` injection site.
    probe_fault(faults, InjectionPoint::JoinBuild, wi)?;
    if let Some(budget) = budget {
        let needed = join_needed_bytes(l, r, pred, kernel);
        if needed > budget {
            match policy {
                MemPolicy::Fail => {
                    return Err(DistError::Oom {
                        worker: wi,
                        needed,
                        budget,
                    });
                }
                MemPolicy::Spill => {
                    // Grace hash join, for real: the build side goes to
                    // this worker's spill scratch in budget-sized runs
                    // and streams back one pass at a time; the probe
                    // side is rescanned per pass. A build side too small
                    // to split (or already a single tuple) still spills
                    // its one run and counts one event: the stage ran
                    // out-of-core. Zero budget degrades to the maximal
                    // grace — one tuple per pass — and never errors
                    // (`mem::grace_passes` pins this).
                    let build_len = l.len().min(r.len()).max(1) as u64;
                    let passes = mem::grace_passes(needed, budget).min(build_len);
                    // Modeled I/O: per-pass probe rescans + the overflow
                    // beyond budget, priced at `mem::SPILL_BPS`. The
                    // probe side is the one the grace join rescans
                    // (split shared with the threshold formula).
                    let (_, probe, _) = build_probe_split(l, r);
                    let spill_s = mem::spill_io_s(
                        (passes - 1) * probe.nbytes() as u64 + needed.saturating_sub(budget),
                    );
                    let space = spill
                        .ok_or_else(|| {
                            DistError::Other(anyhow!(
                                "worker {wi} must spill but no scratch space is configured"
                            ))
                        })?
                        .space()
                        .map_err(DistError::Other)?;
                    let sj = grace_join_spilled(
                        l,
                        r,
                        pred,
                        proj,
                        kernel,
                        passes as usize,
                        backend,
                        &space,
                        faults,
                        wi,
                    )?;
                    // Events count the passes that actually executed
                    // (the run file's run count — pass sizing rounds, so
                    // it can be below the modeled `passes`), beyond the
                    // first; an unsplittable over-budget build still
                    // counts one: the stage ran out-of-core.
                    return Ok(JoinShard {
                        out: sj.out,
                        compute_s: sj.join_s,
                        spill_s,
                        spill_events: sj.runs.max(2) - 1,
                        spill_written: sj.bytes_written,
                        spill_read: sj.bytes_read,
                    });
                }
            }
        }
    }
    // Build done (or within budget): the probe phase is next.
    probe_fault(faults, InjectionPoint::JoinProbe, wi)?;
    let (out, t) = time(|| hash_join(l, r, pred, proj, kernel, backend));
    Ok(JoinShard {
        out: out.map_err(DistError::Other)?,
        compute_s: t,
        spill_s: 0.0,
        spill_events: 0,
        spill_written: 0,
        spill_read: 0,
    })
}

/// A spilled grace join's output plus its measured accounting.
struct SpilledJoin {
    out: Relation,
    /// Join compute seconds (pass rebuild + probe + merge), excluding
    /// file I/O.
    join_s: f64,
    /// Grace passes actually executed (= runs in the spill file).
    runs: u64,
    bytes_written: u64,
    bytes_read: u64,
}

/// Worker-local ⋈ in real grace passes: the build side (chosen by
/// [`build_probe_split`], mirroring `hash_join`'s own rule) is written
/// to the worker's spill scratch as `passes` columnar runs, the write
/// completes *before* any pass joins, then each run streams back and
/// probes against the resident probe shard — the hash table each pass
/// builds covers one run, never the whole build side. (The build
/// *relation handle* itself stays resident: the virtual cluster keeps
/// every worker's shards — and the tape — in one process by design, so
/// what this path makes real is the disk traffic and pass structure of
/// out-of-core execution, not a smaller process RSS; see the ROADMAP
/// open item on resident-set reduction.)
///
/// **Order invariant.** The output relation is identical to single-pass
/// `hash_join(l, r)` *including insertion order*, which is what keeps a
/// downstream Σ's float merge order — and therefore the whole spilled
/// execution — bitwise identical to the in-memory run. Single-pass
/// emission is probe-major with matches in build-insertion order (cross
/// joins: always left-major), so each pass deposits its matches into
/// per-probe buckets; runs are contiguous ascending slices of the build
/// side, hence each bucket accumulates build indices in ascending order
/// across passes, and the final bucket-order assembly replays the
/// single-pass sequence exactly. Per-tuple kernels are pure, so values
/// are unchanged by the altered evaluation order.
#[allow(clippy::too_many_arguments)]
fn grace_join_spilled(
    l: &Relation,
    r: &Relation,
    pred: &JoinPred,
    proj: &KeyProj2,
    kernel: &BinaryKernel,
    passes: usize,
    backend: &dyn KernelBackend,
    space: &SpillSpace,
    faults: Option<&FaultInjector>,
    wi: usize,
) -> Result<SpilledJoin, DistError> {
    // Genuine spill-file I/O failures are *transient* (a flaky scratch
    // device): the stage retry loop replays the whole shard from its
    // immutable inputs, and the aborted attempt's run file is removed by
    // `SpillFile`'s delete-on-drop, so no orphan runs survive a retry.
    let t_err = |what: String| DistError::Transient { worker: wi, what };
    let (build, probe, build_is_left) = build_probe_split(l, r);
    let dir = space
        .ensure_worker_dir(wi)
        .map_err(|e| t_err(format!("creating worker {wi} spill scratch: {e}")))?;
    probe_fault(faults, InjectionPoint::SpillWrite, wi)?;
    let mut writer = SpillWriter::create(&dir)
        .map_err(|e| t_err(format!("creating spill run file under {}: {e}", dir.display())))?;
    if build.is_empty() {
        // An empty build side over budget (huge probe) still runs
        // out-of-core: one empty run, an empty join.
        writer
            .write_run(&[])
            .map_err(|e| t_err(format!("writing spill run: {e}")))?;
    } else {
        let per = build.len().div_ceil(passes.max(1)).max(1);
        for group in build.pairs().chunks(per) {
            writer
                .write_run(group)
                .map_err(|e| t_err(format!("writing spill run: {e}")))?;
        }
    }
    let file = writer
        .finish()
        .map_err(|e| t_err(format!("sealing spill run file: {e}")))?;
    let bytes_written = file.nbytes();
    let runs = file.runs();
    // Build runs are sealed; the per-pass probe phase starts here (the
    // grace-path `JoinProbe` site, mirroring the in-memory join's).
    probe_fault(faults, InjectionPoint::JoinProbe, wi)?;
    probe_fault(faults, InjectionPoint::SpillRead, wi)?;
    let mut reader = SpillReader::open(&file)
        .map_err(|e| t_err(format!("reopening spill run file: {e}")))?;

    // One bucket per emission-major tuple: the probe side for
    // equi-joins, the *left* side for cross joins (hash_join's cross
    // arm is left-major whichever side is smaller).
    let cross = pred.eqs.is_empty();
    let n_buckets = if cross { l.len() } else { probe.len() };
    let mut buckets: Vec<Vec<(Key, Chunk)>> = (0..n_buckets).map(|_| Vec::new()).collect();
    let (bcomps, pcomps) = if build_is_left {
        (pred.left_comps(), pred.right_comps())
    } else {
        (pred.right_comps(), pred.left_comps())
    };
    let lits_ok = |lits: &[(usize, i64)], k: &Key| lits.iter().all(|&(i, v)| k.get(i) == v);
    let (blits, plits) = if build_is_left {
        (&pred.l_lits, &pred.r_lits)
    } else {
        (&pred.r_lits, &pred.l_lits)
    };
    let mut join_s = 0.0f64;
    // Global build-side index of the current run's first tuple (runs are
    // contiguous ascending slices of `build.pairs()`).
    let mut run_base = 0usize;
    while let Some(run) = reader
        .next_run()
        .map_err(|e| t_err(format!("reading spill run: {e}")))?
    {
        let (res, t) = time(|| -> Result<()> {
            if cross {
                // hash_join's cross arm is left-major whichever side is
                // smaller: bucket by the left tuple's global index.
                if build_is_left {
                    for (off, (bk, bv)) in run.iter().enumerate() {
                        if !lits_ok(&pred.l_lits, bk) {
                            continue;
                        }
                        for (rk, rv) in probe.iter() {
                            if !lits_ok(&pred.r_lits, rk) {
                                continue;
                            }
                            let nk = proj.apply(bk, rk);
                            let nv = backend.binary(kernel, &nk, bv, rv);
                            buckets[run_base + off].push((nk, nv));
                        }
                    }
                } else {
                    for (li, (lk, lv)) in probe.iter().enumerate() {
                        if !lits_ok(&pred.l_lits, lk) {
                            continue;
                        }
                        for (bk, bv) in run.iter() {
                            if !lits_ok(&pred.r_lits, bk) {
                                continue;
                            }
                            let nk = proj.apply(lk, bk);
                            let nv = backend.binary(kernel, &nk, lv, bv);
                            buckets[li].push((nk, nv));
                        }
                    }
                }
                return Ok(());
            }
            // Equi-join pass: hash the run (the resident build slice),
            // probe the resident side in insertion order, deposit into
            // per-probe buckets — matches ascend in build order within
            // the run, and run bases ascend across passes.
            let mut table: FxHashMap<Key, Vec<u32>> = FxHashMap::default();
            for (idx, (bk, _)) in run.iter().enumerate() {
                if !lits_ok(blits, bk) {
                    continue;
                }
                table.entry(subkey(bk, &bcomps)).or_default().push(idx as u32);
            }
            for (pi, (pk, pv)) in probe.iter().enumerate() {
                if !lits_ok(plits, pk) {
                    continue;
                }
                if let Some(matches) = table.get(&subkey(pk, &pcomps)) {
                    for &bi in matches {
                        let (bk, bv) = &run[bi as usize];
                        let (nk, nv) = if build_is_left {
                            let nk = proj.apply(bk, pk);
                            let nv = backend.binary(kernel, &nk, bv, pv);
                            (nk, nv)
                        } else {
                            let nk = proj.apply(pk, bk);
                            let nv = backend.binary(kernel, &nk, pv, bv);
                            (nk, nv)
                        };
                        buckets[pi].push((nk, nv));
                    }
                }
            }
            Ok(())
        });
        join_s += t;
        res.map_err(DistError::Other)?;
        run_base += run.len();
    }
    let bytes_read = reader.bytes_read();
    // Assemble in bucket (single-pass emission) order, with the same
    // injectivity check the in-memory join applies.
    let total: usize = buckets.iter().map(|b| b.len()).sum();
    let (res, t) = time(|| -> Result<Relation> {
        let mut out = Relation::with_capacity(total);
        for bucket in buckets {
            for (k, v) in bucket {
                if out.contains(&k) {
                    bail!(
                        "⋈ projection {proj} is not injective on matches: key {k} collides (add a Σ to aggregate)"
                    );
                }
                out.insert(k, v);
            }
        }
        Ok(out)
    });
    join_s += t;
    Ok(SpilledJoin {
        // A non-injective projection is a *plan* error, not a transient
        // fault: it stays `Other` so the retry loop never replays it.
        out: res.map_err(DistError::Other)?,
        join_s,
        runs,
        bytes_written,
        bytes_read,
    })
}

/// One worker's tagged-join output under a skew strategy: matches carry
/// their oblivious `(home, left pos, right pos)` coordinates so the
/// driver can replay `hash_join`'s per-home emission order exactly.
struct SkewJoinShard {
    /// `(home, left pos, right pos, out key, out value)` per match.
    matches: Vec<(u32, u32, u32, Key, Chunk)>,
    compute_s: f64,
    spill_s: f64,
    spill_events: u64,
    spill_written: u64,
    spill_read: u64,
}

/// Compute one tagged match: output key/value via the pure per-pair
/// kernel, plus the oblivious coordinates of the two rows — which agree
/// on `home`, since both sides of a match are homed by the hash of
/// their equal join subkeys.
fn emit_tagged(
    b: &(Key, Chunk, u32, u32),
    p: &(Key, Chunk, u32, u32),
    build_left: bool,
    proj: &KeyProj2,
    kernel: &BinaryKernel,
    backend: &dyn KernelBackend,
) -> (u32, u32, u32, Key, Chunk) {
    debug_assert_eq!(b.2, p.2, "matched rows must share an oblivious home");
    let (nk, nv, lpos, rpos) = if build_left {
        let nk = proj.apply(&b.0, &p.0);
        let nv = backend.binary(kernel, &nk, &b.1, &p.1);
        (nk, nv, b.3, p.3)
    } else {
        let nk = proj.apply(&p.0, &b.0);
        let nv = backend.binary(kernel, &nk, &p.1, &b.1);
        (nk, nv, p.3, b.3)
    };
    (b.2, lpos, rpos, nk, nv)
}

/// One worker's share of a skew-routed join: hash-join its assigned
/// material (cold home rows plus whatever hot rows the skew routing
/// placed here), emitting tagged matches instead of a relation. The
/// local build-side choice and emission order are free — ordering is
/// re-imposed by the driver's merge — so the split rule here (smaller
/// material side builds, ties build right like `hash_join`) only shapes
/// pass structure, never bits. Budget handling mirrors
/// [`join_worker_shard`] with the assigned material as the working set:
/// `Fail` is pre-checked by the driver (the arm here is defensive);
/// `Spill` runs real grace passes over the build material
/// ([`skew_join_spilled`]).
#[allow(clippy::too_many_arguments)]
fn skew_join_worker(
    budget: Option<u64>,
    policy: MemPolicy,
    spill: Option<&LazySpill>,
    faults: Option<&FaultInjector>,
    wi: usize,
    l_mat: &[(Key, Chunk, u32, u32)],
    r_mat: &[(Key, Chunk, u32, u32)],
    pred: &JoinPred,
    proj: &KeyProj2,
    kernel: &BinaryKernel,
    backend: &dyn KernelBackend,
) -> Result<SkewJoinShard, DistError> {
    // About to build the hash table (in-memory) or the spill runs (grace
    // path) — the `JoinBuild` injection site, like the oblivious worker.
    probe_fault(faults, InjectionPoint::JoinBuild, wi)?;
    let build_left = r_mat.len() > l_mat.len();
    let (bmat, pmat) = if build_left {
        (l_mat, r_mat)
    } else {
        (r_mat, l_mat)
    };
    let (bcomps, pcomps) = if build_left {
        (pred.left_comps(), pred.right_comps())
    } else {
        (pred.right_comps(), pred.left_comps())
    };
    let (blits, plits) = if build_left {
        (&pred.l_lits, &pred.r_lits)
    } else {
        (&pred.r_lits, &pred.l_lits)
    };
    let mat_bytes = |m: &[(Key, Chunk, u32, u32)]| {
        m.iter()
            .map(|(_, v, _, _)| shuffle::tuple_bytes(v))
            .sum::<u64>()
    };
    if let Some(budget) = budget {
        let needed = mat_bytes(bmat) + mat_bytes(pmat);
        if needed > budget {
            match policy {
                MemPolicy::Fail => {
                    return Err(DistError::Oom {
                        worker: wi,
                        needed,
                        budget,
                    });
                }
                MemPolicy::Spill => {
                    return skew_join_spilled(
                        needed, budget, spill, faults, wi, bmat, pmat, &bcomps, &pcomps,
                        blits, plits, build_left, proj, kernel, backend,
                    );
                }
            }
        }
    }
    probe_fault(faults, InjectionPoint::JoinProbe, wi)?;
    let lits_ok = |lits: &[(usize, i64)], k: &Key| lits.iter().all(|&(i, v)| k.get(i) == v);
    let (matches, t) = time(|| {
        let mut table: FxHashMap<Key, Vec<u32>> = FxHashMap::default();
        for (idx, b) in bmat.iter().enumerate() {
            if !lits_ok(blits, &b.0) {
                continue;
            }
            table
                .entry(subkey(&b.0, &bcomps))
                .or_default()
                .push(idx as u32);
        }
        let mut out = Vec::new();
        for p in pmat.iter() {
            if !lits_ok(plits, &p.0) {
                continue;
            }
            if let Some(ms) = table.get(&subkey(&p.0, &pcomps)) {
                for &bi in ms {
                    out.push(emit_tagged(
                        &bmat[bi as usize],
                        p,
                        build_left,
                        proj,
                        kernel,
                        backend,
                    ));
                }
            }
        }
        out
    });
    Ok(SkewJoinShard {
        matches,
        compute_s: t,
        spill_s: 0.0,
        spill_events: 0,
        spill_written: 0,
        spill_read: 0,
    })
}

/// [`grace_join_spilled`]'s analogue for a skew worker: the build
/// *material* goes to the worker's spill scratch in budget-sized runs
/// and streams back pass by pass, with the probe material rescanned per
/// pass. Emission is tagged and pass-major — any order is fine, the
/// driver's merge re-imposes the oblivious emission order — and the
/// measured run-file traffic lands in the same counters as the
/// oblivious grace join's.
#[allow(clippy::too_many_arguments)]
fn skew_join_spilled(
    needed: u64,
    budget: u64,
    spill: Option<&LazySpill>,
    faults: Option<&FaultInjector>,
    wi: usize,
    bmat: &[(Key, Chunk, u32, u32)],
    pmat: &[(Key, Chunk, u32, u32)],
    bcomps: &[usize],
    pcomps: &[usize],
    blits: &[(usize, i64)],
    plits: &[(usize, i64)],
    build_left: bool,
    proj: &KeyProj2,
    kernel: &BinaryKernel,
    backend: &dyn KernelBackend,
) -> Result<SkewJoinShard, DistError> {
    let t_err = |what: String| DistError::Transient { worker: wi, what };
    let build_len = bmat.len().max(1) as u64;
    let passes = mem::grace_passes(needed, budget).min(build_len);
    let p_bytes: u64 = pmat
        .iter()
        .map(|(_, v, _, _)| shuffle::tuple_bytes(v))
        .sum();
    let spill_s = mem::spill_io_s((passes - 1) * p_bytes + needed.saturating_sub(budget));
    let space = spill
        .ok_or_else(|| {
            DistError::Other(anyhow!(
                "worker {wi} must spill but no scratch space is configured"
            ))
        })?
        .space()
        .map_err(DistError::Other)?;
    let dir = space
        .ensure_worker_dir(wi)
        .map_err(|e| t_err(format!("creating worker {wi} spill scratch: {e}")))?;
    probe_fault(faults, InjectionPoint::SpillWrite, wi)?;
    let mut writer = SpillWriter::create(&dir)
        .map_err(|e| t_err(format!("creating spill run file under {}: {e}", dir.display())))?;
    if bmat.is_empty() {
        writer
            .write_run(&[])
            .map_err(|e| t_err(format!("writing spill run: {e}")))?;
    } else {
        let per = bmat.len().div_ceil(passes as usize).max(1);
        let pairs: Vec<(Key, Chunk)> = bmat.iter().map(|(k, v, _, _)| (*k, v.clone())).collect();
        for group in pairs.chunks(per) {
            writer
                .write_run(group)
                .map_err(|e| t_err(format!("writing spill run: {e}")))?;
        }
    }
    let file = writer
        .finish()
        .map_err(|e| t_err(format!("sealing spill run file: {e}")))?;
    let bytes_written = file.nbytes();
    let runs = file.runs();
    probe_fault(faults, InjectionPoint::JoinProbe, wi)?;
    probe_fault(faults, InjectionPoint::SpillRead, wi)?;
    let mut reader =
        SpillReader::open(&file).map_err(|e| t_err(format!("reopening spill run file: {e}")))?;
    let lits_ok = |lits: &[(usize, i64)], k: &Key| lits.iter().all(|&(i, v)| k.get(i) == v);
    let mut matches: Vec<(u32, u32, u32, Key, Chunk)> = Vec::new();
    let mut join_s = 0.0f64;
    // Global build-material index of the current run's first tuple (runs
    // are contiguous ascending slices of `bmat`).
    let mut run_base = 0usize;
    while let Some(run) = reader
        .next_run()
        .map_err(|e| t_err(format!("reading spill run: {e}")))?
    {
        let (_, t) = time(|| {
            let mut table: FxHashMap<Key, Vec<u32>> = FxHashMap::default();
            for (idx, (bk, _)) in run.iter().enumerate() {
                if !lits_ok(blits, bk) {
                    continue;
                }
                table.entry(subkey(bk, bcomps)).or_default().push(idx as u32);
            }
            for p in pmat.iter() {
                if !lits_ok(plits, &p.0) {
                    continue;
                }
                if let Some(ms) = table.get(&subkey(&p.0, pcomps)) {
                    for &bi in ms {
                        // Tags come from the resident build material at
                        // the run's global offset; the streamed run rows
                        // are byte-identical copies of it.
                        let b = &bmat[run_base + bi as usize];
                        matches.push(emit_tagged(b, p, build_left, proj, kernel, backend));
                    }
                }
            }
        });
        join_s += t;
        run_base += run.len();
    }
    let bytes_read = reader.bytes_read();
    Ok(SkewJoinShard {
        matches,
        compute_s: join_s,
        spill_s,
        spill_events: runs.max(2) - 1,
        spill_written: bytes_written,
        spill_read: bytes_read,
    })
}

/// Cross-worker key-disjointness check for `Arbitrary` outputs, matching
/// the single-node injectivity error. `Hash`/`Replicated` outputs need no
/// check: equal keys co-locate, so the per-worker checks already caught
/// any collision.
fn check_disjoint(shards: &[Relation], what: impl std::fmt::Display) -> Result<()> {
    let total: usize = shards.iter().map(|s| s.len()).sum();
    let mut seen = crate::util::FxHashSet::default();
    seen.reserve(total);
    for shard in shards {
        for (k, _) in shard.iter() {
            if !seen.insert(*k) {
                bail!("{what} is not injective across workers: key {k} collides");
            }
        }
    }
    Ok(())
}

/// Positions in `proj`'s output carrying each of `comps` (in order);
/// `None` if any component is dropped.
pub(crate) fn preserved_positions(comps: &[usize], proj: &KeyProj) -> Option<Vec<usize>> {
    comps
        .iter()
        .map(|&c| proj.0.iter().position(|s| *s == Sel::C(c)))
        .collect()
}

/// As `preserved_positions`, for one side of a binary projection.
fn preserved_positions2(comps: &[usize], proj: &KeyProj2, left: bool) -> Option<Vec<usize>> {
    comps
        .iter()
        .map(|&c| {
            let want = if left { Sel2::L(c) } else { Sel2::R(c) };
            proj.0.iter().position(|s| *s == want)
        })
        .collect()
}

/// Partitioning of a join output: replicated iff both sides are; else
/// the surviving hash invariant of either stored side, if its components
/// are carried through the projection.
pub(crate) fn join_output_part(
    lpart: &Partitioning,
    rpart: &Partitioning,
    proj: &KeyProj2,
) -> Partitioning {
    if matches!(
        (lpart, rpart),
        (Partitioning::Replicated, Partitioning::Replicated)
    ) {
        return Partitioning::Replicated;
    }
    if let Some(c) = lpart.hash_comps() {
        if let Some(pos) = preserved_positions2(c, proj, true) {
            return Partitioning::Hash(pos);
        }
    }
    if let Some(c) = rpart.hash_comps() {
        if let Some(pos) = preserved_positions2(c, proj, false) {
            return Partitioning::Hash(pos);
        }
    }
    Partitioning::Arbitrary
}

#[inline]
fn tuple_out_bytes(shape: (usize, usize)) -> u64 {
    (4 * shape.0 * shape.1 + std::mem::size_of::<Key>()) as u64
}

/// The build/probe split every memory-accounting consumer shares: the
/// grace join builds (and spills) the smaller-by-count side and rescans
/// the other. Returns `(build, probe, build_is_left)`. The rule —
/// including the tie-break toward the right side — deliberately mirrors
/// `ra::eval::hash_join`'s internal choice, so spilled grace passes
/// reproduce the single-pass emission order tuple for tuple. Keeping
/// this one helper between the `Fail` pre-check's working-set formula,
/// the spill pass sizing, and the modeled I/O charge is what guarantees
/// `Fail`'s OOM threshold and `Spill`'s spill threshold are the same
/// number on identical inputs (unit-tested below).
pub(crate) fn build_probe_split<'r>(
    l: &'r Relation,
    r: &'r Relation,
) -> (&'r Relation, &'r Relation, bool) {
    if r.len() <= l.len() {
        (r, l, false)
    } else {
        (l, r, true)
    }
}

/// Payload bytes of the side the grace join will build — the build term
/// of [`join_needed_bytes`], and the payload an over-budget stage
/// serializes into its spill runs (the writer's exact framing is what
/// `ExecStats::spill_bytes_written` measures).
pub(crate) fn build_side_bytes(l: &Relation, r: &Relation) -> u64 {
    build_probe_split(l, r).0.nbytes() as u64
}

/// One worker's join working set — the byte-accounting formula *both*
/// policies charge, decomposed through the shared build/probe split:
/// build + probe + estimated output. `MemPolicy::Fail` OOMs exactly
/// when this exceeds the budget; `MemPolicy::Spill` spills under
/// exactly the same condition (unit-tested below).
fn join_needed_bytes(l: &Relation, r: &Relation, pred: &JoinPred, kernel: &BinaryKernel) -> u64 {
    let (_, probe, _) = build_probe_split(l, r);
    build_side_bytes(l, r) + probe.nbytes() as u64 + estimate_join_out_bytes(l, r, pred, kernel)
}

/// Bytes the join output will occupy on this worker — exact match
/// counting per join key for equi-joins, an upper bound for cross joins.
fn estimate_join_out_bytes(
    l: &Relation,
    r: &Relation,
    pred: &JoinPred,
    kernel: &BinaryKernel,
) -> u64 {
    if l.is_empty() || r.is_empty() {
        return 0;
    }
    let lv0 = &l.pairs()[0].1;
    let rv0 = &r.pairs()[0].1;
    let default_shape = kernel.out_shape(lv0.shape(), rv0.shape()).unwrap_or(lv0.shape());
    if pred.eqs.is_empty() {
        return (l.len() as u64) * (r.len() as u64) * tuple_out_bytes(default_shape);
    }
    let lcomps = pred.left_comps();
    let rcomps = pred.right_comps();
    let mut groups: FxHashMap<Key, (u64, (usize, usize))> = FxHashMap::default();
    for (rk, rv) in r.iter() {
        if !pred.r_lits.iter().all(|&(j, v)| rk.get(j) == v) {
            continue;
        }
        let e = groups.entry(subkey(rk, &rcomps)).or_insert((0, rv.shape()));
        e.0 += 1;
    }
    let mut total = 0u64;
    for (lk, lv) in l.iter() {
        if !pred.l_lits.iter().all(|&(i, v)| lk.get(i) == v) {
            continue;
        }
        if let Some(&(cnt, rshape)) = groups.get(&subkey(lk, &lcomps)) {
            let shape = kernel.out_shape(lv.shape(), rshape).unwrap_or(default_shape);
            total += cnt * tuple_out_bytes(shape);
        }
    }
    total
}

#[cfg(test)]
// These unit tests exercise the deprecated free-function surface on
// purpose: it must keep working (and keep matching the session path)
// until it is removed. New code goes through `session::Session` — see
// the migration note on the `session` module.
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::kernels::NativeBackend;
    use crate::ra::eval::eval_query;
    use crate::ra::expr::{matmul_query, QueryBuilder};
    use crate::ra::Chunk;
    use crate::util::Prng;

    fn blocked(n: i64, m: i64, c: usize, rng: &mut Prng) -> Relation {
        let mut r = Relation::new();
        for i in 0..n {
            for j in 0..m {
                r.insert(Key::k2(i, j), Chunk::random(c, c, rng, 1.0));
            }
        }
        r
    }

    #[test]
    fn dist_matmul_matches_single_node_across_worker_counts() {
        let mut rng = Prng::new(71);
        let a = blocked(3, 2, 4, &mut rng);
        let b = blocked(2, 3, 4, &mut rng);
        let q = matmul_query();
        let want = eval_query(&q, &[&a, &b], &NativeBackend).unwrap();
        for w in [1usize, 2, 4, 7] {
            let pa = PartitionedRelation::hash_full(&a, w);
            let pb = PartitionedRelation::hash_full(&b, w);
            let (got, stats) =
                dist_eval(&q, &[pa, pb], &ClusterConfig::new(w), &NativeBackend).unwrap();
            assert!(got.gather().approx_eq(&want, 1e-4), "w={w}");
            assert_eq!(stats.spill_passes, 0, "w={w}: unbudgeted run spilled");
            assert!(stats.virtual_time_s > 0.0);
            assert!(stats.wall_s > 0.0);
        }
    }

    #[test]
    fn co_partitioned_inputs_join_locally() {
        let mut rng = Prng::new(72);
        let a = blocked(4, 3, 2, &mut rng);
        let b = blocked(3, 4, 2, &mut rng);
        let q = matmul_query();
        // Matmul joins on A[1] = B[0]: partition A by col, B by row.
        let pa = PartitionedRelation::hash_partition(&a, &[1], 3);
        let pb = PartitionedRelation::hash_partition(&b, &[0], 3);
        let plan = plan_join(
            &pa,
            &pb,
            &crate::ra::funcs::JoinPred::on(vec![(1, 0)]),
            &NetModel::default(),
            3,
        );
        assert_eq!(plan.strategy, JoinStrategy::Local);
        // And the full query still matches single node.
        let want = eval_query(&q, &[&a, &b], &NativeBackend).unwrap();
        let (got, _) =
            dist_eval(&q, &[pa, pb], &ClusterConfig::new(3), &NativeBackend).unwrap();
        assert!(got.gather().approx_eq(&want, 1e-4));
    }

    #[test]
    fn replicated_side_never_moves() {
        let mut rng = Prng::new(73);
        let a = blocked(4, 2, 2, &mut rng);
        let b = blocked(2, 2, 2, &mut rng);
        let pa = PartitionedRelation::hash_partition(&a, &[0], 4);
        let pb = PartitionedRelation::replicate(&b, 4);
        let plan = plan_join(
            &pa,
            &pb,
            &crate::ra::funcs::JoinPred::on(vec![(1, 0)]),
            &NetModel::default(),
            4,
        );
        assert_eq!(plan.strategy, JoinStrategy::Local);
    }

    /// Key-order *and* exact-value equality — the bitwise bar the skew
    /// merge must clear, stricter than `approx_eq` (which ignores
    /// insertion order).
    fn assert_bitwise(a: &Relation, b: &Relation, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: row count diverges");
        for (i, ((ka, va), (kb, vb))) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(ka, kb, "{what}: key order diverges at row {i}");
            assert!(va.approx_eq(vb, 0.0), "{what}: value diverges at key {ka}");
        }
    }

    /// A matmul input with a heavy hitter in the join component
    /// (`A[1] = B[0]` joins on A's column index): most rows share j=0.
    fn skewed_a(rng: &mut Prng) -> Relation {
        let mut a = Relation::new();
        for i in 0..48 {
            a.insert(Key::k2(i, 0), Chunk::random(2, 2, rng, 1.0));
        }
        for i in 0..6 {
            a.insert(Key::k2(100 + i, 1 + (i % 3)), Chunk::random(2, 2, rng, 1.0));
        }
        a
    }

    /// Byte-dominated fabric: unit-test relations are tiny, so zero the
    /// per-message latency and shrink bandwidth to let the straggler
    /// term decide the skew costing.
    fn skew_net() -> NetModel {
        NetModel {
            bandwidth_bps: 1e3,
            latency_s: 0.0,
        }
    }

    #[test]
    fn skew_salt_plan_fires_and_matches_oblivious_bitwise() {
        let mut rng = Prng::new(75);
        let a = skewed_a(&mut rng);
        let mut b = Relation::new();
        for j in 0..4 {
            for k in 0..2 {
                b.insert(Key::k2(j, k), Chunk::random(2, 2, &mut rng, 1.0));
            }
        }
        let q = matmul_query();
        let w = 3;
        let pb = PartitionedRelation::hash_partition(&b, &[0], w);
        let oblivious = PartitionedRelation::hash_partition(&a, &[1], w);
        let mut skewed = PartitionedRelation::hash_partition(&a, &[1], w);
        skewed.part = Partitioning::SkewHash {
            comps: vec![1],
            hot: vec![Key::k1(0)].into(),
        };
        let pred = crate::ra::funcs::JoinPred::on(vec![(1, 0)]);
        let plan = plan_join(&skewed, &pb, &pred, &skew_net(), w);
        assert!(
            matches!(
                plan.strategy,
                JoinStrategy::SkewSalt {
                    side: JoinSide::Left,
                    ..
                }
            ),
            "expected SkewSalt on the annotated side, got {:?}",
            plan.strategy
        );
        let cfg = ClusterConfig::new(w).with_net(skew_net());
        let (want, base) = dist_eval(&q, &[oblivious, pb.clone()], &cfg, &NativeBackend).unwrap();
        let (got, stats) = dist_eval(&q, &[skewed, pb], &cfg, &NativeBackend).unwrap();
        assert_eq!(base.rows_salted, 0, "oblivious run must not salt");
        assert_eq!(base.bytes_hot_replicated, 0);
        assert!(stats.rows_salted > 0, "salted routing must fire");
        assert!(stats.bytes_hot_replicated > 0, "hot rows must replicate");
        for wi in 0..w {
            assert_bitwise(&got.shards[wi], &want.shards[wi], &format!("shard {wi}"));
        }
        assert_bitwise(&got.gather(), &want.gather(), "gathered output");
    }

    #[test]
    fn skew_broadcast_plan_fires_and_matches_oblivious_bitwise() {
        let mut rng = Prng::new(76);
        let a = skewed_a(&mut rng);
        // B is misplaced (partitioned on its k column, not the join
        // component) and hot on the same join key j=0, so the oblivious
        // reshuffle would pile both sides' hot rows onto one worker.
        let mut b = Relation::new();
        for k in 0..30 {
            b.insert(Key::k2(0, k), Chunk::random(2, 2, &mut rng, 1.0));
        }
        for j in 1..4 {
            b.insert(Key::k2(j, 50 + j), Chunk::random(2, 2, &mut rng, 1.0));
        }
        let q = matmul_query();
        let w = 3;
        let pb = PartitionedRelation::hash_partition(&b, &[1], w);
        let oblivious = PartitionedRelation::hash_partition(&a, &[1], w);
        let mut skewed = PartitionedRelation::hash_partition(&a, &[1], w);
        skewed.part = Partitioning::SkewHash {
            comps: vec![1],
            hot: vec![Key::k1(0)].into(),
        };
        let pred = crate::ra::funcs::JoinPred::on(vec![(1, 0)]);
        let plan = plan_join(&skewed, &pb, &pred, &skew_net(), w);
        assert_eq!(
            plan.strategy,
            JoinStrategy::SkewBroadcast {
                side: JoinSide::Left
            },
            "expected SkewBroadcast of the annotated side"
        );
        let cfg = ClusterConfig::new(w).with_net(skew_net());
        let (want, base) = dist_eval(&q, &[oblivious, pb.clone()], &cfg, &NativeBackend).unwrap();
        let (got, stats) = dist_eval(&q, &[skewed, pb], &cfg, &NativeBackend).unwrap();
        assert_eq!(base.rows_salted, 0);
        assert!(stats.rows_salted > 0, "hot probe rows must pin at source");
        assert!(stats.bytes_hot_replicated > 0, "hot build rows must replicate");
        for wi in 0..w {
            assert_bitwise(&got.shards[wi], &want.shards[wi], &format!("shard {wi}"));
        }
        assert_bitwise(&got.gather(), &want.gather(), "gathered output");
    }

    #[test]
    fn spill_results_identical_and_fail_ooms() {
        let mut rng = Prng::new(74);
        let a = blocked(4, 4, 8, &mut rng);
        let b = blocked(4, 4, 8, &mut rng);
        let q = matmul_query();
        let want = {
            let pa = PartitionedRelation::hash_full(&a, 3);
            let pb = PartitionedRelation::hash_full(&b, 3);
            let (got, stats) =
                dist_eval(&q, &[pa, pb], &ClusterConfig::new(3), &NativeBackend).unwrap();
            assert_eq!(stats.spill_passes, 0);
            got.gather()
        };
        let pa = PartitionedRelation::hash_full(&a, 3);
        let pb = PartitionedRelation::hash_full(&b, 3);
        let spill_cfg = ClusterConfig::new(3)
            .with_budget(2048)
            .with_policy(MemPolicy::Spill);
        let (got, stats) =
            dist_eval(&q, &[pa.clone(), pb.clone()], &spill_cfg, &NativeBackend).unwrap();
        assert!(stats.spill_passes > 0, "tight budget must spill");
        assert!(stats.spill_s > 0.0);
        assert!(
            stats.spill_bytes_written > 0,
            "grace passes must hit real temp files"
        );
        assert_eq!(
            stats.spill_bytes_read, stats.spill_bytes_written,
            "a completed run re-reads exactly what it wrote"
        );
        assert!(got.gather().approx_eq(&want, 0.0), "spill changed results");
        let fail_cfg = ClusterConfig::new(3)
            .with_budget(2048)
            .with_policy(MemPolicy::Fail);
        match dist_eval(&q, &[pa, pb], &fail_cfg, &NativeBackend) {
            Err(DistError::Oom { needed, budget, .. }) => {
                assert!(needed > budget);
            }
            other => panic!("expected OOM, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn two_phase_agg_merges_cross_worker_groups() {
        // All tuples share one group: partials live on several workers and
        // must be merged by the exchange.
        let mut rng = Prng::new(75);
        let mut x = Relation::new();
        for i in 0..20 {
            x.insert(Key::k1(i), Chunk::random(1, 1, &mut rng, 1.0));
        }
        let q = {
            let mut qb = QueryBuilder::new();
            let s = qb.scan(0, "x");
            let a = qb.agg(KeyProj::to_empty(), AggKernel::Sum, s);
            qb.finish(a)
        };
        let want = eval_query(&q, &[&x], &NativeBackend).unwrap();
        for w in [1usize, 3, 6] {
            let px = PartitionedRelation::hash_full(&x, w);
            let (got, _) =
                dist_eval(&q, &[px], &ClusterConfig::new(w), &NativeBackend).unwrap();
            let g = got.gather();
            assert_eq!(g.len(), 1);
            assert!(g.approx_eq(&want, 1e-5), "w={w}");
        }
    }

    /// The satellite fix of PR 5: `Fail`'s OOM threshold and `Spill`'s
    /// spill threshold are one formula (`join_needed_bytes`, split via
    /// `build_probe_split`) — on identical inputs the two policies flip
    /// at exactly the same budget.
    #[test]
    fn fail_oom_threshold_equals_spill_threshold() {
        let mut rng = Prng::new(77);
        let a = blocked(3, 3, 4, &mut rng);
        let b = blocked(3, 3, 4, &mut rng);
        let q = matmul_query();
        let pred = crate::ra::funcs::JoinPred::on(vec![(1, 0)]);
        let needed = join_needed_bytes(&a, &b, &pred, &BinaryKernel::MatMul);
        assert!(needed > 0);
        // Equal tuple counts ⇒ the split builds on the right operand,
        // mirroring hash_join's tie-break.
        assert_eq!(build_side_bytes(&a, &b), b.nbytes() as u64);
        for (budget, over) in [(needed, false), (needed - 1, true), (needed / 3, true)] {
            let run = |policy| {
                let pa = PartitionedRelation::hash_full(&a, 1);
                let pb = PartitionedRelation::hash_full(&b, 1);
                let cfg = ClusterConfig::new(1).with_budget(budget).with_policy(policy);
                dist_eval(&q, &[pa, pb], &cfg, &NativeBackend)
            };
            let (_, st) = run(MemPolicy::Spill).expect("Spill must always complete");
            let fail = run(MemPolicy::Fail);
            if over {
                assert!(
                    st.spill_bytes_written > 0,
                    "budget {budget}: Spill did not spill"
                );
                assert!(
                    matches!(fail, Err(DistError::Oom { .. })),
                    "budget {budget}: Fail did not OOM"
                );
            } else {
                // Budget exactly equal to the working set: neither.
                assert_eq!(st.spill_bytes_written, 0, "budget {budget}: spurious spill");
                assert_eq!(st.spill_passes, 0, "budget {budget}");
                assert!(fail.is_ok(), "budget {budget}: spurious OOM");
            }
        }
    }

    /// The invariant that makes spilled execution bitwise-comparable:
    /// grace passes must reproduce the single-pass emission order, or a
    /// downstream Σ reassociates its float merge. This shape is the
    /// adversarial one — every probe tuple matches build tuples in
    /// *different* grace passes, so a pass-major emission (the naive
    /// concatenation) would interleave groups differently.
    #[test]
    fn spilled_grace_passes_preserve_single_pass_emission_order() {
        let mut rng = Prng::new(79);
        let mut build = Relation::new();
        for g in 0..2i64 {
            for i in 0..8i64 {
                build.insert(Key::k2(g, i), Chunk::random(1, 1, &mut rng, 1.0));
            }
        }
        let mut probe = Relation::new();
        for g in 0..2i64 {
            for j in 0..20i64 {
                probe.insert(Key::k2(g, j), Chunk::random(1, 1, &mut rng, 1.0));
            }
        }
        let q = {
            let mut qb = QueryBuilder::new();
            let x = qb.scan(0, "X");
            let y = qb.scan(1, "Y");
            let j = qb.join(
                crate::ra::funcs::JoinPred::on(vec![(0, 0)]),
                KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
                BinaryKernel::Mul,
                x,
                y,
            );
            let s = qb.agg(KeyProj::take(&[2]), AggKernel::Sum, j);
            qb.finish(s)
        };
        let px = PartitionedRelation::hash_full(&build, 1);
        let py = PartitionedRelation::hash_full(&probe, 1);
        let (want, _) = dist_eval(
            &q,
            &[px.clone(), py.clone()],
            &ClusterConfig::new(1),
            &NativeBackend,
        )
        .unwrap();
        let cfg = ClusterConfig::new(1).with_budget(600);
        let (got, st) = dist_eval(&q, &[px, py], &cfg, &NativeBackend).unwrap();
        assert!(
            st.spill_passes >= 2,
            "premise: multi-pass spill (got {} events)",
            st.spill_passes
        );
        let (gw, gg) = (want.gather(), got.gather());
        assert_eq!(gw.len(), gg.len());
        for (k, v) in gw.iter() {
            let w2 = gg.get(k).expect("key sets diverged");
            assert_eq!(v.shape(), w2.shape());
            for (x, y) in v.data().iter().zip(w2.data().iter()) {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "Σ over spilled ⋈ reassociated at {k}"
                );
            }
        }
    }

    /// Pinned semantics for the degenerate budget: zero bytes under
    /// `Spill` is the paper-faithful maximal grace — one build tuple per
    /// pass, never a typed error — while `Fail` OOMs as always.
    #[test]
    fn zero_budget_spills_per_tuple_and_never_errors() {
        let mut rng = Prng::new(78);
        let a = blocked(3, 2, 4, &mut rng);
        let b = blocked(2, 3, 4, &mut rng);
        let q = matmul_query();
        let want = eval_query(&q, &[&a, &b], &NativeBackend).unwrap();
        let pa = PartitionedRelation::hash_full(&a, 1);
        let pb = PartitionedRelation::hash_full(&b, 1);
        let cfg = ClusterConfig::new(1).with_budget(0);
        let (got, st) = dist_eval(&q, &[pa.clone(), pb.clone()], &cfg, &NativeBackend).unwrap();
        assert!(got.gather().approx_eq(&want, 1e-4));
        // Maximal grace: the build side (the smaller-by-count operand)
        // goes one tuple per pass.
        let build_len = a.len().min(b.len()) as u64;
        assert_eq!(st.spill_passes, build_len - 1);
        assert!(st.spill_bytes_written > 0);
        assert_eq!(st.spill_bytes_read, st.spill_bytes_written);
        let fail_cfg = ClusterConfig::new(1).with_budget(0).with_policy(MemPolicy::Fail);
        assert!(matches!(
            dist_eval(&q, &[pa, pb], &fail_cfg, &NativeBackend),
            Err(DistError::Oom { budget: 0, .. })
        ));
    }

    #[test]
    fn estimate_counts_equi_join_output_exactly() {
        let mut rng = Prng::new(76);
        let a = blocked(3, 2, 2, &mut rng);
        let b = blocked(2, 3, 2, &mut rng);
        let pred = crate::ra::funcs::JoinPred::on(vec![(1, 0)]);
        let proj = KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]);
        let kernel = BinaryKernel::MatMul;
        let est = estimate_join_out_bytes(&a, &b, &pred, &kernel);
        let out = hash_join(&a, &b, &pred, &proj, &kernel, &NativeBackend).unwrap();
        assert_eq!(est, out.nbytes() as u64);
    }
}
