//! The persistent worker pool behind the threaded BSP executor.
//!
//! PR 2 fanned every stage out under `std::thread::scope`, spawning and
//! joining `w` OS threads *per BSP stage* and re-minting the per-worker
//! [`KernelBackend`]s on every evaluation — cheap for the native backend,
//! a full PJRT artifact reload per worker per evaluation under
//! `--features xla`. A [`WorkerPool`] instead parks `w` worker threads
//! for the duration of a run: each thread owns one backend instance
//! minted exactly once via [`KernelBackend::for_worker`] when the pool is
//! built, and every stage — compute shards, shuffle route/build phases,
//! gathers, Σ merges — is a batch of jobs dispatched to the same
//! threads.
//!
//! # Lifecycle
//!
//! * a `session::Session` — the supported front door — builds one pool
//!   at construction and keeps it for its whole lifetime: every query,
//!   explain, gradient, and training step of the session shares the
//!   same `w` backends (the pool-reuse tests assert this);
//! * the deprecated free functions ([`exec::dist_eval`]/
//!   [`exec::dist_eval_tape`]) build one pool per evaluation, and the
//!   deprecated `DistTrainer::step` one per training step.
//!
//! The pool engages under the same conditions stage threading always
//! had ([`WorkerPool::engages`]): `ClusterConfig::parallel` is set,
//! there is more than one worker, and the virtual cluster is no wider
//! than the host's core count (oversubscribed shards would time-share
//! cores and corrupt the measured per-shard compute behind
//! `virtual_time_s`). Otherwise execution stays on the serial reference
//! path, bitwise identical by construction.
//!
//! # Execution model
//!
//! [`WorkerPool::run`] submits one job per worker and blocks until all
//! complete — a BSP barrier. Results are returned in worker-index order
//! regardless of completion order, so pooled execution is *bitwise
//! interchangeable* with the serial path. A panicking job is resumed on
//! the driver after the round completes; the pool itself survives (the
//! worker thread catches the unwind), so a failed stage does not poison
//! the run that owns the pool.
//!
//! [`WorkerPool::try_run`]/[`try_run_with`](WorkerPool::try_run_with)
//! are the fault-tolerant flavors the retryable stage bodies use: the
//! same barrier, but per-shard outcomes come back as typed
//! `Result<T, JobFailure>`s instead of unwinding the driver — a panic
//! whose payload downcasts to [`fault::InjectedFault`] is classified
//! [`JobFailure::Injected`] (retryable), anything else
//! [`JobFailure::Fatal`] (a genuine bug, never retried). The pool stays
//! usable either way.
//!
//! [`fault::InjectedFault`]: super::fault::InjectedFault
//!
//! # Multi-owner contract
//!
//! Since the serving layer (PR 9) a pool may be shared — `Arc<WorkerPool>`
//! held by a `session::Session` state and every `serve::Client` over it —
//! and **rounds may be dispatched concurrently from any number of
//! threads**. The contract:
//!
//! * Every round is private: it ships its jobs under one lock on the
//!   senders (so a round's job batch lands contiguously on each worker's
//!   queue) and collects results over its own channel, so interleaved
//!   rounds never mix results. Workers drain queued jobs in FIFO order;
//!   concurrent rounds time-share the workers at job granularity.
//! * Jobs must never dispatch nested rounds on the same pool: a job
//!   waiting for a round whose jobs are queued behind it on its own
//!   worker would deadlock. The executor honors this by construction —
//!   all dispatch happens from driver threads.
//! * Panics stay with the round that owns them: a panicking job unwinds
//!   (or, in the `try_run` flavors, classifies) on *that* round's driver;
//!   other in-flight rounds and later rounds are untouched (the worker
//!   thread catches the unwind either way).
//! * Dropping one owner's handle never stops the pool — worker threads
//!   exit only when the *last* handle drops (and the owning `Drop` joins
//!   them).
//!
//! [`rounds_inflight`](WorkerPool::rounds_inflight) /
//! [`rounds_high_water`](WorkerPool::rounds_high_water) gauge concurrent
//! dispatch — the serving layer's admission tests probe the high-water
//! mark to prove its in-flight cap was never exceeded.
//!
//! [`KernelBackend`]: crate::kernels::KernelBackend
//! [`KernelBackend::for_worker`]: crate::kernels::KernelBackend::for_worker
//! [`exec::dist_eval`]: super::exec::dist_eval
//! [`exec::dist_eval_tape`]: super::exec::dist_eval_tape

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use super::fault::InjectedFault;
use super::mem::MemPolicy;
use super::spill::SpillSpace;
use super::ClusterConfig;
use crate::kernels::KernelBackend;

/// A job shipped to one worker thread: it runs against the thread's own
/// backend instance and reports through a channel it captured.
type Job = Box<dyn FnOnce(&dyn KernelBackend) + Send>;

/// Why one worker's job in a [`WorkerPool::try_run`] round did not
/// produce a value — the typed classification of a caught panic.
#[derive(Debug)]
pub enum JobFailure {
    /// The job panicked with a scripted [`InjectedFault`] payload
    /// (`FaultKind::PanicJob`) — retryable by lineage replay.
    Injected(InjectedFault),
    /// The job panicked with anything else — a genuine bug, rendered
    /// from its `&str`/`String` payload. Never retried.
    Fatal(String),
}

/// Classify a caught unwind payload: scripted faults downcast to
/// [`InjectedFault`]; everything else is a genuine bug.
pub(crate) fn classify_panic(p: Box<dyn std::any::Any + Send>) -> JobFailure {
    match p.downcast::<InjectedFault>() {
        Ok(f) => JobFailure::Injected(*f),
        Err(p) => {
            let msg = if let Some(s) = p.downcast_ref::<&str>() {
                (*s).to_string()
            } else if let Some(s) = p.downcast_ref::<String>() {
                s.clone()
            } else {
                "<non-string panic payload>".to_string()
            };
            JobFailure::Fatal(msg)
        }
    }
}

/// A persistent pool of `w` worker threads, each owning one
/// [`KernelBackend`](crate::kernels::KernelBackend) instance for its
/// lifetime. See the [module docs](self) for the lifecycle and the
/// execution model.
pub struct WorkerPool {
    /// One job channel per worker. Behind a lock so (a) the pool is
    /// `Sync` — concurrent owners dispatch rounds from any thread — and
    /// (b) each round's job batch is enqueued contiguously per worker.
    /// The lock covers only the enqueue, never the barrier wait.
    senders: Mutex<Vec<Sender<Job>>>,
    /// Worker count, denormalized out of `senders` so width checks never
    /// take the lock.
    width: usize,
    handles: Vec<JoinHandle<()>>,
    backend_name: &'static str,
    /// Rounds currently inside `dispatch`/`dispatch_try` (enqueue through
    /// barrier), across all owners. Decremented by a drop guard, so a
    /// round that unwinds out of the barrier still leaves the gauge
    /// exact.
    rounds_inflight: AtomicUsize,
    /// The most concurrent rounds ever observed on this pool — the probe
    /// the serving layer's admission-cap tests assert against.
    rounds_high_water: AtomicUsize,
    /// Session-lifetime spill scratch: one tree for the pool, one
    /// subdirectory per worker, created by [`new_for`](Self::new_for)
    /// when the cluster configuration can actually spill
    /// (`MemPolicy::Spill` with a finite budget) and removed when the
    /// pool drops. Workers own their subdirectory: each creates it on
    /// its first spill and every run file it writes deletes itself when
    /// the pass (or the unwinding stage) finishes.
    spill: Option<Arc<SpillSpace>>,
    /// The spill reservation this pool was built *for*: `None` for a
    /// non-spilling shape (or [`new`](Self::new)), `Some(root hint)`
    /// for a budgeted-Spill shape — recorded independently of whether
    /// the reservation succeeded, so pool caches can detect a config
    /// change via [`spill_matches`](Self::spill_matches) without
    /// rebuilding forever when the scratch root is unwritable.
    spill_shape: Option<Option<std::path::PathBuf>>,
}

impl WorkerPool {
    /// Park `workers` threads, minting one backend instance per worker
    /// from `backend` (this is the only place `for_worker` is called —
    /// once per worker per pool, however many stages and evaluations the
    /// pool later serves).
    ///
    /// `new` itself does not enforce the host-core cap: callers that
    /// bypass [`maybe_new`](Self::maybe_new) and hand an oversubscribed
    /// pool to the executor accept that time-shared shards inflate the
    /// measured per-shard compute behind `virtual_time_s` (tests do this
    /// deliberately on small hosts; production callers should go through
    /// `maybe_new`).
    pub fn new(workers: usize, backend: &dyn KernelBackend) -> WorkerPool {
        assert!(workers >= 1, "a pool needs at least one worker");
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for wi in 0..workers {
            let be = backend.for_worker();
            let (tx, rx) = channel::<Job>();
            senders.push(tx);
            let handle = std::thread::Builder::new()
                .name(format!("relad-worker-{wi}"))
                .spawn(move || {
                    let be: &dyn KernelBackend = &*be;
                    for job in rx {
                        job(be);
                    }
                })
                .expect("failed to spawn pool worker thread");
            handles.push(handle);
        }
        WorkerPool {
            senders: Mutex::new(senders),
            width: workers,
            handles,
            backend_name: backend.name(),
            rounds_inflight: AtomicUsize::new(0),
            rounds_high_water: AtomicUsize::new(0),
            spill: None,
            spill_shape: None,
        }
    }

    /// [`new`](Self::new) for a concrete cluster shape: additionally
    /// reserves the pool's spill scratch space when `cfg` can spill
    /// (`MemPolicy::Spill` with a finite budget), so every evaluation
    /// the pool serves shares one scratch tree instead of creating and
    /// removing its own. Scratch reservation failing (unwritable spill
    /// root) is not fatal here — the executor creates a per-evaluation
    /// space on demand and surfaces the I/O error at spill time, where
    /// it is actually needed.
    pub fn new_for(cfg: &ClusterConfig, backend: &dyn KernelBackend) -> WorkerPool {
        let mut pool = WorkerPool::new(cfg.workers, backend);
        if cfg.policy == MemPolicy::Spill && cfg.budget.is_some() {
            pool.spill_shape = Some(cfg.spill_dir.clone());
            pool.spill = SpillSpace::create(cfg.spill_dir.as_deref())
                .ok()
                .map(Arc::new);
        }
        pool
    }

    /// The pool's spill scratch space, if this cluster shape reserved
    /// one (a handle: the space lives as long as any holder).
    pub fn spill_space(&self) -> Option<Arc<SpillSpace>> {
        self.spill.clone()
    }

    /// Whether the spill reservation this pool was built for still
    /// matches `cfg`. Pool caches that reuse a pool across steps (the
    /// legacy `TrainPipeline`) must rebuild when this is false — a
    /// reused pool would otherwise keep serving a scratch setup (or the
    /// lack of one) captured under an older configuration.
    pub fn spill_matches(&self, cfg: &ClusterConfig) -> bool {
        let want = (cfg.policy == MemPolicy::Spill && cfg.budget.is_some())
            .then(|| cfg.spill_dir.clone());
        self.spill_shape == want
    }

    /// Whether a pool would engage for this cluster shape: threading
    /// requested, more than one worker, and no more workers than host
    /// cores (wider virtual clusters keep the serial reference semantics
    /// so measured per-shard compute stays honest).
    pub fn engages(cfg: &ClusterConfig) -> bool {
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        cfg.parallel && cfg.workers > 1 && cfg.workers <= cores
    }

    /// Build a pool iff [`engages`](Self::engages) says threading is on
    /// for this configuration (with the spill scratch reservation of
    /// [`new_for`](Self::new_for)).
    pub fn maybe_new(cfg: &ClusterConfig, backend: &dyn KernelBackend) -> Option<WorkerPool> {
        WorkerPool::engages(cfg).then(|| WorkerPool::new_for(cfg, backend))
    }

    pub fn workers(&self) -> usize {
        self.width
    }

    /// Rounds currently in flight (enqueue through barrier) across every
    /// owner of this pool.
    pub fn rounds_inflight(&self) -> usize {
        self.rounds_inflight.load(Ordering::SeqCst)
    }

    /// The most concurrent rounds ever in flight on this pool — the
    /// admission-control probe: a serving engine capping in-flight BSP
    /// rounds at `k` must never let this exceed `k`.
    pub fn rounds_high_water(&self) -> usize {
        self.rounds_high_water.load(Ordering::SeqCst)
    }

    /// Name of the backend the pool's worker instances were minted from
    /// (pool caches must rebuild when the backend changes).
    pub fn backend_name(&self) -> &'static str {
        self.backend_name
    }

    /// One BSP round: run `f(worker_index, worker_backend)` once on every
    /// worker, block until all finish, and return the results in
    /// worker-index order. A panicking job is re-raised on the driver
    /// after the round drains; the pool stays usable.
    pub fn run<T, F>(&self, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize, &dyn KernelBackend) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let jobs = (0..self.workers())
            .map(|wi| {
                let f = Arc::clone(&f);
                Box::new(move |be: &dyn KernelBackend| (*f)(wi, be))
                    as Box<dyn FnOnce(&dyn KernelBackend) -> T + Send>
            })
            .collect();
        self.dispatch(jobs)
    }

    /// As [`run`](Self::run), with one owned input per worker:
    /// `f(worker_index, inputs[worker_index], worker_backend)`. Used by
    /// the shuffle phases, whose per-worker inputs (shard handles,
    /// inbound bucket lists) are moved into the job that consumes them.
    pub fn run_with<I, T, F>(&self, inputs: Vec<I>, f: F) -> Vec<T>
    where
        I: Send + 'static,
        T: Send + 'static,
        F: Fn(usize, I, &dyn KernelBackend) -> T + Send + Sync + 'static,
    {
        assert_eq!(
            inputs.len(),
            self.workers(),
            "run_with needs exactly one input per worker"
        );
        let f = Arc::new(f);
        let jobs = inputs
            .into_iter()
            .enumerate()
            .map(|(wi, input)| {
                let f = Arc::clone(&f);
                Box::new(move |be: &dyn KernelBackend| (*f)(wi, input, be))
                    as Box<dyn FnOnce(&dyn KernelBackend) -> T + Send>
            })
            .collect();
        self.dispatch(jobs)
    }

    /// The fault-tolerant [`run`](Self::run): the same one-job-per-worker
    /// barrier, but each shard's outcome comes back as a typed
    /// `Result` — `Ok(T)` for a completed job, `Err(JobFailure)` for a
    /// panicked one, classified injected-retryable vs fatal. The driver
    /// never unwinds; the pool stays usable for the retry round.
    pub fn try_run<T, F>(&self, f: F) -> Vec<Result<T, JobFailure>>
    where
        T: Send + 'static,
        F: Fn(usize, &dyn KernelBackend) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let jobs = (0..self.workers())
            .map(|wi| {
                let f = Arc::clone(&f);
                Box::new(move |be: &dyn KernelBackend| (*f)(wi, be))
                    as Box<dyn FnOnce(&dyn KernelBackend) -> T + Send>
            })
            .collect();
        self.dispatch_try(jobs)
    }

    /// As [`try_run`](Self::try_run), with one owned input per worker
    /// (the fault-tolerant [`run_with`](Self::run_with)).
    pub fn try_run_with<I, T, F>(&self, inputs: Vec<I>, f: F) -> Vec<Result<T, JobFailure>>
    where
        I: Send + 'static,
        T: Send + 'static,
        F: Fn(usize, I, &dyn KernelBackend) -> T + Send + Sync + 'static,
    {
        assert_eq!(
            inputs.len(),
            self.workers(),
            "try_run_with needs exactly one input per worker"
        );
        let f = Arc::new(f);
        let jobs = inputs
            .into_iter()
            .enumerate()
            .map(|(wi, input)| {
                let f = Arc::clone(&f);
                Box::new(move |be: &dyn KernelBackend| (*f)(wi, input, be))
                    as Box<dyn FnOnce(&dyn KernelBackend) -> T + Send>
            })
            .collect();
        self.dispatch_try(jobs)
    }

    /// The barrier at the bottom of both `run` flavors: ship one job per
    /// worker, wait for all `w` results, return them in worker-index
    /// order, and re-raise the first panic *received* (completion order,
    /// not worker order) after the round drains.
    fn dispatch<T: Send + 'static>(
        &self,
        jobs: Vec<Box<dyn FnOnce(&dyn KernelBackend) -> T + Send>>,
    ) -> Vec<T> {
        let w = self.workers();
        debug_assert_eq!(jobs.len(), w);
        let _round = RoundGuard::enter(self);
        let (tx, rx) = channel::<(usize, std::thread::Result<T>)>();
        {
            let senders = self.senders.lock().unwrap();
            for ((wi, sender), job) in senders.iter().enumerate().zip(jobs) {
                let tx = tx.clone();
                let wrapped: Job = Box::new(move |be| {
                    let res = catch_unwind(AssertUnwindSafe(move || job(be)));
                    // The driver may already have unwound on an earlier
                    // worker's panic and dropped the receiver; that is fine.
                    let _ = tx.send((wi, res));
                });
                sender.send(wrapped).expect("pool worker thread is gone");
            }
        }
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..w).map(|_| None).collect();
        let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
        for _ in 0..w {
            match rx.recv() {
                Ok((wi, Ok(v))) => slots[wi] = Some(v),
                Ok((_, Err(p))) => {
                    if panic.is_none() {
                        panic = Some(p);
                    }
                }
                Err(_) => break,
            }
        }
        if let Some(p) = panic {
            std::panic::resume_unwind(p);
        }
        slots
            .into_iter()
            .map(|s| s.expect("pool worker produced no result"))
            .collect()
    }

    /// The fault-tolerant barrier behind the `try_run` flavors: every
    /// shard's caught unwind is classified ([`classify_panic`]) instead
    /// of re-raised, and all `w` slots come back filled.
    fn dispatch_try<T: Send + 'static>(
        &self,
        jobs: Vec<Box<dyn FnOnce(&dyn KernelBackend) -> T + Send>>,
    ) -> Vec<Result<T, JobFailure>> {
        let w = self.workers();
        debug_assert_eq!(jobs.len(), w);
        let _round = RoundGuard::enter(self);
        let (tx, rx) = channel::<(usize, std::thread::Result<T>)>();
        {
            let senders = self.senders.lock().unwrap();
            for ((wi, sender), job) in senders.iter().enumerate().zip(jobs) {
                let tx = tx.clone();
                let wrapped: Job = Box::new(move |be| {
                    let res = catch_unwind(AssertUnwindSafe(move || job(be)));
                    let _ = tx.send((wi, res));
                });
                sender.send(wrapped).expect("pool worker thread is gone");
            }
        }
        drop(tx);
        let mut slots: Vec<Option<Result<T, JobFailure>>> = (0..w).map(|_| None).collect();
        for _ in 0..w {
            match rx.recv() {
                Ok((wi, Ok(v))) => slots[wi] = Some(Ok(v)),
                Ok((wi, Err(p))) => slots[wi] = Some(Err(classify_panic(p))),
                Err(_) => break,
            }
        }
        slots
            .into_iter()
            .map(|s| s.expect("pool worker produced no result"))
            .collect()
    }
}

/// RAII gauge of one dispatched round: bumps the in-flight count (and
/// the high-water mark) on entry and decrements on drop — including the
/// `resume_unwind` path out of a panicked round's barrier.
struct RoundGuard<'p> {
    pool: &'p WorkerPool,
}

impl<'p> RoundGuard<'p> {
    fn enter(pool: &'p WorkerPool) -> RoundGuard<'p> {
        let now = pool.rounds_inflight.fetch_add(1, Ordering::SeqCst) + 1;
        pool.rounds_high_water.fetch_max(now, Ordering::SeqCst);
        RoundGuard { pool }
    }
}

impl Drop for RoundGuard<'_> {
    fn drop(&mut self) {
        self.pool.rounds_inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Disconnect every job channel; workers drain and exit, then the
        // threads are joined so no worker outlives the pool handle.
        // (Shared pools reach here only when the *last* `Arc` owner
        // drops — a client handle going away never runs this.)
        self.senders.lock().unwrap().clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::NativeBackend;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn run_returns_results_in_worker_index_order() {
        let pool = WorkerPool::new(4, &NativeBackend);
        // Stagger completion inversely to index: results must still come
        // back ordered by worker index.
        let got = pool.run(|wi, _| {
            std::thread::sleep(std::time::Duration::from_millis(3 * (4 - wi as u64)));
            wi * 10
        });
        assert_eq!(got, vec![0, 10, 20, 30]);
        // And the same pool serves later rounds (reuse, no respawn).
        let again = pool.run(|wi, _| wi + 1);
        assert_eq!(again, vec![1, 2, 3, 4]);
    }

    #[test]
    fn run_with_hands_each_worker_its_own_input() {
        let pool = WorkerPool::new(3, &NativeBackend);
        let inputs = vec![vec![1u64], vec![2, 2], vec![3, 3, 3]];
        let got = pool.run_with(inputs, |wi, v: Vec<u64>, _| (wi, v.iter().sum::<u64>()));
        assert_eq!(got, vec![(0, 1), (1, 4), (2, 9)]);
    }

    #[test]
    fn mints_one_backend_per_worker_at_construction_only() {
        struct Counting(Arc<AtomicUsize>);
        impl KernelBackend for Counting {
            fn unary(
                &self,
                k: &crate::kernels::UnaryKernel,
                key: &crate::ra::Key,
                x: &crate::ra::Chunk,
            ) -> crate::ra::Chunk {
                crate::kernels::native::apply_unary(k, key, x)
            }
            fn binary(
                &self,
                k: &crate::kernels::BinaryKernel,
                key: &crate::ra::Key,
                l: &crate::ra::Chunk,
                r: &crate::ra::Chunk,
            ) -> crate::ra::Chunk {
                crate::kernels::native::apply_binary(k, key, l, r)
            }
            fn name(&self) -> &'static str {
                "counting"
            }
            fn for_worker(&self) -> Box<dyn KernelBackend + Send + Sync> {
                self.0.fetch_add(1, Ordering::SeqCst);
                Box::new(NativeBackend)
            }
        }
        let minted = Arc::new(AtomicUsize::new(0));
        let pool = WorkerPool::new(3, &Counting(Arc::clone(&minted)));
        assert_eq!(minted.load(Ordering::SeqCst), 3);
        for _ in 0..5 {
            pool.run(|wi, be| {
                assert_eq!(be.name(), "native");
                wi
            });
        }
        // Five rounds later: still exactly one mint per worker.
        assert_eq!(minted.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn panicking_job_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2, &NativeBackend);
        let res = catch_unwind(AssertUnwindSafe(|| {
            pool.run(|wi, _| {
                if wi == 1 {
                    panic!("stage shard failed");
                }
                wi
            })
        }));
        assert!(res.is_err(), "worker panic must reach the driver");
        // The pool is not poisoned: the next round runs normally.
        assert_eq!(pool.run(|wi, _| wi), vec![0, 1]);
    }

    #[test]
    fn try_run_returns_typed_per_shard_results_and_classifies_panics() {
        use crate::dist::fault::{InjectedFault, InjectionPoint};
        let pool = WorkerPool::new(3, &NativeBackend);
        let got = pool.try_run(|wi, _| {
            match wi {
                1 => std::panic::panic_any(InjectedFault {
                    point: InjectionPoint::JoinBuild,
                    worker: 1,
                    occurrence: 4,
                }),
                2 => panic!("genuine bug on worker {wi}"),
                _ => {}
            }
            wi * 10
        });
        assert!(matches!(got[0], Ok(0)));
        match &got[1] {
            Err(JobFailure::Injected(f)) => {
                assert_eq!(f.point, InjectionPoint::JoinBuild);
                assert_eq!(f.worker, 1);
                assert_eq!(f.occurrence, 4);
            }
            other => panic!("worker 1 should be Injected, got {other:?}"),
        }
        match &got[2] {
            Err(JobFailure::Fatal(msg)) => assert!(msg.contains("genuine bug on worker 2")),
            other => panic!("worker 2 should be Fatal, got {other:?}"),
        }
        // No driver unwind, no poisoning: both barrier flavors keep
        // working on the same pool after the failed round.
        assert_eq!(pool.run(|wi, _| wi), vec![0, 1, 2]);
        assert!(pool.try_run(|wi, _| wi).into_iter().all(|r| r.is_ok()));
    }

    #[test]
    fn pool_is_not_poisoned_across_panic_then_clean_rounds() {
        // The PR 3 regression scenario, tested independently of the
        // executor: a propagated panic round, then several clean rounds
        // (both `run` and `try_run_with`), all on the same channels.
        let pool = WorkerPool::new(2, &NativeBackend);
        for round in 0..3 {
            let res = catch_unwind(AssertUnwindSafe(|| {
                pool.run(move |wi, _| {
                    if wi == round % 2 {
                        panic!("round {round} shard failure");
                    }
                    wi
                })
            }));
            assert!(res.is_err());
            assert_eq!(pool.run(|wi, _| wi), vec![0, 1]);
            let with = pool.try_run_with(vec![10usize, 20], |wi, x, _| wi + x);
            let vals: Vec<usize> = with.into_iter().map(|r| r.unwrap()).collect();
            assert_eq!(vals, vec![10, 21]);
        }
    }

    #[test]
    fn classify_panic_payload_kinds() {
        use crate::dist::fault::{InjectedFault, InjectionPoint};
        let injected: Box<dyn std::any::Any + Send> = Box::new(InjectedFault {
            point: InjectionPoint::SpillRead,
            worker: 0,
            occurrence: 1,
        });
        assert!(matches!(classify_panic(injected), JobFailure::Injected(_)));
        let s: Box<dyn std::any::Any + Send> = Box::new("static str panic");
        match classify_panic(s) {
            JobFailure::Fatal(m) => assert_eq!(m, "static str panic"),
            other => panic!("{other:?}"),
        }
        let owned: Box<dyn std::any::Any + Send> = Box::new(String::from("owned panic"));
        match classify_panic(owned) {
            JobFailure::Fatal(m) => assert_eq!(m, "owned panic"),
            other => panic!("{other:?}"),
        }
        let odd: Box<dyn std::any::Any + Send> = Box::new(42u32);
        match classify_panic(odd) {
            JobFailure::Fatal(m) => assert_eq!(m, "<non-string panic payload>"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn pool_reserves_spill_scratch_for_spilling_shapes_only() {
        let plain = WorkerPool::new_for(&ClusterConfig::new(2), &NativeBackend);
        assert!(
            plain.spill_space().is_none(),
            "unbudgeted shape must not touch the filesystem"
        );
        let fail_cfg = ClusterConfig::new(2)
            .with_budget(1024)
            .with_policy(MemPolicy::Fail);
        let fail = WorkerPool::new_for(&fail_cfg, &NativeBackend);
        assert!(fail.spill_space().is_none(), "Fail policy never spills");
        let pool = WorkerPool::new_for(&ClusterConfig::new(2).with_budget(1024), &NativeBackend);
        let space = pool.spill_space().expect("budgeted Spill reserves scratch");
        let root = space.root().to_path_buf();
        assert!(root.exists());
        drop(space);
        drop(pool);
        assert!(!root.exists(), "pool drop must remove its scratch tree");
    }

    #[test]
    fn spill_matches_detects_config_changes() {
        let plain_cfg = ClusterConfig::new(2);
        let budgeted = ClusterConfig::new(2).with_budget(1024);
        let rerooted = ClusterConfig::new(2)
            .with_budget(1024)
            .with_spill_dir(std::env::temp_dir().join("relad-elsewhere"));
        let plain = WorkerPool::new_for(&plain_cfg, &NativeBackend);
        assert!(plain.spill_matches(&plain_cfg));
        assert!(!plain.spill_matches(&budgeted), "gaining a budget must rebuild");
        let pool = WorkerPool::new_for(&budgeted, &NativeBackend);
        assert!(pool.spill_matches(&budgeted));
        assert!(!pool.spill_matches(&plain_cfg), "losing the budget must rebuild");
        assert!(!pool.spill_matches(&rerooted), "moving the scratch root must rebuild");
        // `new()` pools (cfg-less) behave as non-spilling shapes.
        assert!(WorkerPool::new(2, &NativeBackend).spill_matches(&plain_cfg));
    }

    /// The multi-owner contract, concurrency half: two `Arc` owners
    /// dispatch rounds from their own threads at the same time; every
    /// round's results stay private and ordered, and the in-flight gauge
    /// observes the overlap. The rounds are forced to actually overlap:
    /// each round's jobs spin until both rounds are in flight.
    #[test]
    fn concurrent_rounds_from_two_owners_stay_private() {
        let pool = Arc::new(WorkerPool::new(2, &NativeBackend));
        let barrier = Arc::new(std::sync::Barrier::new(2));
        let spawn = |tag: usize| {
            let pool = Arc::clone(&pool);
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let probe = Arc::clone(&pool);
                barrier.wait();
                pool.run(move |wi, _| {
                    // Wait (bounded) until both rounds have been in
                    // flight — the high-water mark is monotone, so the
                    // later round's jobs see it immediately.
                    for _ in 0..5000 {
                        if probe.rounds_high_water() >= 2 {
                            break;
                        }
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    tag * 100 + wi
                })
            })
        };
        let a = spawn(1);
        let b = spawn(2);
        assert_eq!(a.join().unwrap(), vec![100, 101]);
        assert_eq!(b.join().unwrap(), vec![200, 201]);
        assert_eq!(pool.rounds_inflight(), 0, "drop guards must zero the gauge");
        assert_eq!(pool.rounds_high_water(), 2, "the rounds must have overlapped");
    }

    /// The multi-owner contract, isolation half (extends the PR 7
    /// poisoning regression across owners): one owner's panicking rounds
    /// never poison another owner's concurrent clean rounds, and an
    /// owner dropping its handle mid-sequence leaves the pool fully
    /// usable for the survivors.
    #[test]
    fn owner_panic_and_drop_never_poison_other_owners() {
        let pool = Arc::new(WorkerPool::new(2, &NativeBackend));
        let faulty = Arc::clone(&pool);
        let noisy = std::thread::spawn(move || {
            for round in 0..3 {
                let res = catch_unwind(AssertUnwindSafe(|| {
                    faulty.run(move |wi, _| {
                        if wi == round % 2 {
                            panic!("owner-a round {round} shard failure");
                        }
                        wi
                    })
                }));
                assert!(res.is_err(), "the panic belongs to this owner's round");
            }
            // This owner's handle drops here, mid-life of the pool.
        });
        // The second owner keeps dispatching clean rounds throughout.
        for _ in 0..20 {
            assert_eq!(pool.run(|wi, _| wi * 2), vec![0, 2]);
        }
        noisy.join().unwrap();
        // After the first owner is gone entirely: still not poisoned.
        assert_eq!(pool.run(|wi, _| wi + 7), vec![7, 8]);
        assert!(pool.try_run(|wi, _| wi).into_iter().all(|r| r.is_ok()));
        assert_eq!(pool.rounds_inflight(), 0);
    }

    #[test]
    fn engages_respects_parallel_flag_and_width() {
        let on = ClusterConfig::new(2);
        let off = ClusterConfig::new(2).with_parallel(false);
        let one = ClusterConfig::new(1);
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        assert_eq!(WorkerPool::engages(&on), 2 <= cores);
        assert!(!WorkerPool::engages(&off));
        assert!(!WorkerPool::engages(&one));
        // Wider than any host: never threads.
        let wide = ClusterConfig::new(100_000);
        assert!(!WorkerPool::engages(&wide));
        assert!(WorkerPool::maybe_new(&off, &NativeBackend).is_none());
    }
}
