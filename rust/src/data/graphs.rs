//! Power-law graph generation for the GCN experiments (Tables 2–3).

use crate::ra::{Chunk, Key, Relation};
use crate::util::{FxHashSet, Prng};

/// A node-classification graph in both relational (tensor-relation) and
/// edge-list (baseline systems) form.
pub struct GraphDataset {
    pub name: String,
    pub n_nodes: usize,
    pub n_edges: usize,
    pub feat_dim: usize,
    pub n_labels: usize,
    /// `Edge(⟨src,dst⟩ → (1,1) normalized weight)`, self-loops included —
    /// the paper's Edge relation.
    pub edges: Relation,
    /// Raw directed edge list (excluding self-loops), for the baselines.
    pub edge_list: Vec<(u32, u32)>,
    /// Per-node out-degree + 1 (self-loop), shared with baselines.
    pub degree: Vec<u32>,
    /// `Node(⟨id⟩ → (1, F))` feature relation.
    pub feats: Relation,
    /// `⟨id⟩ → (1, L)` one-hot labels for *labeled* nodes only (the
    /// all-zero rows of unlabeled nodes are simply absent = sparse).
    pub labels: Relation,
    pub labeled: Vec<u32>,
}

impl GraphDataset {
    /// Bytes of the raw graph payload (edges + features + labels).
    pub fn nbytes(&self) -> u64 {
        (self.edges.nbytes() + self.feats.nbytes() + self.labels.nbytes()) as u64
    }
}

/// Chung-Lu style power-law graph: endpoints drawn Zipf(s≈0.75), edges
/// deduplicated, symmetrically normalized weights 1/√(dᵤdᵥ) as in GCN.
pub fn power_law_graph(
    name: &str,
    n_nodes: usize,
    n_edges: usize,
    feat_dim: usize,
    n_labels: usize,
    label_frac: f32,
    seed: u64,
) -> GraphDataset {
    let mut rng = Prng::new(seed);
    let mut seen: FxHashSet<u64> = FxHashSet::default();
    let mut edge_list = Vec::with_capacity(n_edges);
    let mut degree = vec![1u32; n_nodes]; // self loop
    let mut attempts = 0usize;
    while edge_list.len() < n_edges && attempts < n_edges * 8 {
        attempts += 1;
        let a = rng.zipf(n_nodes as u64, 0.75) as u32;
        let b = rng.zipf(n_nodes as u64, 0.75) as u32;
        if a == b {
            continue;
        }
        // undirected: canonicalize so (u,v)/(v,u) dedup together
        let (u, v) = (a.min(b), a.max(b));
        let code = ((u as u64) << 32) | v as u64;
        if seen.insert(code) {
            edge_list.push((u, v));
            degree[u as usize] += 1;
            degree[v as usize] += 1;
        }
    }

    // Edge relation with symmetric normalization (both directions +
    // self loops, GCN's Â = D^{-1/2}(A+I)D^{-1/2}).
    let mut edges = Relation::with_capacity(edge_list.len() * 2 + n_nodes);
    for &(u, v) in &edge_list {
        let w = 1.0 / ((degree[u as usize] as f32).sqrt() * (degree[v as usize] as f32).sqrt());
        edges.insert(Key::k2(u as i64, v as i64), Chunk::scalar(w));
        edges.insert(Key::k2(v as i64, u as i64), Chunk::scalar(w));
    }
    for u in 0..n_nodes {
        let w = 1.0 / degree[u] as f32;
        edges.insert(Key::k2(u as i64, u as i64), Chunk::scalar(w));
    }

    let mut feats = Relation::with_capacity(n_nodes);
    for u in 0..n_nodes {
        feats.insert(
            Key::k1(u as i64),
            Chunk::random(1, feat_dim, &mut rng, 1.0),
        );
    }

    let n_labeled = ((n_nodes as f32) * label_frac).max(1.0) as usize;
    let labeled: Vec<u32> = rng
        .sample_indices(n_nodes, n_labeled)
        .into_iter()
        .map(|x| x as u32)
        .collect();
    let mut labels = Relation::with_capacity(labeled.len());
    for &u in &labeled {
        let mut oh = Chunk::zeros(1, n_labels);
        let class = rng.below(n_labels as u64) as usize;
        oh.set(0, class, 1.0);
        labels.insert(Key::k1(u as i64), oh);
    }

    GraphDataset {
        name: name.to_string(),
        n_nodes,
        n_edges: edge_list.len(),
        feat_dim,
        n_labels,
        edges,
        edge_list,
        degree,
        feats,
        labels,
        labeled,
    }
}

/// Paper Table 1 datasets at a documented scale (DESIGN.md).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GraphScale {
    /// ogbn-arxiv (0.2M, 1.1M) at 1/24.
    Arxiv,
    /// ogbn-products (0.1M, 39M) at 1/96 — keeps the very high average
    /// degree that makes products expensive.
    Products,
    /// ogbn-papers100M (0.1B, 1.6B) at 1/4096.
    Papers100M,
    /// friendster (65.6M, 3.6B) at 1/16384.
    Friendster,
}

impl GraphScale {
    /// (nodes, edges, feat, labels, scale_factor)
    pub fn params(&self) -> (usize, usize, usize, usize, u64) {
        match self {
            GraphScale::Arxiv => (8_400, 46_000, 64, 40, 24),
            GraphScale::Products => (2_500, 160_000, 64, 47, 96),
            GraphScale::Papers100M => (26_000, 390_000, 64, 40, 4096),
            GraphScale::Friendster => (4_000, 220_000, 64, 40, 16384),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            GraphScale::Arxiv => "ogbn-arxiv(1/24)",
            GraphScale::Products => "ogbn-products(1/96)",
            GraphScale::Papers100M => "ogbn-papers100M(1/4096)",
            GraphScale::Friendster => "friendster(1/16384)",
        }
    }

    /// The per-worker memory budget in bytes, scaled from the paper's
    /// 64 GB m5.4xlarge by this dataset's scale factor (so working-set /
    /// budget ratios match the real runs).
    pub fn scaled_budget(&self) -> u64 {
        let (_, _, _, _, scale) = self.params();
        (64u64 << 30) / scale
    }
}

impl GraphScale {
    /// Labeled (training) fraction — faithful to the real datasets:
    /// ogbn-arxiv's train split is ~54% of nodes, products ~8%,
    /// papers100M ~1.2%, friendster (synthetic labels) ~1%. This ratio
    /// controls the mini-batch-vs-full-graph cost ratio.
    pub fn label_frac(&self) -> f32 {
        match self {
            GraphScale::Arxiv => 0.54,
            GraphScale::Products => 0.08,
            GraphScale::Papers100M => 0.012,
            GraphScale::Friendster => 0.01,
        }
    }
}

pub fn scaled_dataset(which: GraphScale, seed: u64) -> GraphDataset {
    let (n, e, f, l, _) = which.params();
    power_law_graph(which.name(), n, e, f, l, which.label_frac(), seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn graph_shape_and_normalization() {
        let g = power_law_graph("t", 500, 2000, 16, 5, 0.5, 7);
        assert!(g.n_edges > 1500, "dedup left too few edges: {}", g.n_edges);
        // both directions + self loops
        assert_eq!(g.edges.len(), g.n_edges * 2 + 500);
        assert_eq!(g.feats.len(), 500);
        assert_eq!(g.labels.len(), 250);
        // all weights in (0, 1]
        for (_, w) in g.edges.iter() {
            let v = w.as_scalar();
            assert!(v > 0.0 && v <= 1.0);
        }
        // labels one-hot
        for (_, l) in g.labels.iter() {
            assert!((l.sum() - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn power_law_degree_skew() {
        let g = power_law_graph("t", 2000, 10_000, 4, 3, 0.1, 9);
        let mut deg = g.degree.clone();
        deg.sort_unstable_by(|a, b| b.cmp(a));
        // top node should have far more than average degree
        let avg = 2.0 * g.n_edges as f32 / 2000.0;
        assert!(deg[0] as f32 > avg * 5.0, "no skew: top={} avg={avg}", deg[0]);
    }

    #[test]
    fn deterministic_generation() {
        let a = power_law_graph("t", 300, 900, 8, 4, 0.2, 42);
        let b = power_law_graph("t", 300, 900, 8, 4, 0.2, 42);
        assert_eq!(a.edge_list, b.edge_list);
        assert!(a.feats.approx_eq(&b.feats, 0.0));
    }

    #[test]
    fn scaled_datasets_have_expected_ratios() {
        // friendster must stay sparser per node than products
        let (pn, pe, ..) = GraphScale::Products.params();
        let (fnodes, fe, ..) = GraphScale::Friendster.params();
        assert!((pe / pn) > (fe / fnodes));
        assert!(GraphScale::Papers100M.scaled_budget() < (64u64 << 30));
    }
}
