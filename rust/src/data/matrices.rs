//! Block-decomposed dense matrices for NNMF (Figure 2).

use crate::ra::{Chunk, Key, Relation};
use crate::util::Prng;

/// `⟨bi, bj⟩ → (chunk × chunk)` blocks of a dense matrix.
pub fn random_block_matrix(
    rows: usize,
    cols: usize,
    chunk: usize,
    rng: &mut Prng,
    nonneg: bool,
) -> Relation {
    let nb_r = rows.div_ceil(chunk);
    let nb_c = cols.div_ceil(chunk);
    let mut rel = Relation::with_capacity(nb_r * nb_c);
    for bi in 0..nb_r {
        for bj in 0..nb_c {
            let mut c = Chunk::random(chunk, chunk, rng, 0.5);
            if nonneg {
                c = c.map(f32::abs);
            }
            rel.insert(Key::k2(bi as i64, bj as i64), c);
        }
    }
    rel
}

/// Dense matrix size in blocks: (block_rows, block_cols).
pub fn block_grid(rows: usize, cols: usize, chunk: usize) -> (usize, usize) {
    (rows.div_ceil(chunk), cols.div_ceil(chunk))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_counts() {
        let mut rng = Prng::new(1);
        let r = random_block_matrix(130, 70, 64, &mut rng, false);
        assert_eq!(r.len(), 3 * 2);
        assert_eq!(block_grid(130, 70, 64), (3, 2));
    }

    #[test]
    fn nonneg_flag() {
        let mut rng = Prng::new(2);
        let r = random_block_matrix(64, 64, 64, &mut rng, true);
        for (_, c) in r.iter() {
            assert!(c.data().iter().all(|&x| x >= 0.0));
        }
    }
}
