//! Synthetic dataset generators.
//!
//! The paper's datasets (ogbn-*, friendster, Freebase) are multi-GB
//! downloads; we generate power-law synthetic equivalents preserving the
//! |V|/|E| ratios and label/feature dimensions at a documented scale
//! factor (DESIGN.md §Substitutions). Scaling/OOM behaviour depends on
//! |E|·D traffic and working-set-vs-budget ratios, which proportional
//! scaling preserves.

pub mod graphs;
pub mod kg;
pub mod matrices;

pub use graphs::{scaled_dataset, GraphDataset, GraphScale};
pub use kg::KgDataset;
