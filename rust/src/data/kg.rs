//! Synthetic knowledge graph for the KGE experiments (Figure 3).
//!
//! Freebase-like: Zipfian entity popularity, skewed relation frequency,
//! 90/5/5 train/valid/test split.

use crate::util::{FxHashSet, Prng};

pub struct KgDataset {
    pub n_entities: usize,
    pub n_relations: usize,
    /// (head, relation, tail) triples.
    pub train: Vec<(u32, u16, u32)>,
    pub valid: Vec<(u32, u16, u32)>,
    pub test: Vec<(u32, u16, u32)>,
}

impl KgDataset {
    /// Freebase at 1/512 scale: 86M/512 ≈ 168k entities, 339M/512 ≈ 662k
    /// edges is still large for per-iteration benches; `fraction` scales
    /// further (documented per bench).
    pub fn freebase_scaled(n_entities: usize, n_triples: usize, n_relations: usize, seed: u64) -> KgDataset {
        let mut rng = Prng::new(seed);
        let mut seen: FxHashSet<u64> = FxHashSet::default();
        let mut triples = Vec::with_capacity(n_triples);
        let mut attempts = 0;
        while triples.len() < n_triples && attempts < n_triples * 8 {
            attempts += 1;
            let h = rng.zipf(n_entities as u64, 0.8) as u32;
            let t = rng.zipf(n_entities as u64, 0.8) as u32;
            let r = rng.zipf(n_relations as u64, 1.0) as u16;
            if h == t {
                continue;
            }
            let code = ((h as u64) << 34) ^ ((r as u64) << 20) ^ t as u64;
            if seen.insert(code) {
                triples.push((h, r, t));
            }
        }
        rng.shuffle(&mut triples);
        let n = triples.len();
        let n_test = n / 20;
        let n_valid = n / 20;
        let test = triples.split_off(n - n_test);
        let valid = triples.split_off(n - n_test - n_valid);
        KgDataset {
            n_entities,
            n_relations,
            train: triples,
            valid,
            test,
        }
    }

    /// Sample a batch of positive triples plus `n_neg` corrupted
    /// negatives each (tail corruption, as in TransE).
    pub fn sample_batch(
        &self,
        batch: usize,
        n_neg: usize,
        rng: &mut Prng,
    ) -> (Vec<(u32, u16, u32)>, Vec<Vec<u32>>) {
        let mut pos = Vec::with_capacity(batch);
        let mut negs = Vec::with_capacity(batch);
        for _ in 0..batch {
            let t = self.train[rng.below(self.train.len() as u64) as usize];
            pos.push(t);
            negs.push(
                (0..n_neg)
                    .map(|_| rng.below(self.n_entities as u64) as u32)
                    .collect(),
            );
        }
        (pos, negs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_sizes() {
        let kg = KgDataset::freebase_scaled(1000, 5000, 16, 3);
        let total = kg.train.len() + kg.valid.len() + kg.test.len();
        assert!(total > 4000);
        assert!(kg.train.len() > total * 8 / 10);
        assert!(!kg.valid.is_empty() && !kg.test.is_empty());
    }

    #[test]
    fn batch_shape() {
        let kg = KgDataset::freebase_scaled(500, 2000, 8, 4);
        let mut rng = Prng::new(1);
        let (pos, negs) = kg.sample_batch(32, 5, &mut rng);
        assert_eq!(pos.len(), 32);
        assert_eq!(negs.len(), 32);
        assert!(negs.iter().all(|n| n.len() == 5));
        for &(h, r, t) in &pos {
            assert!((h as usize) < 500 && (t as usize) < 500 && (r as usize) < 8);
        }
    }

    #[test]
    fn zipf_entity_popularity() {
        let kg = KgDataset::freebase_scaled(2000, 20_000, 16, 5);
        let head0 = kg.train.iter().filter(|t| t.0 < 20).count();
        // top-1% entities should appear in far more than 1% of triples
        assert!(head0 > kg.train.len() / 20);
    }
}
